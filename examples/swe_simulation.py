"""Paper Fig. 8: 2D shallow-water equations across precisions.

Only the x-midpoint momentum-flux equation's multiplications run on the
configured multiplier (exactly the paper's substitution).

    PYTHONPATH=src python examples/swe_simulation.py [--steps N]
"""

import argparse

import numpy as np

from repro.precision import PRESETS
from repro.pde import SWEConfig, simulate_swe


def ascii_field(w, width=64, height=20):
    h, wid = w.shape
    ramp = " .:-=+*#%@"
    lo, hi = np.nanmin(w), np.nanmax(w)
    span = (hi - lo) or 1.0
    ys = np.linspace(0, h - 1, height).astype(int)
    xs = np.linspace(0, wid - 1, width).astype(int)
    for y in ys:
        line = ""
        for x in xs:
            v = w[y, x]
            line += "?" if not np.isfinite(v) else ramp[int((v - lo) / span * (len(ramp) - 1))]
        print(line)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()
    cfg = SWEConfig()
    print(f"SWE: {cfg.nx}x{cfg.ny} basin, depth {cfg.depth} m, bump {cfg.bump} m, "
          f"dt {cfg.dt:.1f}s x {args.steps} steps")
    ref, _ = simulate_swe(cfg, PRESETS["f32"], args.steps)
    wref = np.asarray(ref[0]) - cfg.depth
    for name in ("f32", "e5m10", "r2f2_16"):
        out, _ = simulate_swe(cfg, PRESETS[name], args.steps)
        w = np.asarray(out[0]) - cfg.depth
        print(f"\n--- {name} ---")
        ascii_field(w)
        if not np.isfinite(w).all():
            print(f"{name}: SIMULATION DESTROYED (h*h overflowed the fixed format)")
        elif name != "f32":
            corr = np.corrcoef(w.reshape(-1), wref.reshape(-1))[0, 1]
            print(f"{name}: field correlation vs f32 = {corr:.4f}")


if __name__ == "__main__":
    main()
