"""The registered PDE scenario zoo, one precision ladder each.

    PYTHONPATH=src python examples/pde_zoo.py [--steppers a,b] [--ensemble N]
                                              [--execution reference|fused|megakernel|auto]

Drives every workload through the shared ``repro.pde.solver.Simulation``
(no per-workload code): f32 reference, the failing E5M10 baseline, 16-bit
R2F2, and a *tracked* R2F2 run whose final per-site splits are printed —
the paper's precision-adjust unit carried across the whole simulation.
Scenario shapes/steps/metric offsets come from the same table the benchmark
suite uses (``benchmarks.bench_pde.scenarios``), so the zoo and
``BENCH_pde.json`` can never disagree about a workload. With
``--ensemble N``, each scenario also runs a vmapped N-member ensemble of
scaled initial conditions (add a sharding mesh via dist.sharding to spread
it over devices).

Fused quickstart (DESIGN.md §10): ``--execution fused`` runs every ladder
entry as multi-substep Pallas kernel chunks — same verdicts, one
``pallas_call`` per snapshot interval, tracked splits folded from the
kernels' range evidence::

    PYTHONPATH=src python examples/pde_zoo.py --execution fused --steppers burgers1d

Megakernel quickstart (DESIGN.md §14): ``--execution megakernel`` runs each
entry's ENTIRE horizon — snapshots and the on-chip adjust unit included —
in exactly one ``pallas_call``, bit-identical to the fused plane::

    PYTHONPATH=src python examples/pde_zoo.py --execution megakernel --steppers burgers1d

Profiling quickstart (DESIGN.md §11): ``--profile`` additionally captures
each scenario's range distributions on the f32 run and prints the
``repro.profile`` RangeReport (per-site dynamic range, exponent spread over
time, coverage at each flexible split) plus the splits the policy
autotuner would deploy::

    PYTHONPATH=src python examples/pde_zoo.py --profile --steppers heat1d
"""

import argparse
import dataclasses
import pathlib
import sys

import numpy as np

# examples/ are run as scripts; the bench scenario table lives in the
# repo-root `benchmarks` package
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.bench_pde import Scenario, measure, observe, scenarios  # noqa: E402

from repro.precision import PRESETS  # noqa: E402
from repro.pde import Simulation, get_stepper, known_steppers  # noqa: E402

TRACKED = dataclasses.replace(PRESETS["r2f2_16"], mode="rr_tracked")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steppers", default=None, help="comma-separated subset")
    ap.add_argument("--ensemble", type=int, default=0, help="vmapped ensemble size")
    ap.add_argument(
        "--execution",
        default="reference",
        choices=("reference", "fused", "megakernel", "auto"),
        help="arithmetic plane: stepwise engines, Pallas kernel chunks, the "
        "whole-horizon megakernel, or auto (prefers megakernel)",
    )
    ap.add_argument(
        "--profile",
        action="store_true",
        help="capture range distributions on the f32 run and print the "
        "repro.profile report + autotuned splits",
    )
    args = ap.parse_args()
    names = args.steppers.split(",") if args.steppers else known_steppers()
    table = scenarios()

    for name in names:
        stepper = get_stepper(name)
        # steppers registered outside the bench table still run, on defaults
        sc = table.get(name) or Scenario(cfg=stepper.default_config(), steps=400)
        print(f"\n=== {name} [{stepper.failure_mode}] — {stepper.story}"
              f" (execution={args.execution})")
        ref = None
        for prec_name, prec in (
            ("f32", PRESETS["f32"]),
            ("e5m10", PRESETS["e5m10"]),
            ("r2f2_16", PRESETS["r2f2_16"]),
            ("rr_tracked", TRACKED),
        ):
            sim = Simulation(name, sc.cfg, prec)
            res = sim.run(sc.steps, execution=args.execution)
            obs = observe(stepper, sim.cfg, res.state, sc.offset)
            if ref is None:
                ref = obs
                print(f"  {prec_name:11s} reference |max|={np.abs(ref).max():.4g}")
                continue
            m = measure(obs, ref, sc.judge)
            if not m["finite"]:
                print(f"  {prec_name:11s} DESTROYED (NaN/inf)")
                continue
            verdict = "" if m["correct"] else "  [WRONG]"
            line = f"  {prec_name:11s} rel L2 {m['rel']:.5f}"
            if sc.judge == "corr":  # show the number the verdict judges
                line += f" corr {m['corr']:.4f}"
            line += verdict
            if res.tracker is not None:
                ks = {n: int(res.tracker.k(n)) for n in res.tracker.names}
                line += f"   final splits {ks}"
            print(line)

        if args.profile:
            from repro.profile import capture_profile, synthesize_policy

            profile, _ = capture_profile(
                name, sc.cfg, steps=sc.steps, execution=args.execution
                if args.execution != "auto" else "reference",
            )
            print("  " + profile.report().summary().replace("\n", "\n  "))
            pol = synthesize_policy(profile)
            print("  autotuned splits: "
                  + ", ".join(f"{n}: k={d['k']} [{d['k_lo']},{d['k_hi']}]"
                              for n, d in pol.sites.items()))

        if args.ensemble:
            sim = Simulation(name, sc.cfg, PRESETS["r2f2_16"])
            u0 = sim.stepper.init_state(sim.cfg)
            scales = np.linspace(0.5, 1.5, args.ensemble, dtype=np.float32)
            u0b = scales.reshape((-1,) + (1,) * u0.ndim) * np.asarray(u0)[None]
            ens = sim.run_ensemble(u0b, max(1, sc.steps // 4), execution=args.execution)
            print(f"  ensemble[{args.ensemble}] state {ens.state.shape} "
                  f"finite={bool(np.isfinite(np.asarray(ens.state)).all())}")


if __name__ == "__main__":
    main()
