"""Quickstart: the paper's technique in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import FlexFormat, quantize_em, r2f2_mul_sequential, r2f2_multiply
from repro.precision import PRESETS, get_engine

fmt = FlexFormat(3, 9, 3)  # the paper's 16-bit <EB=3, MB=9, FX=3>

print("=== 1. flexible formats: one 16-bit layout, many tradeoffs ===")
for k in range(fmt.fx + 1):
    e, m = fmt.em(k)
    from repro.core import max_normal, min_normal
    print(
        f"  k={k}: E{e}M{m:<2d} range [{float(min_normal(e)):.2e}, "
        f"{float(max_normal(e, m)):.3e}], rel. precision 2^-{m+1}"
    )

print("\n=== 2. runtime reconfiguration beats any fixed 16-bit format ===")
rng = np.random.default_rng(0)
a = (10.0 ** rng.uniform(-4, 4, 100000)).astype(np.float32)
b = (10.0 ** rng.uniform(-4, 4, 100000)).astype(np.float32)
exact = a.astype(np.float64) * b.astype(np.float64)
p_rr, stats = r2f2_multiply(a, b, fmt, tile_shape=(1000,))
p_half = np.asarray(
    quantize_em(np.asarray(quantize_em(a, 5, 10)) * np.asarray(quantize_em(b, 5, 10)), 5, 10)
)
err = lambda p: np.nanmean(np.where(np.isfinite(p), np.abs(p - exact) / np.abs(exact), 1.0))
print(f"  E5M10 (IEEE half) mean rel error: {err(p_half.astype(np.float64))*100:.3f}%  "
      f"(overflows: {(~np.isfinite(p_half)).sum()})")
print(f"  R2F2 {fmt}        mean rel error: {err(np.asarray(p_rr, np.float64))*100:.3f}%  "
      f"(overflows: {int(stats.overflow_count)})")

print("\n=== 3. the hardware state machine (sequential mode) ===")
t = np.linspace(0, 1, 2000).astype(np.float32)
drift = (3e4 * np.exp(-10 * t)).astype(np.float32) + 1e-6
prods, st = r2f2_mul_sequential(drift, drift, fmt)
print(f"  stream drifting 3e4 -> 1e-6: {int(st.overflow_adjusts)} overflow adjusts, "
      f"{int(st.redundancy_adjusts)} redundancy adjusts (paper §5.3 behaviour)")

print("\n=== 4. one pluggable engine per policy mode ===")
for name in ("f32", "e5m10", "r2f2_16", "deploy"):
    eng = get_engine(PRESETS[name])
    print(f"  PRESETS[{name!r}] -> engine {eng.name!r} "
          f"(emulated={eng.emulated}, operand dtype={eng.operand_dtype(PRESETS[name]).__name__})")

print("\n=== 5. drop-in precision policy for a whole simulation ===")
from repro.pde import HeatConfig, simulate_heat
cfg = HeatConfig(nx=128)
ref, _ = simulate_heat(cfg, PRESETS["f32"], 2000)
for name in ("e5m10", "r2f2_16"):
    out, _ = simulate_heat(cfg, PRESETS[name], 2000)
    rel = float(np.linalg.norm(np.asarray(out) - np.asarray(ref)) / np.linalg.norm(np.asarray(ref)))
    print(f"  heat equation with {name:8s}: rel L2 vs f32 = {rel:.4f}")
print("done.")
