"""End-to-end training driver: a ~100M-param LM with rr-precision matmuls,
checkpointing, and restart — the (b) deliverable's full-loop example.

    PYTHONPATH=src python examples/train_lm.py                 # ~10M, quick
    PYTHONPATH=src python examples/train_lm.py --full          # ~100M params
    PYTHONPATH=src python examples/train_lm.py --resume        # restart demo
"""

import argparse
import dataclasses
import time

import jax

from repro.ckpt import latest_step, restore, save
from repro.precision import PRESETS
from repro.data import batch_for_step
from repro.models.config import ModelConfig
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step

SMALL = ModelConfig(  # ~11M params: CI-speed
    name="lm-small", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
    d_ff=1024, vocab=8192, pattern=("attn+mlp",),
)
FULL = ModelConfig(  # ~101M params: the deliverable-scale driver
    name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=3072, vocab=32768, pattern=("attn+mlp",),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--precision", default="deploy", choices=list(PRESETS))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = FULL if args.full else SMALL
    steps = args.steps or (300 if args.full else 60)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params; "
          f"{steps} steps @ batch {args.batch} x seq {args.seq}, precision={args.precision}")

    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=20, total_steps=steps))
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    start = 0
    if args.resume:
        last = latest_step(args.ckpt_dir)
        if last:
            state = restore(state, args.ckpt_dir, last)
            start = last
            print(f"resumed from step {last}")

    fn = jax.jit(make_train_step(cfg, PRESETS[args.precision], tcfg))
    t0 = time.time()
    for step in range(start, steps):
        state, m = fn(state, batch_for_step(cfg, step, args.batch, args.seq))
        if step % 10 == 0 or step == steps - 1:
            toks = args.batch * args.seq / max(time.time() - t0, 1e-9)
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  ({toks:,.0f} tok/s)")
            t0 = time.time()
        if (step + 1) % 50 == 0:
            save(state, args.ckpt_dir, step + 1)
    save(state, args.ckpt_dir, steps)
    print(f"final checkpoint at {args.ckpt_dir}/step_{steps:08d}")


if __name__ == "__main__":
    main()
