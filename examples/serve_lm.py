"""Batched serving example: prefill + jit decode with a KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--ckpt-dir /tmp/repro_lm_ckpt]
                                               [--policy artifacts/profile/<x>_policy.json]

``--policy`` loads a ``repro.profile`` PrecisionPolicy artifact (e.g. one
produced by ``python -m repro.profile heat1d``): the deploy serving
precision is derived from the artifact — same format, same per-site split
hints, validated-only — instead of implicit engine defaults.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import latest_step, restore
from repro.precision import PRESETS
from repro.data import batch_for_step
from repro.models import model_init
from repro.serve import generate
import os, sys
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from train_lm import SMALL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--policy", default=None,
                    help="PrecisionPolicy artifact JSON for the deploy precision")
    args = ap.parse_args()

    cfg = SMALL
    params = model_init(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last:
            from repro.train import OptConfig, TrainConfig, init_train_state
            like = init_train_state(jax.random.PRNGKey(0), cfg, TrainConfig())
            params = restore(like, args.ckpt_dir, last)["params"]
            print(f"loaded checkpoint step {last}")

    prec = PRESETS["deploy"]
    if args.policy:
        from repro.serve.decode import resolve_policy

        prec, policy = resolve_policy(prec, args.policy)
        print(f"serving precision from artifact {args.policy} "
              f"(profiled on {policy.stepper!r}, fmt {policy.fmt}):")
        for site, d in policy.sites.items():
            print(f"  {site}: k={d['k']} bounds [{d['k_lo']}, {d['k_hi']}]")

    prompts = batch_for_step(cfg, 123, args.batch, args.prompt_len)["tokens"]
    t0 = time.time()
    toks = generate(params, cfg, prec, prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"generated {args.batch}x{args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s incl. compile)")
    for i in range(args.batch):
        print(f"  req{i}: prompt[-6:]={list(map(int, prompts[i,-6:]))} -> "
              f"completion={list(map(int, toks[i,:12]))}...")


if __name__ == "__main__":
    main()
