"""Failure + elastic-rescale demo: train on N devices, 'lose' the job, resume
on a DIFFERENT device count from the latest atomic checkpoint.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import subprocess
import sys

CKPT = "/tmp/repro_elastic_demo"


def run(n_devices, steps, extra=()):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = "src"
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "stablelm-12b", "--reduced",
        "--steps", str(steps), "--batch", "8", "--seq", "64",
        "--ckpt-dir", CKPT, "--ckpt-every", "10", *extra,
    ]
    print(f"\n$ devices={n_devices} " + " ".join(cmd[2:]))
    return subprocess.run(cmd, env=env).returncode


def main():
    subprocess.run(["rm", "-rf", CKPT])
    print("=== phase 1: train on 4 devices, inject failure at step 25 ===")
    run(4, 40, ["--inject-failure-at", "25"])
    print("\n=== phase 2: cluster shrank — resume on 2 devices ===")
    run(2, 40, ["--resume"])
    print("\nelastic restart complete: same loss trajectory, half the devices.")


if __name__ == "__main__":
    main()
