"""Serving quickstart: a mixed burst through the simulation service.

    PYTHONPATH=src python examples/pde_service.py [--steps 240] [--smoke]

The production loop end to end (DESIGN.md §11 + §12):

1. **autotune** — for each workload (heat2d, advection1d, burgers1d), run
   the ``repro.profile`` pipeline once: capture the f32 range profile,
   synthesize a per-site ``PrecisionPolicy``, closed-loop validate it;
2. **serve** — submit a mixed burst to one ``repro.service.SimService``:
   per workload an f32 oracle request, two ``rr_tracked`` requests at
   different IC scales carrying the validated artifact (tracker seeded at
   the tuned splits, re-picks clamped to its ``[k_lo, k_hi]`` hints), and a
   **pinned deploy** request — the static profiled-silicon emulation. The
   scheduler buckets compatible requests onto shared vmapped ensemble
   calls; different modes/steppers serve concurrently from sibling buckets.
3. **report** — per-request: snapshots streamed, final splits, rel-L2 of
   the final state against the f32 request served in the same burst; then
   the service metrics surface (throughput, p50/p99 chunk latency, bucket
   occupancy, fleet-level §5.3 adjust counters).

With ``--trace [DIR]`` (default ``artifacts/obs``) the burst runs under
``repro.obs``: the whole pipeline is spanned (request lifecycle, chunk
calls, pallas dispatches), and on exit the Chrome trace, Prometheus text
metrics and per-site precision telemetry are exported to DIR. Open
``DIR/trace.json`` at https://ui.perfetto.dev, or print the fleet view
headlessly with ``python -m repro.obs --dir DIR``. Instrumentation is
passive — the served numerics are bit-identical with or without it.

With ``--health`` the burst additionally runs under the
:mod:`repro.obs.health` monitor (DESIGN.md §16): a deterministic shadow
sampler replays a fraction of requests at f32 and books the rel-L2 drift
into the error-budget metric, anomaly detectors watch the precision
telemetry, and SLO rules watch the service metrics. After the healthy
burst the demo **deploys a stale artifact** — an advection1d policy
pinned at the starved split k=0 — against hot traffic (pulse amplitude
~1e5) whose dynamic range the artifact no longer matches: the quantised
states overflow, the ``overflow_storm`` detector fires, a flight-recorder
dump lands in ``artifacts/flightrec/`` for postmortem, and the process
exits nonzero (the headless alerting contract: an alert is an alarm).
"""

import argparse
import dataclasses
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from repro.core.policy import PrecisionConfig  # noqa: E402
from repro.pde import get_stepper  # noqa: E402
from repro.precision import PRESETS  # noqa: E402
from repro.profile import tune_policy  # noqa: E402
from repro.service import (  # noqa: E402
    ServiceConfig,
    SimRequest,
    SimService,
    scaled_state0,
)

WORKLOADS = ("heat2d", "advection1d", "burgers1d")
TRACKED = dataclasses.replace(PRESETS["r2f2_16"], mode="rr_tracked")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=240)
    ap.add_argument("--smoke", action="store_true", help="reduced steps")
    ap.add_argument("--trace", nargs="?", const="artifacts/obs", default=None,
                    metavar="DIR",
                    help="enable repro.obs and export trace/metrics/telemetry "
                         "artifacts to DIR (default: artifacts/obs)")
    ap.add_argument("--health", action="store_true",
                    help="run under the repro.obs.health monitor (shadow-"
                         "oracle sampling + detectors + SLOs), then deploy a "
                         "starved pinned advection1d policy and watch the "
                         "overflow-storm alert fire (exits nonzero: the "
                         "alarm working)")
    args = ap.parse_args()
    steps = 64 if args.smoke else args.steps

    import repro.obs as obs
    import repro.obs.health as health

    monitor = None
    if args.trace or args.health:
        obs.enable(sample=1.0)
    if args.health:
        monitor = health.enable(shadow_rate=0.5)

    # -- 1. autotune one policy artifact per workload -----------------------
    policies = {}
    for name in WORKLOADS:
        _, _, policy = tune_policy(name, steps=steps)
        stamp = policy.validation or {}
        policies[name] = policy
        print(f"[tune] {name}: "
              + ", ".join(f"{s}: k={d['k']} [{d['k_lo']},{d['k_hi']}]"
                          for s, d in policy.sites.items())
              + f" — {'ACCEPTED' if policy.accepted else 'REJECTED'}"
              f" (rr_tracked rel-L2 {stamp.get('rel_l2_tracked', float('nan')):.2e})")

    # -- 2. the mixed burst --------------------------------------------------
    svc = SimService(ServiceConfig(max_queue=256))
    deploy_pinned = PrecisionConfig(mode="deploy", pinned=True)
    handles = []
    for name in WORKLOADS:
        pol = policies[name]
        handles += [
            svc.submit(SimRequest(name, steps=steps, precision="f32",
                                  tag=f"{name}/f32")),
            svc.submit(SimRequest(name, steps=steps, precision=TRACKED,
                                  policy=pol, tag=f"{name}/rr_tracked@policy")),
            svc.submit(SimRequest(name, steps=steps, precision=TRACKED,
                                  policy=pol, state0=scaled_state0(name, 0.8),
                                  tag=f"{name}/rr_tracked@policy(0.8x)")),
            svc.submit(SimRequest(name, steps=steps, precision=deploy_pinned,
                                  policy=pol, tag=f"{name}/deploy-pinned@policy")),
        ]
    print(f"\n[serve] submitted {len(handles)} requests across "
          f"{len(WORKLOADS)} workloads; pumping to idle...")
    svc.run_until_idle()

    # -- 3. per-request results + metrics -----------------------------------
    oracle = {h.tag.split("/")[0]: h for h in handles if h.tag.endswith("/f32")}
    print()
    for h in handles:
        if h.status != "done":
            print(f"  {h.tag:32s} {h.status.upper()}")
            continue
        res = h.result()
        name = h.tag.split("/")[0]
        offset = get_stepper(name).metric_offset(get_stepper(name).default_config())
        line = f"  {h.tag:32s} {len(res.snapshots)} snapshots"
        ref = oracle[name]
        if h is not ref and "(0.8x)" not in h.tag:
            a = np.asarray(res.state, np.float64) - offset
            b = np.asarray(ref.result().state, np.float64) - offset
            rel = float(np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30))
            line += f", rel-L2 vs f32 {rel:.3e}"
        if res.final_k is not None:
            line += f", final splits {res.final_k}"
        print(line)

    print()
    print(svc.metrics.report())

    # -- 4. (--health) the bad deploy: a starved pinned policy vs hot traffic
    alerted = False
    if monitor is not None:
        from repro.pde.advection1d import AdvectionConfig  # noqa: E402
        from repro.profile.artifact import PrecisionPolicy  # noqa: E402

        print("\n[health] clean burst verdict:")
        v = monitor.verdict()
        print(f"  status {v['status']}, {v['alerts']['total']} alert(s), "
              f"shadow sampled {v['shadow']['sampled']} "
              f"(error-budget burn {v['shadow']['burn']})")

        print("[health] deploying a STALE artifact: advection1d pinned at "
              "the starved split k=0, traffic amplitude ~1e5 ...")
        stale = PrecisionPolicy(
            stepper="advection1d",
            fmt=PRESETS["r2f2_16"].fmt,
            sites={s: {"k": 0, "k_lo": 0, "k_hi": 0}
                   for s in get_stepper("advection1d").sites},
            validation={"accepted": True, "note": "stale artifact (demo)"},
        )
        hot_cfg = AdvectionConfig(nx=64, amplitude=1.0)
        pinned_trk = dataclasses.replace(TRACKED, pinned=True)
        for m in range(3):
            svc.submit(SimRequest(
                "advection1d", steps=32, precision=pinned_trk, cfg=hot_cfg,
                policy=stale, snapshot_every=8,
                tag=f"advection1d/stale-pinned#{m}",
                state0=scaled_state0(
                    "advection1d", scale=(1.0 + 0.1 * m) * 1e5,
                    overrides={"nx": 64, "amplitude": 1.0},
                ),
            ))
        svc.run_until_idle()
        alerted = bool(monitor.alerts)
        print(f"[health] {len(monitor.alerts)} alert(s) after the bad deploy:")
        for a in monitor.alerts:
            print(f"  ALERT {a}")
        for p in monitor.dump_paths:
            print(f"  flight dump: {p}")

    if args.trace:
        paths = obs.export(args.trace)
        print("\n[obs] artifacts exported:")
        for kind, path in sorted(paths.items()):
            print(f"  {kind:12s} {path}")
        print("  open the trace at https://ui.perfetto.dev, or run "
              f"`python -m repro.obs --dir {args.trace}`")

    if monitor is not None:
        health.disable()
    if obs.enabled():
        obs.disable()
    if alerted:
        print("\n[health] alert(s) fired — exiting nonzero (the alarm working)")
        return 3
    return 0


if __name__ == "__main__":
    sys.exit(main())
