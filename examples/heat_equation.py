"""Paper Figs. 1 & 7: 1D heat equation across precisions (ASCII rendering).

    PYTHONPATH=src python examples/heat_equation.py [--init sin|exp] [--steps N]
"""

import argparse

import numpy as np

from repro.precision import PRESETS
from repro.pde import HeatConfig, simulate_heat


def ascii_plot(rows, labels, width=72, height=12):
    lo = min(np.nanmin(r) for r in rows)
    hi = max(np.nanmax(r) for r in rows)
    span = (hi - lo) or 1.0
    grid = [[" "] * width for _ in range(height)]
    marks = "#*o+x"
    for ri, r in enumerate(rows):
        xs = np.linspace(0, len(r) - 1, width).astype(int)
        for c, xi in enumerate(xs):
            v = r[xi]
            if not np.isfinite(v):
                continue
            y = int((1 - (v - lo) / span) * (height - 1))
            grid[y][c] = marks[ri % len(marks)]
    print("\n".join("".join(row) for row in grid))
    for ri, lab in enumerate(labels):
        print(f"  {marks[ri % len(marks)]} = {lab}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--init", default="sin", choices=["sin", "exp"])
    ap.add_argument("--steps", type=int, default=4000)
    ap.add_argument("--nx", type=int, default=128)
    args = ap.parse_args()

    cfg = HeatConfig(nx=args.nx, init=args.init)
    print(f"heat equation: {args.init} init, alpha={cfg.alpha}, r={cfg.cfl}, {args.steps} steps\n")
    curves, labels = [], []
    for name in ("f32", "e5m10", "r2f2_16"):
        out, _ = simulate_heat(cfg, PRESETS[name], args.steps)
        curves.append(np.asarray(out))
        labels.append(name)
    ascii_plot(curves, labels)
    ref = curves[0]
    for c, l in zip(curves[1:], labels[1:]):
        rel = np.linalg.norm(c - ref) / np.linalg.norm(ref)
        verdict = "matches f32" if rel < 0.05 else "WRONG SIMULATION"
        print(f"{l}: rel L2 {rel:.4f} -> {verdict}")


if __name__ == "__main__":
    main()
