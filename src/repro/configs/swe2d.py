"""The paper's own workload #2: 2D shallow-water equations (Lax-Wendroff).

Only the x-midpoint momentum-flux equation's multiplications run on the
configured multiplier (the paper's §5.3 substitution); h*h at a realistic
basin depth overflows E5M10's 65504 ceiling — the overflow failure mode.
"""

from repro.pde.swe2d import SWEConfig

CONFIG = SWEConfig(nx=128, ny=128, depth=500.0, bump=100.0)
BENCH_STEPS = 400
