"""Architecture registry: ``--arch <id>`` resolves here.

10 assigned architectures + the paper's own PDE workloads (heat1d, swe2d).
``reduced(cfg)`` shrinks any architecture to a CPU-smoke-test size while
preserving its block pattern and family (same code paths, tiny shapes).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

from .shapes import SHAPES, ShapeConfig, applicability, cell_window

__all__ = ["ARCHS", "get_config", "reduced", "SHAPES", "ShapeConfig", "applicability", "cell_window"]

_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "hubert-xlarge": "hubert_xlarge",
    "stablelm-12b": "stablelm_12b",
    "llama3-405b": "llama3_405b",
    "yi-34b": "yi_34b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "pixtral-12b": "pixtral_12b",
    "xlstm-1.3b": "xlstm_1_3b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCHS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def reduced(cfg: ModelConfig, layers_mult: int = 1) -> ModelConfig:
    """Smoke-test-size config of the same family (pattern preserved)."""
    period = len(cfg.pattern)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=period * layers_mult,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab=512,
        moe_experts=min(cfg.moe_experts, 4),
        moe_top_k=min(cfg.moe_top_k, 2),
        moe_d_ff=64 if cfg.moe_experts else None,
        frontend_dim=32 if cfg.frontend else 0,
        ssm_state=8,
        dt_rank=8,
    )
