"""llama3-405b [dense] — GQA, 128k vocab. [arXiv:2407.21783]

Full quadratic attention: long_500k cell skipped (DESIGN.md §5)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    pattern=("attn+mlp",),
    rope_theta=500000.0,
)
