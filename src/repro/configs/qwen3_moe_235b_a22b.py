"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, per-expert d_ff=1536.
[hf:Qwen/Qwen3-30B-A3B family]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    pattern=("attn+moe",),
    moe_experts=128,
    moe_top_k=8,
    moe_d_ff=1536,
    rope_theta=1000000.0,
)
