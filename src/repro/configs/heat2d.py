"""Beyond-paper workload: 2D heat equation (explicit 5-point FD).

Same underflow failure mode as the paper's 1D case — the 2D mode decays
faster (two wavenumbers add), so E5M10 freezes by ~1.5k steps — plus 2D
range-locality quantization tiles.
"""

from repro.pde.heat2d import Heat2DConfig

CONFIG = Heat2DConfig(nx=64, ny=64, alpha=1e-5, cfl=0.2, amplitude=500.0, modes=(3, 2))
BENCH_STEPS = 1500
