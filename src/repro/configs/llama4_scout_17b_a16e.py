"""llama4-scout-17b-a16e [moe] — MoE 16e top-1 with llama4-style shared
expert, early-fusion text backbone. [hf:meta-llama/Llama-4-Scout-17B-16E]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    pattern=("attn+moe",),
    moe_experts=16,
    moe_top_k=1,
    moe_d_ff=8192,
    moe_shared_expert=True,
    rope_theta=500000.0,
)
