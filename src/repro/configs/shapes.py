"""Assigned input shapes and the (arch x shape) applicability matrix.

LM transformer shapes are seq_len x global_batch. ``decode_*``/``long_*``
lower ``serve_step`` (one token against a seq_len KV cache), not
``train_step``. Skip rules (recorded per cell in EXPERIMENTS.md):
  - encoder-only archs have no decode step  -> skip decode_32k, long_500k
  - long_500k needs sub-quadratic attention -> runs only for SSM/hybrid
    (xlstm: pure recurrence; jamba: windowed attention), skipped for pure
    full-attention archs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.config import ModelConfig

__all__ = ["ShapeConfig", "SHAPES", "applicability", "cell_window"]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicability(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the cell runs; otherwise the skip reason."""
    if cfg.encoder_only and shape.kind == "decode":
        return "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k":
        if cfg.is_recurrent_only:
            return None  # O(1) state
        if "mamba" in "".join(cfg.pattern):
            return None  # hybrid: windowed attention + recurrent state
        return "pure full-attention arch: long_500k needs sub-quadratic attention"
    return None


def cell_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    """Sliding window used for a cell (jamba long-context mode)."""
    if shape.name == "long_500k" and cfg.has_attn:
        return 4096
    return cfg.window
