"""jamba-v0.1-52b [hybrid] — Mamba:attention 7:1 (attn at layer i%8==4),
MoE 16e top-2 on odd layers. [arXiv:2403.19887]

Hybrid: the single attention layer per period runs with a sliding window in
long-context mode, so the long_500k cell runs (DESIGN.md §5)."""

from repro.models.config import ModelConfig

LONG_WINDOW = 4096  # attention window for the long_500k cell

_pattern = tuple(
    ("attn" if i % 8 == 4 else "mamba") + ("+moe" if i % 2 == 1 else "+mlp")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    pattern=_pattern,
    moe_experts=16,
    moe_top_k=2,
    moe_d_ff=14336,
    ssm_state=16,
    ssm_expand=2,
)
