"""stablelm-12b [dense] — llama-style GQA decoder. [hf:stabilityai]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100352,
    pattern=("attn+mlp",),
    rope_theta=10000.0,
)
