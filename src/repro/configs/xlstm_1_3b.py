"""xlstm-1.3b [ssm] — xLSTM[7:1]: 7 mLSTM : 1 sLSTM blocks, 4 heads.
O(1) recurrent state -> runs the long_500k cell. [arXiv:2405.04517]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=("mlstm",) * 7 + ("slstm",),
    lstm_expand=2,
)
