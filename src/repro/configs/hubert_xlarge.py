"""hubert-xlarge [audio] — encoder-only; conv frame frontend is a STUB
(input_specs provides precomputed frame embeddings). [arXiv:2106.07447]

No autoregressive step exists: decode_32k / long_500k cells are skipped
(DESIGN.md §5)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    pattern=("attn+mlp",),
    causal=False,
    encoder_only=True,
    act="gelu",
    frontend="audio",
    frontend_dim=512,
)
