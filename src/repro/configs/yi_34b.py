"""yi-34b [dense] — llama-arch GQA. [arXiv:2403.04652]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    pattern=("attn+mlp",),
    rope_theta=5000000.0,
)
