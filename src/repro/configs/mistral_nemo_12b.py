"""mistral-nemo-12b [dense] — 128k-context GQA, head_dim 128 (not d/H).
[hf:mistralai/Mistral-Nemo-Base-2407]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    pattern=("attn+mlp",),
    head_dim=128,
    rope_theta=1000000.0,
)
