"""Beyond-paper workload: 1D inviscid Burgers (Lax-Friedrichs).

The nonlinear flux u*u squares the operand range: 1.2e5 overflows E5M10 at
t=0, then post-shock N-wave decay collapses the range by orders of
magnitude — the tracked modes' k must grow to FX and shrink back (the
runtime re-selection story).
"""

from repro.pde.burgers1d import BurgersConfig

CONFIG = BurgersConfig(nx=256, amplitude=350.0, cfl=0.4)
BENCH_STEPS = 1200
