"""pixtral-12b [vlm] — mistral-nemo backbone + pixtral-ViT frontend STUB
(input_specs provides patch embeddings, 1024-dim). [hf:mistralai/Pixtral-12B]"""

from repro.models.config import ModelConfig

IMG_SEQ = 1024  # patch tokens prepended to the text sequence

CONFIG = ModelConfig(
    name="pixtral-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    pattern=("attn+mlp",),
    head_dim=128,
    rope_theta=1000000.0,
    frontend="vision",
    frontend_dim=1024,
)
