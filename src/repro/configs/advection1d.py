"""Beyond-paper workload: 1D linear advection (flux-form upwind, cfl=1).

At cfl=1 the f32 run translates the profile exactly (a bit-for-bit oracle);
the 1e5-amplitude pulse makes the flux operand overflow E5M10's 65504
ceiling — the overflow failure mode on the *field itself*.
"""

from repro.pde.advection1d import AdvectionConfig

CONFIG = AdvectionConfig(nx=256, speed=1.0, cfl=1.0, amplitude=1.0e5)
BENCH_STEPS = 256  # one full period of the periodic domain
