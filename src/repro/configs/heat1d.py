"""The paper's own workload #1: 1D heat equation (explicit FD).

Figure-faithful configuration (see EXPERIMENTS.md §Claims rows 6 & 8):
physical diffusivity drives the alpha*lap products below E5M10's subnormal
floor late in the simulation — the paper's underflow failure mode.
"""

from repro.pde.heat1d import HeatConfig

CONFIG = HeatConfig(nx=128, init="sin", alpha=1e-5, cfl=0.4, amplitude=500.0, modes=3)
CONFIG_EXP = HeatConfig(nx=128, init="exp", alpha=1e-5, cfl=0.4)
BENCH_STEPS = {"sin": 4000, "exp": 16000}
