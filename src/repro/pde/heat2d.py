"""2D heat equation, explicit finite differences — beyond-paper workload #1.

    du/dt = alpha * (d2u/dx2 + d2u/dy2)

Same two-multiplier decomposition as the paper's 1D case (``flux = alpha *
lap`` then ``upd = flux * dtodx2``) and the same *underflow* failure mode:
with a physical diffusivity the flux products sink below E5M10's subnormal
floor as the solution decays, freezing the dynamics. What the second
dimension adds is range *locality at tile granularity*: a 2D field hands the
rr engines genuinely two-dimensional quantization tiles (the paper's "local
clusters" argument, exercised at (tile, tile) blocks instead of 1D rows),
and the Pallas kernels their natural (8, 128)-aligned layout.

Square cells: ``dy == dx == length / nx`` (``ny`` sets the y extent), so the
update needs exactly one ``dt/dx^2`` multiplier, like the 1D solver.
Boundaries are Dirichlet (pinned to zero).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .registry import register_stepper
from .solver import StepOps, Stepper

__all__ = ["Heat2DConfig", "Heat2DStepper", "initial_condition_2d"]


@dataclasses.dataclass(frozen=True)
class Heat2DConfig:
    nx: int = 64
    ny: int = 64
    length: float = 1.0  # x extent; cells are square, so y extent = ny * dx
    alpha: float = 1e-5  # physical diffusivity (steel ~ 1.2e-5 m^2/s)
    cfl: float = 0.2  # r = alpha*dt/dx^2; 2D explicit stability needs r <= 1/4
    init: str = "sin"  # "sin" | "exp"
    amplitude: float = 500.0
    modes: tuple = (3, 2)  # (x, y) sin harmonics

    @property
    def dx(self) -> float:
        return self.length / self.nx

    @property
    def length_y(self) -> float:
        return self.ny * self.dx

    @property
    def dt(self) -> float:
        return self.cfl * self.dx * self.dx / self.alpha

    @property
    def dtodx2(self) -> float:
        return self.dt / (self.dx * self.dx)

    @property
    def decay_rate(self) -> float:
        """Analytic decay rate of the configured sin mode (for tests)."""
        import math

        mx, my = self.modes
        return self.alpha * (
            (mx * math.pi / self.length) ** 2 + (my * math.pi / self.length_y) ** 2
        )


def initial_condition_2d(cfg: Heat2DConfig) -> jnp.ndarray:
    x = jnp.linspace(0.0, cfg.length, cfg.nx, dtype=jnp.float32)
    y = jnp.linspace(0.0, cfg.length_y, cfg.ny, dtype=jnp.float32)
    xx, yy = jnp.meshgrid(x, y, indexing="ij")
    if cfg.init == "sin":
        mx, my = cfg.modes
        u0 = cfg.amplitude * (
            jnp.sin(mx * jnp.pi * xx / cfg.length) * jnp.sin(my * jnp.pi * yy / cfg.length_y)
        )
    elif cfg.init == "exp":
        r2 = ((xx - 0.5 * cfg.length) ** 2 + (yy - 0.5 * cfg.length_y) ** 2) / (
            0.05 * cfg.length
        ) ** 2
        u0 = cfg.amplitude * jnp.exp(-r2)
    else:
        raise ValueError(f"unknown init {cfg.init!r}")
    u0 = u0.at[0, :].set(0.0).at[-1, :].set(0.0)
    return u0.at[:, 0].set(0.0).at[:, -1].set(0.0)


@register_stepper("heat2d")
class Heat2DStepper(Stepper):
    """Explicit 5-point stencil with the paper's two-multiplier split."""

    sites = ("heat2d.flux", "heat2d.update")
    site_ops = ("mul", "mul")
    failure_mode = "underflow"
    story = "2D decay drives alpha*lap below E5M10's floor; 2D locality tiles"
    snapshots_default = 8
    fused_packed = True  # the sweep kernel unpacks/repacks in VMEM

    def default_config(self) -> Heat2DConfig:
        return Heat2DConfig()

    def init_state(self, cfg: Heat2DConfig) -> jnp.ndarray:
        return initial_condition_2d(cfg)

    def step(self, u, cfg: Heat2DConfig, ops: StepOps):
        lap = (  # 5-point interior laplacian, adds in f32
            u[:-2, 1:-1]
            + u[2:, 1:-1]
            + u[1:-1, :-2]
            + u[1:-1, 2:]
            - 4.0 * u[1:-1, 1:-1]
        )
        flux = ops.mul(jnp.float32(cfg.alpha), lap, "heat2d.flux")  # multiplier 1
        upd = ops.mul(flux, jnp.float32(cfg.dtodx2), "heat2d.update")  # multiplier 2
        return u.at[1:-1, 1:-1].add(upd)

    def fused_step(
        self,
        u,
        cfg: Heat2DConfig,
        prec,
        steps: int,
        *,
        k_floor=None,
        collect_evidence: bool = False,
        capture=None,
        interpret=None,
        storage: str = "f32",
    ):
        from repro.kernels.pde_steps import heat2d_sweep  # lazy: pallas off cold paths

        return heat2d_sweep(
            u,
            alpha=cfg.alpha,
            dtodx2=cfg.dtodx2,
            prec=prec,
            steps=steps,
            sites=self.sites,
            k_floor=k_floor,
            collect_evidence=collect_evidence,
            capture=capture,
            interpret=interpret,
            storage=storage,
        )

    def mega_step(
        self,
        u,
        cfg: Heat2DConfig,
        prec,
        steps: int,
        every: int,
        *,
        tracker=None,
        collect_evidence: bool = False,
        capture=None,
        interpret=None,
        storage: str = "f32",
    ):
        from repro.kernels.mega import heat2d_mega  # lazy: pallas off cold paths

        return heat2d_mega(
            u,
            alpha=cfg.alpha,
            dtodx2=cfg.dtodx2,
            prec=prec,
            steps=steps,
            every=every,
            sites=self.sites,
            tracker=tracker,
            collect_evidence=collect_evidence,
            capture=capture,
            interpret=interpret,
            storage=storage,
        )
