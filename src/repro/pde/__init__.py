"""PDE workloads over the unified solver framework.

The paper's two case studies (``heat1d``, ``swe2d``) plus beyond-paper
scenario workloads (``heat2d``, ``advection1d``, ``burgers1d``), each a
:class:`~repro.pde.solver.Stepper` registered by name. Generic code drives
them through :class:`~repro.pde.solver.Simulation`::

    from repro.pde import Simulation, known_steppers
    res = Simulation("burgers1d", None, PRESETS["r2f2_16"]).run(1000)

The original per-workload entry points (``simulate_heat``/``simulate_swe``,
``heat_step``/``swe_step``) remain as numerics-identical shims.
"""

from .registry import get_stepper, known_steppers, register_stepper
from .solver import SimResult, Simulation, StepOps, Stepper

from .advection1d import AdvectionConfig, initial_profile
from .burgers1d import BurgersConfig, initial_wave
from .heat1d import HeatConfig, heat_step
from .heat1d import simulate as simulate_heat
from .heat2d import Heat2DConfig, initial_condition_2d
from .precision_ops import padd, pdiv, pmul, pstore
from .swe2d import SWEConfig, swe_step
from .swe2d import simulate as simulate_swe

__all__ = [
    # framework
    "Stepper",
    "StepOps",
    "Simulation",
    "SimResult",
    "register_stepper",
    "get_stepper",
    "known_steppers",
    # workload configs + shims
    "HeatConfig",
    "Heat2DConfig",
    "AdvectionConfig",
    "BurgersConfig",
    "SWEConfig",
    "initial_condition_2d",
    "initial_profile",
    "initial_wave",
    "heat_step",
    "swe_step",
    "simulate_heat",
    "simulate_swe",
    "pmul",
    "pstore",
    "pdiv",
    "padd",
]
