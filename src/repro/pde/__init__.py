"""PDE solvers — the paper's two case-study applications."""

from .heat1d import HeatConfig, heat_step
from .heat1d import simulate as simulate_heat
from .precision_ops import pdiv, pmul, pstore
from .swe2d import SWEConfig, swe_step
from .swe2d import simulate as simulate_swe
