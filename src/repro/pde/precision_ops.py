"""Backward-compatible shims: PDE elementwise ops over ``repro.precision``.

The paper's system multiplies through R2F2 (or a fixed-format unit) while
additions run on a conventional (wider-accumulator) adder and state is
*stored* in the low-bitwidth format. These primitives keep that vocabulary
for solver code, delegating to the engine API (DESIGN.md §4):

  pmul(a, b, cfg)  == repro.precision.multiply  — policy's multiplier
  pstore(x, cfg)   == repro.precision.store     — low-bitwidth write-back
  pdiv(a, b, cfg)  == repro.precision.divide    — the repro.alu flexible
                      divider under rr modes (quotient-range evidence law);
                      format-rounded for fixed units, f32 for the reference.
  padd(a, b, cfg)  == repro.precision.add       — the repro.alu flexible
                      adder (alignment-shift evidence law).

``pmul``/``pdiv``/``padd`` additionally accept ``tracker``/``site`` (named
sites, e.g. ``site="heat.flux"``) and then return ``(out, tracker)`` — the
deployment story for solvers, mirroring ``rr_einsum``'s uniform tracker
contract.
"""

from __future__ import annotations

__all__ = ["pmul", "pstore", "pdiv", "padd"]


def pmul(a, b, cfg, *, tracker=None, site=None):
    from repro.precision import multiply

    return multiply(a, b, cfg, tracker=tracker, site=site)


def pstore(x, cfg):
    from repro.precision import store

    return store(x, cfg)


def pdiv(a, b, cfg, *, tracker=None, site=None):
    from repro.precision import divide

    return divide(a, b, cfg, tracker=tracker, site=site)


def padd(a, b, cfg, *, tracker=None, site=None):
    from repro.precision import add

    return add(a, b, cfg, tracker=tracker, site=site)
