"""Elementwise precision-policy operations used by the PDE solvers.

The paper's system multiplies through R2F2 (or a fixed-format unit) while
additions run on a conventional (wider-accumulator) adder and state is
*stored* in the low-bitwidth format. These three primitives encode that
split so the solvers read like the numerics they implement:

  pmul(a, b, cfg)  — a multiplication issued to the policy's multiplier
  pstore(x, cfg)   — state written back to low-bitwidth storage
  pdiv(a, b, cfg)  — division; R2F2 is a multiplier, so division stays in
                     the substrate precision (f32) under every rr mode and
                     is format-rounded only for fixed-format units.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.flexformat import quantize_em
from repro.core.policy import PrecisionConfig
from repro.core.r2f2 import r2f2_multiply

__all__ = ["pmul", "pstore", "pdiv"]


def pmul(a, b, cfg: PrecisionConfig):
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    if cfg.mode == "f32":
        return a * b
    if cfg.mode in ("bf16", "deploy"):
        return (a.astype(jnp.bfloat16) * b.astype(jnp.bfloat16)).astype(jnp.float32)
    if cfg.mode == "fixed":
        e, m = cfg.fixed_em
        p = quantize_em(a, e, m) * quantize_em(b, e, m)
        return quantize_em(p, e, m)
    # rr modes: per-tensor runtime split (PDE fields are one locality cluster;
    # the Pallas kernels do the same per VMEM block)
    out, _ = r2f2_multiply(a, b, cfg.fmt, tile_shape=None, tail_approx=cfg.tail_approx)
    return out


def pstore(x, cfg: PrecisionConfig):
    x = jnp.asarray(x, jnp.float32)
    if cfg.mode == "f32":
        return x
    if cfg.mode in ("bf16", "deploy"):
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    if cfg.mode == "fixed":
        e, m = cfg.fixed_em
        return quantize_em(x, e, m)
    # rr storage: minimal-k format for the live range (paper Fig. 4a layout)
    from repro.core.r2f2 import _tile_max_exp, select_k_operand  # local to avoid cycle

    me, _ = _tile_max_exp(x, None)
    k = select_k_operand(me, cfg.fmt)
    return quantize_em(x, cfg.fmt.eb + k, cfg.fmt.mb + cfg.fmt.fx - k)


def pdiv(a, b, cfg: PrecisionConfig):
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    if cfg.mode == "fixed":
        e, m = cfg.fixed_em
        return quantize_em(quantize_em(a, e, m) / quantize_em(b, e, m), e, m)
    if cfg.mode in ("bf16", "deploy"):
        return (a.astype(jnp.bfloat16) / b.astype(jnp.bfloat16)).astype(jnp.float32)
    return a / b
