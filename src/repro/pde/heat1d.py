"""1D heat equation, explicit finite differences (paper §2, Figs. 1/2/7).

    du/dt = alpha * d2u/dx2,   u'[i] = u[i] + alpha*(dt/dx^2)*lap[i]

The update is decomposed into the two multiplications a scalar pipeline
issues —  ``flux = alpha * lap`` then ``upd = flux * dtodx2``  — because that
is where the paper's precision story lives: with a physical diffusivity
(alpha ~ 1e-5 m^2/s, e.g. steel) the intermediate ``alpha * lap`` falls below
E5M10's subnormal floor late in the simulation (paper §3.1: "using E6M9 for
the multiplications whose operands are smaller than 0.0001 can compute
correctly"), so standard half freezes/distorts the dynamics, while R2F2
re-allocates flexible bits to the exponent and tracks the true solution.
The ``exp`` initialization exercises the *overflow* failure instead (initial
values beyond 65504).

Solver state is stored in the policy's format every step (16-bit storage in
the paper's system); additions run in f32 (the FPU adder).

The workload is a thin :class:`repro.pde.solver.Stepper` registered as
``"heat1d"``; ``simulate``/``heat_step`` remain as shims with unchanged
numerics over the shared :class:`~repro.pde.solver.Simulation` driver.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.precision import PrecisionConfig

from .registry import register_stepper
from .solver import Simulation, StepOps, Stepper

__all__ = ["HeatConfig", "Heat1DStepper", "initial_condition", "heat_step", "simulate"]


@dataclasses.dataclass(frozen=True)
class HeatConfig:
    nx: int = 512
    length: float = 1.0
    alpha: float = 1e-5  # physical diffusivity (steel ~ 1.2e-5 m^2/s)
    cfl: float = 0.4  # r = alpha*dt/dx^2
    init: str = "sin"  # "sin" | "exp" (the paper's two initializations)
    amplitude: float = 500.0  # paper Fig. 2: values reach +-500 with sin init
    modes: int = 3  # sin harmonics

    @property
    def dx(self) -> float:
        return self.length / self.nx

    @property
    def dt(self) -> float:
        return self.cfl * self.dx * self.dx / self.alpha

    @property
    def dtodx2(self) -> float:
        return self.dt / (self.dx * self.dx)


def initial_condition(cfg: HeatConfig) -> jnp.ndarray:
    x = jnp.linspace(0.0, cfg.length, cfg.nx, dtype=jnp.float32)
    if cfg.init == "sin":
        u0 = cfg.amplitude * jnp.sin(cfg.modes * jnp.pi * x / cfg.length)
    elif cfg.init == "exp":
        # localized gaussian: decays into the underflow regime where E5M10's
        # flux products flush (progressive failure; sin shows the freeze)
        u0 = 2000.0 * jnp.exp(-(((x - 0.5 * cfg.length) / (0.05 * cfg.length)) ** 2))
    else:
        raise ValueError(f"unknown init {cfg.init!r}")
    return u0.at[0].set(0.0).at[-1].set(0.0)


@register_stepper("heat1d")
class Heat1DStepper(Stepper):
    """One explicit-FD step under the precision policy.

    State stays f32, exactly like the paper's HLS system: the R2F2 unit
    "reads and converts from single precision ... and converts back" (§5.2)
    around each multiplication; only the multiplies see the low bitwidth.
    """

    sites = ("heat.flux", "heat.update")
    site_ops = ("mul", "mul")
    failure_mode = "underflow"
    story = "alpha*lap falls below E5M10's subnormal floor late in the run"
    snapshots_default = 8
    fused_packed = True  # the sweep kernel unpacks/repacks in VMEM

    def default_config(self) -> HeatConfig:
        return HeatConfig(nx=128)

    def init_state(self, cfg: HeatConfig) -> jnp.ndarray:
        return initial_condition(cfg)

    def step(self, u, cfg: HeatConfig, ops: StepOps):
        lap = u[:-2] - 2.0 * u[1:-1] + u[2:]  # adds in f32
        flux = ops.mul(jnp.float32(cfg.alpha), lap, "heat.flux")  # multiplier 1
        upd = ops.mul(flux, jnp.float32(cfg.dtodx2), "heat.update")  # multiplier 2
        interior = u[1:-1] + upd
        return jnp.concatenate([u[:1], interior, u[-1:]])

    def fused_step(
        self,
        u,
        cfg: HeatConfig,
        prec,
        steps: int,
        *,
        k_floor=None,
        collect_evidence: bool = False,
        capture=None,
        interpret=None,
        storage: str = "f32",
    ):
        from repro.kernels.heat_stencil import heat1d_sweep  # lazy: pallas off cold paths

        packed = storage == "packed"
        res = heat1d_sweep(
            u.with_view((1, cfg.nx)) if packed else u[None, :],
            alpha=cfg.alpha,
            dtodx2=cfg.dtodx2,
            prec=prec,
            steps=steps,
            block_rows=1,
            sites=self.sites,
            k_floor=k_floor,
            collect_evidence=collect_evidence,
            capture=capture,
            interpret=interpret,
            storage=storage,
        )
        if capture is not None:
            out, ev, counts = res
            return (out.with_view((cfg.nx,)) if packed else out[0]), ev, counts
        out, ev = res
        return (out.with_view((cfg.nx,)) if packed else out[0]), ev

    def mega_step(
        self,
        u,
        cfg: HeatConfig,
        prec,
        steps: int,
        every: int,
        *,
        tracker=None,
        collect_evidence: bool = False,
        capture=None,
        interpret=None,
        storage: str = "f32",
    ):
        from repro.kernels.mega import heat1d_mega  # lazy: pallas off cold paths

        return heat1d_mega(
            u,
            alpha=cfg.alpha,
            dtodx2=cfg.dtodx2,
            prec=prec,
            steps=steps,
            every=every,
            sites=self.sites,
            tracker=tracker,
            collect_evidence=collect_evidence,
            capture=capture,
            interpret=interpret,
            storage=storage,
        )


_STEPPER = Heat1DStepper()


def heat_step(u, cfg: HeatConfig, prec: PrecisionConfig):
    """One explicit-FD step (untracked shim over the registered stepper)."""
    return _STEPPER.step(u, cfg, StepOps(prec))


def simulate(
    cfg: HeatConfig,
    prec: PrecisionConfig,
    steps: int,
    snapshot_every: Optional[int] = None,
    u0: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run ``steps`` updates. Returns (final_state, snapshots)."""
    res = Simulation("heat1d", cfg, prec).run(
        steps,
        snapshot_every=snapshot_every,
        state0=None if u0 is None else jnp.asarray(u0, jnp.float32),
    )
    return res.state, res.snapshots
