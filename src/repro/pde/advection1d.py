"""1D linear advection, flux-form upwind — beyond-paper workload #2.

    du/dt + c * du/dx = 0,    u'[i] = u[i] - (dt/dx) * (f[i] - f[i-1])

with the flux ``f = c * u`` on the policy's multiplier (periodic domain,
``c > 0``). At ``cfl = c*dt/dx = 1`` the upwind scheme is *exact*: each step
translates the profile by one cell, so the f32 run is a bit-for-bit
translation oracle — any deviation is pure multiplier rounding, the cleanest
per-step error meter in the suite.

Precision story (*overflow*): the flux operand is the field itself, and the
default pulse peaks at 1e5 — past E5M10's 65504 ceiling, so the fixed-format
flux quantizes to inf, the flux difference becomes NaN, and the simulation
is destroyed within a step, while R2F2 widens the exponent (k -> FX) and
rides through with ~10-bit mantissa rounding only.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .registry import register_stepper
from .solver import StepOps, Stepper

__all__ = ["AdvectionConfig", "Advection1DStepper", "initial_profile"]


@dataclasses.dataclass(frozen=True)
class AdvectionConfig:
    nx: int = 256
    length: float = 1.0
    speed: float = 1.0  # c > 0 (upwind bias is to the left neighbour)
    cfl: float = 1.0  # c*dt/dx; 1.0 -> exact translation per step
    amplitude: float = 1.0e5  # peaks past E5M10's 65504 ceiling
    width: float = 0.08  # gaussian pulse width (fraction of the domain)

    @property
    def dx(self) -> float:
        return self.length / self.nx

    @property
    def dt(self) -> float:
        return self.cfl * self.dx / self.speed

    @property
    def dtodx(self) -> float:
        return self.dt / self.dx


def initial_profile(cfg: AdvectionConfig) -> jnp.ndarray:
    x = jnp.linspace(0.0, cfg.length, cfg.nx, endpoint=False, dtype=jnp.float32)
    return cfg.amplitude * jnp.exp(
        -(((x - 0.3 * cfg.length) / (cfg.width * cfg.length)) ** 2)
    )


@register_stepper("advection1d")
class Advection1DStepper(Stepper):
    """Flux-form first-order upwind on a periodic domain."""

    sites = ("adv.flux", "adv.update")
    site_ops = ("mul", "mul")
    failure_mode = "overflow"
    story = "flux operand is the 1e5-peak field itself; E5M10 infs the flux"
    snapshots_default = 8
    fused_packed = True  # the sweep kernel unpacks/repacks in VMEM

    def default_config(self) -> AdvectionConfig:
        return AdvectionConfig()

    def init_state(self, cfg: AdvectionConfig) -> jnp.ndarray:
        return initial_profile(cfg)

    def step(self, u, cfg: AdvectionConfig, ops: StepOps):
        f = ops.mul(jnp.float32(cfg.speed), u, "adv.flux")  # multiplier 1
        df = f - jnp.roll(f, 1)  # upwind difference, adds in f32
        upd = ops.mul(jnp.float32(cfg.dtodx), df, "adv.update")  # multiplier 2
        return u - upd

    def fused_step(
        self,
        u,
        cfg: AdvectionConfig,
        prec,
        steps: int,
        *,
        k_floor=None,
        collect_evidence: bool = False,
        capture=None,
        interpret=None,
        storage: str = "f32",
    ):
        from repro.kernels.pde_steps import advection1d_sweep  # lazy: pallas off cold paths

        return advection1d_sweep(
            u,
            speed=cfg.speed,
            dtodx=cfg.dtodx,
            prec=prec,
            steps=steps,
            sites=self.sites,
            k_floor=k_floor,
            collect_evidence=collect_evidence,
            capture=capture,
            interpret=interpret,
            storage=storage,
        )

    def mega_step(
        self,
        u,
        cfg: AdvectionConfig,
        prec,
        steps: int,
        every: int,
        *,
        tracker=None,
        collect_evidence: bool = False,
        capture=None,
        interpret=None,
        storage: str = "f32",
    ):
        from repro.kernels.mega import advection1d_mega  # lazy: pallas off cold paths

        return advection1d_mega(
            u,
            speed=cfg.speed,
            dtodx=cfg.dtodx,
            prec=prec,
            steps=steps,
            every=every,
            sites=self.sites,
            tracker=tracker,
            collect_evidence=collect_evidence,
            capture=capture,
            interpret=interpret,
            storage=storage,
        )
