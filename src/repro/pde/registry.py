"""String-keyed stepper registry — the pluggability point of the PDE surface.

Mirrors :mod:`repro.precision.registry`: every scenario workload registers a
:class:`repro.pde.solver.Stepper` under a short name, and everything generic
— the :class:`~repro.pde.solver.Simulation` driver, the per-stepper benchmark
suite (``benchmarks/bench_pde.py``), the README scenario table — iterates
:func:`known_steppers` instead of hard-coding workload modules. A third-party
stepper (a reaction-diffusion system, a wave equation, ...) becomes a named
scenario the moment it calls :func:`register_stepper`, with zero edits
elsewhere.

This module deliberately imports nothing from :mod:`repro.pde.solver` at
module scope, so workload modules can import it while the package is still
mid-initialisation.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Tuple

if TYPE_CHECKING:  # pragma: no cover - type-only
    from repro.pde.solver import Stepper

__all__ = ["register_stepper", "get_stepper", "known_steppers"]

_STEPPERS: Dict[str, "Stepper"] = {}
_builtins_loaded = False


def register_stepper(name: str, stepper=None):
    """Register ``stepper`` (an instance or a class) under ``name``.

    Usable directly (``register_stepper("wave1d", Wave1DStepper())``) or as a
    class decorator (``@register_stepper("wave1d")``). Re-registering a name
    replaces the previous stepper — deliberate, so tests/experiments can
    shadow a builtin. Returns the stepper/class for decorator chaining.
    """
    if stepper is None:
        return lambda s: register_stepper(name, s)
    instance = stepper() if isinstance(stepper, type) else stepper
    instance.name = name
    _STEPPERS[name] = instance
    return stepper


def _load_builtins() -> None:
    global _builtins_loaded
    if not _builtins_loaded:
        # registering happens at module import; workload modules are listed
        # here (not via the package __init__) to avoid an import cycle
        from repro.pde import advection1d, burgers1d, heat1d, heat2d, swe2d  # noqa: F401

        # flag set only on success so a failed import is retried, not masked
        _builtins_loaded = True


def get_stepper(name: str) -> "Stepper":
    """Resolve a stepper name to its registered instance."""
    _load_builtins()
    try:
        return _STEPPERS[name]
    except KeyError:
        raise KeyError(
            f"no PDE stepper registered for {name!r}; known: {known_steppers()}"
        ) from None


def known_steppers() -> Tuple[str, ...]:
    """All currently registered stepper names."""
    _load_builtins()
    return tuple(sorted(_STEPPERS))
