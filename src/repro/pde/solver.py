"""Unified PDE solver framework: Stepper protocol + Simulation driver.

Every PDE workload used to hand-roll its own ``lax.scan`` scaffolding, and
none threaded a tracker through the loop — so the cross-step ``rr_tracked``
engine silently degraded to stateless per-tensor selection exactly where the
paper exercises it. This module owns the simulation loop once:

* a :class:`Stepper` is the workload: ``init_state / step / observables``
  plus static metadata (named multiplication sites, precision failure mode);
* :class:`StepOps` is the per-step arithmetic context handed to
  ``Stepper.step``: ``mul/div/store`` route through the precision engine and
  thread the tracker implicitly, so stepper code never touches tracker
  plumbing;
* :class:`Simulation` drives the scan/snapshot loop, carrying
  ``(state, tracker)`` through every step — tracked modes (``rr_tracked`` /
  ``deploy``, any engine with ``tracks=True``) genuinely carry the flexible
  split ``k`` across time, the paper's precision-adjust-unit persistence;
* ensembles of initial conditions run vmapped
  (:meth:`Simulation.run_ensemble`), optionally sharded over the mesh's
  data axes via :mod:`repro.dist.sharding` logical-axis rules (the ensemble
  member dim is the logical ``batch`` axis).

Steppers register under a string key (:mod:`repro.pde.registry`, mirroring
``precision/registry.py``), so benchmarks, examples and docs enumerate
scenarios instead of importing workload modules. See DESIGN.md §9.

The driver owns THREE arithmetic planes (``run(..., execution=...)``,
DESIGN.md §10/§14): the reference ``StepOps`` path above; a **fused
execution plane** where whole snapshot intervals run as multi-substep
Pallas kernel chunks through the stepper's optional ``fused_step`` hook —
one HBM round trip per chunk, per-block runtime splits selected in VMEM,
and the kernels' per-site range evidence folded into the carried tracker
between chunks (:func:`repro.precision.fold_evidence`), so tracked modes
ride the fast path with the same adjust-unit semantics; and a **megakernel
plane** where the stepper's optional ``mega_step`` hook runs the ENTIRE
horizon — snapshots, boundary storage rounding, and the per-substep
on-chip adjust unit (:func:`repro.core.policy.adjust_step`) — in ONE
``pallas_call``, bit-identical to the chunked plane. ``"auto"`` prefers
the megakernel when :func:`repro.precision.mega_eligible` accepts, then
fused when :func:`repro.precision.fused_eligible` accepts, then the
reference path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

import repro.obs as _obs
from repro.core.policy import PrecisionConfig
from repro.dist.sharding import constrain
from repro.pack import is_packed, pack_state, storage_quantize, unpack_state
from repro.precision import (
    fold_evidence,
    fused_eligible,
    get_engine,
    mega_eligible,
    site_tracker_init,
)
from repro.precision.sites import rewrap
from repro.pde.registry import get_stepper
from repro.profile.capture import CaptureResult, CaptureSpec, pair_exp_hist, site_evidence

__all__ = ["Stepper", "StepOps", "Simulation", "SimResult", "STORAGE_MODES"]

#: carried-state storage formats (DESIGN.md §13): "f32" carries raw f32
#: between chunks (the historical behaviour, bit-compatible); "quantized"
#: rounds chunk-boundary state through pack/unpack but carries f32;
#: "packed" carries :class:`repro.pack.PackedArray` payloads — the same
#: values as "quantized" bit-for-bit, at fmt.total_bits per element.
STORAGE_MODES = ("f32", "quantized", "packed")


class StepOps:
    """Per-step policy arithmetic for stepper code.

    Wraps ``(engine, cfg, tracker)`` so a stepper writes
    ``flux = ops.mul(alpha, lap, "heat.flux")`` and the tracker state —
    when one is threaded — is updated in place and returned to the scan
    carry by the driver. With ``tracker=None`` the calls are exactly the
    engine calls the pre-framework solvers made, so untracked numerics are
    bit-identical to the old per-workload loops.
    """

    __slots__ = (
        "prec", "tracker", "_engine", "_cap_spec", "_cap_sites", "cap_counts", "cap_evidence",
    )

    def __init__(self, prec: PrecisionConfig, tracker=None, capture=None):
        self.prec = prec
        self.tracker = tracker
        self._engine = get_engine(prec)
        self._cap_spec = None
        if capture is not None:
            # (CaptureSpec, site tuple, carried (n_sites, 2, n_bins) counts):
            # the driver threads the counts through the scan like the tracker
            self._cap_spec, self._cap_sites, self.cap_counts = capture
            self.cap_evidence = jnp.full(
                (len(self._cap_sites), 2), -127.0, jnp.float32
            )  # per-step site evidence; -127 is the zero-operand floor

    def mul(self, a, b, site: str):
        """Elementwise product on the policy's multiplier at a named site."""
        if self._cap_spec is not None:
            self._capture(a, b, site)
        out, self.tracker = self._engine.multiply(
            a, b, self.prec, tracker=self.tracker, site=site
        )
        return out

    def _capture(self, a, b, site: str):
        """Range capture: bin the (broadcast) operands' elementwise exponents
        and record the site-level max-exponent evidence — the same binning the
        fused kernels apply in-VMEM (:mod:`repro.profile.capture`)."""
        j = self._cap_sites.index(site)
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        shape = jnp.broadcast_shapes(a.shape, b.shape)
        a = jnp.broadcast_to(a, shape)
        b = jnp.broadcast_to(b, shape)
        self.cap_counts = self.cap_counts.at[j].add(pair_exp_hist(a, b, self._cap_spec))
        self.cap_evidence = self.cap_evidence.at[j].set(
            jnp.maximum(self.cap_evidence[j], site_evidence(a, b))
        )

    def add(self, a, b, site: str):
        """Elementwise sum on the policy's flexible adder at a named site
        (``repro.alu`` alignment-shift evidence law)."""
        if self._cap_spec is not None:
            self._capture(a, b, site)
        out, self.tracker = self._engine.add(
            a, b, self.prec, tracker=self.tracker, site=site
        )
        return out

    def div(self, a, b, site: Optional[str] = None):
        """Quotient on the policy's divider. With a named ``site`` this is
        the tracked ``repro.alu`` flexible divider (quotient-range evidence
        law); ``site=None`` keeps the historical untracked engine call."""
        if site is None:
            out, _ = self._engine.divide(a, b, self.prec)
            return out
        if self._cap_spec is not None:
            self._capture(a, b, site)
        out, self.tracker = self._engine.divide(
            a, b, self.prec, tracker=self.tracker, site=site
        )
        return out

    def rsqrt(self, x, site: Optional[str] = None):
        """Reciprocal square root on the policy's datapath; the unary
        evidence is the operand's exponent doubled up."""
        if site is None:
            out, _ = self._engine.rsqrt(x, self.prec)
            return out
        if self._cap_spec is not None:
            self._capture(x, x, site)
        out, self.tracker = self._engine.rsqrt(
            x, self.prec, tracker=self.tracker, site=site
        )
        return out

    def store(self, x):
        """Round state to the policy's storage format."""
        return self._engine.store(x, self.prec)


class Stepper:
    """One PDE workload: state initialisation, one update, what to snapshot.

    Subclasses implement ``init_state`` and ``step`` and declare their named
    multiplication sites (``sites``) — the rows a tracked run's SiteTracker
    carries. ``name`` is stamped by ``register_stepper``; ``failure_mode``
    and ``story`` are documentation metadata surfaced by the README scenario
    table and the per-stepper benchmark suite.
    """

    name: str = "?"
    sites: Tuple[str, ...] = ()
    #: per-site op declarations aligned with ``sites`` ("mul" | "add" |
    #: "div" | "rsqrt") — selects each site's exponent envelope when fused
    #: evidence replays through the adjust unit (``fold_evidence``). Empty
    #: means all-"mul" (the historical multiplier-only workloads).
    site_ops: Tuple[str, ...] = ()
    #: how this scenario breaks a fixed 16-bit format (README table):
    #: "underflow" | "overflow" | "nonlinear-drift"
    failure_mode: str = "?"
    story: str = ""
    #: default number of snapshots when ``snapshot_every`` is not given
    #: (kept per-stepper so the legacy ``simulate`` shims stay bit-identical)
    snapshots_default: int = 8
    #: Optional fused-plane hook, registered alongside ``step``. A stepper
    #: with a fused body overrides this with a method of signature
    #: ``fused_step(state, cfg, prec, steps, *, k_floor=None,
    #: collect_evidence=False, capture=None, interpret=None) ->
    #: (state, evidence)`` that advances ``steps`` substeps through Pallas
    #: whole-step kernels (:mod:`repro.kernels.fused`) and, when asked,
    #: returns the per-substep per-site max-exponent evidence
    #: ``(steps, len(sites), 2)`` the driver folds into the carried tracker.
    #: With a ``capture`` spec (range profiling, DESIGN.md §11) the return
    #: grows a trailing ``(len(sites), 2, n_bins)`` exponent-count array.
    #: Steppers with ``fused_packed = True`` additionally accept
    #: ``storage="packed"`` and then take/return the state as
    #: :class:`repro.pack.PackedArray` leaves, unpacked/repacked inside the
    #: kernel (one HBM round trip at ``fmt.total_bits`` per element).
    #: ``None`` means "reference path only".
    fused_step = None
    #: True when ``fused_step`` supports in-kernel packed storage — the
    #: Pallas sweep unpacks the payload in its prologue and repacks in its
    #: epilogue, so packed chunks never materialise f32 state in HBM.
    #: False (e.g. SWE's flux-kernel stepper) means the driver packs at the
    #: XLA boundary instead: same bits, f32 traffic inside the chunk.
    fused_packed: bool = False
    #: Optional whole-horizon megakernel hook (DESIGN.md §14). A stepper
    #: with one overrides this with a method of signature
    #: ``mega_step(state, cfg, prec, steps, every, *, tracker=None,
    #: collect_evidence=False, capture=None, interpret=None,
    #: storage="f32") -> repro.kernels.mega.MegaResult`` that runs the
    #: ENTIRE horizon — snapshots, boundary storage rounding, and (for
    #: tracked modes) the per-substep on-chip adjust unit — in ONE
    #: ``pallas_call``. ``tracker`` is the raw RangeTracker state (site
    #: rows ordered like ``sites``); evolved state comes back in the
    #: result. ``None`` means "chunked planes only".
    mega_step = None

    def fused_supported(self, cfg, prec: PrecisionConfig) -> bool:
        """Shape/config eligibility gate for the fused body (mode
        eligibility is the policy's side: ``precision.fused_eligible``)."""
        del cfg, prec
        return True

    def mega_supported(self, cfg, prec: PrecisionConfig) -> bool:
        """Shape/config eligibility gate for the megakernel. The megakernel
        keeps one block per state leaf, so steppers whose chunked kernels
        tile the field must refuse configs that exceed one kernel block
        (per-tile split selection would otherwise diverge from the
        whole-field selection and break cross-plane bit parity)."""
        del cfg, prec
        return True

    def default_config(self):
        raise NotImplementedError

    def init_state(self, cfg):
        """Initial solver state (a pytree of f32 arrays)."""
        raise NotImplementedError

    def step(self, state, cfg, ops: StepOps):
        """One update. All policy multiplications go through ``ops.mul``."""
        raise NotImplementedError

    def observables(self, state, cfg):
        """What one snapshot records (default: the whole state)."""
        del cfg
        return state

    def metric_offset(self, cfg) -> float:
        """Constant background removed before rel-L2 metrics (e.g. the SWE
        resting depth) — used by ``repro.profile``'s validation replay."""
        del cfg
        return 0.0


class SimResult(NamedTuple):
    """What a run returns; ``tracker`` is None for untracked modes and
    ``profile`` is None unless the run captured range distributions."""

    state: Any  # final solver state
    snapshots: Any  # stacked observables, leading dim = n snapshots
    tracker: Optional[Any]  # final SiteTracker (tracked modes)
    profile: Optional[Any] = None  # repro.profile.capture.CaptureResult


def _constrain_ensemble(tree):
    """Annotate every leaf's leading (member) dim as the logical batch axis.

    No-op outside a ``dist.sharding.axis_rules`` context, so unsharded
    ensembles and unit tests run mesh-free.
    """
    return jax.tree_util.tree_map(
        lambda x: constrain(x, "batch", *([None] * (x.ndim - 1))), tree
    )


@dataclasses.dataclass
class Simulation:
    """The scan/snapshot scaffolding, owned once for every stepper.

    ``stepper`` may be a registered name or a Stepper instance; ``cfg``
    defaults to the stepper's ``default_config()``.
    """

    stepper: Union[str, Stepper]
    cfg: Any
    prec: PrecisionConfig

    def __post_init__(self):
        if isinstance(self.stepper, str):
            self.stepper = get_stepper(self.stepper)
        if self.cfg is None:
            self.cfg = self.stepper.default_config()

    # -- tracker ------------------------------------------------------------

    def init_tracker(self, k0: Optional[int] = None):
        """Fresh SiteTracker over the stepper's sites (tracked modes only;
        returns None when the engine does not track or there are no sites)."""
        if not (get_engine(self.prec).tracks and self.stepper.sites):
            return None
        return site_tracker_init(self.stepper.sites, self.prec.fmt, k0=k0)

    # -- fused-plane dispatch ----------------------------------------------

    def fused_eligible(self) -> bool:
        """Can this (stepper, cfg, prec) run on the fused execution plane?"""
        return fused_eligible(self.prec, self.stepper, self.cfg)

    def mega_eligible(self) -> bool:
        """Can this (stepper, cfg, prec) run on the whole-horizon megakernel
        plane (DESIGN.md §14)?"""
        return mega_eligible(self.prec, self.stepper, self.cfg)

    def _resolve_execution(self, execution: str) -> str:
        if execution not in ("reference", "fused", "megakernel", "auto"):
            raise ValueError(
                f"unknown execution mode {execution!r}; "
                "expected 'reference' | 'fused' | 'megakernel' | 'auto'"
            )
        if execution == "auto":
            if self.mega_eligible():
                return "megakernel"
            return "fused" if self.fused_eligible() else "reference"
        if execution == "fused" and not self.fused_eligible():
            raise ValueError(
                f"stepper {self.stepper.name!r} is not fused-eligible under "
                f"mode {self.prec.mode!r} (no fused_step hook, unknown fused "
                "arithmetic family, or unsupported shape); use "
                "execution='auto' for graceful fallback"
            )
        if execution == "megakernel" and not self.mega_eligible():
            raise ValueError(
                f"stepper {self.stepper.name!r} is not megakernel-eligible "
                f"under mode {self.prec.mode!r} (no mega_step hook, unknown "
                "fused arithmetic family, or unsupported shape); use "
                "execution='auto' for graceful fallback"
            )
        return execution

    # -- carried-state storage (DESIGN.md §13) -------------------------------

    @staticmethod
    def _resolve_storage(storage: str) -> str:
        if storage not in STORAGE_MODES:
            raise ValueError(
                f"unknown storage mode {storage!r}; expected one of {STORAGE_MODES}"
            )
        return storage

    def _storage_in(self, state0, storage: str):
        """Bring an initial state onto the run's storage format. Packed runs
        accept either f32 leaves (packed here — the run's first and only
        pack of that boundary) or an already-packed tree (a resumed carry
        from a previous packed run / service chunk, used verbatim)."""
        fmt = self.prec.fmt
        if storage == "packed":
            return state0 if is_packed(state0) else pack_state(state0, fmt)
        if is_packed(state0):
            state0 = unpack_state(state0)
        return storage_quantize(state0, fmt) if storage == "quantized" else state0

    # -- profiling / policy plumbing ----------------------------------------

    def _resolve_capture(self, capture):
        """``capture`` may be None, True (default spec) or a CaptureSpec."""
        if capture is None or capture is False:
            return None
        if capture is True:
            capture = CaptureSpec()
        if not isinstance(capture, CaptureSpec):
            raise TypeError(f"capture must be bool or CaptureSpec, got {capture!r}")
        if not self.stepper.sites:
            raise ValueError(
                f"stepper {self.stepper.name!r} declares no multiplication "
                "sites; nothing to capture"
            )
        return capture

    def _apply_policy(self, prec, tracker, policy):
        """Load a ``repro.profile`` PrecisionPolicy artifact: per-site tuned
        starting splits for the tracker plus the floor/ceiling hints as
        ``prec.k_bounds`` (ordered by the stepper's site tuple)."""
        sites = self.stepper.sites
        prec = policy.apply(prec, sites)
        if tracker is None and get_engine(prec).tracks and sites:
            tracker = site_tracker_init(sites, prec.fmt, k0=policy.k_array(sites))
        return prec, tracker

    # -- f32 oracle (shadow replay) ------------------------------------------

    def oracle(self) -> "Simulation":
        """This simulation's f32 oracle twin: same stepper and config,
        reference arithmetic. The health plane's shadow sampler replays
        service requests through it to measure live drift (DESIGN.md §16)."""
        return Simulation(self.stepper, self.cfg, PrecisionConfig(mode="f32"))

    def oracle_replay(
        self,
        steps: int,
        *,
        state0=None,
        snapshot_every: Optional[int] = None,
    ) -> SimResult:
        """Replay a workload at f32 on the reference plane — the shadow
        oracle of :mod:`repro.obs.shadow`.

        This is an entirely separate program over copies of the inputs: it
        shares no carried state, tracker or compiled executable with the
        primary run, which is why shadow sampling is passive (the primary
        path is bit-identical with shadowing on or off; proven in
        ``tests/test_health.py``)."""
        return self.oracle().run(
            steps,
            snapshot_every=snapshot_every,
            state0=state0,
            execution="reference",
        )

    # -- single run ---------------------------------------------------------

    def run(
        self,
        steps: int,
        *,
        snapshot_every: Optional[int] = None,
        state0=None,
        tracker=None,
        execution: str = "reference",
        capture=None,
        policy=None,
        storage: str = "f32",
    ) -> SimResult:
        """Advance ``steps`` updates, snapshotting observables periodically.

        The scan carry is ``(state, tracker)`` — tracked engines see the
        tracker every step and their updated state is carried forward, so
        the flexible split ``k`` genuinely evolves across time. Pass an
        explicit ``tracker`` to resume from saved adjust-unit state; by
        default tracked modes start from :meth:`init_tracker`.

        ``execution`` selects the arithmetic plane (DESIGN.md §10):

        * ``"reference"`` — the stepwise ``StepOps`` engine path (default;
          bit-exact emulation semantics, every mode).
        * ``"fused"`` — whole snapshot intervals run as multi-substep Pallas
          kernel chunks via the stepper's ``fused_step`` hook; tracked modes
          fold the kernels' per-site range evidence into the carried tracker
          between chunks. Raises if the stepper/mode is not fused-eligible.
        * ``"megakernel"`` — the ENTIRE horizon runs in ONE ``pallas_call``
          via the stepper's ``mega_step`` hook (DESIGN.md §14): snapshots
          stream out at their cadence and the precision adjust unit evolves
          on-chip per substep, so there is no per-chunk launch or HBM round
          trip. Bit-identical to ``"fused"`` (same arithmetic, same
          boundary rounding, same adjust law at the same cadence). Raises
          if the stepper/mode is not megakernel-eligible.
        * ``"auto"`` — ``"megakernel"`` when eligible, else ``"fused"``
          when eligible, else ``"reference"``.

        ``capture`` (None | True | :class:`repro.profile.capture.CaptureSpec`)
        turns on range-distribution capture (DESIGN.md §11): the result's
        ``profile`` field carries the per-step site evidence stream and the
        per-site operand exponent histograms, on BOTH execution planes.

        ``policy`` loads a ``repro.profile`` PrecisionPolicy artifact:
        tracked modes start their tracker at the artifact's per-site tuned
        splits and clamp re-picks to its floor/ceiling hints. Combine with
        ``prec.pinned`` for the static profiled-deployment emulation.

        ``storage`` selects the carried-state format between chunk
        boundaries (snapshot intervals — :data:`STORAGE_MODES`, DESIGN.md
        §13). ``"quantized"`` rounds boundary state through the packed
        format but carries f32; ``"packed"`` carries
        :class:`repro.pack.PackedArray` payloads (``fmt.total_bits`` per
        element — the result's ``state`` and any resumed carry are packed
        trees) and is bit-identical to ``"quantized"`` by construction:
        both apply exactly one pack per boundary to the same f32 values.

        With :mod:`repro.obs` enabled the run is wrapped in a ``sim.run``
        span and — for tracked modes — its final tracker (and, when the run
        captured evidence, the full chunk-boundary k series replayed from
        that evidence) is drained into the precision telemetry. All of it is
        passive host-side observation: the numerics are bit-identical with
        observability on or off (``tests/test_obs.py``).
        """
        resolved = self._resolve_execution(execution)
        with _obs.span(
            "sim.run",
            stepper=self.stepper.name,
            mode=self.prec.mode,
            steps=steps,
            execution=resolved,
            storage=storage,
        ):
            _obs.inc(
                "repro_sim_runs_total",
                help="Simulation.run calls by plane",
                stepper=self.stepper.name,
                mode=self.prec.mode,
                execution=resolved,
            )
            res = self._run(
                steps,
                snapshot_every=snapshot_every,
                state0=state0,
                tracker=tracker,
                execution=resolved,
                capture=capture,
                policy=policy,
                storage=storage,
            )
        self._drain_telemetry(res, steps, snapshot_every, tracker, policy)
        return res

    def _run(
        self,
        steps: int,
        *,
        snapshot_every: Optional[int] = None,
        state0=None,
        tracker=None,
        execution: str = "reference",
        capture=None,
        policy=None,
        storage: str = "f32",
    ) -> SimResult:
        stepper, cfg, prec = self.stepper, self.cfg, self.prec
        storage = self._resolve_storage(storage)
        if policy is not None:
            prec, tracker = self._apply_policy(prec, tracker, policy)
        state0 = stepper.init_state(cfg) if state0 is None else state0
        state0 = self._storage_in(state0, storage)
        if tracker is None:
            tracker = self.init_tracker()
        spec = self._resolve_capture(capture)
        every = snapshot_every or max(1, steps // stepper.snapshots_default)
        resolved = self._resolve_execution(execution)
        if resolved == "megakernel":
            return self._run_mega(
                steps, every, state0, tracker, prec=prec, capture=spec, storage=storage
            )
        if resolved == "fused":
            return self._run_fused(
                steps, every, state0, tracker, prec=prec, capture=spec, storage=storage
            )

        def body(carry, _):
            state, tr = carry
            ops = StepOps(prec, tr)
            state = stepper.step(state, cfg, ops)
            return (state, ops.tracker), None

        n_out = steps // every
        rem = steps - n_out * every
        if spec is not None:
            return self._run_reference_captured(
                steps, every, n_out, rem, state0, tracker, prec, spec, storage
            )

        if storage == "packed":
            # the outer carry stays packed; each interval unpacks once,
            # advances in f32, and packs once at the boundary
            def outer(carry, _):
                (state, tr), _ = jax.lax.scan(
                    body, (unpack_state(carry[0]), carry[1]), None, length=every
                )
                packed = pack_state(state, prec.fmt)
                return (packed, tr), stepper.observables(unpack_state(packed), cfg)

            carry = (state0, tracker)
            carry, snaps = jax.lax.scan(outer, carry, None, length=n_out)
            if rem:
                (state, tr), _ = jax.lax.scan(
                    body, (unpack_state(carry[0]), carry[1]), None, length=rem
                )
                carry = (pack_state(state, prec.fmt), tr)
            state, tracker = carry
            return SimResult(state, snaps, tracker)

        def outer(carry, _):
            carry, _ = jax.lax.scan(body, carry, None, length=every)
            state = carry[0]
            if storage == "quantized":
                state = storage_quantize(state, prec.fmt)
            return (state, carry[1]), stepper.observables(state, cfg)

        carry = (state0, tracker)
        carry, snaps = jax.lax.scan(outer, carry, None, length=n_out)
        if rem:
            carry, _ = jax.lax.scan(body, carry, None, length=rem)
            if storage == "quantized":
                carry = (storage_quantize(carry[0], prec.fmt), carry[1])
        state, tracker = carry
        return SimResult(state, snaps, tracker)

    # -- precision-telemetry drain (passive; repro.obs) ----------------------

    def _drain_telemetry(self, res, steps, snapshot_every, tracker_arg, policy):
        """Feed a finished run's tracker into ``repro.obs`` telemetry.

        Passivity guard: if any tracker/evidence leaf is a jax tracer (this
        run is being traced inside jit/vmap — e.g. the service's compiled
        chunk programs) nothing is drained; the concrete values are observed
        by whoever executes the compiled program (the batcher)."""
        o = _obs.active()
        if o is None or o.telemetry is None or res.tracker is None:
            return
        leaves = jax.tree_util.tree_leaves(
            (res.tracker, None if res.profile is None else res.profile.evidence)
        )
        if any(isinstance(x, jax.core.Tracer) for x in leaves):
            return
        stepper = self.stepper
        scope = o.telemetry.unique_scope(f"sim:{stepper.name}")
        if res.profile is None:
            o.telemetry.record_tracker(scope, res.tracker, steps)
            return
        # captured run: replay the evidence stream through the adjust law to
        # reconstruct the k series at every chunk boundary, plus coverage of
        # the final carried splits (repro.obs.precision — no new kernel
        # outputs, the capture plane already emits this stream)
        from repro.obs.precision import coverage_fraction, replay_k_series

        prec, tr0 = self.prec, tracker_arg
        if policy is not None:
            prec, tr0 = self._apply_policy(prec, tr0, policy)
        if tr0 is None:
            tr0 = self.init_tracker()
        every = snapshot_every or max(1, steps // stepper.snapshots_default)
        sites = list(stepper.sites)
        ops = stepper.site_ops or None
        bsteps, k, grew, shrank = replay_k_series(
            res.profile.evidence, prec, sites, site_ops=ops, every=every,
            tracker0=tr0,
        )
        st = res.tracker.state
        final_k = {n: int(st.k[i]) for i, n in enumerate(res.tracker.names)}
        cov = coverage_fraction(
            res.profile.evidence, prec, sites, final_k, site_ops=ops
        )
        o.telemetry.record_series(
            scope, sites, bsteps, k, grew, shrank, coverage=cov
        )

    def _drain_ensemble_telemetry(self, res, steps):
        """Per-member final-tracker drain after a concrete run_ensemble."""
        o = _obs.active()
        if o is None or o.telemetry is None or res.tracker is None:
            return
        leaves = jax.tree_util.tree_leaves(res.tracker)
        if any(isinstance(x, jax.core.Tracer) for x in leaves):
            return
        base = o.telemetry.unique_scope(f"ens:{self.stepper.name}")
        n_members = res.tracker.state.k.shape[0]
        for i in range(n_members):
            tr_i = jax.tree_util.tree_map(lambda x: x[i], res.tracker)
            o.telemetry.record_tracker(f"{base}/m{i}", tr_i, steps)

    def _run_reference_captured(
        self, steps, every, n_out, rem, state0, tracker, prec, spec, storage="f32"
    ) -> SimResult:
        """The reference loop with range capture: the exponent-count
        accumulator rides the scan carry next to the tracker, per-step site
        evidence is a scan output, and each snapshot interval emits its
        count delta (the profile's time axis). Boundary storage rounding is
        applied exactly as in the uncaptured loop (one pack per boundary)."""
        stepper, cfg = self.stepper, self.cfg
        n_sites = len(stepper.sites)
        counts0 = jnp.zeros((n_sites, 2, spec.n_bins), jnp.int32)
        packed_mode = storage == "packed"

        def body(carry, _):
            state, tr, counts = carry
            ops = StepOps(prec, tr, capture=(spec, stepper.sites, counts))
            state = stepper.step(state, cfg, ops)
            return (state, ops.tracker, ops.cap_counts), ops.cap_evidence

        def _boundary(state):
            if storage == "quantized":
                return storage_quantize(state, prec.fmt)
            return pack_state(state, prec.fmt) if packed_mode else state

        def outer(carry, _):
            state, tr, counts = carry
            before = counts
            if packed_mode:
                state = unpack_state(state)
            (state, tr, counts), evs = jax.lax.scan(
                body, (state, tr, counts), None, length=every
            )
            state = _boundary(state)
            obs = stepper.observables(
                unpack_state(state) if packed_mode else state, cfg
            )
            return (state, tr, counts), (obs, evs, counts - before)

        carry = (state0, tracker, counts0)
        carry, (snaps, evs, exp_time) = jax.lax.scan(outer, carry, None, length=n_out)
        evidence = evs.reshape((n_out * every, n_sites, 2))
        if rem:
            state, tr, counts = carry
            if packed_mode:
                state = unpack_state(state)
            (state, tr, counts), evs_rem = jax.lax.scan(
                body, (state, tr, counts), None, length=rem
            )
            carry = (_boundary(state), tr, counts)
            evidence = jnp.concatenate([evidence, evs_rem], axis=0)
        state, tracker, exp_total = carry
        return SimResult(state, snaps, tracker, CaptureResult(evidence, exp_time, exp_total))

    def _run_fused(
        self,
        steps: int,
        every: int,
        state0,
        tracker,
        *,
        prec=None,
        capture=None,
        storage: str = "f32",
    ) -> SimResult:
        """The fused plane's chunked loop: one multi-substep kernel call per
        snapshot interval, tracker evidence folded in between chunks.

        The carried tracker's per-site splits enter each chunk as the rr
        family's k floor (the adjust unit's persistent format choice); the
        chunk's per-substep evidence then replays through the same
        adjust-unit math the stepwise loop applies
        (:func:`repro.precision.fold_evidence`). With ``capture``, the
        kernels' widened evidence stream (per-site exponent counts) comes
        back per chunk and assembles into the run's profile.

        Packed storage has two shapes here. Steppers with
        ``fused_packed = True`` take the PackedArray carry straight into the
        kernel (``fused_step(..., storage="packed")``): unpack rides the
        sweep prologue and repack its epilogue, so the chunk's HBM traffic
        is the payload — ``fmt.total_bits`` per element instead of 32.
        Otherwise the driver packs at the XLA boundary around the f32
        ``fused_step``: same bits (one pack per boundary either way), no
        bandwidth win inside the chunk.
        """
        stepper, cfg = self.stepper, self.cfg
        prec = self.prec if prec is None else prec
        in_kernel = storage == "packed" and getattr(stepper, "fused_packed", False)

        def chunk(carry, n):
            state, tr = carry
            if storage == "packed" and not in_kernel:
                state = unpack_state(state)
            res = stepper.fused_step(
                state,
                cfg,
                prec,
                n,
                k_floor=None if tr is None else tr.state.k,
                # pinned runs never fold evidence, so don't collect it either
                collect_evidence=capture is not None
                or (tr is not None and not prec.pinned),
                capture=capture,
                **({"storage": "packed"} if in_kernel else {}),
            )
            state, ev = res[:2]
            if storage == "quantized":
                state = storage_quantize(state, prec.fmt)
            elif storage == "packed" and not in_kernel:
                state = pack_state(state, prec.fmt)
            if tr is not None:
                tr = fold_evidence(tr, ev, prec, ops=stepper.site_ops or None)
            return (state, tr), ev, (res[2] if capture is not None else None)

        def outer(carry, _):
            carry, ev, counts = chunk(carry, every)
            obs = stepper.observables(
                unpack_state(carry[0]) if storage == "packed" else carry[0], cfg
            )
            return carry, (obs if capture is None else (obs, ev, counts))

        n_out = steps // every
        rem = steps - n_out * every
        carry = (state0, tracker)
        carry, snaps = jax.lax.scan(outer, carry, None, length=n_out)
        evidence = exp_time = exp_total = None
        if capture is not None:
            snaps, evs, exp_time = snaps
            evidence = evs.reshape((n_out * every, len(stepper.sites), 2))
            exp_total = jnp.sum(exp_time, axis=0, dtype=jnp.int32)
        if rem:
            # the one remainder epilogue: a short chunk under the same law as
            # the in-loop cadence (storage rounding included), its evidence
            # and counts appended to the captured stream when profiling
            carry, ev_rem, counts_rem = chunk(carry, rem)
            if capture is not None:
                evidence = jnp.concatenate([evidence, ev_rem], axis=0)
                exp_total = exp_total + counts_rem
        state, tracker = carry
        return SimResult(
            state, snaps, tracker,
            self._assemble_profile(capture, evidence, exp_time, exp_total),
        )

    @staticmethod
    def _assemble_profile(capture, evidence, exp_time, exp_total):
        """Shared capture epilogue for the fused and megakernel planes."""
        if capture is None:
            return None
        return CaptureResult(evidence, exp_time, exp_total)

    def _run_mega(
        self,
        steps: int,
        every: int,
        state0,
        tracker,
        *,
        prec=None,
        capture=None,
        storage: str = "f32",
    ) -> SimResult:
        """The megakernel plane (DESIGN.md §14): the whole horizon in ONE
        ``pallas_call``.

        Where :meth:`_run_fused` re-enters a kernel per snapshot interval
        and folds range evidence on the host between chunks, here the
        stepper's ``mega_step`` keeps state AND adjust unit on-chip for all
        ``steps`` substeps: tracker rows evolve per substep through the
        jax-pure scalar law :func:`repro.core.policy.adjust_step`, the
        *datapath* floor latches at snapshot boundaries — the chunked
        plane's fold cadence, which is what keeps the two planes
        bit-identical — and snapshots / evidence / capture histograms
        stream out as secondary kernel outputs at their cadence. Boundary
        storage rounding (``"quantized"``/``"packed"``) happens in-kernel
        with the shared pack helpers: same splits, same bits, one (virtual)
        pack per boundary.
        """
        stepper, cfg = self.stepper, self.cfg
        prec = self.prec if prec is None else prec
        res = stepper.mega_step(
            state0,
            cfg,
            prec,
            steps,
            every,
            tracker=None if tracker is None else tracker.state,
            capture=capture,
            storage=storage,
        )
        snaps = jax.vmap(lambda s: stepper.observables(s, cfg))(res.snaps)
        if tracker is not None:
            tracker = rewrap(tracker, res.tracker)
        return SimResult(
            res.state, snaps, tracker,
            self._assemble_profile(capture, res.evidence, res.exp_time, res.exp_total),
        )

    # -- ensembles ----------------------------------------------------------

    def run_ensemble(
        self,
        state0_batch,
        steps: int,
        *,
        snapshot_every: Optional[int] = None,
        sharded: bool = False,
        execution: str = "reference",
        capture=None,
        policy=None,
        tracker0_batch=None,
        storage: str = "f32",
    ) -> SimResult:
        """Vmapped ensemble over a batch of initial conditions.

        ``state0_batch`` is the stepper's state pytree with a leading member
        dim on every leaf. Each member carries its own tracker rows (the
        per-member precision-adjust state the hardware would have). With
        ``sharded=True`` the member dim is annotated as the logical
        ``batch`` axis, so inside a ``dist.sharding.axis_rules(mesh)``
        context the ensemble spreads over the mesh's data axes — the
        production-scale path for parameter sweeps and uncertainty
        quantification. ``capture``/``policy`` behave as in :meth:`run`,
        per member (each member gets its own histograms and evidence).

        ``tracker0_batch`` resumes tracked modes from a *stacked* tracker
        (a SiteTracker whose state arrays lead with the member dim — e.g.
        the ``tracker`` a previous ``run_ensemble`` returned). This is the
        repacking contract ``repro.service`` builds its continuous batching
        on: between chunks the serving plane drains finished members, adds
        joiners, restacks ``(state, tracker)`` and calls back in — each
        member's carried split ``k`` and §5.3 adjustment counters survive
        the repack because they are handed straight back here.

        ``storage`` behaves as in :meth:`run`, per member; a packed
        ensemble's state batch (initial and returned) is a PackedArray tree
        whose children lead with the member dim — the repacking contract
        above carries packed members between service chunks without ever
        widening them to f32 in HBM.
        """
        with _obs.span(
            "sim.run_ensemble",
            stepper=self.stepper.name,
            mode=self.prec.mode,
            steps=steps,
            execution=execution,
            sharded=bool(sharded),
        ):
            res = self._run_ensemble(
                state0_batch,
                steps,
                snapshot_every=snapshot_every,
                sharded=sharded,
                execution=execution,
                capture=capture,
                policy=policy,
                tracker0_batch=tracker0_batch,
                storage=storage,
            )
        self._drain_ensemble_telemetry(res, steps)
        return res

    def _run_ensemble(
        self,
        state0_batch,
        steps: int,
        *,
        snapshot_every: Optional[int] = None,
        sharded: bool = False,
        execution: str = "reference",
        capture=None,
        policy=None,
        tracker0_batch=None,
        storage: str = "f32",
    ) -> SimResult:
        if sharded:
            state0_batch = _constrain_ensemble(state0_batch)
            if tracker0_batch is not None:
                tracker0_batch = _constrain_ensemble(tracker0_batch)
        # resolve once outside the vmap so an ineligible explicit "fused"
        # raises eagerly with the real reason rather than from inside a trace
        execution = self._resolve_execution(execution)
        storage = self._resolve_storage(storage)

        def one(s0, tr0=None):
            return self.run(
                steps,
                snapshot_every=snapshot_every,
                state0=s0,
                tracker=tr0,
                execution=execution,
                capture=capture,
                policy=policy,
                storage=storage,
            )

        if tracker0_batch is not None:
            res = jax.vmap(one)(state0_batch, tracker0_batch)
        else:
            res = jax.vmap(one)(state0_batch)
        if sharded:
            # every result leaf (state, snapshots, tracker rows) leads with
            # the member dim — annotate them all so nothing gets replicated
            res = _constrain_ensemble(res)
        return res
