"""Unified PDE solver framework: Stepper protocol + Simulation driver.

Every PDE workload used to hand-roll its own ``lax.scan`` scaffolding, and
none threaded a tracker through the loop — so the cross-step ``rr_tracked``
engine silently degraded to stateless per-tensor selection exactly where the
paper exercises it. This module owns the simulation loop once:

* a :class:`Stepper` is the workload: ``init_state / step / observables``
  plus static metadata (named multiplication sites, precision failure mode);
* :class:`StepOps` is the per-step arithmetic context handed to
  ``Stepper.step``: ``mul/div/store`` route through the precision engine and
  thread the tracker implicitly, so stepper code never touches tracker
  plumbing;
* :class:`Simulation` drives the scan/snapshot loop, carrying
  ``(state, tracker)`` through every step — tracked modes (``rr_tracked`` /
  ``deploy``, any engine with ``tracks=True``) genuinely carry the flexible
  split ``k`` across time, the paper's precision-adjust-unit persistence;
* ensembles of initial conditions run vmapped
  (:meth:`Simulation.run_ensemble`), optionally sharded over the mesh's
  data axes via :mod:`repro.dist.sharding` logical-axis rules (the ensemble
  member dim is the logical ``batch`` axis).

Steppers register under a string key (:mod:`repro.pde.registry`, mirroring
``precision/registry.py``), so benchmarks, examples and docs enumerate
scenarios instead of importing workload modules. See DESIGN.md §9.

The driver owns TWO arithmetic planes (``run(..., execution=...)``,
DESIGN.md §10): the reference ``StepOps`` path above, and a **fused
execution plane** where whole snapshot intervals run as multi-substep
Pallas kernel chunks through the stepper's optional ``fused_step`` hook —
one HBM round trip per chunk, per-block runtime splits selected in VMEM,
and the kernels' per-site range evidence folded into the carried tracker
between chunks (:func:`repro.precision.fold_evidence`), so tracked modes
ride the fast path with the same adjust-unit semantics. ``"auto"`` picks
fused when :func:`repro.precision.fused_eligible` accepts and falls back to
the reference path otherwise.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionConfig
from repro.dist.sharding import constrain
from repro.precision import fold_evidence, fused_eligible, get_engine, site_tracker_init
from repro.pde.registry import get_stepper

__all__ = ["Stepper", "StepOps", "Simulation", "SimResult"]


class StepOps:
    """Per-step policy arithmetic for stepper code.

    Wraps ``(engine, cfg, tracker)`` so a stepper writes
    ``flux = ops.mul(alpha, lap, "heat.flux")`` and the tracker state —
    when one is threaded — is updated in place and returned to the scan
    carry by the driver. With ``tracker=None`` the calls are exactly the
    engine calls the pre-framework solvers made, so untracked numerics are
    bit-identical to the old per-workload loops.
    """

    __slots__ = ("prec", "tracker", "_engine")

    def __init__(self, prec: PrecisionConfig, tracker=None):
        self.prec = prec
        self.tracker = tracker
        self._engine = get_engine(prec)

    def mul(self, a, b, site: str):
        """Elementwise product on the policy's multiplier at a named site."""
        out, self.tracker = self._engine.multiply(
            a, b, self.prec, tracker=self.tracker, site=site
        )
        return out

    def div(self, a, b):
        """Quotient on the substrate divider (R2F2 is a multiplier)."""
        return self._engine.divide(a, b, self.prec)

    def store(self, x):
        """Round state to the policy's storage format."""
        return self._engine.store(x, self.prec)


class Stepper:
    """One PDE workload: state initialisation, one update, what to snapshot.

    Subclasses implement ``init_state`` and ``step`` and declare their named
    multiplication sites (``sites``) — the rows a tracked run's SiteTracker
    carries. ``name`` is stamped by ``register_stepper``; ``failure_mode``
    and ``story`` are documentation metadata surfaced by the README scenario
    table and the per-stepper benchmark suite.
    """

    name: str = "?"
    sites: Tuple[str, ...] = ()
    #: how this scenario breaks a fixed 16-bit format (README table):
    #: "underflow" | "overflow" | "nonlinear-drift"
    failure_mode: str = "?"
    story: str = ""
    #: default number of snapshots when ``snapshot_every`` is not given
    #: (kept per-stepper so the legacy ``simulate`` shims stay bit-identical)
    snapshots_default: int = 8
    #: Optional fused-plane hook, registered alongside ``step``. A stepper
    #: with a fused body overrides this with a method of signature
    #: ``fused_step(state, cfg, prec, steps, *, k_floor=None,
    #: collect_evidence=False, interpret=None) -> (state, evidence)`` that
    #: advances ``steps`` substeps through Pallas whole-step kernels
    #: (:mod:`repro.kernels.fused`) and, when asked, returns the per-substep
    #: per-site max-exponent evidence ``(steps, len(sites), 2)`` the driver
    #: folds into the carried tracker. ``None`` means "reference path only".
    fused_step = None

    def fused_supported(self, cfg, prec: PrecisionConfig) -> bool:
        """Shape/config eligibility gate for the fused body (mode
        eligibility is the policy's side: ``precision.fused_eligible``)."""
        del cfg, prec
        return True

    def default_config(self):
        raise NotImplementedError

    def init_state(self, cfg):
        """Initial solver state (a pytree of f32 arrays)."""
        raise NotImplementedError

    def step(self, state, cfg, ops: StepOps):
        """One update. All policy multiplications go through ``ops.mul``."""
        raise NotImplementedError

    def observables(self, state, cfg):
        """What one snapshot records (default: the whole state)."""
        del cfg
        return state


class SimResult(NamedTuple):
    """What a run returns; ``tracker`` is None for untracked modes."""

    state: Any  # final solver state
    snapshots: Any  # stacked observables, leading dim = n snapshots
    tracker: Optional[Any]  # final SiteTracker (tracked modes)


def _constrain_ensemble(tree):
    """Annotate every leaf's leading (member) dim as the logical batch axis.

    No-op outside a ``dist.sharding.axis_rules`` context, so unsharded
    ensembles and unit tests run mesh-free.
    """
    return jax.tree_util.tree_map(
        lambda x: constrain(x, "batch", *([None] * (x.ndim - 1))), tree
    )


@dataclasses.dataclass
class Simulation:
    """The scan/snapshot scaffolding, owned once for every stepper.

    ``stepper`` may be a registered name or a Stepper instance; ``cfg``
    defaults to the stepper's ``default_config()``.
    """

    stepper: Union[str, Stepper]
    cfg: Any
    prec: PrecisionConfig

    def __post_init__(self):
        if isinstance(self.stepper, str):
            self.stepper = get_stepper(self.stepper)
        if self.cfg is None:
            self.cfg = self.stepper.default_config()

    # -- tracker ------------------------------------------------------------

    def init_tracker(self, k0: Optional[int] = None):
        """Fresh SiteTracker over the stepper's sites (tracked modes only;
        returns None when the engine does not track or there are no sites)."""
        if not (get_engine(self.prec).tracks and self.stepper.sites):
            return None
        return site_tracker_init(self.stepper.sites, self.prec.fmt, k0=k0)

    # -- fused-plane dispatch ----------------------------------------------

    def fused_eligible(self) -> bool:
        """Can this (stepper, cfg, prec) run on the fused execution plane?"""
        return fused_eligible(self.prec, self.stepper, self.cfg)

    def _resolve_execution(self, execution: str) -> str:
        if execution not in ("reference", "fused", "auto"):
            raise ValueError(
                f"unknown execution mode {execution!r}; "
                "expected 'reference' | 'fused' | 'auto'"
            )
        if execution == "auto":
            return "fused" if self.fused_eligible() else "reference"
        if execution == "fused" and not self.fused_eligible():
            raise ValueError(
                f"stepper {self.stepper.name!r} is not fused-eligible under "
                f"mode {self.prec.mode!r} (no fused_step hook, unknown fused "
                "arithmetic family, or unsupported shape); use "
                "execution='auto' for graceful fallback"
            )
        return execution

    # -- single run ---------------------------------------------------------

    def run(
        self,
        steps: int,
        *,
        snapshot_every: Optional[int] = None,
        state0=None,
        tracker=None,
        execution: str = "reference",
    ) -> SimResult:
        """Advance ``steps`` updates, snapshotting observables periodically.

        The scan carry is ``(state, tracker)`` — tracked engines see the
        tracker every step and their updated state is carried forward, so
        the flexible split ``k`` genuinely evolves across time. Pass an
        explicit ``tracker`` to resume from saved adjust-unit state; by
        default tracked modes start from :meth:`init_tracker`.

        ``execution`` selects the arithmetic plane (DESIGN.md §10):

        * ``"reference"`` — the stepwise ``StepOps`` engine path (default;
          bit-exact emulation semantics, every mode).
        * ``"fused"`` — whole snapshot intervals run as multi-substep Pallas
          kernel chunks via the stepper's ``fused_step`` hook; tracked modes
          fold the kernels' per-site range evidence into the carried tracker
          between chunks. Raises if the stepper/mode is not fused-eligible.
        * ``"auto"`` — ``"fused"`` when eligible, else ``"reference"``.
        """
        stepper, cfg, prec = self.stepper, self.cfg, self.prec
        state0 = stepper.init_state(cfg) if state0 is None else state0
        if tracker is None:
            tracker = self.init_tracker()
        every = snapshot_every or max(1, steps // stepper.snapshots_default)
        if self._resolve_execution(execution) == "fused":
            return self._run_fused(steps, every, state0, tracker)

        def body(carry, _):
            state, tr = carry
            ops = StepOps(prec, tr)
            state = stepper.step(state, cfg, ops)
            return (state, ops.tracker), None

        def outer(carry, _):
            carry, _ = jax.lax.scan(body, carry, None, length=every)
            return carry, stepper.observables(carry[0], cfg)

        n_out = steps // every
        carry = (state0, tracker)
        carry, snaps = jax.lax.scan(outer, carry, None, length=n_out)
        rem = steps - n_out * every
        if rem:
            carry, _ = jax.lax.scan(body, carry, None, length=rem)
        state, tracker = carry
        return SimResult(state, snaps, tracker)

    def _run_fused(self, steps: int, every: int, state0, tracker) -> SimResult:
        """The fused plane's chunked loop: one multi-substep kernel call per
        snapshot interval, tracker evidence folded in between chunks.

        The carried tracker's per-site splits enter each chunk as the rr
        family's k floor (the adjust unit's persistent format choice); the
        chunk's per-substep evidence then replays through the same
        adjust-unit math the stepwise loop applies
        (:func:`repro.precision.fold_evidence`).
        """
        stepper, cfg, prec = self.stepper, self.cfg, self.prec

        def chunk(carry, n):
            state, tr = carry
            state, ev = stepper.fused_step(
                state,
                cfg,
                prec,
                n,
                k_floor=None if tr is None else tr.state.k,
                collect_evidence=tr is not None,
            )
            if tr is not None:
                tr = fold_evidence(tr, ev, prec)
            return state, tr

        def outer(carry, _):
            carry = chunk(carry, every)
            return carry, stepper.observables(carry[0], cfg)

        n_out = steps // every
        carry = (state0, tracker)
        carry, snaps = jax.lax.scan(outer, carry, None, length=n_out)
        rem = steps - n_out * every
        if rem:
            carry = chunk(carry, rem)
        state, tracker = carry
        return SimResult(state, snaps, tracker)

    # -- ensembles ----------------------------------------------------------

    def run_ensemble(
        self,
        state0_batch,
        steps: int,
        *,
        snapshot_every: Optional[int] = None,
        sharded: bool = False,
        execution: str = "reference",
    ) -> SimResult:
        """Vmapped ensemble over a batch of initial conditions.

        ``state0_batch`` is the stepper's state pytree with a leading member
        dim on every leaf. Each member carries its own tracker rows (the
        per-member precision-adjust state the hardware would have). With
        ``sharded=True`` the member dim is annotated as the logical
        ``batch`` axis, so inside a ``dist.sharding.axis_rules(mesh)``
        context the ensemble spreads over the mesh's data axes — the
        production-scale path for parameter sweeps and uncertainty
        quantification.
        """
        if sharded:
            state0_batch = _constrain_ensemble(state0_batch)
        # resolve once outside the vmap so an ineligible explicit "fused"
        # raises eagerly with the real reason rather than from inside a trace
        execution = self._resolve_execution(execution)

        def one(s0):
            return self.run(
                steps, snapshot_every=snapshot_every, state0=s0, execution=execution
            )

        res = jax.vmap(one)(state0_batch)
        if sharded:
            # every result leaf (state, snapshots, tracker rows) leads with
            # the member dim — annotate them all so nothing gets replicated
            res = _constrain_ensemble(res)
        return res
