"""1D inviscid Burgers equation, Lax-Friedrichs — beyond-paper workload #3.

    du/dt + d(u^2/2)/dx = 0

The flux is the *nonlinear* product ``u * u`` on the policy's multiplier —
the operand range squares, and it *drifts*: a 350-amplitude sin wave needs
the full flexible split (u^2 ~ 1.2e5 overflows E5M10 outright), then the
shock forms at t* = L/(2*pi*A) and Lax-Friedrichs dissipation decays the
N-wave like ~1/t, dropping the product range by orders of magnitude. A
stateless per-step format choice handles each step; what this workload
stresses is the *tracked* path (``rr_tracked`` / ``deploy``): the carried
split must grow to FX at the start and shrink back as the range collapses —
the paper's §4.2 redundancy rule exercised across thousands of steps, which
is exactly the regression the solver framework's tracker threading exists
for.

Periodic domain; fixed ``dt = cfl * dx / amplitude`` (max|u| only decays, so
the CFL bound holds for the whole run).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .registry import register_stepper
from .solver import StepOps, Stepper

__all__ = ["BurgersConfig", "Burgers1DStepper", "initial_wave"]


@dataclasses.dataclass(frozen=True)
class BurgersConfig:
    nx: int = 256
    length: float = 1.0
    amplitude: float = 350.0  # u*u ~ 1.2e5 overflows E5M10's 65504
    cfl: float = 0.4  # dt = cfl*dx/amplitude (max|u| never grows)
    modes: int = 1  # sin harmonics

    @property
    def dx(self) -> float:
        return self.length / self.nx

    @property
    def dt(self) -> float:
        return self.cfl * self.dx / self.amplitude


def initial_wave(cfg: BurgersConfig) -> jnp.ndarray:
    x = jnp.linspace(0.0, cfg.length, cfg.nx, endpoint=False, dtype=jnp.float32)
    return cfg.amplitude * jnp.sin(2.0 * cfg.modes * jnp.pi * x / cfg.length)


@register_stepper("burgers1d")
class Burgers1DStepper(Stepper):
    """Conservative Lax-Friedrichs update on a periodic domain."""

    sites = ("burgers.uu", "burgers.flux")
    site_ops = ("mul", "mul")
    failure_mode = "nonlinear-drift"
    story = "u*u squares the range, overflows E5M10, then decays ~1/t post-shock"
    snapshots_default = 8
    fused_packed = True  # the sweep kernel unpacks/repacks in VMEM

    def default_config(self) -> BurgersConfig:
        return BurgersConfig()

    def init_state(self, cfg: BurgersConfig) -> jnp.ndarray:
        return initial_wave(cfg)

    def step(self, u, cfg: BurgersConfig, ops: StepOps):
        uu = ops.mul(u, u, "burgers.uu")  # the nonlinear flux product
        f = ops.mul(jnp.float32(0.5), uu, "burgers.flux")  # f = u^2/2
        u_avg = 0.5 * (jnp.roll(u, -1) + jnp.roll(u, 1))  # LF average, f32 adds
        df = jnp.roll(f, -1) - jnp.roll(f, 1)
        return u_avg - (cfg.dt / (2.0 * cfg.dx)) * df

    def fused_step(
        self,
        u,
        cfg: BurgersConfig,
        prec,
        steps: int,
        *,
        k_floor=None,
        collect_evidence: bool = False,
        capture=None,
        interpret=None,
        storage: str = "f32",
    ):
        from repro.kernels.pde_steps import burgers1d_sweep  # lazy: pallas off cold paths

        return burgers1d_sweep(
            u,
            dt=cfg.dt,
            dx=cfg.dx,
            prec=prec,
            steps=steps,
            sites=self.sites,
            k_floor=k_floor,
            collect_evidence=collect_evidence,
            capture=capture,
            interpret=interpret,
            storage=storage,
        )

    def mega_step(
        self,
        u,
        cfg: BurgersConfig,
        prec,
        steps: int,
        every: int,
        *,
        tracker=None,
        collect_evidence: bool = False,
        capture=None,
        interpret=None,
        storage: str = "f32",
    ):
        from repro.kernels.mega import burgers1d_mega  # lazy: pallas off cold paths

        return burgers1d_mega(
            u,
            dt=cfg.dt,
            dx=cfg.dx,
            prec=prec,
            steps=steps,
            every=every,
            sites=self.sites,
            tracker=tracker,
            collect_evidence=collect_evidence,
            capture=capture,
            interpret=interpret,
            storage=storage,
        )
