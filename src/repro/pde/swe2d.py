"""2D shallow-water equations, Lax-Wendroff (Richtmyer two-step) — paper §2
and Fig. 8.

State U = (h, hu, hv) on a square ocean basin. Fluxes

    F(U) = (hu,  hu^2/h + g h^2/2,  huv/h)
    G(U) = (hv,  huv/h,             hv^2/h + g h^2/2)

As in the paper's experiment, ONLY the momentum-flux equation
``Ux_mx = q1_mx*q1_mx/q3_mx + 0.5*g*q3_mx*q3_mx`` is routed through the
precision policy (they substituted exactly one of the 24 sub-equations) —
its three multiplications on the R2F2 multiplier and, since the
``repro.alu`` extension, its division on the tracked flexible divider;
everything else stays f32. With a realistic resting depth
(h0 = 500 m, the ``SWEConfig.depth`` default) the term ``h*h = 2.5e5``
overflows E5M10's 65504 ceiling, so standard half corrupts the simulation
while R2F2 widens the exponent at runtime (k -> FX) and matches the
full-precision run — the paper's Fig. 8.

The workload is a thin :class:`repro.pde.solver.Stepper` registered as
``"swe2d"``; ``simulate``/``swe_step`` remain as shims with unchanged
numerics over the shared :class:`~repro.pde.solver.Simulation` driver.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.precision import PrecisionConfig

from .registry import register_stepper
from .solver import Simulation, StepOps, Stepper

__all__ = ["SWEConfig", "SWE2DStepper", "initial_state", "swe_step", "simulate"]

G = 9.81


@dataclasses.dataclass(frozen=True)
class SWEConfig:
    nx: int = 128
    ny: int = 128
    length: float = 1.0e6  # 1000 km basin
    depth: float = 500.0  # resting depth (m) — h*h = 2.5e5 overflows E5M10
    bump: float = 100.0  # initial gaussian surface displacement (m)
    bump_sigma: float = 0.05  # as a fraction of the basin
    cfl: float = 0.4

    @property
    def dx(self) -> float:
        return self.length / self.nx

    @property
    def dy(self) -> float:
        return self.length / self.ny

    @property
    def dt(self) -> float:
        c = (G * (self.depth + self.bump)) ** 0.5
        return self.cfl * min(self.dx, self.dy) / (c * 2.0**0.5)


def initial_state(cfg: SWEConfig):
    x = jnp.linspace(0, 1, cfg.nx, dtype=jnp.float32)
    y = jnp.linspace(0, 1, cfg.ny, dtype=jnp.float32)
    xx, yy = jnp.meshgrid(x, y, indexing="ij")
    r2 = (xx - 0.5) ** 2 + (yy - 0.5) ** 2
    h = cfg.depth + cfg.bump * jnp.exp(-r2 / (2 * cfg.bump_sigma**2))
    hu = jnp.zeros_like(h)
    hv = jnp.zeros_like(h)
    return jnp.stack([h, hu, hv])


def _momentum_flux(q1, q3, ops: StepOps):
    """The paper's substituted equation: q1*q1/q3 + 0.5*g*q3*q3, with its
    multiplications on the policy's multiplier AND its division on the
    policy's flexible divider (``repro.alu`` — the tracked ``swe.div``
    site, split picked under the quotient-range envelope). Every other
    division in this solver stays on the f32 divider."""
    t1 = ops.mul(q1, q1, "swe.q1q1")
    t2 = ops.div(t1, q3, "swe.div")
    t3 = ops.mul(q3, q3, "swe.q3q3")
    t4 = ops.mul(jnp.float32(0.5 * G), t3, "swe.gq3")
    return t2 + t4


def _momentum_flux_x(q1, q3, prec: PrecisionConfig):
    """Untracked shim kept for the kernel parity tests."""
    return _momentum_flux(q1, q3, StepOps(prec))


def _flux_F(U, mom):
    """F(U) with the substituted momentum flux computed by ``mom(q1, q3)``."""
    h, hu, hv = U[0], U[1], U[2]
    return jnp.stack([hu, mom(hu, h), hu * hv / h])


def _flux_G(U, mom):
    h, hu, hv = U[0], U[1], U[2]
    # G's momentum-y flux is the same algebraic form in (hv, h)
    return jnp.stack([hv, hu * hv / h, mom(hv, h)])


def _reflect(U):
    """Reflective walls: zero normal momentum at boundaries, mirror h."""
    h, hu, hv = U[0], U[1], U[2]
    h = h.at[0, :].set(h[1, :]).at[-1, :].set(h[-2, :])
    h = h.at[:, 0].set(h[:, 1]).at[:, -1].set(h[:, -2])
    hu = hu.at[0, :].set(-hu[1, :]).at[-1, :].set(-hu[-2, :])
    hu = hu.at[:, 0].set(hu[:, 1]).at[:, -1].set(hu[:, -2])
    hv = hv.at[:, 0].set(-hv[:, 1]).at[:, -1].set(-hv[:, -2])
    hv = hv.at[0, :].set(hv[1, :]).at[-1, :].set(hv[-2, :])
    return jnp.stack([h, hu, hv])


_F32 = PrecisionConfig(mode="f32")


def _lw_step(U, cfg: SWEConfig, mom):
    """One Richtmyer two-step Lax-Wendroff update. ``mom(q1, q3)`` computes
    the paper's substituted x-midpoint momentum flux (the only policy-routed
    sub-equation); every other sub-equation stays f32."""
    dt, dx, dy = cfg.dt, cfg.dx, cfg.dy
    f32 = StepOps(_F32)

    def f32_mom(q1, q3):
        return _momentum_flux(q1, q3, f32)

    F = _flux_F(U, f32_mom)
    Gf = _flux_G(U, f32_mom)

    # half-step states at x- and y-midpoints (interior staggered grids)
    Ux = 0.5 * (U[:, 1:, :] + U[:, :-1, :]) - (dt / (2 * dx)) * (F[:, 1:, :] - F[:, :-1, :])
    Uy = 0.5 * (U[:, :, 1:] + U[:, :, :-1]) - (dt / (2 * dy)) * (Gf[:, :, 1:] - Gf[:, :, :-1])

    Fx = _flux_F(Ux, mom)  # fluxes at x-midpoints — the paper's Ux_mx eq
    Gy = _flux_G(Uy, f32_mom)

    interior = (
        U[:, 1:-1, 1:-1]
        - (dt / dx) * (Fx[:, 1:, 1:-1] - Fx[:, :-1, 1:-1])
        - (dt / dy) * (Gy[:, 1:-1, 1:] - Gy[:, 1:-1, :-1])
    )
    U = U.at[:, 1:-1, 1:-1].set(interior)
    return _reflect(U)


@register_stepper("swe2d")
class SWE2DStepper(Stepper):
    """One Richtmyer two-step Lax-Wendroff update.

    Faithful to the paper's experiment (§5.3): of the ~24 sub-equations, ONLY
    the x-midpoint momentum-flux equation ``Ux_mx = q1_mx^2/q3_mx +
    0.5*g*q3_mx^2`` is routed through the precision policy (inside
    ``_flux_F(Ux, ops)``) — three multiplier sites plus the ``swe.div``
    flexible-divider site; every other sub-equation stays in the baseline
    precision.
    """

    sites = ("swe.q1q1", "swe.q3q3", "swe.gq3", "swe.div")
    site_ops = ("mul", "mul", "mul", "div")
    failure_mode = "overflow"
    story = "h*h = 2.5e5 at a realistic basin depth overflows E5M10's 65504"
    snapshots_default = 4

    def default_config(self) -> SWEConfig:
        return SWEConfig()

    def init_state(self, cfg: SWEConfig):
        return initial_state(cfg)

    def step(self, U, cfg: SWEConfig, ops: StepOps):
        return _lw_step(U, cfg, lambda q1, q3: _momentum_flux(q1, q3, ops))

    def fused_step(
        self,
        U,
        cfg: SWEConfig,
        prec,
        steps: int,
        *,
        k_floor=None,
        collect_evidence: bool = False,
        capture=None,
        interpret=None,
    ):
        """Fused-plane chunk: the substituted momentum-flux equation runs in
        the Pallas :func:`repro.kernels.swe_flux.swe_flux_fused` kernel (its
        three policy multiplications + division + add in one VMEM pass);
        the rest of the Lax-Wendroff step is f32 XLA, and the substep loop
        is a scan around the kernel call — the fusion boundary is the
        paper's §5.3 substitution boundary."""
        from repro.kernels.swe_flux import swe_flux_fused  # lazy: pallas off cold paths

        def mom(q1, q3):
            res = swe_flux_fused(
                q1,
                q3,
                prec=prec,
                sites=self.sites,
                site_ops=self.site_ops,
                k_floor=k_floor,
                collect_evidence=collect_evidence,
                capture=capture,
                interpret=interpret,
            )
            if capture is not None:
                flux, mom.evidence, mom.counts = res
            else:
                flux, mom.evidence = res
            return flux

        def substep(U, _):
            U = _lw_step(U, cfg, mom)
            if capture is not None:
                return U, (mom.evidence, mom.counts)
            return U, mom.evidence  # (1, n_sites, 2) per substep, or None

        U, ys = jax.lax.scan(substep, U, None, length=steps)
        if capture is not None:
            ev_steps, counts = ys
            return U, ev_steps[:, 0], jnp.sum(counts, axis=0, dtype=jnp.int32)
        return U, None if ys is None else ys[:, 0]

    def mega_supported(self, cfg: SWEConfig, prec) -> bool:
        """Megakernel parity needs the chunked flux kernel's grid to be a
        single block: the momentum-flux midpoint arrays are ``(nx-1, ny)``,
        so both extents must fit one ``prec.kernel_blocks`` tile — otherwise
        the chunked plane picks per-tile splits the whole-field megakernel
        cannot reproduce."""
        return (cfg.nx - 1) <= prec.kernel_blocks[0] and cfg.ny <= prec.kernel_blocks[1]

    def mega_step(
        self,
        U,
        cfg: SWEConfig,
        prec,
        steps: int,
        every: int,
        *,
        tracker=None,
        collect_evidence: bool = False,
        capture=None,
        interpret=None,
        storage: str = "f32",
    ):
        """Whole-horizon run: the ENTIRE Lax-Wendroff update — the
        substituted momentum-flux equation on the policy datapath, every
        other sub-equation in f32 — plus snapshots and the adjust unit, in
        one ``pallas_call`` (:func:`repro.kernels.mega.swe2d_mega`)."""
        from repro.kernels.mega import swe2d_mega  # lazy: pallas off cold paths

        return swe2d_mega(
            U,
            cfg=cfg,
            prec=prec,
            steps=steps,
            every=every,
            sites=self.sites,
            site_ops=self.site_ops,
            tracker=tracker,
            collect_evidence=collect_evidence,
            capture=capture,
            interpret=interpret,
            storage=storage,
        )

    def observables(self, U, cfg: SWEConfig):
        return U[0]  # snapshot h only

    def metric_offset(self, cfg: SWEConfig) -> float:
        return cfg.depth  # rel-L2 judges the wave, not the resting basin


_STEPPER = SWE2DStepper()


def swe_step(U, cfg: SWEConfig, prec: PrecisionConfig):
    """One Lax-Wendroff update (untracked shim over the registered stepper)."""
    return _STEPPER.step(U, cfg, StepOps(prec))


def simulate(
    cfg: SWEConfig,
    prec: PrecisionConfig,
    steps: int,
    snapshot_every: Optional[int] = None,
    U0: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    res = Simulation("swe2d", cfg, prec).run(
        steps,
        snapshot_every=snapshot_every,
        state0=None if U0 is None else jnp.asarray(U0, jnp.float32),
    )
    return res.state, res.snapshots
