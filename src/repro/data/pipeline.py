"""Deterministic synthetic data pipeline.

``batch_for_step(step)`` is a *pure function* — the pipeline has no cursor
state, so checkpoint/restart resumes exactly, elastic re-sharding is trivial
(any host can regenerate any shard), and straggler recovery can recompute a
pod's batch without coordination (DESIGN.md §6).

Token streams are Zipf-ish synthetic language (markov-perturbed) rather than
uniform noise so losses move and rr-precision range trackers see realistic
activation clustering. Frontend archs get frame/patch embeddings per the
STUB contract; hubert gets span masks + cluster labels; pixtral gets a loss
mask covering text positions only.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["batch_for_step", "batch_spec"]

IMG_SEQ = 1024  # pixtral patch-token prefix length (kept modest vs text)


def _token_stream(key, batch, seq, vocab):
    """Zipf-distributed ids with local repetition structure."""
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.uniform(k1, (batch, seq), minval=1e-6, maxval=1.0)
    zipf = jnp.minimum((u ** -0.9 - 1.0), float(vocab - 1)).astype(jnp.int32)
    # splice short repeats to create learnable bigram structure
    shift = jnp.roll(zipf, 3, axis=1)
    take = jax.random.bernoulli(k2, 0.3, (batch, seq))
    toks = jnp.where(take, shift, zipf)
    return jnp.clip(toks, 0, vocab - 1)


def batch_for_step(
    cfg: ModelConfig,
    step: int,
    batch: int,
    seq: int,
    seed: int = 17,
) -> Dict[str, jnp.ndarray]:
    """Global batch for ``step`` (shard by slicing the batch dim)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    kt, kf, km = jax.random.split(key, 3)

    if cfg.frontend == "audio":  # hubert: frames in, masked cluster prediction
        embeds = jax.random.normal(kf, (batch, seq, cfg.frontend_dim), jnp.float32)
        labels = jax.random.randint(kt, (batch, seq), 0, cfg.vocab)
        mask = jax.random.bernoulli(km, 0.08, (batch, seq)).astype(jnp.float32)
        return {"embeds": embeds, "labels": labels, "mask": mask}

    if cfg.frontend == "vision":  # pixtral: patch prefix + text tokens
        img = min(IMG_SEQ, seq // 2)
        text = seq - img
        embeds = jax.random.normal(kf, (batch, img, cfg.frontend_dim), jnp.float32)
        toks = _token_stream(kt, batch, text, cfg.vocab)
        labels = jnp.roll(toks, -1, axis=1)
        mask = jnp.ones((batch, text), jnp.float32).at[:, -1].set(0.0)
        return {"embeds": embeds, "tokens": toks, "labels": labels, "mask": mask}

    toks = _token_stream(kt, batch, seq, cfg.vocab)
    labels = jnp.roll(toks, -1, axis=1)
    mask = jnp.ones((batch, seq), jnp.float32).at[:, -1].set(0.0)
    return {"tokens": toks, "labels": labels, "mask": mask}


def batch_spec(cfg: ModelConfig, batch: int, seq: int):
    """ShapeDtypeStructs matching batch_for_step (for .lower())."""
    f32 = jnp.float32
    i32 = jnp.int32
    if cfg.frontend == "audio":
        return {
            "embeds": jax.ShapeDtypeStruct((batch, seq, cfg.frontend_dim), f32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
            "mask": jax.ShapeDtypeStruct((batch, seq), f32),
        }
    if cfg.frontend == "vision":
        img = min(IMG_SEQ, seq // 2)
        text = seq - img
        return {
            "embeds": jax.ShapeDtypeStruct((batch, img, cfg.frontend_dim), f32),
            "tokens": jax.ShapeDtypeStruct((batch, text), i32),
            "labels": jax.ShapeDtypeStruct((batch, text), i32),
            "mask": jax.ShapeDtypeStruct((batch, text), f32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
        "mask": jax.ShapeDtypeStruct((batch, seq), f32),
    }
