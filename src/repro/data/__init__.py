"""Deterministic synthetic data pipelines (pure function of step)."""

from .pipeline import batch_for_step, batch_spec
