"""Model assembly: embedding -> scanned block groups -> norm -> head.

The cyclic ``cfg.pattern`` (config.py) defines one *group*; parameters are
stacked over ``cfg.groups`` and applied with ``jax.lax.scan`` so the HLO is
depth-independent. Decode threads a per-group cache pytree (KV caches for
attention positions, recurrent states for mamba/xLSTM positions) through the
same scan.

Three entry points:
  forward()     — full-sequence (training / encoder / prefill)
  prefill()     — forward + per-layer cache collection
  decode_step() — one token with cache (the serve_step body)
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.precision import PrecisionConfig, dot
from repro.models import attention, moe, ssm, xlstm
from repro.models.config import ModelConfig, parse_entry
from repro.models.layers import embed_init, mlp_apply, mlp_init, rmsnorm, rmsnorm_init

__all__ = ["model_init", "forward", "prefill", "decode_step", "init_decode_state", "lm_loss"]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _block_init(key, entry: str, cfg: ModelConfig):
    mixer, ffn = parse_entry(entry)
    ks = jax.random.split(key, 3)
    p: dict = {"ln1": rmsnorm_init(cfg.d_model)}
    if mixer == "attn":
        p["attn"] = attention.attn_init(ks[0], cfg)
    elif mixer == "mamba":
        p["mamba"] = ssm.mamba_init(ks[0], cfg)
    elif mixer == "mlstm":
        p["mlstm"] = xlstm.mlstm_init(ks[0], cfg)
    elif mixer == "slstm":
        p["slstm"] = xlstm.slstm_init(ks[0], cfg)
    if ffn == "mlp":
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act)
    elif ffn == "moe":
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["moe"] = moe.moe_init(ks[1], cfg)
    return p


def model_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, len(cfg.pattern) + 3)
    blocks = []
    for i, entry in enumerate(cfg.pattern):
        gkeys = jax.random.split(ks[i], cfg.groups)
        blocks.append(jax.vmap(lambda k: _block_init(k, entry, cfg))(gkeys))
    params = {
        "embed": embed_init(ks[-3], cfg.vocab, cfg.d_model),
        "blocks": tuple(blocks),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = (
            jax.random.normal(ks[-2], (cfg.d_model, cfg.vocab), jnp.float32)
            * cfg.d_model**-0.5
        )
    if cfg.frontend is not None:
        params["frontend_proj"] = (
            jax.random.normal(ks[-1], (cfg.frontend_dim, cfg.d_model), jnp.float32)
            * cfg.frontend_dim**-0.5
        )
    return params


# --------------------------------------------------------------------------
# block application
# --------------------------------------------------------------------------


def _mixer_apply(entry, bp, x, cfg, prec, window, cache=None, pos=None):
    """Returns (residual_out, new_cache_or_state)."""
    mixer, _ = parse_entry(entry)
    h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
    if mixer == "attn":
        if cache is not None and pos is not None:
            return attention.attn_decode(bp["attn"], h, cache, pos, cfg, prec, window=window)
        return attention.attn_apply(bp["attn"], h, cfg, prec, window=window)
    if mixer == "mamba":
        return ssm.mamba_apply(bp["mamba"], h, cfg, prec, state=cache)
    if mixer == "mlstm":
        return xlstm.mlstm_apply(bp["mlstm"], h, cfg, prec, state=cache)
    if mixer == "slstm":
        return xlstm.slstm_apply(bp["slstm"], h, cfg, prec, state=cache)
    raise ValueError(mixer)


def _ffn_apply(entry, bp, x, cfg, prec):
    """Returns (residual_out, aux_loss)."""
    _, ffn = parse_entry(entry)
    if ffn is None:
        return None, 0.0
    h = rmsnorm(x, bp["ln2"], cfg.norm_eps)
    if ffn == "mlp":
        return mlp_apply(bp["mlp"], h, cfg.act, prec), 0.0
    out, aux = moe.moe_apply(bp["moe"], h, cfg, prec)
    return out, aux


def _group_apply(x, group_params, cfg, prec, window, caches=None, pos=None):
    """Apply one pattern period. caches: tuple per position (or None)."""
    aux_total = jnp.float32(0.0)
    new_caches = []
    for i, entry in enumerate(cfg.pattern):
        bp = jax.tree_util.tree_map(lambda a: a, group_params[i])
        cache_i = None if caches is None else caches[i]
        out, new_cache = _mixer_apply(entry, bp, x, cfg, prec, window, cache_i, pos)
        x = x + out
        x = constrain(x, "batch", "seq", "embed")
        ffn_out, aux = _ffn_apply(entry, bp, x, cfg, prec)
        if ffn_out is not None:
            x = x + ffn_out
            x = constrain(x, "batch", "seq", "embed")
        aux_total = aux_total + aux
        new_caches.append(new_cache)
    return x, aux_total, tuple(new_caches)


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, tokens=None, embeds=None, prec=None):
    """tokens: (B, S) int32 and/or embeds: (B, S_f, frontend_dim)."""
    parts = []
    if embeds is not None:
        parts.append(
            dot(embeds.astype(jnp.float32), params["frontend_proj"], prec, site="lm.frontend")
        )
    if tokens is not None:
        parts.append(params["embed"][tokens])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return constrain(x, "batch", "seq", "embed")


def forward(
    params,
    cfg: ModelConfig,
    prec: PrecisionConfig,
    tokens=None,
    embeds=None,
    window: Optional[int] = None,
    remat: bool = True,
    carry_dtype=None,
):
    """Full-sequence forward. Returns (logits, aux_loss).

    ``carry_dtype=jnp.bfloat16`` stores the scanned group-boundary
    activations (the only tensors remat must keep, one (B,S,d) per group) in
    bf16 — halves the dominant training-memory term for deep models (§Perf:
    llama3-405b keeps 126 boundaries).
    """
    x = _embed_inputs(params, cfg, tokens, embeds, prec)

    def group_fn(x, gp):
        x, aux, _ = _group_apply(x.astype(jnp.float32), gp, cfg, prec, window)
        if carry_dtype is not None:
            x = x.astype(carry_dtype)
        return x, aux

    if remat:
        group_fn = jax.checkpoint(
            group_fn, policy=jax.checkpoint_policies.nothing_saveable
        )
    if carry_dtype is not None:
        x = x.astype(carry_dtype)
    x, auxs = jax.lax.scan(group_fn, x, params["blocks"])
    x = x.astype(jnp.float32)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = dot(x, head, prec, site="lm.head")
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, jnp.sum(auxs)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, cache_dtype=jnp.bfloat16):
    """Cache pytree: tuple over pattern positions, leading groups dim."""

    def one_group(_):
        caches = []
        for entry in cfg.pattern:
            mixer, _ = parse_entry(entry)
            if mixer == "attn":
                caches.append(attention.init_cache(cfg, batch, max_len, dtype=cache_dtype))
            elif mixer == "mamba":
                caches.append(ssm.init_mamba_state(cfg, batch))
            elif mixer == "mlstm":
                caches.append(xlstm.init_mlstm_state(cfg, batch))
            elif mixer == "slstm":
                caches.append(xlstm.init_slstm_state(cfg, batch))
        return tuple(caches)

    return jax.vmap(one_group)(jnp.arange(cfg.groups))


def decode_step(
    params,
    caches,
    tokens,
    pos,
    cfg: ModelConfig,
    prec: PrecisionConfig,
    window: Optional[int] = None,
):
    """One decode step. tokens: (B, 1) int32; pos: scalar int32.
    Returns (logits (B, 1, vocab), new_caches)."""
    x = params["embed"][tokens]
    x = constrain(x, "batch", None, "embed")

    def group_fn(x, gp_and_cache):
        gp, cache = gp_and_cache
        x, _, new_cache = _group_apply(x, gp, cfg, prec, window, caches=cache, pos=pos)
        return x, new_cache

    x, new_caches = jax.lax.scan(group_fn, x, (params["blocks"], caches))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = dot(x, head, prec, site="lm.head")
    return constrain(logits, "batch", None, "vocab"), new_caches


def prefill(params, cfg, prec, tokens=None, embeds=None, max_len=None, window=None, cache_dtype=jnp.bfloat16):
    """Forward pass that also fills a decode cache (attention positions only
    get true caches; recurrent positions get their boundary states)."""
    B = (tokens if tokens is not None else embeds).shape[0]
    x = _embed_inputs(params, cfg, tokens, embeds, prec)
    S = x.shape[1]
    max_len = max_len or S

    def group_fn(x, gp):
        x, aux, caches = _group_apply(x, gp, cfg, prec, window)
        # pad attention KV caches out to max_len for the decode phase
        padded = []
        for entry, c in zip(cfg.pattern, caches):
            mixer, _ = parse_entry(entry)
            if mixer == "attn":
                pad = max_len - S
                k = jnp.pad(c.k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache_dtype)
                v = jnp.pad(c.v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache_dtype)
                padded.append(attention.KVCache(k=k, v=v))
            else:
                padded.append(c)
        return x, (aux, tuple(padded))

    x, (auxs, caches) = jax.lax.scan(group_fn, x, params["blocks"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = dot(x, head, prec, site="lm.head")
    return constrain(logits, "batch", "seq", "vocab"), caches


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------


def lm_loss(
    params,
    batch,
    cfg: ModelConfig,
    prec: PrecisionConfig,
    window: Optional[int] = None,
    remat: bool = True,
    carry_dtype=None,
):
    """Causal-LM (or masked-prediction for encoder-only) mean cross-entropy.

    batch: {"tokens": (B,S) int32} and/or {"embeds": (B,S,f)}, plus
    {"labels": (B,S) int32, "mask": optional (B,S) f32}.
    """
    logits, aux = forward(
        params,
        cfg,
        prec,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        window=window,
        remat=remat,
        carry_dtype=carry_dtype,
    )
    labels = batch["labels"]
    # frontends prepend embeddings: align logits tail with text labels
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1] :]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(ll)
    loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + 0.01 * aux
