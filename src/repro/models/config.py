"""Model configuration — one dataclass describes every architecture in the
pool (dense / MoE / SSM / xLSTM / hybrid / encoder-only / VLM-stub).

A model is a cyclic ``pattern`` of block descriptors, repeated
``n_layers / len(pattern)`` times; the repeat ("group") axis is scanned with
``jax.lax.scan`` so compile time and HLO size are depth-independent (a
126-layer llama3-405b compiles one group body). Pattern entries:

    "attn+mlp"   — GQA attention + dense FFN
    "attn+moe"   — GQA attention + MoE FFN
    "mamba+mlp"  — Mamba (S6) mixer + dense FFN
    "mamba+moe"  — Mamba + MoE FFN
    "mlstm"      — xLSTM mLSTM block (self-contained up/down projection)
    "slstm"      — xLSTM sLSTM block (self-contained)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "MIXERS", "parse_entry"]

MIXERS = ("attn", "mamba", "mlstm", "slstm")


def parse_entry(entry: str) -> Tuple[str, Optional[str]]:
    """'attn+moe' -> ('attn', 'moe'); 'mlstm' -> ('mlstm', None)."""
    parts = entry.split("+")
    mixer = parts[0]
    if mixer not in MIXERS:
        raise ValueError(f"unknown mixer {mixer!r} in pattern entry {entry!r}")
    ffn = parts[1] if len(parts) > 1 else None
    if ffn not in (None, "mlp", "moe"):
        raise ValueError(f"unknown ffn {ffn!r} in pattern entry {entry!r}")
    return mixer, ffn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: Tuple[str, ...] = ("attn+mlp",)
    head_dim: Optional[int] = None  # None -> d_model // n_heads

    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 1
    moe_d_ff: Optional[int] = None  # per-expert hidden; None -> d_ff
    moe_shared_expert: bool = False  # llama4-style always-on shared expert
    capacity_factor: float = 1.25

    # --- attention ---
    causal: bool = True
    encoder_only: bool = False
    rope_theta: float = 500000.0
    window: Optional[int] = None  # sliding-window size (long-context mode)

    # --- modality frontend (STUB: input_specs provides embeddings) ---
    frontend: Optional[str] = None  # None | "audio" | "vision"
    frontend_dim: int = 0

    # --- SSM (mamba) ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: Optional[int] = None  # None -> ceil(d_model / 16)

    # --- xLSTM ---
    lstm_expand: int = 2

    # --- misc ---
    act: str = "swiglu"  # "swiglu" | "gelu"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    def __post_init__(self):
        if self.n_layers % len(self.pattern):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not a multiple of "
                f"pattern period {len(self.pattern)}"
            )
        for e in self.pattern:
            parse_entry(e)
        if self.n_heads % self.n_kv_heads:
            raise ValueError(f"{self.name}: heads {self.n_heads} % kv {self.n_kv_heads}")

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank if self.dt_rank is not None else -(-self.d_model // 16)

    @property
    def lstm_inner(self) -> int:
        return self.lstm_expand * self.d_model

    @property
    def moe_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    @property
    def has_attn(self) -> bool:
        return any(parse_entry(e)[0] == "attn" for e in self.pattern)

    @property
    def is_recurrent_only(self) -> bool:
        return not self.has_attn

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline math)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embed
        if not self.tie_embeddings:
            total += d * v  # head
        total += d  # final norm
        for e in self.pattern:
            mixer, ffn = parse_entry(e)
            n = 0
            if mixer == "attn":
                n += d * self.n_heads * self.hd + d * self.n_kv_heads * self.hd * 2
                n += self.n_heads * self.hd * d
                n += d  # ln
            elif mixer == "mamba":
                di, r, s = self.d_inner, self.dt_rank_, self.ssm_state
                n += d * 2 * di + di * self.ssm_conv + di * (r + 2 * s) + r * di
                n += di * s + di  # A_log, D
                n += di * d + d  # out proj + ln
            elif mixer == "mlstm":
                li = self.lstm_inner
                n += d * 2 * li  # up (x and gate)
                n += 3 * li * 4 + li * 2 * self.n_heads  # block-diag qkv + gates
                n += li * d + d  # down + ln
            elif mixer == "slstm":
                li = self.lstm_inner
                n += 4 * d * li + 4 * li * (li // self.n_heads)  # in + block-diag rec
                n += li * d + d
            if ffn == "mlp":
                mult = 3 if self.act == "swiglu" else 2
                n += mult * d * self.d_ff + d
            elif ffn == "moe":
                mult = 3 if self.act == "swiglu" else 2
                n += self.moe_experts * mult * d * self.moe_ff + d * self.moe_experts + d
                if self.moe_shared_expert:
                    n += mult * d * self.moe_ff
            total += n * self.groups
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts) for 6*N_active*D."""
        if self.moe_experts == 0:
            return self.param_count()
        d = self.d_model
        mult = 3 if self.act == "swiglu" else 2
        per_expert = mult * d * self.moe_ff
        n_moe_layers = sum(
            1 for e in self.pattern if parse_entry(e)[1] == "moe"
        ) * self.groups
        inactive = per_expert * (self.moe_experts - self.moe_top_k) * n_moe_layers
        if self.moe_shared_expert:
            pass  # shared expert always active
        return self.param_count() - inactive
