"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix-memory, parallelizable)
and sLSTM (scalar-memory, sequential) — the xlstm-1.3b architecture is a
7:1 interleave of the two.

mLSTM here is its chunkwise linear-attention form: per head, state
C in R^{dk x dv} evolves as  C_t = f_t C_{t-1} + i_t k_t v_t^T,
y_t = C_t^T q_t / max(|n_t^T q_t|, 1). We use sigmoid input/forget gates in
log-space (always-stable) rather than the paper's exponential-gate
max-stabilizer; shapes/FLOPs/memory are identical and this numeric substrate
is orthogonal to the R2F2 contribution (noted in DESIGN.md §8). Chunked:
intra-chunk attention-like compute + boundary state carried by lax.scan.

Both cells decode with O(1) state — xlstm-1.3b runs the long_500k cell.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.precision import PrecisionConfig, contract, dot
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init, silu

__all__ = [
    "mlstm_init",
    "mlstm_apply",
    "mlstm_decode",
    "MLSTMState",
    "init_mlstm_state",
    "slstm_init",
    "slstm_apply",
    "slstm_decode",
    "SLSTMState",
    "init_slstm_state",
]

LSTM_CHUNK = 256


class MLSTMState(NamedTuple):
    C: jnp.ndarray  # (B, H, dk, dv)
    n: jnp.ndarray  # (B, H, dk)


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # (B, li)
    h: jnp.ndarray  # (B, li)


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


QKV_BLOCK = 4  # xLSTM qkv_proj_blocksize: block-diagonal q/k/v projections


def mlstm_init(key, cfg: ModelConfig):
    d, li = cfg.d_model, cfg.lstm_inner
    ks = jax.random.split(key, 7)
    nb = li // QKV_BLOCK
    blk = lambda k: jax.random.normal(k, (nb, QKV_BLOCK, QKV_BLOCK), jnp.float32) * (
        QKV_BLOCK**-0.5
    )
    return {
        "up_x": dense_init(ks[0], d, li),
        "up_z": dense_init(ks[1], d, li),
        "wq": blk(ks[2]),
        "wk": blk(ks[3]),
        "wv": blk(ks[4]),
        "w_if": dense_init(ks[5], li, 2 * cfg.n_heads),  # input & forget gates/head
        "norm": rmsnorm_init(li),
        "down": dense_init(ks[6], li, d),
    }


def _blockdiag_proj(x, w, prec):
    """x: (B, S, li) -> (B, S, li) through block-diagonal (nb, bs, bs) w."""
    B, S, li = x.shape
    nb, bs, _ = w.shape
    xb = x.reshape(B, S, nb, bs)
    out = contract("bsng,ngh->bsnh", xb, w, prec, site="xlstm.qkv")
    return out.reshape(B, S, li)


def _mlstm_chunked(q, k, v, log_i, log_f, state: MLSTMState, chunk=None):
    """q,k,v: (B, S, H, dh); log_i/log_f: (B, S, H) (log-sigmoid gates).
    Chunkwise gated linear attention. Returns (y, new_state)."""
    B, S, H, dh = q.shape
    chunk = min(chunk or LSTM_CHUNK, S)
    assert S % chunk == 0
    nc = S // chunk

    def reshape_c(x):
        return jnp.moveaxis(x.reshape(B, nc, chunk, *x.shape[2:]), 1, 0)

    qc, kc, vc = reshape_c(q), reshape_c(k), reshape_c(v)
    lic, lfc = reshape_c(log_i), reshape_c(log_f)

    def chunk_body(carry, inp):
        C, n = carry  # (B,H,dk,dv), (B,H,dk)
        qb, kb, vb, li_b, lf_b = inp  # (B,c,H,*) each

        F = jnp.cumsum(lf_b, axis=1)  # (B,c,H) log decay from chunk start (<=0)
        Ftot = F[:, -1]  # (B,H)

        # inter-chunk: contribution of the carried state, decayed (exp(F)<=1)
        q_dec = qb * jnp.exp(F)[..., None]  # (B,c,H,dk)
        y_inter = jnp.einsum("bchk,bhkv->bchv", q_dec, C)
        n_inter = jnp.einsum("bchk,bhk->bch", q_dec, n)

        # intra-chunk: pairwise-stable decay D[t,s] = exp(F_t - F_s + li_s),
        # masked to s<=t so every exponent is <= 0 (never overflows).
        Ft = jnp.moveaxis(F, 1, 2)  # (B,H,c)
        lit = jnp.moveaxis(li_b, 1, 2)  # (B,H,c)
        rel = Ft[..., :, None] - Ft[..., None, :] + lit[..., None, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(mask[None, None], jnp.exp(jnp.minimum(rel, 0.0)), 0.0)
        qk = jnp.einsum("bchk,bshk->bhcs", qb, kb)
        logits = qk * D
        y_intra = jnp.einsum("bhcs,bshv->bchv", logits, vb)
        n_intra = jnp.moveaxis(jnp.sum(logits, axis=-1), 1, 2)  # (B,c,H)

        y = y_inter + y_intra
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), 1.0)[..., None]
        y = y / denom

        # state update to chunk end: weights exp(Ftot - F_s + li_s) <= 1
        w_end = jnp.exp(jnp.minimum(Ftot[:, None] - F + li_b, 0.0))[..., None]
        kv = jnp.einsum("bshk,bshv->bhkv", kb * w_end, vb)
        C_new = C * jnp.exp(Ftot)[..., None, None] + kv
        n_new = n * jnp.exp(Ftot)[..., None] + jnp.sum(kb * w_end, axis=1)
        return (C_new, n_new), y

    (C, n), ys = jax.lax.scan(chunk_body, (state.C, state.n), (qc, kc, vc, lic, lfc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, dh)
    return y, MLSTMState(C=C, n=n)


def mlstm_apply(p, x, cfg: ModelConfig, prec: PrecisionConfig, state=None):
    B, S, d = x.shape
    H, li = cfg.n_heads, cfg.lstm_inner
    dh = li // H
    xi = silu(dot(x, p["up_x"], prec, site="xlstm.up_x"))
    z = dot(x, p["up_z"], prec, site="xlstm.up_z")

    q = _blockdiag_proj(xi, p["wq"], prec).reshape(B, S, H, dh)
    k = _blockdiag_proj(xi, p["wk"], prec).reshape(B, S, H, dh) * (dh**-0.5)
    v = _blockdiag_proj(xi, p["wv"], prec).reshape(B, S, H, dh)
    gates = dot(xi, p["w_if"], prec, site="xlstm.gates").reshape(B, S, H, 2)
    log_i = jax.nn.log_sigmoid(gates[..., 0])
    log_f = jax.nn.log_sigmoid(gates[..., 1])

    if state is None:
        state = init_mlstm_state(cfg, B)
    y, new_state = _mlstm_chunked(q, k, v, log_i, log_f, state)
    y = y.reshape(B, S, li)
    y = rmsnorm(y, p["norm"], cfg.norm_eps) * silu(z)
    return dot(y, p["down"], prec, site="xlstm.down"), new_state


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    H = cfg.n_heads
    dh = cfg.lstm_inner // H
    return MLSTMState(
        C=jnp.zeros((batch, H, dh, dh), jnp.float32),
        n=jnp.zeros((batch, H, dh), jnp.float32),
    )


def mlstm_decode(p, x, state: MLSTMState, cfg: ModelConfig, prec: PrecisionConfig):
    return mlstm_apply(p, x, cfg, prec, state=state)


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def slstm_init(key, cfg: ModelConfig):
    d, li, H = cfg.d_model, cfg.lstm_inner, cfg.n_heads
    dh = li // H
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], d, 4 * li),  # i, f, z, o pre-activations
        "r_blk": jax.random.normal(ks[1], (4, H, dh, dh), jnp.float32) * (dh**-0.5),
        "norm": rmsnorm_init(li),
        "down": dense_init(ks[2], li, d),
    }


def _slstm_step(p, carry, wx, cfg: ModelConfig):
    c, h = carry  # (B, li) each
    B = c.shape[0]
    H = cfg.n_heads
    dh = cfg.lstm_inner // H
    hb = h.reshape(B, H, dh)
    rec = jnp.einsum("ghkv,bhk->gbhv", p["r_blk"], hb).reshape(4, B, H * dh)
    pre = wx.reshape(B, 4, -1).transpose(1, 0, 2) + rec  # (4, B, li)
    i = jax.nn.sigmoid(pre[0])
    f = jax.nn.sigmoid(pre[1])
    z = jnp.tanh(pre[2])
    o = jax.nn.sigmoid(pre[3])
    c_new = f * c + i * z
    h_new = o * jnp.tanh(c_new)
    return (c_new, h_new), h_new


def slstm_apply(p, x, cfg: ModelConfig, prec: PrecisionConfig, state=None):
    B, S, d = x.shape
    li = cfg.lstm_inner
    wx = dot(x, p["w_in"], prec, site="slstm.w_in")  # (B, S, 4*li) gate pre-activations
    if state is None:
        state = init_slstm_state(cfg, B)

    def step(carry, wxt):
        return _slstm_step(p, carry, wxt, cfg)

    (c, h), hs = jax.lax.scan(step, (state.c, state.h), jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1)  # (B, S, li)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    return dot(y, p["down"], prec, site="slstm.down"), SLSTMState(c=c, h=h)


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    li = cfg.lstm_inner
    return SLSTMState(c=jnp.zeros((batch, li), jnp.float32), h=jnp.zeros((batch, li), jnp.float32))


def slstm_decode(p, x, state: SLSTMState, cfg: ModelConfig, prec: PrecisionConfig):
    return slstm_apply(p, x, cfg, prec, state=state)
