"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch
(GShard/Switch formulation — pure einsum, shards cleanly with experts on the
'model' mesh axis).

Dispatch: each token picks its top-k experts; tokens beyond an expert's
capacity C = (tokens/E) * capacity_factor * k are dropped (standard dropless
alternatives trade ragged layouts for this; capacity dispatch is the
TPU-friendly dense form). Compute per expert is a (E, C, d) x (E, d, f)
batched matmul -> FLOPs scale with top_k, not E.

rr-precision note (DESIGN.md §5): expert weight matrices get *per-expert*
range statistics by construction — the (E, C, d) operand layout gives each
expert its own quantization tiles, which is exactly the paper's "local
clusters" exploited per expert.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.precision import PrecisionConfig, contract, operand_dtype
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, silu

__all__ = ["moe_init", "moe_apply"]

# "einsum" (SPMD-friendly one-hot dispatch) | "scatter" (index dispatch);
# overridable for A/B measurement via REPRO_MOE_DISPATCH.
import os as _os

DISPATCH_MODE = _os.environ.get("REPRO_MOE_DISPATCH", "scatter")


def moe_init(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.moe_ff, cfg.moe_experts
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": dense_init(ks[0], d, e),
        "gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale,
        "up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale,
        "down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * (f ** -0.5),
    }
    if cfg.moe_shared_expert:
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": dense_init(kk[0], d, f),
            "up": dense_init(kk[1], d, f),
            "down": dense_init(kk[2], f, d),
        }
    return p


def moe_apply(p, x, cfg: ModelConfig, prec: PrecisionConfig):
    """x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    n = B * S
    xt = x.reshape(n, d)

    logits = contract("nd,de->ne", xt, p["router"], prec, site="moe.router")
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (n, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int((n // e) * cfg.capacity_factor * k))

    # position of each token within its chosen expert's queue (per k-slot)
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)  # (n, k, e)
    flat = onehot.reshape(n * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=0) * flat - 1  # (n*k, e)
    pos = pos_in_expert.max(axis=-1).reshape(n, k)  # (n, k)
    keep = (pos < capacity) & (pos >= 0)

    if DISPATCH_MODE == "einsum":
        # one-hot einsum dispatch (GShard form). A/B-measured on qwen3
        # train_4k (EXPERIMENTS.md §Perf iteration 3): collective bytes are
        # ~unchanged vs scatter while the (n,e,c,d) dispatch contraction adds
        # token-quadratic MXU flops — kept only for comparison/small-n use.
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity, dtype=xt.dtype)
        disp = jnp.einsum("nke,nkc->nec", onehot.astype(xt.dtype), pos_oh)
        comb = jnp.einsum(
            "nk,nke,nkc->nec", gate_vals.astype(xt.dtype), onehot.astype(xt.dtype), pos_oh
        )
        xe = contract("nec,nd->ecd", disp, xt, prec, site="moe.dispatch")
        xe = constrain(xe, "experts", None, "embed")
        h = silu(contract("ecd,edf->ecf", xe, p["gate"], prec, site="moe.gate")) * contract(
            "ecd,edf->ecf", xe, p["up"], prec, site="moe.up"
        )
        h = constrain(h, "experts", None, None)
        ye = contract("ecf,efd->ecd", h, p["down"], prec, site="moe.down")
        out = contract("nec,ecd->nd", comb, ye, prec, site="moe.combine").reshape(B, S, d)
    else:
        # scatter dispatch: O(n*k*d) flops; the SPMD-lowered scatter/gather
        # all-reduces are ~the all-to-all dispatch lower bound (every token
        # may route anywhere). Payloads move in the policy's operand width
        # (bf16 under deploy/bf16 — halves ICI/DCI bytes; f32 for exact runs).
        payload = operand_dtype(prec)
        flat_e = expert_idx.reshape(-1)
        flat_pos = jnp.where(keep, pos, capacity).reshape(-1)  # slot `capacity` = drop
        xb = xt.astype(payload)
        x_rep = jnp.broadcast_to(xb[:, None, :], (n, k, d)).reshape(n * k, d)
        xe = (
            jnp.zeros((e, capacity + 1, d), payload)
            .at[flat_e, flat_pos]
            .add(x_rep)[:, :capacity]
        ).astype(jnp.float32)
        xe = constrain(xe, "experts", None, "embed")
        h = silu(contract("ecd,edf->ecf", xe, p["gate"], prec, site="moe.gate")) * contract(
            "ecd,edf->ecf", xe, p["up"], prec, site="moe.up"
        )
        h = constrain(h, "experts", None, None)
        ye = contract("ecf,efd->ecd", h, p["down"], prec, site="moe.down")
        yb = ye.astype(payload)
        yk = yb[flat_e, jnp.minimum(flat_pos, capacity - 1)]  # (n*k, d) payload moves
        yk = jnp.where(keep.reshape(-1, 1), yk, payload(0)).reshape(n, k, d)
        out = (
            jnp.sum(yk.astype(jnp.float32) * gate_vals[..., None], axis=1)
            .reshape(B, S, d)
        )

    if cfg.moe_shared_expert:
        sp = p["shared"]
        hs = silu(contract("nd,df->nf", xt, sp["gate"], prec, site="moe.shared.gate")) * contract(
            "nd,df->nf", xt, sp["up"], prec, site="moe.shared.up"
        )
        out = out + contract("nf,fd->nd", hs, sp["down"], prec, site="moe.shared.down").reshape(B, S, d)

    # load-balancing aux loss (Switch): e * sum_e(fraction_tokens * mean_prob)
    frac = jnp.mean(onehot[:, 0, :].astype(jnp.float32), axis=0)  # top-1 assignment share
    mean_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * mean_prob)
    return out, aux
