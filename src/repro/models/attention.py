"""GQA attention: training/prefill (full-sequence) and decode (KV cache).

Grouped computation never materializes repeated KV heads: q is viewed as
(B, S, KV, G, hd) and contracted against (B, T, KV, hd) directly.

Decode KV caches are sharded over the *sequence* axis of the cache
("kv_seq" -> model axis): with GQA the kv-head count (4-16) is usually
smaller than the TP degree, so head-sharding the cache wastes chips, while
sequence-sharding scales to any mesh and XLA's SPMD partitioner inserts the
flash-decoding-style max/sum all-reduces for the softmax over the sharded
axis (DESIGN.md §6).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.precision import PrecisionConfig, contract, dot
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rope

__all__ = ["attn_init", "attn_apply", "attn_decode", "KVCache", "init_cache"]

_NEG = -1e30


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, S_max, KV, hd)
    v: jnp.ndarray  # (B, S_max, KV, hd)


def attn_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d),
    }


def _qkv(p, x, cfg: ModelConfig, positions, prec: PrecisionConfig):
    """Returns q: (B,S,H,hd) flat heads; k, v: (B,S,KV,hd)."""
    B, S, _ = x.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    q = dot(x, p["wq"], prec, site="attn.q").reshape(B, S, cfg.n_heads, hd)
    k = dot(x, p["wk"], prec, site="attn.k").reshape(B, S, kv, hd)
    v = dot(x, p["wv"], prec, site="attn.v").reshape(B, S, kv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


FLASH_THRESHOLD = 4096  # S*T logits above this use the chunked path
FLASH_CHUNK = 1024


def _expand_kv(k, G):
    """Repeat KV heads to the full head count. Under SPMD with heads sharded
    on 'model', only the local head group materializes — the repeat is the
    sharding-friendly flat-head GQA form (§Perf iteration 1: the grouped
    (B,KV,G,S,T) layout made XLA involuntarily replicate S*T tensors)."""
    return jnp.repeat(k, G, axis=2)


def _dense_attention(q, k, v, causal, window, prec):
    """q: (B,S,H,hd); k,v: (B,T,H,hd) (already expanded). -> (B,S,H,hd)"""
    B, S = q.shape[:2]
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    v = constrain(v, "batch", None, "heads", None)
    logits = contract("bshd,bthd->bhst", q, k, prec, site="attn.qk")  # (B,H,S,T)
    logits = constrain(logits, "batch", "heads", None, None)
    ti = jnp.arange(S)[None, :]
    si = jnp.arange(S)[:, None]
    mask = jnp.ones((S, S), bool) if not causal else (ti <= si)
    if window is not None:
        mask = mask & (ti > si - window)
    logits = jnp.where(mask[None, None], logits, _NEG)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = contract("bhst,bthd->bshd", probs, v, prec, site="attn.pv")
    return constrain(out, "batch", None, "heads", None)


def _chunked_attention(q, k, v, causal, window, prec, cq=FLASH_CHUNK, ck=FLASH_CHUNK):
    """Flash-style online-softmax attention in pure jnp: outer scan over Q
    chunks, inner scan over KV chunks with (running max, sum, acc) carry.
    Peak live logits = (B, H, cq, ck) instead of (B, H, S, T)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    cq = min(cq, S)
    ck = min(ck, T)
    assert S % cq == 0 and T % ck == 0, (S, T, cq, ck)
    nq, nk = S // cq, T // ck

    qc = jnp.moveaxis(q.reshape(B, nq, cq, H, hd), 1, 0)  # (nq,B,cq,H,hd)
    kc = jnp.moveaxis(k.reshape(B, nk, ck, H, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, ck, H, hd), 1, 0)

    qpos_base = jnp.arange(cq)
    kpos_base = jnp.arange(ck)

    def q_body(_, qi_qblk):
        qi, qblk = qi_qblk
        m0 = jnp.full((B, H, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, cq, H, hd), jnp.float32)

        def k_body(carry, kj_blk):
            m, l, acc = carry
            kj, kblk, vblk = kj_blk
            logit = contract("bshd,bthd->bhst", qblk, kblk, prec, site="attn.qk")  # (B,H,cq,ck)
            logit = constrain(logit, "batch", "heads", None, None)
            qp = qi * cq + qpos_base[:, None]
            kp = kj * ck + kpos_base[None, :]
            msk = jnp.ones((cq, ck), bool) if not causal else (kp <= qp)
            if window is not None:
                msk = msk & (kp > qp - window)
            logit = jnp.where(msk[None, None], logit, _NEG)
            m_new = jnp.maximum(m, jnp.max(logit, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(logit - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = contract("bhst,bthd->bshd", p, vblk, prec, site="attn.pv")  # (B,cq,H,hd)
            acc_new = acc * jnp.moveaxis(corr, 2, 1)[..., None] + pv
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            k_body, (m0, l0, a0), (jnp.arange(nk), kc, vc)
        )
        out = acc / jnp.maximum(jnp.moveaxis(l, 2, 1), 1e-30)[..., None]
        return None, constrain(out, "batch", None, "heads", None)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), qc))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


def attn_apply(p, x, cfg: ModelConfig, prec: PrecisionConfig, positions=None, window: Optional[int] = None):
    """Full-sequence attention (training / prefill). Returns (out, KVCache)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = _qkv(p, x, cfg, positions, prec)
    G = cfg.n_heads // cfg.n_kv_heads
    qf = q * (cfg.hd ** -0.5)
    kf = _expand_kv(k, G)
    vf = _expand_kv(v, G)

    if S <= FLASH_THRESHOLD:
        out = _dense_attention(qf, kf, vf, cfg.causal, window, prec)  # (B,S,H,hd)
    else:
        out = _chunked_attention(qf, kf, vf, cfg.causal, window, prec)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    out = constrain(out, "batch", "seq", "heads")
    return dot(out, p["wo"], prec, site="attn.o"), KVCache(k=k, v=v)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def attn_decode(p, x, cache: KVCache, pos, cfg: ModelConfig, prec: PrecisionConfig, window: Optional[int] = None):
    """One decode step. x: (B, 1, D); pos: scalar int32 (current index).
    Returns (out, updated cache)."""
    B = x.shape[0]
    kv, g, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(p, x, cfg, positions, prec)

    k_cache = jax.lax.dynamic_update_slice(
        cache.k, k_new.astype(cache.k.dtype), (0, pos, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        cache.v, v_new.astype(cache.v.dtype), (0, pos, 0, 0)
    )
    k_cache = constrain(k_cache, "batch", "kv_seq", "kv_heads", None)
    v_cache = constrain(v_cache, "batch", "kv_seq", "kv_heads", None)

    # flash-decoding form: flat heads, cache sequence stays sharded; XLA
    # inserts the distributed max/sum for the softmax over the sharded T.
    kf = _expand_kv(k_cache.astype(jnp.float32), g)
    vf = _expand_kv(v_cache.astype(jnp.float32), g)
    logits = contract("bshd,bthd->bhst", q * (hd ** -0.5), kf, prec, site="attn.qk")  # (B,H,1,T)
    t = jnp.arange(cache.k.shape[1])
    valid = t <= pos
    if window is not None:
        valid = valid & (t > pos - window)
    logits = jnp.where(valid[None, None, None, :], logits, _NEG)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = contract("bhst,bthd->bshd", probs, vf, prec, site="attn.pv")
    out = out.reshape(B, 1, cfg.n_heads * hd)
    return dot(out, p["wo"], prec, site="attn.o"), KVCache(k=k_cache, v=v_cache)
