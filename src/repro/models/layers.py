"""Shared neural-net layers (pure functional JAX; params = nested dicts).

Every matmul routes through the ``repro.precision`` engine API so the
paper's rr-precision policy applies uniformly (DESIGN.md §4). Initializers take an
explicit PRNG key; dtypes are f32 at rest (the precision policy decides the
compute representation).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.precision import PrecisionConfig, dot

__all__ = [
    "dense_init",
    "rmsnorm_init",
    "rmsnorm",
    "mlp_init",
    "mlp_apply",
    "embed_init",
    "rope",
    "silu",
]


def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None):
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def embed_init(key, vocab: int, d: int):
    return jax.random.normal(key, (vocab, d), jnp.float32) * 0.02


def rmsnorm_init(d: int):
    return jnp.ones((d,), jnp.float32)


def rmsnorm(x, w, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)) * w


def silu(x):
    return x * jax.nn.sigmoid(x)


def mlp_init(key, d: int, d_ff: int, act: str):
    ks = jax.random.split(key, 3)
    p = {"down": dense_init(ks[0], d_ff, d)}
    if act == "swiglu":
        p["gate"] = dense_init(ks[1], d, d_ff)
        p["up"] = dense_init(ks[2], d, d_ff)
    else:  # gelu
        p["up"] = dense_init(ks[2], d, d_ff)
    return p


def mlp_apply(p, x, act: str, prec: PrecisionConfig):
    if act == "swiglu":
        h = silu(dot(x, p["gate"], prec, site="mlp.gate")) * dot(x, p["up"], prec, site="mlp.up")
    else:
        h = jax.nn.gelu(dot(x, p["up"], prec, site="mlp.up"))
    h = constrain(h, "batch", "seq", "mlp")
    return dot(h, p["down"], prec, site="mlp.down")


def rope(x, positions, theta: float):
    """Rotary embedding on the last (head) dim. x: (..., S, n, hd);
    positions: (..., S) int32 broadcastable."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
