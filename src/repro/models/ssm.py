"""Mamba (S6 selective SSM) mixer — used by jamba-v0.1 (1 attn : 7 mamba).

TPU-idiomatic chunked selective scan: the sequence is split into chunks;
within a chunk the linear recurrence runs as a log-depth
``lax.associative_scan`` (VPU-parallel), chunks are stitched by a cheap
outer ``lax.scan`` carrying the (B, d_inner, state) boundary state. Peak
intermediate memory is (B, chunk, d_inner, state) instead of
(B, S, d_inner, state).

Decode is the O(1)-state recurrent step (this is why jamba runs the
long_500k cell while pure-attention models cannot).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.precision import PrecisionConfig, dot
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, silu

__all__ = ["mamba_init", "mamba_apply", "mamba_decode", "MambaState", "init_mamba_state"]

SSM_CHUNK = 256


class MambaState(NamedTuple):
    h: jnp.ndarray  # (B, d_inner, state)
    conv: jnp.ndarray  # (B, conv_k - 1, d_inner) rolling conv window


def mamba_init(key, cfg: ModelConfig):
    d, di, r, s = cfg.d_model, cfg.d_inner, cfg.dt_rank_, cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32) * 0.2,
        "x_proj": dense_init(ks[2], di, r + 2 * s),
        "dt_proj": dense_init(ks[3], r, di, scale=r**-0.5),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))),
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, s + 1, dtype=jnp.float32), (di, s))
        ),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d),
    }


def _ssm_inputs(p, x, cfg: ModelConfig, prec):
    """Projections shared by train and decode: returns (xz-split, dt, Bc, Cc)."""
    r, s = cfg.dt_rank_, cfg.ssm_state
    xbc = dot(x, p["x_proj"], prec, site="ssm.x_proj")  # (..., r + 2s)
    dt = jax.nn.softplus(dot(xbc[..., :r], p["dt_proj"], prec, site="ssm.dt_proj") + p["dt_bias"])
    Bc = xbc[..., r : r + s]
    Cc = xbc[..., r + s :]
    return dt, Bc, Cc


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B, S, di), w: (K, di). If ``state``
    ((B, K-1, di)) is given, it prefixes x (decode/streaming)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1) :, :]
    return out, new_state


def _selective_scan_chunked(u, dt, Bc, Cc, A, h0, chunk=None):
    """u, dt: (B, S, di); Bc, Cc: (B, S, s); A: (di, s); h0: (B, di, s).
    Returns (y (B, S, di), h_final).

    The (di x s) state expansion (decay/drive outer products) happens INSIDE
    the chunk body, so the peak intermediate is (B, chunk, di, s) rather than
    (B, S, di, s) — at jamba train_4k scale that is 2 GB vs 34 GB per layer
    (§Perf iteration: the v1 dry-run showed the full-S expansion dominating
    the temp footprint)."""
    B_, S, di = u.shape
    s = A.shape[1]
    chunk = min(chunk or SSM_CHUNK, S)
    assert S % chunk == 0
    nc = S // chunk

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B_, nc, chunk, *x.shape[2:]), 1, 0)

    uc, dtc, bc, cc = to_chunks(u), to_chunks(dt), to_chunks(Bc), to_chunks(Cc)

    def chunk_body(h, inputs):
        u_b, dt_b, B_b, C_b = inputs  # (B,c,di) (B,c,di) (B,c,s) (B,c,s)
        dec = jnp.exp(dt_b[..., None] * A)  # (B, c, di, s)
        drv = (dt_b * u_b)[..., None] * B_b[:, :, None, :]

        def combine(a, b):
            (da, xa), (db, xb) = a, b
            return da * db, xa * db + xb

        dec_c, drv_c = jax.lax.associative_scan(combine, (dec, drv), axis=1)
        h_all = dec_c * h[:, None] + drv_c  # (B, c, di, s)
        y = jnp.einsum("bcds,bcs->bcd", h_all, C_b)
        return h_all[:, -1], y

    h_fin, ys = jax.lax.scan(chunk_body, h0, (uc, dtc, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, S, di)
    return y, h_fin


def mamba_apply(p, x, cfg: ModelConfig, prec: PrecisionConfig, state=None):
    """Full-sequence mixer. x: (B, S, d). Returns (out, MambaState)."""
    B, S, d = x.shape
    di = cfg.d_inner
    xz = dot(x, p["in_proj"], prec, site="ssm.in_proj")
    xi, z = xz[..., :di], xz[..., di:]
    conv_state = None if state is None else state.conv
    xi, new_conv = _causal_conv(xi, p["conv_w"], conv_state)
    xi = silu(xi)

    dt, Bc, Cc = _ssm_inputs(p, xi, cfg, prec)
    A = -jnp.exp(p["A_log"])
    h0 = (
        jnp.zeros((B, di, cfg.ssm_state), jnp.float32) if state is None else state.h
    )
    y, h_fin = _selective_scan_chunked(xi, dt, Bc, Cc, A, h0)
    y = y + xi * p["D"]
    y = y * silu(z)
    out = dot(y, p["out_proj"], prec, site="ssm.out_proj")
    return out, MambaState(h=h_fin, conv=new_conv)


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    return MambaState(
        h=jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), jnp.float32),
    )


def mamba_decode(p, x, state: MambaState, cfg: ModelConfig, prec: PrecisionConfig):
    """One-token step. x: (B, 1, d). O(1) in context length."""
    out, new_state = mamba_apply(p, x, cfg, prec, state=state)
    return out, new_state
