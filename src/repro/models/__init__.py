"""Composable model zoo: dense / MoE / SSM / xLSTM / hybrid transformers."""

from .config import ModelConfig
from .model import decode_step, forward, init_decode_state, lm_loss, model_init, prefill
