"""repro.pack — packed R2F2 storage: solver state at the carried split.

The arithmetic side of the paper halves operand width; this package halves
*storage*: solver state is carried between chunk boundaries, snapshots, and
``repro.ckpt`` evictions as a :class:`PackedArray` — the ``total_bits``-wide
bit payload of :func:`repro.core.flexformat.pack_r2f2` (uint16 for all
<=16-bit formats) plus per-block split metadata — instead of f32.
"""

from .packed import (
    PackedArray,
    block_storage_k,
    is_packed,
    pack_array,
    pack_block,
    pack_state,
    payload_dtype,
    storage_quantize,
    state_nbytes,
    unpack_array,
    unpack_block,
    unpack_state,
)

__all__ = [
    "PackedArray",
    "pack_array",
    "unpack_array",
    "pack_state",
    "unpack_state",
    "storage_quantize",
    "is_packed",
    "state_nbytes",
    # block-level helpers shared with the fused sweep prologue/epilogue
    "payload_dtype",
    "block_storage_k",
    "pack_block",
    "unpack_block",
]
