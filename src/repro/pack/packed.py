"""The packed R2F2 storage format (DESIGN.md §13).

A :class:`PackedArray` is a registered pytree node carrying one array's
R2F2 storage representation:

* ``payload`` — the 2-D bit payload (``pack_r2f2`` fields: sign | exp |
  mantissa), ``uint16`` whenever the format fits 16 bits (every format the
  paper studies), ``uint32`` otherwise;
* ``k`` — the per-block flexible split, one int32 per storage block;
* static aux data — the :class:`~repro.core.flexformat.FlexFormat`, the
  logical array shape, the 2-D view dims, and the storage block shape —
  which rides in the treedef, so jit/scan/vmap treat two PackedArrays of
  the same geometry as one structure.

Packing picks, per block, the minimal split whose format represents the
block's value-cluster top as a normal (``select_k_operand`` — the same
rule the tile-wise multiplier applies to operands), then quantizes with the
bit-exact RNE path and encodes the bits. ``unpack(pack(x))`` is therefore
``quantize_em`` at the chosen splits — pack/unpack is bijective on
quantized values (proven by the pack round-trip property suites), which is
what makes packed and quantized-f32 runs bit-identical.

The pure block-level helpers (:func:`block_storage_k`, :func:`pack_block`,
:func:`unpack_block`) are shared verbatim with the fused Pallas sweep
prologue/epilogue (``repro.kernels.fused``), so in-kernel packing and
XLA-boundary packing can never disagree.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.flexformat import (
    FlexFormat,
    pack_r2f2,
    quantize_em,
    unbiased_exponent,
    unpack_r2f2,
)
from repro.core.r2f2 import select_k_operand

__all__ = [
    "PackedArray",
    "pack_array",
    "unpack_array",
    "pack_state",
    "unpack_state",
    "storage_quantize",
    "is_packed",
    "state_nbytes",
    "payload_dtype",
    "block_storage_k",
    "pack_block",
    "unpack_block",
]


def payload_dtype(fmt: FlexFormat):
    """Narrowest unsigned dtype holding ``fmt.total_bits`` payload bits."""
    if fmt.total_bits <= 8:
        return jnp.uint8
    return jnp.uint16 if fmt.total_bits <= 16 else jnp.uint32


def block_storage_k(x, fmt: FlexFormat, k_min: int = 0):
    """Storage split for one 2-D block: minimal k representing the block's
    finite value-cluster top as a normal (zeros and non-finites excluded,
    empty blocks floor at exponent -127 -> widest-coverage-downward split is
    clamped by ``k_min``)."""
    mag = jnp.where(jnp.isfinite(x), jnp.abs(jnp.asarray(x, jnp.float32)), 0.0)
    me = unbiased_exponent(jnp.maximum(jnp.max(mag), jnp.float32(1e-38)))
    return jnp.clip(select_k_operand(me, fmt), k_min, fmt.fx)


def pack_block(x, fmt: FlexFormat, k):
    """Quantize one block at split ``k`` and encode the storage payload
    (uint32 bits; callers narrow to :func:`payload_dtype`)."""
    e = fmt.eb + jnp.asarray(k, jnp.int32)
    m = fmt.mb + fmt.fx - jnp.asarray(k, jnp.int32)
    q = quantize_em(jnp.asarray(x, jnp.float32), e, m)
    return pack_r2f2(q, fmt, k)


def unpack_block(payload, fmt: FlexFormat, k):
    """Decode one block's payload back to f32 at split ``k``."""
    return unpack_r2f2(jnp.asarray(payload, jnp.uint32), fmt, k)


def _view2d(shape: Tuple[int, ...]) -> Tuple[int, int]:
    """Canonical 2-D view of an arbitrary-rank array: trailing axis stays
    contiguous (the stencil axis), leading axes collapse into rows."""
    if len(shape) == 0:
        return (1, 1)
    if len(shape) == 1:
        return (1, shape[0])
    rows = 1
    for d in shape[:-1]:
        rows *= d
    return (rows, shape[-1])


class PackedArray:
    """One array in packed R2F2 storage — see module docstring.

    Registered pytree node: children ``(payload, k)`` (so it flows through
    jit / scan / vmap / ``repro.ckpt`` like any state leaf), aux data
    ``(fmt, shape, block)`` (static, hashable — part of the treedef).
    """

    __slots__ = ("payload", "k", "fmt", "shape", "block")

    def __init__(self, payload, k, fmt: FlexFormat, shape: Tuple[int, ...], block: Tuple[int, int]):
        self.payload = payload
        self.k = k
        self.fmt = fmt
        self.shape = tuple(shape)
        self.block = tuple(block)

    @property
    def nbytes(self) -> int:
        """Storage footprint: payload plus split metadata."""
        return int(self.payload.nbytes) + int(self.k.nbytes)

    def with_view(self, shape: Tuple[int, ...]) -> "PackedArray":
        """The same packed elements under a different logical shape.

        Only valid for single-block arrays (one split covers every element
        either way, so the payload is a pure reshape) — which is what the
        fused sweep kernels need to re-view e.g. a ``(nx, ny)`` field as the
        kernel's ``(1, nx*ny)`` leaf and back.
        """
        shape = tuple(int(d) for d in shape)
        n_new = 1
        for d in shape:
            n_new *= d
        n_old = 1
        for d in self.shape:
            n_old *= d
        if n_new != n_old:
            raise ValueError(f"cannot view {self.shape} as {shape}: size differs")
        if tuple(self.k.shape[-2:]) != (1, 1):
            raise ValueError(
                "with_view needs a single-block PackedArray; got k of shape "
                f"{tuple(self.k.shape)}"
            )
        view = _view2d(shape)
        payload = self.payload.reshape(self.payload.shape[: -2] + view)
        return PackedArray(payload, self.k, self.fmt, shape, view)

    def __repr__(self) -> str:
        return (
            f"PackedArray({self.fmt}, shape={self.shape}, block={self.block}, "
            f"payload={getattr(self.payload, 'dtype', '?')}{getattr(self.payload, 'shape', '')})"
        )

    def tree_flatten(self):
        return (self.payload, self.k), (self.fmt, self.shape, self.block)

    @classmethod
    def tree_unflatten(cls, aux, children):
        payload, k = children
        fmt, shape, block = aux
        return cls(payload, k, fmt, shape, block)


jax.tree_util.register_pytree_node(
    PackedArray,
    lambda pa: pa.tree_flatten(),
    PackedArray.tree_unflatten,
)


def pack_array(
    x,
    fmt: FlexFormat,
    *,
    block: Optional[Tuple[int, int]] = None,
    k_min: int = 0,
) -> PackedArray:
    """Pack one f32 array. ``block`` is the storage block granularity over
    the canonical 2-D view (one split per block; default: one block —
    per-tensor k, which is exactly the per-block case for the solver's
    whole-extent sweep kernels). Blocks that do not divide are zero-padded;
    the pad is cropped on unpack and excluded from split selection (zeros
    carry no exponent)."""
    x = jnp.asarray(x, jnp.float32)
    shape = tuple(x.shape)
    rows, width = _view2d(shape)
    x2 = x.reshape(rows, width)
    if block is None:
        block = (rows, width)
    br, bw = (min(block[0], rows), min(block[1], width))
    gi, gj = -(-rows // br), -(-width // bw)
    pad_r, pad_w = gi * br - rows, gj * bw - width
    if pad_r or pad_w:
        x2 = jnp.pad(x2, ((0, pad_r), (0, pad_w)))

    # (gi, br, gj, bw) tiling; one split per (gi, gj) block
    xt = x2.reshape(gi, br, gj, bw)
    mag = jnp.where(jnp.isfinite(xt), jnp.abs(xt), 0.0)
    me = unbiased_exponent(jnp.maximum(jnp.max(mag, axis=(1, 3)), jnp.float32(1e-38)))
    k = jnp.clip(select_k_operand(me, fmt), k_min, fmt.fx).astype(jnp.int32)

    k_elem = jnp.broadcast_to(k[:, None, :, None], xt.shape)
    payload = pack_block(xt, fmt, k_elem).reshape(gi * br, gj * bw)
    return PackedArray(payload.astype(payload_dtype(fmt)), k, fmt, shape, (br, bw))


def unpack_array(pa: PackedArray):
    """Decode a PackedArray back to its logical-shape f32 array."""
    rows, width = _view2d(pa.shape)
    br, bw = pa.block
    gi, gj = -(-rows // br), -(-width // bw)
    pt = jnp.asarray(pa.payload, jnp.uint32).reshape(gi, br, gj, bw)
    k_elem = jnp.broadcast_to(pa.k[:, None, :, None], pt.shape)
    x2 = unpack_block(pt, pa.fmt, k_elem).reshape(gi * br, gj * bw)
    return x2[:rows, :width].reshape(pa.shape)


def pack_state(state, fmt: FlexFormat, *, block=None, k_min: int = 0):
    """Pack every leaf of a solver-state pytree (ISSUE's ``pack_state``)."""
    return jax.tree_util.tree_map(
        lambda x: pack_array(x, fmt, block=block, k_min=k_min), state
    )


def unpack_state(packed):
    """Inverse of :func:`pack_state`: PackedArray leaves back to f32."""
    return jax.tree_util.tree_map(
        lambda pa: unpack_array(pa),
        packed,
        is_leaf=lambda x: isinstance(x, PackedArray),
    )


def storage_quantize(state, fmt: FlexFormat, *, block=None, k_min: int = 0):
    """The f32-carried reference rounding: ``unpack(pack(state))``. A run
    carrying ``storage="quantized"`` state is bit-identical to the packed
    run at the same splits — by construction, since pack/unpack is
    bijective on quantized values."""
    return unpack_state(pack_state(state, fmt, block=block, k_min=k_min))


def is_packed(tree) -> bool:
    """Does any node of ``tree`` carry packed storage?"""
    found = []
    jax.tree_util.tree_map(
        lambda x: found.append(isinstance(x, PackedArray)) or x,
        tree,
        is_leaf=lambda x: isinstance(x, PackedArray),
    )
    return any(found)


def state_nbytes(tree) -> int:
    """Total carried-state bytes (payload + metadata for packed leaves)."""
    return sum(int(x.nbytes) for x in jax.tree_util.tree_leaves(tree))
