"""Fault-tolerant, mesh-agnostic checkpointing.

Layout: ``<dir>/step_<n>/`` with one ``.npy`` per leaf (path-encoded names)
plus ``manifest.json`` (treedef, shapes, dtypes, step, timestamp). Writes go
to ``step_<n>.tmp`` and are atomically renamed, so a crash mid-write never
corrupts the latest checkpoint; ``latest_step`` only trusts complete
directories.

Restore is *mesh-agnostic*: leaves are loaded on host and ``device_put``
against whatever sharding the caller provides — the elastic-rescale path
(launch/elastic.py) is exactly "restore with a different mesh".
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "available_steps"]

_SEP = "__"


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = _SEP.join(_key_str(k) for k in path)
        out[name] = leaf
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "name"):
        return str(k.name)
    if hasattr(k, "idx"):
        return f"idx{k.idx}"
    return str(k)


def save(tree, ckpt_dir: str, step: int) -> str:
    """Atomic checkpoint write. Returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "time": time.time(), "leaves": {}}
    for name, leaf in leaves.items():
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"][name] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def available_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                steps.append(int(d[len("step_") :]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(like, ckpt_dir: str, step: int, shardings=None):
    """Load a checkpoint into the structure of ``like``.

    ``shardings``: optional pytree (matching ``like``) of jax.sharding
    objects — pass the *current* mesh's shardings to reshard on load
    (elastic restart). Without it, arrays land on the default device.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    names = list(_flatten_with_paths(like).keys())
    missing = [n for n in names if n not in manifest["leaves"]]
    if missing:
        raise ValueError(f"checkpoint at {path} missing leaves: {missing[:5]}...")

    loaded = {n: np.load(os.path.join(path, n + ".npy")) for n in names}
    flat_like, tdef = jax.tree_util.tree_flatten(like)
    ordered = [loaded[n] for n in names]

    if shardings is not None:
        shard_flat = tdef.flatten_up_to(shardings)
        ordered = [jax.device_put(a, s) for a, s in zip(ordered, shard_flat)]
    else:
        ordered = [jax.device_put(a) for a in ordered]
    return tdef.unflatten(ordered)
