"""Fault-tolerant checkpointing."""

from .checkpoint import available_steps, latest_step, restore, save
