"""repro.alu — the flexible-precision arithmetic plane beyond multiply.

The paper substitutes only multiplications; this package extends the same
``<EB, MB, FX>`` runtime-reconfigurable emulation to the remaining solver
arithmetic — add/sub, divide, and rsqrt — with the Fig.-5 grow-and-retry
law generalized per op (alignment-shift evidence for add, quotient-range
evidence for divide; see :func:`repro.core.r2f2.op_bounds`). The
:class:`repro.precision` engines and the fused ``blockops`` primitives both
route through these functions, so the stepwise and in-kernel planes share
one definition of every flexible op.
"""

from .flexops import flex_add, flex_div, flex_op, flex_rsqrt, flex_sub

__all__ = ["flex_add", "flex_sub", "flex_div", "flex_rsqrt", "flex_op"]
