"""Tile-wise flexible ALU ops — ``r2f2_multiply``'s shape for add/div/rsqrt.

Semantics follow the repo's emulation convention for non-multiply arithmetic
(established by the fixed-format engine): quantize the operands to the
runtime format ``E(EB+k)M(MB+FX-k)``, perform the operation on the f32
substrate, and quantize the result to the same format. There is no
flexible-region tail approximation here — that approximation models dropped
partial *products* (Fig. 4b) and has no analogue in an adder or divider
datapath, so results are plain RNE roundings of the substrate op.

``k=None`` selects, per tile, the minimal split covering the op's exponent
envelope (:func:`repro.core.r2f2.select_k_op`) — the vectorized collapse of
the paper's grow-and-retry loop, exactly as the multiplier does it.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.flexformat import FlexFormat, quantize_em_with_flags
from repro.core.r2f2 import R2F2Stats, _tile_max_exp, select_k_op

__all__ = ["flex_add", "flex_sub", "flex_div", "flex_rsqrt", "flex_op"]

_SUBSTRATE = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "div": lambda a, b: a / b,
    "rsqrt": lambda a, _b: jax.lax.rsqrt(a),
}

#: substrate op name -> the adjust-law envelope it is governed by
#: (sub shares add's alignment-shift evidence; see DESIGN.md §13)
_EVIDENCE_OP = {"add": "add", "sub": "add", "div": "div", "rsqrt": "rsqrt"}


def flex_op(
    a,
    b,
    fmt: FlexFormat,
    op: str,
    *,
    k=None,
    tile_shape: Optional[Tuple[int, ...]] = None,
):
    """Shared tile-wise driver. ``b`` is ignored for unary ops (rsqrt).

    Returns ``(result, R2F2Stats)`` exactly like
    :func:`repro.core.r2f2.r2f2_multiply`: per-tile chosen splits plus
    overflow/underflow element counts (the adjust-up triggers).
    """
    if op not in _SUBSTRATE:
        raise ValueError(f"unknown flex op {op!r}; known: {tuple(_SUBSTRATE)}")
    ev_op = _EVIDENCE_OP[op]
    a = jnp.asarray(a, jnp.float32)
    unary = op == "rsqrt"
    b = a if unary else jnp.broadcast_to(jnp.asarray(b, jnp.float32), a.shape)

    if k is None:
        ae, bcast_a = _tile_max_exp(a, tile_shape)
        be = ae if unary else _tile_max_exp(b, tile_shape)[0]
        k_tile = select_k_op(ae, be, fmt, ev_op)
        k_full = bcast_a(k_tile)
    else:
        k_tile = jnp.asarray(k, jnp.int32)
        k_full = jnp.broadcast_to(k_tile, a.shape) if k_tile.ndim == 0 else k_tile

    e_bits = fmt.eb + k_full
    m_bits = fmt.mb + fmt.fx - k_full

    qa, oa, ua = quantize_em_with_flags(a, e_bits, m_bits)
    if unary:
        qb, ob, ub = qa, jnp.zeros_like(oa), jnp.zeros_like(ua)
    else:
        qb, ob, ub = quantize_em_with_flags(b, e_bits, m_bits)
    r = _SUBSTRATE[op](qa, qb)
    qr, orr, ur = quantize_em_with_flags(r, e_bits, m_bits)

    stats = R2F2Stats(
        k=k_tile,
        overflow_count=jnp.sum(oa | ob | orr),
        underflow_count=jnp.sum(ua | ub | ur),
    )
    return qr, stats


def flex_add(a, b, fmt: FlexFormat, *, k=None, tile_shape=None):
    """Flexible-precision addition (alignment-shift evidence law)."""
    return flex_op(a, b, fmt, "add", k=k, tile_shape=tile_shape)


def flex_sub(a, b, fmt: FlexFormat, *, k=None, tile_shape=None):
    """Flexible-precision subtraction (shares the add envelope)."""
    return flex_op(a, b, fmt, "sub", k=k, tile_shape=tile_shape)


def flex_div(a, b, fmt: FlexFormat, *, k=None, tile_shape=None):
    """Flexible-precision division (quotient-range evidence law)."""
    return flex_op(a, b, fmt, "div", k=k, tile_shape=tile_shape)


def flex_rsqrt(x, fmt: FlexFormat, *, k=None, tile_shape=None):
    """Flexible-precision reciprocal square root (unary envelope)."""
    return flex_op(x, None, fmt, "rsqrt", k=k, tile_shape=tile_shape)
