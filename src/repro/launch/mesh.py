"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.

Single pod:  (16, 16)      -> ("data", "model")        = 256 chips
Multi-pod:   (2, 16, 16)   -> ("pod", "data", "model") = 512 chips

The 'pod' axis carries outer data parallelism / FSDP; cross-pod traffic is
gradient reduction only (and optional rr-16-compressed, train.py
--grad-comm), matching DCI << ICI bandwidth reality.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / examples): 1D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
