import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
os.environ.setdefault("REPRO_NATIVE_BF16", "1")  # accurate HLO byte accounting

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, on BOTH the single-pod
(16, 16) and multi-pod (2, 16, 16) production meshes:

    with mesh:
        lowered  = jax.jit(step_fn, in_shardings=...).lower(*input_specs)
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves it fits
        compiled.cost_analysis()     # FLOPs/bytes for the roofline

Results (memory/FLOP/collective-bytes per cell) land in
``artifacts/dryrun/<cell>.json`` — benchmarks/roofline.py reads them.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes [--skip-existing]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, applicability, cell_window, get_config
from repro.precision import PRESETS
from repro.launch.hlo_cost import parse_hlo_costs
from repro.dist.sharding import axis_rules
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import decode_specs, prefill_specs, train_specs
from repro.models import prefill
from repro.train.step import TrainConfig, make_serve_step, make_train_step

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[tok_dtype]


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in the partitioned HLO,
    split by op kind. (Result bytes ~ payload; all-gather results count the
    gathered size, reduce-scatter the scattered size — a consistent,
    conservative proxy; see EXPERIMENTS.md §Roofline notes.)"""
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w\.\-]+ = (.+)", ls)
        if m is None:
            continue
        rest = m.group(1)
        for c in COLLECTIVES:
            # match the op name, not substrings of other ops
            if re.search(rf"\b{c}(-start|-done)?\(", rest):
                if c == "all-reduce" and "all-reduce-done" in rest:
                    continue  # payload counted at -start
                shapes = _SHAPE_RE.findall(rest.split("(")[0])
                total = sum(_shape_bytes(t, d) for t, d in shapes)
                out[c] += total
                counts[c] += 1
                break
    return out, counts


# §Perf hillclimb: per-cell tuned training configs for the three selected
# cells (EXPERIMENTS.md §Perf documents each hypothesis->measurement cycle).
# All other cells run the plain baseline TrainConfig.
from repro.train.optimizer import OptConfig  # noqa: E402

TRAIN_OVERRIDES = {
    # memory-bound at 550 GiB/dev temp (126 f32 scan boundaries + B_loc=16
    # activations); 16 microbatches + bf16 boundaries + factored optimizer
    "llama3-405b": dict(
        microbatches=8, carry_dtype="bf16", opt=OptConfig(kind="adafactor")
    ),
    # collective-bound (94 groups x 128-expert FSDP all-gathers) + memory
    "qwen3-moe-235b-a22b": dict(microbatches=4, carry_dtype="bf16"),
    # memory-bound hybrid (mamba state expansion + MoE); chunk-local
    # selective scan (ssm.py) is the structural half of this iteration
    "jamba-v0.1-52b": dict(microbatches=4, carry_dtype="bf16"),
}


def build_cell(arch: str, shape_name: str, mesh, prec_name: str = "deploy"):
    """Returns (fn, in_shardings, args_sds) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    prec = PRESETS[prec_name]
    window = cell_window(cfg, shape)

    if shape.kind == "train":
        over = {} if os.environ.get("REPRO_NO_OVERRIDES") else TRAIN_OVERRIDES.get(arch, {})
        tcfg = TrainConfig(window=window, **over)
        (state_sds, b_sds), (state_sh, b_sh), _ = train_specs(cfg, shape, tcfg, mesh)
        fn = make_train_step(cfg, prec, tcfg, param_shardings=state_sh["params"])
        return fn, (state_sh, b_sh), (state_sds, b_sds)

    if shape.kind == "prefill":
        (p_sds, b_sds), (p_sh, b_sh) = prefill_specs(cfg, shape, mesh)

        def fn(params, batch):
            return prefill(
                params,
                cfg,
                prec,
                tokens=batch.get("tokens"),
                embeds=batch.get("embeds"),
                max_len=shape.seq_len,
                window=window,
            )

        return fn, (p_sh, b_sh), (p_sds, b_sds)

    # decode
    (p_sds, c_sds, t_sds, pos_sds), (p_sh, c_sh, t_sh, pos_sh) = decode_specs(
        cfg, shape, mesh
    )
    fn = make_serve_step(cfg, prec, window=window)
    return fn, (p_sh, c_sh, t_sh, pos_sh), (p_sds, c_sds, t_sds, pos_sds)


def run_cell(arch: str, shape_name: str, multi_pod: bool, save: bool = True, verbose=True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = applicability(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    if skip:
        result = {"cell": cell_id, "status": "skip", "reason": skip}
        if save:
            _save(cell_id, result)
        if verbose:
            print(f"[skip] {cell_id}: {skip}")
        return result

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        with mesh, axis_rules(mesh):
            fn, in_sh, args_sds = build_cell(arch, shape_name, mesh)
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args_sds)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            coll, coll_counts = collective_bytes(hlo)
            # trip-count-aware rollup (XLA cost_analysis counts loop bodies
            # once; see launch/hlo_cost.py) — the roofline reads these.
            corrected = parse_hlo_costs(hlo)

        n_chips = mesh.devices.size
        result = {
            "cell": cell_id,
            "status": "ok",
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_name,
            "chips": int(n_chips),
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops_per_device": float(cost.get("flops", -1.0)),
            "bytes_accessed_per_device": float(cost.get("bytes accessed", -1.0)),
            "memory": {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", -1)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", -1)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", -1)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", -1)),
            },
            "collective_bytes": coll,
            "collective_counts": coll_counts,
            "corrected": {
                "flops_per_device": corrected["flops"],
                "bytes_per_device": corrected["bytes"],
                "collective_bytes": corrected["collective_bytes"],
                "collective_counts": corrected["collective_counts"],
            },
            "params_B": round(cfg.param_count() / 1e9, 3),
            "active_params_B": round(cfg.active_param_count() / 1e9, 3),
        }
        if verbose:
            m = result["memory"]
            print(
                f"[ok]   {cell_id}: compile {t_compile:.0f}s, "
                f"{corrected['flops']/1e9:.1f} GFLOP/dev (raw {result['flops_per_device']/1e9:.1f}), "
                f"args {m['argument_bytes']/2**30:.2f} GiB/dev, "
                f"temp {m['temp_bytes']/2**30:.2f} GiB/dev, "
                f"coll {sum(corrected['collective_bytes'].values())/2**20:.1f} MiB/dev"
            )
    except Exception as e:  # a failure here is a bug in the system
        result = {
            "cell": cell_id,
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-4000:],
        }
        if verbose:
            print(f"[FAIL] {cell_id}: {type(e).__name__}: {str(e)[:300]}")
    if save:
        _save(cell_id, result)
    return result


def _save(cell_id, result):
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, cell_id + ".json"), "w") as f:
        json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true", help="use the 2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    ok = fail = skip = 0
    for a, s, mp in cells:
        cid = f"{a}__{s}__{'2x16x16' if mp else '16x16'}"
        path = os.path.join(ARTIFACTS, cid + ".json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                r = json.load(f)
            print(f"[cached] {cid}: {r['status']}")
        else:
            r = run_cell(a, s, mp)
        ok += r["status"] == "ok"
        fail += r["status"] == "error"
        skip += r["status"] == "skip"
    print(f"\ndry-run summary: {ok} ok, {skip} skipped (by rule), {fail} FAILED")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
