"""Trip-count-aware cost rollup over compiled (SPMD-partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body exactly ONCE,
regardless of trip count (verified empirically: a scan of L matmuls reports
one matmul's flops for any L). Every repeated structure in this framework —
the scan over layer groups, flash-attention chunk loops, SSM/xLSTM chunk
scans — therefore vanishes from the naive numbers. This module re-derives

    flops            — 2 * numel(result) * prod(contracting dims) per dot
    bytes accessed   — HBM-traffic model: result bytes of *materializing*
                       ops (fusions, dots, copies/converts, gathers/scatters,
                       dynamic slices, reduces, collectives) plus dot operand
                       reads (weights/activations). Elementwise chains live
                       inside fusions post-optimization, and producers'
                       results are counted exactly once — no per-consumer
                       double counting. VMEM residency: dot operands small
                       enough to stay on-chip (<= VMEM_RESIDENT_BYTES) are
                       charged once per loop *entry*, not per trip — a TPU
                       keeps loop-invariant weights resident (e.g. sLSTM's
                       16.8 MB recurrent block read 4096x per layer would
                       otherwise dominate every other term by 100x).
    collective bytes — result-shape bytes per collective, by kind

by walking the computation graph with multipliers: ``while`` bodies get
``known_trip_count`` (present in backend_config for all lax.scan loops),
call/fusion/conditional branches get x1.

This is the measurement layer behind EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import json
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["parse_hlo_costs", "HLOCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0, "s2": 1, "u2": 1,
}

COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

VMEM_RESIDENT_BYTES = 32 * 2**20  # operands below this stay on-chip in loops

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_ONE_RE = re.compile(r"(?:condition|body|to_apply|calls)=%([\w\.\-]+)")
_CALLED_LIST_RE = re.compile(r"(?:calls|branch_computations)=\{([^}]*)\}")


def _called_computations(line: str) -> List[str]:
    names = list(_CALLED_ONE_RE.findall(line))
    for group in _CALLED_LIST_RE.findall(line):
        names += re.findall(r"%([\w\.\-]+)", group)
    return names

_BOOKKEEPING = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "reshape",  # layout-preserving on CPU/TPU when bitcastable
}

# ops whose results are HBM-materialized buffers in scheduled post-opt HLO
_MATERIALIZING = {
    "fusion", "dot", "custom-call", "scatter", "gather", "dynamic-slice",
    "dynamic-update-slice", "copy", "copy-start", "transpose", "convert",
    "reduce", "reduce-window", "sort", "select-and-scatter", "pad",
    "concatenate", "slice", "reverse", "cholesky", "triangular-solve",
    "all-reduce", "all-reduce-start", "all-gather", "all-gather-start",
    "reduce-scatter", "all-to-all", "collective-permute",
    "collective-permute-start", "rng", "rng-bit-generator",
}


def _shape_numel_bytes(type_str: str) -> Tuple[int, int]:
    """Total (numel, bytes) across all array shapes in a type string."""
    numel = 0
    bts = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel += n
        bts += n * _DTYPE_BYTES[dt]
    return numel, bts


class _Instr:
    __slots__ = ("name", "result_type", "op", "body", "line")

    def __init__(self, name, result_type, op, line):
        self.name = name
        self.result_type = result_type
        self.op = op
        self.line = line


class HLOCost(dict):
    """dict with keys: flops, bytes, collective_bytes (per kind), counts."""


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    depth = 0
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(s)
            if m and s.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                depth = 1
            continue
        depth += s.count("{") - s.count("}")
        if depth <= 0:
            cur = None
            continue
        if s and not s.startswith("//"):
            comps[cur].append(s)
    return comps


def _result_type_of(rest: str) -> str:
    """Everything up to the op name: 'f32[8,16]{1,0} dot(...)' -> type part."""
    # op name = first identifier followed by '('
    m = re.search(r"([\w\-]+)\(", rest)
    if m is None:
        return rest
    return rest[: m.start()]


def _op_of(rest: str) -> str:
    m = re.search(r"([\w\-]+)\(", rest)
    return m.group(1) if m else ""


def _dus_update_bytes(ins, instrs, type_of, operand_names):
    """If ``ins`` is a dynamic-update-slice (or a fusion rooted in one),
    return the UPDATE operand's bytes; else None. XLA aliases the target
    buffer, so only the slice moves — charging the full result per loop
    iteration over-counted scan-transpose residual writes by ~4 orders of
    magnitude (EXPERIMENTS.md §Roofline notes)."""
    line = ins.line
    if ins.op == "dynamic-update-slice":
        ops = operand_names(line)
        if len(ops) >= 2:
            return _shape_numel_bytes(type_of.get(ops[1], ""))[1]
        return None
    if ins.op == "fusion":
        for sub in _called_computations(line):
            body = instrs.get(sub, [])
            if not body:
                continue
            root = body[-1]
            if root.op == "dynamic-update-slice":
                ops = operand_names(root.line)
                if len(ops) >= 2:
                    return _shape_numel_bytes(type_of.get(ops[1], ""))[1]
    return None


def parse_hlo_costs(text: str, entry: Optional[str] = None) -> HLOCost:
    comps = _split_computations(text)
    if not comps:
        return HLOCost(flops=0.0, bytes=0.0, collective_bytes={}, collective_counts={})

    # name -> result type string (for operand shape lookup), per computation
    # (instruction names are unique module-wide in practice; keep global map)
    type_of: Dict[str, str] = {}
    instrs: Dict[str, List[_Instr]] = {}
    for cname, lines in comps.items():
        out = []
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rest = m.group(1), m.group(2)
            rtype = _result_type_of(rest)
            op = _op_of(rest)
            type_of[name] = rtype
            out.append(_Instr(name, rtype, op, line))
        instrs[cname] = out

    entry_name = entry
    if entry_name is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
        entry_name = m.group(1) if m else next(iter(comps))

    flops = 0.0
    bytes_acc = 0.0
    coll_bytes = defaultdict(float)
    coll_counts = defaultdict(float)

    def operand_names(line: str) -> List[str]:
        m = re.search(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)", line[line.find("(") :])
        if not m:
            return []
        args = m.group(1)
        return re.findall(r"%([\w\.\-]+)", args)

    def dot_flops(ins: _Instr) -> float:
        out_numel, _ = _shape_numel_bytes(ins.result_type)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
        ops = operand_names(ins.line)
        if not m or not ops:
            return 0.0
        lhs_type = type_of.get(ops[0], "")
        sm = _SHAPE_RE.search(lhs_type)
        if not sm:
            return 0.0
        dims = [int(d) for d in sm.group(2).split(",") if d]
        contract = 1
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(dims):
                contract *= dims[idx]
        return 2.0 * out_numel * contract

    visited_stack = set()

    def walk(cname: str, mult: float, entry_mult: float = 1.0):
        nonlocal flops, bytes_acc
        if cname not in instrs or cname in visited_stack:
            return
        visited_stack.add(cname)
        for ins in instrs[cname]:
            op = ins.op
            # recurse into called computations
            called = _called_computations(ins.line)
            trip = 1.0
            if op == "while":
                tm = _TRIP_RE.search(ins.line)
                trip = float(tm.group(1)) if tm else 1.0
            if op == "fusion":
                # fusion internals: count dot flops only (rare on CPU),
                # bytes at the fusion boundary below
                for sub in called:
                    for fins in instrs.get(sub, []):
                        if fins.op == "dot":
                            flops += mult * dot_flops(fins)
            else:
                for sub in called:
                    walk(sub, mult * trip, mult)

            if op in _BOOKKEEPING or not op:
                continue
            if op == "dot":
                flops += mult * dot_flops(ins)

            is_coll = None
            for c in COLLECTIVES:
                if op == c or op == c + "-start":
                    is_coll = c
                    break
            _, rbytes = _shape_numel_bytes(ins.result_type)
            if is_coll:
                coll_bytes[is_coll] += mult * rbytes
                coll_counts[is_coll] += mult

            # HBM-traffic model (see module docstring)
            if op in _MATERIALIZING:
                dus_update = _dus_update_bytes(ins, instrs, type_of, operand_names)
                if dus_update is not None:
                    # in-place slice write (XLA aliases the buffer): charge
                    # the read-modify-write of the UPDATE, not the buffer
                    bytes_acc += mult * 2 * dus_update
                else:
                    bytes_acc += mult * rbytes
                if op == "dot":
                    for o in operand_names(ins.line):
                        ob = _shape_numel_bytes(type_of.get(o, ""))[1]
                        # VMEM residency for small (weight-like) operands
                        m_eff = entry_mult if ob <= VMEM_RESIDENT_BYTES else mult
                        bytes_acc += m_eff * ob
        visited_stack.discard(cname)

    walk(entry_name, 1.0)
    return HLOCost(
        flops=flops,
        bytes=bytes_acc,
        collective_bytes=dict(coll_bytes),
        collective_counts=dict(coll_counts),
    )
