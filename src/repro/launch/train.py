"""Fault-tolerant training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mistral-nemo-12b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt \
        --ckpt-every 10 [--resume] [--inject-failure-at 25]

Fault-tolerance model (DESIGN.md §6):
  * checkpoints are atomic and mesh-agnostic (repro.ckpt);
  * the data pipeline is a pure function of the step index, so
    restart-from-latest replays *exactly* the batches the lost steps saw;
  * --inject-failure-at simulates a node failure mid-run; rerunning with
    --resume must produce bit-identical training to an uninterrupted run
    (tests/test_fault_tolerance.py asserts this);
  * straggler mitigation: per-step wall-clock watchdog logs steps slower
    than --straggler-grace x the running median (on real pods this is where
    you fire the preemption/respawn hook).
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp

from repro.ckpt import latest_step, restore, save
from repro.configs import get_config, reduced
from repro.precision import PRESETS
from repro.data import batch_for_step
from repro.dist.sharding import axis_rules
from repro.launch.mesh import make_host_mesh
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--precision", default="deploy", choices=list(PRESETS))
    ap.add_argument("--opt", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-comm", default=None, choices=[None, "bf16", "rr16"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--straggler-grace", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=17)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    prec = PRESETS[args.precision]
    tcfg = TrainConfig(
        opt=OptConfig(kind=args.opt, lr=args.lr, total_steps=args.steps),
        microbatches=args.microbatches,
        grad_comm=args.grad_comm,
    )

    mesh = make_host_mesh()
    with mesh, axis_rules(mesh):
        state = init_train_state(jax.random.PRNGKey(args.seed), cfg, tcfg)
        start = 0
        if args.resume and args.ckpt_dir:
            last = latest_step(args.ckpt_dir)
            if last is not None:
                state = restore(state, args.ckpt_dir, last)
                start = last
                print(f"[resume] restored step {last} from {args.ckpt_dir}")

        step_fn = jax.jit(make_train_step(cfg, prec, tcfg))
        times = []
        for step in range(start, args.steps):
            if args.inject_failure_at is not None and step == args.inject_failure_at:
                print(f"[failure-injection] simulated node failure at step {step}")
                raise SystemExit(42)

            t0 = time.time()
            batch = batch_for_step(cfg, step, args.batch, args.seq, seed=args.seed)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])  # sync point
            dt = time.time() - t0
            times.append(dt)

            if len(times) > 5:
                med = statistics.median(times[-50:])
                if dt > args.straggler_grace * med:
                    print(
                        f"[straggler] step {step} took {dt:.2f}s "
                        f"({dt/med:.1f}x median {med:.2f}s)"
                    )
            if step % args.log_every == 0 or step == args.steps - 1:
                print(
                    f"step {step:5d} loss {loss:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} "
                    f"lr {float(metrics['lr']):.2e} ({dt*1e3:.0f} ms)"
                )
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                path = save(state, args.ckpt_dir, step + 1)
                print(f"[ckpt] step {step+1} -> {path}")

        if args.ckpt_dir:
            save(state, args.ckpt_dir, args.steps)
        print(f"done: final loss {loss:.4f}")
        return loss


if __name__ == "__main__":
    main()
