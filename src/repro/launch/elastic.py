"""Elastic rescale: resume a checkpoint on a DIFFERENT device count/mesh.

Checkpoints are mesh-agnostic (host numpy + manifest), so elastic scaling is
"restore with the new mesh's shardings". The data pipeline being a pure
function of step means the token stream is unaffected by the re-shard; only
the per-host batch slices change.

    PYTHONPATH=src python -m repro.launch.elastic --devices 8 --arch ... \
        --ckpt-dir /tmp/ckpt --steps 10

spawns itself with ``xla_force_host_platform_device_count=<devices>`` and
continues training on the new mesh (examples/elastic_restart.py demos the
full failure -> shrink -> resume cycle).
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys


def respawn_with_devices(n_devices: int, argv):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} "
        + env.get("XLA_FLAGS", "")
    )
    env["REPRO_ELASTIC_CHILD"] = "1"
    cmd = [sys.executable, "-m", "repro.launch.elastic"] + argv
    return subprocess.run(cmd, env=env).returncode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--seed", type=int, default=17)
    args, rest = ap.parse_known_args()

    if args.devices and not os.environ.get("REPRO_ELASTIC_CHILD"):
        argv = [a for a in sys.argv[1:] if not a.startswith("--devices")]
        argv = [a for i, a in enumerate(argv) if not (a == str(args.devices) and sys.argv[sys.argv.index(a) - 1] == "--devices")]
        raise SystemExit(respawn_with_devices(args.devices, argv))

    # child (or direct) path: restore on whatever mesh exists now
    import jax

    from repro.launch.train import main as train_main

    print(f"[elastic] resuming on {len(jax.devices())} devices")
    train_main(
        [
            "--arch", args.arch,
            *(["--reduced"] if args.reduced else []),
            "--steps", str(args.steps),
            "--batch", str(args.batch),
            "--seq", str(args.seq),
            "--ckpt-dir", args.ckpt_dir,
            "--resume",
            "--seed", str(args.seed),
        ]
    )


if __name__ == "__main__":
    main()
