"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs`` returns abstract shapes only — no device allocation — the
same pattern shannon/kernels uses: weak-type-correct, shardable stand-ins
for ``jax.jit(...).lower()``.

Divisibility-guarded sharding: an axis is sharded only when the dimension
divides the mesh extent; otherwise it silently falls back to replication
(e.g. hubert's 504-way vocab on a 16-wide model axis, or batch=1 in
long_500k, whose KV-cache sequence is sharded over ('data','model')=256
instead — sequence-parallel decode).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeConfig
from repro.data.pipeline import batch_spec
from repro.models import init_decode_state, model_init
from repro.models.config import ModelConfig
from repro.train.step import TrainConfig, init_train_state, param_pspec

__all__ = [
    "mesh_extent",
    "guarded",
    "train_specs",
    "decode_specs",
    "prefill_specs",
    "cache_pspec_tree",
]


def mesh_extent(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def guarded(mesh: Mesh, dim: int, axes):
    """axes if dim divides their extent (and extent present), else None."""
    if axes is None:
        return None
    if isinstance(axes, tuple):
        kept = tuple(a for a in axes if a in mesh.axis_names)
    else:
        kept = (axes,) if axes in mesh.axis_names else ()
    if not kept:
        return None
    ext = mesh_extent(mesh, kept)
    if ext <= 1 or dim % ext != 0:
        return None
    return kept if len(kept) > 1 else kept[0]


def _sds_pspec(tree, spec_fn):
    return jax.tree_util.tree_map_with_path(spec_fn, tree)


def _named(tree_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------------------
# parameter / optimizer-state specs (divisibility-guarded variant)
# --------------------------------------------------------------------------


def _guard_pspec(spec: P, shape, mesh: Mesh) -> P:
    return P(*(guarded(mesh, d, s) for d, s in zip(shape, tuple(spec) + (None,) * len(shape))))


def state_specs(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh):
    """(state ShapeDtypeStructs, state PartitionSpecs) for train_step."""
    sds = jax.eval_shape(
        lambda k: init_train_state(k, cfg, tcfg), jax.random.PRNGKey(0)
    )

    from repro.train.step import state_pspec_tree  # local import to avoid cycle

    raw = state_pspec_tree(sds, None, mesh)
    specs = jax.tree_util.tree_map(
        lambda leaf, sp: _guard_pspec(sp, leaf.shape, mesh), sds, raw,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    return sds, specs


def params_specs(cfg: ModelConfig, mesh: Mesh):
    sds = jax.eval_shape(lambda k: model_init(k, cfg), jax.random.PRNGKey(0))
    specs = jax.tree_util.tree_map_with_path(
        lambda path, leaf: _guard_pspec(param_pspec(path, leaf, mesh), leaf.shape, mesh),
        sds,
    )
    return sds, specs


# --------------------------------------------------------------------------
# cache specs for decode
# --------------------------------------------------------------------------


def cache_pspec_tree(cache_sds, cfg: ModelConfig, mesh: Mesh, batch: int):
    """Decode-state shardings. KV caches: (G, B, S, KV, hd) — batch over
    ('pod','data') when divisible, cache *sequence* over 'model'
    (flash-decoding distributed softmax); recurrent states: inner dim on
    'model'."""
    batch_axes = ("pod", "data")

    def spec(path, leaf):
        names = [
            str(
                getattr(k, "key", None)
                or getattr(k, "name", None)
                or getattr(k, "idx", "")
            )
            for k in path
        ]
        shape = leaf.shape  # leading groups dim
        dims = list(shape)
        out = [None] * len(dims)
        field = names[-1] if names else ""
        if field in ("k", "v") and len(dims) == 5:  # (G,B,S,KV,hd)
            out[1] = guarded(mesh, dims[1], batch_axes)
            out[2] = guarded(mesh, dims[2], "model")
            if out[1] is None and out[2] == "model":
                # batch unshardable (e.g. B=1): spread seq over everything
                out[2] = guarded(mesh, dims[2], ("data", "model")) or "model"
        elif field == "h" and len(dims) == 4:  # mamba h: (G,B,di,s)
            out[1] = guarded(mesh, dims[1], batch_axes)
            out[2] = guarded(mesh, dims[2], "model")
        elif field == "conv" and len(dims) == 4:  # (G,B,K-1,di)
            out[1] = guarded(mesh, dims[1], batch_axes)
            out[3] = guarded(mesh, dims[3], "model")
        elif field == "C" and len(dims) == 5:  # mlstm C: (G,B,H,dk,dv)
            out[1] = guarded(mesh, dims[1], batch_axes)
            out[3] = guarded(mesh, dims[3], "model")
        elif field == "n" and len(dims) == 4:  # (G,B,H,dk)
            out[1] = guarded(mesh, dims[1], batch_axes)
            out[3] = guarded(mesh, dims[3], "model")
        elif field in ("c", "h") and len(dims) == 3:  # slstm: (G,B,li)
            out[1] = guarded(mesh, dims[1], batch_axes)
            out[2] = guarded(mesh, dims[2], "model")
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, cache_sds)


# --------------------------------------------------------------------------
# per-cell input specs
# --------------------------------------------------------------------------


def train_specs(cfg: ModelConfig, shape: ShapeConfig, tcfg: TrainConfig, mesh: Mesh):
    """Returns (args_sds, in_shardings, out_shardings_hint) for train_step."""
    state_sds, state_sp = state_specs(cfg, tcfg, mesh)
    b_sds = batch_spec(cfg, shape.global_batch, shape.seq_len)
    b_sp = jax.tree_util.tree_map(
        lambda leaf: P(
            guarded(mesh, leaf.shape[0], ("pod", "data")), *((None,) * (leaf.ndim - 1))
        ),
        b_sds,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    return (state_sds, b_sds), (_named(state_sp, mesh), _named(b_sp, mesh)), _named(state_sp, mesh)


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    p_sds, p_sp = params_specs(cfg, mesh)
    b_sds = batch_spec(cfg, shape.global_batch, shape.seq_len)
    b_sds = {k: v for k, v in b_sds.items() if k in ("tokens", "embeds")}
    b_sp = jax.tree_util.tree_map(
        lambda leaf: P(
            guarded(mesh, leaf.shape[0], ("pod", "data")), *((None,) * (leaf.ndim - 1))
        ),
        b_sds,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    return (p_sds, b_sds), (_named(p_sp, mesh), _named(b_sp, mesh))


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    p_sds, p_sp = params_specs(cfg, mesh)
    cache_sds = jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len)
    )
    cache_sp = cache_pspec_tree(cache_sds, cfg, mesh, shape.global_batch)
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_sp = P(guarded(mesh, shape.global_batch, ("pod", "data")), None)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    return (
        (p_sds, cache_sds, tok_sds, pos_sds),
        (
            _named(p_sp, mesh),
            _named(cache_sp, mesh),
            NamedSharding(mesh, tok_sp),
            NamedSharding(mesh, P()),
        ),
    )
