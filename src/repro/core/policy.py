"""Precision policy: how rr-precision plugs into models and solvers.

The paper's precision adjustment unit is *stateful in time* because hardware
sees one multiplication at a time. A vector machine sees whole tiles, so two
complementary mechanisms cover the same behaviour (DESIGN.md §2):

* **stateless tile selection** (``mode="rr_tile"``): every operand tile gets
  the minimal safe exponent split ``k`` from a max-|x| pre-pass — the
  runtime reconfiguration happens per tile per step, no carried state;
* **tracked selection** (``mode="rr_tracked"``): a :class:`RangeTracker`
  carries an EMA of each site's max exponent across steps (the moral
  equivalent of the hardware unit's persistence, and of AMP loss-scaling
  state), so the split is available *before* the data is seen — this is the
  deployment story, where the format choice must precede the MXU issue.

``mode="deploy"`` runs the arithmetic in bf16 (the MXU-rate proxy for 16-bit
flexible operands — same operand bytes, same issue rate) while still driving
the tracker, so dry-run/roofline numbers reflect what R2F2 silicon would
execute; ``emulate`` modes are bit-exact but slow (numerics studies).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .flexformat import FlexFormat, unbiased_exponent
from .r2f2 import (  # noqa: F401
    _needed_e_bits,
    _needed_e_bits_lo,
    _tile_max_exp,
    op_bounds,
    select_k,
)

__all__ = [
    "PrecisionConfig",
    "KNOWN_MODES",
    "RangeTracker",
    "adjust_step",
    "tracker_init",
    "tracker_observe",
    "tracker_update",
    "tracker_k",
    "evidence_bounds",
    "evidence_k_need",
    "PRESETS",
]

# Modes a PrecisionConfig may carry. The six builtins are listed statically;
# repro.precision.register_engine() extends this set at registration time, so
# third-party engines (fp8, stochastic rounding, ...) become valid modes
# without touching this module.
KNOWN_MODES = {"f32", "bf16", "fixed", "rr_tile", "rr_tracked", "deploy"}


@dataclasses.dataclass(frozen=True)
class PrecisionConfig:
    """Static (hashable — safe as a jit static arg) precision policy.

    mode (each is a registered repro.precision engine):
      "f32"        — reference arithmetic
      "bf16"       — plain mixed precision baseline
      "fixed"      — fixed E(e)M(m) emulation (e.g. E5M10: the paper's
                     failing baseline), ``fixed_em`` below
      "rr_tile"    — R2F2 emulation, per-tile runtime k selection
      "rr_tracked" — R2F2 emulation, k from a (Site)Tracker site
      "deploy"     — bf16 arithmetic + tracker-driven k bookkeeping

    use_kernels: let rr engines dispatch eligible 2-D contractions to the
    Pallas ``r2f2_matmul`` fast path (forward-only; see DESIGN.md §7). The
    policy — not the call site — picks the fast path.
    """

    mode: str = "deploy"
    fmt: FlexFormat = FlexFormat(3, 9, 3)  # the paper's favourite 16-bit config
    fixed_em: Tuple[int, int] = (5, 10)
    tile: int = 128  # tile edge used for per-tile k selection
    tail_approx: bool = True  # paper's flexible-region product approximation
    ema: float = 0.95  # RangeTracker decay
    headroom: int = 1  # extra exponent slack (in powers of 2) for tracked mode
    use_kernels: bool = False  # Pallas fast path for eligible contractions
    #: Freeze the carried split: tracked engines (``rr_tracked``/``deploy``)
    #: neither update the tracker nor widen the live k past it — the run
    #: executes at exactly the per-site k the tracker was initialised with.
    #: This is the *profiled static deployment* emulation (a silicon build
    #: without the adjust unit, configured from a ``repro.profile``
    #: PrecisionPolicy artifact); it also makes policy replays bit-stable.
    pinned: bool = False
    #: Per-site ``(k_lo, k_hi)`` clamps applied by ``tracker_observe`` when
    #: re-picking a site's split — the autotuner's floor/ceiling hints for
    #: ``rr_tracked`` (ordered like the tracker's site rows, normally set via
    #: ``repro.profile.PrecisionPolicy.apply``). None: unconstrained.
    k_bounds: Optional[Tuple[Tuple[int, int], ...]] = None
    #: Pallas kernel block shapes, (bm, bn, bk): the matmul fast path tiles
    #: (bm, bk) x (bk, bn), and elementwise fused kernels (the SWE flux)
    #: tile 2-D fields with (bm, bn) — the policy, not the kernel module,
    #: owns that tiling, so dispatch eligibility and the kernels can never
    #: disagree about blocks. Stencil sweep kernels are exempt: they keep
    #: the coupled extent whole in-block by construction and only ever
    #: block the independent row axis. Shapes that don't divide are padded
    #: and cropped, never rejected.
    kernel_blocks: Tuple[int, int, int] = (128, 128, 128)

    def __post_init__(self):
        if self.mode not in KNOWN_MODES:
            raise ValueError(
                f"unknown precision mode {self.mode!r}; known: {sorted(KNOWN_MODES)} "
                "(register new modes via repro.precision.register_engine)"
            )

    @property
    def is_emulated(self) -> bool:
        from repro.precision.registry import get_engine  # lazy: no import cycle

        return get_engine(self).emulated


PRESETS = {
    "f32": PrecisionConfig(mode="f32"),
    "bf16": PrecisionConfig(mode="bf16"),
    "e5m10": PrecisionConfig(mode="fixed", fixed_em=(5, 10)),
    "e5m9": PrecisionConfig(mode="fixed", fixed_em=(5, 9)),
    "e5m8": PrecisionConfig(mode="fixed", fixed_em=(5, 8)),
    "r2f2_16": PrecisionConfig(mode="rr_tile", fmt=FlexFormat(3, 9, 3)),
    "r2f2_16_384": PrecisionConfig(mode="rr_tile", fmt=FlexFormat(3, 8, 4)),
    "r2f2_15": PrecisionConfig(mode="rr_tile", fmt=FlexFormat(3, 8, 3)),
    "r2f2_14": PrecisionConfig(mode="rr_tile", fmt=FlexFormat(3, 7, 3)),
    "deploy": PrecisionConfig(mode="deploy"),
}


class RangeTracker(NamedTuple):
    """Per-site numeric state (a pytree; thread it like RNG state).

    Arrays are [n_sites]-shaped; model layers under ``scan`` hold their own
    stacked copies (leading layer dim) like any other carried state.
    """

    hi_ema: jnp.ndarray  # f32 — EMA of per-step max needed exponent
    lo_ema: jnp.ndarray  # f32 — EMA of per-step min needed exponent (underflow side)
    k: jnp.ndarray  # int32 — current flexible split per site
    overflow_steps: jnp.ndarray  # int32 — cumulative adjust-up events
    shrink_steps: jnp.ndarray  # int32 — cumulative adjust-down events


def tracker_init(n_sites: int, fmt: FlexFormat, k0=None) -> RangeTracker:
    """Fresh tracker. ``k0`` may be a scalar or an ``(n_sites,)`` array of
    per-site starting splits (e.g. a ``repro.profile`` policy's tuned k);
    default: start wide (safe), shrink via redundancy."""
    k0 = fmt.fx if k0 is None else k0
    return RangeTracker(
        hi_ema=jnp.zeros((n_sites,), jnp.float32),
        lo_ema=jnp.zeros((n_sites,), jnp.float32),
        k=jnp.broadcast_to(jnp.asarray(k0, jnp.int32), (n_sites,)),
        overflow_steps=jnp.zeros((n_sites,), jnp.int32),
        shrink_steps=jnp.zeros((n_sites,), jnp.int32),
    )


def _site_max_exp(x) -> jnp.ndarray:
    mag = jnp.where(jnp.isfinite(x), jnp.abs(x), 0.0)
    return unbiased_exponent(jnp.maximum(jnp.max(mag), jnp.float32(1e-38))).astype(jnp.float32)


def _k_for(hi, lo, fmt: FlexFormat):
    """Split whose format covers the exponent envelope ``[lo, hi]``."""
    e = jnp.maximum(
        _needed_e_bits(hi.astype(jnp.int32), fmt.eb, fmt.fx),
        _needed_e_bits_lo(lo.astype(jnp.int32), fmt.eb, fmt.fx),
    )
    return e - fmt.eb


def evidence_bounds(ae, be, op: str = "mul"):
    """One observation's exponent envelope ``(step_hi, step_lo)``: operand
    cluster tops plus the op's result bound (same derivation as
    :func:`repro.core.r2f2.select_k`, generalized per op by
    :func:`repro.core.r2f2.op_bounds`). Vectorized over evidence arrays."""
    return op_bounds(ae, be, op)


def evidence_k_need(ae, be, cfg: PrecisionConfig, op: str = "mul") -> jnp.ndarray:
    """Instantaneous split one site-level observation ``(ae, be)`` demands
    (headroom included) — the per-issue statistic the tracker grows toward
    and ``repro.profile``'s autotuner derives its floor/ceiling hints from.
    Vectorized: feed the whole captured evidence stream at once."""
    step_hi, step_lo = evidence_bounds(ae, be, op)
    return _k_for(step_hi + cfg.headroom, step_lo - cfg.headroom, cfg.fmt)


def adjust_step(
    k,
    hi_ema,
    lo_ema,
    overflow_steps,
    shrink_steps,
    ae,
    be,
    cfg: PrecisionConfig,
    op: str = "mul",
    k_bounds: Optional[Tuple[int, int]] = None,
):
    """One tick of the paper's adjust unit, in jax-pure scalar-state form:
    fold one operation's operand max-exponent evidence ``(ae, be)`` into a
    single site's carried state and re-pick its split. Grow immediately on
    demand (overflow semantics); shrink only when the EMA shows persistent
    redundancy; count both events (the §5.3 adjustment counters).

    All five state values are scalars (or broadcastable arrays) — no
    ``RangeTracker`` gather/scatter — so the law runs unchanged inside a
    Pallas kernel body where the tracker lives in registers/SMEM and
    evolves on-chip each substep (``repro.kernels.mega``), exactly like
    the hardware unit sitting next to the multiplier. ``k_bounds`` is this
    site's static ``(k_lo, k_hi)`` clamp, or None for unconstrained.

    Returns ``(k, hi_ema, lo_ema, overflow_steps, shrink_steps)`` updated.
    """
    fmt = cfg.fmt
    step_hi, step_lo = evidence_bounds(ae, be, op)

    hi = cfg.ema * hi_ema + (1.0 - cfg.ema) * step_hi
    hi = jnp.maximum(hi, step_hi)  # never smooth away a spike
    lo = cfg.ema * lo_ema + (1.0 - cfg.ema) * step_lo
    lo = jnp.minimum(lo, step_lo)

    k_need_now = _k_for(step_hi + cfg.headroom, step_lo - cfg.headroom, fmt)
    k_need_ema = _k_for(hi + cfg.headroom, lo - cfg.headroom, fmt)
    # grow immediately on demand; shrink only toward the persistent-need EMA
    k_new = jnp.maximum(k_need_now, jnp.minimum(k, k_need_ema))
    if k_bounds is not None:
        # the autotuner's floor/ceiling hints for this site
        k_new = jnp.clip(k_new, k_bounds[0], k_bounds[1])
    grew = (k_new > k).astype(jnp.int32)
    shrank = (k_new < k).astype(jnp.int32)
    return k_new, hi, lo, overflow_steps + grew, shrink_steps + shrank


def tracker_observe(
    state: RangeTracker, site: int, ae, be, cfg: PrecisionConfig, op: str = "mul"
) -> RangeTracker:
    """Fold one operation's operand max-exponent evidence ``(ae, be)``
    into the tracker and re-pick the site's split: gather the site's
    scalar state, apply :func:`adjust_step` (the jax-pure adjust-unit
    law), scatter back. ``op`` picks the envelope law — alignment-shift
    for add, quotient-range for div (see :data:`repro.core.r2f2.OPS`);
    the default keeps the paper's multiply semantics.

    The evidence is exactly what the fused Pallas kernels emit per substep
    (per-site max-exponent reductions, cross-block maxed), so the fused
    execution plane's chunk fold-in, the megakernel's on-chip per-substep
    adjust, and the stepwise ``tracker_update`` apply identical
    adjust-unit math.
    """
    kb = None if cfg.k_bounds is None else cfg.k_bounds[site]
    k_new, hi_ema, lo_ema, ov, sh = adjust_step(
        state.k[site],
        state.hi_ema[site],
        state.lo_ema[site],
        state.overflow_steps[site],
        state.shrink_steps[site],
        ae,
        be,
        cfg,
        op,
        k_bounds=kb,
    )
    return RangeTracker(
        hi_ema=state.hi_ema.at[site].set(hi_ema),
        lo_ema=state.lo_ema.at[site].set(lo_ema),
        k=state.k.at[site].set(k_new),
        overflow_steps=state.overflow_steps.at[site].set(ov),
        shrink_steps=state.shrink_steps.at[site].set(sh),
    )


def tracker_update(
    state: RangeTracker, site: int, a, b, cfg: PrecisionConfig, op: str = "mul"
) -> RangeTracker:
    """Fold the live ranges of an arithmetic site into the tracker
    (reduce the operands to max-exponent evidence, then
    :func:`tracker_observe`)."""
    return tracker_observe(state, site, _site_max_exp(a), _site_max_exp(b), cfg, op)


def tracker_k(state: RangeTracker, site: int) -> jnp.ndarray:
    return state.k[site]
