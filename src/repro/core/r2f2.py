"""R2F2 — the paper's Runtime-ReconFigurable Floating-point multiplier (§4).

Two execution models of the same semantics:

1. ``r2f2_multiply`` — **tile-wise, TPU-native** (DESIGN.md §2): a vector
   machine can scan operand tiles before multiplying, so the hardware's
   "overflow -> grow exponent -> retry" feedback loop collapses into a
   single pre-pass that picks, per tile, the minimal exponent width
   ``k in [0, FX]`` that represents the operands and their products. The
   minimal-k choice subsumes the paper's redundancy rule (a redundant
   exponent is exactly a non-minimal one).

2. ``r2f2_mul_sequential`` — **hardware-faithful state machine**: a
   ``lax.scan`` over a multiplication stream carrying the current split
   ``k``, reproducing the paper's precision adjustment unit (Fig. 5)
   bit-for-bit: on overflow/underflow grow the exponent by one bit and
   *retry* the multiply; when operands and result all show exponent
   redundancy (§4.2's two-bits-after-MSB rule) shrink by one bit. Used to
   reproduce the paper's adjustment-count observations (§5.3).

Both models round products with the paper's flexible-region approximation
(Fig. 4b): only ``FX`` extra bits of the flexible partial products are kept,
which for split ``k`` leaves ``MB + 1 + k`` guard bits below the target
mantissa LSB before the final round-to-nearest-even (see guard-bit derivation
in the docstring of :func:`product_guard_bits`).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .flexformat import (
    FlexFormat,
    exponent_redundant,
    max_normal,
    min_normal,
    quantize_em_with_flags,
    unbiased_exponent,
)

__all__ = [
    "R2F2Stats",
    "product_guard_bits",
    "OPS",
    "op_bounds",
    "select_k",
    "select_k_op",
    "select_k_operand",
    "r2f2_multiply",
    "r2f2_mul_sequential",
    "SequentialState",
]

#: Operations the adjust unit knows an exponent envelope for. ``"mul"`` is
#: the paper's op; the rest generalize the Fig.-5 law to the remaining
#: solver arithmetic (repro.alu): alignment-shift evidence for add/sub,
#: quotient-range evidence for divide, and the halved-exponent envelope for
#: rsqrt.
OPS = ("mul", "add", "div", "rsqrt")


class R2F2Stats(NamedTuple):
    """Diagnostics returned by the tile-wise multiplier."""

    k: jnp.ndarray  # per-tile chosen flexible split
    overflow_count: jnp.ndarray  # elements that still overflow at k (saturated at FX)
    underflow_count: jnp.ndarray  # elements quantized into the subnormal range


def product_guard_bits(fmt: FlexFormat, k) -> jnp.ndarray:
    """Guard bits kept below the result-mantissa LSB under the paper's
    approximation.

    Fig. 4b: the fixed partial product keeps ``2*(MB+1)`` bits and the
    flexible region keeps only ``FX`` extra bits, so the assembled product
    significand has ``2*(MB+1) + FX`` bits. The result mantissa needs
    ``m + 1 = MB + FX - k + 1`` bits, leaving

        guard = (2*MB + 2 + FX) - (MB + FX - k + 1) = MB + 1 + k

    bits before RNE. When ``k = FX`` the full product fits and the
    approximation is exact.
    """
    return fmt.mb + 1 + jnp.asarray(k, jnp.int32)


def _needed_e_bits(max_exp, eb: int, fx: int):
    """Smallest e_bits in [eb, eb+fx] whose emax covers ``max_exp``
    (emax(e) = 2**(e-1) - 1). Saturates at eb+fx like the hardware does
    after exhausting its flexible bits."""
    need = jnp.maximum(max_exp, 0)
    # e such that 2**(e-1) - 1 >= need  <=>  e >= log2(need+1) + 1
    e = jnp.ceil(jnp.log2(need.astype(jnp.float32) + 1.0)).astype(jnp.int32) + 1
    return jnp.clip(e, eb, eb + fx)


def _needed_e_bits_lo(min_exp, eb: int, fx: int):
    """Smallest e_bits in [eb, eb+fx] whose emin reaches DOWN to ``min_exp``
    (emin(e) = 2 - 2**(e-1) <= min_exp), so the value-cluster top stays
    normal instead of flushing — the paper's underflow-adjust trigger."""
    t = jnp.maximum(2 - min_exp, 1).astype(jnp.float32)
    e = jnp.ceil(jnp.log2(t)).astype(jnp.int32) + 1
    return jnp.clip(e, eb, eb + fx)


def select_k(a_max_exp, b_max_exp, fmt: FlexFormat):
    """Minimal flexible split ``k`` such that the operand clusters AND their
    product neither overflow nor underflow in ``E(EB+k)``.

    ``a_max_exp``/``b_max_exp`` are per-tile ``floor(log2(max|.|))`` values
    (int32). Upper bound: the product of values with exponents ea, eb is
    < 2**(ea+eb+2), so covering ``ea+eb+1`` suffices. Lower bound: the
    *cluster tops* (max magnitudes) of both operands and of the product
    (>= 2**(ea+eb)) must stay normal — this reproduces the paper's §3.1
    observation that multiplications with operands < 1e-4 need E6M9 rather
    than E5M10: small operands push the LOW coverage, not the high one.
    Values far below their tile's top are distribution tails (e.g. zero
    crossings) and may flush gradually, as in the hardware.
    """
    hi = jnp.maximum(jnp.maximum(a_max_exp, b_max_exp), a_max_exp + b_max_exp + 1)
    lo = jnp.minimum(jnp.minimum(a_max_exp, b_max_exp), a_max_exp + b_max_exp)
    e = jnp.maximum(
        _needed_e_bits(hi, fmt.eb, fmt.fx), _needed_e_bits_lo(lo, fmt.eb, fmt.fx)
    )
    return e - fmt.eb


def op_bounds(ae, be, op: str = "mul"):
    """Exponent envelope ``(hi, lo)`` an operation on value clusters topped
    at exponents ``(ae, be)`` must cover — the per-op generalization of
    :func:`select_k`'s product bound. All arithmetic is f32 (exact for
    exponent-sized integers), so int32 and f32 evidence agree bit-for-bit.

    mul:   product of tops is < 2**(ae+be+2) and >= 2**(ae+be).
    add:   alignment-shift evidence — the sum's top can carry out one bit
           above the larger operand; cancellation tails flush gradually like
           any distribution tail, so the low side is the smaller operand top.
    div:   quotient-range evidence — |a/b| for cluster tops lies within
           2**(ae-be-1) .. 2**(ae-be+1), and both operands must stay normal.
    rsqrt: unary (callers pass ``be = ae``) — the result exponent is
           ~ -ae/2, so the envelope spans the operand top and the halved,
           negated top on both sides.
    """
    if op not in OPS:
        raise ValueError(f"unknown alu op {op!r}; known: {OPS}")
    ae = jnp.asarray(ae, jnp.float32)
    be = jnp.asarray(be, jnp.float32)
    if op == "mul":
        hi = jnp.maximum(jnp.maximum(ae, be), ae + be + 1)
        lo = jnp.minimum(jnp.minimum(ae, be), ae + be)
    elif op == "add":
        hi = jnp.maximum(ae, be) + 1
        lo = jnp.minimum(ae, be)
    elif op == "div":
        hi = jnp.maximum(jnp.maximum(ae, be), ae - be + 1)
        lo = jnp.minimum(jnp.minimum(ae, be), ae - be - 1)
    else:  # rsqrt
        r_hi = jnp.ceil(-ae / 2.0)
        r_lo = jnp.floor(-(ae + 1.0) / 2.0)
        hi = jnp.maximum(ae, r_hi)
        lo = jnp.minimum(ae, r_lo)
    return hi, lo


def select_k_op(a_max_exp, b_max_exp, fmt: FlexFormat, op: str = "mul"):
    """Minimal flexible split covering one operation's exponent envelope —
    :func:`select_k` generalized over :data:`OPS` via :func:`op_bounds`.
    ``select_k_op(ae, be, fmt, "mul")`` equals ``select_k(ae, be, fmt)``."""
    hi, lo = op_bounds(a_max_exp, b_max_exp, op)
    e = jnp.maximum(
        _needed_e_bits(hi, fmt.eb, fmt.fx), _needed_e_bits_lo(lo, fmt.eb, fmt.fx)
    )
    return e - fmt.eb


def select_k_operand(max_exp, fmt: FlexFormat):
    """Minimal split for a single operand tile: its cluster top must be
    representable as a normal (neither overflow nor flush)."""
    e = jnp.maximum(
        _needed_e_bits(max_exp, fmt.eb, fmt.fx),
        _needed_e_bits_lo(max_exp, fmt.eb, fmt.fx),
    )
    return e - fmt.eb


def _tile_max_exp(x, tile_shape: Optional[Tuple[int, ...]]):
    """Per-tile max unbiased exponent; returns (max_exp_tiles, broadcast_fn).

    ``tile_shape`` of None means one format for the whole array (per-tensor).
    Otherwise x is viewed as tiles of ``tile_shape`` (must divide x.shape)
    and the reduction is per tile; the broadcast_fn expands a per-tile value
    back to elementwise shape.
    """
    finite_mag = jnp.where(jnp.isfinite(x), jnp.abs(x), 0.0)
    if tile_shape is None:
        m = jnp.max(finite_mag)
        return unbiased_exponent(jnp.maximum(m, jnp.float32(1e-45))), (lambda t: t)

    if len(tile_shape) != x.ndim:
        raise ValueError(f"tile_shape rank {len(tile_shape)} != operand rank {x.ndim}")
    for d, t in zip(x.shape, tile_shape):
        if d % t != 0:
            raise ValueError(f"tile {tile_shape} does not divide shape {x.shape}")
    # reshape (d0, d1, ...) -> (d0//t0, t0, d1//t1, t1, ...), reduce tile dims
    split = []
    for d, t in zip(x.shape, tile_shape):
        split += [d // t, t]
    xt = finite_mag.reshape(split)
    red_axes = tuple(range(1, 2 * x.ndim, 2))
    m = jnp.max(xt, axis=red_axes)
    me = unbiased_exponent(jnp.maximum(m, jnp.float32(1e-45)))

    def broadcast(t):
        t = jnp.asarray(t)
        expand = t.reshape(tuple(s for pair in zip(t.shape, (1,) * x.ndim) for s in pair))
        return jnp.broadcast_to(
            expand, tuple(s for pair in zip(t.shape, tile_shape) for s in pair)
        ).reshape(x.shape)

    return me, broadcast


def r2f2_multiply(
    a,
    b,
    fmt: FlexFormat,
    *,
    k=None,
    tile_shape: Optional[Tuple[int, ...]] = None,
    tail_approx: bool = True,
):
    """Tile-wise R2F2 elementwise product emulation.

    a, b: f32 arrays (same shape). ``k``: fixed split, or None to select the
    minimal split per tile (``tile_shape``; None = per-tensor). Returns
    ``(product, R2F2Stats)``. The product is rounded to the runtime format
    with the paper's flexible-region tail approximation when ``tail_approx``.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    if k is None:
        ae, bcast_a = _tile_max_exp(a, tile_shape)
        be, _ = _tile_max_exp(b, tile_shape)
        k_tile = select_k(ae, be, fmt)
        k_full = bcast_a(k_tile)
    else:
        k_tile = jnp.asarray(k, jnp.int32)
        k_full = jnp.broadcast_to(k_tile, a.shape) if k_tile.ndim == 0 else k_tile

    e_bits = fmt.eb + k_full
    m_bits = fmt.mb + fmt.fx - k_full

    qa, oa, ua = quantize_em_with_flags(a, e_bits, m_bits)
    qb, ob, ub = quantize_em_with_flags(b, e_bits, m_bits)
    # Products of <=13-bit significands are exact in f32 (24-bit significand).
    p = qa * qb
    guard = product_guard_bits(fmt, k_full) if tail_approx else None
    qp, op, up = quantize_em_with_flags(p, e_bits, m_bits, tail_trunc_bits=guard)

    stats = R2F2Stats(
        k=k_tile,
        overflow_count=jnp.sum(oa | ob | op),
        underflow_count=jnp.sum(ua | ub | up),
    )
    return qp, stats


# ---------------------------------------------------------------------------
# Hardware-faithful sequential mode (paper Fig. 5 state machine).
# ---------------------------------------------------------------------------


class SequentialState(NamedTuple):
    k: jnp.ndarray  # current flexible split (int32 scalar)
    overflow_adjusts: jnp.ndarray  # times precision was increased (paper §5.3)
    redundancy_adjusts: jnp.ndarray  # times precision was decreased


def sequential_init(fmt: FlexFormat, k0: int = 0) -> SequentialState:
    del fmt
    return SequentialState(
        k=jnp.asarray(k0, jnp.int32),
        overflow_adjusts=jnp.asarray(0, jnp.int32),
        redundancy_adjusts=jnp.asarray(0, jnp.int32),
    )


def _mul_at_k(a, b, fmt: FlexFormat, k, tail_approx: bool):
    e_bits = fmt.eb + k
    m_bits = fmt.mb + fmt.fx - k
    qa, oa, ua = quantize_em_with_flags(a, e_bits, m_bits)
    qb, ob, ub = quantize_em_with_flags(b, e_bits, m_bits)
    p = qa * qb
    guard = product_guard_bits(fmt, k) if tail_approx else None
    qp, op, up = quantize_em_with_flags(p, e_bits, m_bits, tail_trunc_bits=guard)
    fault = oa | ob | op | ua | ub | up
    return qp, fault


def r2f2_mul_sequential(
    a_stream,
    b_stream,
    fmt: FlexFormat,
    *,
    k0: int = 0,
    tail_approx: bool = True,
):
    """Run a stream of scalar multiplications through the paper's adjustment
    unit. Semantics per element (Fig. 5):

      1. multiply at the current split ``k``;
      2. if overflow/underflow occurred: grow the exponent (``k += 1``) and
         retry, up to the FX budget (a ``fori_loop`` over FX retries — the
         hardware re-issues the multiply with the updated mask);
      3. else if BOTH operands and the result show exponent redundancy
         (two-bits-after-MSB rule): shrink the exponent (``k -= 1``) for
         subsequent operations (no retry -- the current result is exact
         enough by construction).

    Returns ``(products, SequentialState)`` with the adjustment counters the
    paper reports (e.g. heat eq: 5 overflow / 23 redundancy in 1.5M muls).
    """
    a_stream = jnp.asarray(a_stream, jnp.float32).reshape(-1)
    b_stream = jnp.asarray(b_stream, jnp.float32).reshape(-1)

    def step(state: SequentialState, ab):
        a, b = ab

        def retry_body(_, carry):
            k, n_up, done = carry
            _, fault = _mul_at_k(a, b, fmt, k, tail_approx)
            grow = fault & (k < fmt.fx) & ~done
            return (
                k + grow.astype(jnp.int32),
                n_up + grow.astype(jnp.int32),
                done | ~fault,
            )

        k, n_up, _ = jax.lax.fori_loop(
            0, fmt.fx + 1, retry_body, (state.k, jnp.asarray(0, jnp.int32), jnp.asarray(False))
        )
        p, _ = _mul_at_k(a, b, fmt, k, tail_approx)

        e_bits = fmt.eb + k
        red = (
            exponent_redundant(a, e_bits)
            & exponent_redundant(b, e_bits)
            & exponent_redundant(p, e_bits)
            & (k > 0)
            & (n_up == 0)
        )
        new_state = SequentialState(
            k=k - red.astype(jnp.int32),
            overflow_adjusts=state.overflow_adjusts + n_up,
            redundancy_adjusts=state.redundancy_adjusts + red.astype(jnp.int32),
        )
        return new_state, p

    init = sequential_init(fmt)
    final_state, products = jax.lax.scan(step, init, (a_stream, b_stream))
    return products, final_state
