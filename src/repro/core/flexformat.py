"""Flexible floating-point formats (paper §4.1, Fig. 4a).

A FlexFormat ``<EB, MB, FX>`` is a fixed-total-bitwidth floating point layout:

    [ 1 sign | EB fixed exponent | MB fixed mantissa | FX flexible bits ]

At runtime, ``k`` of the FX flexible bits are allocated to the exponent and
``FX - k`` to the mantissa (mask bits in hardware), yielding an effective
IEEE-style binary format ``E(EB+k) M(MB+FX-k)`` with

    bias       = 2**(e-1) - 1
    emax       = 2**(e-1) - 1          (all-ones biased exponent reserved)
    emin       = 2 - 2**(e-1)          (minimum normal exponent)
    subnormals supported, signed zero, overflow -> +-inf.

These conventions exactly reproduce the paper's examples: E5M10's largest
value is 65504 = 2**15 * (2 - 2**-10), and <3,8,4> with all flexible bits on
the exponent (k=4 -> E7M8) represents up to 2**63 * (1 + 255/256) ~= 1.84e19.

Everything in this module is pure-jnp, bit-exact (round-to-nearest-even via
integer arithmetic on the f32 encoding), and fully vectorized, so it can be
used inside jit/pjit/Pallas and is the ground-truth oracle for the kernels.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FlexFormat",
    "quantize_em",
    "quantize_em_with_flags",
    "quantize_product",
    "max_normal",
    "min_normal",
    "min_subnormal",
    "exponent_bias",
    "unbiased_exponent",
    "exponent_redundant",
    "pack_r2f2",
    "unpack_r2f2",
    "E5M10",
    "E5M9",
    "E5M8",
    "E8M23",
]

_F32_MANT_BITS = 23
_F32_EXP_BITS = 8
_F32_BIAS = 127
_U32_ABS_MASK = np.uint32(0x7FFFFFFF)
_U32_SIGN_MASK = np.uint32(0x80000000)


@dataclasses.dataclass(frozen=True)
class FlexFormat:
    """The paper's ``<EB, MB, FX>`` flexible format descriptor."""

    eb: int  # fixed exponent bits
    mb: int  # fixed mantissa bits
    fx: int  # flexible bits (runtime-assignable to exponent or mantissa)

    def __post_init__(self):
        if self.eb < 2:
            raise ValueError("need >=2 fixed exponent bits")
        if self.mb < 1:
            raise ValueError("need >=1 fixed mantissa bits")
        if self.fx < 0:
            raise ValueError("FX must be >= 0")
        if self.eb + self.fx > _F32_EXP_BITS:
            raise ValueError("exponent cannot exceed f32's 8 bits (emulation substrate)")
        if self.mb + self.fx > _F32_MANT_BITS:
            raise ValueError("mantissa cannot exceed f32's 23 bits (emulation substrate)")

    @property
    def total_bits(self) -> int:
        return 1 + self.eb + self.mb + self.fx

    def em(self, k) -> Tuple[int, int]:
        """Effective (exponent_bits, mantissa_bits) when ``k`` flex bits go to exponent."""
        return self.eb + k, self.mb + self.fx - k

    def k_range(self):
        return 0, self.fx

    def __str__(self) -> str:  # paper notation
        return f"<{self.eb},{self.mb},{self.fx}>"


# Fixed IEEE-style formats used as baselines in the paper (FX = 0).
E5M10 = FlexFormat(5, 10, 0)  # standard half
E5M9 = FlexFormat(5, 9, 0)  # 15-bit fixed
E5M8 = FlexFormat(5, 8, 0)  # 14-bit fixed
E8M23 = FlexFormat(8, 23, 0)  # f32 itself (identity quantization)


def exponent_bias(e_bits) -> jnp.ndarray:
    return (1 << (jnp.asarray(e_bits, jnp.int32) - 1)) - 1


def _emax(e_bits):
    # All-ones biased exponent reserved for inf/nan (IEEE convention; matches
    # the paper's 65504 / 1.84e19 examples).
    return (1 << (jnp.asarray(e_bits, jnp.int32) - 1)) - 1


def _emin(e_bits):
    return 2 - (1 << (jnp.asarray(e_bits, jnp.int32) - 1))


def max_normal(e_bits, m_bits) -> jnp.ndarray:
    """Largest finite value of E(e)M(m), as f32."""
    return _scale_pow2(2.0 - _pow2(-jnp.asarray(m_bits, jnp.int32)), _emax(e_bits))


def min_normal(e_bits) -> jnp.ndarray:
    return _pow2(_emin(e_bits))


def min_subnormal(e_bits, m_bits) -> jnp.ndarray:
    return _pow2(_emin(e_bits) - jnp.asarray(m_bits, jnp.int32))


def _bits(x):
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.uint32)


def _from_bits(u):
    return jax.lax.bitcast_convert_type(jnp.asarray(u, jnp.uint32), jnp.float32)


def _pow2(n):
    """Exact 2**n as f32 for integer n in [-149, 127], via bit construction.

    (XLA lowers jnp.exp2 to exp(x*ln2) on CPU which is NOT exact for integer
    powers -- exactness here is load-bearing for bit-exact quantization.)
    """
    n = jnp.asarray(n, jnp.int32)
    normal = _from_bits((jnp.clip(n, -126, 127) + 127).astype(jnp.uint32) << _F32_MANT_BITS)
    sub_shift = jnp.clip(n + 149, 0, _F32_MANT_BITS).astype(jnp.uint32)
    sub = _from_bits(jnp.uint32(1) << sub_shift)
    return jnp.where(n >= -126, normal, sub)


def _scale_pow2(x, n):
    """Exact x * 2**n in (up to) two exact power-of-two multiplies, valid for
    |n| <= 254 as long as the final result is representable."""
    n = jnp.asarray(n, jnp.int32)
    h1 = jnp.clip(n, -126, 127)
    return x * _pow2(h1) * _pow2(n - h1)


def unbiased_exponent(x) -> jnp.ndarray:
    """floor(log2(|x|)) for normal f32 inputs, via bit extraction (int32)."""
    u = _bits(x) & _U32_ABS_MASK
    return (u >> _F32_MANT_BITS).astype(jnp.int32) - _F32_BIAS


def _round_mantissa_rne(u_abs, m_bits):
    """RNE-round the f32 encoding ``u_abs`` (sign stripped) to ``m_bits`` of
    mantissa. Integer trick: the carry out of the mantissa propagates into the
    exponent field automatically, which is exactly IEEE behaviour."""
    shift = _F32_MANT_BITS - jnp.asarray(m_bits, jnp.uint32)
    one = jnp.uint32(1)
    half = (one << shift) >> 1  # 2**(shift-1); 0 when shift == 0
    lsb = (u_abs >> shift) & one
    rounded = u_abs + jnp.where(shift > 0, half - one + lsb, jnp.uint32(0))
    return rounded & ~((one << shift) - one)


def quantize_em_with_flags(x, e_bits, m_bits, tail_trunc_bits=None):
    """Bit-exact RNE quantization of f32 ``x`` to E(e)M(m).

    ``e_bits``/``m_bits`` may be scalars or arrays broadcastable against ``x``
    (per-tile formats). Returns ``(y, overflow, underflow)`` where

      overflow : |x| rounds above max_normal  -> y = +-inf  (hardware raises
                 the adjust-up signal, paper Fig. 5)
      underflow: x != 0 but |x| lands in the subnormal/zero range of the
                 target format (gradual precision loss; also an adjust-up
                 trigger in the paper's unit).

    ``tail_trunc_bits``: if set to ``t``, the mantissa is first truncated
    (toward zero) to ``m_bits + t`` fractional bits before the final RNE
    rounding. This models the paper's flexible-region product approximation
    ("only keep FX extra bits and eliminate the computation after that",
    §4.1): partial products below the FX guard region are dropped.

    Note: XLA CPU runs with DAZ/FTZ for f32 subnormals, so inputs with
    |x| < 2**-126 are explicitly treated as (signed) zero here for
    self-consistency. This is invisible for every format with e_bits <= 8
    whose own subnormals are f32-normal (all the paper's <=16-bit formats).
    """
    x = jnp.asarray(x, jnp.float32)
    e_bits = jnp.asarray(e_bits, jnp.int32)
    m_bits = jnp.asarray(m_bits, jnp.int32)

    u = _bits(x)
    sign = u & _U32_SIGN_MASK
    u_abs = u & _U32_ABS_MASK
    # Explicit DAZ (see docstring): zero the magnitude of f32 subnormals.
    u_abs = jnp.where((u_abs >> _F32_MANT_BITS) == 0, jnp.uint32(0), u_abs)

    is_nan = jnp.isnan(x)
    is_inf = jnp.isinf(x)

    if tail_trunc_bits is not None:
        # Drop everything below m+t fractional mantissa bits (truncate toward
        # zero on the magnitude) -- the hardware never computes those partial
        # products. Only affects normals; the subnormal path re-derives from
        # the truncated value as the hardware rounds from its res register.
        t = jnp.asarray(tail_trunc_bits, jnp.int32)
        keep = jnp.clip(m_bits + t, 1, _F32_MANT_BITS)
        tshift = (_F32_MANT_BITS - keep).astype(jnp.uint32)
        u_abs = u_abs & ~((jnp.uint32(1) << tshift) - jnp.uint32(1))

    # --- normal path: RNE mantissa rounding with natural exponent carry.
    r = _round_mantissa_rne(u_abs, m_bits)
    r_exp = (r >> _F32_MANT_BITS).astype(jnp.int32) - _F32_BIAS

    emax = _emax(e_bits)
    emin = _emin(e_bits)

    overflow = (r_exp > emax) & ~is_nan
    y_norm = _from_bits(sign | r)

    # --- subnormal path: single-rounding from the (possibly tail-truncated)
    # original magnitude. |x| < 2**emin  =>  x / 2**(emin-m) < 2**m <= 2**23,
    # so the scaled value is exactly representable and jnp.round (RNE) gives
    # the correctly-rounded subnormal.
    x_mag = _from_bits(u_abs)
    sub_ulp_exp = emin - m_bits
    scaled = _scale_pow2(x_mag, -sub_ulp_exp)
    y_sub_mag = _scale_pow2(jnp.round(scaled), sub_ulp_exp)
    y_sub = jnp.where(sign != 0, -y_sub_mag, y_sub_mag)

    x_exp = (u_abs >> _F32_MANT_BITS).astype(jnp.int32) - _F32_BIAS
    in_sub_range = (x_exp < emin) & (u_abs != 0)
    # After RNE the subnormal may round up to min_normal; that is fine (it is
    # representable) but it is no longer an underflow event.
    rounded_to_normal = jnp.abs(y_sub) >= _pow2(emin)

    y = jnp.where(in_sub_range, y_sub, y_norm)
    inf = _from_bits(sign | jnp.uint32(0x7F800000))
    y = jnp.where(overflow | is_inf, inf, y)
    y = jnp.where(is_nan, x, y)
    y = jnp.where(u_abs == 0, _from_bits(sign), y)  # signed zero passthrough

    underflow = in_sub_range & ~rounded_to_normal & ~is_nan
    overflow = overflow | (is_inf & ~is_nan)
    return y, overflow, underflow


def quantize_em(x, e_bits, m_bits, tail_trunc_bits=None):
    """Value-only variant of :func:`quantize_em_with_flags`."""
    return quantize_em_with_flags(x, e_bits, m_bits, tail_trunc_bits)[0]


def quantize_product(p, e_bits, m_bits, fx_guard_bits):
    """Round an exact f32 product to E(e)M(m) with the paper's FX-tail
    truncation approximation (§4.1, Fig. 4b)."""
    return quantize_em_with_flags(p, e_bits, m_bits, tail_trunc_bits=fx_guard_bits)


def exponent_redundant(x, e_bits):
    """The paper's redundancy detector (§4.2): in the biased exponent of
    ``x`` under an ``e_bits``-wide exponent, the two bits following the MSB
    both being the complement of the MSB indicates the exponent field is
    wider than needed and one flexible bit can be returned to the mantissa.

    Example (paper): 8-bit biased exponent 10000111 (2**8) has MSB=1 followed
    by 00 -> redundant; representable in 5 bits as 10111.
    """
    x = jnp.asarray(x, jnp.float32)
    e_bits = jnp.asarray(e_bits, jnp.int32)
    ue = unbiased_exponent(x)
    biased = ue + exponent_bias(e_bits)  # value in [0, 2**e) for in-range x
    msb = (biased >> (e_bits - 1)) & 1
    b1 = (biased >> (e_bits - 2)) & 1
    b2 = jnp.where(e_bits >= 3, (biased >> (e_bits - 3)) & 1, 1 - msb)
    nz = jnp.abs(x) > 0
    return nz & (b1 == 1 - msb) & (b2 == 1 - msb)


# ---------------------------------------------------------------------------
# Bit-level packing of the storage layout (Fig. 4a): sign | exp | mantissa in
# ``1 + EB + MB + FX`` bits, plus the k (mask) metadata kept out-of-band.
# Used by property tests to prove the emulation matches the storage format.
# ---------------------------------------------------------------------------


def pack_r2f2(x, fmt: FlexFormat, k):
    """Encode quantized f32 values into the ``total_bits``-wide integer
    payload for format ``fmt`` at flex split ``k``. Assumes ``x`` is already
    representable (i.e. output of quantize_em for the same (e, m))."""
    e_bits = fmt.eb + jnp.asarray(k, jnp.int32)
    m_bits = fmt.mb + fmt.fx - jnp.asarray(k, jnp.int32)
    x = jnp.asarray(x, jnp.float32)
    u = _bits(x)
    sign = (u >> 31).astype(jnp.uint32)
    f32_exp = ((u & _U32_ABS_MASK) >> _F32_MANT_BITS).astype(jnp.int32)
    mant32 = (u & jnp.uint32((1 << _F32_MANT_BITS) - 1)).astype(jnp.uint32)

    bias = exponent_bias(e_bits)
    emin = _emin(e_bits)
    unb = f32_exp - _F32_BIAS

    is_zero = (u & _U32_ABS_MASK) == 0
    is_inf = jnp.isinf(x)
    is_nan = jnp.isnan(x)
    is_sub = (~is_zero) & (unb < emin)

    mshift = (_F32_MANT_BITS - m_bits).astype(jnp.uint32)
    mant_norm = (mant32 >> mshift).astype(jnp.uint32)
    # subnormal: value = 0.mant * 2**emin -> mantissa field = round(|x| / 2**(emin-m))
    sub_field = jnp.round(_scale_pow2(jnp.abs(x), -(emin - m_bits)))
    mant_sub = sub_field.astype(jnp.uint32)

    exp_field = jnp.where(is_sub | is_zero, 0, unb + bias).astype(jnp.uint32)
    exp_field = jnp.where(is_inf | is_nan, ((1 << e_bits) - 1).astype(jnp.uint32), exp_field)
    mant_field = jnp.where(is_sub, mant_sub, jnp.where(is_zero | is_inf, 0, mant_norm))
    mant_field = jnp.where(is_nan, jnp.uint32(1) << (m_bits - 1).astype(jnp.uint32), mant_field)

    payload = (
        (sign << (e_bits + m_bits).astype(jnp.uint32))
        | (exp_field << m_bits.astype(jnp.uint32))
        | mant_field
    )
    return payload.astype(jnp.uint32)


def unpack_r2f2(payload, fmt: FlexFormat, k):
    """Decode :func:`pack_r2f2` payloads back to f32."""
    e_bits = fmt.eb + jnp.asarray(k, jnp.int32)
    m_bits = fmt.mb + fmt.fx - jnp.asarray(k, jnp.int32)
    payload = jnp.asarray(payload, jnp.uint32)

    one = jnp.uint32(1)
    m_mask = (one << m_bits.astype(jnp.uint32)) - one
    e_mask = (one << e_bits.astype(jnp.uint32)) - one
    mant = (payload & m_mask).astype(jnp.float32)
    expf = ((payload >> m_bits.astype(jnp.uint32)) & e_mask).astype(jnp.int32)
    sign = (payload >> (e_bits + m_bits).astype(jnp.uint32)) & one

    bias = exponent_bias(e_bits)
    emin = _emin(e_bits)
    m_f = m_bits.astype(jnp.float32)

    is_sub = expf == 0
    is_special = expf == ((one << e_bits.astype(jnp.uint32)) - one).astype(jnp.int32)
    del m_f

    mag_norm = _scale_pow2(1.0 + mant * _pow2(-m_bits), expf - bias)
    mag_sub = _scale_pow2(mant, emin - m_bits)
    mag = jnp.where(is_sub, mag_sub, mag_norm)
    mag = jnp.where(is_special, jnp.where(mant == 0, jnp.inf, jnp.nan), mag)
    return jnp.where(sign == 1, -mag, mag).astype(jnp.float32)
