"""repro.core — the paper's contribution: runtime-reconfigurable FP precision.

Layers:
  flexformat — the <EB, MB, FX> format family + bit-exact quantization
  r2f2       — the reconfigurable multiplier (tile-wise + sequential-faithful)
  policy     — PrecisionConfig / RangeTracker (when & how to reconfigure)
  rr_dot     — einsum/dot wrappers every model matmul routes through
"""

from .flexformat import (
    E5M8,
    E5M9,
    E5M10,
    E8M23,
    FlexFormat,
    exponent_redundant,
    max_normal,
    min_normal,
    min_subnormal,
    pack_r2f2,
    quantize_em,
    quantize_em_with_flags,
    quantize_product,
    unbiased_exponent,
    unpack_r2f2,
)
from .policy import (
    PRESETS,
    PrecisionConfig,
    RangeTracker,
    adjust_step,
    tracker_init,
    tracker_k,
    tracker_update,
)
from .r2f2 import (
    OPS,
    R2F2Stats,
    SequentialState,
    op_bounds,
    product_guard_bits,
    r2f2_mul_sequential,
    r2f2_multiply,
    select_k,
    select_k_op,
    select_k_operand,
)
from .rr_dot import rr_dot, rr_einsum, rr_operand
