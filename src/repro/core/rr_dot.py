"""rr-precision matmul/einsum wrappers — the single integration point
between the paper's numeric substrate and every model in the framework.

All dense compute in ``repro.models`` and the PDE solvers routes through
:func:`rr_einsum` / :func:`rr_dot`. The :class:`PrecisionConfig` decides what
actually happens to the operands (see policy.py). Quantization is
elementwise, so operand preparation composes with any contraction; the
product itself accumulates in f32 (``preferred_element_type``), matching both
the paper's multiplier (whose result register is wider than the operands) and
MXU semantics (bf16 operands, f32 accumulate).

Same-format constraint: the paper requires both operands of one multiply to
share a format. ``rr_einsum(shared_k=True)`` enforces one k per contraction
(the max of both operands' needs — what the sequential hardware converges
to); ``shared_k=False`` lets each operand tile carry its own split, which is
the natural generalisation on a machine with per-tile metadata (noted as a
deliberate extension in DESIGN.md §8; the Pallas matmul kernel implements the
faithful per-block-pair shared k).
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .flexformat import quantize_em_with_flags
from .policy import PrecisionConfig, RangeTracker, tracker_k, tracker_update
from .r2f2 import _tile_max_exp, select_k, select_k_operand

__all__ = ["rr_operand", "rr_einsum", "rr_dot"]


def _native_bf16() -> bool:
    """Keep operands in native bf16 inside contractions?

    True on TPU (MXU semantics) and for compile-only dry-runs
    (REPRO_NATIVE_BF16=1 — accurate HLO byte accounting). False on CPU
    execution paths: XLA:CPU cannot execute batched bf16xbf16->f32 dots, and
    casting the rounded operands back to f32 is value-identical to an MXU's
    exact-product/f32-accumulate anyway.
    """
    env = os.environ.get("REPRO_NATIVE_BF16")
    if env is not None:
        return env == "1"
    return jax.default_backend() == "tpu"


def _bf16_pair(a, b):
    a = a.astype(jnp.bfloat16)
    b = b.astype(jnp.bfloat16)
    if not _native_bf16():
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
    return a, b


def _tile_shape_for(x, tile: int) -> Optional[Tuple[int, ...]]:
    """Tiles of ``tile`` on the last two dims (1 elsewhere) when divisible;
    per-tensor fallback otherwise."""
    if x.ndim == 0:
        return None
    shape = [1] * x.ndim
    for ax in range(max(0, x.ndim - 2), x.ndim):
        shape[ax] = tile if x.shape[ax] % tile == 0 else x.shape[ax]
    return tuple(shape)


def _ste(x, xq):
    """Straight-through estimator: bit-exact quantized forward, identity
    backward — the emulation's integer ops are non-differentiable, and STE
    is the standard QAT contract for training through quantizers."""
    return x + jax.lax.stop_gradient(xq - x)


def rr_operand(x, cfg: PrecisionConfig, *, k=None):
    """Quantize one operand according to the policy. Returns (x_q, k_tile).

    For "rr_tile" with ``k=None`` the split is selected per tile from the
    live data (the runtime reconfiguration). A provided ``k`` (from a
    tracker or a shared-k contraction) overrides selection. Emulated modes
    are differentiable via STE.
    """
    x = jnp.asarray(x, jnp.float32)
    fmt = cfg.fmt
    if cfg.mode == "f32":
        return x, None
    if cfg.mode in ("bf16", "deploy"):
        return x.astype(jnp.bfloat16).astype(jnp.float32), None
    if cfg.mode == "fixed":
        e, m = cfg.fixed_em
        return _ste(x, quantize_em_with_flags(x, e, m)[0]), None

    # rr_tile / rr_tracked emulation
    if k is None:
        me, bcast = _tile_max_exp(x, _tile_shape_for(x, cfg.tile))
        k = select_k_operand(me, fmt)  # operand-range-only need
        k_full = bcast(k)
    else:
        k = jnp.asarray(k, jnp.int32)
        if k.ndim == 0:
            k_full = k
        else:
            _, bcast = _tile_max_exp(x, _tile_shape_for(x, cfg.tile))
            k_full = bcast(k)
    e_bits = fmt.eb + k_full
    m_bits = fmt.mb + fmt.fx - k_full
    xq, _, _ = quantize_em_with_flags(x, e_bits, m_bits)
    return _ste(x, xq), k


def _shared_k(a, b, cfg: PrecisionConfig):
    """One split per contraction: max need across both whole operands plus
    the product bound (paper's same-format rule)."""
    ae, _ = _tile_max_exp(a, None)
    be, _ = _tile_max_exp(b, None)
    return select_k(ae, be, cfg.fmt)


def rr_einsum(
    spec: str,
    a,
    b,
    cfg: PrecisionConfig,
    *,
    tracker: Optional[RangeTracker] = None,
    site: Optional[int] = None,
    shared_k: bool = False,
):
    """Einsum with rr-precision operand treatment.

    Returns ``out`` (and the updated tracker when one is passed:
    ``(out, tracker)``). f32 accumulation always.
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)

    if cfg.mode == "f32":
        out = jnp.einsum(spec, a.astype(jnp.float32), b.astype(jnp.float32))
        return (out, tracker) if tracker is not None else out

    if cfg.mode in ("bf16", "deploy"):
        aq, bq = _bf16_pair(a, b)
        out = jnp.einsum(spec, aq, bq, preferred_element_type=jnp.float32)
        if tracker is not None and cfg.mode == "deploy" and site is not None:
            tracker = tracker_update(tracker, site, a, b, cfg)
            return out, tracker
        return (out, tracker) if tracker is not None else out

    if cfg.mode == "fixed":
        e, m = cfg.fixed_em
        af = a.astype(jnp.float32)
        bf = b.astype(jnp.float32)
        aq = _ste(af, quantize_em_with_flags(af, e, m)[0])
        bq = _ste(bf, quantize_em_with_flags(bf, e, m)[0])
        out = jnp.einsum(spec, aq, bq)
        return (out, tracker) if tracker is not None else out

    # --- emulated rr modes ---
    k = None
    if cfg.mode == "rr_tracked":
        if tracker is None or site is None:
            raise ValueError("rr_tracked needs tracker+site")
        k = tracker_k(tracker, site)
        tracker = tracker_update(tracker, site, a, b, cfg)
    elif shared_k:
        k = _shared_k(a.astype(jnp.float32), b.astype(jnp.float32), cfg)

    aq, _ = rr_operand(a, cfg, k=k)
    bq, _ = rr_operand(b, cfg, k=k)
    out = jnp.einsum(spec, aq, bq, preferred_element_type=jnp.float32)
    return (out, tracker) if tracker is not None else out


def rr_dot(x, w, cfg: PrecisionConfig, **kw):
    """Dense-layer contraction: last dim of ``x`` against first of ``w``."""
    n = x.ndim
    lhs = "".join(chr(ord("a") + i) for i in range(n - 1)) + "z"
    rhs_extra = "".join(chr(ord("m") + i) for i in range(w.ndim - 1))
    spec = f"{lhs},z{rhs_extra}->{lhs[:-1]}{rhs_extra}"
    return rr_einsum(spec, x, w, cfg, **kw)
