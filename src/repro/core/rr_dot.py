"""Backward-compatible shims over the ``repro.precision`` engine API.

Historically this module *was* the integration point between the paper's
numeric substrate and every model: it held the per-mode dispatch chains for
operand prep and contractions. That logic now lives in
``repro.precision.engines`` (one engine per mode, registry-dispatched —
DESIGN.md §4); these wrappers exist so the original call-site surface keeps
working unchanged:

    rr_operand(x, cfg)            == repro.precision.prepare_operand(x, cfg)
    rr_einsum(spec, a, b, cfg)    == repro.precision.contract(spec, a, b, cfg)
    rr_dot(x, w, cfg)             == repro.precision.dot(x, w, cfg)

Return contract (now uniform across modes, fixing the historical
inconsistency): ``rr_einsum``/``rr_dot`` return ``out`` when no tracker is
passed and ``(out, tracker)`` whenever one is — for every mode. ``site``
accepts the legacy integer index or a named site string when ``tracker`` is
a :class:`repro.precision.SiteTracker`.

Imports are function-local: ``repro.core`` must stay importable without
pulling the engine package (which imports back into core).
"""

from __future__ import annotations

from typing import Optional

__all__ = ["rr_operand", "rr_einsum", "rr_dot"]


def rr_operand(x, cfg, *, k=None):
    """Quantize one operand according to the policy. Returns (x_q, k_tile).

    For "rr_tile" with ``k=None`` the split is selected per tile from the
    live data (the runtime reconfiguration). A provided ``k`` (from a
    tracker or a shared-k contraction) overrides selection. Emulated modes
    are differentiable via STE.
    """
    from repro.precision import prepare_operand

    return prepare_operand(x, cfg, k=k)


def rr_einsum(
    spec: str,
    a,
    b,
    cfg,
    *,
    tracker=None,
    site=None,
    shared_k: bool = False,
):
    """Einsum with rr-precision operand treatment.

    Returns ``out`` (and the updated tracker when one is passed:
    ``(out, tracker)``). f32 accumulation always.
    """
    from repro.precision import contract

    return contract(spec, a, b, cfg, tracker=tracker, site=site, shared_k=shared_k)


def rr_dot(x, w, cfg, **kw):
    """Dense-layer contraction: last dim of ``x`` against first of ``w``."""
    from repro.precision import dot

    return dot(x, w, cfg, **kw)
