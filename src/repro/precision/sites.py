"""Named multiplication sites: string keys over the RangeTracker pytree.

The paper's precision adjustment unit is *per multiplier instance*; model
code used to identify its multipliers by hand-numbered integers
(``site=0, 1, ...``), which is exactly as brittle as it sounds — insert one
matmul and every later index shifts. A :class:`SiteTracker` owns the
name -> row mapping: the names are static pytree metadata (so a SiteTracker
jits, scans, and checkpoints like any other carried state — the site
strings never become tracers), and the numeric state is the existing
:class:`repro.core.policy.RangeTracker` verbatim.

Naming convention (DESIGN.md §3): ``"<subsystem>.<op>"`` —
``"attn.qk"``, ``"mlp.down"``, ``"heat.flux"``, ``"swe.q3q3"``. Engines
resolve either form through :func:`resolve_site`, so legacy
``(RangeTracker, int)`` callers keep working unchanged.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax

from repro.core.policy import RangeTracker, tracker_init
from repro.core.flexformat import FlexFormat

__all__ = ["SiteTracker", "site_tracker_init", "resolve_site"]


@jax.tree_util.register_pytree_node_class
class SiteTracker:
    """A RangeTracker whose rows are addressed by name.

    ``names`` is aux (static) data: two SiteTrackers with different site
    lists are different pytree types, which is what you want — a scan carry
    can never silently re-number its sites.
    """

    def __init__(self, names: Tuple[str, ...], state: RangeTracker):
        self.names = tuple(names)
        self.state = state
        if len(self.names) != len(set(self.names)):
            raise ValueError(f"duplicate site names: {self.names}")

    # -- pytree protocol ----------------------------------------------------

    def tree_flatten(self):
        return (self.state,), self.names

    @classmethod
    def tree_unflatten(cls, names, children):
        (state,) = children
        obj = object.__new__(cls)  # skip __init__ checks on trace-time rebuilds
        obj.names = names
        obj.state = state
        return obj

    # -- site addressing ----------------------------------------------------

    def index(self, name: str) -> int:
        try:
            return self.names.index(name)
        except ValueError:
            raise KeyError(
                f"unknown precision site {name!r}; tracked sites: {self.names}"
            ) from None

    def k(self, name: str):
        """Current flexible split for a named site."""
        return self.state.k[self.index(name)]

    def with_state(self, state: RangeTracker) -> "SiteTracker":
        return SiteTracker(self.names, state)

    def __len__(self) -> int:
        return len(self.names)

    def __repr__(self) -> str:
        return f"SiteTracker(sites={list(self.names)})"


def site_tracker_init(names: Sequence[str], fmt: FlexFormat, k0=None) -> SiteTracker:
    """Fresh tracker with one row per named site. ``k0``: scalar or per-site
    array of starting splits (default: start wide, shrink via redundancy —
    same convention as :func:`repro.core.policy.tracker_init`)."""
    return SiteTracker(tuple(names), tracker_init(len(names), fmt, k0=k0))


def resolve_site(tracker, site) -> Tuple[Optional[RangeTracker], Optional[int]]:
    """Normalize (tracker, site) to the raw ``(RangeTracker, int)`` engines
    consume. Accepts:

      * ``(SiteTracker, "name")``  — the named-site API;
      * ``(RangeTracker, int)``    — the legacy hand-numbered API;
      * ``(None, anything)``       — untracked call (site names are allowed
        and simply ignored, so call sites can document their site name
        whether or not a tracker is threaded).
    """
    if tracker is None:
        return None, None
    if isinstance(tracker, SiteTracker):
        if site is None:
            return tracker.state, None
        return tracker.state, tracker.index(site) if isinstance(site, str) else int(site)
    if isinstance(site, str):
        raise TypeError(
            f"named site {site!r} needs a SiteTracker; got {type(tracker).__name__} "
            "(wrap it with SiteTracker(names, state))"
        )
    return tracker, site


def rewrap(tracker, state: Optional[RangeTracker]):
    """Re-attach updated numeric state to the caller's tracker container."""
    if state is None or tracker is None:
        return tracker
    if isinstance(tracker, SiteTracker):
        return tracker.with_state(state)
    return state
