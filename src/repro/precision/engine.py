"""The PrecisionEngine protocol + numeric helpers shared by engines.

An engine is the *whole* answer to "what does this policy do to arithmetic":

    prepare_operand(x, cfg, *, k=None) -> (x_q, k)   one operand, policy-rounded
    multiply(a, b, cfg, *, tracker, site)            elementwise product
    add(a, b, cfg, *, tracker, site)                 elementwise sum (repro.alu)
    divide(a, b, cfg, *, tracker, site)              elementwise quotient
    rsqrt(x, cfg, *, tracker, site)                  elementwise 1/sqrt
    store(x, cfg)                                    state write-back rounding
    contract(spec, a, b, cfg, *, tracker, site, shared_k)
                                                     einsum with policy operands

``contract`` and every elementwise op ALWAYS return ``(out, tracker)`` —
tracker is passed through unchanged by engines that do not track (the old
``rr_einsum`` sometimes returned a bare array, sometimes a tuple; the engine
layer is where that contract is now uniform). Tracked engines fold each
op's evidence under its own envelope law (``op="add"``/``"div"``/
``"rsqrt"`` in :func:`repro.core.policy.tracker_observe`). ``tracker`` may be a raw
:class:`repro.core.policy.RangeTracker` with an integer ``site`` (legacy) or
a :class:`repro.precision.sites.SiteTracker` with a *named* site
(``site="attn.qk"``) — resolution is handled once, in
:func:`repro.precision.sites.resolve_site`.

The base class implements every method generically on top of
``prepare_operand`` + f32 accumulation, so a new engine (fp8, stochastic
rounding, ...) is usually ``prepare_operand`` + ``register_engine`` and
nothing else.

Helpers here are verbatim moves from the pre-engine ``core/rr_dot.py`` —
their numerics are load-bearing (bit-exactness tests compare against them).
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["PrecisionEngine", "native_bf16", "bf16_pair", "tile_shape_for", "ste"]


def native_bf16() -> bool:
    """Keep operands in native bf16 inside contractions?

    True on TPU (MXU semantics) and for compile-only dry-runs
    (REPRO_NATIVE_BF16=1 — accurate HLO byte accounting). False on CPU
    execution paths: XLA:CPU cannot execute batched bf16xbf16->f32 dots, and
    casting the rounded operands back to f32 is value-identical to an MXU's
    exact-product/f32-accumulate anyway.
    """
    env = os.environ.get("REPRO_NATIVE_BF16")
    if env is not None:
        return env == "1"
    return jax.default_backend() == "tpu"


def bf16_pair(a, b):
    a = a.astype(jnp.bfloat16)
    b = b.astype(jnp.bfloat16)
    if not native_bf16():
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
    return a, b


def tile_shape_for(x, tile: int) -> Optional[Tuple[int, ...]]:
    """Tiles of ``tile`` on the last two dims (1 elsewhere) when divisible;
    per-tensor fallback otherwise."""
    if x.ndim == 0:
        return None
    shape = [1] * x.ndim
    for ax in range(max(0, x.ndim - 2), x.ndim):
        shape[ax] = tile if x.shape[ax] % tile == 0 else x.shape[ax]
    return tuple(shape)


def ste(x, xq):
    """Straight-through estimator: bit-exact quantized forward, identity
    backward — the emulation's integer ops are non-differentiable, and STE
    is the standard QAT contract for training through quantizers."""
    return x + jax.lax.stop_gradient(xq - x)


class PrecisionEngine:
    """Base engine: f32 pass-through semantics, generic contract.

    Subclasses override ``prepare_operand`` (and whichever of the other
    methods need non-generic treatment). ``name`` is stamped by
    ``register_engine``; ``emulated`` marks bit-exact-but-slow engines
    (drives ``PrecisionConfig.is_emulated``).
    """

    name: str = "?"
    emulated: bool = False
    #: Does this engine consume/update a threaded tracker? Frameworks that own
    #: a simulation loop (``repro.pde.solver.Simulation``) read this to decide
    #: whether to auto-initialise a SiteTracker for the workload's named sites
    #: — without it, tracked modes silently degrade to stateless selection.
    tracks: bool = False

    # -- operand treatment ---------------------------------------------------

    def prepare_operand(self, x, cfg, *, k=None):
        """Quantize one operand per the policy. Returns ``(x_q, k)`` where
        ``k`` is the chosen flexible split (None for non-flexible engines)."""
        del cfg, k
        return jnp.asarray(x, jnp.float32), None

    def operand_dtype(self, cfg):
        """The wire dtype of prepared operands — what collectives should move
        (moe dispatch payloads, grad compression, ...)."""
        del cfg
        return jnp.float32

    # -- elementwise ---------------------------------------------------------

    def multiply(self, a, b, cfg, *, tracker=None, site=None):
        """Elementwise product on the policy's multiplier.

        Returns ``(out, tracker)``; non-tracking engines pass the tracker
        through untouched.
        """
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        aq, _ = self.prepare_operand(a, cfg)
        bq, _ = self.prepare_operand(b, cfg)
        return aq * bq, tracker

    def add(self, a, b, cfg, *, tracker=None, site=None):
        """Elementwise sum on the policy's adder. Returns ``(out, tracker)``."""
        del site
        aq, _ = self.prepare_operand(jnp.asarray(a, jnp.float32), cfg)
        bq, _ = self.prepare_operand(jnp.asarray(b, jnp.float32), cfg)
        return aq + bq, tracker

    def divide(self, a, b, cfg, *, tracker=None, site=None):
        """Elementwise quotient. Returns ``(out, tracker)``. The base engine
        leaves division to the f32 substrate divider."""
        del site
        aq, _ = self.prepare_operand(jnp.asarray(a, jnp.float32), cfg)
        bq, _ = self.prepare_operand(jnp.asarray(b, jnp.float32), cfg)
        return aq / bq, tracker

    def rsqrt(self, x, cfg, *, tracker=None, site=None):
        """Elementwise reciprocal square root. Returns ``(out, tracker)``."""
        del site
        xq, _ = self.prepare_operand(jnp.asarray(x, jnp.float32), cfg)
        return jax.lax.rsqrt(xq), tracker

    def store(self, x, cfg):
        """State written back to the policy's storage format."""
        xq, _ = self.prepare_operand(jnp.asarray(x, jnp.float32), cfg)
        return xq

    # -- contractions --------------------------------------------------------

    def contract(self, spec, a, b, cfg, *, tracker=None, site=None, shared_k=False):
        """Einsum with policy-treated operands, f32 accumulation.

        ALWAYS returns ``(out, tracker)`` — the uniform return contract the
        thin ``rr_einsum`` shim unwraps for backward compatibility.
        """
        del site, shared_k
        aq, _ = self.prepare_operand(jnp.asarray(a), cfg)
        bq, _ = self.prepare_operand(jnp.asarray(b), cfg)
        out = jnp.einsum(spec, aq, bq, preferred_element_type=jnp.float32)
        return out, tracker
