"""repro.precision — the single integration point for precision-policy math.

The paper ships *one* runtime-reconfigurable multiplier that every workload
shares; this package is that multiplier's software seam. All policy-aware
arithmetic — model contractions, PDE elementwise products, state stores,
gradient compression — routes through one :class:`PrecisionEngine` resolved
from the config's mode by a string-keyed registry:

    from repro.precision import PRESETS, get_engine, contract, dot, multiply

    prec = PRESETS["r2f2_16"]                      # rr_tile engine
    y = dot(x, w, prec, site="mlp.up")             # dense-layer contraction
    out = contract("bshd,bthd->bhst", q, k, prec, site="attn.qk")
    p = multiply(alpha, lap, prec, site="heat.flux")

Tracked modes thread a :class:`SiteTracker` (named sites) or a raw
``RangeTracker`` (legacy integer sites) through the same calls::

    st = site_tracker_init(("attn.qk", "attn.pv"), prec.fmt)
    out, st = contract(spec, q, k, prec_tracked, tracker=st, site="attn.qk")

Return contract: with ``tracker=None`` the functions return the array; with
a tracker they return ``(out, tracker)`` — for EVERY mode (the old
``rr_einsum`` surface was inconsistent about this; the engine layer is not).

New numeric behaviours are drop-in: implement ``prepare_operand`` on a
``PrecisionEngine`` subclass, ``register_engine("fp8", MyEngine)``, and
``PrecisionConfig(mode="fp8")`` is immediately valid everywhere. Set
``PrecisionConfig(use_kernels=True)`` to let rr engines dispatch eligible
2-D contractions to the Pallas ``r2f2_matmul`` kernel (DESIGN.md §7).

``core.rr_dot`` (``rr_einsum``/``rr_dot``/``rr_operand``) and
``pde.precision_ops`` (``pmul``/``pstore``/``pdiv``) remain as thin
delegating shims for backward compatibility.
"""

from __future__ import annotations

from .engine import PrecisionEngine
from .fusion import (
    FUSED_FAMILIES,
    fold_evidence,
    fused_eligible,
    fused_family,
    mega_eligible,
)
from .registry import get_engine, is_known_mode, known_modes, register_engine
from .sites import SiteTracker, resolve_site, site_tracker_init
from . import engines as _engines  # noqa: F401 — registers the six builtins

# Convenience re-exports: the precision surface in one import.
from repro.core.flexformat import FlexFormat
from repro.core.policy import (
    PRESETS,
    PrecisionConfig,
    RangeTracker,
    adjust_step,
    tracker_init,
)

__all__ = [
    # engine plumbing
    "PrecisionEngine",
    "register_engine",
    "get_engine",
    "known_modes",
    "is_known_mode",
    # named sites
    "SiteTracker",
    "site_tracker_init",
    "resolve_site",
    # fused execution plane (DESIGN.md §10)
    "FUSED_FAMILIES",
    "fused_family",
    "fused_eligible",
    "mega_eligible",
    "fold_evidence",
    # functional API
    "prepare_operand",
    "multiply",
    "add",
    "divide",
    "rsqrt",
    "store",
    "contract",
    "dot",
    "operand_dtype",
    # config re-exports
    "FlexFormat",
    "PrecisionConfig",
    "PRESETS",
    "RangeTracker",
    "adjust_step",
    "tracker_init",
]


def prepare_operand(x, cfg, *, k=None):
    """Policy-round one operand. Returns ``(x_q, k)``."""
    return get_engine(cfg).prepare_operand(x, cfg, k=k)


def multiply(a, b, cfg, *, tracker=None, site=None):
    """Elementwise product on the policy's multiplier.

    Returns ``out`` — or ``(out, tracker)`` whenever a tracker is passed.
    """
    out, tracker_out = get_engine(cfg).multiply(a, b, cfg, tracker=tracker, site=site)
    return (out, tracker_out) if tracker is not None else out


def add(a, b, cfg, *, tracker=None, site=None):
    """Elementwise sum on the policy's adder (repro.alu flexible add).

    Returns ``out`` — or ``(out, tracker)`` whenever a tracker is passed.
    """
    out, tracker_out = get_engine(cfg).add(a, b, cfg, tracker=tracker, site=site)
    return (out, tracker_out) if tracker is not None else out


def divide(a, b, cfg, *, tracker=None, site=None):
    """Elementwise quotient on the policy's divider (repro.alu flexible
    divide for rr modes; historically the substrate's f32 divider).

    Returns ``out`` — or ``(out, tracker)`` whenever a tracker is passed.
    """
    out, tracker_out = get_engine(cfg).divide(a, b, cfg, tracker=tracker, site=site)
    return (out, tracker_out) if tracker is not None else out


def rsqrt(x, cfg, *, tracker=None, site=None):
    """Elementwise reciprocal square root on the policy's datapath.

    Returns ``out`` — or ``(out, tracker)`` whenever a tracker is passed.
    """
    out, tracker_out = get_engine(cfg).rsqrt(x, cfg, tracker=tracker, site=site)
    return (out, tracker_out) if tracker is not None else out


def store(x, cfg):
    """Round state to the policy's storage format."""
    return get_engine(cfg).store(x, cfg)


def contract(spec, a, b, cfg, *, tracker=None, site=None, shared_k=False):
    """Einsum with policy-treated operands and f32 accumulation.

    Returns ``out`` — or ``(out, tracker)`` whenever a tracker is passed,
    for every mode. ``site`` may always be given (it documents the
    multiplication site); it only has an effect when a tracker is threaded.
    """
    out, tracker_out = get_engine(cfg).contract(
        spec, a, b, cfg, tracker=tracker, site=site, shared_k=shared_k
    )
    return (out, tracker_out) if tracker is not None else out


def dot(x, w, cfg, **kw):
    """Dense-layer contraction: last dim of ``x`` against first of ``w``."""
    n = x.ndim
    lhs = "".join(chr(ord("a") + i) for i in range(n - 1)) + "z"
    rhs_extra = "".join(chr(ord("m") + i) for i in range(w.ndim - 1))
    spec = f"{lhs},z{rhs_extra}->{lhs[:-1]}{rhs_extra}"
    return contract(spec, x, w, cfg, **kw)


def operand_dtype(cfg):
    """Wire dtype of prepared operands (what collectives should move)."""
    return get_engine(cfg).operand_dtype(cfg)
