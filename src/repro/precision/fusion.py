"""Fused-execution-plane policy: eligibility + tracker fold-in.

The fused plane (DESIGN.md §10) runs whole solver steps — or whole
multi-substep chunks — inside Pallas kernels built by
:mod:`repro.kernels.fused`. This module is the *policy* half of that plane:

* :data:`FUSED_FAMILIES` maps each builtin precision mode to the arithmetic
  family a fused kernel body implements in-VMEM (``"rr"`` per-block runtime
  split, ``"bf16"``, ``"fixed"``, ``"f32"``). Third-party engines registered
  via :func:`repro.precision.register_engine` have no family and therefore
  fall back to the reference ``StepOps`` path.
* :func:`fused_eligible` is the single dispatch predicate the
  :class:`repro.pde.solver.Simulation` driver consults for
  ``execution="auto"``/``"fused"``.
* :func:`fold_evidence` replays a fused chunk's per-substep site evidence
  (per-site operand max-exponent reductions, cross-block maxed — the second
  output every fused kernel emits) through
  :func:`repro.core.policy.tracker_observe`, so a carried
  :class:`~repro.precision.sites.SiteTracker` evolves exactly like the
  stepwise loop's per-multiply ``tracker_update`` calls. This is how
  ``rr_tracked``/``deploy`` ride the fast path: the multiplier runs at
  hardware rate with per-block instantaneous splits (floored at the carried
  k), while the adjust unit observes the emitted range flags between chunks
  — the paper's Fig. 5 unit watching the datapath instead of gating it.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.core.policy import PrecisionConfig, tracker_observe

from .sites import SiteTracker, rewrap

__all__ = [
    "FUSED_FAMILIES",
    "fused_family",
    "fused_eligible",
    "mega_eligible",
    "fold_evidence",
]

#: precision mode -> in-kernel arithmetic family (see module docstring).
FUSED_FAMILIES = {
    "f32": "f32",
    "bf16": "bf16",
    "deploy": "bf16",  # MXU-rate proxy: bf16 datapath + tracker bookkeeping
    "fixed": "fixed",
    "rr_tile": "rr",
    "rr_tracked": "rr",
}


def fused_family(mode: str) -> Optional[str]:
    """The fused kernels' arithmetic family for a mode (None: not fusable)."""
    return FUSED_FAMILIES.get(mode)


def fused_eligible(prec: PrecisionConfig, stepper, cfg=None) -> bool:
    """Can this (policy, stepper, config) run on the fused execution plane?

    True iff the mode has a fused arithmetic family, the stepper defines the
    optional ``fused_step`` hook, and the stepper's ``fused_supported``
    shape check (default: always True) accepts the config.
    """
    if fused_family(prec.mode) is None:
        return False
    if not callable(getattr(stepper, "fused_step", None)):
        return False
    supported = getattr(stepper, "fused_supported", None)
    return bool(supported(cfg, prec)) if callable(supported) else True


def mega_eligible(prec: PrecisionConfig, stepper, cfg=None) -> bool:
    """Can this (policy, stepper, config) run on the whole-horizon megakernel
    plane (DESIGN.md §14)?

    Same structure as :func:`fused_eligible`, against the stepper's
    ``mega_step`` hook and its ``mega_supported`` shape gate. The megakernel
    keeps one block per state leaf, so steppers whose chunked kernels tile
    the field (per-tile split selection) must refuse configs whose fields
    exceed one kernel block — that is what keeps megakernel arithmetic
    bit-identical to the chunked plane.
    """
    if fused_family(prec.mode) is None:
        return False
    if not callable(getattr(stepper, "mega_step", None)):
        return False
    supported = getattr(stepper, "mega_supported", None)
    return bool(supported(cfg, prec)) if callable(supported) else True


def fold_evidence(tracker, evidence, cfg: PrecisionConfig, ops=None):
    """Fold a fused chunk's evidence into the carried tracker.

    ``evidence`` is the kernels' second output after cross-block max
    reduction: ``(substeps, n_sites, 2)`` f32, where ``[..., 0]``/``[..., 1]``
    are the per-site max unbiased exponents of the two operands of that
    site's operation at that substep. Each substep is replayed in order
    through :func:`repro.core.policy.tracker_observe` — identical adjust-unit
    math (EMA, grow-on-demand, shrink-on-redundancy, §5.3 counters) to the
    stepwise loop, just batched per chunk.

    ``ops`` is the per-site operation tuple (a stepper's ``site_ops`` —
    ``"mul"``/``"add"``/``"div"``/``"rsqrt"``), selecting each site's
    exponent envelope (:func:`repro.core.r2f2.op_bounds`) when the evidence
    replays; ``None`` keeps the historical all-multiplier law.

    ``tracker`` may be a :class:`SiteTracker` (site order must match the
    evidence's site axis — the stepper's ``sites`` tuple) or a raw
    ``RangeTracker``. Returns the tracker re-wrapped around updated state.
    """
    if tracker is None:
        return None
    if cfg.pinned:  # static profiled k: the adjust unit is out of the loop
        return tracker
    state = tracker.state if isinstance(tracker, SiteTracker) else tracker
    n_sites = evidence.shape[1]
    if len(state.k) != n_sites:
        raise ValueError(
            f"evidence covers {n_sites} sites but tracker has {len(state.k)} rows"
        )
    if ops is not None and len(ops) != n_sites:
        raise ValueError(
            f"site_ops covers {len(ops)} sites but evidence has {n_sites}"
        )

    def substep(st, ev_s):  # ev_s: (n_sites, 2)
        for j in range(n_sites):
            op = "mul" if ops is None else ops[j]
            st = tracker_observe(st, j, ev_s[j, 0], ev_s[j, 1], cfg, op)
        return st, None

    state, _ = jax.lax.scan(substep, state, evidence)
    return rewrap(tracker, state)
