"""String-keyed engine registry — the pluggability point of the precision API.

Every ``PrecisionConfig.mode`` names an engine registered here. The six
builtin engines (f32 / bf16 / fixed / rr_tile / rr_tracked / deploy) are
registered when :mod:`repro.precision.engines` first loads; third-party
engines (an fp8 engine, a stochastic-rounding engine, ...) become drop-in
modes the moment they call :func:`register_engine` — ``PrecisionConfig``
validation, :func:`get_engine` dispatch, and every call site that already
routes through the engine API pick them up with zero further edits.

The single source of truth for valid modes is
``repro.core.policy.KNOWN_MODES``: it is seeded with the six builtins
(whose engines load lazily) and :func:`register_engine` extends it, so
config validation and engine dispatch can never disagree about a name.

This module deliberately imports nothing from :mod:`repro.core` at module
scope (all policy access is function-local), so it is importable while
``repro.core`` is still mid-initialisation.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Tuple, Union

if TYPE_CHECKING:  # pragma: no cover - type-only; avoids a core import cycle
    from repro.core.policy import PrecisionConfig
    from repro.precision.engine import PrecisionEngine

__all__ = ["register_engine", "get_engine", "known_modes", "is_known_mode"]

_REGISTRY: Dict[str, "PrecisionEngine"] = {}


def register_engine(name: str, engine=None):
    """Register ``engine`` (an instance or a class) under ``name``.

    Usable directly (``register_engine("fp8", FP8Engine())``) or as a class
    decorator (``@register_engine("fp8")``). Re-registering a name replaces
    the previous engine — deliberate, so tests/experiments can shadow a
    builtin. Returns the engine/class for decorator chaining.
    """
    if engine is None:
        return lambda e: register_engine(name, e)
    instance = engine() if isinstance(engine, type) else engine
    instance.name = name
    _REGISTRY[name] = instance

    # a registered engine's mode is a constructible PrecisionConfig mode
    from repro.core.policy import KNOWN_MODES  # runtime: policy is loaded by now

    KNOWN_MODES.add(name)
    return engine


def _load_builtins() -> None:
    if not _REGISTRY:
        from repro.precision import engines  # noqa: F401 — registers on import


def get_engine(cfg: Union["PrecisionConfig", str]) -> "PrecisionEngine":
    """Resolve a config (or bare mode string) to its registered engine."""
    mode = cfg if isinstance(cfg, str) else cfg.mode
    _load_builtins()
    try:
        return _REGISTRY[mode]
    except KeyError:
        raise KeyError(
            f"no precision engine registered for mode {mode!r}; known: {known_modes()}"
        ) from None


def known_modes() -> Tuple[str, ...]:
    """All modes a PrecisionConfig may currently carry."""
    from repro.core.policy import KNOWN_MODES

    return tuple(sorted(KNOWN_MODES))


def is_known_mode(mode: str) -> bool:
    from repro.core.policy import KNOWN_MODES

    return mode in KNOWN_MODES
