"""The six builtin precision engines — one per historical ``cfg.mode``.

Every ``if cfg.mode == ...`` chain that used to be copy-pasted across
``core/rr_dot.py`` and ``pde/precision_ops.py`` lives here as a class each;
dispatch is a registry lookup (:func:`repro.precision.registry.get_engine`).
Numeric bodies are verbatim moves from the pre-engine modules — bit-exact
parity with the old surface is asserted by tests/test_precision_engine.py.

Engine map:

  f32         reference arithmetic (pass-through)
  bf16        plain mixed-precision baseline
  fixed       fixed E(e)M(m) emulation (the paper's failing E5M10 baseline)
  rr_tile     R2F2 emulation, per-tile runtime k selection (+ Pallas fast
              path when ``cfg.use_kernels`` and the contraction is eligible)
  rr_tracked  R2F2 emulation, k from a (Site)Tracker site
  deploy      bf16 arithmetic + tracker-driven k bookkeeping (MXU-rate proxy)

Kernel-dispatch eligibility (DESIGN.md §7): a contraction reaches the Pallas
``r2f2_matmul`` kernel iff ``cfg.use_kernels`` is set, both operands are
2-D, the spec is a plain row-by-column matmul (``"ab,bc->ac"`` up to letter
renaming), and no tracker drives ``k`` (the kernel picks its own
per-block-pair shared split — the paper's same-format rule). Block shapes
come from ``cfg.kernel_blocks`` and non-divisible dims are padded and
cropped inside the kernel, so odd shapes stay eligible. The fast path is
forward-only (no custom VJP); ``use_kernels`` defaults to False so training
paths are untouched.
"""

from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.alu import flex_op
from repro.core.flexformat import quantize_em, quantize_em_with_flags
from repro.core.policy import tracker_k, tracker_update
from repro.core.r2f2 import _tile_max_exp, r2f2_multiply, select_k, select_k_op, select_k_operand

from .engine import PrecisionEngine, bf16_pair, ste, tile_shape_for
from .registry import register_engine
from .sites import resolve_site, rewrap

__all__ = [
    "F32Engine",
    "BF16Engine",
    "FixedEngine",
    "RRTileEngine",
    "RRTrackedEngine",
    "DeployEngine",
    "kernel_eligible",
]


# ---------------------------------------------------------------------------
# Pallas fast-path eligibility
# ---------------------------------------------------------------------------

# "ab,bc->ac" with any distinct letters: 2-D row-by-column matmul, the only
# contraction shape the blocked kernel implements.
_MATMUL_SPEC = re.compile(r"^([a-zA-Z])([a-zA-Z]),([a-zA-Z])([a-zA-Z])->([a-zA-Z])([a-zA-Z])$")


def kernel_eligible(spec: str, a, b, cfg) -> bool:
    """Can this contraction run on the Pallas ``r2f2_matmul`` kernel?"""
    if not getattr(cfg, "use_kernels", False):
        return False
    if getattr(a, "ndim", 0) != 2 or getattr(b, "ndim", 0) != 2:
        return False
    m = _MATMUL_SPEC.match(spec.replace(" ", ""))
    if m is None:
        return False
    i, j, j2, l, oi, ol = m.groups()
    if len({i, j, l}) != 3 or j2 != j or (oi, ol) != (i, l):
        return False
    (M, K), (K2, N) = a.shape, b.shape
    # block shapes are a policy knob (cfg.kernel_blocks) and the kernel
    # pads-and-crops non-divisible dims, so any 2-D matmul shape is eligible
    return K == K2


def _kernel_contract(a, b, cfg):
    from repro.kernels import ops as kernel_ops  # lazy: keep pallas off cold paths

    a32 = jnp.asarray(a, jnp.float32)
    b32 = jnp.asarray(b, jnp.float32)
    return kernel_ops.r2f2_matmul(
        a32, b32, cfg.fmt, blocks=cfg.kernel_blocks, tail_approx=cfg.tail_approx
    )


# ---------------------------------------------------------------------------
# engines
# ---------------------------------------------------------------------------


@register_engine("f32")
class F32Engine(PrecisionEngine):
    """Reference arithmetic: everything stays f32."""

    def contract(self, spec, a, b, cfg, *, tracker=None, site=None, shared_k=False):
        del site, shared_k
        out = jnp.einsum(spec, jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32))
        return out, tracker

    def multiply(self, a, b, cfg, *, tracker=None, site=None):
        del site
        return jnp.asarray(a, jnp.float32) * jnp.asarray(b, jnp.float32), tracker

    def store(self, x, cfg):
        return jnp.asarray(x, jnp.float32)


@register_engine("bf16")
class BF16Engine(PrecisionEngine):
    """Plain mixed precision: bf16 operands, f32 accumulate."""

    def prepare_operand(self, x, cfg, *, k=None):
        del cfg, k
        x = jnp.asarray(x, jnp.float32)
        return x.astype(jnp.bfloat16).astype(jnp.float32), None

    def operand_dtype(self, cfg):
        del cfg
        return jnp.bfloat16

    def contract(self, spec, a, b, cfg, *, tracker=None, site=None, shared_k=False):
        del site, shared_k
        aq, bq = bf16_pair(jnp.asarray(a), jnp.asarray(b))
        out = jnp.einsum(spec, aq, bq, preferred_element_type=jnp.float32)
        return out, tracker

    def multiply(self, a, b, cfg, *, tracker=None, site=None):
        del site
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        out = (a.astype(jnp.bfloat16) * b.astype(jnp.bfloat16)).astype(jnp.float32)
        return out, tracker

    def add(self, a, b, cfg, *, tracker=None, site=None):
        del site
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        out = (a.astype(jnp.bfloat16) + b.astype(jnp.bfloat16)).astype(jnp.float32)
        return out, tracker

    def divide(self, a, b, cfg, *, tracker=None, site=None):
        del site
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        out = (a.astype(jnp.bfloat16) / b.astype(jnp.bfloat16)).astype(jnp.float32)
        return out, tracker

    def rsqrt(self, x, cfg, *, tracker=None, site=None):
        del site
        x = jnp.asarray(x, jnp.float32)
        out = jax.lax.rsqrt(x.astype(jnp.bfloat16)).astype(jnp.float32)
        return out, tracker


@register_engine("fixed")
class FixedEngine(PrecisionEngine):
    """Fixed E(e)M(m) emulation — e.g. E5M10, the paper's failing baseline."""

    emulated = True

    def prepare_operand(self, x, cfg, *, k=None):
        del k
        x = jnp.asarray(x, jnp.float32)
        e, m = cfg.fixed_em
        return ste(x, quantize_em_with_flags(x, e, m)[0]), None

    def contract(self, spec, a, b, cfg, *, tracker=None, site=None, shared_k=False):
        del site, shared_k
        e, m = cfg.fixed_em
        af = jnp.asarray(a, jnp.float32)
        bf = jnp.asarray(b, jnp.float32)
        aq = ste(af, quantize_em_with_flags(af, e, m)[0])
        bq = ste(bf, quantize_em_with_flags(bf, e, m)[0])
        return jnp.einsum(spec, aq, bq), tracker

    def multiply(self, a, b, cfg, *, tracker=None, site=None):
        del site
        e, m = cfg.fixed_em
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        p = quantize_em(a, e, m) * quantize_em(b, e, m)
        return quantize_em(p, e, m), tracker

    def add(self, a, b, cfg, *, tracker=None, site=None):
        del site
        e, m = cfg.fixed_em
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        return quantize_em(quantize_em(a, e, m) + quantize_em(b, e, m), e, m), tracker

    def divide(self, a, b, cfg, *, tracker=None, site=None):
        del site
        e, m = cfg.fixed_em
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        return quantize_em(quantize_em(a, e, m) / quantize_em(b, e, m), e, m), tracker

    def rsqrt(self, x, cfg, *, tracker=None, site=None):
        del site
        e, m = cfg.fixed_em
        x = jnp.asarray(x, jnp.float32)
        return quantize_em(jax.lax.rsqrt(quantize_em(x, e, m)), e, m), tracker

    def store(self, x, cfg):
        e, m = cfg.fixed_em
        return quantize_em(jnp.asarray(x, jnp.float32), e, m)


def _shared_k(a, b, cfg):
    """One split per contraction: max need across both whole operands plus
    the product bound (paper's same-format rule)."""
    ae, _ = _tile_max_exp(a, None)
    be, _ = _tile_max_exp(b, None)
    return select_k(ae, be, cfg.fmt)


def _shared_k_op(a, b, cfg, op):
    """Per-tensor shared split for one flexible ALU op — :func:`_shared_k`
    under the op's own exponent envelope (repro.alu)."""
    ae, _ = _tile_max_exp(a, None)
    be, _ = _tile_max_exp(b, None)
    return select_k_op(ae, be, cfg.fmt, op)


@register_engine("rr_tile")
class RRTileEngine(PrecisionEngine):
    """R2F2 emulation with per-tile runtime k selection (stateless)."""

    emulated = True

    def prepare_operand(self, x, cfg, *, k=None):
        x = jnp.asarray(x, jnp.float32)
        fmt = cfg.fmt
        if k is None:
            me, bcast = _tile_max_exp(x, tile_shape_for(x, cfg.tile))
            k = select_k_operand(me, fmt)  # operand-range-only need
            k_full = bcast(k)
        else:
            k = jnp.asarray(k, jnp.int32)
            if k.ndim == 0:
                k_full = k
            else:
                _, bcast = _tile_max_exp(x, tile_shape_for(x, cfg.tile))
                k_full = bcast(k)
        e_bits = fmt.eb + k_full
        m_bits = fmt.mb + fmt.fx - k_full
        xq, _, _ = quantize_em_with_flags(x, e_bits, m_bits)
        return ste(x, xq), k

    def contract(self, spec, a, b, cfg, *, tracker=None, site=None, shared_k=False):
        del site
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        if kernel_eligible(spec, a, b, cfg):
            return _kernel_contract(a, b, cfg), tracker
        k = None
        if shared_k:
            k = _shared_k(a.astype(jnp.float32), b.astype(jnp.float32), cfg)
        aq, _ = self.prepare_operand(a, cfg, k=k)
        bq, _ = self.prepare_operand(b, cfg, k=k)
        out = jnp.einsum(spec, aq, bq, preferred_element_type=jnp.float32)
        return out, tracker

    def multiply(self, a, b, cfg, *, tracker=None, site=None):
        # per-tensor runtime split (PDE fields are one locality cluster; the
        # Pallas kernels do the same per VMEM block)
        del site
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        out, _ = r2f2_multiply(a, b, cfg.fmt, tile_shape=None, tail_approx=cfg.tail_approx)
        return out, tracker

    def add(self, a, b, cfg, *, tracker=None, site=None):
        del site
        out, _ = flex_op(a, b, cfg.fmt, "add", tile_shape=None)
        return out, tracker

    def divide(self, a, b, cfg, *, tracker=None, site=None):
        del site
        out, _ = flex_op(a, b, cfg.fmt, "div", tile_shape=None)
        return out, tracker

    def rsqrt(self, x, cfg, *, tracker=None, site=None):
        del site
        out, _ = flex_op(x, None, cfg.fmt, "rsqrt", tile_shape=None)
        return out, tracker

    def store(self, x, cfg):
        # rr storage: minimal-k format for the live range (paper Fig. 4a)
        x = jnp.asarray(x, jnp.float32)
        me, _ = _tile_max_exp(x, None)
        k = select_k_operand(me, cfg.fmt)
        return quantize_em(x, cfg.fmt.eb + k, cfg.fmt.mb + cfg.fmt.fx - k)


@register_engine("rr_tracked")
class RRTrackedEngine(RRTileEngine):
    """R2F2 emulation with k carried across steps by a (Site)Tracker.

    The live split is the *tracked* one widened to the instantaneous safe
    minimum: the paper's Fig. 5 unit detects overflow/underflow DURING a
    multiplication and retries it at a grown split, so a range spike can
    never fault the current operation — only *shrinking* below the carried
    k requires the tracker's cross-step redundancy evidence (EMA), which is
    exactly the persistence the tracker provides.
    """

    emulated = True
    tracks = True

    def _k_live(self, state, idx, a, b, cfg):
        """Carried split, grown on demand (the hardware's overflow-retry).
        Under ``cfg.pinned`` the carried split is used verbatim — the static
        profiled-deployment emulation (no adjust unit in the loop)."""
        if cfg.pinned:
            return tracker_k(state, idx)
        return jnp.maximum(tracker_k(state, idx), _shared_k(a, b, cfg))

    def contract(self, spec, a, b, cfg, *, tracker=None, site=None, shared_k=False):
        del shared_k
        state, idx = resolve_site(tracker, site)
        if state is None or idx is None:
            raise ValueError("rr_tracked needs tracker+site")
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        k = self._k_live(state, idx, a, b, cfg)
        if not cfg.pinned:
            state = tracker_update(state, idx, a, b, cfg)
        aq, _ = self.prepare_operand(a, cfg, k=k)
        bq, _ = self.prepare_operand(b, cfg, k=k)
        out = jnp.einsum(spec, aq, bq, preferred_element_type=jnp.float32)
        return out, rewrap(tracker, state)

    def multiply(self, a, b, cfg, *, tracker=None, site=None):
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        state, idx = resolve_site(tracker, site)
        if state is None or idx is None:
            # untracked fallback: stateless per-tensor selection (rr_tile)
            out, _ = r2f2_multiply(a, b, cfg.fmt, tile_shape=None, tail_approx=cfg.tail_approx)
            return out, tracker
        k = self._k_live(state, idx, a, b, cfg)
        if not cfg.pinned:
            state = tracker_update(state, idx, a, b, cfg)
        out, _ = r2f2_multiply(a, b, cfg.fmt, k=k, tile_shape=None, tail_approx=cfg.tail_approx)
        return out, rewrap(tracker, state)

    def _tracked_alu(self, op, a, b, cfg, tracker, site):
        """Shared tracked driver for the repro.alu ops: carried split grown
        to the op's instantaneous envelope need, evidence folded under the
        op's own law (``tracker_observe(..., op=...)``)."""
        a = jnp.asarray(a, jnp.float32)
        b = a if b is None else jnp.asarray(b, jnp.float32)
        state, idx = resolve_site(tracker, site)
        if state is None or idx is None:
            # untracked fallback: stateless per-tensor selection (rr_tile)
            out, _ = flex_op(a, b, cfg.fmt, op, tile_shape=None)
            return out, tracker
        ev_op = "add" if op == "sub" else op
        if cfg.pinned:
            k = tracker_k(state, idx)
        else:
            k = jnp.maximum(tracker_k(state, idx), _shared_k_op(a, b, cfg, ev_op))
            state = tracker_update(state, idx, a, b, cfg, ev_op)
        out, _ = flex_op(a, b, cfg.fmt, op, k=k)
        return out, rewrap(tracker, state)

    def add(self, a, b, cfg, *, tracker=None, site=None):
        return self._tracked_alu("add", a, b, cfg, tracker, site)

    def divide(self, a, b, cfg, *, tracker=None, site=None):
        return self._tracked_alu("div", a, b, cfg, tracker, site)

    def rsqrt(self, x, cfg, *, tracker=None, site=None):
        return self._tracked_alu("rsqrt", x, None, cfg, tracker, site)


@register_engine("deploy")
class DeployEngine(BF16Engine):
    """bf16 arithmetic (the MXU-rate proxy for 16-bit flexible operands) +
    tracker-driven k bookkeeping, so dry-run/roofline numbers reflect what
    R2F2 silicon would execute while the format choice stays observable."""

    tracks = True

    def _track(self, tracker, site, a, b, cfg, op="mul"):
        if cfg.pinned:  # static profiled k: bookkeeping stays at the policy's split
            return tracker
        state, idx = resolve_site(tracker, site)
        if state is not None and idx is not None:
            tracker = rewrap(tracker, tracker_update(state, idx, a, b, cfg, op))
        return tracker

    def contract(self, spec, a, b, cfg, *, tracker=None, site=None, shared_k=False):
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        out, _ = super().contract(spec, a, b, cfg, shared_k=shared_k)
        return out, self._track(tracker, site, a, b, cfg)

    def multiply(self, a, b, cfg, *, tracker=None, site=None):
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        out, _ = super().multiply(a, b, cfg)
        return out, self._track(tracker, site, a, b, cfg)

    def add(self, a, b, cfg, *, tracker=None, site=None):
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        out, _ = super().add(a, b, cfg)
        return out, self._track(tracker, site, a, b, cfg, "add")

    def divide(self, a, b, cfg, *, tracker=None, site=None):
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        out, _ = super().divide(a, b, cfg)
        return out, self._track(tracker, site, a, b, cfg, "div")

    def rsqrt(self, x, cfg, *, tracker=None, site=None):
        x = jnp.asarray(x, jnp.float32)
        out, _ = super().rsqrt(x, cfg)
        return out, self._track(tracker, site, x, x, cfg, "rsqrt")
