"""Policy synthesis + closed-loop validation — profile in, artifact out.

The synthesizer does NOT invent a new precision law: it replays the
captured evidence stream through the very adjust-unit math the runtime
tracker applies (:func:`repro.precision.fold_evidence` →
:func:`repro.core.policy.tracker_observe`), so the tuned per-site ``k`` is
*by construction* the split an ``rr_tracked`` run over the same evidence
converges to. Around it, the instantaneous-need extremes
(:func:`repro.core.policy.evidence_k_need`) become the floor/ceiling hints:
``k_hi`` is what a static no-adjust-unit build must provision, ``k_lo`` is
the narrowest split the run ever tolerated.

Validation closes the loop (the paper's deploy contract): before an
artifact is stamped ``accepted``, the stepper re-runs under the synthesized
policy — ``rr_tracked`` seeded and clamped by the artifact (flexible-format
arithmetic actually exercising the tuned splits) — and its rel-L2 against
the f32 oracle must clear the tolerance. A pinned ``deploy`` replay is
recorded alongside (the MXU-rate proxy a production run will reproduce
bit-for-bit from the same artifact).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.policy import PrecisionConfig, evidence_k_need, tracker_init
from repro.precision import fold_evidence, site_tracker_init

from .analysis import RangeProfile
from .artifact import PrecisionPolicy

__all__ = ["synthesize_policy", "validate_policy", "tune_policy"]


def synthesize_policy(profile: RangeProfile, prec: Optional[PrecisionConfig] = None) -> PrecisionPolicy:
    """Convert a range profile into a (not yet validated) PrecisionPolicy.

    ``prec`` supplies the target format and adjust-unit constants
    (``fmt``/``ema``/``headroom``); defaults to the profile's own capture
    config. Per site:

    * ``k``   — final split after replaying the whole evidence stream
      through the adjust-unit law from the standard wide start;
    * ``k_lo``/``k_hi`` — min/max instantaneous need over the run.
    """
    prec = profile.prec if prec is None else prec
    base = dataclasses.replace(prec, k_bounds=None, pinned=False)
    n_sites = len(profile.sites)
    ev = jnp.asarray(profile.evidence, jnp.float32)

    ops = profile.site_ops  # None = all-mul; else per-site op envelopes
    state = fold_evidence(tracker_init(n_sites, base.fmt), ev, base, ops=ops)
    k = np.asarray(state.k, np.int64)
    if ops is None:
        k_need = np.asarray(evidence_k_need(ev[..., 0], ev[..., 1], base), np.int64)
    else:
        k_need = np.stack(
            [
                np.asarray(
                    evidence_k_need(ev[:, j, 0], ev[:, j, 1], base, op), np.int64
                )
                for j, op in enumerate(ops)
            ],
            axis=1,
        )
    k_hi = np.maximum(k_need.max(axis=0), k)  # converged k never exceeds max
    k_lo = np.minimum(k_need.min(axis=0), k)  # need, but keep the invariant
    sites = {
        name: {"k": int(k[j]), "k_lo": int(k_lo[j]), "k_hi": int(k_hi[j])}
        for j, name in enumerate(profile.sites)
    }
    return PrecisionPolicy(
        stepper=profile.stepper,
        fmt=base.fmt,
        sites=sites,
        ema=base.ema,
        headroom=base.headroom,
        meta={
            "created_unix": time.time(),
            "profile": {
                "steps": profile.steps,
                "execution": profile.execution,
                "capture_mode": profile.prec.mode,
                "spec": {"e_lo": profile.spec.e_lo, "e_hi": profile.spec.e_hi},
            },
            "adjust_counters": {
                "overflow_steps": [int(x) for x in np.asarray(state.overflow_steps)],
                "shrink_steps": [int(x) for x in np.asarray(state.shrink_steps)],
            },
        },
    )


def _rel_l2(obs, ref, offset: float) -> float:
    obs = np.asarray(obs, np.float64) - offset
    ref = np.asarray(ref, np.float64) - offset
    denom = max(float(np.linalg.norm(ref)), 1e-30)
    return float(np.linalg.norm(obs - ref) / denom)


def validate_policy(
    policy: PrecisionPolicy,
    cfg=None,
    *,
    steps: int,
    tol: float = 0.1,
    execution: str = "reference",
    snapshot_every: Optional[int] = None,
) -> Dict[str, Any]:
    """Closed-loop validation replay; stamps ``policy.validation`` in place
    and returns the stamp.

    Four runs of ``policy.stepper`` over ``steps``, judged against the f32
    oracle:

    * ``rr_tracked`` seeded+clamped by the policy — the *dynamic* gate
      (flexible-format arithmetic with the tuned splits in the loop);
    * ``rr_tracked`` **pinned at the artifact's** ``k_hi`` — the *static*
      gate: a build without the adjust unit provisions the ceiling hint,
      and its per-multiply retry net is gone, so an under-provisioned
      ceiling shows up here as overflow/NaN instead of being silently
      rescued by the live widen;
    * pinned ``deploy`` under the policy — the MXU-rate proxy, whose rel-L2
      is recorded for the deploy-time reproducibility check.
    """
    from repro.pde.solver import Simulation  # lazy: no pde import at module scope

    def run(prec, policy_arg, tracker=None):
        sim = Simulation(policy.stepper, cfg, prec)
        res = sim.run(
            steps,
            snapshot_every=snapshot_every,
            execution=execution,
            policy=policy_arg,
            tracker=tracker,
        )
        return sim, res

    sim, ref = run(PrecisionConfig(mode="f32", fmt=policy.fmt), None)
    offset = sim.stepper.metric_offset(sim.cfg)
    ref_obs = sim.stepper.observables(ref.state, sim.cfg)

    base = PrecisionConfig(
        mode="rr_tracked", fmt=policy.fmt, ema=policy.ema, headroom=policy.headroom
    )
    _, tracked = run(base, policy)
    tracked_obs = sim.stepper.observables(tracked.state, sim.cfg)
    rel_tracked = _rel_l2(tracked_obs, ref_obs, offset)

    sites = sim.stepper.sites
    k_hi = np.asarray([policy.sites[n]["k_hi"] for n in sites], np.int32)
    static_tr = site_tracker_init(sites, policy.fmt, k0=k_hi)
    _, static = run(dataclasses.replace(base, pinned=True), None, tracker=static_tr)
    static_obs = sim.stepper.observables(static.state, sim.cfg)
    rel_static = _rel_l2(static_obs, ref_obs, offset)

    deploy_prec = dataclasses.replace(base, mode="deploy", pinned=True)
    _, deploy = run(deploy_prec, policy)
    deploy_obs = sim.stepper.observables(deploy.state, sim.cfg)
    rel_deploy = _rel_l2(deploy_obs, ref_obs, offset)

    finite = bool(
        np.isfinite(np.asarray(tracked_obs)).all()
        and np.isfinite(np.asarray(static_obs)).all()
        and np.isfinite(np.asarray(deploy_obs)).all()
    )
    ok = finite and rel_tracked <= tol and rel_static <= tol
    stamp = {
        "accepted": bool(ok),
        "tol": tol,
        "oracle": "f32",
        "steps": steps,
        "execution": execution,
        "snapshot_every": snapshot_every,
        "rel_l2_tracked": rel_tracked,
        "rel_l2_static": rel_static,
        "rel_l2_deploy": rel_deploy,
        "finite": finite,
        "validated_unix": time.time(),
    }
    policy.validation = stamp
    return stamp


def tune_policy(
    stepper,
    cfg=None,
    *,
    steps: int,
    prec: Optional[PrecisionConfig] = None,
    capture_prec: Optional[PrecisionConfig] = None,
    execution: str = "reference",
    snapshot_every: Optional[int] = None,
    tol: float = 0.1,
    validate: bool = True,
):
    """Capture → synthesize → validate, in one call.

    ``capture_prec`` is the mode the profiling run executes under (default
    f32 — the oracle trajectory); ``prec`` supplies the target format and
    adjust constants for synthesis (default: same as capture). Returns
    ``(profile, report, policy)`` with ``policy.validation`` stamped when
    ``validate``.
    """
    from .pipeline import capture_profile

    profile, _ = capture_profile(
        stepper,
        cfg,
        steps=steps,
        prec=capture_prec,
        execution=execution,
        snapshot_every=snapshot_every,
    )
    policy = synthesize_policy(profile, prec)
    if validate:
        validate_policy(
            policy, cfg, steps=steps, tol=tol, execution=execution,
            snapshot_every=snapshot_every,
        )
    return profile, profile.report(), policy
