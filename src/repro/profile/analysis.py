"""Offline range analysis — the paper's Fig. 3/4 views from a captured run.

:class:`RangeProfile` is the host-side (numpy) form of a capture: the
evidence stream, the exponent histograms, and the run context (stepper,
sites, precision, execution plane). :class:`RangeReport` derives the views
the paper builds its precision argument on:

* **dynamic range** per site/operand — occupied exponent span of every
  value that flowed through the multiplier (Fig. 3's distributions);
* **exponent spread over simulation time** — per-snapshot-interval occupied
  spans, showing the drift that makes a static format fail late (heat's
  flux sinking toward the subnormal floor, Burgers' post-shock collapse);
* **representability** — % of multiplication issues whose instantaneous
  need ``k_need`` (the adjust-unit statistic,
  :func:`repro.core.policy.evidence_k_need`) is covered at each flexible
  split ``k``, i.e. the fraction of multiplies a static ``E(EB+k)`` format
  computes without an adjust event (Fig. 4's flexible-split trade-off).

Pure numpy — nothing here traces or jits; it consumes arrays the capture
layer already materialized.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.policy import PrecisionConfig, evidence_k_need

from .capture import CaptureResult, CaptureSpec

__all__ = ["RangeProfile", "RangeReport"]


class RangeProfile:
    """A captured run, hosted: numpy arrays + static context."""

    def __init__(
        self,
        stepper: str,
        sites: Tuple[str, ...],
        spec: CaptureSpec,
        prec: PrecisionConfig,
        steps: int,
        execution: str,
        result: CaptureResult,
        site_ops: Optional[Tuple[str, ...]] = None,
    ):
        self.stepper = stepper
        self.sites = tuple(sites)
        #: per-site op declarations ("mul"/"add"/"div"/"rsqrt") — selects the
        #: exponent envelope k_need/synthesis replays under; None = all-mul
        self.site_ops = None if site_ops is None else tuple(site_ops)
        if self.site_ops is not None and len(self.site_ops) != len(self.sites):
            raise ValueError(
                f"site_ops covers {len(self.site_ops)} entries for "
                f"{len(self.sites)} sites"
            )
        self.spec = spec
        self.prec = prec
        self.steps = int(steps)
        self.execution = execution
        self.evidence = np.asarray(result.evidence, np.float32)
        self.exp_time = np.asarray(result.exp_time, np.int64)
        self.exp_total = np.asarray(result.exp_total, np.int64)
        n_sites = len(self.sites)
        if self.evidence.shape[1:] != (n_sites, 2):
            raise ValueError(
                f"evidence shape {self.evidence.shape} does not match "
                f"{n_sites} sites"
            )
        if self.exp_total.shape != (n_sites, 2, spec.n_bins):
            raise ValueError(
                f"exp_total shape {self.exp_total.shape} != "
                f"{(n_sites, 2, spec.n_bins)}"
            )

    def site_index(self, name: str) -> int:
        try:
            return self.sites.index(name)
        except ValueError:
            raise KeyError(f"unknown site {name!r}; profiled: {self.sites}") from None

    def report(self) -> "RangeReport":
        return RangeReport(self)


def _occupied_span(counts, spec: CaptureSpec) -> Optional[Tuple[int, int]]:
    """(min_exp, max_exp) of the occupied bins, or None if nothing counted."""
    (occ,) = np.nonzero(counts)
    if occ.size == 0:
        return None
    return int(occ[0] + spec.e_lo), int(occ[-1] + spec.e_lo)


class RangeReport:
    """Derived per-site statistics over a :class:`RangeProfile`."""

    def __init__(self, profile: RangeProfile):
        self.profile = profile
        p = profile
        fx = p.prec.fmt.fx
        # per-issue instantaneous need, the adjust unit's own statistic —
        # each site judged under its own op envelope when ops are declared
        if p.site_ops is None:
            self.k_need = np.asarray(
                evidence_k_need(p.evidence[..., 0], p.evidence[..., 1], p.prec),
                np.int32,
            )  # (steps, n_sites); saturates at FX like the hardware
        else:
            self.k_need = np.stack(
                [
                    np.asarray(
                        evidence_k_need(
                            p.evidence[:, j, 0], p.evidence[:, j, 1], p.prec, op
                        ),
                        np.int32,
                    )
                    for j, op in enumerate(p.site_ops)
                ],
                axis=1,
            )
        self.sites: Dict[str, Dict[str, Any]] = {}
        for j, name in enumerate(p.sites):
            per_op = [_occupied_span(p.exp_total[j, s], p.spec) for s in (0, 1)]
            both = p.exp_total[j].sum(axis=0)
            span = _occupied_span(both, p.spec)
            kn = self.k_need[:, j]
            coverage = {
                int(k): float(np.mean(kn <= k)) for k in range(fx + 1)
            }  # % of issues a static split k covers without an adjust event
            spread = [
                _occupied_span(p.exp_time[t, j].sum(axis=0), p.spec)
                for t in range(p.exp_time.shape[0])
            ]
            self.sites[name] = {
                "values_counted": int(both.sum()),
                "exp_span": span,
                "exp_span_a": per_op[0],
                "exp_span_b": per_op[1],
                "dynamic_range_bits": None if span is None else span[1] - span[0] + 1,
                "k_need_min": int(kn.min()),
                "k_need_max": int(kn.max()),
                "k_need_final": int(kn[-1]),
                "coverage_at_k": coverage,
                "spread_over_time": spread,
            }

    def to_dict(self) -> Dict[str, Any]:
        p = self.profile
        return {
            "stepper": p.stepper,
            "execution": p.execution,
            "capture_mode": p.prec.mode,
            "steps": p.steps,
            "fmt": str(p.prec.fmt),
            "sites": self.sites,
        }

    def summary(self) -> str:
        """Human-readable per-site table (the CLI's report body)."""
        p = self.profile
        fx = p.prec.fmt.fx
        lines = [
            f"range profile: {p.stepper} | {p.steps} steps | "
            f"mode={p.prec.mode} | execution={p.execution} | fmt={p.prec.fmt}",
            f"{'site':<16} {'values':>10} {'exp span':>12} {'k_need':>9} "
            + " ".join(f"cov@k={k}" for k in range(fx + 1)),
        ]
        for name, s in self.sites.items():
            span = s["exp_span"]
            span_s = "-" if span is None else f"[{span[0]},{span[1]}]"
            cov = " ".join(
                f"{100.0 * s['coverage_at_k'][k]:6.1f}%" for k in range(fx + 1)
            )
            lines.append(
                f"{name:<16} {s['values_counted']:>10} {span_s:>12} "
                f"{s['k_need_min']}..{s['k_need_max']:<6} {cov}"
            )
            first, last = s["spread_over_time"][0], s["spread_over_time"][-1]
            lines.append(
                f"{'':<16} spread over time: first interval {first} -> last {last}"
            )
        return "\n".join(lines)
