"""repro.profile — range-distribution telemetry, offline analysis, and the
precision-policy autotuner (DESIGN.md §11).

The paper's deploy scenario assumes a *profiled*, per-site static precision;
this package is the profile→tune→deploy pipeline that produces it:

1. **capture** (:mod:`~repro.profile.capture`) — per-site exponent
   histograms + the site-level evidence stream, recorded during
   ``Simulation.run(..., capture=True)`` on both execution planes;
2. **analysis** (:mod:`~repro.profile.analysis`) — the offline
   :class:`RangeProfile`/:class:`RangeReport` pair reproducing the paper's
   Fig. 3/4 views (dynamic range, exponent spread over time, %% of
   multiplies representable at each flexible split k);
3. **autotune** (:mod:`~repro.profile.autotune`) — replays the captured
   evidence through the adjust-unit law to synthesize a versioned
   :class:`PrecisionPolicy` artifact (per-site static k for ``deploy``,
   floor/ceiling hints for ``rr_tracked``), then closes the loop with a
   validation replay against the f32 oracle before stamping it accepted;
4. **artifact I/O + CLI** (:mod:`~repro.profile.artifact`,
   ``python -m repro.profile <stepper>``) — schema-versioned JSON save/load
   consumed by ``Simulation.run(..., policy=...)`` and
   ``repro.serve.generate(..., policy=...)``.
"""

from __future__ import annotations

from .capture import CaptureResult, CaptureSpec, exp_hist, pair_exp_hist, site_evidence
from .artifact import SCHEMA, SCHEMA_VERSION, PrecisionPolicy, resolve_policy
from .analysis import RangeProfile, RangeReport
from .autotune import synthesize_policy, tune_policy, validate_policy
from .pipeline import capture_profile

__all__ = [
    "CaptureSpec",
    "CaptureResult",
    "exp_hist",
    "pair_exp_hist",
    "site_evidence",
    "SCHEMA",
    "SCHEMA_VERSION",
    "PrecisionPolicy",
    "resolve_policy",
    "RangeProfile",
    "RangeReport",
    "synthesize_policy",
    "validate_policy",
    "tune_policy",
    "capture_profile",
]
