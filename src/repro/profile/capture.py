"""Range-distribution capture primitives — the profile subsystem's in-loop
half (DESIGN.md §11).

The paper's first contribution is "a thorough analysis of data range
distributions during scientific simulations" (Figs. 3–4); the tracker only
keeps an EMA of each site's *max* exponent, which is enough to drive the
adjust unit but not to reproduce those figures or to tune a static policy
offline. Capture widens the evidence stream to **binned counts**: every
policy multiplication bins the unbiased exponents of both (broadcast)
operands into width-1 exponent bins, per named site, alongside the existing
site-level max-exponent evidence.

Everything here is pure ``jnp`` over ``repro.core`` — deliberately free of
solver/kernel imports — so the SAME binning functions run in three places
and can never disagree:

* inside :class:`repro.pde.solver.StepOps` (reference execution),
* inside :class:`repro.kernels.fused.FusedOps` (Pallas kernel bodies, where
  the counts ride out as an extra kernel output, summed across blocks),
* offline, when tests replay operands through the binning directly.

Counting convention: exact zeros and non-finite values are excluded (they
carry no exponent; zero padding in fused kernels therefore cannot
contaminate the counts), and exponents outside ``[e_lo, e_hi]`` clamp into
the edge bins. Counts are int32 (exact far beyond f32's 2**24 integer
ceiling). With width-1 bins the per-site max exponent is exactly the
highest occupied bin, which is what makes the histogram a strict widening
of the max-exponent evidence.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax.numpy as jnp

from repro.core.flexformat import unbiased_exponent
from repro.core.policy import _site_max_exp

__all__ = ["CaptureSpec", "CaptureResult", "exp_hist", "pair_exp_hist", "site_evidence"]


@dataclasses.dataclass(frozen=True)
class CaptureSpec:
    """Static (hashable — safe as a jit/pallas static arg) binning layout.

    One bin per unbiased exponent value in ``[e_lo, e_hi]`` inclusive. The
    defaults cover every workload in the repo with wide margins (operand
    exponents observed so far span roughly [-40, 35]); out-of-range
    exponents clamp into the edge bins rather than being dropped, so a
    saturated edge bin is visible in the report instead of silent.
    """

    e_lo: int = -64
    e_hi: int = 63

    def __post_init__(self):
        if self.e_hi <= self.e_lo:
            raise ValueError(f"empty exponent range [{self.e_lo}, {self.e_hi}]")

    @property
    def n_bins(self) -> int:
        return self.e_hi - self.e_lo + 1

    def edges(self):
        """Bin exponents as a host-side range (analysis axis labels)."""
        return range(self.e_lo, self.e_hi + 1)


class CaptureResult(NamedTuple):
    """What a captured run hands to the offline layer (arrays only — a plain
    pytree, so it rides through jit/scan/vmap like any other result leaf).

    ``evidence``  (steps, n_sites, 2) f32 — per-step site-level operand
                  max exponents, the same stream the adjust unit consumes
                  (:func:`repro.core.policy.tracker_observe`); the
                  autotuner replays it verbatim.
    ``exp_time``  (n_snapshots, n_sites, 2, n_bins) int32 — per-snapshot-
                  interval elementwise operand exponent counts (the paper's
                  range-over-simulation-time view).
    ``exp_total`` (n_sites, 2, n_bins) int32 — whole-run counts, remainder
                  steps included (``exp_time`` covers only whole intervals).
    """

    evidence: Any
    exp_time: Any
    exp_total: Any


def exp_hist(x, spec: CaptureSpec, mask=None) -> jnp.ndarray:
    """Bin one (broadcast) operand's elementwise unbiased exponents.

    Returns ``(n_bins,) int32``. Zeros and non-finite values are excluded;
    out-of-range exponents clamp into the edge bins. ``mask`` (same shape,
    bool) restricts counting to True lanes — the fused kernels use it to
    keep non-zero pad lanes out of the counts.
    """
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    keep = jnp.isfinite(x) & (x != 0.0)
    if mask is not None:
        keep = keep & jnp.asarray(mask).reshape(-1)
    idx = jnp.clip(unbiased_exponent(x) - spec.e_lo, 0, spec.n_bins - 1)
    hit = (idx[:, None] == jnp.arange(spec.n_bins, dtype=jnp.int32)[None, :]) & keep[:, None]
    return jnp.sum(hit, axis=0, dtype=jnp.int32)


def pair_exp_hist(a, b, spec: CaptureSpec, mask=None) -> jnp.ndarray:
    """Bin both operands of one multiplication (already broadcast to a
    common shape by the caller). Returns ``(2, n_bins) int32``."""
    return jnp.stack([exp_hist(a, spec, mask), exp_hist(b, spec, mask)])


def site_evidence(a, b) -> jnp.ndarray:
    """One multiplication's site-level evidence ``(a_max_exp, b_max_exp)``
    as a ``(2,) f32`` — byte-for-byte what the tracker consumes
    (:func:`repro.core.policy.tracker_update`'s reduction) and what the
    fused kernels emit per substep."""
    return jnp.stack([_site_max_exp(a), _site_max_exp(b)])
