"""One-command profile→report→tune→validate→deploy pipeline.

    PYTHONPATH=src python -m repro.profile <stepper> [--steps 400]
        [--execution both|reference|fused] [--capture-mode f32] [--tol 0.1]
        [--out artifacts/profile] [--smoke]

End to end, headlessly:

1. capture a range profile of the registered stepper (reference execution,
   and the fused Pallas plane too under ``--execution both``/``fused``,
   with a histogram/evidence parity check between the planes);
2. print the :class:`~repro.profile.analysis.RangeReport`;
3. synthesize a :class:`~repro.profile.artifact.PrecisionPolicy`;
4. closed-loop validate it (rr_tracked replay vs the f32 oracle) and stamp;
5. save the artifact JSON, then **reload it from disk** and run a pinned
   ``deploy`` simulation under the loaded policy, checking its rel-L2
   reproduces the one the validation replay recorded.

Exit status 0 only if the artifact was accepted and the deploy replay
reproduced; 2 otherwise (CI treats this as the profiler smoke gate).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import numpy as np

from repro.core.policy import PrecisionConfig

from .analysis import RangeProfile
from .artifact import PrecisionPolicy
from .autotune import _rel_l2, synthesize_policy, validate_policy
from .capture import CaptureSpec
from .pipeline import capture_profile


def _parity(a: RangeProfile, b: RangeProfile) -> bool:
    return bool(
        np.array_equal(a.evidence, b.evidence)
        and np.array_equal(a.exp_total, b.exp_total)
        and np.array_equal(a.exp_time, b.exp_time)
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.profile")
    ap.add_argument("stepper", help="registered PDE stepper name (e.g. heat1d)")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--snapshot-every", type=int, default=None)
    ap.add_argument(
        "--execution",
        default="both",
        choices=("both", "reference", "fused"),
        help="capture plane(s); 'both' also checks histogram parity",
    )
    ap.add_argument(
        "--capture-mode",
        default="f32",
        help="precision mode the profiling run executes under",
    )
    ap.add_argument("--tol", type=float, default=0.1, help="validation rel-L2 gate")
    ap.add_argument("--out", default="artifacts/profile", help="artifact directory")
    ap.add_argument(
        "--smoke", action="store_true", help="reduced steps for the CI fast tier"
    )
    args = ap.parse_args(argv)

    steps = 64 if args.smoke else args.steps
    cap_prec = PrecisionConfig(mode=args.capture_mode)
    spec = CaptureSpec()

    # -- 1. capture ---------------------------------------------------------
    planes = {"both": ("reference", "fused"), "reference": ("reference",),
              "fused": ("fused",)}[args.execution]
    profiles = {}
    for plane in planes:
        profiles[plane], _ = capture_profile(
            args.stepper, steps=steps, prec=cap_prec, execution=plane,
            snapshot_every=args.snapshot_every, spec=spec,
        )
        print(f"[profile] captured {args.stepper} ({steps} steps, {plane} execution)")
    if len(profiles) == 2:
        ok = _parity(profiles["reference"], profiles["fused"])
        print(f"[profile] reference/fused histogram+evidence parity: "
              f"{'EXACT' if ok else 'MISMATCH'}")
        if not ok:
            return 2
    profile = profiles[planes[0]]

    # -- 2. report ----------------------------------------------------------
    report = profile.report()
    print()
    print(report.summary())
    print()

    # -- 3./4. tune + validate ---------------------------------------------
    policy = synthesize_policy(profile)
    stamp = validate_policy(
        policy, steps=steps, tol=args.tol, snapshot_every=args.snapshot_every
    )
    print(f"[tune] per-site splits: "
          + ", ".join(f"{n}: k={d['k']} [{d['k_lo']},{d['k_hi']}]"
                      for n, d in policy.sites.items()))
    print(f"[validate] rr_tracked rel-L2 {stamp['rel_l2_tracked']:.3e} | "
          f"static@k_hi rel-L2 {stamp['rel_l2_static']:.3e} (tol {args.tol}) | "
          f"deploy rel-L2 {stamp['rel_l2_deploy']:.3e} | "
          f"{'ACCEPTED' if stamp['accepted'] else 'REJECTED'}")

    # -- 5. save, reload, re-deploy ----------------------------------------
    path = os.path.join(args.out, f"{args.stepper}_policy.json")
    policy.save(path)
    report_path = os.path.join(args.out, f"{args.stepper}_report.json")
    with open(report_path, "w") as f:
        json.dump(report.to_dict(), f, indent=2, sort_keys=True, default=str)
    print(f"[artifact] wrote {path} and {report_path}")

    loaded = PrecisionPolicy.load(path)
    from repro.pde.solver import Simulation  # lazy: keep module import light

    deploy_prec = PrecisionConfig(
        mode="deploy", fmt=loaded.fmt, ema=loaded.ema, headroom=loaded.headroom,
        pinned=True,
    )
    sim = Simulation(args.stepper, None, deploy_prec)
    res = sim.run(steps, snapshot_every=args.snapshot_every, policy=loaded)
    oracle = Simulation(args.stepper, None, PrecisionConfig(mode="f32", fmt=loaded.fmt))
    ref = oracle.run(steps, snapshot_every=args.snapshot_every)
    offset = sim.stepper.metric_offset(sim.cfg)
    rel = _rel_l2(
        sim.stepper.observables(res.state, sim.cfg),
        sim.stepper.observables(ref.state, sim.cfg),
        offset,
    )
    recorded = loaded.validation["rel_l2_deploy"]
    reproduced = abs(rel - recorded) <= 1e-12 * max(1.0, abs(recorded))
    ks = {n: int(res.tracker.k(n)) for n in res.tracker.names} if res.tracker else {}
    print(f"[deploy] pinned run under loaded artifact: rel-L2 {rel:.3e} "
          f"(validation recorded {recorded:.3e}) — "
          f"{'REPRODUCED' if reproduced else 'DRIFTED'} | static splits {ks}")

    return 0 if (stamp["accepted"] and reproduced) else 2


if __name__ == "__main__":
    sys.exit(main())
