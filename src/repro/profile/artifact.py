"""PrecisionPolicy artifacts — the profile subsystem's durable output.

A policy is the paper's deploy story made reproducible: per-site flexible
splits derived from an observed range profile, written as a schema-versioned
JSON file that survives the run that produced it. Consumers:

* ``Simulation.run(..., policy=...)`` — tracked PDE runs start their
  SiteTracker at the artifact's per-site ``k`` and clamp re-picks to the
  ``[k_lo, k_hi]`` hints (``PrecisionConfig.k_bounds``);
* ``Simulation.run(..., policy=..., prec=<pinned deploy>)`` — the static
  profiled-deployment emulation (no adjust unit in the loop);
* ``repro.serve.generate(..., policy=...)`` — the LM serving path loads the
  same format (site names differ; the artifact is the contract).

Schema stability: ``schema``/``schema_version`` are checked on load; older
minor payload additions must keep existing keys, and a major change bumps
``SCHEMA_VERSION`` (load refuses newer-than-supported artifacts loudly
instead of misreading them).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.flexformat import FlexFormat
from repro.core.policy import PrecisionConfig

__all__ = ["SCHEMA", "SCHEMA_VERSION", "PrecisionPolicy", "resolve_policy"]

SCHEMA = "repro.profile/policy"
SCHEMA_VERSION = 1


@dataclasses.dataclass
class PrecisionPolicy:
    """Per-site static precision derived from a range profile.

    ``sites`` maps site name -> ``{"k", "k_lo", "k_hi"}``: ``k`` is the
    split the adjust unit converged to under the profiled evidence (the
    deploy default), ``k_lo``/``k_hi`` are the min/max instantaneous need
    observed across the run (the rr_tracked floor/ceiling hints — a static
    build that must survive the whole run uses ``k_hi``).
    """

    stepper: str
    fmt: FlexFormat
    sites: Dict[str, Dict[str, int]]
    ema: float = 0.95
    headroom: int = 1
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    validation: Optional[Dict[str, Any]] = None

    # -- queries ------------------------------------------------------------

    @property
    def accepted(self) -> bool:
        """Did the closed-loop validation replay stamp this artifact?"""
        return bool(self.validation and self.validation.get("accepted"))

    @property
    def site_names(self) -> Tuple[str, ...]:
        return tuple(self.sites)

    def _site(self, name: str) -> Dict[str, int]:
        try:
            return self.sites[name]
        except KeyError:
            raise KeyError(
                f"policy for {self.stepper!r} has no site {name!r}; "
                f"covered sites: {list(self.sites)}"
            ) from None

    def k_array(self, sites: Optional[Sequence[str]] = None) -> np.ndarray:
        """Per-site tuned splits, ordered like ``sites`` (default: the
        artifact's own order) — a tracker's ``k0``."""
        names = self.site_names if sites is None else tuple(sites)
        return np.asarray([self._site(n)["k"] for n in names], np.int32)

    def bounds(self, sites: Optional[Sequence[str]] = None) -> Tuple[Tuple[int, int], ...]:
        """Per-site ``(k_lo, k_hi)`` hints for ``PrecisionConfig.k_bounds``."""
        names = self.site_names if sites is None else tuple(sites)
        return tuple((self._site(n)["k_lo"], self._site(n)["k_hi"]) for n in names)

    def apply(self, prec: PrecisionConfig, sites: Optional[Sequence[str]] = None) -> PrecisionConfig:
        """Config with this policy's floor/ceiling hints installed (ordered
        by ``sites`` — must match the tracker row order the run will use).
        Refuses a format mismatch: a policy tuned for one ``<EB,MB,FX>``
        says nothing about another."""
        if prec.fmt != self.fmt:
            raise ValueError(
                f"policy was profiled for fmt {self.fmt} but the run uses "
                f"{prec.fmt}; re-profile or match the format"
            )
        return dataclasses.replace(prec, k_bounds=self.bounds(sites))

    # -- (de)serialization --------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": SCHEMA,
            "schema_version": SCHEMA_VERSION,
            "stepper": self.stepper,
            "fmt": {"eb": self.fmt.eb, "mb": self.fmt.mb, "fx": self.fmt.fx},
            "ema": self.ema,
            "headroom": self.headroom,
            "sites": {
                n: {k: int(v) for k, v in d.items()} for n, d in self.sites.items()
            },
            "meta": self.meta,
            "validation": self.validation,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PrecisionPolicy":
        if d.get("schema") != SCHEMA:
            raise ValueError(f"not a {SCHEMA} artifact: schema={d.get('schema')!r}")
        ver = d.get("schema_version")
        if not isinstance(ver, int) or ver < 1 or ver > SCHEMA_VERSION:
            raise ValueError(
                f"unsupported {SCHEMA} schema_version {ver!r} "
                f"(this build reads <= {SCHEMA_VERSION})"
            )
        fmt = d["fmt"]
        return cls(
            stepper=d["stepper"],
            fmt=FlexFormat(int(fmt["eb"]), int(fmt["mb"]), int(fmt["fx"])),
            sites={n: {k: int(v) for k, v in s.items()} for n, s in d["sites"].items()},
            ema=float(d.get("ema", 0.95)),
            headroom=int(d.get("headroom", 1)),
            meta=dict(d.get("meta") or {}),
            validation=d.get("validation"),
        )

    def save(self, path: str) -> str:
        """Write the artifact (parent dirs created); returns ``path``."""
        payload = self.to_dict()
        payload.setdefault("meta", {}).setdefault("created_unix", time.time())
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        return path

    @classmethod
    def load(cls, path: str) -> "PrecisionPolicy":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def resolve_policy(
    prec: PrecisionConfig, policy, require_accepted: bool = True
) -> Tuple[PrecisionConfig, PrecisionPolicy]:
    """Derive a consumer's precision from a PrecisionPolicy artifact.

    The one shared implementation of the artifact-consumption gate — the LM
    serving path (``repro.serve.decode.resolve_policy`` is a thin shim over
    this) and the simulation-serving plane (``repro.service``) both resolve
    per-request artifacts here, so the rules can never drift:

    * ``policy`` may be a :class:`PrecisionPolicy` or a path to its JSON
      (``load`` applies the schema/version checks);
    * artifacts whose closed-loop validation never stamped them ``accepted``
      are refused (``require_accepted=False`` opts out, e.g. for dry-runs);
    * the returned config is re-based on the artifact's ``<EB,MB,FX>``
      format — a policy tuned for one format says nothing about another.

    Returns ``(prec, policy)``. The per-site ``[k_lo, k_hi]`` hints stay on
    the returned artifact: they are keyed by the *producer's* site names and
    only apply where the consumer threads a tracker with matching sites
    (``PrecisionPolicy.apply`` installs them positionally; a consumer with
    foreign site names must not).
    """
    if isinstance(policy, str):
        policy = PrecisionPolicy.load(policy)
    if require_accepted and not policy.accepted:
        raise ValueError(
            f"policy artifact for {policy.stepper!r} was never accepted by a "
            "validation replay; re-run `python -m repro.profile` or pass "
            "require_accepted=False"
        )
    return dataclasses.replace(prec, fmt=policy.fmt), policy
