"""Capture driver: one call from stepper name to hosted RangeProfile.

Thin glue over ``repro.pde.solver.Simulation`` — the capture itself lives
in the solver loops and the fused kernels; this module just runs a
simulation with capture on and hosts the result. PDE imports are lazy so
``repro.profile`` stays importable from low-level modules (the fused kernel
builder imports the capture primitives at module scope).
"""

from __future__ import annotations

from typing import Optional, Tuple

from repro.core.policy import PrecisionConfig

from .analysis import RangeProfile
from .capture import CaptureSpec

__all__ = ["capture_profile"]


def capture_profile(
    stepper,
    cfg=None,
    *,
    steps: int,
    prec: Optional[PrecisionConfig] = None,
    execution: str = "reference",
    snapshot_every: Optional[int] = None,
    spec: Optional[CaptureSpec] = None,
    state0=None,
) -> Tuple[RangeProfile, "SimResult"]:  # noqa: F821 — lazy pde import
    """Run ``steps`` of a registered stepper with range capture on.

    ``prec`` defaults to f32 — profile the oracle trajectory — but any mode
    works (profiling under ``rr_tracked`` observes exactly the evidence the
    adjust unit saw, which is what the autotuner's convergence-match
    guarantee is stated against). Returns ``(RangeProfile, SimResult)`` so
    callers keep the run's final state/tracker alongside the profile.
    """
    from repro.pde.solver import Simulation  # lazy: no pde import at module scope

    prec = PrecisionConfig(mode="f32") if prec is None else prec
    spec = CaptureSpec() if spec is None else spec
    sim = Simulation(stepper, cfg, prec)
    res = sim.run(
        steps,
        snapshot_every=snapshot_every,
        state0=state0,
        execution=execution,
        capture=spec,
    )
    profile = RangeProfile(
        sim.stepper.name,
        sim.stepper.sites,
        spec,
        prec,
        steps,
        execution,
        res.profile,
        site_ops=getattr(sim.stepper, "site_ops", None) or None,
    )
    return profile, res
