"""repro.obs.health — live numerics-health monitoring over the obs registry.

PR 9 made the precision runtime observable; this module makes it *watched*.
One :class:`HealthMonitor` rides a :class:`repro.obs.Observability` scope
and layers four things on top of the recording substrate (DESIGN.md §16):

* **anomaly detectors** over the precision-telemetry stream — pure,
  step-indexed functions of the per-(scope, site) series (no wall clock,
  no RNG): overflow-storm detection on §5.3 grow-counter rates *and* on
  non-finite fractions in streamed snapshot frames (a starved pinned
  deployment overflows without ever touching its grow counters — the
  adjust unit is out of the loop, so the state itself is the only
  witness); k-thrash detection on grow/shrink oscillation; evidence-
  coverage-drop alarms. The same telemetry stream always produces the
  same alert sequence (:func:`run_detectors` is the offline replay of the
  exact per-series law the live monitor applies incrementally).
* **shadow-oracle sampling** (:mod:`repro.obs.shadow`) — a deterministic
  low-rate sampler replays completed service requests at f32 and books the
  rel-L2 drift into the error-budget metrics.
* a **declarative SLO rule set** (:class:`SLORule`) over rolling windows —
  p99 chunk latency (from the registry histogram via
  :func:`repro.obs.metrics.histogram_quantile`, not the raw sample
  window), error-budget burn, thrash rate, queue depth — evaluated at
  chunk boundaries; breaches fire on the rising edge.
* a bounded **flight recorder** (:mod:`repro.obs.flightrec`) dumped on any
  alert or request failure.

Alerts go four places at once: the monitor's ``alerts`` list, a
``health.alert`` instant in the trace, the ``repro_health_alerts_total``
counter, and a flight-recorder dump. ``python -m repro.obs.health`` is the
operator surface: offline detector replay over exported artifacts,
``--watch`` (scrape server over an artifact directory), and ``--smoke``
(the CI gate: clean burst must exit 0, ``--burst storm`` must exit
nonzero).

Everything here is passive (DESIGN.md §15): hooks observe host-side values
the service already materialised; served states, snapshots and tracker
bits are bit-identical with the monitor on or off
(``tests/test_health.py``).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import repro.obs as obs

from .flightrec import FlightRecorder, load_flightrec
from .precision import PrecisionTelemetry, SiteSeries
from .shadow import ShadowJob, ShadowSampler, nonfinite_fraction

__all__ = [
    "Alert",
    "SLORule",
    "DEFAULT_SLOS",
    "HealthConfig",
    "HealthMonitor",
    "detect_series",
    "run_detectors",
    "enable",
    "disable",
    "active",
]

VERDICT_SCHEMA = "repro.obs/health@1"


# ---------------------------------------------------------------------------
# alerts
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Alert:
    """One detector/SLO firing. ``step`` is the telemetry boundary step (or
    the chunk sequence number for SLO breaches) — never a wall-clock time,
    so alert sequences are comparable across runs."""

    kind: str  # overflow_storm | k_thrash | coverage_drop | slo_breach
    scope: str  # telemetry scope, request scope, or SLO rule name
    site: str  # site name ("" when not site-scoped)
    step: int
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def key(self) -> Tuple[str, str, str, int]:
        return (self.kind, self.scope, self.site, self.step)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        at = f"{self.scope}:{self.site}" if self.site else self.scope
        brief = ", ".join(f"{k}={v}" for k, v in sorted(self.detail.items()))
        return f"[{self.kind}] {at} @ step {self.step}" + (
            f" ({brief})" if brief else ""
        )


# ---------------------------------------------------------------------------
# declarative SLO rules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLORule:
    """One service-level objective: ``metric op threshold`` over a rolling
    window. ``metric`` names a monitor-computed value:

    * ``chunk_latency_p<NN>_us`` — bucket-estimated latency percentile of
      the ``repro_service_chunk_latency_seconds`` histogram (µs);
    * ``error_budget_burn`` — fraction of recently shadowed requests whose
      rel-L2 drift exceeded ``HealthConfig.err_budget``;
    * ``thrash_rate`` — k-thrash alerts per chunk over the last ``window``
      chunks;
    * ``queue_depth`` — the scheduler's admission queue length.

    ``op`` is ``"<="`` or ``">="`` (the healthy direction). A NaN metric
    value (no data yet) never breaches.
    """

    name: str
    metric: str
    op: str
    threshold: float
    window: int = 32

    def __post_init__(self):
        if self.op not in ("<=", ">="):
            raise ValueError(f"SLO op must be '<=' or '>=', got {self.op!r}")
        if self.window <= 0:
            raise ValueError(f"SLO window must be positive: {self.window}")

    def ok(self, value: float) -> bool:
        if value != value:  # NaN: no data, no breach
            return True
        return value <= self.threshold if self.op == "<=" else value >= self.threshold

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SLORule":
        return cls(
            name=d["name"],
            metric=d["metric"],
            op=d["op"],
            threshold=float(d["threshold"]),
            window=int(d.get("window", 32)),
        )


#: default rule set — generous enough that a healthy smoke burst is silent,
#: tight enough that an overflowing one is not
DEFAULT_SLOS: Tuple[SLORule, ...] = (
    SLORule("chunk_latency", "chunk_latency_p99_us", "<=", 10e6),
    SLORule("error_budget", "error_budget_burn", "<=", 0.5),
    SLORule("thrash", "thrash_rate", "<=", 0.5),
    SLORule("queue", "queue_depth", "<=", 256.0),
)


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Knobs of the monitoring plane (thresholds are per-window, windows
    are in telemetry boundary samples / chunks — never seconds)."""

    window: int = 8  # boundary samples per detector window
    grow_rate: float = 0.25  # §5.3 grow events per step that call a storm
    grow_min_events: int = 4  # ... with at least this many events in-window
    thrash_reversals: int = 3  # k direction reversals in-window
    coverage_min: float = 0.9  # evidence-coverage floor
    nonfinite_frac: float = 0.0  # frame non-finite fraction above this alerts
    shadow_rate: float = 0.0  # fraction of requests shadow-replayed at f32
    err_budget: float = 1e-2  # rel-L2 budget per shadowed request
    shadow_window: int = 64  # shadowed requests in the burn window
    slos: Tuple[SLORule, ...] = DEFAULT_SLOS
    flight_capacity: int = 512
    flight_dir: str = "artifacts/flightrec"
    max_dumps: int = 16


# ---------------------------------------------------------------------------
# detectors — pure functions of one telemetry series
# ---------------------------------------------------------------------------

def _reversals(ks: Sequence[int]) -> int:
    """Direction reversals in a k trajectory: the number of sign flips in
    the sequence of non-zero first differences (grow->shrink or
    shrink->grow counts one each)."""
    dirs = []
    for a, b in zip(ks, ks[1:]):
        d = (b > a) - (b < a)
        if d != 0:
            dirs.append(d)
    return sum(1 for a, b in zip(dirs, dirs[1:]) if a != b)


def detect_series(series: SiteSeries, config: HealthConfig) -> List[Alert]:
    """Every alert one (scope, site) series has earned, in boundary order.

    Pure and deterministic: depends only on the series' step-indexed
    samples and the config — no wall clock, no monitor state. Each kind
    fires at most once per series (at its first qualifying boundary), so
    the returned list only ever *grows* as the series grows; the live
    monitor exploits exactly that to emit incrementally
    (``offline[len(already_emitted):]`` is always the fresh suffix).
    """
    alerts: List[Alert] = []
    n = len(series.steps)
    W = max(1, config.window)
    fired_storm = fired_thrash = False
    for i in range(n):
        if fired_storm and fired_thrash:
            break
        lo = max(0, i - W + 1)
        base_g = series.grew[lo - 1] if lo > 0 else 0
        base_s = series.steps[lo - 1] if lo > 0 else 0
        dg = series.grew[i] - base_g
        ds = series.steps[i] - base_s
        if (
            not fired_storm
            and dg >= config.grow_min_events
            and ds > 0
            and dg / ds >= config.grow_rate
        ):
            fired_storm = True
            alerts.append(Alert(
                "overflow_storm", series.scope, series.site, series.steps[i],
                {"signal": "grow_rate", "grew": int(dg), "steps": int(ds),
                 "rate": dg / ds},
            ))
        if not fired_thrash:
            rev = _reversals(series.k[lo : i + 1])
            if rev >= config.thrash_reversals:
                fired_thrash = True
                alerts.append(Alert(
                    "k_thrash", series.scope, series.site, series.steps[i],
                    {"reversals": int(rev), "window": W,
                     "k": [int(k) for k in series.k[lo : i + 1]]},
                ))
    if (
        series.coverage is not None
        and series.coverage < config.coverage_min
        and series.steps
    ):
        alerts.append(Alert(
            "coverage_drop", series.scope, series.site, series.steps[-1],
            {"coverage": float(series.coverage),
             "floor": config.coverage_min},
        ))
    return alerts


def run_detectors(
    telemetry: PrecisionTelemetry, config: Optional[HealthConfig] = None
) -> List[Alert]:
    """Offline detector replay over a whole telemetry stream — series in
    sorted (scope, site) order, each through :func:`detect_series`. Used by
    the CLI report mode and the determinism tests: same stream in, same
    alert sequence out, always."""
    config = config or HealthConfig()
    out: List[Alert] = []
    for s in telemetry.all_series():
        out.extend(detect_series(s, config))
    return out


# ---------------------------------------------------------------------------
# the live monitor
# ---------------------------------------------------------------------------

class HealthMonitor:
    """The monitoring plane over one obs scope (see module docstring).

    Construct it after :func:`repro.obs.enable` (or let :func:`enable`
    below do both) and **before** the :class:`~repro.service.scheduler.
    SimService`, so the service's metrics land in the registry the SLO
    rules read.
    """

    def __init__(
        self,
        config: Optional[HealthConfig] = None,
        scope: Optional[obs.Observability] = None,
    ):
        self.config = config or HealthConfig()
        if scope is None:
            scope = obs.active() or obs.enable()
        self.obs = scope
        self.flight = FlightRecorder(capacity=self.config.flight_capacity)
        self.alerts: List[Alert] = []
        self.dump_paths: List[str] = []
        self._emitted: Dict[Tuple[str, str], int] = {}  # per-series alert count
        self._frame_alerted: set = set()  # request ids already frame-stormed
        self._slo_breached: Dict[str, bool] = {}
        self._slo_last: Dict[str, float] = {}
        self._chunk_seq = 0
        self._queue_depth = 0
        self._active_members = 0
        self._sampler = ShadowSampler(self.config.shadow_rate)
        self._pending: Dict[int, ShadowJob] = {}
        #: request id -> shadow rel-L2, for every completed shadow replay
        #: (the per-request view behind the rolling burn window; the bench
        #: suite attributes error budget to cells from it)
        self.shadow_rel: Dict[int, float] = {}
        self._shadow_window: Deque[Tuple[int, float]] = deque(
            maxlen=max(1, self.config.shadow_window)
        )
        self._thrash_seqs: Deque[int] = deque(maxlen=4096)
        reg = self.obs.registry
        self._alert_counter = reg.counter(
            "repro_health_alerts_total", "health alerts fired, by kind"
        )
        self._shadow_sampled = reg.counter(
            "repro_health_shadow_sampled_total", "requests shadow-replayed at f32"
        )
        self._shadow_breaches = reg.counter(
            "repro_health_shadow_breach_total",
            "shadow replays whose rel-L2 drift exceeded the error budget",
        )
        self._shadow_seconds = reg.counter(
            "repro_health_shadow_seconds_total", "wall seconds in shadow replays"
        )
        self._burn_gauge = reg.gauge(
            "repro_health_error_budget_burn",
            "breaching fraction of the recent shadow window",
        )
        self._rel_gauge = reg.gauge(
            "repro_health_shadow_rel_l2", "rel-L2 drift of the last shadow replay"
        )
        self._queue_gauge = reg.gauge(
            "repro_health_queue_depth", "admission queue length at last chunk"
        )
        self._latency_hist = reg.histogram(
            "repro_service_chunk_latency_seconds",
            "steady-state chunk wall time (compile calls excluded)",
        )

    # -- service hooks (all passive, all no-throw into the primary path) -----

    def on_submit(self, rec) -> None:
        """Admission: record the lifecycle event and, when the deterministic
        sampler picks this request, capture its shadow job (host copies)."""
        self.flight.record(
            "submit", request=rec.id, bucket=rec.key.short(), steps=rec.steps
        )
        if self._sampler.pick():
            self._pending[rec.id] = ShadowJob.capture(rec)
            self.flight.record("shadow_pick", request=rec.id)

    def note_occupancy(self, queued: int, active: int) -> None:
        self._queue_depth = int(queued)
        self._active_members = int(active)
        self._queue_gauge.set(queued)

    def on_chunk(
        self, key, n_members: int, steps: int, seconds: float, compiled: bool
    ) -> None:
        """Chunk boundary: the evaluation point. Records the chunk, sweeps
        the telemetry detectors, evaluates the SLO rules."""
        self._chunk_seq += 1
        self.flight.record(
            "chunk", seq=self._chunk_seq, bucket=key.short(), members=n_members,
            steps=steps, seconds=seconds, compiled=compiled,
        )
        self.sweep()
        self._eval_slos()

    def observe_frame(self, rec, frame) -> None:
        """A streamed snapshot frame (already a host numpy pytree in the
        batcher). Non-finite content is the direct overflow signal — the
        one a starved *pinned* deployment gives, since its adjust unit
        never bumps a grow counter."""
        frac = nonfinite_fraction(frame)
        if frac > self.config.nonfinite_frac and rec.id not in self._frame_alerted:
            self._frame_alerted.add(rec.id)
            self._fire(Alert(
                "overflow_storm", f"req{rec.id}:{rec.key.stepper}", "",
                rec.elapsed,
                {"signal": "nonfinite", "fraction": frac},
            ))

    def on_tracker(self, rec, chunk_steps: int) -> None:
        """Carried-k sample for the flight recorder (telemetry itself is
        drained by the batcher's existing ``obs.record_tracker``)."""
        st = rec.tracker.state
        self.flight.record(
            "tracker", request=rec.id, step=rec.elapsed + chunk_steps,
            k=[int(k) for k in st.k],
        )

    def on_request_done(self, rec) -> None:
        self.flight.record(
            "done", request=rec.id, steps=rec.elapsed, chunks=rec.chunks
        )
        job = self._pending.pop(rec.id, None)
        if job is None or rec.result is None:
            return
        t0 = time.perf_counter()
        rel = job.replay(rec.result.state)
        dt = time.perf_counter() - t0
        self._shadow_sampled.inc()
        self._shadow_seconds.inc(dt)
        self._rel_gauge.set(rel)
        breach = not (rel <= self.config.err_budget)  # NaN/inf breach too
        if breach:
            self._shadow_breaches.inc()
        self._shadow_window.append((rec.id, rel))
        self.shadow_rel[rec.id] = rel
        self._burn_gauge.set(self.error_budget_burn())
        self.flight.record(
            "shadow", request=rec.id, rel_l2=rel, budget=self.config.err_budget,
            breach=breach, seconds=dt,
        )

    def on_request_failed(self, rec, error: str) -> None:
        self.flight.record(
            "failed", request=rec.id, steps=rec.elapsed, error=str(error)
        )
        self._pending.pop(rec.id, None)
        self.dump(f"request_failed_req{rec.id}")

    # -- detector sweep ------------------------------------------------------

    def sweep(self) -> None:
        """Incremental detector pass over every telemetry series: emit the
        suffix of :func:`detect_series` beyond what this monitor already
        fired (per-series fire-once makes the suffix well-defined)."""
        tel = self.obs.telemetry
        if tel is None:
            return
        for s in tel.all_series():
            key = (s.scope, s.site)
            seen = self._emitted.get(key, 0)
            fresh = detect_series(s, self.config)[seen:]
            if fresh:
                self._emitted[key] = seen + len(fresh)
                for a in fresh:
                    self._fire(a)

    def _fire(self, alert: Alert) -> None:
        self.alerts.append(alert)
        if alert.kind == "k_thrash":
            self._thrash_seqs.append(self._chunk_seq)
        self._alert_counter.inc(kind=alert.kind)
        if self.obs.tracer is not None:
            self.obs.tracer.instant(
                "health.alert", kind=alert.kind, scope=alert.scope,
                site=alert.site, step=alert.step,
            )
        self.flight.record("alert", alert=alert.to_dict())
        self.dump(alert.kind)

    # -- SLO evaluation ------------------------------------------------------

    def error_budget_burn(self) -> float:
        """Breaching fraction of the rolling shadow window (NaN with no
        shadowed requests yet)."""
        if not self._shadow_window:
            return float("nan")
        bad = sum(
            1 for _, rel in self._shadow_window
            if not (rel <= self.config.err_budget)
        )
        return bad / len(self._shadow_window)

    def _metric_value(self, rule: SLORule) -> float:
        m = rule.metric
        if m.startswith("chunk_latency_p") and m.endswith("_us"):
            pct = float(m[len("chunk_latency_p") : -len("_us")])
            return self._latency_hist.quantile(pct / 100.0) * 1e6
        if m == "error_budget_burn":
            return self.error_budget_burn()
        if m == "thrash_rate":
            if self._chunk_seq == 0:
                return float("nan")
            floor_seq = self._chunk_seq - rule.window
            recent = sum(1 for s in self._thrash_seqs if s > floor_seq)
            return recent / min(rule.window, self._chunk_seq)
        if m == "queue_depth":
            return float(self._queue_depth)
        return float("nan")  # unknown metric: no data, never breaches

    def _eval_slos(self) -> None:
        for rule in self.config.slos:
            value = self._metric_value(rule)
            self._slo_last[rule.name] = value
            ok = rule.ok(value)
            was_breached = self._slo_breached.get(rule.name, False)
            if not ok and not was_breached:
                self._fire(Alert(
                    "slo_breach", rule.name, "", self._chunk_seq,
                    {"metric": rule.metric, "op": rule.op, "value": value,
                     "threshold": rule.threshold, "window": rule.window},
                ))
            self._slo_breached[rule.name] = not ok

    # -- verdict / dumps -----------------------------------------------------

    def alerting(self) -> bool:
        return bool(self.alerts)

    def verdict(self) -> Dict[str, Any]:
        """The JSON health verdict (the ``/health`` endpoint body)."""
        by_kind: Dict[str, int] = {}
        for a in self.alerts:
            by_kind[a.kind] = by_kind.get(a.kind, 0) + 1
        return {
            "schema": VERDICT_SCHEMA,
            "status": "alerting" if self.alerts else "ok",
            "alerts": {"total": len(self.alerts), "by_kind": by_kind},
            "slo": {
                rule.name: {
                    "metric": rule.metric,
                    "op": rule.op,
                    "threshold": rule.threshold,
                    "window": rule.window,
                    "value": self._slo_last.get(rule.name, float("nan")),
                    "ok": not self._slo_breached.get(rule.name, False),
                }
                for rule in self.config.slos
            },
            "shadow": {
                "rate": self.config.shadow_rate,
                "sampled": int(self._shadow_sampled.total()),
                "breaches": int(self._shadow_breaches.total()),
                "budget": self.config.err_budget,
                "burn": self.error_budget_burn(),
                "seconds": self._shadow_seconds.total(),
            },
            "chunks": self._chunk_seq,
            "queue_depth": self._queue_depth,
            "active_members": self._active_members,
            "flight_dumps": list(self.dump_paths),
        }

    def dump(self, reason: str) -> Optional[str]:
        """Flight-recorder dump (bounded by ``max_dumps``); returns the
        path, or None once the budget is spent."""
        if len(self.dump_paths) >= self.config.max_dumps:
            return None
        path = self.flight.dump(
            self.config.flight_dir, reason,
            metrics=self.obs.registry.export_json(),
            verdict=self.verdict(),
        )
        self.dump_paths.append(path)
        return path


# ---------------------------------------------------------------------------
# process-wide install (mirrors repro.obs.enable/disable/active)
# ---------------------------------------------------------------------------

_MONITOR: Optional[HealthMonitor] = None


def enable(config: Optional[HealthConfig] = None, **overrides) -> HealthMonitor:
    """Install the process-wide health monitor (enabling ``repro.obs``
    first if needed). Keyword overrides are :class:`HealthConfig` fields:
    ``enable(shadow_rate=0.25, flight_dir=...)``. Idempotent in the same
    sense as ``obs.enable``: a second call replaces the monitor."""
    global _MONITOR
    if config is None:
        config = HealthConfig(**overrides)
    elif overrides:
        config = dataclasses.replace(config, **overrides)
    _MONITOR = HealthMonitor(config)
    return _MONITOR


def disable() -> None:
    """Remove the monitor; every service hook reverts to a no-op. (Leaves
    ``repro.obs`` itself as-is.)"""
    global _MONITOR
    _MONITOR = None


def active() -> Optional[HealthMonitor]:
    return _MONITOR


# ---------------------------------------------------------------------------
# CLI: offline report / --watch / --smoke
# ---------------------------------------------------------------------------

def offline_verdict(
    art_dir: str, config: Optional[HealthConfig] = None
) -> Dict[str, Any]:
    """Detector replay over an exported artifact directory (telemetry.json
    if present), shaped like :meth:`HealthMonitor.verdict`."""
    config = config or HealthConfig()
    alerts: List[Alert] = []
    tel_path = os.path.join(art_dir, "telemetry.json")
    source = None
    if os.path.exists(tel_path):
        from .precision import load_telemetry

        alerts = run_detectors(load_telemetry(tel_path), config)
        source = tel_path
    by_kind: Dict[str, int] = {}
    for a in alerts:
        by_kind[a.kind] = by_kind.get(a.kind, 0) + 1
    return {
        "schema": VERDICT_SCHEMA,
        "status": "alerting" if alerts else "ok",
        "alerts": {"total": len(alerts), "by_kind": by_kind},
        "alert_list": [a.to_dict() for a in alerts],
        "telemetry": source,
        "mode": "offline",
    }


def run_report(art_dir: str) -> int:
    v = offline_verdict(art_dir)
    if v["telemetry"] is None:
        print(f"(telemetry.json: not found in {art_dir} — nothing to detect on)")
    print(f"health: {v['status']} ({v['alerts']['total']} alert(s))")
    for a in v["alert_list"]:
        print("  " + str(Alert(**{k: a[k] for k in
                                  ("kind", "scope", "site", "step", "detail")})))
    return 1 if v["alerts"]["total"] else 0


def run_watch(art_dir: str, port: int, interval: float) -> int:
    """Serve ``/metrics``, ``/health`` and ``/telemetry`` over an artifact
    directory, recomputing the offline verdict on demand."""
    from .server import HealthServer

    def metrics_text() -> str:
        p = os.path.join(art_dir, "metrics.prom")
        if not os.path.exists(p):
            return "# no metrics.prom in " + art_dir + "\n"
        with open(p) as f:
            return f.read()

    def telemetry_doc() -> Dict[str, Any]:
        p = os.path.join(art_dir, "telemetry.json")
        if not os.path.exists(p):
            return {"error": f"no telemetry.json in {art_dir}"}
        with open(p) as f:
            return json.load(f)

    server = HealthServer(
        metrics_fn=metrics_text,
        health_fn=lambda: offline_verdict(art_dir),
        telemetry_fn=telemetry_doc,
        port=port,
    )
    server.start()
    print(f"watching {art_dir} at {server.url} "
          f"(/metrics /health /telemetry; ctrl-c to stop)")
    try:
        while True:
            time.sleep(interval)
    except KeyboardInterrupt:
        return 0
    finally:
        server.stop()


# -- smoke: the CI gate ------------------------------------------------------

def _starved_policy(stepper_name: str):
    """A validation-stamped PrecisionPolicy pinning every site at the
    starved split k=0 — the 'stale artifact' that induces the storm burst
    (tuned against a workload whose dynamic range the live traffic no
    longer matches)."""
    from repro.core.policy import PRESETS
    from repro.pde.registry import get_stepper
    from repro.profile.artifact import PrecisionPolicy

    fmt = PRESETS["r2f2_16"].fmt
    sites = {name: {"k": 0, "k_lo": 0, "k_hi": 0}
             for name in get_stepper(stepper_name).sites}
    return PrecisionPolicy(
        stepper=stepper_name,
        fmt=fmt,
        sites=sites,
        validation={"accepted": True, "note": "synthetic starved policy (smoke)"},
    )


def _storm_burst(svc, members: int = 3):
    """Submit the storm traffic: pinned rr_tracked advection members whose
    initial pulses (amplitude 1e5) overflow the starved k=0 split."""
    import dataclasses as _dc

    from repro.core.policy import PRESETS
    from repro.pde.advection1d import AdvectionConfig
    from repro.service import SimRequest
    from repro.service.request import scaled_state0

    cfg = AdvectionConfig(nx=64, amplitude=1.0)
    prec = _dc.replace(PRESETS["r2f2_16"], mode="rr_tracked", pinned=True)
    policy = _starved_policy("advection1d")
    handles = []
    for m in range(members):
        handles.append(svc.submit(SimRequest(
            "advection1d", steps=32, precision=prec, cfg=cfg, policy=policy,
            snapshot_every=8,
            state0=scaled_state0(
                "advection1d", scale=(1.0 + 0.1 * m) * 1e5,
                overrides={"nx": 64, "amplitude": 1.0},
            ),
        )))
    return handles


def run_smoke(out_dir: str, burst: str = "clean") -> int:
    """The exit-code-gated self-check.

    ``clean``: serve a healthy burst under the monitor; the scrape server
    must round-trip, a synthetic telemetry stream must fire a detector and
    produce a loadable flight dump, and the real burst must stay silent.
    Exit 0 on pass, 2 on any failure.

    ``storm``: serve the starved-pinned advection burst; exits 3 (nonzero,
    by design — this is the alarm working) when an ``overflow_storm``
    alert fired AND its flight dump reloads, else 0 so CI's negated
    invocation catches a dead detector.
    """
    failures: List[str] = []

    def check(ok: bool, what: str) -> None:
        print(("  ok   " if ok else "  FAIL ") + what)
        if not ok:
            failures.append(what)

    from repro.service import SimRequest, SimService

    flight_dir = os.path.join(out_dir, "flightrec")

    if burst == "storm":
        print("health smoke: storm burst (starved pinned policy, hot traffic)")
        obs.enable(sample=1.0)
        try:
            monitor = enable(shadow_rate=1.0, flight_dir=flight_dir)
            svc = SimService()
            _storm_burst(svc)
            svc.run_until_idle()
            obs.export(out_dir)
        finally:
            disable()
            obs.disable()
        storm_alerts = [a for a in monitor.alerts if a.kind == "overflow_storm"]
        print(f"  {len(monitor.alerts)} alert(s), "
              f"{len(storm_alerts)} overflow_storm, "
              f"{len(monitor.dump_paths)} flight dump(s)")
        for a in monitor.alerts:
            print("    " + str(a))
        dump_ok = False
        if monitor.dump_paths:
            try:
                load_flightrec(monitor.dump_paths[0])
                dump_ok = True
            except (ValueError, OSError) as e:
                print(f"  flight dump failed to reload: {e}")
        if storm_alerts and dump_ok:
            print("storm burst alerted (exit 3 — the alarm works)")
            return 3
        print("SMOKE FAIL: storm burst did not alert (or dump unreadable)")
        return 0  # CI negates this invocation; silence here must read as failure

    print("health smoke: clean burst with the monitor enabled")
    obs.enable(sample=1.0)
    try:
        monitor = enable(shadow_rate=1.0, flight_dir=flight_dir)
        svc = SimService()
        handles = [
            svc.submit(SimRequest("heat1d", steps=64, precision="f32",
                                  snapshot_every=16)),
            svc.submit(SimRequest("heat1d", steps=64, precision="rr_tracked",
                                  snapshot_every=16)),
        ]
        svc.run_until_idle()
        for h in handles:
            h.result()

        # 1. scrape round-trip against the live monitor
        from urllib.request import urlopen

        from .metrics import parse_prometheus
        from .server import HealthServer

        server = HealthServer.for_monitor(monitor)
        server.start()
        try:
            with urlopen(server.url + "/metrics", timeout=10) as r:
                families = parse_prometheus(r.read().decode())
            check("repro_health_alerts_total" in families
                  and "repro_service_chunk_latency_seconds" in families,
                  f"/metrics round-trips the strict parser "
                  f"({len(families)} families)")
            with urlopen(server.url + "/health", timeout=10) as r:
                verdict = json.loads(r.read().decode())
            check(verdict.get("schema") == VERDICT_SCHEMA
                  and verdict.get("status") == "ok",
                  f"/health verdict is ok ({verdict.get('status')})")
            with urlopen(server.url + "/telemetry", timeout=10) as r:
                tel_doc = json.loads(r.read().decode())
            check(tel_doc.get("schema") == "repro.obs/telemetry@1",
                  "/telemetry serves the telemetry schema")
        finally:
            server.stop()

        # 2. the clean burst stayed silent, and shadowing actually ran
        check(not monitor.alerts,
              f"clean burst fired no alerts ({len(monitor.alerts)})")
        sampled = int(monitor._shadow_sampled.total())
        burn = monitor.error_budget_burn()
        check(sampled >= 1 and burn == 0.0,
              f"shadow oracle sampled {sampled} request(s), burn {burn}")
        obs.export(out_dir)
    finally:
        disable()
        obs.disable()

    # 3. a synthetic overflow storm fires the detector and dumps a loadable
    #    flight recording (a private scope — nothing touches the real burst)
    synth_scope = obs.Observability(trace=True, telemetry=True)
    synth = HealthMonitor(
        HealthConfig(flight_dir=os.path.join(flight_dir, "synthetic")),
        scope=synth_scope,
    )
    series = synth_scope.telemetry.series("synthetic", "site0")
    for b in range(8):
        series.append(step=(b + 1) * 4, k=3, grew=(b + 1) * 4, shrank=0)
    synth.sweep()
    kinds = [a.kind for a in synth.alerts]
    check(kinds == ["overflow_storm"],
          f"synthetic storm stream fires exactly one overflow_storm ({kinds})")
    dump_ok = False
    if synth.dump_paths:
        try:
            doc = load_flightrec(synth.dump_paths[0])
            dump_ok = doc["verdict"]["status"] == "alerting"
        except (ValueError, OSError) as e:
            print(f"  flight dump reload: {e}")
    check(dump_ok, "synthetic alert's flight-recorder dump reloads and validates")

    if failures:
        print(f"SMOKE FAIL: {len(failures)} check(s) failed")
        return 2
    print(f"health smoke passed; artifacts in {out_dir}")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.health",
        description="Numerics-health monitor: offline detector replay, "
                    "artifact watcher, CI smoke gate.",
    )
    ap.add_argument("--dir", default="artifacts/obs",
                    help="artifact directory to report on (default: %(default)s)")
    ap.add_argument("--watch", action="store_true",
                    help="serve /metrics /health /telemetry over --dir")
    ap.add_argument("--port", type=int, default=0,
                    help="watch-mode port (default: ephemeral)")
    ap.add_argument("--interval", type=float, default=2.0,
                    help="watch-mode poll interval in seconds")
    ap.add_argument("--smoke", action="store_true",
                    help="serve an instrumented burst and gate the health "
                         "contract (CI mode; exit 2 on failure)")
    ap.add_argument("--burst", choices=("clean", "storm"), default="clean",
                    help="smoke burst flavour: 'clean' must exit 0, 'storm' "
                         "must exit nonzero (the alarm firing)")
    ap.add_argument("--out", default=None,
                    help="smoke-mode export directory (default: --dir)")
    args = ap.parse_args(argv)
    if args.smoke:
        return run_smoke(args.out or args.dir, burst=args.burst)
    if args.watch:
        return run_watch(args.dir, args.port, args.interval)
    return run_report(args.dir)


if __name__ == "__main__":
    # ``python -m repro.obs.health`` executes this file as ``__main__`` —
    # a SECOND module object. enable() must install the monitor on the
    # canonical ``repro.obs.health`` module (the one the service hooks
    # import), so delegate to that copy's main().
    from repro.obs.health import main as _canonical_main

    sys.exit(_canonical_main())
