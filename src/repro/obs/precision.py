"""Precision telemetry: per-site carried-k time series, §5.3 counters,
evidence-coverage fractions — drained from trackers at chunk boundaries.

The adjust unit's state (the flexible split ``k``, the exponent EMAs, the
§5.3 grow/shrink counters) already exists on every plane; what was missing
is a surface that *watches* it. :class:`PrecisionTelemetry` accumulates,
per ``(scope, site)``:

* the **k time series** — ``(step, k)`` samples at chunk boundaries;
* the **adjustment counters** — cumulative ``grew``/``shrank`` at each
  sample (the paper's §5.3 adjustment statistics as a trajectory, not just
  a final total);
* optionally a **coverage fraction** — how many of the run's multiply/op
  issues the final carried split covers without an adjust event, computed
  from the capture plane's evidence stream.

Two feeding paths, both passive (DESIGN.md §15):

* the **service plane** drains each member's carried tracker right after a
  bucket chunk returns (:meth:`record_tracker` — the tracker is already on
  its way to the host there, so the drain adds one ``np.asarray`` per
  site);
* the **solver planes** record the final tracker after ``Simulation.run``,
  and — when the run captured range evidence — reconstruct the full
  per-chunk-boundary series by replaying the captured evidence through the
  adjust law itself (:func:`replay_k_series` drives
  ``repro.precision.fold_evidence``, the same §5.3 math every plane
  applies), reusing the existing evidence stream with **no new kernel
  outputs**. The replayed boundary k provably equals the carried tracker's
  (tested in ``tests/test_obs.py``).

Module-level imports are numpy-only; everything that needs jax or
``repro.precision`` imports lazily, so the reporter can load exported
telemetry artifacts on a machine without an accelerator stack.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "PrecisionTelemetry",
    "SiteSeries",
    "replay_k_series",
    "coverage_fraction",
    "load_telemetry",
]

SCHEMA = "repro.obs/telemetry@1"


class SiteSeries:
    """One (scope, site) trajectory: parallel step/k/grew/shrank lists."""

    __slots__ = ("scope", "site", "steps", "k", "grew", "shrank", "coverage")

    def __init__(self, scope: str, site: str):
        self.scope = scope
        self.site = site
        self.steps: List[int] = []
        self.k: List[int] = []
        self.grew: List[int] = []
        self.shrank: List[int] = []
        self.coverage: Optional[float] = None  # at the final carried k

    def append(self, step: int, k: int, grew: int, shrank: int) -> None:
        self.steps.append(int(step))
        self.k.append(int(k))
        self.grew.append(int(grew))
        self.shrank.append(int(shrank))

    def to_dict(self) -> Dict[str, Any]:
        return {
            "scope": self.scope,
            "site": self.site,
            "steps": self.steps,
            "k": self.k,
            "grew": self.grew,
            "shrank": self.shrank,
            "coverage": self.coverage,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SiteSeries":
        s = cls(d["scope"], d["site"])
        s.steps = [int(x) for x in d["steps"]]
        s.k = [int(x) for x in d["k"]]
        s.grew = [int(x) for x in d["grew"]]
        s.shrank = [int(x) for x in d["shrank"]]
        s.coverage = d.get("coverage")
        return s

    def __repr__(self) -> str:
        ks = "->".join(str(k) for k in self.k) or "?"
        return f"SiteSeries({self.scope}:{self.site}, k {ks})"


class PrecisionTelemetry:
    """The accumulator (see module docstring). Keyed by (scope, site)."""

    def __init__(self):
        self._series: Dict[Tuple[str, str], SiteSeries] = {}
        self._scope_seq: Dict[str, int] = {}

    # -- recording -----------------------------------------------------------

    def series(self, scope: str, site: str) -> SiteSeries:
        key = (scope, site)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = SiteSeries(scope, site)
        return s

    def unique_scope(self, prefix: str) -> str:
        """A fresh scope name under ``prefix`` (``sim:heat1d``,
        ``sim:heat1d#2``, ...) so repeated solo runs never interleave."""
        n = self._scope_seq.get(prefix, 0) + 1
        self._scope_seq[prefix] = n
        return prefix if n == 1 else f"{prefix}#{n}"

    def record_tracker(self, scope: str, tracker, step: int) -> None:
        """Drain one SiteTracker snapshot (host-side arrays) at ``step``.

        Safe to call with ``tracker=None`` (no-op). The caller is
        responsible for only passing concrete (non-traced) trackers —
        ``repro.obs.record_tracker`` guards that."""
        if tracker is None:
            return
        st = tracker.state
        k = np.asarray(st.k)
        grew = np.asarray(st.overflow_steps)
        shrank = np.asarray(st.shrink_steps)
        for i, name in enumerate(tracker.names):
            self.series(scope, name).append(step, k[i], grew[i], shrank[i])

    def record_series(
        self,
        scope: str,
        sites: Sequence[str],
        steps: Sequence[int],
        k: np.ndarray,
        grew: np.ndarray,
        shrank: np.ndarray,
        coverage: Optional[Dict[str, float]] = None,
    ) -> None:
        """Install a whole reconstructed trajectory (``k``/``grew``/
        ``shrank`` are ``(n_boundaries, n_sites)``)."""
        for j, name in enumerate(sites):
            s = self.series(scope, name)
            for b, step in enumerate(steps):
                s.append(step, k[b, j], grew[b, j], shrank[b, j])
            if coverage is not None and name in coverage:
                s.coverage = float(coverage[name])

    # -- views / export ------------------------------------------------------

    def scopes(self) -> List[str]:
        return sorted({scope for scope, _ in self._series})

    def all_series(self) -> List[SiteSeries]:
        return [self._series[k] for k in sorted(self._series)]

    def k_series(self, scope: str, site: str) -> Tuple[np.ndarray, np.ndarray]:
        s = self._series[(scope, site)]
        return np.asarray(s.steps, np.int64), np.asarray(s.k, np.int64)

    def final_k(self, scope: str) -> Dict[str, int]:
        return {
            site: s.k[-1]
            for (sc, site), s in sorted(self._series.items())
            if sc == scope and s.k
        }

    def to_dict(self) -> Dict[str, Any]:
        return {"schema": SCHEMA, "series": [s.to_dict() for s in self.all_series()]}

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
        return path

    def __len__(self) -> int:
        return len(self._series)


def load_telemetry(path: str) -> PrecisionTelemetry:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != SCHEMA:
        raise ValueError(
            f"{path}: unknown telemetry schema {doc.get('schema')!r} "
            f"(expected {SCHEMA})"
        )
    t = PrecisionTelemetry()
    for d in doc["series"]:
        t._series[(d["scope"], d["site"])] = SiteSeries.from_dict(d)
    return t


# ---------------------------------------------------------------------------
# evidence replay: the chunk-boundary k series, from the capture stream
# ---------------------------------------------------------------------------

def replay_k_series(
    evidence,
    prec,
    sites: Sequence[str],
    site_ops: Optional[Sequence[str]] = None,
    every: int = 1,
    k0=None,
    tracker0=None,
):
    """Replay a captured per-substep evidence stream through the §5.3
    adjust law, sampling tracker state at every chunk boundary.

    ``evidence`` is the capture plane's ``(steps, n_sites, 2)`` stream;
    ``every`` is the run's snapshot cadence (the chunk length — the same
    boundaries ``Simulation``'s fused/megakernel planes fold at; a trailing
    remainder chunk is sampled too, matching the driver's epilogue).
    ``k0`` seeds the tracker exactly as the run did (None = start wide);
    ``tracker0`` instead resumes from a full carried SiteTracker (EMAs and
    §5.3 counters included), for runs that started from saved adjust-unit
    state.

    Returns ``(boundary_steps, k, grew, shrank)`` with the arrays shaped
    ``(n_boundaries, n_sites)``. Because :func:`repro.precision.
    fold_evidence` replays each substep through ``tracker_observe`` — the
    identical law the stepwise loop, the fused chunk fold and the
    megakernel's on-chip ``adjust_step`` apply — the sampled k equals the
    run's carried tracker at every boundary, bit for bit.
    """
    from repro.precision import site_tracker_init
    from repro.precision.fusion import fold_evidence

    import jax.numpy as jnp

    ev = np.asarray(evidence, np.float32)
    steps = ev.shape[0]
    if ev.ndim != 3 or ev.shape[1] != len(sites) or ev.shape[2] != 2:
        raise ValueError(
            f"evidence shape {ev.shape} does not match {len(sites)} sites"
        )
    every = max(1, int(every))
    ops = None if site_ops is None else tuple(site_ops)
    tr = tracker0 if tracker0 is not None else site_tracker_init(
        tuple(sites), prec.fmt, k0=k0
    )
    out_steps, out_k, out_g, out_s = [], [], [], []
    for start in range(0, steps, every):
        chunk = jnp.asarray(ev[start : start + every])
        tr = fold_evidence(tr, chunk, prec, ops=ops)
        out_steps.append(min(start + every, steps))
        out_k.append(np.asarray(tr.state.k))
        out_g.append(np.asarray(tr.state.overflow_steps))
        out_s.append(np.asarray(tr.state.shrink_steps))
    return (
        out_steps,
        np.stack(out_k) if out_k else np.zeros((0, len(sites)), np.int32),
        np.stack(out_g) if out_g else np.zeros((0, len(sites)), np.int32),
        np.stack(out_s) if out_s else np.zeros((0, len(sites)), np.int32),
    )


def coverage_fraction(
    evidence,
    prec,
    sites: Sequence[str],
    k_final: Dict[str, int],
    site_ops: Optional[Sequence[str]] = None,
) -> Dict[str, float]:
    """Fraction of each site's issues its final carried split covers
    without an adjust event — ``mean(k_need <= k_final)`` over the
    captured evidence, each site judged under its own op envelope
    (:func:`repro.core.policy.evidence_k_need`, the adjust unit's own
    per-issue statistic)."""
    from repro.core.policy import evidence_k_need

    ev = np.asarray(evidence, np.float32)
    out = {}
    for j, name in enumerate(sites):
        if name not in k_final:
            continue
        op = "mul" if site_ops is None else site_ops[j]
        need = np.asarray(evidence_k_need(ev[:, j, 0], ev[:, j, 1], prec, op))
        out[name] = float(np.mean(need <= int(k_final[name]))) if need.size else 1.0
    return out
