"""Metrics registry: named counters/gauges/histograms with label sets.

One :class:`MetricsRegistry` per scope (the process-wide one installed by
``repro.obs.enable``, or a private one per ``ServiceMetrics``) owns every
metric by name. Metrics are the standard three:

* :class:`Counter` — monotone float, ``inc(amount, **labels)``;
* :class:`Gauge` — settable float, ``set(value, **labels)``;
* :class:`Histogram` — cumulative buckets + sum + count,
  ``observe(value, **labels)``.

Every series is addressed by a **label set** (sorted kwargs), and every
metric carries a hard **cardinality bound** (``max_series``): a label set
beyond the bound is dropped and counted in ``registry.dropped_series``
instead of growing host memory without limit — unbounded label cardinality
is the classic way a metrics layer becomes the outage. Declaring the same
name twice returns the same metric object (idempotent); re-declaring under
a different type raises.

Export is Prometheus text exposition format (``# HELP``/``# TYPE`` +
samples; histograms as ``_bucket``/``_sum``/``_count`` with cumulative
``le`` buckets) and a JSON mirror. :func:`parse_prometheus` is a *strict*
parser of the same format — name/label grammar, TYPE-before-samples,
cumulative-bucket monotonicity, ``+Inf`` terminal bucket, count/sum
consistency — used by the round-trip tests and the ``repro.obs`` reporter,
so an export that drifts from the spec fails loudly in CI rather than in
someone's scrape pipeline.

Pure stdlib on the hot path; recording never touches jax (the passivity
contract, DESIGN.md §15).
"""

from __future__ import annotations

import json
import math
import os
import re
import threading
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "histogram_quantile",
    "parse_prometheus",
    "DEFAULT_BUCKETS",
]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: default histogram buckets (seconds): µs-scale kernel launches through
#: multi-second compiles
DEFAULT_BUCKETS = (
    1e-5, 5e-5, 1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5, 1.0, 5.0, 10.0,
)


def _labelkey(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    for name in labels:
        if not _LABEL_RE.match(name):
            raise ValueError(f"invalid label name {name!r}")
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')


def _fmt_labels(key: Tuple[Tuple[str, str], ...], extra: str = "") -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in key]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    return repr(float(v))


class _Metric:
    kind = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str):
        self.registry = registry
        self.name = name
        self.help = help
        self._series: Dict[Tuple, Any] = {}
        self._lock = threading.Lock()

    def _get_series(self, labels: Dict[str, Any], default):
        """The state cell of one label set, or None past the cardinality
        bound (the drop is counted on the registry)."""
        key = _labelkey(labels)
        with self._lock:
            cell = self._series.get(key)
            if cell is None:
                if len(self._series) >= self.registry.max_series:
                    self.registry._dropped += 1
                    return None
                cell = self._series[key] = default()
            return cell

    def labelsets(self) -> List[Dict[str, str]]:
        with self._lock:
            return [dict(k) for k in self._series]

    def __len__(self) -> int:
        return len(self._series)


class _Cell:
    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = value


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({amount})")
        cell = self._get_series(labels, _Cell)
        if cell is not None:
            cell.value += amount

    def value(self, **labels) -> float:
        cell = self._series.get(_labelkey(labels))
        return 0.0 if cell is None else cell.value

    def total(self) -> float:
        """Sum over every label set."""
        with self._lock:
            return sum(c.value for c in self._series.values())

    def samples(self):
        with self._lock:
            return [(self.name, key, cell.value) for key, cell in self._series.items()]


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        cell = self._get_series(labels, _Cell)
        if cell is not None:
            cell.value = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        cell = self._get_series(labels, _Cell)
        if cell is not None:
            cell.value += amount

    def value(self, **labels) -> float:
        cell = self._series.get(_labelkey(labels))
        return float("nan") if cell is None else cell.value

    def samples(self):
        with self._lock:
            return [(self.name, key, cell.value) for key, cell in self._series.items()]


class _HistCell:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets  # per-bucket (non-cumulative) counts
        self.sum = 0.0
        self.count = 0


def histogram_quantile(q: float, buckets, count: float) -> float:
    """Prometheus-style quantile estimate from cumulative ``le`` buckets.

    ``buckets`` is a sequence of ``(le, cumulative_count)`` pairs over the
    *finite* bucket bounds, ascending (exactly what
    :meth:`Histogram.snapshot` returns); ``count`` is the total observation
    count (the implicit ``+Inf`` bucket). Estimation is linear interpolation
    inside the bucket holding rank ``q * count``, with 0 as the lower bound
    of the first bucket; a rank past the last finite bucket returns that
    bucket's bound (the standard `histogram_quantile` convention — the
    estimate never invents mass above the largest finite bound). Returns
    NaN with no observations or a ``q`` outside ``[0, 1]``.
    """
    count = float(count)
    if not (0.0 <= q <= 1.0) or count <= 0 or not buckets:
        return float("nan")
    rank = q * count
    lower = 0.0
    prev_cum = 0.0
    for le, cum in buckets:
        if cum >= rank:
            width = float(le) - lower
            in_bucket = cum - prev_cum
            if in_bucket <= 0 or width <= 0:
                return float(le)
            return lower + width * (rank - prev_cum) / in_bucket
        lower = float(le)
        prev_cum = float(cum)
    return float(buckets[-1][0])


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, registry, name, help, buckets=DEFAULT_BUCKETS):
        super().__init__(registry, name, help)
        bs = tuple(float(b) for b in buckets)
        if list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"histogram buckets must be strictly increasing: {bs}")
        if bs and math.isinf(bs[-1]):
            bs = bs[:-1]  # +Inf is implicit
        self.buckets = bs

    def observe(self, value: float, **labels) -> None:
        cell = self._get_series(labels, lambda: _HistCell(len(self.buckets) + 1))
        if cell is None:
            return
        i = len(self.buckets)  # the +Inf bucket
        for j, b in enumerate(self.buckets):
            if value <= b:
                i = j
                break
        cell.counts[i] += 1
        cell.sum += value
        cell.count += 1

    def snapshot(self, **labels) -> Optional[Dict[str, Any]]:
        """(cumulative bucket counts, sum, count) of one label set."""
        cell = self._series.get(_labelkey(labels))
        if cell is None:
            return None
        cum, acc = [], 0
        for c in cell.counts:
            acc += c
            cum.append(acc)
        return {"buckets": list(zip(self.buckets, cum[:-1])), "sum": cell.sum,
                "count": cell.count}

    def quantile(self, q: float, **labels) -> float:
        """Bucket-estimated quantile (seconds for latency histograms) of one
        label set — or, with no labels, of the distribution aggregated over
        every label set (all series share this histogram's bucket layout, so
        cumulative counts sum exactly). NaN with no observations."""
        if labels:
            snap = self.snapshot(**labels)
            if snap is None:
                return float("nan")
            return histogram_quantile(q, snap["buckets"], snap["count"])
        with self._lock:
            cells = list(self._series.values())
        counts = [0] * (len(self.buckets) + 1)
        total = 0
        for cell in cells:
            for i, c in enumerate(cell.counts):
                counts[i] += c
            total += cell.count
        cum, acc = [], 0
        for c in counts[:-1]:
            acc += c
            cum.append(acc)
        return histogram_quantile(q, list(zip(self.buckets, cum)), total)

    def samples(self):
        out = []
        with self._lock:
            for key, cell in self._series.items():
                acc = 0
                for b, c in zip(self.buckets, cell.counts):
                    acc += c
                    out.append((f"{self.name}_bucket", key + (("le", _fmt_value(b)),), acc))
                out.append(
                    (f"{self.name}_bucket", key + (("le", "+Inf"),), cell.count)
                )
                out.append((f"{self.name}_sum", key, cell.sum))
                out.append((f"{self.name}_count", key, cell.count))
        return out


class MetricsRegistry:
    """Name -> metric map with idempotent declaration and a per-metric
    series-cardinality bound (see module docstring)."""

    def __init__(self, max_series: int = 256):
        self.max_series = int(max_series)
        self._metrics: Dict[str, _Metric] = {}
        self._dropped = 0
        self._lock = threading.Lock()

    @property
    def dropped_series(self) -> int:
        """Label sets refused by the cardinality bound (process lifetime)."""
        return self._dropped

    def _declare(self, cls, name: str, help: str, **kw) -> _Metric:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(self, name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already declared as {m.kind}, not {cls.kind}"
                )
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._declare(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._declare(Gauge, name, help)

    def histogram(self, name: str, help: str = "", buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._declare(Histogram, name, help, buckets=buckets)

    def metrics(self) -> List[_Metric]:
        with self._lock:
            return [self._metrics[n] for n in sorted(self._metrics)]

    # -- export --------------------------------------------------------------

    def export_prometheus(self) -> str:
        """Prometheus text exposition format, strict-parser clean."""
        lines = []
        for m in self.metrics():
            lines.append(f"# HELP {m.name} {m.help or m.name}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for name, key, value in m.samples():
                lines.append(f"{name}{_fmt_labels(key)} {_fmt_value(value)}")
        return "\n".join(lines) + "\n"

    def export_json(self) -> Dict[str, Any]:
        out = {}
        for m in self.metrics():
            out[m.name] = {
                "type": m.kind,
                "help": m.help,
                "samples": [
                    {"name": name, "labels": dict(key), "value": value}
                    for name, key, value in m.samples()
                ],
            }
        return {"schema": "repro.obs/metrics@1", "dropped_series": self._dropped,
                "metrics": out}

    def save(self, prom_path: Optional[str] = None, json_path: Optional[str] = None):
        for path, text in (
            (prom_path, lambda: self.export_prometheus()),
            (json_path, lambda: json.dumps(self.export_json(), indent=2, sort_keys=True)),
        ):
            if path:
                os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
                with open(path, "w") as f:
                    f.write(text())


# ---------------------------------------------------------------------------
# strict text-format parser (round-trip tests + the repro.obs reporter)
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>\S+)\s*$"
)
_LABEL_PAIR_RE = re.compile(
    r'\s*(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\]|\\.)*)"\s*(?:,|$)'
)


def _parse_value(tok: str) -> float:
    if tok == "+Inf":
        return float("inf")
    if tok == "-Inf":
        return float("-inf")
    if tok == "NaN":
        return float("nan")
    return float(tok)  # raises ValueError on garbage


def _parse_labels(body: str) -> Dict[str, str]:
    out, pos = {}, 0
    while pos < len(body):
        m = _LABEL_PAIR_RE.match(body, pos)
        if m is None:
            raise ValueError(f"malformed label body {body!r}")
        v = m.group("v").replace(r"\n", "\n").replace(r"\"", '"').replace(r"\\", "\\")
        out[m.group("k")] = v
        pos = m.end()
    return out


def parse_prometheus(text: str) -> Dict[str, Dict[str, Any]]:
    """Strictly parse Prometheus text format.

    Returns ``{family: {"type", "help", "samples": [(name, labels, value)]}}``.
    Raises ``ValueError`` on grammar violations, samples without a ``TYPE``
    declaration, non-cumulative histogram buckets, a histogram missing its
    ``+Inf`` bucket, or ``_count`` disagreeing with the terminal bucket.
    """
    families: Dict[str, Dict[str, Any]] = {}

    def family_of(sample_name: str) -> Optional[str]:
        for fam in families:
            if families[fam]["type"] == "histogram" and sample_name in (
                f"{fam}_bucket", f"{fam}_sum", f"{fam}_count"
            ):
                return fam
            if sample_name == fam:
                return fam
        return None

    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("# HELP "):
            parts = line.split(None, 3)
            if len(parts) < 3 or not _NAME_RE.match(parts[2]):
                raise ValueError(f"line {lineno}: malformed HELP: {raw!r}")
            families.setdefault(
                parts[2], {"type": None, "help": "", "samples": []}
            )["help"] = parts[3] if len(parts) > 3 else ""
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or not _NAME_RE.match(parts[2]):
                raise ValueError(f"line {lineno}: malformed TYPE: {raw!r}")
            if parts[3] not in ("counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {lineno}: unknown metric type {parts[3]!r}")
            fam = families.setdefault(parts[2], {"type": None, "help": "", "samples": []})
            if fam["type"] is not None:
                raise ValueError(f"line {lineno}: duplicate TYPE for {parts[2]!r}")
            fam["type"] = parts[3]
            continue
        if line.startswith("#"):
            continue  # comment
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: malformed sample: {raw!r}")
        name = m.group("name")
        labels = _parse_labels(m.group("labels")) if m.group("labels") else {}
        try:
            value = _parse_value(m.group("value"))
        except ValueError:
            raise ValueError(
                f"line {lineno}: malformed value {m.group('value')!r}"
            ) from None
        fam = family_of(name)
        if fam is None or families[fam]["type"] is None:
            raise ValueError(f"line {lineno}: sample {name!r} has no TYPE declaration")
        families[fam]["samples"].append((name, labels, value))

    # histogram structural checks
    for fam, rec in families.items():
        if rec["type"] != "histogram":
            continue
        by_series: Dict[Tuple, Dict[str, Any]] = {}
        for name, labels, value in rec["samples"]:
            base = {k: v for k, v in labels.items() if k != "le"}
            key = tuple(sorted(base.items()))
            s = by_series.setdefault(key, {"buckets": [], "sum": None, "count": None})
            if name == f"{fam}_bucket":
                if "le" not in labels:
                    raise ValueError(f"{fam}: bucket sample without le label")
                s["buckets"].append((_parse_value(labels["le"]), value))
            elif name == f"{fam}_sum":
                s["sum"] = value
            elif name == f"{fam}_count":
                s["count"] = value
        for key, s in by_series.items():
            bs = sorted(s["buckets"], key=lambda t: t[0])
            if not bs or not math.isinf(bs[-1][0]):
                raise ValueError(f"{fam}{dict(key)}: histogram missing +Inf bucket")
            counts = [c for _, c in bs]
            if any(b > a for a, b in zip(counts[1:], counts)):
                raise ValueError(f"{fam}{dict(key)}: bucket counts not cumulative")
            if s["count"] is None or s["sum"] is None:
                raise ValueError(f"{fam}{dict(key)}: missing _sum/_count")
            if s["count"] != counts[-1]:
                raise ValueError(
                    f"{fam}{dict(key)}: _count {s['count']} != +Inf bucket {counts[-1]}"
                )
    return families
