"""Shadow-oracle sampling: replay a deterministic slice of traffic at f32.

Siklósi et al. (arXiv 2505.20911) document the failure mode this exists
for: reduced-precision runs that stay plausible while drifting from the
full-precision answer. The only way to *see* that drift live is to pay for
a full-precision replay of some traffic — so the health plane samples a
deterministic low-rate subset of completed service requests, reruns each
one at f32 through :meth:`repro.pde.solver.Simulation.oracle_replay`, and
books the relative L2 distance between the served final state and the
oracle's into the error-budget metrics.

Passivity: the sampler decides at admission from the *submission count*
alone (the same ``floor((n+1)r) > floor(nr)`` law the tracer uses — no
RNG, no wall clock), the job captures host-side **copies** of the request's
initial state, and the replay is a separate f32 program that shares nothing
with the primary run. The primary path is bit-identical with shadowing on
or off (``tests/test_health.py``).

Module-level imports are numpy-only; jax and the solver load lazily inside
:meth:`ShadowJob.replay`, so importing the health plane costs nothing on a
host that only ever reads artifacts.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import numpy as np

__all__ = ["ShadowSampler", "ShadowJob", "rel_l2", "nonfinite_fraction"]


def rel_l2(state, oracle_state, offset: float = 0.0) -> float:
    """Relative L2 distance between two state pytrees, after removing the
    stepper's additive baseline (``Stepper.metric_offset`` — e.g. the SWE
    resting depth, so drift is measured on the dynamic field). Any
    non-finite value in either tree makes the distance ``inf`` — an
    overflowed primary is *maximally* wrong, not NaN-silently fine."""
    import jax

    a = np.concatenate(
        [np.ravel(np.asarray(x, np.float64)) - offset
         for x in jax.tree_util.tree_leaves(state)]
    )
    b = np.concatenate(
        [np.ravel(np.asarray(x, np.float64)) - offset
         for x in jax.tree_util.tree_leaves(oracle_state)]
    )
    if a.shape != b.shape:
        raise ValueError(f"state shapes differ: {a.shape} vs {b.shape}")
    if not (np.all(np.isfinite(a)) and np.all(np.isfinite(b))):
        return float("inf")
    ref = float(np.linalg.norm(b))
    err = float(np.linalg.norm(a - b))
    if ref == 0.0:
        return 0.0 if err == 0.0 else float("inf")
    return err / ref


def nonfinite_fraction(tree) -> float:
    """Fraction of non-finite elements across a (host-side) pytree — the
    frame statistic behind the overflow-storm detector's direct signal."""
    import jax

    total = 0
    bad = 0
    for x in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(x)
        if not np.issubdtype(arr.dtype, np.floating):
            continue
        total += arr.size
        bad += int(np.count_nonzero(~np.isfinite(arr)))
    return bad / total if total else 0.0


class ShadowSampler:
    """Deterministic rate sampler over a monotone admission counter.

    Keeps request ``n`` iff ``floor((n+1) * rate) > floor(n * rate)`` —
    exactly ``rate`` of traffic in the long run, the *same* requests every
    run, and no state beyond the counter (so two services fed the same
    burst shadow the same members)."""

    def __init__(self, rate: float):
        if not (0.0 <= rate <= 1.0):
            raise ValueError(f"shadow rate must be in [0, 1], got {rate}")
        self.rate = float(rate)
        self._n = 0

    def pick(self) -> bool:
        n = self._n
        self._n += 1
        if self.rate <= 0.0:
            return False
        return math.floor((n + 1) * self.rate) > math.floor(n * self.rate)


class ShadowJob:
    """One sampled request's replayable workload, captured at admission.

    ``state0`` is a host-side numpy copy taken before the request ever
    enters a bucket; ``sim`` is the request's own Simulation (static
    config), from which :meth:`replay` derives the f32 oracle twin.
    """

    def __init__(self, request_id: int, sim, state0, steps: int, offset: float):
        import jax

        self.request_id = int(request_id)
        self.sim = sim
        self.state0 = jax.tree_util.tree_map(
            lambda x: np.array(x, copy=True), state0
        )
        self.steps = int(steps)
        self.offset = float(offset)

    @classmethod
    def capture(cls, rec) -> "ShadowJob":
        """Snapshot a just-admitted RequestRecord (its ``state`` is still
        the initial condition at that point)."""
        stepper, cfg = rec.sim.stepper, rec.sim.cfg
        return cls(rec.id, rec.sim, rec.state, rec.steps, stepper.metric_offset(cfg))

    def replay(self, primary_state) -> float:
        """Run the f32 oracle over the captured workload and return the
        rel-L2 drift of ``primary_state`` (the served final state) from it.
        Packed served states are unpacked first — the comparison is always
        between decoded values."""
        from repro.pack import is_packed, unpack_state

        res = self.sim.oracle_replay(self.steps, state0=self.state0)
        if is_packed(primary_state):
            primary_state = unpack_state(primary_state)
        return rel_l2(primary_state, res.state, offset=self.offset)
