"""Stdlib-HTTP scrape endpoint for the health plane.

Three routes, provider-agnostic (each backed by a zero-argument callable,
so the same server fronts a live :class:`~repro.obs.health.HealthMonitor`
or a directory of exported artifacts in ``--watch`` mode):

* ``GET /metrics`` — Prometheus text exposition (the registry's
  ``export_prometheus``, strict-parser clean);
* ``GET /health`` — the JSON health verdict; HTTP 200 while ``status`` is
  ``ok``, 503 once alerting (load balancers and probes get the verdict for
  free);
* ``GET /telemetry`` — the precision-telemetry JSON document.

``ThreadingHTTPServer`` on a daemon thread, ephemeral port by default —
the serving loop stays single-process and synchronous; the scrape path
only ever *reads* host-side state the monitor already holds (passivity,
DESIGN.md §15). Callables that raise turn into HTTP 500 with the error
text instead of killing the thread.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, Optional

__all__ = ["HealthServer"]


class HealthServer:
    """The scrape server (see module docstring)."""

    def __init__(
        self,
        metrics_fn: Callable[[], str],
        health_fn: Callable[[], Dict[str, Any]],
        telemetry_fn: Callable[[], Dict[str, Any]],
        host: str = "127.0.0.1",
        port: int = 0,
    ):
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):  # silence per-request stderr
                pass

            def _send(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):  # noqa: N802 (http.server API)
                path = self.path.split("?", 1)[0].rstrip("/") or "/"
                try:
                    if path == "/metrics":
                        self._send(200, outer._metrics_fn().encode(),
                                   "text/plain; version=0.0.4")
                    elif path == "/health":
                        verdict = outer._health_fn()
                        code = 200 if verdict.get("status") == "ok" else 503
                        self._send(code, _json_bytes(verdict),
                                   "application/json")
                    elif path == "/telemetry":
                        self._send(200, _json_bytes(outer._telemetry_fn()),
                                   "application/json")
                    else:
                        self._send(404, _json_bytes(
                            {"error": f"unknown route {path!r}",
                             "routes": ["/metrics", "/health", "/telemetry"]},
                        ), "application/json")
                except Exception as e:  # a broken provider must not kill the thread
                    self._send(500, _json_bytes({"error": repr(e)}),
                               "application/json")

        self._metrics_fn = metrics_fn
        self._health_fn = health_fn
        self._telemetry_fn = telemetry_fn
        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread: Optional[threading.Thread] = None
        self.host = self._httpd.server_address[0]
        self.port = self._httpd.server_address[1]

    @classmethod
    def for_monitor(cls, monitor, host: str = "127.0.0.1", port: int = 0):
        """Wire the three routes to a live HealthMonitor's scope."""

        def telemetry_doc() -> Dict[str, Any]:
            tel = monitor.obs.telemetry
            return tel.to_dict() if tel is not None else {"error": "telemetry off"}

        return cls(
            metrics_fn=monitor.obs.registry.export_prometheus,
            health_fn=monitor.verdict,
            telemetry_fn=telemetry_doc,
            host=host,
            port=port,
        )

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "HealthServer":
        if self._thread is not None:
            return self
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-health-scrape",
            daemon=True,
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._httpd.shutdown()
        self._thread.join(timeout=5)
        self._httpd.server_close()
        self._thread = None


def _sanitize(x):
    """Non-finite floats become null — NaN/inf are not valid JSON and the
    verdict uses NaN for 'no data yet'."""
    if isinstance(x, float) and not (x == x and abs(x) != float("inf")):
        return None
    if isinstance(x, dict):
        return {k: _sanitize(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_sanitize(v) for v in x]
    return x


def _json_bytes(doc: Dict[str, Any]) -> bytes:
    return json.dumps(_sanitize(doc), indent=2, sort_keys=True, default=str,
                      allow_nan=False).encode()
