"""Flight recorder: a bounded ring of recent health/lifecycle events.

The postmortem story of the health plane (DESIGN.md §16): while the service
runs, :class:`FlightRecorder` keeps the last ``capacity`` events — request
lifecycle transitions, chunk records, carried-k samples, shadow-replay
results, alerts — in a plain ``deque``. Nothing is written until something
goes wrong; on any alert or request failure the monitor calls :meth:`dump`,
which freezes the ring plus the current metric summary and health verdict
into one schema-versioned JSON file under ``artifacts/flightrec/``.

Design constraints, in order:

* **bounded** — the ring never grows past ``capacity`` events and dumps
  are capped by the monitor (``HealthConfig.max_dumps``), so a pathological
  alert storm cannot fill the disk the way it filled the logs;
* **deterministic** — events carry a monotone ``seq`` (and whatever step /
  chunk indices the caller supplies), never wall-clock timestamps, so two
  runs of the same burst dump byte-identical recordings;
* **loadable** — :func:`load_flightrec` is a strict loader (schema tag,
  required keys, monotone ``seq``) used by the ``--smoke`` gate: a dump CI
  cannot reload is a bug today, not during the real postmortem.

Pure stdlib; recording is O(1) dict appends on the host (passivity,
DESIGN.md §15).
"""

from __future__ import annotations

import json
import os
import re
from collections import deque
from typing import Any, Deque, Dict, List, Optional

__all__ = ["FlightRecorder", "load_flightrec", "SCHEMA"]

SCHEMA = "repro.obs/flightrec@1"

_SLUG_RE = re.compile(r"[^a-zA-Z0-9_.-]+")


def _slug(text: str) -> str:
    return _SLUG_RE.sub("-", str(text)).strip("-") or "event"


class FlightRecorder:
    """The ring buffer (see module docstring)."""

    def __init__(self, capacity: int = 512):
        if capacity <= 0:
            raise ValueError(f"flight-recorder capacity must be positive: {capacity}")
        self.capacity = int(capacity)
        self._events: Deque[Dict[str, Any]] = deque(maxlen=self.capacity)
        self._seq = 0  # events ever recorded (dumps report truncation)
        self._dump_seq = 0

    def record(self, kind: str, **fields) -> None:
        """Append one event. ``fields`` must be JSON-serialisable scalars /
        small structures — the recorder stores them verbatim."""
        self._seq += 1
        self._events.append({"seq": self._seq, "kind": kind, **fields})

    @property
    def recorded(self) -> int:
        """Events ever recorded (>= len(self) once the ring wraps)."""
        return self._seq

    def events(self) -> List[Dict[str, Any]]:
        return list(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def dump(
        self,
        out_dir: str,
        reason: str,
        metrics: Optional[Dict[str, Any]] = None,
        verdict: Optional[Dict[str, Any]] = None,
    ) -> str:
        """Freeze the ring to ``out_dir/flightrec-NNNN-<reason>.json``.

        The write is atomic (tmp + rename) so a crash mid-dump never leaves
        a half-written recording for the loader to choke on. Returns the
        path."""
        self._dump_seq += 1
        os.makedirs(out_dir, exist_ok=True)
        name = f"flightrec-{self._dump_seq:04d}-{_slug(reason)}.json"
        path = os.path.join(out_dir, name)
        doc = {
            "schema": SCHEMA,
            "reason": str(reason),
            "dump_seq": self._dump_seq,
            "capacity": self.capacity,
            "recorded": self._seq,
            "events": self.events(),
            "metrics": metrics or {},
            "verdict": verdict or {},
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
        return path


def load_flightrec(path: str) -> Dict[str, Any]:
    """Strictly load one dump: schema tag, required keys, every event a
    dict with ``seq``/``kind``, ``seq`` strictly increasing. Raises
    ``ValueError`` on any violation."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != SCHEMA:
        raise ValueError(
            f"{path}: unknown flightrec schema {doc.get('schema')!r} "
            f"(expected {SCHEMA})"
        )
    for key in ("reason", "capacity", "recorded", "events", "metrics", "verdict"):
        if key not in doc:
            raise ValueError(f"{path}: flightrec dump missing key {key!r}")
    prev = 0
    for e in doc["events"]:
        if not isinstance(e, dict) or "seq" not in e or "kind" not in e:
            raise ValueError(f"{path}: malformed flightrec event {e!r}")
        if e["seq"] <= prev:
            raise ValueError(
                f"{path}: event seq not strictly increasing at {e['seq']}"
            )
        prev = e["seq"]
    if len(doc["events"]) > doc["capacity"]:
        raise ValueError(
            f"{path}: {len(doc['events'])} events exceed capacity {doc['capacity']}"
        )
    return doc
