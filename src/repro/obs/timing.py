"""Shared bench timing with an explicit compile/execute split.

Every benchmark in the repo used to time jitted callables with an ad-hoc
``perf_counter`` pair around a warmup loop, which silently folds XLA trace
+ compile time into the first sample (or throws it away entirely without
reporting it). :func:`measure` is the one helper they now share:

* the **first call** is timed separately and reported as ``compile_us`` —
  for a jitted callable this is trace + compile + one execution, the
  figure the service plane's ``compile_seconds`` metric tracks;
* the remaining ``iters`` calls are averaged into ``us_per_call`` — the
  steady-state figure the bench baselines compare.

Each call is fenced with ``jax.block_until_ready`` so device asynchrony
cannot leak one sample into the next.
"""

from __future__ import annotations

import time
from typing import Any, NamedTuple

__all__ = ["Timing", "measure"]


class Timing(NamedTuple):
    compile_us: float  # first call: trace + compile + execute
    us_per_call: float  # steady-state mean over `iters` calls
    iters: int
    result: Any  # last call's (blocked-on) output


def measure(fn, *args, iters: int = 3, **kw) -> Timing:
    """Time ``fn(*args, **kw)``: one compile-inclusive first call, then the
    mean of ``iters`` steady-state calls (see module docstring)."""
    import jax

    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args, **kw))
    compile_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args, **kw))
    us = (time.perf_counter() - t0) * 1e6 / iters
    return Timing(compile_us=compile_us, us_per_call=us, iters=iters, result=out)
