"""``python -m repro.obs`` — headless fleet reporter over exported artifacts.

Default mode loads a directory of artifacts written by
:func:`repro.obs.export` (``trace.json``, ``metrics.prom``,
``telemetry.json``) and prints the fleet view: top-k slow spans, per-bucket
chunk latency, per-site carried-k trajectories. It needs only stdlib +
numpy — point it at artifacts scp'd off a serving host.

``--smoke`` is the CI gate: serve a tiny mixed burst in-process with
observability enabled, export, reload the artifacts through the strict
loaders, and verify the whole contract — trace loads with complete spans,
the Prometheus text round-trips through the strict parser, telemetry's
final carried k matches the request results, and the recorder's measured
self-time stays under the 5% overhead budget. Exit 0 on pass, 2 on any
failure (printed with a ``SMOKE FAIL`` prefix).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional

OVERHEAD_BUDGET = 0.05  # recorder self-time / device-busy time


# ---------------------------------------------------------------------------
# report mode
# ---------------------------------------------------------------------------

def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.1f}ms"
    return f"{us:.0f}us"


def report_trace(path: str, top: int = 10) -> List[str]:
    from .trace import load_trace

    doc = load_trace(path)
    events = doc["traceEvents"]
    complete = [e for e in events if e.get("ph") == "X"]
    dropped = doc.get("otherData", {}).get("dropped", 0)
    lines = [
        f"trace: {len(complete)} spans, "
        f"{len(events) - len(complete)} instants, "
        f"{dropped} dropped past capacity ({path})"
    ]
    if dropped:
        lines.append(
            f"  WARNING: {dropped} sampled event(s) fell past the recorder "
            "capacity — raise capacity or lower sample for complete traces"
        )
    slow = sorted(complete, key=lambda e: -e.get("dur", 0.0))[:top]
    if slow:
        lines.append(f"  top {len(slow)} slow spans:")
        width = max(len(e["name"]) for e in slow)
        for e in slow:
            args = e.get("args", {})
            brief = ", ".join(
                f"{k}={v}" for k, v in sorted(args.items()) if k != "depth"
            )
            lines.append(
                f"    {e['name']:<{width}}  {_fmt_us(e.get('dur', 0.0)):>9}"
                + (f"  [{brief}]" if brief else "")
            )
    return lines


def report_metrics(path: str) -> List[str]:
    from .metrics import parse_prometheus

    with open(path) as f:
        families = parse_prometheus(f.read())
    lines = [f"metrics: {len(families)} families ({path})"]
    for name, fam in sorted(families.items()):
        if fam["type"] == "histogram":
            # per-label-set mean latency from _sum/_count
            sums: Dict[Any, float] = {}
            counts: Dict[Any, float] = {}
            for sname, labels, value in fam["samples"]:
                key = tuple(sorted(labels.items()))
                if sname.endswith("_sum"):
                    sums[key] = value
                elif sname.endswith("_count"):
                    counts[key] = value
            lines.append(f"  {name} (histogram):")
            for key in sorted(sums):
                n = counts.get(key, 0.0)
                mean = sums[key] / n if n else float("nan")
                lbl = ", ".join(f"{k}={v}" for k, v in key) or "(no labels)"
                lines.append(
                    f"    {lbl}: n={n:.0f} mean={_fmt_us(mean * 1e6)}"
                )
        else:
            for sname, labels, value in fam["samples"]:
                lbl = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
                lines.append(
                    f"  {sname}{{{lbl}}} = {value:g}" if lbl
                    else f"  {sname} = {value:g}"
                )
    return lines


def report_telemetry(path: str) -> List[str]:
    from .precision import load_telemetry

    tel = load_telemetry(path)
    lines = [f"telemetry: {len(tel)} site series ({path})"]
    for s in tel.all_series():
        if not s.k:
            continue
        traj = "->".join(str(k) for k in _dedup(s.k))
        cov = f" coverage={s.coverage:.3f}" if s.coverage is not None else ""
        lines.append(
            f"  {s.scope}:{s.site}  k {traj}  "
            f"(grew {s.grew[-1]}, shrank {s.shrank[-1]}, "
            f"{len(s.steps)} samples){cov}"
        )
    return lines


def _dedup(ks: List[int]) -> List[int]:
    out: List[int] = []
    for k in ks:
        if not out or out[-1] != k:
            out.append(int(k))
    return out


def run_report(dir: str, top: int) -> int:
    """Report every artifact that is present and loadable; a missing or
    malformed file (a partial export, a truncated write) degrades to a
    warning line instead of crashing the whole report."""
    any_found = False
    for fname, fn in (
        ("trace.json", lambda p: report_trace(p, top)),
        ("metrics.prom", report_metrics),
        ("telemetry.json", report_telemetry),
    ):
        path = os.path.join(dir, fname)
        if not os.path.exists(path):
            print(f"({fname}: not found in {dir})")
            continue
        try:
            lines = fn(path)
        except Exception as e:  # partial/corrupt artifact: report and move on
            print(f"({fname}: unreadable — {e})")
            continue
        any_found = True
        for line in lines:
            print(line)
    if not any_found:
        print(f"no obs artifacts in {dir!r} — run with repro.obs.export() first")
        return 1
    return 0


# ---------------------------------------------------------------------------
# smoke mode (the CI gate)
# ---------------------------------------------------------------------------

def run_smoke(out_dir: str) -> int:
    failures: List[str] = []

    def check(ok: bool, what: str) -> None:
        print(("  ok   " if ok else "  FAIL ") + what)
        if not ok:
            failures.append(what)

    import numpy as np

    import repro.obs as obs
    from repro.service import SimRequest, SimService

    print("smoke: serving a mixed burst with observability enabled")
    obs.enable(sample=1.0)
    try:
        svc = SimService()
        h_f32 = svc.submit(SimRequest("heat1d", steps=64, precision="f32",
                                      snapshot_every=16))
        h_trk = svc.submit(SimRequest("heat1d", steps=64,
                                      precision="rr_tracked",
                                      snapshot_every=16))
        svc.run_until_idle()
        res_trk = h_trk.result()
        h_f32.result()
        summary = svc.metrics.summary()
        paths = obs.export(out_dir)
        o = obs.active()
        tracer_self = o.tracer.self_seconds if o.tracer else 0.0
        n_spans = len(o.tracer.spans) if o.tracer else 0
    finally:
        obs.disable()

    # 1. trace artifact loads and has complete spans
    doc = obs.load_trace(paths["trace"])
    complete = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    check(len(complete) >= 1, f"trace has complete spans ({len(complete)})")
    check(any(e["name"] == "service.chunk" for e in complete),
          "trace covers service.chunk spans")

    # 2. Prometheus export round-trips through the strict parser
    with open(paths["prometheus"]) as f:
        prom_text = f.read()
    try:
        families = obs.parse_prometheus(prom_text)
        check(len(families) >= 1, f"prometheus parses ({len(families)} families)")
    except ValueError as e:
        check(False, f"prometheus parses ({e})")
        families = {}
    check("repro_service_chunk_latency_seconds" in families,
          "chunk-latency histogram exported")

    # 3. compile/execute split landed in the metrics
    check(summary.get("compiles", 0) >= 1,
          f"compile calls recorded ({summary.get('compiles')})")
    check(summary.get("compile_seconds", 0.0) > 0.0,
          "compile_seconds > 0")
    check(np.isfinite(summary.get("chunk_latency_p50_us", float("nan"))),
          "execute-only latency percentile is finite")

    # 4. telemetry: final carried k in the series matches the request result
    tel = obs.load_telemetry(paths["telemetry"])
    check(len(tel) >= 1, f"telemetry has site series ({len(tel)})")
    ok_k = False
    if res_trk.final_k:
        for scope in tel.scopes():
            if tel.final_k(scope) == res_trk.final_k:
                ok_k = True
                break
    check(ok_k, f"telemetry final k matches request result {res_trk.final_k}")

    # 5. measured recorder overhead under budget
    busy = summary.get("busy_seconds", 0.0) + summary.get("compile_seconds", 0.0)
    frac = tracer_self / busy if busy > 0 else 0.0
    check(frac < OVERHEAD_BUDGET,
          f"recorder self-time {frac * 100:.2f}% of busy time "
          f"(budget {OVERHEAD_BUDGET * 100:.0f}%, {n_spans} spans)")

    if failures:
        print(f"SMOKE FAIL: {len(failures)} check(s) failed")
        return 2
    print(f"smoke passed; artifacts in {out_dir}")
    return 0


# ---------------------------------------------------------------------------

def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Fleet reporter over exported repro.obs artifacts.",
    )
    ap.add_argument("--dir", default="artifacts/obs",
                    help="artifact directory to report on (default: %(default)s)")
    ap.add_argument("--top", type=int, default=10,
                    help="how many slow spans to show (default: %(default)s)")
    ap.add_argument("--smoke", action="store_true",
                    help="serve a tiny instrumented burst and gate the "
                         "whole obs contract (CI mode; exit 2 on failure)")
    ap.add_argument("--out", default=None,
                    help="smoke-mode export directory (default: --dir)")
    args = ap.parse_args(argv)
    if args.smoke:
        return run_smoke(args.out or args.dir)
    return run_report(args.dir, args.top)


if __name__ == "__main__":
    sys.exit(main())
