"""repro.obs — unified observability for the service, solver and kernel planes.

One subsystem the whole stack reports into (DESIGN.md §15):

* :mod:`repro.obs.trace` — nestable spans + lifecycle instants with a
  process-wide sampled recorder; Chrome-trace/Perfetto JSON export.
* :mod:`repro.obs.metrics` — named counters/gauges/histograms with label
  sets; Prometheus text + JSON export, strict round-trip parser.
* :mod:`repro.obs.precision` — per-site carried-k time series, §5.3
  grow/shrink counters and evidence-coverage fractions, drained from
  trackers at chunk boundaries.
* :mod:`repro.obs.timing` — the shared bench helper with an explicit
  compile/execute split.
* :mod:`repro.obs.health` — the live monitoring plane over this substrate
  (DESIGN.md §16): anomaly detectors on the telemetry stream, shadow-
  oracle sampling (:mod:`repro.obs.shadow`), declarative SLO rules, a
  bounded flight recorder (:mod:`repro.obs.flightrec`) and a stdlib-HTTP
  scrape endpoint (:mod:`repro.obs.server`).
* ``python -m repro.obs`` — headless fleet reporter over exported
  artifacts, plus the ``--smoke`` self-check CI gates on; ``python -m
  repro.obs.health`` is the health-plane counterpart (offline detector
  replay, ``--watch``, and its own ``--smoke`` gate).

The passivity contract
----------------------

Instrumentation is **passive**: it observes values the program already
materialises on the host and never feeds anything back.

* Spans and metrics are host-side Python; nothing here is traced into a
  jitted program, so an instrumented run is bit-identical to an
  uninstrumented one (proven by ``tests/test_obs.py``'s parity suite).
* Telemetry drains only *concrete* trackers: :func:`record_tracker`
  refuses jax tracers, so instrumented code inside ``jit``/``vmap``
  quietly skips the drain instead of corrupting the trace.
* When observability is disabled (the default), every hook below is a
  no-op measured in nanoseconds — :func:`span` returns a shared reentrant
  null context manager and the counters short-circuit before any lookup.

Usage::

    import repro.obs as obs

    obs.enable(sample=1.0)
    ... run / serve ...
    paths = obs.export("artifacts/obs")   # trace.json, metrics.prom, ...
    obs.disable()
"""

from __future__ import annotations

import os
from typing import Any, Dict, Optional

from .metrics import (  # noqa: F401  (re-exported)
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    parse_prometheus,
)
from .precision import PrecisionTelemetry, load_telemetry  # noqa: F401
from .timing import Timing, measure  # noqa: F401
from .trace import NULL_SPAN, Span, Tracer, load_trace  # noqa: F401

__all__ = [
    "Observability",
    "enable",
    "disable",
    "active",
    "enabled",
    "span",
    "instant",
    "inc",
    "observe",
    "set_gauge",
    "record_tracker",
    "export",
    # re-exports
    "Tracer",
    "Span",
    "NULL_SPAN",
    "load_trace",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "parse_prometheus",
    "PrecisionTelemetry",
    "load_telemetry",
    "Timing",
    "measure",
]


class Observability:
    """One enabled observability scope: a tracer, a metrics registry and a
    precision-telemetry accumulator."""

    def __init__(
        self,
        trace: bool = True,
        telemetry: bool = True,
        sample: float = 1.0,
        capacity: int = 65536,
        registry: Optional[MetricsRegistry] = None,
    ):
        self.tracer: Optional[Tracer] = (
            Tracer(sample=sample, capacity=capacity) if trace else None
        )
        self.registry: MetricsRegistry = registry or MetricsRegistry()
        self.telemetry: Optional[PrecisionTelemetry] = (
            PrecisionTelemetry() if telemetry else None
        )

    def export(self, out_dir: str) -> Dict[str, str]:
        """Write every artifact under ``out_dir``; returns name -> path."""
        os.makedirs(out_dir, exist_ok=True)
        paths: Dict[str, str] = {}
        if self.tracer is not None:
            paths["trace"] = self.tracer.save(os.path.join(out_dir, "trace.json"))
        prom = os.path.join(out_dir, "metrics.prom")
        mjson = os.path.join(out_dir, "metrics.json")
        self.registry.save(prom_path=prom, json_path=mjson)
        paths["prometheus"] = prom
        paths["metrics_json"] = mjson
        if self.telemetry is not None:
            paths["telemetry"] = self.telemetry.save(
                os.path.join(out_dir, "telemetry.json")
            )
        return paths


_OBS: Optional[Observability] = None


def enable(
    trace: bool = True,
    telemetry: bool = True,
    sample: float = 1.0,
    capacity: int = 65536,
    registry: Optional[MetricsRegistry] = None,
) -> Observability:
    """Turn on process-wide observability (idempotent: replaces any prior
    scope). ``sample`` thins top-level spans deterministically."""
    global _OBS
    _OBS = Observability(
        trace=trace,
        telemetry=telemetry,
        sample=sample,
        capacity=capacity,
        registry=registry,
    )
    return _OBS


def disable() -> None:
    """Turn observability off; every hook reverts to its no-op fast path."""
    global _OBS
    _OBS = None


def active() -> Optional[Observability]:
    """The enabled scope, or None."""
    return _OBS


def enabled() -> bool:
    return _OBS is not None


# ---------------------------------------------------------------------------
# instrumentation hooks — no-ops unless enable() was called
# ---------------------------------------------------------------------------

def span(name: str, **args):
    """A tracing span context manager (NULL_SPAN when disabled)."""
    o = _OBS
    if o is None or o.tracer is None:
        return NULL_SPAN
    return o.tracer.span(name, **args)


def instant(name: str, **args) -> None:
    """A zero-duration lifecycle event."""
    o = _OBS
    if o is not None and o.tracer is not None:
        o.tracer.instant(name, **args)


def inc(name: str, amount: float = 1, help: str = "", **labels) -> None:
    """Bump a counter on the active registry."""
    o = _OBS
    if o is not None:
        o.registry.counter(name, help).inc(amount, **labels)


def observe(name: str, value: float, help: str = "", **labels) -> None:
    """Record a histogram observation on the active registry."""
    o = _OBS
    if o is not None:
        o.registry.histogram(name, help).observe(value, **labels)


def set_gauge(name: str, value: float, help: str = "", **labels) -> None:
    """Set a gauge on the active registry."""
    o = _OBS
    if o is not None:
        o.registry.gauge(name, help).set(value, **labels)


def _concrete(tracker) -> bool:
    """True iff every leaf of the tracker is a concrete (non-traced) value —
    the guard that keeps telemetry drains out of jit/vmap traces."""
    import jax

    for leaf in jax.tree_util.tree_leaves(tracker):
        if isinstance(leaf, jax.core.Tracer):
            return False
    return True


def record_tracker(scope: str, tracker, step: int) -> None:
    """Drain a carried SiteTracker's (k, grow, shrink) into the telemetry
    series at ``step``. No-op when disabled, when ``tracker`` is None, or —
    crucially — when called under a jax trace (passivity: the drain never
    enters a jitted program)."""
    o = _OBS
    if o is None or o.telemetry is None or tracker is None:
        return
    if not _concrete(tracker):
        return
    o.telemetry.record_tracker(scope, tracker, step)


def export(out_dir: str) -> Dict[str, str]:
    """Export the active scope's artifacts (raises if disabled)."""
    if _OBS is None:
        raise RuntimeError("repro.obs is not enabled; call repro.obs.enable() first")
    return _OBS.export(out_dir)
