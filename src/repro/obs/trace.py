"""Structured tracing: nestable spans, one process-wide sampled recorder.

A :class:`Tracer` records *complete* spans (``ph: "X"`` in Chrome-trace
terms: a name, a start timestamp and a duration) and *instant* lifecycle
events (``ph: "i"``), both carrying free-form JSON ``args``. Spans nest by
plain dynamic scoping — a thread-local stack — so a served burst renders as
a real timeline in Perfetto: ``service.pump`` containing ``service.chunk``
containing the ``sim.run`` trace and its ``pallas.*`` dispatch spans.

The recorder is deliberately dumb and host-only (DESIGN.md §15):

* **passive** — entering/leaving a span reads ``time.perf_counter`` and
  appends to a Python list; nothing here ever touches a jax value, so an
  instrumented program is bit-identical to an uninstrumented one;
* **sampled** — ``sample=r`` keeps a deterministic ``r`` fraction of
  *top-level* spans (the n-th top-level span is kept iff
  ``floor((n+1)·r) > floor(n·r)`` — no RNG, so two identical runs record
  identical span sets); nested spans and instants inherit the enclosing
  top-level decision;
* **bounded** — at most ``capacity`` events are retained; further kept
  events only bump ``dropped`` (a long-lived service cannot leak host
  memory through its own observability);
* **self-measuring** — the recorder accumulates the wall time spent inside
  its own bookkeeping (``self_seconds``), which is what the <5% overhead
  gate in ``python -m repro.obs --smoke`` and the bench smoke measure.

Export is Chrome-trace JSON (the ``{"traceEvents": [...]}`` envelope),
loadable by Perfetto (https://ui.perfetto.dev) and ``chrome://tracing``.
"""

from __future__ import annotations

import json
import math
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, NamedTuple, Optional

__all__ = ["Span", "Tracer", "NULL_SPAN", "load_trace"]


class Span(NamedTuple):
    """One recorded event. ``dur_us`` is None for instant events."""

    name: str
    ts_us: float  # microseconds since the tracer's epoch
    dur_us: Optional[float]
    tid: int
    depth: int  # nesting depth at record time (0 = top-level)
    args: Dict[str, Any]


class _Frame:
    __slots__ = ("name", "args", "keep", "depth", "t0")

    def __init__(self, name, args, keep, depth, t0):
        self.name = name
        self.args = args
        self.keep = keep
        self.depth = depth
        self.t0 = t0


class _NullSpan:
    """Reentrant no-op context manager — the disabled-path span."""

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    """The process-wide span recorder (see module docstring)."""

    def __init__(self, sample: float = 1.0, capacity: int = 65536):
        if not 0.0 <= sample <= 1.0:
            raise ValueError(f"sample must be in [0, 1], got {sample}")
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.sample = float(sample)
        self.capacity = int(capacity)
        self.spans: List[Span] = []
        self.dropped = 0  # kept-by-sampling events beyond capacity
        self.self_seconds = 0.0  # recorder bookkeeping wall time
        self._top_seen = 0  # top-level spans offered (sampling counter)
        self._epoch = time.perf_counter()
        self._tls = threading.local()
        self._lock = threading.Lock()

    # -- internals -----------------------------------------------------------

    def _stack(self) -> List[_Frame]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _keep_top(self) -> bool:
        """Deterministic proportional sampling over top-level spans."""
        with self._lock:
            n = self._top_seen
            self._top_seen += 1
        return math.floor((n + 1) * self.sample) > math.floor(n * self.sample)

    def _record(self, span: Span) -> None:
        with self._lock:
            if len(self.spans) < self.capacity:
                self.spans.append(span)
            else:
                self.dropped += 1

    # -- recording API -------------------------------------------------------

    @contextmanager
    def span(self, name: str, **args):
        """A complete span around the ``with`` body. Yields the mutable
        ``args`` dict, so the body can attach late attributes (e.g. a chunk
        size computed inside)."""
        t_in = time.perf_counter()
        stack = self._stack()
        keep = stack[-1].keep if stack else self._keep_top()
        frame = _Frame(name, dict(args), keep, len(stack), time.perf_counter())
        stack.append(frame)
        self.self_seconds += time.perf_counter() - t_in
        try:
            yield frame.args
        finally:
            t_out = time.perf_counter()
            stack.pop()
            if keep:
                self._record(
                    Span(
                        name=frame.name,
                        ts_us=(frame.t0 - self._epoch) * 1e6,
                        dur_us=(t_out - frame.t0) * 1e6,
                        tid=threading.get_ident(),
                        depth=frame.depth,
                        args=frame.args,
                    )
                )
            self.self_seconds += time.perf_counter() - t_out

    def instant(self, name: str, **args) -> None:
        """A zero-duration lifecycle event (request submitted / joined a
        bucket / evicted / done ...). Inside a span it inherits that span's
        sampling decision; outside one it is always kept (lifecycle events
        are rare and cheap)."""
        t_in = time.perf_counter()
        stack = self._stack()
        keep = stack[-1].keep if stack else True
        if keep:
            self._record(
                Span(
                    name=name,
                    ts_us=(t_in - self._epoch) * 1e6,
                    dur_us=None,
                    tid=threading.get_ident(),
                    depth=len(stack),
                    args=dict(args),
                )
            )
        self.self_seconds += time.perf_counter() - t_in

    # -- export --------------------------------------------------------------

    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome-trace/Perfetto JSON object (``traceEvents`` envelope)."""
        pid = os.getpid()
        events = []
        for s in self.spans:
            ev = {
                "name": s.name,
                "cat": "repro",
                "ph": "X" if s.dur_us is not None else "i",
                "ts": round(s.ts_us, 3),
                "pid": pid,
                "tid": s.tid,
                "args": {**s.args, "depth": s.depth},
            }
            if s.dur_us is not None:
                ev["dur"] = round(s.dur_us, 3)
            else:
                ev["s"] = "t"  # instant scope: thread
            events.append(ev)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "recorder": "repro.obs",
                "sample": self.sample,
                "dropped": self.dropped,
            },
        }

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


def load_trace(path: str) -> Dict[str, Any]:
    """Load an exported Chrome trace, validating the envelope the reporter
    (and Perfetto) depends on."""
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError(f"{path}: not a Chrome trace (no traceEvents list)")
    for ev in events:
        if "name" not in ev or "ph" not in ev or "ts" not in ev:
            raise ValueError(f"{path}: malformed trace event {ev!r}")
    return doc
