"""Headless service driver: submit a mixed burst, pump to idle, report.

    PYTHONPATH=src python -m repro.service [--steppers a,b] [--per 2]
        [--precisions f32,r2f2_16,rr_tracked] [--steps 240]
        [--execution auto] [--max-bucket 8] [--smoke]

Submits ``--per`` requests per (registered stepper × precision) with scaled
initial conditions — compatible members pack into shared buckets (the
occupancy line shows it), different precisions/steppers land in sibling
buckets — then drives the service to idle and prints one line per request
plus the metrics report. Exit status 0 only if every admitted request
completed — the CI-friendly smoke gate for the serving plane.

``--health`` additionally runs the burst under the
:mod:`repro.obs.health` monitor (shadow-oracle sampling at ``--shadow-rate``,
anomaly detectors, SLO rules) and makes ANY health alert a nonzero exit —
the headless alerting contract (DESIGN.md §16).
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.pde import known_steppers

from .request import SimRequest, scaled_state0
from .scheduler import ServiceConfig, SimService


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.service")
    ap.add_argument("--steppers", default=None, help="comma-separated subset")
    ap.add_argument("--per", type=int, default=2,
                    help="requests per (stepper, precision) — bucket packing")
    ap.add_argument("--precisions", default="f32,r2f2_16,rr_tracked",
                    help="comma-separated presets/modes")
    ap.add_argument("--steps", type=int, default=240)
    ap.add_argument("--execution", default="auto",
                    choices=("auto", "reference", "fused", "megakernel"))
    ap.add_argument("--max-bucket", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced burst for the CI fast tier")
    ap.add_argument("--health", action="store_true",
                    help="run under the repro.obs.health monitor; any alert "
                         "makes the exit status nonzero")
    ap.add_argument("--shadow-rate", type=float, default=0.25,
                    help="--health shadow-oracle sampling rate")
    args = ap.parse_args(argv)

    names = args.steppers.split(",") if args.steppers else known_steppers()
    steps = 48 if args.smoke else args.steps
    precs = ("f32", "rr_tracked") if args.smoke else tuple(args.precisions.split(","))

    monitor = None
    if args.health:
        import repro.obs as obs
        import repro.obs.health as health

        if not obs.enabled():
            obs.enable(sample=1.0)
        monitor = health.enable(shadow_rate=args.shadow_rate)

    svc = SimService(ServiceConfig(max_bucket=args.max_bucket, max_queue=1024))
    handles = []
    for name in names:
        for prec in precs:
            for i in range(args.per):
                handles.append(
                    svc.submit(
                        SimRequest(
                            name,
                            steps=steps,
                            precision=prec,
                            execution=args.execution,
                            state0=scaled_state0(name, 0.6 + 0.2 * i),
                            tag=f"{name}/{prec}#{i}",
                        )
                    )
                )
    print(f"[service] submitted {len(handles)} requests "
          f"({len(names)} steppers x {len(precs)} precisions x {args.per}, "
          f"{steps} steps, execution={args.execution})")

    svc.run_until_idle()

    ok = True
    for h in handles:
        if h.status != "done":
            ok = False
            print(f"  {h.tag:24s} {h.status.upper()}")
            continue
        res = h.result()
        amax = max(
            (float(np.abs(s).max()) for s in res.snapshots), default=float("nan")
        )
        line = (f"  {h.tag:24s} done: {len(res.snapshots)} snapshots, "
                f"{res.chunks} chunks, |max|={amax:.4g}")
        if res.final_k is not None:
            line += f", k={res.final_k}"
        print(line)

    print()
    print(svc.metrics.report())
    if monitor is not None:
        v = monitor.verdict()
        print(f"health: {v['status']} — {v['alerts']['total']} alert(s), "
              f"shadow sampled {v['shadow']['sampled']} "
              f"(burn {v['shadow']['burn']})")
        for a in monitor.alerts:
            print(f"  {a}")
        if monitor.alerts:
            return 3  # headless alerting contract: alerts are a nonzero exit
    return 0 if ok else 2


if __name__ == "__main__":
    sys.exit(main())
