"""The service's observability surface: counters, latency, occupancy.

One :class:`ServiceMetrics` per :class:`~repro.service.scheduler.SimService`
accumulates everything the ISSUE's production story needs to be judged by:

* **throughput** — member-steps advanced per second of busy (chunk) time:
  the saturation measure of the fused plane under heterogeneous traffic;
* **chunk latency** — wall seconds per bucket chunk call (p50/p99 over the
  service lifetime, and per bucket key for the benchmark suite), with a
  **compile/execute split**: the first call of each cached chunk program
  (XLA trace + compile) lands in ``compile_seconds``/``compiles`` instead
  of polluting the latency percentiles, throughput denominator, or busy
  time;
* **bucket occupancy** — members per chunk call: how well the bucketing
  scheduler packs the vmapped ensembles (1.0 = no batching win at all);
* **per-site adjust counters** — the §5.3 grow/shrink totals drained from
  completed tracked requests, aggregated by site name: the fleet-level view
  of how hard the precision-adjust unit worked;
* lifecycle counters — submitted / rejected (backpressure) / completed /
  evicted / resumed / snapshots streamed.

Since PR 9 this class is a thin consumer of a
:class:`repro.obs.MetricsRegistry` — every counter/histogram lives in the
registry (and is therefore Prometheus/JSON-exportable), while the public
attribute API (``metrics.submitted += 1``, ``metrics.busy_seconds``, ...)
is preserved via properties over the registry cells. When
``repro.obs.enable()`` is active at construction, the service reports into
the process-wide registry so one export captures the whole fleet;
otherwise it gets a private registry and behaves exactly as before.

Derived views guard their denominators: throughput with zero busy time and
latency/occupancy over an empty window return NaN (never raise, never
inf). Everything is plain Python floats/ints on the host — metrics never
touch the jitted chunk programs.
"""

from __future__ import annotations

from collections import defaultdict, deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import MetricsRegistry

__all__ = ["ServiceMetrics"]

#: lifecycle counter attribute -> registry counter (name, help)
_LIFECYCLE = {
    "submitted": ("repro_service_submitted_total", "requests admitted"),
    "rejected": ("repro_service_rejected_total", "requests refused (backpressure)"),
    "completed": ("repro_service_completed_total", "requests finished"),
    "failed": ("repro_service_failed_total", "requests failed"),
    "evicted": ("repro_service_evicted_total", "members parked under pressure"),
    "resumed": ("repro_service_resumed_total", "parked members re-admitted"),
    "snapshots_emitted": ("repro_service_snapshots_total", "snapshot frames streamed"),
    "chunks": ("repro_service_chunks_total", "bucket chunk calls"),
    "member_steps": ("repro_service_member_steps_total",
                     "member-steps advanced (all chunk calls)"),
    "compiles": ("repro_service_compiles_total",
                 "chunk calls that traced+compiled a fresh program"),
}

_FLOAT_COUNTERS = {
    "busy_seconds": ("repro_service_busy_seconds_total",
                     "wall seconds in steady-state chunk execution"),
    "compile_seconds": ("repro_service_compile_seconds_total",
                        "wall seconds in first-call trace+compile"),
}


def _counter_property(attr: str, name: str, as_int: bool):
    def getter(self):
        v = self._reg.counter(name).total()
        return int(v) if as_int else v

    def setter(self, value):
        # preserves the historical `metrics.submitted += 1` call sites:
        # assignment becomes a delta-increment on the registry counter
        delta = value - getter(self)
        if delta:
            self._reg.counter(name).inc(delta)

    return property(getter, setter)


def _key_labels(key) -> Dict[str, str]:
    """Low-cardinality labels from a BucketKey (display classes only — the
    full key still keys the sample window)."""
    prec = getattr(key, "prec", None)
    return {
        "stepper": str(getattr(key, "stepper", key)),
        "mode": str(getattr(prec, "mode", prec if prec is not None else "?")),
        "execution": str(getattr(key, "execution", "?")),
    }


class ServiceMetrics:
    def __init__(self, window: int = 65536, registry: Optional[MetricsRegistry] = None):
        if registry is None:
            import repro.obs as obs

            o = obs.active()
            registry = o.registry if o is not None else MetricsRegistry()
        self._reg = registry
        for name, help in list(_LIFECYCLE.values()) + list(_FLOAT_COUNTERS.values()):
            registry.counter(name, help)
        self._latency_hist = registry.histogram(
            "repro_service_chunk_latency_seconds",
            "steady-state chunk wall time (compile calls excluded)",
        )
        self._adjust_counter = registry.counter(
            "repro_service_site_adjust_total",
            "per-site precision adjustments from completed tracked requests",
        )
        #: cumulative member-steps over execute-only (non-compile) chunk
        #: calls — the throughput numerator matching ``busy_seconds``
        self._exec_member_steps = 0
        #: recent per-chunk samples (full BucketKey, n_members, steps, secs,
        #: compiled) — a bounded window, so a long-lived service never grows
        #: unbounded host state; percentiles/occupancy/per-key stats are over
        #: this window while the counters stay cumulative. Samples key on the
        #: FULL bucket key, so buckets that differ only in format/config/
        #: shape never merge in per-key statistics (``BucketKey.short()`` is
        #: display only).
        self.chunk_samples: Deque[Tuple[Any, int, int, float, bool]] = deque(
            maxlen=window
        )
        #: site name -> [grew, shrank] totals from completed tracked requests
        self.site_adjustments: Dict[str, List[int]] = defaultdict(lambda: [0, 0])

    @property
    def registry(self) -> MetricsRegistry:
        """The backing obs registry (for export)."""
        return self._reg

    # -- recording -----------------------------------------------------------

    def observe_chunk(
        self, key, n_members: int, steps: int, seconds: float, compiled: bool = False
    ):
        """Record one bucket chunk call. ``compiled=True`` marks the first
        call of a freshly cached program: its wall time (dominated by XLA
        trace+compile) is booked as ``compile_seconds`` and kept out of the
        latency window and the throughput denominator."""
        self.chunks += 1
        self.member_steps += n_members * steps
        if compiled:
            self.compiles += 1
            self.compile_seconds += seconds
        else:
            self.busy_seconds += seconds
            self._exec_member_steps += n_members * steps
            self._latency_hist.observe(seconds, **_key_labels(key))
        self.chunk_samples.append((key, n_members, steps, seconds, compiled))

    def observe_completion(self, adjustments: Optional[Dict[str, Tuple[int, int]]]):
        self.completed += 1
        for site, (grew, shrank) in (adjustments or {}).items():
            self.site_adjustments[site][0] += grew
            self.site_adjustments[site][1] += shrank
            if grew:
                self._adjust_counter.inc(grew, site=site, dir="grow")
            if shrank:
                self._adjust_counter.inc(shrank, site=site, dir="shrink")

    # -- derived views -------------------------------------------------------

    def _latencies(self, key=None) -> np.ndarray:
        xs = [
            s
            for k, _, _, s, compiled in self.chunk_samples
            if not compiled and (key is None or k == key)
        ]
        return np.asarray(xs, np.float64)

    def latency_us(self, pct: float, key=None) -> float:
        """Execute-only chunk-latency percentile in microseconds (NaN with
        no samples). ``key``: a full BucketKey to restrict to one bucket
        class. Compile calls never enter this distribution."""
        xs = self._latencies(key)
        return float(np.percentile(xs, pct) * 1e6) if xs.size else float("nan")

    def throughput(self, key=None) -> float:
        """Member-steps per second of busy (execute-only) time (NaN with no
        busy time yet).

        Service-wide throughput uses the cumulative counters; per-key
        throughput is over the recent sample window."""
        if key is None:
            busy = self.busy_seconds
            return self._exec_member_steps / busy if busy > 0 else float("nan")
        rows = [
            (n * st, s)
            for k, n, st, s, compiled in self.chunk_samples
            if not compiled and k == key
        ]
        secs = sum(r[1] for r in rows)
        return sum(r[0] for r in rows) / secs if secs > 0 else float("nan")

    def occupancy(self, key=None) -> Tuple[float, int]:
        """(mean, max) members per chunk call ((NaN, 0) with no samples).
        Occupancy is a packing measure, so compile calls count too."""
        ns = [n for k, n, _, _, _ in self.chunk_samples if key is None or k == key]
        return (float(np.mean(ns)), int(max(ns))) if ns else (float("nan"), 0)

    def summary(self) -> Dict:
        occ_mean, occ_max = self.occupancy()
        return {
            "submitted": self.submitted,
            "rejected": self.rejected,
            "completed": self.completed,
            "failed": self.failed,
            "evicted": self.evicted,
            "resumed": self.resumed,
            "snapshots_emitted": self.snapshots_emitted,
            "chunks": self.chunks,
            "member_steps": self.member_steps,
            "busy_seconds": self.busy_seconds,
            "compiles": self.compiles,
            "compile_seconds": self.compile_seconds,
            "throughput_steps_per_s": self.throughput(),
            "chunk_latency_p50_us": self.latency_us(50),
            "chunk_latency_p99_us": self.latency_us(99),
            "occupancy_mean": occ_mean,
            "occupancy_max": occ_max,
            "site_adjustments": {
                s: tuple(v) for s, v in sorted(self.site_adjustments.items())
            },
        }

    def report(self) -> str:
        s = self.summary()
        lines = [
            "service metrics:",
            f"  requests    submitted={s['submitted']} completed={s['completed']} "
            f"rejected={s['rejected']} failed={s['failed']} "
            f"evicted={s['evicted']} resumed={s['resumed']}",
            f"  chunks      n={s['chunks']} p50={s['chunk_latency_p50_us']:.0f}us "
            f"p99={s['chunk_latency_p99_us']:.0f}us busy={s['busy_seconds']:.2f}s",
            f"  compile     n={s['compiles']} {s['compile_seconds']:.2f}s "
            f"(excluded from latency/throughput)",
            f"  throughput  {s['throughput_steps_per_s']:.0f} member-steps/s "
            f"({s['member_steps']} steps, {s['snapshots_emitted']} snapshots streamed)",
            f"  occupancy   mean={s['occupancy_mean']:.2f} max={s['occupancy_max']} "
            f"members/chunk",
        ]
        if s["site_adjustments"]:
            adj = ", ".join(
                f"{site}:+{g}/-{h}" for site, (g, h) in s["site_adjustments"].items()
            )
            lines.append(f"  adjust unit {adj}")
        return "\n".join(lines)


for _attr, (_name, _help) in _LIFECYCLE.items():
    setattr(ServiceMetrics, _attr, _counter_property(_attr, _name, as_int=True))
for _attr, (_name, _help) in _FLOAT_COUNTERS.items():
    setattr(ServiceMetrics, _attr, _counter_property(_attr, _name, as_int=False))
del _attr, _name, _help
