"""The service's observability surface: counters, latency, occupancy.

One :class:`ServiceMetrics` per :class:`~repro.service.scheduler.SimService`
accumulates everything the ISSUE's production story needs to be judged by:

* **throughput** — member-steps advanced per second of busy (chunk) time:
  the saturation measure of the fused plane under heterogeneous traffic;
* **chunk latency** — wall seconds per bucket chunk call (p50/p99 over the
  service lifetime, and per bucket key for the benchmark suite);
* **bucket occupancy** — members per chunk call: how well the bucketing
  scheduler packs the vmapped ensembles (1.0 = no batching win at all);
* **per-site adjust counters** — the §5.3 grow/shrink totals drained from
  completed tracked requests, aggregated by site name: the fleet-level view
  of how hard the precision-adjust unit worked;
* lifecycle counters — submitted / rejected (backpressure) / completed /
  evicted / resumed / snapshots streamed.

Everything is plain Python floats/ints on the host — metrics never touch
the jitted chunk programs.
"""

from __future__ import annotations

from collections import defaultdict, deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["ServiceMetrics"]


class ServiceMetrics:
    def __init__(self, window: int = 65536):
        self.submitted = 0
        self.rejected = 0
        self.completed = 0
        self.failed = 0
        self.evicted = 0
        self.resumed = 0
        self.snapshots_emitted = 0
        self.chunks = 0
        self.member_steps = 0  # sum over chunks of n_members * chunk_steps
        self.busy_seconds = 0.0
        #: recent per-chunk samples (full BucketKey, n_members, steps, secs)
        #: — a bounded window, so a long-lived service never grows unbounded
        #: host state; percentiles/occupancy/per-key stats are over this
        #: window while the counters above stay cumulative. Samples key on
        #: the FULL bucket key, so buckets that differ only in format/config/
        #: shape never merge in per-key statistics (``BucketKey.short()`` is
        #: display only).
        self.chunk_samples: Deque[Tuple[Any, int, int, float]] = deque(maxlen=window)
        #: site name -> [grew, shrank] totals from completed tracked requests
        self.site_adjustments: Dict[str, List[int]] = defaultdict(lambda: [0, 0])

    # -- recording -----------------------------------------------------------

    def observe_chunk(self, key, n_members: int, steps: int, seconds: float):
        self.chunks += 1
        self.member_steps += n_members * steps
        self.busy_seconds += seconds
        self.chunk_samples.append((key, n_members, steps, seconds))

    def observe_completion(self, adjustments: Optional[Dict[str, Tuple[int, int]]]):
        self.completed += 1
        for site, (grew, shrank) in (adjustments or {}).items():
            self.site_adjustments[site][0] += grew
            self.site_adjustments[site][1] += shrank

    # -- derived views -------------------------------------------------------

    def _latencies(self, key=None) -> np.ndarray:
        xs = [s for k, _, _, s in self.chunk_samples if key is None or k == key]
        return np.asarray(xs, np.float64)

    def latency_us(self, pct: float, key=None) -> float:
        """Chunk-latency percentile in microseconds (NaN with no samples).
        ``key``: a full BucketKey to restrict to one bucket class."""
        xs = self._latencies(key)
        return float(np.percentile(xs, pct) * 1e6) if xs.size else float("nan")

    def throughput(self, key=None) -> float:
        """Member-steps per second of busy time (0.0 with no samples).

        Service-wide throughput uses the cumulative counters; per-key
        throughput is over the recent sample window."""
        if key is None:
            return self.member_steps / self.busy_seconds if self.busy_seconds > 0 else 0.0
        rows = [(n * st, s) for k, n, st, s in self.chunk_samples if k == key]
        steps = sum(r[0] for r in rows)
        secs = sum(r[1] for r in rows)
        return steps / secs if secs > 0 else 0.0

    def occupancy(self, key=None) -> Tuple[float, int]:
        """(mean, max) members per chunk call ((0.0, 0) with no samples)."""
        ns = [n for k, n, _, _ in self.chunk_samples if key is None or k == key]
        return (float(np.mean(ns)), int(max(ns))) if ns else (0.0, 0)

    def summary(self) -> Dict:
        occ_mean, occ_max = self.occupancy()
        return {
            "submitted": self.submitted,
            "rejected": self.rejected,
            "completed": self.completed,
            "failed": self.failed,
            "evicted": self.evicted,
            "resumed": self.resumed,
            "snapshots_emitted": self.snapshots_emitted,
            "chunks": self.chunks,
            "member_steps": self.member_steps,
            "busy_seconds": self.busy_seconds,
            "throughput_steps_per_s": self.throughput(),
            "chunk_latency_p50_us": self.latency_us(50),
            "chunk_latency_p99_us": self.latency_us(99),
            "occupancy_mean": occ_mean,
            "occupancy_max": occ_max,
            "site_adjustments": {
                s: tuple(v) for s, v in sorted(self.site_adjustments.items())
            },
        }

    def report(self) -> str:
        s = self.summary()
        lines = [
            "service metrics:",
            f"  requests    submitted={s['submitted']} completed={s['completed']} "
            f"rejected={s['rejected']} failed={s['failed']} "
            f"evicted={s['evicted']} resumed={s['resumed']}",
            f"  chunks      n={s['chunks']} p50={s['chunk_latency_p50_us']:.0f}us "
            f"p99={s['chunk_latency_p99_us']:.0f}us busy={s['busy_seconds']:.2f}s",
            f"  throughput  {s['throughput_steps_per_s']:.0f} member-steps/s "
            f"({s['member_steps']} steps, {s['snapshots_emitted']} snapshots streamed)",
            f"  occupancy   mean={s['occupancy_mean']:.2f} max={s['occupancy_max']} "
            f"members/chunk",
        ]
        if s["site_adjustments"]:
            adj = ", ".join(
                f"{site}:+{g}/-{h}" for site, (g, h) in s["site_adjustments"].items()
            )
            lines.append(f"  adjust unit {adj}")
        return "\n".join(lines)
