"""Streaming result delivery: per-request event streams + client handles.

A served simulation does not return once at the end — snapshots become
available at every chunk boundary the request's cadence hits, and a
production client wants them as they land (progress bars, live dashboards,
early-exit on divergence). Each :class:`~repro.service.request.SimRequest`
admitted by the service gets a :class:`ResultStream`: an ordered,
thread-safe event queue the batcher pushes into between chunks.

Event kinds (``StreamEvent.kind``):

* ``"snapshot"`` — one observable frame; ``step`` is the request's own
  elapsed step count, ``payload`` the host-side numpy array;
* ``"evicted"`` — the request was checkpointed out to ``repro.ckpt``;
  ``payload`` is the checkpoint directory;
* ``"resumed"`` — the request re-joined a bucket from its checkpoint;
* ``"done"`` — terminal; ``payload`` is the final
  :class:`~repro.service.request.RequestResult`;
* ``"failed"`` — terminal; ``payload`` is the stringified error.

The service is cooperatively pumped (``SimService.pump`` /
``run_until_idle``), so single-threaded clients drain with the
non-blocking :meth:`ResultStream.drain`; a client on another thread can
block in :meth:`ResultStream.next_event`.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Any, List, NamedTuple, Optional

__all__ = ["StreamEvent", "ResultStream", "RequestHandle"]


class StreamEvent(NamedTuple):
    kind: str  # "snapshot" | "evicted" | "resumed" | "done" | "failed"
    step: int  # the request's elapsed steps when the event fired
    payload: Any = None


class ResultStream:
    """Ordered event stream for one request (producer: the batcher)."""

    def __init__(self):
        self._events: deque = deque()
        self._cv = threading.Condition()
        self._closed = False

    # -- producer side (service internals) ----------------------------------

    def emit(self, kind: str, step: int, payload=None) -> None:
        with self._cv:
            if self._closed:
                raise RuntimeError(f"stream already closed; cannot emit {kind!r}")
            self._events.append(StreamEvent(kind, int(step), payload))
            if kind in ("done", "failed"):
                self._closed = True
            self._cv.notify_all()

    # -- consumer side -------------------------------------------------------

    @property
    def closed(self) -> bool:
        """True once a terminal event (``done``/``failed``) was emitted."""
        with self._cv:
            return self._closed

    def drain(self) -> List[StreamEvent]:
        """Pop every event currently available (non-blocking)."""
        with self._cv:
            out = list(self._events)
            self._events.clear()
        return out

    def next_event(self, timeout: Optional[float] = None) -> Optional[StreamEvent]:
        """Blocking pop for threaded clients; None on timeout or when the
        stream is closed and fully drained."""
        with self._cv:
            while not self._events:
                if self._closed or not self._cv.wait(timeout=timeout):
                    return None
            return self._events.popleft()

    def __iter__(self):
        """Drain currently-available events (non-blocking iteration)."""
        return iter(self.drain())


class RequestHandle:
    """What ``SimService.submit`` returns: the client's view of one request.

    Wraps the live request record, so ``status``/``snapshots``/``result``
    reflect service progress as the caller pumps. Snapshot arrays are also
    accumulated here (in arrival order, with their step stamps) so a client
    that ignores the event stream still gets the full trajectory.
    """

    def __init__(self, record):
        self._record = record

    @property
    def id(self) -> int:
        return self._record.id

    @property
    def tag(self) -> str:
        return self._record.req.tag

    @property
    def status(self) -> str:
        return self._record.status

    @property
    def stream(self) -> ResultStream:
        return self._record.stream

    @property
    def bucket_key(self):
        """The scheduler's compatibility key this request packs under."""
        return self._record.key

    @property
    def snapshot_steps(self) -> List[int]:
        return [s for s, _ in self._record.snapshots]

    @property
    def snapshots(self) -> List[Any]:
        """Host-side observable frames delivered so far (arrival order)."""
        return [a for _, a in self._record.snapshots]

    @property
    def done(self) -> bool:
        return self._record.status in ("done", "failed")

    def result(self):
        """The final :class:`RequestResult`; raises unless ``status=='done'``."""
        if self._record.status == "failed":
            raise RuntimeError(
                f"request {self.id} failed: {self._record.error}"
            )
        if self._record.status != "done":
            raise RuntimeError(
                f"request {self.id} is {self._record.status!r}, not done — "
                "pump the service (SimService.run_until_idle) first"
            )
        return self._record.result

    def __repr__(self) -> str:
        r = self._record
        return (
            f"RequestHandle(id={r.id}, stepper={r.req.stepper!r}, "
            f"status={r.status!r}, elapsed={r.elapsed}/{r.steps})"
        )
