"""repro.service — the batched simulation-serving plane (DESIGN.md §12).

The ROADMAP's production story, made concrete: concurrent, heterogeneous
simulation requests — each with its own stepper, horizon, snapshot cadence
and per-request precision policy artifact — continuously batched onto the
vmapped fused ensembles so the Pallas execution plane stays saturated while
every request's numerics remain bit-identical to a solo run.

    from repro.service import SimRequest, SimService

    svc = SimService()
    h = svc.submit(SimRequest("heat2d", steps=1500, precision="rr_tracked"))
    svc.run_until_idle()
    res = h.result()            # snapshots streamed; final splits in res.final_k
    print(svc.metrics.report()) # throughput, p50/p99 chunk latency, occupancy

Layers: :mod:`~repro.service.request` (job model + admission-time
resolution), :mod:`~repro.service.scheduler` (bounded-queue admission,
bucketing, eviction/resume policy, the :class:`SimService` facade),
:mod:`~repro.service.batcher` (continuous batching at chunk boundaries onto
``Simulation.run_ensemble``), :mod:`~repro.service.stream` (per-request
event streams), :mod:`~repro.service.metrics` (the observability surface).
"""

from __future__ import annotations

from .batcher import Bucket, ChunkCompiler
from .metrics import ServiceMetrics
from .request import (
    BucketKey,
    RequestRecord,
    RequestResult,
    SimRequest,
    resolve_request,
    scaled_state0,
)
from .scheduler import ServiceConfig, ServiceOverloaded, SimService
from .stream import RequestHandle, ResultStream, StreamEvent

__all__ = [
    "SimRequest",
    "SimService",
    "ServiceConfig",
    "ServiceOverloaded",
    "RequestHandle",
    "RequestRecord",
    "RequestResult",
    "ResultStream",
    "StreamEvent",
    "ServiceMetrics",
    "Bucket",
    "BucketKey",
    "ChunkCompiler",
    "resolve_request",
    "scaled_state0",
]
