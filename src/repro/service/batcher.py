"""Continuous batching onto the fused ensembles, at chunk boundaries.

A :class:`Bucket` is one group of compatible requests (same
:class:`~repro.service.request.BucketKey`) advancing together through ONE
vmapped ``Simulation.run_ensemble`` call per chunk. The fused execution
plane (DESIGN.md §10) already runs whole snapshot intervals as single
Pallas kernel chunks; the bucket exploits exactly that seam:

* **chunk size is event-driven** — ``min`` over members of steps-to-next-
  event (own snapshot point or horizon), so no member is ever stepped past
  a point where a solo run would have paused. Members with heterogeneous
  cadences/horizons coexist; the bucket just pauses more often.
* **join/drain between chunks** — the member list is plain host state
  between chunks: finished requests drain out, queued compatible requests
  pack in, and the next chunk call restacks ``(state, tracker)``. Because
  each member's carried :class:`SiteTracker` rows (split ``k``, EMAs, §5.3
  adjustment counters) ride the stack and come back sliced, repacking is
  *semantically invisible* — a member's trajectory is bit-identical to its
  solo ``Simulation.run`` (asserted per stepper/mode in
  ``tests/test_service.py``).
* **compiled-chunk cache** — chunk programs are jitted once per
  ``(bucket key, chunk steps, member count)`` and reused across repacks, so
  steady-state traffic pays tracing cost only when the packing shape
  actually changes.

Why invisibility holds: a ``lax.scan`` over ``c1 + c2`` steps computes the
same op sequence as two scans of ``c1`` then ``c2`` (no cross-iteration
reassociation), vmapped elementwise/stencil arithmetic is per-lane
identical to the solo program, and snapshots are only recorded when a
member's own ``elapsed`` hits its own cadence — the same states a solo run
observes. The one deliberate relaxation: on the fused plane, ``rr_tracked``
folds kernel evidence at *bucket* chunk boundaries, which may be finer than
a solo run's snapshot intervals when cadences mix — the adjust unit then
sees the same evidence replayed in the same order, just folded earlier, so
final splits and §5.3 counters still match (the same guarantee the fused
plane itself makes vs the stepwise loop).
"""

from __future__ import annotations

import time
from collections import OrderedDict
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
import repro.obs.health as health
from repro.dist.sharding import active_mesh

from .metrics import ServiceMetrics
from .request import BucketKey, RequestRecord, RequestResult

__all__ = ["Bucket", "ChunkCompiler", "tree_stack", "tree_slice"]


def tree_stack(trees):
    """Stack a list of congruent pytrees along a new leading member dim."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def tree_slice(tree, i: int):
    """Member ``i``'s slice of a stacked pytree (drops the member dim)."""
    return jax.tree_util.tree_map(lambda x: x[i], tree)


class ChunkCompiler:
    """Jitted chunk programs, cached per (key, chunk, n_members, mesh).

    The program is ``run_ensemble(state_b, chunk, snapshot_every=chunk,
    tracker0_batch=tracker_b)`` — one snapshot interval, vmapped over the
    bucket, trackers threaded through and returned stacked for repacking.
    ``mesh`` must be the active ``axis_rules`` mesh (or None): sharded
    programs bake ``NamedSharding(mesh, ...)`` constraints in at trace
    time, so a program traced under one mesh must never serve another.

    The cache is LRU-bounded (``maxsize``): event-driven chunking produces
    one distinct chunk length per distinct member-event spacing, so a
    long-lived service with heterogeneous traffic would otherwise retain
    compiled executables without limit. Evicted entries simply retrace on
    next use.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._cache: "OrderedDict[Tuple, Callable]" = OrderedDict()

    def get(
        self, sim, key: BucketKey, chunk: int, n: int, sharded: bool, mesh=None
    ) -> Tuple[Callable, bool]:
        """Returns ``(chunk_fn, fresh)`` — ``fresh`` marks a cache miss, i.e.
        the next call of ``chunk_fn`` will trace + compile. The batcher books
        that call as compile time, not a chunk-latency sample."""
        cache_key = (key, chunk, n, sharded, mesh)
        fn = self._cache.get(cache_key)
        fresh = fn is None
        if fresh:

            def chunk_fn(state_b, tracker_b):
                res = sim.run_ensemble(
                    state_b,
                    chunk,
                    snapshot_every=chunk,
                    tracker0_batch=tracker_b,
                    execution=key.execution,
                    sharded=sharded,
                    storage=key.storage,
                )
                return res.state, res.snapshots, res.tracker

            fn = self._cache[cache_key] = jax.jit(chunk_fn)
            if len(self._cache) > self.maxsize:
                self._cache.popitem(last=False)
        else:
            self._cache.move_to_end(cache_key)
        return fn, fresh

    def __len__(self) -> int:
        return len(self._cache)


class Bucket:
    """One packing of compatible requests; advances one chunk at a time."""

    def __init__(self, key: BucketKey):
        self.key = key
        self.members: List[RequestRecord] = []

    def __len__(self) -> int:
        return len(self.members)

    def add(self, rec: RequestRecord) -> None:
        if rec.key != self.key:
            raise ValueError(
                f"request {rec.id} (key {rec.key.short()}) is not compatible "
                f"with bucket {self.key.short()}"
            )
        self.members.append(rec)
        rec.status = "running"
        obs.instant("request.join", request=rec.id, bucket=self.key.short())

    def next_chunk(self) -> int:
        """Steps until the earliest member event — the next chunk's length."""
        return min(m.steps_to_next_event() for m in self.members)

    def advance(
        self,
        compiler: ChunkCompiler,
        metrics: ServiceMetrics,
        sharded: Optional[bool] = None,
    ) -> List[RequestRecord]:
        """Run one chunk for every member; returns the members that drained.

        ``sharded=None`` auto-detects: bucket members ride the logical
        ``batch`` axis whenever a ``dist.sharding.axis_rules`` mesh context
        is active (``repro.dist.sharding.active_mesh``), so the same service
        loop spreads buckets over a mesh's data axes unchanged.
        """
        if not self.members:
            return []
        mesh = active_mesh()
        if sharded is None:
            sharded = mesh is not None
        chunk = self.next_chunk()
        n = len(self.members)
        sim = self.members[0].sim  # identical (stepper, cfg, prec) by key

        state_b = tree_stack([m.state for m in self.members])
        tracked = self.members[0].tracked
        tracker_b = (
            tree_stack([m.tracker for m in self.members]) if tracked else None
        )

        fn, fresh = compiler.get(
            sim, self.key, chunk, n, sharded, mesh=mesh if sharded else None
        )
        with obs.span(
            "service.chunk",
            bucket=self.key.short(),
            members=n,
            steps=chunk,
            compile=fresh,
        ):
            t0 = time.perf_counter()
            out_state, out_snaps, out_tracker = jax.block_until_ready(
                fn(state_b, tracker_b)
            )
            dt = time.perf_counter() - t0
        metrics.observe_chunk(self.key, n, chunk, dt, compiled=fresh)
        mon = health.active()

        drained: List[RequestRecord] = []
        for i, m in enumerate(self.members):
            m.state = tree_slice(out_state, i)
            if tracked:
                m.tracker = tree_slice(out_tracker, i)
                obs.record_tracker(
                    f"req{m.id}:{m.key.stepper}", m.tracker, m.elapsed + chunk
                )
                if mon is not None:
                    mon.on_tracker(m, chunk)
            m.elapsed += chunk
            m.chunks += 1
            if m.snapshot_due():
                # snaps lead with (member, n_out=1, ...): this member's frame
                snap = jax.tree_util.tree_map(
                    lambda x: np.asarray(x[i, 0]), out_snaps
                )
                m.snapshots.append((m.elapsed, snap))
                m.stream.emit("snapshot", m.elapsed, snap)
                metrics.snapshots_emitted += 1
                if mon is not None:
                    mon.observe_frame(m, snap)
            if m.remaining == 0:
                drained.append(m)

        # chunk-boundary health evaluation AFTER the member updates, so the
        # detectors see the telemetry this chunk just drained
        if mon is not None:
            mon.on_chunk(self.key, n, chunk, dt, compiled=fresh)

        for m in drained:
            self.members.remove(m)
            self._finalize(m, metrics)
            if mon is not None:
                mon.on_request_done(m)
        return drained

    @staticmethod
    def _finalize(m: RequestRecord, metrics: ServiceMetrics) -> None:
        final_k, adjustments = m.site_summary()
        m.status = "done"
        m.result = RequestResult(
            state=jax.tree_util.tree_map(np.asarray, m.state),
            snapshots=[a for _, a in m.snapshots],
            snapshot_steps=[s for s, _ in m.snapshots],
            tracker=m.tracker,
            final_k=final_k,
            adjustments=adjustments,
            elapsed=m.elapsed,
            chunks=m.chunks,
        )
        m.stream.emit("done", m.elapsed, m.result)
        obs.instant(
            "request.done", request=m.id, steps=m.elapsed, chunks=m.chunks
        )
        metrics.observe_completion(adjustments)
