"""The service's job model: what one simulation request is, resolved once.

A :class:`SimRequest` is the wire-level ask — stepper name, config
overrides, precision mode, an optional validated
:class:`~repro.profile.artifact.PrecisionPolicy` artifact, horizon and
snapshot cadence. Admission resolves it into a :class:`RequestRecord`, the
mutable runtime record the scheduler buckets and the batcher advances:

* the precision string/preset becomes an **effective**
  :class:`~repro.core.policy.PrecisionConfig` — policy artifacts are
  resolved through the shared :func:`repro.profile.artifact.resolve_policy`
  gate (validated-only, format re-base) and their ``[k_lo, k_hi]`` hints
  installed via ``PrecisionPolicy.apply`` (site names are the stepper's
  own, so the positional install is safe here, unlike the LM path);
* tracked modes get a per-request :class:`~repro.precision.sites.SiteTracker`
  seeded at the artifact's tuned splits (or the wide default) — this is the
  per-member adjust-unit state that survives bucket repacking;
* ``execution="auto"`` is resolved **at admission**, so the bucket key is
  concrete and an ineligible explicit ``"fused"`` fails fast at submit
  instead of mid-flight.

The :class:`BucketKey` is the compatibility contract of the scheduler:
requests sharing ``(stepper, cfg, effective precision, execution plane,
state-shape signature)`` step through bit-identical per-member programs and
may therefore share one vmapped fused ensemble call. ``cfg`` (a frozen
dataclass) subsumes the grid shape for builtin steppers; the explicit shape
signature additionally guards custom ``state0`` pytrees.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.policy import PRESETS, PrecisionConfig
from repro.pde.solver import Simulation
from repro.profile.artifact import PrecisionPolicy, resolve_policy

from .stream import ResultStream

__all__ = [
    "SimRequest",
    "RequestRecord",
    "RequestResult",
    "BucketKey",
    "resolve_request",
    "scaled_state0",
]


@dataclasses.dataclass
class SimRequest:
    """One client ask. Everything beyond ``stepper``/``steps`` is optional.

    ``precision`` may be a preset name (``"r2f2_16"``, ``"e5m10"``, ...), a
    bare mode name (``"rr_tracked"``, ``"deploy"``), or a full
    :class:`PrecisionConfig`. ``overrides`` are ``dataclasses.replace``
    fields on the stepper's default config (or on ``cfg`` when given).
    ``policy`` is a PrecisionPolicy artifact (object or JSON path) — it must
    be validated-accepted and profiled for this stepper. ``state0`` replaces
    the stepper's initial condition (a pytree matching ``init_state``'s
    structure). ``storage`` selects the carried-state format between chunks
    (:data:`repro.pde.solver.STORAGE_MODES` — ``"packed"`` members carry
    R2F2 payloads through the whole bucket lifecycle, including eviction).
    ``tag`` is a free-form client label echoed in reports.
    """

    stepper: str
    steps: int
    precision: Union[str, PrecisionConfig] = "f32"
    overrides: Optional[Dict[str, Any]] = None
    cfg: Any = None
    policy: Union[str, PrecisionPolicy, None] = None
    snapshot_every: Optional[int] = None
    execution: str = "auto"
    state0: Any = None
    storage: str = "f32"
    tag: str = ""


class RequestResult(NamedTuple):
    """Terminal payload of a completed request (host-side arrays)."""

    state: Any  # final solver state (numpy pytree)
    snapshots: List[Any]  # observable frames, arrival order
    snapshot_steps: List[int]
    tracker: Optional[Any]  # final SiteTracker (tracked modes)
    final_k: Optional[Dict[str, int]]  # per-site converged splits
    adjustments: Optional[Dict[str, Tuple[int, int]]]  # site -> (grew, shrank)
    elapsed: int
    chunks: int  # how many bucket chunks this request rode


class BucketKey(NamedTuple):
    """Scheduler compatibility key — see module docstring. ``storage`` is
    part of the key: members carrying packed state step through a different
    compiled program (PackedArray carry) than f32 members and must never
    share a stack with them."""

    stepper: str
    cfg: Any
    prec: PrecisionConfig
    execution: str
    shape_sig: Any
    storage: str = "f32"

    def short(self) -> str:
        s = f"{self.stepper}/{self.prec.mode}/{self.execution}"
        return s if self.storage == "f32" else f"{s}/{self.storage}"


def _shape_sig(state) -> Tuple:
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return (treedef, tuple((tuple(x.shape), str(x.dtype)) for x in leaves))


def _resolve_precision(precision: Union[str, PrecisionConfig]) -> PrecisionConfig:
    if isinstance(precision, PrecisionConfig):
        return precision
    if precision in PRESETS:
        return PRESETS[precision]
    # bare mode name ("rr_tracked", "deploy", a registered third-party mode);
    # PrecisionConfig validates against the registry's known modes
    return PrecisionConfig(mode=precision)


class RequestRecord:
    """The live, mutable runtime record of one admitted request.

    ``state``/``tracker`` are the member's carried simulation state between
    chunks — the batcher stacks them into a bucket's vmapped call and hands
    the sliced results back, so the adjust unit's ``k`` and §5.3 counters
    genuinely survive repacking, eviction and resume.

    Lifecycle (``status``): ``queued`` -> ``running`` -> (``evicted`` <->
    ``running``) -> ``done`` | ``failed``.
    """

    def __init__(self, rid: int, req: SimRequest, sim: Simulation, key: BucketKey,
                 state, tracker, steps: int, every: int):
        self.id = rid
        self.req = req
        self.sim = sim
        self.key = key
        self.state = state
        self.tracker = tracker
        self.tracked = tracker is not None
        self.steps = steps
        self.every = every
        self.elapsed = 0
        self.chunks = 0
        self.status = "queued"
        self.stream = ResultStream()
        self.snapshots: List[Tuple[int, Any]] = []
        self.result: Optional[RequestResult] = None
        self.error: Optional[str] = None  # set when status == "failed"
        self.ckpt_dir: Optional[str] = None
        self.templates = None  # ShapeDtypeStruct tree for ckpt restore

    # -- scheduling queries --------------------------------------------------

    @property
    def remaining(self) -> int:
        return self.steps - self.elapsed

    def steps_to_next_event(self) -> int:
        """Steps until this member next needs the bucket to pause — its own
        snapshot point or its horizon, whichever is sooner. The bucket chunk
        is the min of this over members (continuous batching never steps a
        member past one of its events)."""
        return min(self.remaining, self.every - (self.elapsed % self.every))

    def snapshot_due(self) -> bool:
        """Does the current ``elapsed`` coincide with one of the snapshot
        points a solo ``Simulation.run(steps, snapshot_every=every)`` would
        record? Exactly the positive multiples of the cadence: chunking
        never advances past the horizon, so every such multiple is one the
        solo run snapshots (remainder steps never land on one)."""
        return self.elapsed > 0 and self.elapsed % self.every == 0

    def site_summary(self):
        """(final_k, adjustments) dicts from the carried tracker, or Nones."""
        if self.tracker is None:
            return None, None
        st = self.tracker.state
        names = self.tracker.names
        final_k = {n: int(st.k[i]) for i, n in enumerate(names)}
        adjustments = {
            n: (int(st.overflow_steps[i]), int(st.shrink_steps[i]))
            for i, n in enumerate(names)
        }
        return final_k, adjustments

    def __repr__(self) -> str:
        return (
            f"RequestRecord(id={self.id}, {self.key.short()}, "
            f"{self.elapsed}/{self.steps}, {self.status})"
        )


def resolve_request(rid: int, req: SimRequest) -> RequestRecord:
    """Admission-time resolution: validate and freeze everything static.

    Raises (rejecting the request before it enters the queue) on: unknown
    stepper/mode, invalid horizon, unvalidated or foreign policy artifacts,
    format-mismatched artifacts, and explicitly-requested-but-ineligible
    fused execution.
    """
    if req.steps <= 0:
        raise ValueError(f"request horizon must be positive, got {req.steps}")
    if req.snapshot_every is not None and req.snapshot_every <= 0:
        raise ValueError(
            f"snapshot_every must be positive, got {req.snapshot_every} — a "
            "non-positive cadence would drive bucket chunking backwards"
        )

    prec = _resolve_precision(req.precision)
    sim0 = Simulation(req.stepper, req.cfg, prec)  # resolves stepper + default cfg
    stepper, cfg = sim0.stepper, sim0.cfg
    if req.overrides:
        cfg = dataclasses.replace(cfg, **req.overrides)

    policy = None
    if req.policy is not None:
        prec, policy = resolve_policy(prec, req.policy)  # accepted-gate + fmt rebase
        if policy.stepper != stepper.name:
            raise ValueError(
                f"policy artifact was profiled for stepper {policy.stepper!r} "
                f"but the request targets {stepper.name!r}; per-site splits "
                "do not transfer across steppers"
            )
        prec = policy.apply(prec, stepper.sites)  # [k_lo, k_hi] -> prec.k_bounds

    sim = Simulation(stepper, cfg, prec)
    execution = sim._resolve_execution(req.execution)  # "auto" -> concrete plane
    storage = sim._resolve_storage(req.storage)  # reject unknown formats at admit

    state0 = stepper.init_state(cfg) if req.state0 is None else req.state0
    state0 = jax.tree_util.tree_map(jnp.asarray, state0)
    tracker = sim.init_tracker(
        k0=None if policy is None else policy.k_array(stepper.sites)
    )
    every = req.snapshot_every or max(1, req.steps // stepper.snapshots_default)

    key = BucketKey(stepper.name, cfg, prec, execution, _shape_sig(state0), storage)
    return RequestRecord(rid, req, sim, key, state0, tracker, req.steps, every)


def scaled_state0(stepper_name: str, scale: float = 1.0, overrides=None):
    """A stepper's default initial condition scaled by ``scale`` (with
    optional config-override fields) — the burst drivers' way of submitting
    members that genuinely differ while staying bucket-compatible."""
    from repro.pde.registry import get_stepper

    stepper = get_stepper(stepper_name)
    cfg = stepper.default_config()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return jax.tree_util.tree_map(
        lambda x: (scale * x).astype(x.dtype), stepper.init_state(cfg)
    )
