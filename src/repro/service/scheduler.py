"""Admission, bucketing and the `SimService` facade.

The scheduler — not the user — decides how requests pack onto hardware
(the RAPTOR/Siklósi shape: every request carries its own stepper, horizon
and validated precision artifact; the service owns the packing):

* **admission control** — a bounded FIFO queue; ``submit`` resolves the
  request eagerly (bad steppers/modes/artifacts are rejected before they
  cost anything) and raises :class:`ServiceOverloaded` once the queue is
  full — backpressure the client can see.
* **bucketing** — queued requests join the first
  :class:`~repro.service.batcher.Bucket` of their
  :class:`~repro.service.request.BucketKey` with room (``max_bucket`` caps
  the vmap width; further compatible requests open sibling buckets), up to
  ``max_active_members`` total running members — the service's hardware
  occupancy budget. Joins happen only at chunk boundaries, which is when
  ``pump`` runs the fill pass.
* **eviction / resume** — ``evict`` checkpoints a running member's
  ``(state, tracker)`` through :mod:`repro.ckpt` (atomic, bit-exact arrays)
  and frees its slot; ``resume`` restores and re-queues it, and the fill
  pass auto-resumes evicted members whenever slots are free and no fresh
  work is queued. With ``auto_evict=True`` the fill pass itself evicts the
  longest-remaining member to admit shorter queued work — the
  long-horizon-spill policy. Resumed members rejoin at a chunk boundary
  with their carried tracker intact, so an evicted+resumed request's
  trajectory is bit-identical to an uninterrupted one (tested).

``pump()`` is one cooperative scheduling iteration (fill → advance one
bucket one chunk → fill); ``run_until_idle()`` drives it to completion.
Single-process and synchronous by design — the batching/scheduling
semantics are the subject here, not an async runtime; a server front-end
can pump this loop from any thread.
"""

from __future__ import annotations

import dataclasses
import itertools
import os
from collections import deque
from typing import Deque, Dict, List, Optional

import jax

import repro.obs as obs
import repro.obs.health as health
from repro.ckpt import checkpoint as ckpt

from .batcher import Bucket, ChunkCompiler
from .metrics import ServiceMetrics
from .request import RequestRecord, SimRequest, resolve_request
from .stream import RequestHandle

__all__ = ["ServiceConfig", "ServiceOverloaded", "SimService"]


class ServiceOverloaded(RuntimeError):
    """Admission queue is full — backpressure; retry later."""


@dataclasses.dataclass
class ServiceConfig:
    """Knobs of the serving plane (all host-side scheduling policy)."""

    max_queue: int = 64  # admission bound; submit raises beyond it
    max_bucket: int = 8  # vmap width cap per bucket
    max_active_members: int = 16  # total running members (occupancy budget)
    ckpt_dir: str = "artifacts/service_ckpt"  # eviction checkpoint root
    auto_evict: bool = False  # spill longest-remaining members under pressure
    evict_min_remaining: int = 64  # only members with more left are spillable
    auto_resume: bool = True  # restore evicted members when slots free up
    #: None = auto: shard bucket members on the logical ``batch`` axis iff a
    #: ``dist.sharding.axis_rules`` mesh context is active at chunk time.
    #: The context stack is THREAD-LOCAL — pump from the thread that entered
    #: ``axis_rules`` (or pass an explicit True and enter the context around
    #: the pumping thread's loop); a different thread sees no mesh and would
    #: silently run unsharded.
    sharded: Optional[bool] = None
    #: how many terminal (done/failed) RequestRecords the service itself
    #: retains for ``handle(id)`` lookups; older ones are released so a
    #: long-lived service never grows unbounded host state (clients holding
    #: a RequestHandle keep their record alive regardless)
    retain_terminal: int = 1024


class SimService:
    """The batched simulation-serving plane (see module docstring)."""

    def __init__(self, config: Optional[ServiceConfig] = None):
        self.config = config or ServiceConfig()
        self.metrics = ServiceMetrics()
        self._queue: Deque[RequestRecord] = deque()
        self._buckets: Dict[object, List[Bucket]] = {}
        self._requests: Dict[int, RequestRecord] = {}
        self._terminal: Deque[int] = deque()  # retention FIFO of finished ids
        self._evicted: Deque[RequestRecord] = deque()
        self._ids = itertools.count(1)
        self._compiler = ChunkCompiler()
        self._rr = 0  # round-robin bucket cursor

    # -- client surface ------------------------------------------------------

    def submit(self, req: SimRequest) -> RequestHandle:
        """Admit one request (resolved eagerly; may raise, see
        ``resolve_request``) or raise :class:`ServiceOverloaded`."""
        if len(self._queue) >= self.config.max_queue:
            self.metrics.rejected += 1
            raise ServiceOverloaded(
                f"admission queue is full ({self.config.max_queue} requests); "
                "pump the service or retry later"
            )
        try:
            rec = resolve_request(next(self._ids), req)
        except Exception:
            self.metrics.rejected += 1
            raise
        self._queue.append(rec)
        self._requests[rec.id] = rec
        self.metrics.submitted += 1
        mon = health.active()
        if mon is not None:
            mon.on_submit(rec)  # deterministic shadow-sampling decision
        obs.instant(
            "request.submit",
            request=rec.id,
            stepper=rec.key.stepper,
            mode=rec.key.prec.mode,
            steps=rec.steps,
        )
        return RequestHandle(rec)

    def handle(self, request_id: int) -> RequestHandle:
        return RequestHandle(self._requests[request_id])

    def pump(self) -> bool:
        """One scheduling iteration: fill buckets, advance ONE bucket by one
        chunk, fill again (joins/drains happen at the boundary). Returns
        False when there is nothing left to do."""
        with obs.span("service.pump") as sp:
            self._fill()
            buckets = self._live_buckets()
            if not buckets:
                return False
            bucket = buckets[self._rr % len(buckets)]
            self._rr += 1
            if sp is not None:
                sp["bucket"] = bucket.key.short()
                sp["members"] = len(bucket)
            mon = health.active()
            if mon is not None:
                mon.note_occupancy(self.queued, self.active_members)
            try:
                drained = bucket.advance(
                    self._compiler, self.metrics, sharded=self.config.sharded
                )
            except Exception as e:  # compile/runtime failure: fail the members
                for m in list(bucket.members):
                    bucket.members.remove(m)
                    m.status = "failed"
                    m.error = repr(e)
                    m.stream.emit("failed", m.elapsed, repr(e))
                    self.metrics.failed += 1
                    self._retire(m)
                    if mon is not None:
                        mon.on_request_failed(m, repr(e))
                raise
            for m in drained:
                self._retire(m)
            self._gc_buckets()
            self._fill()
        return True

    def _retire(self, rec: RequestRecord) -> None:
        """Bound service-side retention of terminal records: keep the most
        recent ``retain_terminal`` for ``handle(id)`` lookups, release the
        rest (outstanding RequestHandles keep their record alive)."""
        self._terminal.append(rec.id)
        while len(self._terminal) > self.config.retain_terminal:
            self._requests.pop(self._terminal.popleft(), None)

    def run_until_idle(self, max_chunks: int = 100_000) -> ServiceMetrics:
        """Pump until no bucket has members and the queue is empty (evicted
        members auto-resume along the way unless ``auto_resume=False``)."""
        for _ in range(max_chunks):
            if not self.pump():
                break
        return self.metrics

    # -- occupancy -----------------------------------------------------------

    @property
    def active_members(self) -> int:
        return sum(len(b) for bs in self._buckets.values() for b in bs)

    @property
    def queued(self) -> int:
        return len(self._queue)

    @property
    def evicted_ids(self) -> List[int]:
        return [m.id for m in self._evicted]

    def _live_buckets(self) -> List[Bucket]:
        return [b for bs in self._buckets.values() for b in bs if b.members]

    def _gc_buckets(self) -> None:
        for key in list(self._buckets):
            self._buckets[key] = [b for b in self._buckets[key] if b.members]
            if not self._buckets[key]:
                del self._buckets[key]

    # -- bucketing -----------------------------------------------------------

    def _bucket_for(self, rec: RequestRecord) -> Bucket:
        buckets = self._buckets.setdefault(rec.key, [])
        for b in buckets:
            if len(b) < self.config.max_bucket:
                return b
        b = Bucket(rec.key)
        buckets.append(b)
        return b

    def _fill(self) -> None:
        cfg = self.config
        while self._queue and self.active_members < cfg.max_active_members:
            rec = self._queue.popleft()
            self._bucket_for(rec).add(rec)
        # pressure: spill the longest-remaining member to admit queued work
        while self._queue and cfg.auto_evict:
            victim = self._evictable()
            if victim is None or victim.remaining <= self._queue[0].remaining:
                break
            self.evict(victim.id)
            rec = self._queue.popleft()
            self._bucket_for(rec).add(rec)
        # free slots + no fresh work: transparently restore evicted members
        while (
            cfg.auto_resume
            and self._evicted
            and not self._queue
            and self.active_members < cfg.max_active_members
        ):
            self.resume(self._evicted[0].id)
            rec = self._queue.popleft()  # resume() re-queues; admit it now
            self._bucket_for(rec).add(rec)

    def _evictable(self) -> Optional[RequestRecord]:
        members = [m for b in self._live_buckets() for m in b.members]
        members = [m for m in members if m.remaining > self.config.evict_min_remaining]
        return max(members, key=lambda m: m.remaining) if members else None

    # -- eviction / resume ---------------------------------------------------

    def _ckpt_dir(self, rec: RequestRecord) -> str:
        return os.path.join(self.config.ckpt_dir, f"req_{rec.id:06d}")

    def evict(self, request_id: int) -> str:
        """Checkpoint a running (or still-queued) request out of the service.

        The member's carried ``(state, tracker)`` goes through
        ``repro.ckpt`` (atomic directory rename; f32/int32 arrays round-trip
        bit-exactly) stamped with its elapsed step; the slot frees
        immediately. Returns the checkpoint directory."""
        rec = self._requests[request_id]
        if rec.status not in ("running", "queued"):
            raise ValueError(
                f"request {request_id} is {rec.status!r}; only running or "
                "queued requests can be evicted"
            )
        tree = {"state": rec.state, "tracker": rec.tracker}
        rec.ckpt_dir = self._ckpt_dir(rec)
        ckpt.save(tree, rec.ckpt_dir, step=rec.elapsed)
        # structure templates for the mesh-agnostic restore
        rec.templates = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
        )
        if rec.status == "running":
            for b in self._buckets.get(rec.key, []):
                if rec in b.members:
                    b.members.remove(rec)
                    break
            self._gc_buckets()
        else:
            self._queue.remove(rec)
        rec.state = None
        rec.tracker = None
        rec.status = "evicted"
        self._evicted.append(rec)
        rec.stream.emit("evicted", rec.elapsed, rec.ckpt_dir)
        obs.instant("request.evict", request=rec.id, step=rec.elapsed)
        self.metrics.evicted += 1
        return rec.ckpt_dir

    def resume(self, request_id: int) -> RequestHandle:
        """Restore an evicted request from its checkpoint and re-queue it;
        it rejoins a bucket at the next fill pass with its adjust-unit state
        (split ``k``, EMAs, §5.3 counters) exactly as checkpointed."""
        rec = self._requests[request_id]
        if rec.status != "evicted":
            raise ValueError(f"request {request_id} is {rec.status!r}, not evicted")
        step = ckpt.latest_step(rec.ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {rec.ckpt_dir}")
        tree = ckpt.restore(rec.templates, rec.ckpt_dir, step)
        rec.state, rec.tracker = tree["state"], tree["tracker"]
        rec.elapsed = step
        rec.status = "queued"
        self._evicted.remove(rec)
        self._queue.append(rec)
        rec.stream.emit("resumed", rec.elapsed)
        obs.instant("request.resume", request=rec.id, step=rec.elapsed)
        self.metrics.resumed += 1
        return RequestHandle(rec)
