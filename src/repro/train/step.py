"""train_step / serve_step builders + parameter sharding rules.

Sharding strategy (DESIGN.md §6):
  - weights: TP on the 'model' axis (FFN hidden, attention head block,
    experts, vocab) x FSDP/ZeRO-3 on ('pod','data') for the other big dim;
  - optimizer state: mirrors parameter sharding (ZeRO-3 falls out);
  - activations: constrained on (batch -> ('pod','data')); internal layouts
    are left to XLA's sharding propagation from the weight specs, which
    avoids forcing uneven head splits (e.g. 40 or 56 q-heads on a 16-wide
    model axis) and lets SPMD insert the cheapest collectives;
  - decode KV cache: sequence axis on 'model' (flash-decoding softmax).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import decode_step, init_decode_state, lm_loss, model_init
from repro.precision import PrecisionConfig
from repro.models.config import ModelConfig
from repro.train.optimizer import OptConfig, opt_init, opt_update

__all__ = [
    "TrainConfig",
    "param_pspec",
    "params_pspec_tree",
    "state_pspec_tree",
    "batch_pspec",
    "make_train_step",
    "make_serve_step",
    "init_train_state",
]

_FSDP = ("pod", "data")

# name -> spec for the *last two-or-three* dims of 2D/3D weights
_RULES_2D = {
    "embed": ("model", _FSDP),
    "head": (_FSDP, "model"),
    "frontend_proj": (None, _FSDP),
    "wq": (_FSDP, "model"),
    "wk": (_FSDP, "model"),
    "wv": (_FSDP, "model"),
    "wo": ("model", _FSDP),
    "gate": (_FSDP, "model"),
    "up": (_FSDP, "model"),
    "down": ("model", _FSDP),
    "router": (_FSDP, None),
    "in_proj": (_FSDP, "model"),
    "conv_w": (None, "model"),
    "x_proj": ("model", None),
    "dt_proj": (None, "model"),
    "A_log": ("model", None),
    "out_proj": ("model", _FSDP),
    "up_x": (_FSDP, "model"),
    "up_z": (_FSDP, "model"),
    "w_if": ("model", None),
    "w_in": (_FSDP, None),
}

_RULES_3D = {  # MoE expert-stacked weights: experts on 'model' (EP),
    # FSDP on the d_model dim. (A/B-measured on qwen3 train_4k, §Perf:
    # f-dim FSDP regressed the collective term 180s->241s; einsum one-hot
    # dispatch traded 180s coll for +252s of quadratic dispatch FLOPs.)
    "gate": ("model", _FSDP, None),
    "up": ("model", _FSDP, None),
    "down": ("model", None, _FSDP),
}


def _filter_axes(spec, mesh: Mesh):
    out = []
    for s in spec:
        if s is None:
            out.append(None)
        elif isinstance(s, tuple):
            kept = tuple(a for a in s if a in mesh.axis_names)
            out.append(kept if kept else None)
        else:
            out.append(s if s in mesh.axis_names else None)
    return tuple(out)


def param_pspec(path, leaf, mesh: Mesh) -> P:
    names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    name = names[-1] if names else None
    scanned = "blocks" in names  # leading group dim from scan stacking
    nd = leaf.ndim - (1 if scanned else 0)

    spec = None
    if name in ("wq", "wk", "wv") and nd == 3:
        spec = ("model", None, None)  # mLSTM block-diagonal projections
    elif nd == 3 and name in _RULES_3D:
        spec = _RULES_3D[name]
    elif nd == 2 and name in _RULES_2D:
        spec = _RULES_2D[name]
    elif name == "r_blk":
        spec = (None,) * nd
    else:
        spec = (None,) * nd  # norms, biases, scalars: replicated

    spec = _filter_axes(spec, mesh)
    if scanned:
        spec = (None,) + spec
    return P(*spec)


def params_pspec_tree(params, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(path, leaf, mesh), params
    )


def state_pspec_tree(state, params_spec, mesh: Mesh):
    """Optimizer/train state mirrors parameter sharding; counters replicated."""

    def spec_for(path, leaf):
        names = [getattr(k, "key", None) for k in path]
        if names and names[0] == "params":
            return param_pspec(path[1:], leaf, mesh)
        if names and names[0] == "opt":
            # mu/nu/v mirror params; factored vr/vc keep the surviving dims
            inner = [n for n in names[1:] if n not in ("mu", "nu", "v", "vr", "vc")]
            # reconstruct a pseudo-path for the rule lookup
            class _K:  # minimal DictKey stand-in
                def __init__(self, key):
                    self.key = key

            pseudo = [_K(n) for n in inner if n is not None]
            if names[-1] in ("vr", "vc"):
                return P(*((None,) * leaf.ndim))  # factored: replicate (small)
            if leaf.ndim == 0:
                return P()
            return param_pspec(pseudo, leaf, mesh)
        return P(*((None,) * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, state)


def batch_pspec(batch_tree, mesh: Mesh):
    fsdp = tuple(a for a in _FSDP if a in mesh.axis_names)
    return jax.tree_util.tree_map(
        lambda leaf: P(fsdp if fsdp else None, *((None,) * (leaf.ndim - 1))), batch_tree
    )


# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1  # gradient accumulation
    remat: bool = True
    window: Optional[int] = None
    carry_dtype: Optional[str] = None  # "bf16" stores scan boundaries in bf16
    grad_comm: Optional[str] = None  # None | "bf16" | "rr16" — gradient
    # compression for the cross-pod all-reduce. "rr16" quantizes each gradient
    # tensor to the paper's 16-bit flexible format (per-tensor runtime split):
    # halves DCI payload vs f32 with ~12 mantissa bits where the range is
    # narrow — a beyond-paper application of R2F2 (DESIGN.md §6).


def init_train_state(key, cfg: ModelConfig, tcfg: TrainConfig):
    params = model_init(key, cfg)
    return {
        "params": params,
        "opt": opt_init(params, tcfg.opt),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(
    cfg: ModelConfig,
    prec: PrecisionConfig,
    tcfg: TrainConfig,
    param_shardings=None,
):
    """Returns train_step(state, batch) -> (state, metrics). Pure; jit at
    the call site with mesh-specific shardings.

    ``param_shardings``: optional pytree of NamedShardings matching params.
    Pinning gradients to the parameter sharding forces XLA to REDUCE-SCATTER
    the data-parallel gradient sum instead of all-reducing to a replicated
    gradient (§Perf: unpinned microbatch accumulators made XLA all-reduce
    full f32 expert/param gradients per microbatch — TiBs of traffic).
    """
    prec_rr16 = dataclasses.replace(prec, mode="rr_tile")

    carry = jnp.bfloat16 if tcfg.carry_dtype == "bf16" else None

    def pin(grads):
        if param_shardings is None:
            return grads
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, param_shardings
        )

    def loss_fn(params, batch):
        return lm_loss(
            params, batch, cfg, prec, window=tcfg.window, remat=tcfg.remat,
            carry_dtype=carry,
        )

    def train_step(state, batch):
        if tcfg.microbatches > 1:
            mb = tcfg.microbatches

            def micro(acc, mbatch):
                l, g = jax.value_and_grad(loss_fn)(state["params"], mbatch)
                g = pin(g)
                return (
                    acc[0] + l / mb,
                    pin(jax.tree_util.tree_map(lambda a, b: a + b / mb, acc[1], g)),
                ), None

            zeros = pin(jax.tree_util.tree_map(jnp.zeros_like, state["params"]))
            split = jax.tree_util.tree_map(
                lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]), batch
            )
            (loss, grads), _ = jax.lax.scan(micro, (jnp.float32(0.0), zeros), split)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
            grads = pin(grads)

        if tcfg.grad_comm == "bf16":
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads
            )
        elif tcfg.grad_comm == "rr16":
            from repro.precision import prepare_operand

            grads = jax.tree_util.tree_map(
                lambda g: prepare_operand(g, prec_rr16)[0], grads
            )

        new_params, new_opt, metrics = opt_update(
            grads, state["opt"], state["params"], tcfg.opt, state["step"]
        )
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, prec: PrecisionConfig, window: Optional[int] = None):
    """Returns serve_step(params, caches, tokens, pos) -> (next_tokens, caches).
    One greedy decode step against a filled KV cache."""

    def serve_step(params, caches, tokens, pos):
        logits, caches = decode_step(params, caches, tokens, pos, cfg, prec, window=window)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return nxt, caches

    return serve_step
