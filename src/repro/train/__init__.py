"""Training substrate: optimizers, train/serve step builders, sharding rules."""

from .optimizer import OptConfig, lr_at, opt_init, opt_update
from .step import (
    TrainConfig,
    batch_pspec,
    init_train_state,
    make_serve_step,
    make_train_step,
    params_pspec_tree,
    state_pspec_tree,
)
