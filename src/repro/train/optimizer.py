"""Optimizers (pure-JAX, no external deps): AdamW and Adafactor.

AdamW is the default; Adafactor (factored second moment, no first moment by
default) is the memory-tier option that lets llama3-405b training states fit
a single 256-chip pod (see EXPERIMENTS.md §Perf — optimizer-state bytes are
a roofline memory term at that scale).

All state is a pytree mirroring ``params`` and shards identically to the
parameters (FSDP over ('pod','data')), so ZeRO-3 falls out of the sharding
rules rather than being a separate mechanism.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "opt_init", "opt_update", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"  # "adamw" | "adafactor"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # adafactor
    factored_min: int = 128  # factor second moment only for dims >= this


def lr_at(cfg: OptConfig, step):
    """Linear warmup + cosine decay to 10%."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.55 + 0.45 * jnp.cos(jnp.pi * prog)
    return cfg.lr * warm * cos


def _is_factored(shape, cfg: OptConfig):
    return len(shape) >= 2 and shape[-1] >= cfg.factored_min and shape[-2] >= cfg.factored_min


def opt_init(params, cfg: OptConfig):
    if cfg.kind == "adamw":
        return {
            "mu": jax.tree_util.tree_map(jnp.zeros_like, params),
            "nu": jax.tree_util.tree_map(jnp.zeros_like, params),
            "count": jnp.zeros((), jnp.int32),
        }
    if cfg.kind == "adafactor":

        def second_moment(p):
            if _is_factored(p.shape, cfg):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p)}

        return {
            "v": jax.tree_util.tree_map(second_moment, params),
            "count": jnp.zeros((), jnp.int32),
        }
    raise ValueError(cfg.kind)


def _global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree))
    )


def opt_update(grads, state, params, cfg: OptConfig, step):
    """Returns (new_params, new_state, metrics)."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    lr = lr_at(cfg, step)

    if cfg.kind == "adamw":
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - cfg.b1**c
        bc2 = 1.0 - cfg.b2**c

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32)
            mu = cfg.b1 * mu + (1 - cfg.b1) * g
            nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
            u = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
            p = p - lr * (u + cfg.weight_decay * p)
            return p, mu, nu

        flat, tdef = jax.tree_util.tree_flatten(params)
        gflat = tdef.flatten_up_to(grads)
        muf = tdef.flatten_up_to(state["mu"])
        nuf = tdef.flatten_up_to(state["nu"])
        out = [upd(p, g, m, n) for p, g, m, n in zip(flat, gflat, muf, nuf)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_state = {
            "mu": tdef.unflatten([o[1] for o in out]),
            "nu": tdef.unflatten([o[2] for o in out]),
            "count": count,
        }
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

    # ---- adafactor ----
    count = state["count"] + 1
    decay = 1.0 - (count.astype(jnp.float32) + 1.0) ** -0.8

    def upd(p, g, v):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if "vr" in v:
            vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
            new_v = {"vr": vr, "vc": vc}
        else:
            vhat = decay * v["v"] + (1 - decay) * g2
            new_v = {"v": vhat}
        u = g / jnp.sqrt(vhat + 1e-30)
        # update clipping (Adafactor RMS rule)
        u = u / jnp.maximum(1.0, _rms(u))
        p = p - lr * (u + cfg.weight_decay * p)
        return p, new_v

    flat, tdef = jax.tree_util.tree_flatten(params)
    gflat = tdef.flatten_up_to(grads)
    vf = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, v) for p, g, v in zip(flat, gflat, vf)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {"v": tdef.unflatten([o[1] for o in out]), "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)
