"""Logical-axis sharding: name activation dims, resolve them per mesh.

Model code annotates activations with *logical* axis names
(``constrain(x, "batch", "seq", "embed")``) instead of mesh axes, so the
same forward pass runs unsharded in unit tests, on the host mesh, and on
the (2, 16, 16) production mesh without edits. The mapping from logical
name to mesh axes lives in one table (:data:`DEFAULT_RULES`, DESIGN.md §6):

  * ``batch``   -> ("pod", "data")   outer data parallelism / FSDP
  * ``heads`` / ``mlp`` / ``vocab`` / ``experts`` -> "model"  (TP / EP)
  * ``kv_seq`` -> "model"            decode KV cache sequence sharding
                                     (flash-decoding softmax; kv *heads*
                                     stay unsharded — GQA head counts are
                                     usually below the TP degree)
  * ``seq`` / ``embed`` / ``kv_heads`` -> None (left to XLA propagation)

``constrain`` is a no-op unless an :func:`axis_rules` context is active, so
importing a model never touches jax device state. Inside the context it
lowers to ``jax.lax.with_sharding_constraint`` with every rule filtered
against the live mesh: axes the mesh doesn't have are dropped, and a dim
that the surviving axes don't divide evenly is left unconstrained (small
test meshes must never make a model shape invalid).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DEFAULT_RULES", "active_mesh", "axis_rules", "constrain", "logical_spec"]

# One entry per logical activation axis: mesh axis name, tuple of names, or
# None (unconstrained). Axes missing from the live mesh are filtered at
# resolution time, so the same table serves (data,), (data, model) and
# (pod, data, model) meshes.
Rule = Union[None, str, Tuple[str, ...]]

DEFAULT_RULES: Dict[str, Rule] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": None,
    "kv_seq": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
}

_ACTIVE = threading.local()  # .stack: list of (mesh, rules)


def _filter_rule(rule: Rule, mesh: Mesh) -> Rule:
    """Drop mesh axes the live mesh doesn't have; collapse empties to None."""
    if rule is None:
        return None
    if isinstance(rule, str):
        return rule if rule in mesh.axis_names else None
    kept = tuple(a for a in rule if a in mesh.axis_names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def logical_spec(name: Optional[str], *, mesh: Mesh, rules: Optional[Dict[str, Rule]] = None) -> Rule:
    """Resolve one logical axis name to a PartitionSpec entry for ``mesh``.

    Unknown names raise ``KeyError`` — a typo'd logical axis must fail loudly
    rather than silently replicate. ``None`` passes through (unconstrained).
    """
    if name is None:
        return None
    table = DEFAULT_RULES if rules is None else rules
    return _filter_rule(table[name], mesh)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[Dict[str, Rule]] = None):
    """Activate ``constrain`` with this mesh + rule table for the block.

    Nestable; the innermost context wins. Typical use::

        with mesh, axis_rules(mesh):
            step = jax.jit(make_train_step(...))
            state, metrics = step(state, batch)
    """
    stack = getattr(_ACTIVE, "stack", None)
    if stack is None:
        stack = _ACTIVE.stack = []
    stack.append((mesh, DEFAULT_RULES if rules is None else rules))
    try:
        yield mesh
    finally:
        stack.pop()


def active_mesh() -> Optional[Mesh]:
    """The innermost :func:`axis_rules` context's mesh, or None.

    Lets mesh-agnostic layers (e.g. the ``repro.service`` batcher putting
    bucket members on the logical ``batch`` axis) decide whether to request
    sharded ensembles without threading a mesh handle through their API.
    """
    stack = getattr(_ACTIVE, "stack", None)
    return stack[-1][0] if stack else None


def _axis_extent(rule: Rule, mesh: Mesh) -> int:
    ext = 1
    for a in rule if isinstance(rule, tuple) else (rule,):
        ext *= mesh.shape[a]
    return ext


def constrain(x, *names: Optional[str]):
    """Annotate each dim of ``x`` with a logical axis name (or None).

    Outside an :func:`axis_rules` context this is the identity, which keeps
    unit tests and single-host examples mesh-free. Inside, it resolves every
    name through the active rule table and applies a sharding constraint,
    skipping dims the mesh extent does not divide.
    """
    stack = getattr(_ACTIVE, "stack", None)
    if not stack:
        return x
    mesh, rules = stack[-1]
    if len(names) != x.ndim:
        raise ValueError(
            f"constrain got {len(names)} axis names for rank-{x.ndim} value {x.shape}"
        )
    entries = []
    for dim, name in zip(x.shape, names):
        rule = logical_spec(name, mesh=mesh, rules=rules)
        if rule is not None and dim % _axis_extent(rule, mesh) != 0:
            rule = None  # uneven split: leave the dim to XLA propagation
        entries.append(rule)
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))
