"""repro.dist — logical-axis sharding for models and launchers."""

from .sharding import DEFAULT_RULES, axis_rules, constrain, logical_spec

__all__ = ["DEFAULT_RULES", "axis_rules", "constrain", "logical_spec"]
