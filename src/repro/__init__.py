"""repro — R2F2 (runtime-reconfigurable floating-point precision) in JAX.

Paper: "Exploring and Exploiting Runtime Reconfigurable Floating Point
Precision in Scientific Computing: a Case Study for Solving PDEs" (2024).

Subpackages:
  core      — flexible formats, R2F2 multiplier, PrecisionConfig/RangeTracker
  precision — THE precision surface: PrecisionEngine registry, named-site
              SiteTracker, contract/dot/multiply/divide/store functional API
              (core.rr_dot and pde.precision_ops are shims over it)
  kernels  — Pallas TPU kernels (+ jnp oracles)
  pde      — heat1d / swe2d case studies
  models   — 10-architecture LM zoo (dense/MoE/SSM/xLSTM/hybrid/encoder/VLM)
  configs  — assigned architectures x shapes registry
  train    — optimizers, train/serve steps, sharding rules
  ckpt     — fault-tolerant checkpointing
  data     — deterministic synthetic pipelines
  serve    — prefill + decode serving
  dist     — logical-axis sharding
  launch   — production meshes, multi-pod dry-run, HLO cost rollup, CLI
"""

__version__ = "1.0.0"
