"""Batched serving loop: prefill once, then jit-compiled greedy decode.

The decode step is the same ``serve_step`` the dry-run lowers for the
decode_32k / long_500k cells; this module adds the host-side loop and a
minimal static-batch scheduler (requests padded to the batch; finished
sequences keep decoding into a sink — the standard static-batching serving
baseline, which the dry-run's KV sharding story is built around).

Deploy serving consumes the same :class:`repro.profile.PrecisionPolicy`
artifact format the PDE steppers profile and validate: pass ``policy=`` (an
object or a JSON path) and the serving precision is derived from the
artifact — its ``<EB,MB,FX>`` format, gated on the artifact having passed
its closed-loop validation — instead of implicit engine defaults. The
artifact's per-site ``[k_lo, k_hi]`` hints are keyed by *its* site names
and only apply where a consumer threads a tracker with matching sites, so
they are deliberately NOT installed here (serving threads no tracker; a
positional install against foreign site names would clamp the wrong rows).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import init_decode_state, prefill
from repro.precision import PrecisionConfig
from repro.models.config import ModelConfig
from repro.train.step import make_serve_step

__all__ = ["generate", "resolve_policy"]


def resolve_policy(prec: PrecisionConfig, policy, require_accepted: bool = True):
    """Derive the serving precision from a PrecisionPolicy artifact.

    Thin shim: the accepted-gate and format-rebase rules live in
    :func:`repro.profile.artifact.resolve_policy`, shared with the
    simulation-serving plane (``repro.service``) so the two consumers can
    never drift. Returns ``(prec, policy)``; the per-site hints stay on the
    returned artifact for consumers that thread a tracker whose site names
    match (see module docstring).
    """
    from repro.profile.artifact import resolve_policy as _resolve  # lazy: light

    return _resolve(prec, policy, require_accepted=require_accepted)


def generate(
    params,
    cfg: ModelConfig,
    prec: PrecisionConfig,
    prompts: jnp.ndarray,  # (B, S_prompt) int32
    max_new_tokens: int = 32,
    max_len: Optional[int] = None,
    window: Optional[int] = None,
    eos_id: Optional[int] = None,
    policy=None,
):
    """Greedy generation. Returns (B, max_new_tokens) int32.

    ``policy``: optional PrecisionPolicy artifact (object or JSON path) the
    serving precision is derived from (see :func:`resolve_policy`).
    """
    if policy is not None:
        prec, _ = resolve_policy(prec, policy)
    B, S = prompts.shape
    max_len = max_len or (S + max_new_tokens)

    logits, caches = prefill(params, cfg, prec, tokens=prompts, max_len=max_len, window=window)
    next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]

    step_fn = jax.jit(make_serve_step(cfg, prec, window=window))
    out = [next_tok]
    done = jnp.zeros((B, 1), bool)
    for i in range(max_new_tokens - 1):
        tok = out[-1]
        nxt, caches = step_fn(params, caches, tok, jnp.int32(S + i))
        if eos_id is not None:
            done = done | (tok == eos_id)
            nxt = jnp.where(done, eos_id, nxt)
        out.append(nxt)
    return jnp.concatenate(out, axis=1)
