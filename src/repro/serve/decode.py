"""Batched serving loop: prefill once, then jit-compiled greedy decode.

The decode step is the same ``serve_step`` the dry-run lowers for the
decode_32k / long_500k cells; this module adds the host-side loop and a
minimal static-batch scheduler (requests padded to the batch; finished
sequences keep decoding into a sink — the standard static-batching serving
baseline, which the dry-run's KV sharding story is built around).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import init_decode_state, prefill
from repro.precision import PrecisionConfig
from repro.models.config import ModelConfig
from repro.train.step import make_serve_step

__all__ = ["generate"]


def generate(
    params,
    cfg: ModelConfig,
    prec: PrecisionConfig,
    prompts: jnp.ndarray,  # (B, S_prompt) int32
    max_new_tokens: int = 32,
    max_len: Optional[int] = None,
    window: Optional[int] = None,
    eos_id: Optional[int] = None,
):
    """Greedy generation. Returns (B, max_new_tokens) int32."""
    B, S = prompts.shape
    max_len = max_len or (S + max_new_tokens)

    logits, caches = prefill(params, cfg, prec, tokens=prompts, max_len=max_len, window=window)
    next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]

    step_fn = jax.jit(make_serve_step(cfg, prec, window=window))
    out = [next_tok]
    done = jnp.zeros((B, 1), bool)
    for i in range(max_new_tokens - 1):
        tok = out[-1]
        nxt, caches = step_fn(params, caches, tok, jnp.int32(S + i))
        if eos_id is not None:
            done = done | (tok == eos_id)
            nxt = jnp.where(done, eos_id, nxt)
        out.append(nxt)
    return jnp.concatenate(out, axis=1)
