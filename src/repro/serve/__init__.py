"""Serving: batched prefill + greedy decode."""

from .decode import generate
