"""Serving: batched prefill + greedy decode."""

from .decode import generate, resolve_policy
