"""Pallas kernel: 1D heat-equation explicit-FD stencil with R2F2 multiplies.

One solver step is ``u' = u + r * (u_left - 2u + u_right)`` (paper §2). The
kernel fuses, per VMEM block: state quantization to the runtime format
(storage is 16-bit in the paper's system), the stencil shifts, and the R2F2
multiplication ``r * lap`` with per-block runtime split selection — one HBM
round-trip per step instead of four.

Layout: many independent rods are batched as rows of a (rows, nx) array —
the row dimension is the natural TPU parallel/shard axis. The x extent stays
whole inside the block (a 16k-point f32 rod is 64 KiB — VMEM-friendly), so
the shifts are in-register slices; Dirichlet boundary values are pinned.

Block: (block_rows, nx); grid over row groups only; (8, 128)-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.blockops import rr_mul_block


def _heat_kernel(u_ref, c_ref, o_ref, *, fmt, steps, tail_approx):
    u = u_ref[...]  # (br, nx) f32 — state stays f32 (paper §5.2: the unit
    # converts from/to single precision around each multiply)
    alpha = c_ref[0, 0]
    dtodx2 = c_ref[0, 1]

    def one_step(_, u):
        # interior laplacian only (boundary columns are Dirichlet-pinned and
        # must not contaminate the per-block range statistics)
        lap = u[:, :-2] - 2.0 * u[:, 1:-1] + u[:, 2:]  # adds in f32
        flux = rr_mul_block(jnp.broadcast_to(alpha, lap.shape), lap, fmt, tail_approx)
        upd = rr_mul_block(flux, jnp.broadcast_to(dtodx2, lap.shape), fmt, tail_approx)
        interior = u[:, 1:-1] + upd
        return jnp.concatenate([u[:, :1], interior, u[:, -1:]], axis=1)

    o_ref[...] = jax.lax.fori_loop(0, steps, one_step, u)


@functools.partial(
    jax.jit, static_argnames=("fmt", "steps", "block_rows", "tail_approx", "interpret")
)
def heat_stencil_pallas(
    u0, alpha, dtodx2, *, fmt, steps=1, block_rows=8, tail_approx=True, interpret=True
):
    """Advance (rows, nx) rod states ``steps`` explicit-FD steps, with the
    update decomposed into the two R2F2 multiplies ``alpha * lap`` and
    ``flux * (dt/dx^2)`` exactly like repro.pde.heat1d."""
    rows, nx = u0.shape
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows {rows} not divisible by block_rows {br}")
    c_arr = jnp.array([[alpha, dtodx2]], jnp.float32)
    return pl.pallas_call(
        functools.partial(_heat_kernel, fmt=fmt, steps=steps, tail_approx=tail_approx),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, nx), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, nx), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, nx), jnp.float32),
        interpret=interpret,
    )(u0.astype(jnp.float32), c_arr)
