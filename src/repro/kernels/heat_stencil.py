"""Fused Pallas kernel: 1D heat-equation explicit-FD sweep with R2F2
multiplies — built on the shared :mod:`repro.kernels.fused` sweep machinery.

One solver step is ``u' = u + r * (u_left - 2u + u_right)`` (paper §2),
decomposed into the two multiplications a scalar pipeline issues (``flux =
alpha * lap`` then ``upd = flux * dtodx2``) — exactly like
``repro.pde.heat1d``. The sweep fuses, per VMEM block: the stencil shifts,
both policy multiplies with per-block runtime split selection, and up to a
whole snapshot interval of substeps — one HBM round trip per chunk instead
of four per step.

Layout: many independent rods are batched as rows of a (rows, nx) array —
the row dimension is the natural TPU parallel/shard axis (non-divisible row
counts are padded and cropped). The x extent stays whole inside the block
(a 16k-point f32 rod is 64 KiB — VMEM-friendly), so the shifts are
in-register slices; Dirichlet boundary values are pinned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.policy import PrecisionConfig
from repro.kernels import fused
from repro.kernels.blockops import rr_mul_block  # noqa: F401 — shared block math

HEAT1D_SITES = ("heat.flux", "heat.update")


def _heat1d_body(alpha, dtodx2, sites):
    """One explicit-FD substep on a (block_rows, nx) block."""
    flux_site, update_site = sites

    def body(state, ops):
        (u,) = state
        # interior laplacian only (boundary columns are Dirichlet-pinned and
        # must not contaminate the per-block range statistics)
        lap = u[:, :-2] - 2.0 * u[:, 1:-1] + u[:, 2:]  # adds in f32
        flux = ops.mul(jnp.float32(alpha), lap, flux_site)
        upd = ops.mul(flux, jnp.float32(dtodx2), update_site)
        interior = u[:, 1:-1] + upd
        return (jnp.concatenate([u[:, :1], interior, u[:, -1:]], axis=1),)

    return body


@functools.partial(
    jax.jit,
    static_argnames=(
        "alpha",
        "dtodx2",
        "prec",
        "steps",
        "block_rows",
        "sites",
        "collect_evidence",
        "capture",
        "interpret",
        "storage",
    ),
)
def heat1d_sweep(
    u0,
    *,
    alpha,
    dtodx2,
    prec,
    steps=1,
    block_rows=8,
    sites=HEAT1D_SITES,
    k_floor=None,
    collect_evidence=False,
    capture=None,
    interpret=None,
    storage="f32",
):
    """Fused-plane entry: advance (rows, nx) rod states ``steps`` substeps.

    Returns ``(u, evidence)`` — the stepper's ``fused_step`` contract —
    plus a trailing ``(n_sites, 2, n_bins)`` exponent-count array when a
    ``capture`` spec is given (range-distribution profiling). With
    ``storage="packed"`` the rod state comes and goes as a
    :class:`repro.pack.PackedArray` (single storage block — so the sweep
    block must cover the field: ``block_rows >= rows``), unpacked in the
    kernel prologue and re-packed in its epilogue.
    """
    res = fused.fused_sweep(
        _heat1d_body(float(alpha), float(dtodx2), sites),
        (u0,),
        prec=prec,
        sites=sites,
        steps=steps,
        block=(block_rows, u0.shape[1]),
        k_floor=k_floor,
        collect_evidence=collect_evidence,
        capture=capture,
        interpret=interpret,
        storage=storage,
    )
    if capture is not None:
        (out,), ev, counts = res
        return out, ev, counts
    (out,), ev = res
    return out, ev


def heat_stencil_pallas(
    u0, alpha, dtodx2, *, fmt, steps=1, block_rows=8, tail_approx=True, interpret=None
):
    """Advance (rows, nx) rod states ``steps`` explicit-FD steps, with the
    update decomposed into the two R2F2 multiplies ``alpha * lap`` and
    ``flux * (dt/dx^2)`` exactly like repro.pde.heat1d. Kept as the
    historical fmt-keyed surface over :func:`heat1d_sweep` (rr_tile
    semantics, no evidence); ``interpret=None`` auto-detects the backend."""
    prec = PrecisionConfig(mode="rr_tile", fmt=fmt, tail_approx=tail_approx)
    out, _ = heat1d_sweep(
        jnp.asarray(u0, jnp.float32),
        alpha=float(alpha),
        dtodx2=float(dtodx2),
        prec=prec,
        steps=steps,
        block_rows=block_rows,
        interpret=interpret,
    )
    return out
