"""Fused whole-step Pallas kernels for the beyond-paper PDE workloads
(heat2d / advection1d / burgers1d), on the shared
:mod:`repro.kernels.fused` sweep machinery.

Each kernel advances the workload a whole multi-substep chunk inside one
``pallas_call`` — the same two-phase shape as ``heat_stencil``: state loads
once into VMEM, every policy multiplication runs on a per-block runtime
split, and the per-site range evidence comes back for the adjust unit. The
bodies are line-for-line the registered steppers' ``step`` methods (same op
order, same f32 adds), which is what makes the fused and reference paths
bit-identical whenever a block covers the whole field.

Layout notes: the 1-D periodic workloads keep the whole rod in-block (the
rolls are in-register); the 2-D heat field rides flattened as one
``(1, nx*ny)`` leaf and is reshaped inside the body — the coupled extent
never crosses a block boundary, so there is no inter-block halo to
exchange.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fused

HEAT2D_SITES = ("heat2d.flux", "heat2d.update")
ADVECTION1D_SITES = ("adv.flux", "adv.update")
BURGERS1D_SITES = ("burgers.uu", "burgers.flux")


# ---------------------------------------------------------------------------
# 2D heat: explicit 5-point stencil, two-multiplier split
# ---------------------------------------------------------------------------


def _heat2d_body(nx, ny, alpha, dtodx2, sites):
    flux_site, update_site = sites

    def body(state, ops):
        (uf,) = state
        u = uf.reshape(nx, ny)
        lap = (  # 5-point interior laplacian, adds in f32
            u[:-2, 1:-1]
            + u[2:, 1:-1]
            + u[1:-1, :-2]
            + u[1:-1, 2:]
            - 4.0 * u[1:-1, 1:-1]
        )
        flux = ops.mul(jnp.float32(alpha), lap, flux_site)
        upd = ops.mul(flux, jnp.float32(dtodx2), update_site)
        u = u.at[1:-1, 1:-1].add(upd)
        return (u.reshape(1, nx * ny),)

    return body


@functools.partial(
    jax.jit,
    static_argnames=(
        "alpha", "dtodx2", "prec", "steps", "sites", "collect_evidence", "capture",
        "interpret", "storage",
    ),
)
def heat2d_sweep(
    u0,
    *,
    alpha,
    dtodx2,
    prec,
    steps=1,
    sites=HEAT2D_SITES,
    k_floor=None,
    collect_evidence=False,
    capture=None,
    interpret=None,
    storage="f32",
):
    """Advance a (nx, ny) field ``steps`` 5-point explicit-FD substeps.

    Returns ``(u, evidence)`` (+ exponent counts when ``capture`` is set).
    ``storage="packed"`` takes and returns the field as a single-block
    :class:`repro.pack.PackedArray`, re-viewed to the kernel's flattened
    ``(1, nx*ny)`` leaf (same split either way — one block).
    """
    packed = storage == "packed"
    nx, ny = u0.shape
    lead = u0.with_view((1, nx * ny)) if packed else u0.reshape(1, nx * ny)
    res = fused.fused_sweep(
        _heat2d_body(nx, ny, float(alpha), float(dtodx2), sites),
        (lead,),
        prec=prec,
        sites=sites,
        steps=steps,
        block=(1, nx * ny),
        k_floor=k_floor,
        collect_evidence=collect_evidence,
        capture=capture,
        interpret=interpret,
        storage=storage,
    )
    if capture is not None:
        (out,), ev, counts = res
        return (out.with_view((nx, ny)) if packed else out.reshape(nx, ny)), ev, counts
    (out,), ev = res
    return (out.with_view((nx, ny)) if packed else out.reshape(nx, ny)), ev


# ---------------------------------------------------------------------------
# 1D advection: flux-form upwind, periodic
# ---------------------------------------------------------------------------


def _advection1d_body(speed, dtodx, sites):
    flux_site, update_site = sites

    def body(state, ops):
        (u,) = state
        f = ops.mul(jnp.float32(speed), u, flux_site)
        df = f - jnp.roll(f, 1, axis=1)  # upwind difference, adds in f32
        upd = ops.mul(jnp.float32(dtodx), df, update_site)
        return (u - upd,)

    return body


@functools.partial(
    jax.jit,
    static_argnames=(
        "speed", "dtodx", "prec", "steps", "sites", "collect_evidence", "capture",
        "interpret", "storage",
    ),
)
def advection1d_sweep(
    u0,
    *,
    speed,
    dtodx,
    prec,
    steps=1,
    sites=ADVECTION1D_SITES,
    k_floor=None,
    collect_evidence=False,
    capture=None,
    interpret=None,
    storage="f32",
):
    """Advance a (nx,) periodic profile ``steps`` upwind substeps.

    Returns ``(u, evidence)`` (+ exponent counts when ``capture`` is set).
    ``storage="packed"`` takes/returns a single-block PackedArray profile.
    """
    packed = storage == "packed"
    n = u0.shape[0]
    lead = u0.with_view((1, n)) if packed else u0[None, :]
    res = fused.fused_sweep(
        _advection1d_body(float(speed), float(dtodx), sites),
        (lead,),
        prec=prec,
        sites=sites,
        steps=steps,
        block=(1, n),
        k_floor=k_floor,
        collect_evidence=collect_evidence,
        capture=capture,
        interpret=interpret,
        storage=storage,
    )
    if capture is not None:
        (out,), ev, counts = res
        return (out.with_view((n,)) if packed else out[0]), ev, counts
    (out,), ev = res
    return (out.with_view((n,)) if packed else out[0]), ev


# ---------------------------------------------------------------------------
# 1D Burgers: conservative Lax-Friedrichs, periodic
# ---------------------------------------------------------------------------


def _burgers1d_body(dt, dx, sites):
    uu_site, flux_site = sites

    def body(state, ops):
        (u,) = state
        uu = ops.mul(u, u, uu_site)  # the nonlinear flux product
        f = ops.mul(jnp.float32(0.5), uu, flux_site)  # f = u^2/2
        u_avg = 0.5 * (jnp.roll(u, -1, axis=1) + jnp.roll(u, 1, axis=1))
        df = jnp.roll(f, -1, axis=1) - jnp.roll(f, 1, axis=1)
        return (u_avg - (dt / (2.0 * dx)) * df,)

    return body


@functools.partial(
    jax.jit,
    static_argnames=(
        "dt", "dx", "prec", "steps", "sites", "collect_evidence", "capture",
        "interpret", "storage",
    ),
)
def burgers1d_sweep(
    u0,
    *,
    dt,
    dx,
    prec,
    steps=1,
    sites=BURGERS1D_SITES,
    k_floor=None,
    collect_evidence=False,
    capture=None,
    interpret=None,
    storage="f32",
):
    """Advance a (nx,) periodic wave ``steps`` Lax-Friedrichs substeps.

    Returns ``(u, evidence)`` (+ exponent counts when ``capture`` is set).
    ``storage="packed"`` takes/returns a single-block PackedArray wave.
    """
    packed = storage == "packed"
    n = u0.shape[0]
    lead = u0.with_view((1, n)) if packed else u0[None, :]
    res = fused.fused_sweep(
        _burgers1d_body(float(dt), float(dx), sites),
        (lead,),
        prec=prec,
        sites=sites,
        steps=steps,
        block=(1, n),
        k_floor=k_floor,
        collect_evidence=collect_evidence,
        capture=capture,
        interpret=interpret,
        storage=storage,
    )
    if capture is not None:
        (out,), ev, counts = res
        return (out.with_view((n,)) if packed else out[0]), ev, counts
    (out,), ev = res
    return (out.with_view((n,)) if packed else out[0]), ev
