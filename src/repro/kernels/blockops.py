"""Shared per-block R2F2 primitives for Pallas kernel bodies.

Every stencil kernel needs the same in-VMEM building block: a shared-split
R2F2 product of two blocks (the paper's same-format rule, §4.1 — one runtime
``k`` per block pair, covering both operands and the product bound). It used
to be copy-pasted verbatim into each kernel module; it lives here once now,
and any new stencil kernel composes it.

Pure ``jnp`` on purpose: inside a ``pallas_call`` the ops trace onto VMEM
block refs; outside they run as plain XLA — which is what the bit-parity
tests rely on. The oracles in :mod:`repro.kernels.ref` deliberately do NOT
import this module: they re-derive the same math independently so a bug
here cannot hide from the kernel-vs-oracle tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.flexformat import quantize_em, unbiased_exponent
from repro.core.r2f2 import product_guard_bits, select_k, select_k_op

__all__ = [
    "block_max_exp",
    "rr_mul_block",
    "rr_add_block",
    "rr_div_block",
    "rr_rsqrt_block",
]


def block_max_exp(t):
    """Max unbiased exponent over one VMEM block (finite values only)."""
    mag = jnp.where(jnp.isfinite(t), jnp.abs(t), 0.0)
    return unbiased_exponent(jnp.maximum(jnp.max(mag), jnp.float32(1e-38)))


def rr_mul_block(a, b, fmt, tail_approx, *, exps=None, k_min=None, k_fixed=None):
    """Shared-split R2F2 product of two blocks (same-format rule, §4.1).

    ``exps`` lets a caller that already reduced the operands (the fused
    plane computes the exponents once for both split selection and tracker
    evidence) pass ``(a_max_exp, b_max_exp)`` instead of re-reducing;
    ``k_min`` floors the selected split at a carried tracker value — the
    adjust unit's persistent k under which a tracked fused chunk runs;
    ``k_fixed`` bypasses selection entirely and multiplies at exactly that
    split (the pinned static-deployment emulation — no live widen). All
    default to the original pre-fused-plane behaviour bit-for-bit.
    """
    if k_fixed is not None:
        k = jnp.asarray(k_fixed, jnp.int32)
    else:
        ae, be = exps if exps is not None else (block_max_exp(a), block_max_exp(b))
        k = select_k(ae, be, fmt)
        if k_min is not None:
            k = jnp.maximum(k, jnp.asarray(k_min, jnp.int32))
    e_b, m_b = fmt.eb + k, fmt.mb + fmt.fx - k
    aq = quantize_em(a, e_b, m_b)
    bq = quantize_em(b, e_b, m_b)
    guard = product_guard_bits(fmt, k) if tail_approx else None
    return quantize_em(aq * bq, e_b, m_b, tail_trunc_bits=guard)


def _rr_alu_block(a, b, fmt, op, substrate, *, exps=None, k_min=None, k_fixed=None):
    """Shared-split flexible ALU op on blocks — ``rr_mul_block``'s shape for
    the repro.alu ops, with the split picked under the op's own exponent
    envelope (:func:`repro.core.r2f2.select_k_op`). No tail truncation: the
    flexible-region approximation models dropped partial *products* and has
    no analogue in adder/divider datapaths (see ``repro.alu.flexops``)."""
    if k_fixed is not None:
        k = jnp.asarray(k_fixed, jnp.int32)
    else:
        ae, be = exps if exps is not None else (block_max_exp(a), block_max_exp(b))
        k = select_k_op(ae, be, fmt, op)
        if k_min is not None:
            k = jnp.maximum(k, jnp.asarray(k_min, jnp.int32))
    e_b, m_b = fmt.eb + k, fmt.mb + fmt.fx - k
    aq = quantize_em(a, e_b, m_b)
    bq = quantize_em(b, e_b, m_b)
    return quantize_em(substrate(aq, bq), e_b, m_b)


def rr_add_block(a, b, fmt, *, exps=None, k_min=None, k_fixed=None):
    """Shared-split flexible sum (alignment-shift envelope)."""
    return _rr_alu_block(a, b, fmt, "add", lambda x, y: x + y, exps=exps, k_min=k_min, k_fixed=k_fixed)


def rr_div_block(a, b, fmt, *, exps=None, k_min=None, k_fixed=None):
    """Shared-split flexible quotient (quotient-range envelope)."""
    return _rr_alu_block(a, b, fmt, "div", lambda x, y: x / y, exps=exps, k_min=k_min, k_fixed=k_fixed)


def rr_rsqrt_block(x, fmt, *, exps=None, k_min=None, k_fixed=None):
    """Shared-split flexible reciprocal square root (unary envelope);
    ``exps`` is the operand exponent doubled up, ``(ex, ex)``."""
    if exps is None:
        ex = block_max_exp(x)
        exps = (ex, ex)
    return _rr_alu_block(
        x, x, fmt, "rsqrt", lambda v, _w: jax.lax.rsqrt(v), exps=exps, k_min=k_min, k_fixed=k_fixed
    )
