"""Whole-horizon megakernel: the fused plane without the chunking
(DESIGN.md §14).

:func:`repro.kernels.fused.fused_sweep` runs one snapshot interval per
``pallas_call`` and hands the per-substep range evidence back to the host,
where ``fold_evidence`` replays it through the adjust unit between chunks —
a kernel launch plus an HBM round trip per interval. :func:`mega_sweep`
removes both: the ENTIRE horizon (``steps`` substeps, snapshots included)
runs in ONE ``pallas_call``, and the adjust unit itself moves on-chip. The
carried tracker state (per-site k, hi/lo EMAs, §5.3 counters) lives in
registers/SMEM and evolves every substep through the jax-pure scalar law
:func:`repro.core.policy.adjust_step` — the paper's hardware unit sitting
next to the multiplier, not a host callback. Snapshots, per-substep
evidence, and capture histograms stream out as secondary outputs written at
their cadence (``pl.when`` + dynamic-slice stores at snapshot boundaries),
so the state never round-trips HBM mid-horizon.

Semantics contract with the chunked plane (what the parity suite pins):

* Untracked modes (f32 / bf16 / fixed / rr_tile) and ``deploy`` are
  **bit-exact** against chunked-fused: same :class:`FusedOps` arithmetic,
  same whole-field blocks, same boundary storage rounding.
* ``rr_tracked``: the tracker evolves per substep on-chip, but the
  *datapath* floor latches at snapshot boundaries — exactly the cadence at
  which the chunked plane folds evidence and re-enters the kernel with the
  updated k. The arithmetic is therefore bit-identical, and the final
  per-site k and §5.3 grow/shrink counters match the chunked fold exactly.
* Storage: ``"quantized"``/``"packed"`` round the state at every snapshot
  boundary in-kernel with the shared :func:`repro.pack.packed` block
  helpers — one (virtual) pack per boundary, same splits, same bits as the
  chunked boundary pack. Packed-io steppers encode/decode payloads in the
  kernel prologue/epilogue so packed state never materialises f32 in HBM;
  other steppers get the carried storage split streamed out (``kst``) so
  the host-side final pack reuses the in-kernel split instead of re-picking
  one from already-quantized values (which could disagree at power-of-two
  rounding edges).

Eligibility: whole-field-in-VMEM workloads only — the megakernel keeps one
block per leaf, so a stepper whose chunked kernels tile the field (and thus
pick per-tile splits) must gate itself out via ``mega_supported``.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import repro.obs as _obs

from repro.core.flexformat import quantize_em
from repro.core.policy import RangeTracker, adjust_step
from repro.kernels.fused import FusedOps, resolve_interpret
from repro.pack.packed import (
    PackedArray,
    _view2d,
    block_storage_k,
    pack_block,
    payload_dtype,
    unpack_block,
)

__all__ = [
    "MegaResult",
    "mega_sweep",
    "heat1d_mega",
    "heat2d_mega",
    "advection1d_mega",
    "burgers1d_mega",
    "swe2d_mega",
]


class MegaResult(NamedTuple):
    """Everything one whole-horizon kernel call produces."""

    state: Tuple  # advanced leaves (PackedArray leaves under storage="packed")
    snaps: Tuple  # per-leaf (n_out, *leaf.shape) f32 boundary snapshots
    tracker: Optional[RangeTracker]  # on-chip-evolved adjust-unit state
    evidence: Optional[jnp.ndarray]  # (steps, n_sites, 2) f32, when requested
    exp_time: Optional[jnp.ndarray]  # (n_out, n_sites, 2, n_bins) i32, capture
    exp_total: Optional[jnp.ndarray]  # (n_sites, 2, n_bins) i32, capture


def _mega_kernel(
    *refs,
    body,
    prec,
    sites,
    site_ops,
    steps,
    every,
    n_out,
    n_state,
    evolve,
    has_floor,
    emit_ev,
    capture,
    storage,
    packed_io,
):
    fmt = prec.fmt
    n_sites = len(sites)
    rounding = storage != "f32"

    # ---- input refs ------------------------------------------------------
    pos = 0
    if packed_io:
        pay_refs = refs[pos : pos + n_state]
        ks_refs = refs[pos + n_state : pos + 2 * n_state]
        pos += 2 * n_state
        state = tuple(
            unpack_block(pr[...], fmt, kr[...][0, 0])
            for pr, kr in zip(pay_refs, ks_refs)
        )
    else:
        state = tuple(r[...] for r in refs[pos : pos + n_state])
        pos += n_state
    trk0 = ()
    k_active = None
    if evolve:
        # the adjust unit's carried state — scalar rows living in registers
        k0, hi0, lo0, ov0, sh0 = (refs[pos + i][...][0] for i in range(5))
        pos += 5
        trk0 = (k0.astype(jnp.int32), hi0, lo0, ov0.astype(jnp.int32), sh0.astype(jnp.int32))
        k_active = trk0[0]  # datapath floor, latched at snapshot boundaries
    elif has_floor:
        k_active = refs[pos][...][0]  # pinned: static profiled splits
        pos += 1

    # ---- output refs -----------------------------------------------------
    out_refs = refs[pos : pos + n_state]
    pos += n_state
    kout_refs = kst_ref = None
    if packed_io:
        kout_refs = refs[pos : pos + n_state]
        pos += n_state
    elif storage == "packed":
        kst_ref = refs[pos]
        pos += 1
    snap_refs = ()
    if n_out > 0:
        snap_refs = refs[pos : pos + n_state]
        pos += n_state
    trk_out = ()
    if evolve:
        trk_out = refs[pos : pos + 5]
        pos += 5
    ev_ref = cnt_ref = time_ref = None
    if emit_ev:
        ev_ref = refs[pos]
        pos += 1
    if capture is not None:
        cnt_ref = refs[pos]
        pos += 1
        if n_out > 0:
            time_ref = refs[pos]

    collect = evolve or emit_ev

    def _round_all(st):
        """Boundary storage rounding: the chunked plane's pack/unpack on the
        raw values, via the shared block helpers (same splits, same bits)."""
        qs, ks = [], []
        for v in st:
            kb = block_storage_k(v, fmt)
            qs.append(quantize_em(v, fmt.eb + kb, fmt.mb + fmt.fx - kb))
            ks.append(kb)
        return tuple(qs), jnp.stack(ks).astype(jnp.int32)

    ev0 = jnp.zeros((steps, n_sites, 2) if emit_ev else (1,), jnp.float32)
    cnt0 = jnp.zeros(
        (n_sites, 2, capture.n_bins) if capture is not None else (1,), jnp.int32
    )
    kst0 = jnp.zeros((n_state,), jnp.int32)
    ka0 = k_active if evolve else jnp.zeros((1,), jnp.int32)

    def substep(s, carry):
        st, trk, ka, ev, cnt, cnt_last, kst = carry
        floor = (ka if evolve else k_active) if (evolve or has_floor) else None
        ops = FusedOps(
            prec, sites, k_floor=floor, collect=collect, capture=capture,
            site_ops=site_ops,
        )
        new = body(st, ops)
        if not isinstance(new, tuple):
            new = (new,)
        if len(new) != n_state:
            raise ValueError(
                f"mega body returned {len(new)} leaves for {n_state} state "
                "leaves: the output is the next substep's input"
            )
        if collect:
            missing = [n for n in sites if n not in ops.evidence]
            if missing:
                raise ValueError(f"mega body never hit sites {missing}")
        if evolve:
            # the on-chip adjust unit: one scalar tick per site, this substep
            k_a, hi_a, lo_a, ov_a, sh_a = trk
            rows = []
            for j, name in enumerate(sites):
                ae, be = ops.evidence[name]
                op = "mul" if site_ops is None else site_ops[j]
                kb = None if prec.k_bounds is None else prec.k_bounds[j]
                rows.append(
                    adjust_step(
                        k_a[j], hi_a[j], lo_a[j], ov_a[j], sh_a[j],
                        ae, be, prec, op, k_bounds=kb,
                    )
                )
            trk = tuple(jnp.stack(col) for col in zip(*rows))
        if emit_ev:
            for j, name in enumerate(sites):
                ae, be = ops.evidence[name]
                ev = ev.at[s, j, 0].set(ae)
                ev = ev.at[s, j, 1].set(be)
        if capture is not None:
            cnt = cnt + jnp.stack([ops.counts[name] for name in sites])

        boundary = ((s + 1) % every) == 0
        if rounding:
            qs, ks = _round_all(new)
            new = tuple(jnp.where(boundary, q, v) for q, v in zip(qs, new))
            kst = jnp.where(boundary, ks, kst)
        if evolve:
            # latch the datapath floor at the chunk cadence — the substeps
            # between boundaries run at the same splits the chunked plane's
            # between-chunk fold would hand the next kernel call
            ka = jnp.where(boundary, trk[0], ka)
        if n_out > 0:
            idx = (s + 1) // every - 1

            @pl.when(boundary)
            def _store():
                for r, v in zip(snap_refs, new):
                    r[pl.ds(idx, 1)] = v[None].astype(jnp.float32)
                if time_ref is not None:
                    time_ref[pl.ds(idx, 1)] = (cnt - cnt_last)[None]

            if capture is not None:
                cnt_last = jnp.where(boundary, cnt, cnt_last)
        return new, trk, ka, ev, cnt, cnt_last, kst

    carry = (state, trk0, ka0, ev0, cnt0, cnt0, kst0)
    state, trk, _ka, ev, cnt, _cl, kst = jax.lax.fori_loop(0, steps, substep, carry)

    rem = steps - n_out * every
    if rem and rounding:
        # the remainder epilogue: same boundary law as the in-loop cadence
        state, kst = _round_all(state)

    if packed_io:
        for i, (pr, kr) in enumerate(zip(out_refs, kout_refs)):
            # idempotent re-encode: the state is already quantized at kst, so
            # packing at the SAME carried split reproduces the chunked
            # plane's pack-from-raw bits exactly
            pr[...] = pack_block(state[i], fmt, kst[i]).astype(payload_dtype(fmt))
            kr[...] = jnp.reshape(kst[i], (1, 1)).astype(jnp.int32)
    else:
        for r, v in zip(out_refs, state):
            r[...] = v
        if kst_ref is not None:
            kst_ref[...] = kst[None]
    if evolve:
        for r, v in zip(trk_out, trk):
            r[...] = v[None]
    if emit_ev:
        ev_ref[...] = ev
    if capture is not None:
        cnt_ref[...] = cnt


def mega_sweep(
    body: Callable,
    state: Sequence,
    *,
    prec,
    sites: Tuple[str, ...],
    site_ops: Optional[Tuple[str, ...]] = None,
    steps: int,
    every: int,
    tracker: Optional[RangeTracker] = None,
    collect_evidence: bool = False,
    capture=None,
    interpret: Optional[bool] = None,
    storage: str = "f32",
) -> MegaResult:
    """Run an ENTIRE simulation horizon — ``steps`` substeps with snapshots
    every ``every`` — in one ``pallas_call``.

    Arguments mirror :func:`repro.kernels.fused.fused_sweep` where shared:

      body: ``body(state_leaves, ops) -> out_leaves`` over whole-field
        values (any rank — the megakernel keeps one block per leaf).
      state: the leaves. :class:`repro.pack.PackedArray` leaves (requires
        ``storage="packed"``) ride packed io: decoded in the kernel
        prologue, re-encoded in its epilogue, never f32 in HBM. Plain f32
        leaves under ``storage="packed"`` run the host-pack path: the
        kernel quantizes at boundaries and streams out the carried storage
        split ``kst``; the final pack happens here at that split.
      tracker: a :class:`repro.core.policy.RangeTracker` (site order =
        ``sites``). Non-pinned policies evolve it ON-CHIP per substep via
        :func:`repro.core.policy.adjust_step`; pinned policies use its k
        rows as the static datapath splits. None: untracked.
      every: snapshot cadence; ``steps // every`` boundary snapshots (and
        boundary storage roundings) happen inside the kernel.

    Returns a :class:`MegaResult`. ``evidence`` is populated when
    ``collect_evidence`` or ``capture`` asks for it (the tracker fold no
    longer needs it — that happens on-chip); ``exp_time``/``exp_total`` are
    the capture profile's interval/total histograms.
    """
    interpret = resolve_interpret(interpret)
    if storage not in ("f32", "quantized", "packed"):
        raise ValueError(f"unknown mega storage {storage!r}")
    n_sites = len(sites)
    if site_ops is not None:
        site_ops = tuple(site_ops)
        if len(site_ops) != n_sites:
            raise ValueError(
                f"site_ops covers {len(site_ops)} entries for {n_sites} sites"
            )
    emit_ev = bool(collect_evidence) or capture is not None
    evolve = tracker is not None and not prec.pinned
    has_floor = tracker is not None and prec.pinned
    n_out = steps // every

    packed_io = any(isinstance(x, PackedArray) for x in state)
    if packed_io:
        if storage != "packed":
            raise ValueError("PackedArray leaves require storage='packed'")
        pas = list(state)
        for pa in pas:
            if not isinstance(pa, PackedArray):
                raise TypeError("mixed packed/f32 state leaves")
            if pa.fmt != prec.fmt:
                raise ValueError(
                    f"packed leaf format {pa.fmt} disagrees with the policy "
                    f"format {prec.fmt}"
                )
            if tuple(pa.k.shape[-2:]) != (1, 1):
                raise ValueError(
                    "megakernel packed io takes single-block PackedArrays; "
                    f"got k of shape {tuple(pa.k.shape)}"
                )
        leaves = [pa.payload for pa in pas]
    else:
        leaves = [jnp.asarray(x, jnp.float32) for x in state]
    n_state = len(leaves)
    shapes = [tuple(x.shape) for x in leaves]

    inputs = list(leaves)
    if packed_io:
        inputs += [jnp.reshape(pa.k, (1, 1)).astype(jnp.int32) for pa in pas]
    if evolve:
        inputs += [
            jnp.asarray(tracker.k, jnp.int32).reshape(1, n_sites),
            jnp.asarray(tracker.hi_ema, jnp.float32).reshape(1, n_sites),
            jnp.asarray(tracker.lo_ema, jnp.float32).reshape(1, n_sites),
            jnp.asarray(tracker.overflow_steps, jnp.int32).reshape(1, n_sites),
            jnp.asarray(tracker.shrink_steps, jnp.int32).reshape(1, n_sites),
        ]
    elif has_floor:
        inputs.append(jnp.asarray(tracker.k, jnp.int32).reshape(1, n_sites))

    out_shape = []
    if packed_io:
        pdt = payload_dtype(prec.fmt)
        out_shape += [jax.ShapeDtypeStruct(s, pdt) for s in shapes]
        out_shape += [jax.ShapeDtypeStruct((1, 1), jnp.int32)] * n_state
    else:
        out_shape += [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
        if storage == "packed":
            out_shape.append(jax.ShapeDtypeStruct((1, n_state), jnp.int32))
    if n_out > 0:
        out_shape += [jax.ShapeDtypeStruct((n_out,) + s, jnp.float32) for s in shapes]
    if evolve:
        out_shape += [
            jax.ShapeDtypeStruct((1, n_sites), jnp.int32),
            jax.ShapeDtypeStruct((1, n_sites), jnp.float32),
            jax.ShapeDtypeStruct((1, n_sites), jnp.float32),
            jax.ShapeDtypeStruct((1, n_sites), jnp.int32),
            jax.ShapeDtypeStruct((1, n_sites), jnp.int32),
        ]
    if emit_ev:
        out_shape.append(jax.ShapeDtypeStruct((steps, n_sites, 2), jnp.float32))
    if capture is not None:
        nb = capture.n_bins
        out_shape.append(jax.ShapeDtypeStruct((n_sites, 2, nb), jnp.int32))
        if n_out > 0:
            out_shape.append(jax.ShapeDtypeStruct((n_out, n_sites, 2, nb), jnp.int32))

    call = (
        pl.pallas_call(
            functools.partial(
                _mega_kernel,
                body=body,
                prec=prec,
                sites=tuple(sites),
                site_ops=site_ops,
                steps=steps,
                every=every,
                n_out=n_out,
                n_state=n_state,
                evolve=evolve,
                has_floor=has_floor,
                emit_ev=emit_ev,
                capture=capture,
                storage=storage,
                packed_io=packed_io,
            ),
            out_shape=tuple(out_shape),
            interpret=interpret,
        )
    )
    with _obs.span("pallas.mega_sweep", steps=steps, every=every):
        _obs.inc(
            "repro_pallas_dispatch_total",
            help="pallas_call dispatch sites entered",
            kernel="mega_sweep",
        )
        outs = list(call(*inputs))

    # ---- unpack the flat output list -------------------------------------
    time_cnt = outs.pop() if (capture is not None and n_out > 0) else None
    total_cnt = outs.pop() if capture is not None else None
    evidence = outs.pop() if emit_ev else None
    tracker_out = tracker
    if evolve:
        sh = outs.pop()[0]
        ov = outs.pop()[0]
        lo = outs.pop()[0]
        hi = outs.pop()[0]
        k = outs.pop()[0]
        tracker_out = RangeTracker(
            hi_ema=hi, lo_ema=lo, k=k, overflow_steps=ov, shrink_steps=sh
        )
    snaps = tuple(
        jnp.zeros((0,) + s, jnp.float32) for s in shapes
    )
    if n_out > 0:
        snaps = tuple(outs[-n_state:])
        del outs[-n_state:]
    if packed_io:
        kouts = outs[n_state : 2 * n_state]
        final = tuple(
            PackedArray(p, jnp.reshape(kk, pa.k.shape), pa.fmt, pa.shape, pa.block)
            for p, kk, pa in zip(outs[:n_state], kouts, pas)
        )
    elif storage == "packed":
        kst = outs[n_state][0]
        final = []
        for i, q in enumerate(outs[:n_state]):
            view = _view2d(shapes[i])
            payload = pack_block(q.reshape(view), prec.fmt, kst[i])
            final.append(
                PackedArray(
                    payload.astype(payload_dtype(prec.fmt)),
                    jnp.reshape(kst[i], (1, 1)),
                    prec.fmt,
                    shapes[i],
                    view,
                )
            )
        final = tuple(final)
    else:
        final = tuple(outs[:n_state])

    exp_time = exp_total = None
    if capture is not None:
        exp_total = total_cnt
        exp_time = (
            time_cnt
            if time_cnt is not None
            else jnp.zeros((0, n_sites, 2, capture.n_bins), jnp.int32)
        )
    return MegaResult(final, snaps, tracker_out, evidence, exp_time, exp_total)


# ---------------------------------------------------------------------------
# per-stepper whole-horizon entries (the steppers' mega_step hooks)
# ---------------------------------------------------------------------------

_MEGA_STATICS = (
    "prec", "steps", "every", "sites", "collect_evidence", "capture",
    "interpret", "storage",
)


def _single_leaf(res: MegaResult, unwrap, snap_shape) -> MegaResult:
    """Re-view a single-leaf MegaResult into the stepper's natural shapes."""
    (out,) = res.state
    (snaps,) = res.snaps
    return res._replace(
        state=unwrap(out), snaps=snaps.reshape((snaps.shape[0],) + snap_shape)
    )


@functools.partial(jax.jit, static_argnames=_MEGA_STATICS + ("alpha", "dtodx2"))
def heat1d_mega(
    u0, *, alpha, dtodx2, prec, steps, every, sites, tracker=None,
    collect_evidence=False, capture=None, interpret=None, storage="f32",
):
    """Whole-horizon 1-D heat sweep; ``u0`` is the (nx,) rod (PackedArray
    under packed storage)."""
    from repro.kernels.heat_stencil import _heat1d_body

    packed = isinstance(u0, PackedArray)
    nx = u0.shape[-1]
    lead = u0.with_view((1, nx)) if packed else jnp.asarray(u0, jnp.float32)[None, :]
    res = mega_sweep(
        _heat1d_body(float(alpha), float(dtodx2), sites),
        (lead,),
        prec=prec, sites=sites, steps=steps, every=every, tracker=tracker,
        collect_evidence=collect_evidence, capture=capture, interpret=interpret,
        storage=storage,
    )
    unwrap = (lambda o: o.with_view((nx,))) if packed else (lambda o: o[0])
    return _single_leaf(res, unwrap, (nx,))


@functools.partial(jax.jit, static_argnames=_MEGA_STATICS + ("alpha", "dtodx2"))
def heat2d_mega(
    u0, *, alpha, dtodx2, prec, steps, every, sites, tracker=None,
    collect_evidence=False, capture=None, interpret=None, storage="f32",
):
    """Whole-horizon 2-D heat sweep; ``u0`` is the (nx, ny) field."""
    from repro.kernels.pde_steps import _heat2d_body

    packed = isinstance(u0, PackedArray)
    nx, ny = u0.shape
    lead = u0.with_view((1, nx * ny)) if packed else u0.reshape(1, nx * ny)
    res = mega_sweep(
        _heat2d_body(nx, ny, float(alpha), float(dtodx2), sites),
        (lead,),
        prec=prec, sites=sites, steps=steps, every=every, tracker=tracker,
        collect_evidence=collect_evidence, capture=capture, interpret=interpret,
        storage=storage,
    )
    unwrap = (lambda o: o.with_view((nx, ny))) if packed else (lambda o: o.reshape(nx, ny))
    return _single_leaf(res, unwrap, (nx, ny))


@functools.partial(jax.jit, static_argnames=_MEGA_STATICS + ("speed", "dtodx"))
def advection1d_mega(
    u0, *, speed, dtodx, prec, steps, every, sites, tracker=None,
    collect_evidence=False, capture=None, interpret=None, storage="f32",
):
    """Whole-horizon upwind advection sweep; ``u0`` is the (nx,) profile."""
    from repro.kernels.pde_steps import _advection1d_body

    packed = isinstance(u0, PackedArray)
    n = u0.shape[-1]
    lead = u0.with_view((1, n)) if packed else jnp.asarray(u0, jnp.float32)[None, :]
    res = mega_sweep(
        _advection1d_body(float(speed), float(dtodx), sites),
        (lead,),
        prec=prec, sites=sites, steps=steps, every=every, tracker=tracker,
        collect_evidence=collect_evidence, capture=capture, interpret=interpret,
        storage=storage,
    )
    unwrap = (lambda o: o.with_view((n,))) if packed else (lambda o: o[0])
    return _single_leaf(res, unwrap, (n,))


@functools.partial(jax.jit, static_argnames=_MEGA_STATICS + ("dt", "dx"))
def burgers1d_mega(
    u0, *, dt, dx, prec, steps, every, sites, tracker=None,
    collect_evidence=False, capture=None, interpret=None, storage="f32",
):
    """Whole-horizon Lax-Friedrichs Burgers sweep; ``u0`` is the (nx,) wave."""
    from repro.kernels.pde_steps import _burgers1d_body

    packed = isinstance(u0, PackedArray)
    n = u0.shape[-1]
    lead = u0.with_view((1, n)) if packed else jnp.asarray(u0, jnp.float32)[None, :]
    res = mega_sweep(
        _burgers1d_body(float(dt), float(dx), sites),
        (lead,),
        prec=prec, sites=sites, steps=steps, every=every, tracker=tracker,
        collect_evidence=collect_evidence, capture=capture, interpret=interpret,
        storage=storage,
    )
    unwrap = (lambda o: o.with_view((n,))) if packed else (lambda o: o[0])
    return _single_leaf(res, unwrap, (n,))


def _swe2d_body(cfg, sites):
    """One whole Richtmyer Lax-Wendroff update in-kernel: the substituted
    momentum-flux equation routes through the megakernel's :class:`FusedOps`
    (same sites, same op order as the chunked ``swe_flux_fused`` kernel);
    every other sub-equation stays f32 jnp, exactly as outside."""
    from repro.pde.swe2d import _lw_step, _momentum_flux

    def body(state, ops):
        (U,) = state
        U = _lw_step(U, cfg, lambda q1, q3: _momentum_flux(q1, q3, ops))
        return (U,)

    return body


@functools.partial(jax.jit, static_argnames=_MEGA_STATICS + ("cfg", "site_ops"))
def swe2d_mega(
    U0, *, cfg, prec, steps, every, sites, site_ops, tracker=None,
    collect_evidence=False, capture=None, interpret=None, storage="f32",
):
    """Whole-horizon shallow-water run; ``U0`` is the stacked (3, nx, ny)
    state. Packed storage takes the XLA-boundary shape the chunked plane
    uses (SWE has no packed-io kernel): a packed carry is decoded here, the
    kernel rounds at boundaries and streams the storage split out, and
    :func:`mega_sweep` re-packs the final state at that split."""
    from repro.pack.packed import unpack_array

    packed = isinstance(U0, PackedArray)
    lead = unpack_array(U0) if packed else jnp.asarray(U0, jnp.float32)
    res = mega_sweep(
        _swe2d_body(cfg, sites),
        (lead,),
        prec=prec, sites=sites, site_ops=site_ops, steps=steps, every=every,
        tracker=tracker, collect_evidence=collect_evidence, capture=capture,
        interpret=interpret, storage=storage,
    )
    (out,) = res.state
    (snaps,) = res.snaps
    return res._replace(state=out, snaps=snaps)
