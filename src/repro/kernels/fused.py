"""Shared Pallas stencil-sweep builder — the fused execution plane's engine
room (DESIGN.md §10).

Every fused whole-step kernel in this package is the same machine with a
different body: load state blocks into VMEM, run ``steps`` solver substeps
in one in-kernel ``fori_loop`` (one HBM round trip per *chunk* instead of
per arithmetic op), route every policy multiplication through a per-block
runtime-k R2F2 split (:mod:`repro.kernels.blockops`), and emit — next to
the advanced state — the per-site max-exponent evidence the precision
adjust unit consumes between chunks. :func:`fused_sweep` owns that machine
once: grid/BlockSpec plumbing, row padding-and-cropping for non-divisible
shapes, the substep loop, the evidence output, and the carried-k floor
input for tracked modes.

A kernel body is a plain function over VMEM blocks::

    def body(state, ops):              # state: tuple of (br, bw) f32 blocks
        (u,) = state
        lap = u[:, :-2] - 2.0 * u[:, 1:-1] + u[:, 2:]
        flux = ops.mul(alpha, lap, "heat.flux")       # policy multiplier
        ...
        return (u_next,)

``ops`` is a :class:`FusedOps` — the in-kernel mirror of
``repro.pde.solver.StepOps``: ``mul(a, b, site)`` applies the policy's
arithmetic family (``rr`` per-block shared split / ``bf16`` / ``fixed`` /
``f32``, see :data:`repro.precision.fusion.FUSED_FAMILIES`) and records the
operands' block max exponents as tracker evidence. Stepper code therefore
reads identically inside and outside the kernel, which is what keeps the
fused and reference paths in bit-parity wherever a block covers the whole
field.

Blocking contract: state leaves are 2-D ``(rows, width)``. The row axis is
*independent* (batched rods, ensemble members, or a singleton) and may be
blocked and padded freely; the width axis carries the stencil coupling for
sweep kernels and must then stay whole in the block (``block[1] == width``)
— halos never cross blocks by construction. Purely elementwise bodies
(e.g. the SWE momentum flux) may tile both axes.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import repro.obs as _obs
from repro.core.flexformat import quantize_em
from repro.kernels.blockops import (
    block_max_exp,
    rr_add_block,
    rr_div_block,
    rr_mul_block,
    rr_rsqrt_block,
)
from repro.pack.packed import (
    PackedArray,
    block_storage_k,
    pack_block,
    payload_dtype,
    unpack_block,
)
from repro.precision.fusion import fused_family
from repro.profile.capture import pair_exp_hist

__all__ = ["on_tpu", "resolve_interpret", "FusedOps", "fused_sweep"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` -> interpret off TPU, compile to Mosaic on TPU — every
    kernel entry point routes through this, so no call site hard-codes
    interpreter mode."""
    return (not on_tpu()) if interpret is None else bool(interpret)


class FusedOps:
    """Per-substep policy arithmetic inside a fused kernel body.

    Mirrors ``repro.pde.solver.StepOps``: stepper bodies write
    ``ops.mul(a, b, "site")`` and this object supplies the family
    arithmetic, the per-block runtime split (floored at the carried tracker
    k for tracked modes), and the evidence capture. One instance lives per
    substep; the builder harvests ``.evidence`` after the body returns.
    """

    __slots__ = (
        "prec", "sites", "site_ops", "family", "k_floor", "collect", "capture",
        "valid", "evidence", "counts",
    )

    def __init__(
        self, prec, sites: Tuple[str, ...], k_floor=None, collect=False,
        capture=None, valid=None, site_ops=None,
    ):
        self.prec = prec
        self.sites = tuple(sites)
        #: per-site declared op ("mul"/"add"/"div"/"rsqrt") — when given, a
        #: body calling the wrong method at a site fails at trace time
        self.site_ops = None if site_ops is None else tuple(site_ops)
        self.family = fused_family(prec.mode)
        if self.family is None:
            raise ValueError(
                f"mode {prec.mode!r} has no fused arithmetic family; "
                "run it on the reference execution path"
            )
        self.k_floor = k_floor  # (n_sites,) int32 carried splits, or None
        self.collect = collect
        self.capture = capture  # CaptureSpec: widen evidence to binned counts
        #: (row_ok (br,1)|None, col_ok (1,bw)|None, br, bw) — this block's
        #: valid-lane masks when the grid is padded; capture counts only
        #: valid lanes, so pad constants can never contaminate a profile
        self.valid = valid
        self.evidence = {}  # site -> (a_max_exp, b_max_exp) f32 scalars
        self.counts = {}  # site -> (2, n_bins) int32 operand exponent counts

    def _valid_mask(self, shape):
        """Valid-lane mask broadcast to an operand's shape (None: all valid).

        Row padding needs the operand to keep the block's row extent (sweep
        bodies slice only along width); column padding needs the full block
        width (elementwise bodies). Anything else cannot be attributed to
        lanes and is refused at trace time.
        """
        if self.valid is None:
            return None
        row_ok, col_ok, br, bw = self.valid
        m = None
        if row_ok is not None:
            if len(shape) != 2 or shape[0] != br:
                raise ValueError(
                    f"capture on a row-padded grid needs body operands to keep "
                    f"the block row extent {br}; got shape {shape}"
                )
            m = jnp.broadcast_to(row_ok, shape)
        if col_ok is not None:
            if len(shape) != 2 or shape[1] != bw:
                raise ValueError(
                    f"capture on a width-padded grid needs body operands to "
                    f"keep the block width {bw}; got shape {shape}"
                )
            c = jnp.broadcast_to(col_ok, shape)
            m = c if m is None else (m & c)
        return m

    def _record(self, a, b, site: str, op: str):
        """Broadcast the operands, check the site's declared op, and record
        evidence/counts. Returns ``(a, b, exps)`` with ``exps`` the block max
        exponents (None when neither the rr family nor collection needs them).
        """
        a = jnp.asarray(a, jnp.float32)
        b = jnp.asarray(b, jnp.float32)
        shape = jnp.broadcast_shapes(a.shape, b.shape)
        a = jnp.broadcast_to(a, shape)
        b = jnp.broadcast_to(b, shape)
        if self.site_ops is not None:
            declared = self.site_ops[self.sites.index(site)]
            if declared != op:
                raise ValueError(
                    f"site {site!r} is declared as a {declared!r} op but the "
                    f"fused body called ops.{op} there"
                )

        exps = None
        if self.collect or self.family == "rr":
            exps = (block_max_exp(a), block_max_exp(b))
        if self.collect:
            if site in self.evidence:
                raise ValueError(f"fused body hit site {site!r} twice in one substep")
            self.evidence[site] = tuple(e.astype(jnp.float32) for e in exps)
        if self.capture is not None:
            self.counts[site] = pair_exp_hist(a, b, self.capture, self._valid_mask(shape))
        return a, b, exps

    def _k_floor_at(self, site: str):
        if self.k_floor is None:
            return None
        return self.k_floor[self.sites.index(site)]

    def mul(self, a, b, site: str):
        """Product of two blocks on the policy's multiplier at a named site."""
        a, b, exps = self._record(a, b, site, "mul")
        if self.family == "f32":
            return a * b
        if self.family == "bf16":
            return (a.astype(jnp.bfloat16) * b.astype(jnp.bfloat16)).astype(jnp.float32)
        if self.family == "fixed":
            e, m = self.prec.fixed_em
            return quantize_em(quantize_em(a, e, m) * quantize_em(b, e, m), e, m)
        # "rr": per-block shared split (same-format rule), grown on demand by
        # construction and floored at the carried adjust-unit split. Under
        # cfg.pinned the carried split IS the split (static profiled
        # deployment — no live widen), mirroring the reference plane.
        k_min = self._k_floor_at(site)
        if self.prec.pinned and k_min is not None:
            return rr_mul_block(
                a, b, self.prec.fmt, self.prec.tail_approx, exps=exps, k_fixed=k_min
            )
        return rr_mul_block(a, b, self.prec.fmt, self.prec.tail_approx, exps=exps, k_min=k_min)

    def _alu(self, a, b, site: str, op: str, substrate, rr_block):
        """Shared family dispatch for the repro.alu ops (add/div/rsqrt):
        same structure as :meth:`mul`, with the rr family routed through the
        op's own blockops primitive (per-op exponent envelope, no tail
        truncation — adder/divider datapaths drop no partial products)."""
        a, b, exps = self._record(a, b, site, op)
        if self.family == "f32":
            return substrate(a, b)
        if self.family == "bf16":
            return substrate(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16)).astype(
                jnp.float32
            )
        if self.family == "fixed":
            e, m = self.prec.fixed_em
            return quantize_em(substrate(quantize_em(a, e, m), quantize_em(b, e, m)), e, m)
        k_min = self._k_floor_at(site)
        if self.prec.pinned and k_min is not None:
            return rr_block(a, b, self.prec.fmt, exps=exps, k_fixed=k_min)
        return rr_block(a, b, self.prec.fmt, exps=exps, k_min=k_min)

    def add(self, a, b, site: str):
        """Sum of two blocks on the policy's flexible adder at a named site
        (alignment-shift evidence law)."""
        return self._alu(a, b, site, "add", lambda x, y: x + y, rr_add_block)

    def div(self, a, b, site: str):
        """Quotient of two blocks on the policy's flexible divider at a
        named site (quotient-range evidence law)."""
        return self._alu(a, b, site, "div", lambda x, y: x / y, rr_div_block)

    def rsqrt(self, x, site: str):
        """Reciprocal square root of one block on the policy's datapath at a
        named site; the unary evidence is the operand exponent doubled."""
        return self._alu(
            x,
            x,
            site,
            "rsqrt",
            lambda v, _w: jax.lax.rsqrt(v),
            lambda a, b, fmt, **kw: rr_rsqrt_block(a, fmt, **kw),
        )


def _sweep_kernel(
    *refs, body, prec, sites, site_ops, steps, n_state, n_out, collect, capture,
    has_floor, extent, packed,
):
    if packed:
        # packed storage: payload + per-leaf storage split arrive instead of
        # f32 state; the prologue decodes in-VMEM (DESIGN.md §13)
        pay_refs = refs[:n_state]
        ks_refs = refs[n_state : 2 * n_state]
        pos = 2 * n_state
    else:
        state_refs = refs[:n_state]
        pos = n_state
    k_floor = None
    if has_floor:
        k_floor = refs[pos][...][0]  # (n_sites,) int32
        pos += 1
    if packed:
        out_refs = refs[pos : pos + n_out]
        kout_refs = refs[pos + n_out : pos + 2 * n_out]
        pos += 2 * n_out
    else:
        out_refs = refs[pos : pos + n_out]
        pos += n_out
    ev_ref = cnt_ref = None
    if collect:
        ev_ref = refs[pos]
        pos += 1
    if capture is not None:
        cnt_ref = refs[pos]

    if packed:
        # prologue: unpack each leaf at its carried storage split
        state = tuple(
            unpack_block(pr[...], prec.fmt, kr[...][0, 0])
            for pr, kr in zip(pay_refs, ks_refs)
        )
    else:
        state = tuple(r[...] for r in state_refs)
    n_sites = len(sites)
    # evidence/counts carried functionally through the substep loop, written once
    ev0 = jnp.zeros((steps, n_sites, 2) if collect else (1,), jnp.float32)
    cnt0 = jnp.zeros(
        (n_sites, 2, capture.n_bins) if capture is not None else (1,), jnp.int32
    )

    # valid-lane masks for capture on padded grids: this block's global row/
    # col positions vs the unpadded extents (static), so pad lanes never count
    valid = None
    if capture is not None and extent is not None:
        rows, width = extent
        br, bw = state_refs[0].shape
        row_ok = col_ok = None
        if rows is not None:
            pos = pl.program_id(0) * br + jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0)
            row_ok = pos < rows
        if width is not None:
            pos = pl.program_id(1) * bw + jax.lax.broadcasted_iota(jnp.int32, (1, bw), 1)
            col_ok = pos < width
        valid = (row_ok, col_ok, br, bw)

    def substep(s, carry):
        st, ev, cnt = carry
        ops = FusedOps(
            prec, sites, k_floor=k_floor, collect=collect, capture=capture,
            valid=valid, site_ops=site_ops,
        )
        new = body(st, ops)
        if not isinstance(new, tuple):
            new = (new,)
        if len(new) != n_out:
            raise ValueError(
                f"fused body returned {len(new)} leaves but the sweep was "
                f"declared with n_out={n_out}"
            )
        if collect:
            missing = [n for n in sites if n not in ops.evidence]
            if missing:
                raise ValueError(f"fused body never multiplied at sites {missing}")
            for j, name in enumerate(sites):
                ae, be = ops.evidence[name]
                ev = ev.at[s, j, 0].set(ae)
                ev = ev.at[s, j, 1].set(be)
        if capture is not None:
            # the widened evidence: substep counts accumulate over the chunk
            cnt = cnt + jnp.stack([ops.counts[name] for name in sites])
        return new, ev, cnt

    if steps == 1:
        # single-substep bodies (e.g. an elementwise flux) may return fewer
        # leaves than they take — no loop carry to keep structurally stable
        state, ev, cnt = substep(0, (state, ev0, cnt0))
    else:
        if n_out != n_state:
            raise ValueError(
                f"multi-substep sweeps need body in/out leaf counts to match "
                f"({n_state} != {n_out}): the output is the next substep's input"
            )
        state, ev, cnt = jax.lax.fori_loop(0, steps, substep, (state, ev0, cnt0))
    if packed:
        # epilogue: re-pick each leaf's storage split from the advanced
        # values and encode — identical math to repro.pack's XLA-boundary
        # pack (shared helpers), so in-kernel packing can never disagree
        for pr, kr, v in zip(out_refs, kout_refs, state):
            k_st = block_storage_k(v, prec.fmt)
            pr[...] = pack_block(v, prec.fmt, k_st).astype(payload_dtype(prec.fmt))
            kr[...] = jnp.reshape(k_st, (1, 1)).astype(jnp.int32)
    else:
        for r, v in zip(out_refs, state):
            r[...] = v
    if collect:
        ev_ref[...] = ev[None, None]  # (1, 1, steps, n_sites, 2) block
    if capture is not None:
        cnt_ref[...] = cnt[None, None]  # (1, 1, n_sites, 2, n_bins) block


def fused_sweep(
    body: Callable,
    state: Sequence,
    *,
    prec,
    sites: Tuple[str, ...],
    site_ops: Optional[Tuple[str, ...]] = None,
    steps: int = 1,
    block: Tuple[int, int],
    n_out: Optional[int] = None,
    pad_values: Optional[Sequence[float]] = None,
    k_floor=None,
    collect_evidence: bool = False,
    capture=None,
    interpret: Optional[bool] = None,
    storage: str = "f32",
):
    """Run ``steps`` substeps of ``body`` over blocked state in ONE
    ``pallas_call``.

    Arguments:
      body: ``body(state_blocks, ops) -> out_blocks`` — pure function of
        VMEM blocks; every policy multiplication through ``ops.mul``.
      state: 2-D ``(rows, width)`` f32 leaves, all the same shape.
      prec: the (static, hashable) :class:`PrecisionConfig`.
      sites: the workload's named multiplication sites, in body call order.
      steps: substeps fused into the kernel's ``fori_loop``.
      block: ``(block_rows, block_width)``; clamped to the state shape.
        Sweep bodies (stencil coupling along width) must keep
        ``block_width >= width`` so the coupled extent stays whole in-block.
      n_out: number of leaves ``body`` returns (default: ``len(state)``).
      pad_values: per-leaf constants used when rows/width don't divide the
        clamped block (default 0.0) — pick values that can't dominate a
        mixed block's max-exponent reduction (e.g. 1.0 for a divisor field).
      k_floor: ``(n_sites,) int32`` carried tracker splits; floors the rr
        family's per-block selection (tracked modes).
      collect_evidence: also return the per-substep per-site operand
        max-exponent evidence, cross-block maxed: ``(steps, n_sites, 2)``.
      capture: a :class:`repro.profile.capture.CaptureSpec` widens the
        evidence stream to binned counts — every policy multiplication's
        elementwise operand exponents are histogrammed in-VMEM and the
        per-block counts summed across blocks and substeps, giving
        ``(n_sites, 2, n_bins) int32`` for the whole chunk. Implies
        ``collect_evidence`` (the profile consumes both). Pad lanes are
        masked out of the counts (zero pads by the zero-exponent
        convention, non-zero pads by the in-kernel valid-lane mask), so a
        padded grid profiles identically to the reference plane.
      site_ops: per-site op declarations (``"mul"``/``"add"``/``"div"``/
        ``"rsqrt"``) — when given, a body calling the wrong ``ops`` method
        at a site fails at trace time.
      storage: ``"f32"`` (default) moves f32 state through HBM; ``"packed"``
        takes :class:`repro.pack.PackedArray` leaves instead, decodes them
        in the kernel prologue, and re-packs the advanced state in the
        epilogue at a freshly-picked per-leaf storage split — HBM traffic
        at ``fmt.total_bits`` instead of 32 (the fusion-boundary rule,
        DESIGN.md §13). Requires the block to cover the whole field (one
        storage block == one sweep block) and ``n_out == n_state``.

    Returns ``(out_leaves_tuple, evidence_or_None)``, plus a trailing
    ``counts`` element when ``capture`` is set. Under ``storage="packed"``
    the out leaves are PackedArrays carrying the input leaves' geometry.
    """
    interpret = resolve_interpret(interpret)
    collect_evidence = bool(collect_evidence) or capture is not None
    if storage not in ("f32", "packed"):
        raise ValueError(f"unknown fused storage {storage!r}; 'f32' | 'packed'")
    packed = storage == "packed"
    n_sites = len(sites)
    if site_ops is not None:
        site_ops = tuple(site_ops)
        if len(site_ops) != n_sites:
            raise ValueError(
                f"site_ops covers {len(site_ops)} entries for {n_sites} sites"
            )

    if packed:
        pas = list(state)
        for pa in pas:
            if not isinstance(pa, PackedArray):
                raise TypeError(
                    "storage='packed' takes repro.pack.PackedArray leaves; "
                    f"got {type(pa).__name__}"
                )
            if pa.fmt != prec.fmt:
                raise ValueError(
                    f"packed leaf format {pa.fmt} disagrees with the policy "
                    f"format {prec.fmt}"
                )
        leaves = [pa.payload for pa in pas]
        rows, width = leaves[0].shape
    else:
        leaves = [jnp.asarray(x, jnp.float32) for x in state]
        rows, width = leaves[0].shape
    for x in leaves[1:]:
        if x.shape != (rows, width):
            raise ValueError(f"state leaves disagree: {x.shape} vs {(rows, width)}")
    n_state = len(leaves)
    n_out = n_state if n_out is None else n_out

    br = min(block[0], rows)
    bw = min(block[1], width)
    pr, pw = -rows % br, -width % bw
    if packed:
        if (br, bw) != (rows, width):
            raise ValueError(
                "in-kernel packed storage requires the sweep block to cover "
                f"the whole field: block {(br, bw)} vs state {(rows, width)} "
                "(one storage block per leaf)"
            )
        if n_out != n_state:
            raise ValueError(
                "in-kernel packed storage needs body in/out leaf counts to "
                f"match ({n_state} != {n_out}): every out leaf re-packs"
            )
        for pa in pas:
            if tuple(pa.k.shape[-2:]) != (1, 1):
                raise ValueError(
                    "in-kernel packed storage takes single-block PackedArrays "
                    f"(one split per leaf); got k of shape {tuple(pa.k.shape)}"
                )
        pr = pw = 0
    if pr or pw:
        pv = tuple(pad_values) if pad_values is not None else (0.0,) * n_state
        leaves = [
            jnp.pad(x, ((0, pr), (0, pw)), constant_values=v)
            for x, v in zip(leaves, pv)
        ]
    rp, wp = rows + pr, width + pw
    gi, gj = rp // br, wp // bw

    state_spec = pl.BlockSpec((br, bw), lambda i, j: (i, j))
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    in_specs = [state_spec] * n_state
    inputs = list(leaves)
    if packed:
        in_specs += [scalar_spec] * n_state
        inputs += [jnp.reshape(pa.k, (1, 1)).astype(jnp.int32) for pa in pas]
    if k_floor is not None:
        in_specs.append(pl.BlockSpec((1, n_sites), lambda i, j: (0, 0)))
        inputs.append(jnp.asarray(k_floor, jnp.int32).reshape(1, n_sites))
    out_specs = [state_spec] * n_out
    if packed:
        pdt = payload_dtype(prec.fmt)
        out_shape = [jax.ShapeDtypeStruct((rp, wp), pdt)] * n_out
        out_specs += [scalar_spec] * n_out
        out_shape += [jax.ShapeDtypeStruct((1, 1), jnp.int32)] * n_out
    else:
        out_shape = [jax.ShapeDtypeStruct((rp, wp), jnp.float32)] * n_out
    if collect_evidence:
        out_specs.append(
            pl.BlockSpec((1, 1, steps, n_sites, 2), lambda i, j: (i, j, 0, 0, 0))
        )
        out_shape.append(jax.ShapeDtypeStruct((gi, gj, steps, n_sites, 2), jnp.float32))
    if capture is not None:
        nb = capture.n_bins
        out_specs.append(
            pl.BlockSpec((1, 1, n_sites, 2, nb), lambda i, j: (i, j, 0, 0, 0))
        )
        out_shape.append(jax.ShapeDtypeStruct((gi, gj, n_sites, 2, nb), jnp.int32))

    call = pl.pallas_call(
        functools.partial(
            _sweep_kernel,
            body=body,
            prec=prec,
            sites=tuple(sites),
            site_ops=site_ops,
            steps=steps,
            n_state=n_state,
            n_out=n_out,
            collect=collect_evidence,
            capture=capture,
            has_floor=k_floor is not None,
            extent=(rows if pr else None, width if pw else None) if (pr or pw) else None,
            packed=packed,
        ),
        grid=(gi, gj),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )
    with _obs.span("pallas.fused_sweep", steps=steps, grid=f"{gi}x{gj}"):
        _obs.inc(
            "repro_pallas_dispatch_total",
            help="pallas_call dispatch sites entered",
            kernel="fused_sweep",
        )
        outs = call(*inputs)

    outs = list(outs)
    counts = None
    if capture is not None:
        # global counts = sum of per-block counts (blocks partition elements)
        counts = jnp.sum(outs.pop(), axis=(0, 1), dtype=jnp.int32)
    evidence = None
    if collect_evidence:
        # the global per-substep site evidence is the max over blocks (max of
        # block maxes); padded-only blocks contribute their pad constants'
        # exponents, which the pad_values contract keeps dominated
        evidence = jnp.max(outs.pop(), axis=(0, 1))
    if packed:
        # reassemble PackedArrays around the epilogue's (payload, split)
        # pairs, carrying each input leaf's logical geometry forward
        k_outs = outs[n_out:]
        outs = [
            PackedArray(p, jnp.reshape(kk, pa.k.shape), pa.fmt, pa.shape, pa.block)
            for p, kk, pa in zip(outs[:n_out], k_outs, pas)
        ]
    elif pr or pw:
        outs = [o[:rows, :width] for o in outs]
    if capture is not None:
        return tuple(outs), evidence, counts
    return tuple(outs), evidence
