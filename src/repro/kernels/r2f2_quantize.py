"""Pallas kernel: per-tile R2F2 quantization (the "precision adjustment
unit" as a TPU vector-unit pass).

Each grid cell owns one (bm, bn) VMEM tile. The kernel body scans the tile's
max magnitude, picks the minimal flexible split ``k`` (DESIGN.md §2 — the
hardware's overflow-retry loop collapsed into a pre-pass), quantizes the tile
to ``E(EB+k) M(MB+FX-k)`` with bit-exact RNE, and writes both the quantized
tile and the per-tile ``k`` metadata (the mask bits of Fig. 4a, stored
out-of-band like any block-scaled format's scale).

TPU notes: everything is elementwise u32 bit-twiddling + an 8x128-lane max
reduction — pure VPU work, no MXU. Block shape defaults to (256, 256) f32 =
256 KiB in VMEM (in+out), well under the ~16 MiB/core budget, and is a
multiple of the (8, 128) f32 tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import repro.obs as _obs
from repro.core.flexformat import quantize_em, unbiased_exponent
from repro.core.r2f2 import select_k_operand

DEFAULT_BLOCK = (256, 256)


def _quantize_kernel(x_ref, y_ref, k_ref, *, fmt):
    x = x_ref[...]
    mag = jnp.where(jnp.isfinite(x), jnp.abs(x), 0.0)
    me = unbiased_exponent(jnp.maximum(jnp.max(mag), jnp.float32(1e-38)))
    # operand-only need: product bound handled by the consumer's shared-k
    k = select_k_operand(me, fmt)
    e_bits = fmt.eb + k
    m_bits = fmt.mb + fmt.fx - k
    y_ref[...] = quantize_em(x, e_bits, m_bits)
    k_ref[0, 0] = k


@functools.partial(jax.jit, static_argnames=("fmt", "block", "interpret"))
def r2f2_quantize_pallas(x, *, fmt, block=DEFAULT_BLOCK, interpret=True):
    """Quantize a 2D f32 array tile-by-tile. Returns (y, k_tiles)."""
    m, n = x.shape
    bm = min(block[0], m)
    bn = min(block[1], n)
    if m % bm or n % bn:
        raise ValueError(f"shape {x.shape} not divisible by block ({bm},{bn})")
    grid = (m // bm, n // bn)
    call = pl.pallas_call(
        functools.partial(_quantize_kernel, fmt=fmt),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct(grid, jnp.int32),
        ],
        interpret=interpret,
    )
    with _obs.span("pallas.r2f2_quantize", m=m, n=n):
        _obs.inc(
            "repro_pallas_dispatch_total",
            help="pallas_call dispatch sites entered",
            kernel="r2f2_quantize",
        )
        y, k = call(x.astype(jnp.float32))
    return y, k
