"""Pure-jnp oracles for every Pallas kernel in this package.

Each function reproduces its kernel's semantics exactly (same per-tile split
selection, same quantization, same accumulation order *modulo* f32-add
reassociation, which is exact here because tests compare allclose with tight
tolerances and the emulated formats have few mantissa bits).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.flexformat import quantize_em, unbiased_exponent
from repro.core.r2f2 import product_guard_bits, select_k, select_k_op, select_k_operand


def _max_exp(t):
    mag = jnp.where(jnp.isfinite(t), jnp.abs(t), 0.0)
    return unbiased_exponent(jnp.maximum(jnp.max(mag), jnp.float32(1e-38)))


def _operand_k(t, fmt):
    return select_k_operand(_max_exp(t), fmt)


def r2f2_quantize_ref(x, *, fmt, block=(256, 256)):
    """Oracle for r2f2_quantize_pallas: per-(bm,bn)-tile minimal-k quantize."""
    x = jnp.asarray(x, jnp.float32)
    m, n = x.shape
    bm = min(block[0], m)
    bn = min(block[1], n)
    gm, gn = m // bm, n // bn
    xt = x.reshape(gm, bm, gn, bn)
    mag = jnp.where(jnp.isfinite(xt), jnp.abs(xt), 0.0)
    me = unbiased_exponent(jnp.maximum(jnp.max(mag, axis=(1, 3)), jnp.float32(1e-38)))
    k = select_k_operand(me, fmt)
    kb = k[:, None, :, None]
    y = quantize_em(xt, fmt.eb + kb, fmt.mb + fmt.fx - kb)
    return y.reshape(m, n), k


def r2f2_matmul_ref(a, b, *, fmt, blocks=(128, 128, 128), round_products=False, tail_approx=True):
    """Oracle for r2f2_matmul_pallas: loop over block pairs in the same
    (i, j, k) order, shared split per pair, f32 accumulation."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    m, kd = a.shape
    _, n = b.shape
    bm = min(blocks[0], m)
    bn = min(blocks[1], n)
    bk = min(blocks[2], kd)
    out = jnp.zeros((m, n), jnp.float32)
    for i in range(m // bm):
        for j in range(n // bn):
            acc = jnp.zeros((bm, bn), jnp.float32)
            for kk in range(kd // bk):
                at = a[i * bm:(i + 1) * bm, kk * bk:(kk + 1) * bk]
                bt = b[kk * bk:(kk + 1) * bk, j * bn:(j + 1) * bn]
                k = select_k(_max_exp(at), _max_exp(bt), fmt)
                e_bits, m_bits = fmt.eb + k, fmt.mb + fmt.fx - k
                aq = quantize_em(at, e_bits, m_bits)
                bq = quantize_em(bt, e_bits, m_bits)
                if round_products:
                    guard = product_guard_bits(fmt, k) if tail_approx else None
                    prods = aq[:, :, None] * bq[None, :, :]
                    prods = quantize_em(prods, e_bits, m_bits, tail_trunc_bits=guard)
                    acc = acc + jnp.sum(prods, axis=1)
                else:
                    acc = acc + jnp.dot(aq, bq, preferred_element_type=jnp.float32)
            out = out.at[i * bm:(i + 1) * bm, j * bn:(j + 1) * bn].set(acc)
    return out


def heat_stencil_ref(u0, alpha, dtodx2, *, fmt, steps=1, block_rows=8, tail_approx=True):
    """Oracle for heat_stencil_pallas: identical math per row-block."""
    u0 = jnp.asarray(u0, jnp.float32)
    rows, nx = u0.shape
    br = min(block_rows, rows)
    alpha = jnp.asarray(alpha, jnp.float32)
    dtodx2 = jnp.asarray(dtodx2, jnp.float32)

    def rr_mul(a, b):
        k = select_k(_max_exp(a), _max_exp(b), fmt)
        e_b, m_b = fmt.eb + k, fmt.mb + fmt.fx - k
        guard = product_guard_bits(fmt, k) if tail_approx else None
        return quantize_em(
            quantize_em(a, e_b, m_b) * quantize_em(b, e_b, m_b),
            e_b,
            m_b,
            tail_trunc_bits=guard,
        )

    def block_step(u):
        lap = u[:, :-2] - 2.0 * u[:, 1:-1] + u[:, 2:]
        flux = rr_mul(jnp.broadcast_to(alpha, lap.shape), lap)
        upd = rr_mul(flux, jnp.broadcast_to(dtodx2, lap.shape))
        interior = u[:, 1:-1] + upd
        return jnp.concatenate([u[:, :1], interior, u[:, -1:]], axis=1)

    blocks = []
    for i in range(rows // br):
        u = u0[i * br:(i + 1) * br]
        for _ in range(steps):
            u = block_step(u)
        blocks.append(u)
    return jnp.concatenate(blocks, axis=0)


def swe_flux_ref(q1, q3, *, fmt, block=(64, 128), tail_approx=True):
    """Oracle for swe_flux_pallas: per-block momentum flux with R2F2 muls
    and the flexible divide (shared split under the quotient-range envelope,
    no tail truncation — dividers have no partial-product tail to drop)."""
    q1 = jnp.asarray(q1, jnp.float32)
    q3 = jnp.asarray(q3, jnp.float32)
    m, n = q1.shape
    bm, bn = min(block[0], m), min(block[1], n)

    def rr_mul(a, b):
        k = select_k(_max_exp(a), _max_exp(b), fmt)
        e_b, m_b = fmt.eb + k, fmt.mb + fmt.fx - k
        guard = product_guard_bits(fmt, k) if tail_approx else None
        return quantize_em(
            quantize_em(a, e_b, m_b) * quantize_em(b, e_b, m_b),
            e_b, m_b, tail_trunc_bits=guard,
        )

    def rr_div(a, b):
        k = select_k_op(_max_exp(a), _max_exp(b), fmt, "div")
        e_b, m_b = fmt.eb + k, fmt.mb + fmt.fx - k
        return quantize_em(
            quantize_em(a, e_b, m_b) / quantize_em(b, e_b, m_b), e_b, m_b
        )

    out = jnp.zeros((m, n), jnp.float32)
    for i in range(m // bm):
        for j in range(n // bn):
            a = q1[i * bm:(i + 1) * bm, j * bn:(j + 1) * bn]
            h = q3[i * bm:(i + 1) * bm, j * bn:(j + 1) * bn]
            t2 = rr_div(rr_mul(a, a), h)
            t3 = rr_mul(h, h)
            t4 = rr_mul(jnp.full_like(t3, 0.5 * 9.81), t3)
            out = out.at[i * bm:(i + 1) * bm, j * bn:(j + 1) * bn].set(t2 + t4)
    return out
