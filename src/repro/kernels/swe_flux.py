"""Pallas kernel: SWE momentum-flux equation with R2F2 multiplies.

The paper's substituted sub-equation (§5.3) is the SWE hot spot:

    Ux_mx = q1*q1/q3 + 0.5*g*q3*q3

This kernel fuses, per VMEM block: the two R2F2 multiplications (q1*q1 and
g/2*q3*q3, each with a block-shared runtime split), the f32 division, and
the add — one HBM round trip for the whole flux field instead of five.

Blocks are (bm, bn) tiles over the 2D field, (8, 128)-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.blockops import rr_mul_block

G_GRAV = 9.81
DEFAULT_BLOCK = (64, 128)


def _swe_flux_kernel(q1_ref, q3_ref, o_ref, *, fmt, tail_approx):
    q1 = q1_ref[...]
    q3 = q3_ref[...]
    t1 = rr_mul_block(q1, q1, fmt, tail_approx)  # multiplier 1
    t2 = t1 / q3  # f32 divider (R2F2 is a multiplier)
    t3 = rr_mul_block(q3, q3, fmt, tail_approx)  # multiplier 2
    t4 = rr_mul_block(jnp.full_like(t3, 0.5 * G_GRAV), t3, fmt, tail_approx)  # mult 3
    o_ref[...] = t2 + t4


@functools.partial(
    jax.jit, static_argnames=("fmt", "block", "tail_approx", "interpret")
)
def swe_flux_pallas(q1, q3, *, fmt, block=DEFAULT_BLOCK, tail_approx=True, interpret=True):
    """Momentum flux over 2D fields q1=(hu), q3=h. Returns same-shape f32."""
    m, n = q1.shape
    bm = min(block[0], m)
    bn = min(block[1], n)
    if m % bm or n % bn:
        raise ValueError(f"shape {q1.shape} not divisible by block ({bm},{bn})")
    return pl.pallas_call(
        functools.partial(_swe_flux_kernel, fmt=fmt, tail_approx=tail_approx),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(q1.astype(jnp.float32), q3.astype(jnp.float32))
