"""Fused Pallas kernel: SWE momentum-flux equation with R2F2 multiplies —
built on the shared :mod:`repro.kernels.fused` sweep machinery.

The paper's substituted sub-equation (§5.3) is the SWE hot spot:

    Ux_mx = q1*q1/q3 + 0.5*g*q3*q3

This kernel fuses, per VMEM block: the three policy multiplications (q1*q1,
q3*q3 and g/2*(q3*q3), each with a block-shared runtime split), the policy
division (the ``repro.alu`` flexible divider — its split picked under the
quotient-range envelope at the ``swe.div`` site), and the add — one HBM
round trip for the whole flux field instead of five. The body is purely
elementwise, so both axes tile freely; non-divisible shapes are padded (q3
with 1.0 so the padded divisor stays finite and can't dominate a mixed
block's range reduction) and cropped.

Blocks are (bm, bn) tiles over the 2D field, (8, 128)-aligned.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.policy import PrecisionConfig
from repro.kernels import fused
from repro.kernels.blockops import rr_mul_block  # noqa: F401 — shared block math

G_GRAV = 9.81
DEFAULT_BLOCK = (64, 128)

SWE_SITES = ("swe.q1q1", "swe.q3q3", "swe.gq3", "swe.div")
#: per-site ops aligned with SWE_SITES — the division is a first-class
#: policy op now (repro.alu), no longer a raw-f32 bystander
SWE_OPS = ("mul", "mul", "mul", "div")


def _swe_flux_body(sites):
    q1q1_site, q3q3_site, gq3_site, div_site = sites

    def body(state, ops):
        q1, q3 = state
        t1 = ops.mul(q1, q1, q1q1_site)  # multiplier 1
        t2 = ops.div(t1, q3, div_site)  # flexible divider (quotient envelope)
        t3 = ops.mul(q3, q3, q3q3_site)  # multiplier 2
        t4 = ops.mul(jnp.full_like(t3, 0.5 * G_GRAV), t3, gq3_site)  # mult 3
        return (t2 + t4,)

    return body


def swe_flux_fused(
    q1,
    q3,
    *,
    prec,
    block=None,
    sites=SWE_SITES,
    site_ops=SWE_OPS,
    k_floor=None,
    collect_evidence=False,
    capture=None,
    interpret=None,
):
    """Fused-plane entry: momentum flux + per-site evidence over 2D fields.

    ``block`` defaults to the policy's ``kernel_blocks[:2]``. Returns
    ``(flux, evidence)`` with evidence shaped ``(1, n_sites, 2)`` (the flux
    is one substep of a fused chunk), plus a ``(n_sites, 2, n_bins)``
    exponent-count array when a ``capture`` spec is given.
    """
    block = tuple(prec.kernel_blocks[:2]) if block is None else block
    res = fused.fused_sweep(
        _swe_flux_body(sites),
        (q1, q3),
        prec=prec,
        sites=sites,
        site_ops=site_ops,
        steps=1,
        block=block,
        n_out=1,
        pad_values=(0.0, 1.0),  # q3 is a divisor: pad finite, range-neutral
        k_floor=k_floor,
        collect_evidence=collect_evidence,
        capture=capture,
        interpret=interpret,
    )
    if capture is not None:
        (out,), ev, counts = res
        return out, ev, counts
    (out,), ev = res
    return out, ev


def swe_flux_pallas(q1, q3, *, fmt, block=DEFAULT_BLOCK, tail_approx=True, interpret=None):
    """Momentum flux over 2D fields q1=(hu), q3=h. Returns same-shape f32.

    Historical fmt-keyed surface over :func:`swe_flux_fused` (rr_tile
    semantics, no evidence); ``interpret=None`` auto-detects the backend."""
    prec = PrecisionConfig(mode="rr_tile", fmt=fmt, tail_approx=tail_approx)
    out, _ = swe_flux_fused(q1, q3, prec=prec, block=block, interpret=interpret)
    return out
