"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to None everywhere, which
:func:`repro.kernels.fused.resolve_interpret` resolves to True unless a
real TPU backend is present — this container validates kernel bodies on CPU
(interpret mode executes the same program), while on TPU the identical call
sites compile to Mosaic. The fused whole-step kernels (heat_stencil,
pde_steps, swe_flux) route through the same resolution inside
:func:`repro.kernels.fused.fused_sweep`, so no call site hard-codes the
interpreter.
"""

from __future__ import annotations

from repro.core.flexformat import FlexFormat

from .fused import on_tpu, resolve_interpret
from .heat_stencil import heat_stencil_pallas
from .r2f2_matmul import r2f2_matmul_pallas
from .r2f2_quantize import r2f2_quantize_pallas
from .swe_flux import swe_flux_pallas

__all__ = [
    "on_tpu",
    "resolve_interpret",
    "r2f2_quantize",
    "r2f2_matmul",
    "heat_stencil",
    "swe_flux",
]


def r2f2_quantize(x, fmt: FlexFormat, *, block=(256, 256), interpret=None):
    """Tile-quantize x to the runtime-selected flexible format. -> (y, k_tiles)"""
    return r2f2_quantize_pallas(x, fmt=fmt, block=block, interpret=resolve_interpret(interpret))


def r2f2_matmul(
    a,
    b,
    fmt: FlexFormat,
    *,
    blocks=(128, 128, 128),
    round_products=False,
    tail_approx=True,
    interpret=None,
):
    """A @ B through block-granular R2F2 multipliers (f32 accumulate)."""
    return r2f2_matmul_pallas(
        a,
        b,
        fmt=fmt,
        blocks=blocks,
        round_products=round_products,
        tail_approx=tail_approx,
        interpret=resolve_interpret(interpret),
    )


def heat_stencil(u0, alpha, dtodx2, fmt: FlexFormat, *, steps=1, block_rows=8, tail_approx=True, interpret=None):
    """Fused heat-equation step(s) with R2F2 multiplies and 16-bit state."""
    return heat_stencil_pallas(
        u0, alpha, dtodx2, fmt=fmt, steps=steps, block_rows=block_rows, tail_approx=tail_approx, interpret=interpret
    )


def swe_flux(q1, q3, fmt: FlexFormat, *, block=(64, 128), tail_approx=True, interpret=None):
    """Fused SWE momentum-flux (the paper's substituted equation) per block."""
    return swe_flux_pallas(
        q1, q3, fmt=fmt, block=block, tail_approx=tail_approx, interpret=interpret
    )
