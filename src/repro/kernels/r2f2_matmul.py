"""Pallas kernel: blocked matmul through R2F2 multipliers.

Faithful mapping of the paper's multiplier into an MXU pipeline:

* each (bm, bk) x (bk, bn) block pair shares ONE flexible split ``k`` —
  the paper's same-format-operands rule (§4.1) at block granularity;
* ``k`` is the minimal split covering both operand tiles AND their product
  bound — the overflow-retry loop collapsed into a pre-pass (DESIGN.md §2);
* operands are quantized to ``E(EB+k) M(MB+FX-k)`` bit-exactly (RNE);
* products accumulate in f32. Two product-rounding semantics:
    - ``round_products=False`` (deployment): products stay exact into the
      accumulator — how an R2F2-fed MXU would behave (bf16-MXU-style);
    - ``round_products=True`` (scalar-faithful): every scalar product is
      rounded to the runtime format (incl. the paper's FX-tail truncation)
      before summation — the paper's discrete multiplier feeding an adder.
      Materializes (bm, bk, bn) intermediates; use small blocks.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary" semantics — sequential
accumulation into the same output block; m, n are "parallel"). Default
blocks (128, 128, 128): A+B+O tiles = 3 * 64 KiB f32 in VMEM, MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import repro.obs as _obs
from repro.core.flexformat import quantize_em, unbiased_exponent
from repro.core.r2f2 import product_guard_bits, select_k

DEFAULT_BLOCKS = (128, 128, 128)


def _matmul_kernel(a_ref, b_ref, o_ref, *, fmt, round_products, tail_approx):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    b = b_ref[...]

    def tile_max_exp(t):
        mag = jnp.where(jnp.isfinite(t), jnp.abs(t), 0.0)
        return unbiased_exponent(jnp.maximum(jnp.max(mag), jnp.float32(1e-38)))

    k = select_k(tile_max_exp(a), tile_max_exp(b), fmt)
    e_bits = fmt.eb + k
    m_bits = fmt.mb + fmt.fx - k
    aq = quantize_em(a, e_bits, m_bits)
    bq = quantize_em(b, e_bits, m_bits)

    if round_products:
        # scalar-faithful: round each product to the runtime format before
        # the adds (paper Fig. 4b, incl. the FX-tail truncation).
        guard = product_guard_bits(fmt, k) if tail_approx else None
        prods = aq[:, :, None] * bq[None, :, :]  # (bm, bk, bn), exact in f32
        prods = quantize_em(prods, e_bits, m_bits, tail_trunc_bits=guard)
        partial = jnp.sum(prods, axis=1)
    else:
        partial = jnp.dot(aq, bq, preferred_element_type=jnp.float32)

    o_ref[...] += partial


@functools.partial(
    jax.jit,
    static_argnames=("fmt", "blocks", "round_products", "tail_approx", "interpret"),
)
def r2f2_matmul_pallas(
    a,
    b,
    *,
    fmt,
    blocks=DEFAULT_BLOCKS,
    round_products=False,
    tail_approx=True,
    interpret=True,
):
    """C = A @ B with R2F2 block semantics. A: (M, K) f32, B: (K, N) f32.

    Non-divisible shapes are zero-padded up to block multiples and the
    output cropped back: padded zeros contribute nothing to the products
    and never raise a block's max exponent, so the real region's split
    selection and quantization are unchanged.
    """
    m, kdim = a.shape
    k2, n = b.shape
    if kdim != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    bm = min(blocks[0], m)
    bn = min(blocks[1], n)
    bk = min(blocks[2], kdim)
    pm, pn, pk = -m % bm, -n % bn, -kdim % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    mp, np_, kp = m + pm, n + pn, kdim + pk

    grid = (mp // bm, np_ // bn, kp // bk)
    call = pl.pallas_call(
        functools.partial(
            _matmul_kernel,
            fmt=fmt,
            round_products=round_products,
            tail_approx=tail_approx,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )
    with _obs.span("pallas.r2f2_matmul", m=m, n=n, k=kdim):
        _obs.inc(
            "repro_pallas_dispatch_total",
            help="pallas_call dispatch sites entered",
            kernel="r2f2_matmul",
        )
        out = call(a, b)
    return out[:m, :n] if (pm or pn) else out
