"""Dependency-free stand-in for the slice of the hypothesis API our
property suites use (``given``, ``settings``, ``strategies.floats`` /
``strategies.integers``).

The baked runtime image does not ship hypothesis, and the repo may not
install anything; rather than skip the bit-level property modules,
``tests/conftest.py`` installs this module as ``sys.modules["hypothesis"]``
when the real package is absent, so the same test source runs under either.
Semantics under the stub:

* **deterministic** — the example stream is seeded from the test's qualname
  (crc32, not ``hash``), so a failure reproduces without shrinking;
* **edge-first** — every strategy contributes a corner list (signed zeros,
  bound endpoints, subnormal floor, max-normal neighborhood, ...) and the
  first examples round-robin through those before random draws start; the
  corners are the cases these suites exist for;
* **bounded** — the example budget is ``settings(max_examples=...)`` capped
  by ``REPRO_HYPOTHESIS_EXAMPLES`` (default 50), which is how CI's fast
  tier keeps the property modules inside its time budget. Under the real
  package the same env var is applied via a profile in conftest.

No shrinking, no ``assume``, no stateful testing — the suites here don't
use them.
"""

from __future__ import annotations

import functools
import inspect
import itertools
import os
import random
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies"]

#: hard cap on per-test examples, CI's knob for the fast tier
ENV_BUDGET = "REPRO_HYPOTHESIS_EXAMPLES"
DEFAULT_MAX_EXAMPLES = 50


def _budget(requested: int) -> int:
    cap = int(os.environ.get(ENV_BUDGET, DEFAULT_MAX_EXAMPLES))
    return max(1, min(requested, cap))


class _Strategy:
    """A corner list + a random draw function."""

    def __init__(self, edges, draw):
        self.edges = list(edges)
        self.draw = draw


def _floats(
    min_value=None,
    max_value=None,
    allow_nan=False,
    allow_infinity=False,
    width=64,
):
    lo = -1.7e308 if min_value is None else float(min_value)
    hi = 1.7e308 if max_value is None else float(max_value)
    corners = [
        0.0,
        -0.0,
        lo,
        hi,
        1.0,
        -1.0,
        1.5,
        2.0**-126,  # f32 normal floor
        -(2.0**-126),
        2.0**-149,  # f32 subnormal floor
        65504.0,  # E5M10 max normal
        -65504.0,
        65520.0,  # first value past it (rounds to inf at E5M10)
        2.0**-24,
        3.14159265,
    ]
    edges = [x for x in corners if lo <= x <= hi]
    # random: sign * log-uniform magnitude over the representable span,
    # clipped to the requested bounds; width=32 snaps to an f32 value
    hi_mag = max(abs(lo), abs(hi), 2.0**-120)
    e_hi = np.log2(hi_mag)

    def draw(rng: random.Random) -> float:
        if rng.random() < 0.05:
            return 0.0
        mag = 2.0 ** rng.uniform(-130.0, e_hi)
        x = mag * (1 if rng.random() < 0.5 else -1) * (1.0 + rng.random())
        x = min(max(x, lo), hi)
        return float(np.float32(x)) if width == 32 else float(x)

    if width == 32:
        edges = [float(np.float32(x)) for x in edges]
        edges = [x for x in edges if lo <= x <= hi]
    return _Strategy(edges, draw)


def _integers(min_value, max_value):
    lo, hi = int(min_value), int(max_value)
    corners = [lo, hi, 0, 1, -1, lo + 1, hi - 1]
    edges = sorted({x for x in corners if lo <= x <= hi})

    def draw(rng: random.Random) -> int:
        return rng.randint(lo, hi)

    return _Strategy(edges, draw)


class strategies:  # noqa: N801 — mirrors the `hypothesis.strategies` module
    floats = staticmethod(_floats)
    integers = staticmethod(_integers)


def settings(*, max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Record the example budget on the (possibly given-wrapped) function."""

    def decorate(fn):
        fn._hyp_max_examples = max_examples
        return fn

    return decorate


def _examples(params, rng: random.Random, n: int):
    """Edge combos first (round-robin so every corner appears), then random."""
    names = list(params)
    width = max((len(params[k].edges) for k in names), default=0)
    count = 0
    for i in range(width):
        if count >= n:
            return
        yield {
            k: params[k].edges[i % len(params[k].edges)]
            for k in names
            if params[k].edges
        }
        count += 1
    while count < n:
        yield {k: params[k].draw(rng) for k in names}
        count += 1


def given(**params):
    """kwargs-only ``@given`` — the form every suite in this repo uses."""

    def decorate(fn):
        sig = inspect.signature(fn)
        remaining = [p for name, p in sig.parameters.items() if name not in params]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = _budget(getattr(wrapper, "_hyp_max_examples", DEFAULT_MAX_EXAMPLES))
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for ex in _examples(params, rng, n):
                try:
                    fn(*args, **kwargs, **ex)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({fn.__qualname__}): {ex!r}"
                    ) from e

        # hide the strategy params from pytest's fixture resolution
        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper

    return decorate
