"""repro.service — the batched simulation-serving plane (ISSUE 5).

The acceptance contract: packing is *semantically invisible*. For every
registered stepper, a request served through a multi-request bucket —
including one that joins mid-flight via continuous batching, with a
deliberately misaligned snapshot cadence so the bucket's chunking differs
from either solo run — yields bit-identical snapshots/state to a solo
``Simulation.run`` for f32/bf16/fixed/rr_tile/deploy, and identical final
split ``k`` + §5.3 adjustment counters for ``rr_tracked``. Around that:
eviction→resume bit-exactness through ``repro.ckpt``, admission control and
backpressure, bucketing rules, the unified policy-artifact resolution,
streaming, metrics, and the solver's new ``tracker0_batch`` repacking entry.
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flexformat import FlexFormat
from repro.core.policy import PRESETS, PrecisionConfig
from repro.pde import (
    AdvectionConfig,
    BurgersConfig,
    HeatConfig,
    Heat2DConfig,
    SWEConfig,
    Simulation,
)
from repro.profile import PrecisionPolicy
from repro.service import (
    BucketKey,
    ServiceConfig,
    ServiceOverloaded,
    SimRequest,
    SimService,
    resolve_request,
)

TRACKED = dataclasses.replace(PRESETS["r2f2_16"], mode="rr_tracked")

#: small grids: the parity matrix runs 5 steppers x 6 modes in the fast tier
SMALL_CFGS = {
    "heat1d": HeatConfig(nx=48),
    "heat2d": Heat2DConfig(nx=16, ny=16),
    "advection1d": AdvectionConfig(nx=64),
    "burgers1d": BurgersConfig(nx=48),
    "swe2d": SWEConfig(nx=16, ny=16),
}

#: (label, config, bit_exact) — rr_tracked's guarantee is final split k +
#: §5.3 counters (bit-exactness additionally holds on the reference plane
#: and is asserted there)
MODES = (
    ("f32", PRESETS["f32"], True),
    ("bf16", PRESETS["bf16"], True),
    ("e5m10", PRESETS["e5m10"], True),
    ("r2f2_16", PRESETS["r2f2_16"], True),
    ("deploy", PRESETS["deploy"], True),
    ("rr_tracked", TRACKED, True),
)


def _scaled(state, s):
    return jax.tree_util.tree_map(lambda x: (s * x).astype(x.dtype), state)


def _assert_trackers_equal(a, b):
    assert (a is None) == (b is None)
    if a is None:
        return
    np.testing.assert_array_equal(np.asarray(a.state.k), np.asarray(b.state.k))
    np.testing.assert_array_equal(
        np.asarray(a.state.overflow_steps), np.asarray(b.state.overflow_steps)
    )
    np.testing.assert_array_equal(
        np.asarray(a.state.shrink_steps), np.asarray(b.state.shrink_steps)
    )


# ---------------------------------------------------------------------------
# the acceptance matrix: packing invisibility per stepper x mode
# ---------------------------------------------------------------------------


class TestPackingInvisibility:
    @pytest.mark.parametrize("stepper", sorted(SMALL_CFGS))
    @pytest.mark.parametrize("mode", [m[0] for m in MODES])
    def test_bucketed_equals_solo(self, stepper, mode):
        """Two requests share a bucket; the second joins mid-flight with a
        misaligned cadence (forcing chunk subdivision); both must reproduce
        their solo runs."""
        prec = dict((m[0], m[1]) for m in MODES)[mode]
        bit_exact = dict((m[0], m[2]) for m in MODES)[mode]
        cfg = SMALL_CFGS[stepper]
        sim = Simulation(stepper, cfg, prec)
        s0b = _scaled(sim.stepper.init_state(cfg), 0.5)

        svc = SimService(ServiceConfig())
        hA = svc.submit(
            SimRequest(stepper, steps=24, precision=prec, cfg=cfg,
                       snapshot_every=8, execution="reference")
        )
        assert svc.pump()  # A runs its first chunk alone...
        hB = svc.submit(  # ...then B joins the running bucket mid-flight
            SimRequest(stepper, steps=18, precision=prec, cfg=cfg,
                       snapshot_every=6, state0=s0b, execution="reference")
        )
        svc.run_until_idle()
        assert hA.status == "done" and hB.status == "done"
        # they really shared one bucket (continuous batching, not siblings)
        assert svc.metrics.occupancy()[1] == 2

        soloA = sim.run(24, snapshot_every=8)
        soloB = sim.run(18, snapshot_every=6, state0=s0b)
        for h, solo in ((hA, soloA), (hB, soloB)):
            if bit_exact:
                np.testing.assert_array_equal(
                    np.stack(h.snapshots), np.asarray(solo.snapshots)
                )
                np.testing.assert_array_equal(
                    np.asarray(h.result().state), np.asarray(solo.state)
                )
            _assert_trackers_equal(h.result().tracker, solo.tracker)

    def test_fused_bucket_parity(self):
        """The fused plane: deploy rides bf16 kernels bit-exactly through a
        shared bucket with a mid-flight joiner; rr_tracked converges to the
        identical final split + §5.3 counters."""
        cfg = Heat2DConfig(nx=16, ny=16)
        for prec, bit_exact in ((PRESETS["deploy"], True), (TRACKED, False)):
            sim = Simulation("heat2d", cfg, prec)
            if not sim.fused_eligible():
                pytest.skip("heat2d not fused-eligible in this build")
            svc = SimService(ServiceConfig())
            hA = svc.submit(
                SimRequest("heat2d", steps=12, precision=prec, cfg=cfg,
                           snapshot_every=4, execution="fused")
            )
            assert svc.pump()
            hB = svc.submit(
                SimRequest("heat2d", steps=12, precision=prec, cfg=cfg,
                           snapshot_every=4,
                           state0=_scaled(sim.stepper.init_state(cfg), 0.5),
                           execution="fused")
            )
            svc.run_until_idle()
            assert svc.metrics.occupancy()[1] == 2
            soloA = sim.run(12, snapshot_every=4, execution="fused")
            soloB = sim.run(
                12, snapshot_every=4, execution="fused",
                state0=_scaled(sim.stepper.init_state(cfg), 0.5),
            )
            for h, solo in ((hA, soloA), (hB, soloB)):
                _assert_trackers_equal(h.result().tracker, solo.tracker)
                if bit_exact:
                    np.testing.assert_array_equal(
                        np.stack(h.snapshots), np.asarray(solo.snapshots)
                    )
                else:
                    np.testing.assert_allclose(
                        np.stack(h.snapshots), np.asarray(solo.snapshots),
                        rtol=2e-2, atol=1e-5,
                    )

    def test_remainder_horizon(self):
        """A horizon that is not a multiple of the cadence drains with the
        same snapshots + final state as solo (remainder steps run, no
        trailing snapshot)."""
        cfg = HeatConfig(nx=48)
        svc = SimService(ServiceConfig())
        h = svc.submit(
            SimRequest("heat1d", steps=23, precision="r2f2_16", cfg=cfg,
                       snapshot_every=8, execution="reference")
        )
        svc.run_until_idle()
        solo = Simulation("heat1d", cfg, PRESETS["r2f2_16"]).run(23, snapshot_every=8)
        assert h.snapshot_steps == [8, 16]
        np.testing.assert_array_equal(np.stack(h.snapshots), np.asarray(solo.snapshots))
        np.testing.assert_array_equal(np.asarray(h.result().state), np.asarray(solo.state))


# ---------------------------------------------------------------------------
# solver: the repacking entry the service builds on
# ---------------------------------------------------------------------------


class TestTrackerBatchRepacking:
    def test_run_ensemble_tracker0_batch_resumes(self):
        """Chunked ensemble advance with tracker stacks handed back in ==
        one uninterrupted ensemble, bit for bit (state AND adjust state)."""
        cfg = BurgersConfig(nx=48)
        sim = Simulation("burgers1d", cfg, TRACKED)
        u0 = sim.stepper.init_state(cfg)
        u0b = jnp.stack([u0, 0.5 * u0, 2.0 * u0])

        full = sim.run_ensemble(u0b, 20, snapshot_every=10)
        first = sim.run_ensemble(u0b, 10, snapshot_every=10)
        second = sim.run_ensemble(
            first.state, 10, snapshot_every=10, tracker0_batch=first.tracker
        )
        np.testing.assert_array_equal(np.asarray(second.state), np.asarray(full.state))
        _assert_trackers_equal(second.tracker, full.tracker)


# ---------------------------------------------------------------------------
# scheduler: bucketing rules, admission control, backpressure
# ---------------------------------------------------------------------------


class TestScheduling:
    def test_compatible_requests_share_a_bucket(self):
        svc = SimService(ServiceConfig())
        cfg = HeatConfig(nx=48)
        for _ in range(3):
            svc.submit(SimRequest("heat1d", steps=8, precision="f32", cfg=cfg))
        svc._fill()
        assert len(svc._live_buckets()) == 1
        assert len(svc._live_buckets()[0]) == 3

    def test_incompatible_requests_get_sibling_buckets(self):
        svc = SimService(ServiceConfig())
        cfg = HeatConfig(nx=48)
        svc.submit(SimRequest("heat1d", steps=8, precision="f32", cfg=cfg))
        svc.submit(SimRequest("heat1d", steps=8, precision="bf16", cfg=cfg))  # mode
        svc.submit(SimRequest("heat1d", steps=8, precision="f32", cfg=HeatConfig(nx=32)))  # cfg
        svc.submit(SimRequest("heat2d", steps=8, precision="f32"))  # stepper
        svc._fill()
        assert len(svc._live_buckets()) == 4

    def test_max_bucket_caps_vmap_width(self):
        svc = SimService(ServiceConfig(max_bucket=2))
        cfg = HeatConfig(nx=48)
        for _ in range(5):
            svc.submit(SimRequest("heat1d", steps=8, precision="f32", cfg=cfg))
        svc._fill()
        widths = sorted(len(b) for b in svc._live_buckets())
        assert widths == [1, 2, 2]

    def test_backpressure_raises_and_counts(self):
        svc = SimService(ServiceConfig(max_queue=2))
        svc.submit(SimRequest("heat1d", steps=8))
        svc.submit(SimRequest("heat1d", steps=8))
        with pytest.raises(ServiceOverloaded):
            svc.submit(SimRequest("heat1d", steps=8))
        assert svc.metrics.rejected == 1
        assert svc.metrics.submitted == 2

    def test_bad_requests_rejected_at_admission(self):
        svc = SimService(ServiceConfig())
        with pytest.raises(KeyError, match="no PDE stepper"):
            svc.submit(SimRequest("not-a-stepper", steps=8))
        with pytest.raises(ValueError, match="horizon"):
            svc.submit(SimRequest("heat1d", steps=0))
        with pytest.raises(ValueError, match="snapshot_every"):
            svc.submit(SimRequest("heat1d", steps=8, snapshot_every=-5))
        assert svc.metrics.rejected == 3

    def test_explicit_fused_ineligible_rejected_at_submit(self):
        """execution='fused' on a stepper without a fused body fails at
        admission, not mid-flight."""
        from repro.pde import Stepper, register_stepper
        from repro.pde.registry import _STEPPERS

        class NoFused(Stepper):
            sites = ("nf.mul",)

            def default_config(self):
                return None

            def init_state(self, cfg):
                return jnp.ones((8,), jnp.float32)

            def step(self, u, cfg, ops):
                return ops.mul(jnp.float32(0.5), u, "nf.mul")

        try:
            register_stepper("test_nofused", NoFused)
            svc = SimService(ServiceConfig())
            with pytest.raises(ValueError, match="not fused-eligible"):
                svc.submit(SimRequest("test_nofused", steps=4, precision="f32",
                                      execution="fused"))
            assert svc.metrics.rejected == 1
        finally:
            _STEPPERS.pop("test_nofused", None)

    def test_max_active_members_bounds_occupancy(self):
        svc = SimService(ServiceConfig(max_active_members=2))
        cfg = HeatConfig(nx=48)
        for _ in range(4):
            svc.submit(SimRequest("heat1d", steps=8, precision="f32", cfg=cfg,
                                  snapshot_every=4))
        svc.run_until_idle()
        assert svc.metrics.completed == 4
        assert svc.metrics.occupancy()[1] <= 2


# ---------------------------------------------------------------------------
# eviction / resume (satellite: bit-exact round trip through repro.ckpt)
# ---------------------------------------------------------------------------


class TestEvictionResume:
    def test_evicted_and_resumed_is_bit_identical(self, tmp_path):
        """A tracked request checkpointed out mid-run and resumed produces
        bit-identical snapshots AND identical final tracker k / §5.3
        counters to an uninterrupted run."""
        cfg = BurgersConfig(nx=48)
        svc = SimService(ServiceConfig(ckpt_dir=str(tmp_path), auto_resume=False))
        hA = svc.submit(SimRequest("burgers1d", steps=30, precision=TRACKED,
                                   cfg=cfg, snapshot_every=10, execution="reference"))
        hB = svc.submit(SimRequest("burgers1d", steps=30, precision=TRACKED,
                                   cfg=cfg, snapshot_every=10, execution="reference"))
        svc.pump()  # both at elapsed=10
        path = svc.evict(hA.id)
        assert hA.status == "evicted"
        assert os.path.isdir(path)
        assert svc.evicted_ids == [hA.id]

        svc.run_until_idle()  # B completes alone; A stays evicted
        assert hB.status == "done" and hA.status == "evicted"

        svc.resume(hA.id)
        svc.run_until_idle()
        assert hA.status == "done"

        solo = Simulation("burgers1d", cfg, TRACKED).run(30, snapshot_every=10)
        np.testing.assert_array_equal(np.stack(hA.snapshots), np.asarray(solo.snapshots))
        np.testing.assert_array_equal(np.asarray(hA.result().state), np.asarray(solo.state))
        _assert_trackers_equal(hA.result().tracker, solo.tracker)

        kinds = [e.kind for e in hA.stream.drain()]
        assert kinds == ["snapshot", "evicted", "resumed", "snapshot", "snapshot", "done"]
        assert svc.metrics.evicted == 1 and svc.metrics.resumed == 1

    def test_auto_evict_spills_long_horizon_under_pressure(self, tmp_path):
        """With one slot, a long-horizon member is spilled for shorter
        queued work and transparently restored after — both complete,
        bit-identically to solo."""
        cfg = HeatConfig(nx=48)
        svc = SimService(ServiceConfig(
            ckpt_dir=str(tmp_path), max_active_members=1,
            auto_evict=True, evict_min_remaining=0,
        ))
        hLong = svc.submit(SimRequest("heat1d", steps=40, precision="r2f2_16",
                                      cfg=cfg, snapshot_every=10))
        svc.pump()  # long runs its first chunk
        hShort = svc.submit(SimRequest("heat1d", steps=8, precision="r2f2_16",
                                       cfg=cfg, snapshot_every=4))
        svc.run_until_idle()
        assert hLong.status == "done" and hShort.status == "done"
        assert svc.metrics.evicted >= 1 and svc.metrics.resumed >= 1

        soloL = Simulation("heat1d", cfg, PRESETS["r2f2_16"]).run(40, snapshot_every=10)
        np.testing.assert_array_equal(
            np.stack(hLong.snapshots), np.asarray(soloL.snapshots)
        )


# ---------------------------------------------------------------------------
# per-request precision policies (unified artifact resolution)
# ---------------------------------------------------------------------------


def _accepted_policy():
    return PrecisionPolicy(
        stepper="heat1d",
        fmt=FlexFormat(3, 9, 3),
        sites={
            "heat.flux": {"k": 1, "k_lo": 0, "k_hi": 2},
            "heat.update": {"k": 2, "k_lo": 1, "k_hi": 3},
        },
        validation={"accepted": True, "rel_l2_deploy": 0.0},
    )


class TestPerRequestPolicies:
    def test_policy_seeds_tracker_and_bounds(self):
        rec = resolve_request(
            1, SimRequest("heat1d", steps=8, precision=TRACKED, policy=_accepted_policy())
        )
        np.testing.assert_array_equal(np.asarray(rec.tracker.state.k), [1, 2])
        assert rec.key.prec.k_bounds == ((0, 2), (1, 3))

    def test_unaccepted_policy_refused(self):
        pol = _accepted_policy()
        pol.validation = None
        svc = SimService(ServiceConfig())
        with pytest.raises(ValueError, match="never accepted"):
            svc.submit(SimRequest("heat1d", steps=8, precision=TRACKED, policy=pol))
        assert svc.metrics.rejected == 1

    def test_foreign_stepper_policy_refused(self):
        with pytest.raises(ValueError, match="do not transfer"):
            resolve_request(
                1, SimRequest("burgers1d", steps=8, precision=TRACKED,
                              policy=_accepted_policy())
            )

    def test_policy_fmt_rebases_request_precision(self):
        """The artifact's format wins (shared resolve_policy gate), so a
        request submitted with a different fmt still buckets on the
        artifact's <EB,MB,FX>."""
        other = dataclasses.replace(TRACKED, fmt=FlexFormat(3, 8, 4))
        rec = resolve_request(
            1, SimRequest("heat1d", steps=8, precision=other, policy=_accepted_policy())
        )
        assert rec.key.prec.fmt == FlexFormat(3, 9, 3)

    def test_different_policies_same_bounds_pack_by_prec(self):
        """Bucket compatibility is the *effective* config: two requests with
        the same artifact share a bucket; different k_bounds split."""
        polA = _accepted_policy()
        recA = resolve_request(1, SimRequest("heat1d", steps=8, precision=TRACKED, policy=polA))
        recB = resolve_request(2, SimRequest("heat1d", steps=8, precision=TRACKED, policy=polA))
        assert recA.key == recB.key
        polC = _accepted_policy()
        polC.sites["heat.flux"]["k_hi"] = 3
        recC = resolve_request(3, SimRequest("heat1d", steps=8, precision=TRACKED, policy=polC))
        assert recC.key != recA.key

    def test_service_run_with_policy_matches_solo_policy_run(self):
        pol = _accepted_policy()
        svc = SimService(ServiceConfig())
        h = svc.submit(SimRequest("heat1d", steps=16, precision=TRACKED,
                                  policy=pol, snapshot_every=8))
        svc.run_until_idle()
        solo = Simulation("heat1d", None, TRACKED).run(16, snapshot_every=8, policy=pol)
        np.testing.assert_array_equal(np.stack(h.snapshots), np.asarray(solo.snapshots))
        _assert_trackers_equal(h.result().tracker, solo.tracker)

    def test_serve_shim_delegates_to_artifact_impl(self):
        """serve.decode.resolve_policy is a thin shim over the single
        implementation in repro.profile.artifact."""
        from repro.profile.artifact import resolve_policy as impl
        from repro.serve import resolve_policy as shim

        pol = _accepted_policy()
        prec = PrecisionConfig(mode="deploy", fmt=FlexFormat(3, 8, 4))
        got_prec, got_pol = shim(prec, pol)
        exp_prec, exp_pol = impl(prec, pol)
        assert got_prec == exp_prec and got_pol is exp_pol is pol
        pol.validation = None
        with pytest.raises(ValueError, match="never accepted"):
            shim(prec, pol)
        # opting out mirrors the shared impl too
        assert shim(prec, pol, require_accepted=False)[0].fmt == pol.fmt


# ---------------------------------------------------------------------------
# streaming + metrics
# ---------------------------------------------------------------------------


class TestStreamingAndMetrics:
    def test_stream_events_arrive_in_order(self):
        svc = SimService(ServiceConfig())
        h = svc.submit(SimRequest("heat1d", steps=12, precision="f32",
                                  cfg=HeatConfig(nx=48), snapshot_every=4))
        seen = []
        while svc.pump():
            seen += h.stream.drain()
        kinds = [e.kind for e in seen]
        assert kinds == ["snapshot", "snapshot", "snapshot", "done"]
        assert [e.step for e in seen] == [4, 8, 12, 12]
        assert h.stream.closed
        snap0 = seen[0].payload
        assert isinstance(snap0, np.ndarray) and snap0.shape == (48,)

    def test_metrics_surface(self):
        svc = SimService(ServiceConfig())
        cfg = BurgersConfig(nx=48)
        for s in (1.0, 0.5):
            svc.submit(SimRequest("burgers1d", steps=12, precision=TRACKED, cfg=cfg,
                                  snapshot_every=4,
                                  state0=s * Simulation("burgers1d", cfg, TRACKED).stepper.init_state(cfg)))
        svc.run_until_idle()
        s = svc.metrics.summary()
        assert s["submitted"] == s["completed"] == 2
        assert s["chunks"] == 3  # both members aligned: 3 shared chunks
        assert s["member_steps"] == 24
        assert s["occupancy_mean"] == 2.0 and s["occupancy_max"] == 2
        assert s["throughput_steps_per_s"] > 0
        assert np.isfinite(s["chunk_latency_p50_us"])
        assert s["chunk_latency_p99_us"] >= s["chunk_latency_p50_us"]
        assert set(s["site_adjustments"]) == {"burgers.uu", "burgers.flux"}
        assert "throughput" in svc.metrics.report()

    def test_compiled_chunk_cache_reused_across_repacks(self):
        """Steady-state traffic re-uses jitted chunk programs: serving two
        identical sequential requests compiles no more programs than the
        distinct (chunk, width) shapes seen."""
        svc = SimService(ServiceConfig())
        cfg = HeatConfig(nx=48)
        svc.submit(SimRequest("heat1d", steps=12, precision="f32", cfg=cfg,
                              snapshot_every=4))
        svc.run_until_idle()
        n_first = len(svc._compiler)
        svc.submit(SimRequest("heat1d", steps=12, precision="f32", cfg=cfg,
                              snapshot_every=4))
        svc.run_until_idle()
        assert len(svc._compiler) == n_first  # same (key, chunk, width): no retrace


# ---------------------------------------------------------------------------
# sharding: bucket members ride the logical batch axis
# ---------------------------------------------------------------------------


class TestShardedService:
    def test_service_under_mesh_context(self):
        from jax.sharding import Mesh

        from repro.dist.sharding import axis_rules

        cfg = BurgersConfig(nx=48)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
        svc = SimService(ServiceConfig())  # sharded=None -> auto-detect
        with mesh, axis_rules(mesh):
            hs = [
                svc.submit(SimRequest("burgers1d", steps=12, precision="r2f2_16",
                                      cfg=cfg, snapshot_every=4))
                for _ in range(2)
            ]
            svc.run_until_idle()
        assert all(h.status == "done" for h in hs)
        solo = Simulation("burgers1d", cfg, PRESETS["r2f2_16"]).run(12, snapshot_every=4)
        np.testing.assert_array_equal(np.stack(hs[0].snapshots), np.asarray(solo.snapshots))
