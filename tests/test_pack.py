"""Bit-level + integration suites for packed R2F2 storage (repro.pack).

Property tests pin the storage law: ``unpack(pack(x))`` IS ``quantize_em``
at the block's chosen split (pack/unpack bijective on quantized values),
across every reachable k, block granularity, and the padding crop. The
integration half asserts the design rule the solver builds on — a run
carrying ``storage="packed"`` state is bit-identical to the f32-carried
``storage="quantized"`` run on every stepper and plane — plus the service
legs: bucket separation by storage format and evict->resume parity through
``repro.ckpt`` with PackedArray state.
"""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import FlexFormat, quantize_em
from repro.core.policy import PrecisionConfig
from repro.pack import (
    PackedArray,
    block_storage_k,
    is_packed,
    pack_array,
    pack_state,
    payload_dtype,
    state_nbytes,
    storage_quantize,
    unpack_array,
    unpack_state,
)
from repro.pde import Simulation, get_stepper, known_steppers

FMT = FlexFormat(3, 9, 3)

STEPPER_SMALL_CFG = {
    "heat1d": {"nx": 64},
    "heat2d": {"nx": 16, "ny": 16},
    "advection1d": {"nx": 64},
    "burgers1d": {"nx": 64},
    "swe2d": {"nx": 16, "ny": 16},
}


def _small_cfg(name):
    return dataclasses.replace(
        get_stepper(name).default_config(), **STEPPER_SMALL_CFG[name]
    )


# ---------------------------------------------------------------- properties


@settings(max_examples=80, deadline=None)
@given(
    e=st.integers(-14, 28),  # magnitude exponent: drives the chosen k over 0..FX
    n=st.integers(1, 48),
    seed=st.integers(0, 2**16),
)
def test_prop_roundtrip_is_quantize_at_chosen_k(e, n, seed):
    rng = np.random.default_rng(seed)
    x = (rng.uniform(-1.0, 1.0, n) * 2.0**e).astype(np.float32)
    pa = pack_array(x, FMT)
    assert pa.payload.dtype == payload_dtype(FMT)
    k = int(np.asarray(pa.k).max())
    expect = np.asarray(
        quantize_em(x, FMT.eb + k, FMT.mb + FMT.fx - k), np.float32
    )
    np.testing.assert_array_equal(np.asarray(unpack_array(pa), np.float32), expect)
    # the chosen split is block_storage_k's answer
    assert k == int(np.asarray(block_storage_k(x.reshape(1, -1), FMT)))


@settings(max_examples=60, deadline=None)
@given(
    e=st.integers(-12, 24),
    rows=st.integers(1, 12),
    width=st.integers(1, 24),
    br=st.integers(1, 12),
    bw=st.integers(1, 24),
    seed=st.integers(0, 2**16),
)
def test_prop_blocked_roundtrip_and_padding_crop(e, rows, width, br, bw, seed):
    """Per-block splits + non-dividing blocks: pad is cropped, every block
    decodes to its own quantize_em."""
    rng = np.random.default_rng(seed)
    x = (rng.uniform(-1.0, 1.0, (rows, width)) * 2.0**e).astype(np.float32)
    pa = pack_array(x, FMT, block=(br, bw))
    out = np.asarray(unpack_array(pa), np.float32)
    assert out.shape == x.shape
    k = np.asarray(pa.k)
    bR, bW = pa.block
    for i in range(k.shape[0]):
        for j in range(k.shape[1]):
            blk = x[i * bR : (i + 1) * bR, j * bW : (j + 1) * bW]
            kk = int(k[i, j])
            expect = np.asarray(
                quantize_em(blk, FMT.eb + kk, FMT.mb + FMT.fx - kk), np.float32
            )
            np.testing.assert_array_equal(
                out[i * bR : (i + 1) * bR, j * bW : (j + 1) * bW], expect
            )


@settings(max_examples=60, deadline=None)
@given(e=st.integers(-12, 24), seed=st.integers(0, 2**16))
def test_prop_storage_quantize_idempotent(e, seed):
    """quantize -> pack is a projection: a second storage round-trip changes
    nothing (operands bounded away from the round-up-past-max-normal corner,
    where one pack may legitimately overflow to inf — the reason every
    storage path applies exactly ONE pack per boundary)."""
    rng = np.random.default_rng(seed)
    x = (rng.uniform(-0.99, 0.99, 32) * 2.0**e).astype(np.float32)
    once = np.asarray(storage_quantize(x, FMT), np.float32)
    twice = np.asarray(storage_quantize(once, FMT), np.float32)
    np.testing.assert_array_equal(once, twice)


class TestPytree:
    def test_registered_node_survives_jit_and_vmap(self):
        x = np.linspace(-3.0, 3.0, 32, dtype=np.float32)
        pa = pack_array(x, FMT)
        out = jax.jit(lambda p: p)(pa)
        assert isinstance(out, PackedArray)
        np.testing.assert_array_equal(
            np.asarray(unpack_array(out)), np.asarray(unpack_array(pa))
        )
        stacked = jax.tree_util.tree_map(lambda a: jnp.stack([a, a]), pa)
        sliced = jax.tree_util.tree_map(lambda a: a[1], stacked)
        np.testing.assert_array_equal(
            np.asarray(unpack_array(sliced)), np.asarray(unpack_array(pa))
        )

    def test_with_view_round_trips_shapes(self):
        x = np.arange(24, dtype=np.float32).reshape(4, 6) / 7.0
        pa = pack_array(x, FMT)
        flat = pa.with_view((1, 24))
        back = flat.with_view((4, 6))
        np.testing.assert_array_equal(
            np.asarray(unpack_array(back)), np.asarray(unpack_array(pa))
        )

    def test_nbytes_halves_f32(self):
        state = {"u": np.ones((64, 64), np.float32)}
        packed = pack_state(state, FMT)
        assert is_packed(packed) and not is_packed(state)
        assert state_nbytes(packed) < 0.6 * state_nbytes(state)


# -------------------------------------------------------- solver integration


@pytest.mark.parametrize("name", sorted(known_steppers()))
def test_fused_packed_bit_identical_to_quantized(name):
    """The acceptance criterion: packed-state fused runs are bit-identical
    to the f32-carried quantized runs at the same carried splits, on every
    registered stepper (in-kernel packing on the sweep steppers, XLA-boundary
    packing on SWE)."""
    cfg = _small_cfg(name)
    prec = PrecisionConfig(mode="rr_tracked", fmt=FMT)
    steps, every = 8, 4
    runs = {}
    for storage in ("packed", "quantized"):
        sim = Simulation(name, cfg, prec)
        runs[storage] = sim.run(
            steps, snapshot_every=every, execution="fused", storage=storage
        )
    final_p = unpack_state(runs["packed"].state)
    fp, fq = jax.tree_util.tree_leaves(final_p), jax.tree_util.tree_leaves(
        runs["quantized"].state
    )
    for a, b in zip(fp, fq):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(runs["packed"].snapshots), np.asarray(runs["quantized"].snapshots)
    )


def test_reference_plane_packed_matches_quantized():
    cfg = _small_cfg("heat1d")
    prec = PrecisionConfig(mode="rr_tile", fmt=FMT)
    runs = {
        storage: Simulation("heat1d", cfg, prec).run(
            8, snapshot_every=4, execution="reference", storage=storage
        )
        for storage in ("packed", "quantized")
    }
    np.testing.assert_array_equal(
        np.asarray(unpack_state(runs["packed"].state)),
        np.asarray(runs["quantized"].state),
    )
    np.testing.assert_array_equal(
        np.asarray(runs["packed"].snapshots), np.asarray(runs["quantized"].snapshots)
    )


def test_packed_ensemble_carries_packed_state():
    cfg = _small_cfg("heat1d")
    prec = PrecisionConfig(mode="rr_tracked", fmt=FMT)
    sim = Simulation("heat1d", cfg, prec)
    state0 = sim.stepper.init_state(cfg)
    batch = jax.tree_util.tree_map(
        lambda x: jnp.stack([x, 0.5 * x, 2.0 * x]), state0
    )
    res = sim.run_ensemble(batch, 8, snapshot_every=4, storage="packed")
    assert is_packed(res.state)
    member = jax.tree_util.tree_map(lambda x: x[1], res.state)
    solo0 = jax.tree_util.tree_map(lambda x: 0.5 * x, state0)
    solo = sim.run(8, snapshot_every=4, state0=solo0, storage="packed")
    np.testing.assert_array_equal(
        np.asarray(unpack_state(member)), np.asarray(unpack_state(solo.state))
    )


# --------------------------------------------------------------- service leg


def test_service_buckets_separate_by_storage():
    from repro.service.request import SimRequest, resolve_request

    r_f32 = resolve_request(1, SimRequest("heat1d", 8, precision="rr_tracked"))
    r_pk = resolve_request(
        2, SimRequest("heat1d", 8, precision="rr_tracked", storage="packed")
    )
    assert r_f32.key != r_pk.key
    assert r_pk.key.storage == "packed"
    assert r_pk.key.short().endswith("/packed")
    assert "/f32" not in r_f32.key.short()  # f32 keys keep the legacy label

    with pytest.raises(ValueError):
        resolve_request(3, SimRequest("heat1d", 8, storage="zstd"))


def test_service_evict_resume_packed_parity():
    """A packed member evicted through repro.ckpt and resumed finishes with
    state + snapshots bit-identical to a solo packed run."""
    from repro.service.request import SimRequest
    from repro.service.scheduler import ServiceConfig, SimService

    with tempfile.TemporaryDirectory() as td:
        svc = SimService(ServiceConfig(ckpt_dir=td))
        h = svc.submit(
            SimRequest(
                "heat1d", 12, precision="rr_tracked", snapshot_every=4,
                storage="packed",
            )
        )
        rid = h.id
        svc._fill()
        svc.pump()  # one chunk in
        rec = svc._requests[rid]
        assert is_packed(rec.state)
        svc.evict(rid)
        assert rec.status == "evicted"
        assert is_packed(rec.templates["state"])  # templates keep the treedef
        svc.resume(rid)
        svc.run_until_idle()
        result = rec.result
        assert result is not None and is_packed(result.state)

        sim = Simulation("heat1d", None, PrecisionConfig(mode="rr_tracked", fmt=FMT))
        solo = sim.run(
            12, snapshot_every=4, execution=rec.key.execution, storage="packed"
        )
        np.testing.assert_array_equal(
            np.asarray(unpack_state(result.state)),
            np.asarray(unpack_state(solo.state)),
        )
        solo_snaps = np.asarray(solo.snapshots)
        for i, snap in enumerate(result.snapshots):
            np.testing.assert_array_equal(np.asarray(snap), solo_snaps[i])
