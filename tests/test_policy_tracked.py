"""RangeTracker (rr_tracked / deploy modes): the paper's precision adjust
unit as cross-step training state — grows on range spikes, shrinks on
persistent redundancy, and trains a real model end-to-end."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FlexFormat, PrecisionConfig, rr_einsum, tracker_init


def test_tracker_grows_then_shrinks():
    cfg = PrecisionConfig(mode="rr_tracked", fmt=FlexFormat(3, 9, 3), ema=0.5)
    tr = tracker_init(1, cfg.fmt, k0=0)
    rng = np.random.default_rng(0)
    w = rng.normal(0, 1.0, (64, 64)).astype(np.float32)

    # range spike: operands ~1e4 -> product exp ~28 -> k must jump to 3
    x_big = (1e4 * rng.normal(0, 1, (8, 64))).astype(np.float32)
    _, tr = rr_einsum("md,df->mf", x_big, w, cfg, tracker=tr, site=0)
    assert int(tr.k[0]) == 3
    grew_at = int(tr.overflow_steps[0])
    assert grew_at >= 0  # k0=0 -> first update may grow immediately

    # sustained narrow range: EMA decays, k shrinks back
    x_small = rng.normal(0, 1, (8, 64)).astype(np.float32)
    for _ in range(40):
        _, tr = rr_einsum("md,df->mf", x_small, w, cfg, tracker=tr, site=0)
    assert int(tr.k[0]) < 3
    assert int(tr.shrink_steps[0]) >= 1


def test_tracked_training_step_threads_state():
    """A minimal train loop threading tracker state like RNG state."""
    from repro.core import tracker_k

    cfg = PrecisionConfig(mode="rr_tracked", fmt=FlexFormat(3, 9, 3))
    key = jax.random.PRNGKey(0)
    w1 = jax.random.normal(key, (32, 64)) * 0.1
    w2 = jax.random.normal(key, (64, 8)) * 0.1
    tr = tracker_init(2, cfg.fmt)

    @jax.jit
    def step(params, tr, x, y):
        def loss_fn(params, tr):
            h, tr = rr_einsum("md,df->mf", x, params[0], cfg, tracker=tr, site=0)
            h = jax.nn.relu(h)
            out, tr = rr_einsum("mf,fo->mo", h, params[1], cfg, tracker=tr, site=1)
            return jnp.mean((out - y) ** 2), tr

        (l, tr), g = jax.value_and_grad(loss_fn, has_aux=True)(params, tr)
        params = jax.tree_util.tree_map(lambda p, gg: p - 0.05 * gg, params, g)
        return params, tr, l

    params = (w1, w2)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
    w_true = jax.random.normal(jax.random.PRNGKey(3), (32, 8)) * 0.3
    y = x @ w_true  # learnable teacher target
    losses = []
    for _ in range(60):
        params, tr, l = step(params, tr, x, y)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.5
    assert bool(jnp.all((tr.k >= 0) & (tr.k <= cfg.fmt.fx)))
