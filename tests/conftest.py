"""Shared test config: gate modules whose optional deps are absent.

``hypothesis`` is not part of the baked runtime image; the two property-test
modules that use it are skipped (not failed) when it is missing so the tier-1
suite stays runnable everywhere. tests/test_precision_engine.py carries a
hypothesis-free pack/unpack property sweep covering the same surface.
"""

collect_ignore = []

try:
    import hypothesis  # noqa: F401
except ImportError:
    collect_ignore += ["test_flexformat.py", "test_r2f2.py"]
