"""Shared test config: property-test backend selection + example budgets.

The bit-level property modules (test_flexformat, test_r2f2, test_alu,
test_pack) are written against the hypothesis API. The baked runtime image
does not ship hypothesis and the repo installs nothing, so when the real
package is absent we install ``tests/_hypothesis_stub.py`` (same API
surface: kwargs-``given``, ``settings``, ``floats``/``integers``
strategies; deterministic, edge-first, bounded) as ``sys.modules
["hypothesis"]`` before collection. Either way the per-test example count
is capped by ``REPRO_HYPOTHESIS_EXAMPLES`` (default 50) so the CI fast
tier's property pass stays inside its time budget; set it higher locally
for a deeper sweep.
"""

import os
import sys

collect_ignore = []

try:
    import hypothesis

    _BUDGET = int(os.environ.get("REPRO_HYPOTHESIS_EXAMPLES", "50"))
    hypothesis.settings.register_profile(
        "repro_ci", max_examples=_BUDGET, deadline=None
    )
    hypothesis.settings.load_profile("repro_ci")
except ImportError:
    import importlib.util

    _path = os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _stub = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _stub
    _spec.loader.exec_module(_stub)
