"""repro.obs.health — the live numerics-health monitoring plane (DESIGN.md §16).

Covers the ISSUE-10 contract: the histogram-quantile estimator against
known bucket layouts, detector math (overflow-storm grow rates, k-thrash
reversals, coverage floor) with fire-once semantics, alert DETERMINISM
(same telemetry stream -> same alert sequence, offline replay == the live
monitor's incremental sweep, no wall-clock dependence), SLO rising-edge
evaluation, the bounded flight recorder's ring + dump round-trip, the
deterministic shadow sampler and rel-L2 drift metric, PASSIVITY (served
request states/snapshots/tracker bits identical with health enabled vs
disabled on heat1d + swe2d across all three execution planes), an
in-process overflow storm from a starved pinned policy, and the fleet
reporter's graceful degradation on partial artifacts."""

import dataclasses
import json
import math
import os

import jax
import numpy as np
import pytest

import repro.obs as obs
import repro.obs.health as health
from repro.core.policy import PRESETS
from repro.obs.__main__ import report_trace, run_report
from repro.obs.flightrec import FlightRecorder, load_flightrec
from repro.obs.health import (
    Alert,
    HealthConfig,
    HealthMonitor,
    SLORule,
    detect_series,
    run_detectors,
)
from repro.obs.metrics import MetricsRegistry, histogram_quantile
from repro.obs.precision import PrecisionTelemetry, SiteSeries
from repro.obs.server import _sanitize
from repro.obs.shadow import ShadowSampler, nonfinite_fraction, rel_l2
from repro.obs.trace import Tracer
from repro.service import ServiceConfig, SimRequest, SimService, scaled_state0

TRACKED = dataclasses.replace(PRESETS["r2f2_16"], mode="rr_tracked")

#: small grids for the served passivity matrix (mirrors tests/test_obs.py)
SMALL_OV = {
    "heat1d": {"nx": 64},
    "swe2d": {"nx": 32, "ny": 32},
}


@pytest.fixture(autouse=True)
def _health_off():
    """Every test starts and ends with the monitor and obs disabled."""
    health.disable()
    obs.disable()
    yield
    health.disable()
    obs.disable()


def assert_bits_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype
    if a.dtype == np.float32:
        np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))
    else:
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# histogram quantile: known bucket layouts
# ---------------------------------------------------------------------------


class TestHistogramQuantile:
    # 100 observations: 25 in (0, .1], 25 in (.1, .25], 50 in (.25, .5]
    BUCKETS = [(0.1, 25), (0.25, 50), (0.5, 100)]

    def test_interpolation_inside_buckets(self):
        assert histogram_quantile(0.10, self.BUCKETS, 100) == pytest.approx(0.04)
        assert histogram_quantile(0.25, self.BUCKETS, 100) == pytest.approx(0.1)
        assert histogram_quantile(0.50, self.BUCKETS, 100) == pytest.approx(0.25)
        assert histogram_quantile(0.75, self.BUCKETS, 100) == pytest.approx(0.375)
        assert histogram_quantile(1.00, self.BUCKETS, 100) == pytest.approx(0.5)

    def test_rank_past_last_finite_bucket_clamps(self):
        # 5 of 10 observations landed past every finite bound (+Inf bucket):
        # the estimate never invents mass above the largest finite le
        assert histogram_quantile(0.9, [(0.1, 5)], 10) == pytest.approx(0.1)

    def test_no_data_and_bad_q_are_nan(self):
        assert math.isnan(histogram_quantile(0.5, [], 0))
        assert math.isnan(histogram_quantile(0.5, [(1.0, 0)], 0))
        assert math.isnan(histogram_quantile(-0.1, self.BUCKETS, 100))
        assert math.isnan(histogram_quantile(1.1, self.BUCKETS, 100))

    def test_histogram_method_matches_module_function(self):
        h = MetricsRegistry().histogram("h", "", buckets=(0.1, 0.25, 0.5))
        for v in [0.05] * 25 + [0.2] * 25 + [0.4] * 50:
            h.observe(v, plane="a")
        snap = h.snapshot(plane="a")
        for q in (0.1, 0.5, 0.9):
            assert h.quantile(q, plane="a") == pytest.approx(
                histogram_quantile(q, snap["buckets"], snap["count"])
            )

    def test_aggregate_quantile_merges_label_sets(self):
        h = MetricsRegistry().histogram("h", "", buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.5):
            h.observe(v, plane="a")
        for v in (3.0, 3.5):
            h.observe(v, plane="b")
        # merged cumulative counts: [(1,1), (2,2), (4,4)], count 4
        assert h.quantile(0.5) == pytest.approx(
            histogram_quantile(0.5, [(1.0, 1), (2.0, 2), (4.0, 4)], 4)
        )
        assert math.isnan(h.quantile(0.5, plane="missing"))


# ---------------------------------------------------------------------------
# detectors: math + fire-once + determinism
# ---------------------------------------------------------------------------


def _series(scope="svc", site="site", steps=(), k=(), grew=(), shrank=(),
            coverage=None):
    return SiteSeries.from_dict({
        "scope": scope, "site": site, "steps": list(steps), "k": list(k),
        "grew": list(grew), "shrank": list(shrank), "coverage": coverage,
    })


class TestDetectors:
    CFG = HealthConfig(window=8, grow_rate=0.25, grow_min_events=4,
                       thrash_reversals=3, coverage_min=0.9)

    def test_overflow_storm_on_grow_rate(self):
        # cumulative §5.3 grow counters: 8 grow events over 32 steps = the
        # 0.25 threshold, first reached at the step-32 boundary
        s = _series(steps=[8, 16, 24, 32], k=[2, 2, 2, 2],
                    grew=[0, 0, 4, 8], shrank=[0, 0, 0, 0])
        alerts = detect_series(s, self.CFG)
        assert [a.kind for a in alerts] == ["overflow_storm"]
        assert alerts[0].step == 32
        assert alerts[0].detail["signal"] == "grow_rate"
        assert alerts[0].detail["rate"] == pytest.approx(8 / 32)

    def test_storm_needs_minimum_events(self):
        # 1 grow in 4 steps is a 0.25 *rate* but only one event — silence
        s = _series(steps=[4], k=[2], grew=[1], shrank=[0])
        assert detect_series(s, self.CFG) == []

    def test_storm_fires_once_per_series(self):
        s = _series(steps=[8, 16, 24, 32, 40, 48], k=[2] * 6,
                    grew=[0, 0, 4, 8, 12, 16], shrank=[0] * 6)
        kinds = [a.kind for a in detect_series(s, self.CFG)]
        assert kinds == ["overflow_storm"]

    def test_k_thrash_on_reversals(self):
        # k 3->2->3->2->3: three direction reversals inside one window
        s = _series(steps=[8, 16, 24, 32, 40], k=[3, 2, 3, 2, 3],
                    grew=[0, 0, 1, 1, 2], shrank=[0, 1, 1, 2, 2])
        alerts = detect_series(s, self.CFG)
        assert [a.kind for a in alerts] == ["k_thrash"]
        assert alerts[0].detail["reversals"] == 3

    def test_monotone_k_never_thrashes(self):
        s = _series(steps=[8, 16, 24, 32], k=[0, 1, 2, 3],
                    grew=[1, 2, 3, 4], shrank=[0, 0, 0, 0])
        assert all(a.kind != "k_thrash" for a in detect_series(s, self.CFG))

    def test_coverage_drop_below_floor(self):
        s = _series(steps=[8, 16], k=[2, 2], grew=[0, 0], shrank=[0, 0],
                    coverage=0.5)
        alerts = detect_series(s, self.CFG)
        assert [a.kind for a in alerts] == ["coverage_drop"]
        assert alerts[0].step == 16
        ok = _series(steps=[8, 16], k=[2, 2], grew=[0, 0], shrank=[0, 0],
                     coverage=0.95)
        assert detect_series(ok, self.CFG) == []

    def test_same_stream_same_alert_sequence(self):
        """The whole determinism contract: replaying the identical telemetry
        gives the identical alert list (steps, kinds, details — no wall
        clock anywhere in a detector)."""
        tel = PrecisionTelemetry()
        for site, ks, gs in (
            ("a", [3, 2, 3, 2, 3], [0, 0, 1, 1, 2]),
            ("b", [2, 2, 2, 2, 2], [0, 4, 8, 12, 16]),
        ):
            s = tel.series("svc", site)
            for i, (k, g) in enumerate(zip(ks, gs)):
                s.append((i + 1) * 8, k, g, 0)
        first = run_detectors(tel, self.CFG)
        second = run_detectors(tel, self.CFG)
        assert first == second
        assert [a.kind for a in first] == ["k_thrash", "overflow_storm"]

    def test_live_sweep_equals_offline_replay(self, tmp_path):
        """The live monitor emits incrementally (suffix per sweep) as the
        stream grows; the accumulated sequence must equal one offline pass
        over the final stream — however the chunking falls."""
        cfg = dataclasses.replace(self.CFG, flight_dir=str(tmp_path))
        ks = [3, 2, 3, 2, 3, 3, 3]
        gs = [0, 0, 1, 1, 2, 6, 14]  # 14 grow events over 56 steps: rate 0.25

        def live(chunking):
            obs.enable(sample=0.0)
            mon = HealthMonitor(cfg)
            s = obs.active().telemetry.series("svc", "a")
            i = 0
            for n in chunking:
                for _ in range(n):
                    s.append((i + 1) * 8, ks[i], gs[i], 0)
                    i += 1
                mon.sweep()
            got = list(mon.alerts)
            obs.disable()
            return got

        offline = PrecisionTelemetry()
        sr = offline.series("svc", "a")
        for i, (k, g) in enumerate(zip(ks, gs)):
            sr.append((i + 1) * 8, k, g, 0)
        expected = run_detectors(offline, self.CFG)
        assert [a.kind for a in expected] == ["k_thrash", "overflow_storm"]
        # one sample per sweep, everything in one sweep, uneven chunks:
        # the emitted sequence never depends on where the sweeps landed
        assert live([1] * 7) == expected
        assert live([7]) == expected
        assert live([2, 3, 2]) == expected


# ---------------------------------------------------------------------------
# SLO rules: schema + rising-edge evaluation
# ---------------------------------------------------------------------------


class _Key:
    def short(self):
        return "bucket"


class TestSLORules:
    def test_op_validation(self):
        with pytest.raises(ValueError):
            SLORule("x", "queue_depth", "<", 1.0)
        with pytest.raises(ValueError):
            SLORule("x", "queue_depth", "<=", 1.0, window=0)

    def test_ok_directions_and_nan(self):
        lo = SLORule("lo", "m", "<=", 2.0)
        hi = SLORule("hi", "m", ">=", 2.0)
        assert lo.ok(2.0) and not lo.ok(2.1)
        assert hi.ok(2.0) and not hi.ok(1.9)
        assert lo.ok(float("nan")) and hi.ok(float("nan"))  # no data, no breach

    def test_round_trips_through_dict(self):
        r = SLORule("q", "queue_depth", "<=", 4.0, window=16)
        assert SLORule.from_dict(r.to_dict()) == r

    def test_breach_fires_on_rising_edge_only(self, tmp_path):
        obs.enable(sample=0.0)
        mon = HealthMonitor(HealthConfig(
            slos=(SLORule("queue", "queue_depth", "<=", 2.0),),
            flight_dir=str(tmp_path),
        ))
        key = _Key()
        mon.note_occupancy(queued=5, active=0)
        mon.on_chunk(key, 1, 8, 1e-4, compiled=False)
        mon.on_chunk(key, 1, 8, 1e-4, compiled=False)  # still breached: no dup
        assert [a.kind for a in mon.alerts] == ["slo_breach"]
        assert mon.alerts[0].scope == "queue"
        mon.note_occupancy(queued=0, active=0)
        mon.on_chunk(key, 1, 8, 1e-4, compiled=False)  # recovers
        mon.note_occupancy(queued=9, active=0)
        mon.on_chunk(key, 1, 8, 1e-4, compiled=False)  # breaches again
        assert [a.kind for a in mon.alerts] == ["slo_breach", "slo_breach"]
        assert mon.verdict()["slo"]["queue"]["ok"] is False

    def test_latency_slo_reads_the_bucket_quantile(self, tmp_path):
        obs.enable(sample=0.0)
        mon = HealthMonitor(HealthConfig(
            slos=(SLORule("lat", "chunk_latency_p99_us", "<=", 1.0),),
            flight_dir=str(tmp_path),
        ))
        hist = obs.active().registry.histogram(
            "repro_service_chunk_latency_seconds"
        )
        hist.observe(0.5)  # 0.5 s >> 1 µs threshold
        mon.on_chunk(_Key(), 1, 8, 0.5, compiled=False)
        assert [a.kind for a in mon.alerts] == ["slo_breach"]
        assert mon.alerts[0].detail["value"] == pytest.approx(
            hist.quantile(0.99) * 1e6
        )


# ---------------------------------------------------------------------------
# flight recorder: bounded ring + dump round-trip
# ---------------------------------------------------------------------------


class TestFlightRecorder:
    def test_ring_keeps_the_tail_with_monotone_seq(self):
        fr = FlightRecorder(capacity=4)
        for i in range(10):
            fr.record("ev", i=i)
        assert fr.recorded == 10
        events = fr.events()
        assert len(events) == 4
        assert [e["i"] for e in events] == [6, 7, 8, 9]
        seqs = [e["seq"] for e in events]
        assert seqs == sorted(seqs) and len(set(seqs)) == 4

    def test_dump_load_round_trip(self, tmp_path):
        fr = FlightRecorder(capacity=8)
        fr.record("submit", request=1)
        fr.record("alert", alert={"kind": "overflow_storm"})
        path = fr.dump(str(tmp_path), "overflow_storm",
                       metrics={"m": 1}, verdict={"status": "alerting"})
        assert os.path.basename(path).endswith("-overflow_storm.json")
        doc = load_flightrec(path)
        assert doc["reason"] == "overflow_storm"
        assert doc["recorded"] == 2
        assert [e["kind"] for e in doc["events"]] == ["submit", "alert"]
        assert doc["metrics"] == {"m": 1}
        assert doc["verdict"]["status"] == "alerting"

    def test_load_rejects_bad_schema_and_seq(self, tmp_path):
        fr = FlightRecorder(capacity=8)
        fr.record("ev")
        path = fr.dump(str(tmp_path), "ok")
        doc = json.load(open(path))
        doc["schema"] = "bogus@9"
        bad1 = tmp_path / "bad_schema.json"
        bad1.write_text(json.dumps(doc))
        with pytest.raises(ValueError):
            load_flightrec(str(bad1))
        doc = json.load(open(path))
        doc["events"] = [{"seq": 2, "kind": "a"}, {"seq": 1, "kind": "b"}]
        bad2 = tmp_path / "bad_seq.json"
        bad2.write_text(json.dumps(doc))
        with pytest.raises(ValueError):
            load_flightrec(str(bad2))


# ---------------------------------------------------------------------------
# shadow sampling: deterministic picks, drift metric
# ---------------------------------------------------------------------------


class TestShadow:
    def test_sampler_follows_the_floor_rule(self):
        s = ShadowSampler(0.3)
        picks = [s.pick() for _ in range(20)]
        expected = [
            math.floor((n + 1) * 0.3) > math.floor(n * 0.3) for n in range(20)
        ]
        assert picks == expected
        assert sum(picks) == 6  # exactly the rate over the long run

    def test_sampler_is_replayable_and_bounded(self):
        sa, sb = ShadowSampler(0.5), ShadowSampler(0.5)
        assert [sa.pick() for _ in range(10)] == [sb.pick() for _ in range(10)]
        never, always = ShadowSampler(0.0), ShadowSampler(1.0)
        assert not any(never.pick() for _ in range(10))
        assert all(always.pick() for _ in range(10))
        with pytest.raises(ValueError):
            ShadowSampler(1.5)

    def test_rel_l2_known_values(self):
        b = {"u": np.array([3.0, 4.0], np.float32)}
        assert rel_l2(b, b) == 0.0
        a = {"u": np.array([3.0, 4.0 + 5.0], np.float32)}
        assert rel_l2(a, b) == pytest.approx(1.0)  # |err|=5 over |ref|=5

    def test_rel_l2_offset_removes_the_baseline(self):
        # a resting-depth style additive baseline must not dilute the drift
        base = np.array([10.0, 10.0], np.float64)
        a, b = {"h": base + [0.0, 2.0]}, {"h": base + [0.0, 1.0]}
        assert rel_l2(a, b, offset=10.0) == pytest.approx(1.0)

    def test_rel_l2_nonfinite_is_inf(self):
        b = {"u": np.array([1.0, 2.0], np.float32)}
        a = {"u": np.array([1.0, np.inf], np.float32)}
        assert rel_l2(a, b) == float("inf")
        assert rel_l2(b, a) == float("inf")

    def test_nonfinite_fraction_floats_only(self):
        tree = {
            "u": np.array([1.0, np.nan, np.inf, 4.0], np.float32),
            "k": np.array([1, 2, 3, 4], np.int32),  # ints never count
        }
        assert nonfinite_fraction(tree) == pytest.approx(0.5)
        assert nonfinite_fraction({"k": np.arange(3)}) == 0.0


# ---------------------------------------------------------------------------
# passivity: served bits identical with health enabled vs disabled
# ---------------------------------------------------------------------------


def _serve(name, execution, tmp_path=None, shadowed=False):
    if shadowed:
        obs.enable(sample=1.0)
        health.enable(shadow_rate=1.0, flight_dir=str(tmp_path))
    svc = SimService(ServiceConfig(max_bucket=4))
    ov = SMALL_OV[name]
    handles = [
        svc.submit(SimRequest(
            name, steps=16, precision=TRACKED, overrides=ov,
            snapshot_every=8, execution=execution,
            state0=scaled_state0(name, 0.6 + 0.2 * i, overrides=ov),
        ))
        for i in range(2)
    ]
    svc.run_until_idle()
    results = [h.result() for h in handles]
    monitor = health.active()
    health.disable()
    obs.disable()
    return results, monitor


class TestServicePassivity:
    @pytest.mark.parametrize("name", ["heat1d", "swe2d"])
    @pytest.mark.parametrize("execution", ["reference", "fused", "megakernel"])
    def test_served_bits_identical_under_health(self, name, execution, tmp_path):
        base, _ = _serve(name, execution)
        inst, monitor = _serve(name, execution, tmp_path, shadowed=True)
        # health really was live: every request shadow-replayed, none alerted
        assert len(monitor.shadow_rel) == 2
        assert monitor.alerts == []
        assert all(rel <= monitor.config.err_budget
                   for rel in monitor.shadow_rel.values())
        for b, i in zip(base, inst):
            jax.tree_util.tree_map(assert_bits_equal, b.state, i.state)
            assert b.snapshot_steps == i.snapshot_steps
            for sb, si in zip(b.snapshots, i.snapshots):
                jax.tree_util.tree_map(assert_bits_equal, sb, si)
            np.testing.assert_array_equal(
                np.asarray(b.tracker.state.k), np.asarray(i.tracker.state.k)
            )
            np.testing.assert_array_equal(
                np.asarray(b.tracker.state.overflow_steps),
                np.asarray(i.tracker.state.overflow_steps),
            )
            assert b.final_k == i.final_k


# ---------------------------------------------------------------------------
# the induced storm: starved pinned policy vs hot traffic
# ---------------------------------------------------------------------------


class TestOverflowStorm:
    def test_starved_pinned_policy_fires_and_dumps(self, tmp_path):
        obs.enable(sample=1.0)
        monitor = health.enable(flight_dir=str(tmp_path))
        svc = SimService()
        handles = health._storm_burst(svc, members=1)
        svc.run_until_idle()
        health.disable()
        obs.disable()

        assert all(h.status == "done" for h in handles)  # overflow, not crash
        storms = [a for a in monitor.alerts if a.kind == "overflow_storm"]
        assert storms, "starved pinned policy must raise an overflow storm"
        assert storms[0].detail["signal"] == "nonfinite"
        assert storms[0].detail["fraction"] > 0
        assert monitor.verdict()["status"] == "alerting"
        assert monitor.dump_paths
        doc = load_flightrec(monitor.dump_paths[0])
        assert doc["reason"] == "overflow_storm"
        kinds = {e["kind"] for e in doc["events"]}
        assert "alert" in kinds and "submit" in kinds


# ---------------------------------------------------------------------------
# verdict JSON + reporter degradation (satellite coverage)
# ---------------------------------------------------------------------------


class TestVerdictAndReporter:
    def test_verdict_sanitizes_to_strict_json(self, tmp_path):
        obs.enable(sample=0.0)
        mon = HealthMonitor(HealthConfig(flight_dir=str(tmp_path)))
        v = _sanitize(mon.verdict())  # burn is NaN with no shadow data yet
        text = json.dumps(v, allow_nan=False)  # must not raise
        assert json.loads(text)["status"] == "ok"
        assert json.loads(text)["shadow"]["burn"] is None

    def test_reporter_degrades_on_partial_artifacts(self, tmp_path, capsys):
        reg = MetricsRegistry()
        reg.counter("repro_x_total", "x").inc()
        reg.save(prom_path=str(tmp_path / "metrics.prom"))
        (tmp_path / "telemetry.json").write_text("{not json")
        assert run_report(str(tmp_path), top=5) == 0
        out = capsys.readouterr().out
        assert "telemetry.json: unreadable" in out
        assert "trace.json: not found" in out
        assert "repro_x_total" in out

    def test_reporter_fails_with_nothing_loadable(self, tmp_path):
        (tmp_path / "trace.json").write_text("{not json")
        assert run_report(str(tmp_path), top=5) == 1

    def test_reporter_surfaces_dropped_spans(self, tmp_path):
        tr = Tracer(sample=1.0, capacity=2)
        for i in range(5):
            with tr.span(f"s{i}"):
                pass
        path = tr.save(str(tmp_path / "trace.json"))
        lines = "\n".join(report_trace(path))
        assert "3 dropped past capacity" in lines
        assert "WARNING" in lines
