"""The megakernel execution plane (DESIGN.md §14): whole-horizon runs in
ONE ``pallas_call`` with the adjust unit evolving on-chip. Per-stepper
bit-parity against the chunked fused plane across the mode ladder (overflow
workloads produce NaNs, so parity is checked on raw f32 BIT patterns),
tracked-mode final splits and §5.3 counters, capture-stream parity, packed
carried storage, single-launch program structure, dispatch/fallback, and
the scalar adjust-unit law itself."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import PRESETS, adjust_step, tracker_init, tracker_observe
from repro.pack import unpack_state
from repro.pde import Simulation, Stepper, get_stepper
from repro.pde.advection1d import AdvectionConfig
from repro.pde.burgers1d import BurgersConfig, initial_wave
from repro.pde.heat1d import HeatConfig
from repro.pde.heat2d import Heat2DConfig
from repro.pde.swe2d import SWEConfig
from repro.precision import mega_eligible

TRACKED = dataclasses.replace(PRESETS["r2f2_16"], mode="rr_tracked")
BUILTINS = ("advection1d", "burgers1d", "heat1d", "heat2d", "swe2d")

SMALL = {
    "heat1d": HeatConfig(nx=64),
    "heat2d": Heat2DConfig(nx=24, ny=24),
    "advection1d": AdvectionConfig(nx=128),
    "burgers1d": BurgersConfig(nx=128),
    "swe2d": SWEConfig(nx=32, ny=32),
}


def assert_bits_equal(a, b):
    """Bit-pattern equality for f32 arrays. Overflow-mode workloads (e5m10
    on a 2.5e5 field) legitimately produce NaNs on BOTH planes; ``==``
    compares NaN as unequal, so parity is asserted on the raw bits."""
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype == np.float32
    np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))


def _pair(name, prec, steps=20, every=6, **kw):
    """(chunked fused, megakernel) runs of the same horizon — steps=20,
    every=6 exercises the remainder interval (two trailing substeps)."""
    cfg = SMALL[name]
    fus = Simulation(name, cfg, prec).run(
        steps, snapshot_every=every, execution="fused", **kw
    )
    meg = Simulation(name, cfg, prec).run(
        steps, snapshot_every=every, execution="megakernel", **kw
    )
    return fus, meg


# ---------------------------------------------------------------------------
# parity: megakernel == chunked fused, per stepper, across the mode ladder
# ---------------------------------------------------------------------------


class TestMegaParity:
    @pytest.mark.parametrize("name", BUILTINS)
    @pytest.mark.parametrize("preset", ["r2f2_16", "e5m10", "bf16", "f32"])
    def test_untracked_modes_bit_exact(self, name, preset):
        """The in-kernel substep uses the same FusedOps arithmetic and the
        same boundary storage rounding as the chunked plane, so states and
        snapshots must agree bit for bit — NaN patterns included."""
        fus, meg = _pair(name, PRESETS[preset])
        assert_bits_equal(fus.state, meg.state)
        assert_bits_equal(fus.snapshots, meg.snapshots)
        assert meg.tracker is None

    @pytest.mark.parametrize("name", BUILTINS)
    def test_rr_tracked_bit_exact_with_identical_counters(self, name):
        """The tentpole's parity contract: the on-chip adjust unit ticks
        every substep but the datapath floor latches only at snapshot
        boundaries (the chunked plane's fold cadence), so rr_tracked is
        bit-exact AND the final per-site splits, EMAs, and §5.3 counters
        are identical — not merely close."""
        fus, meg = _pair(name, TRACKED)
        assert_bits_equal(fus.state, meg.state)
        assert_bits_equal(fus.snapshots, meg.snapshots)
        for field in ("k", "hi_ema", "lo_ema", "overflow_steps", "shrink_steps"):
            np.testing.assert_array_equal(
                np.asarray(getattr(fus.tracker.state, field)),
                np.asarray(getattr(meg.tracker.state, field)),
                err_msg=f"{name}: tracker.{field} diverged",
            )

    @pytest.mark.parametrize("name", BUILTINS)
    def test_deploy_bit_exact_including_tracker(self, name):
        """deploy (bf16 datapath, shadow tracker) evolves its tracker
        on-chip too; arithmetic is split-independent so everything matches."""
        fus, meg = _pair(name, PRESETS["deploy"])
        assert_bits_equal(fus.state, meg.state)
        np.testing.assert_array_equal(
            np.asarray(fus.tracker.state.k), np.asarray(meg.tracker.state.k)
        )

    def test_tracked_mega_resumes(self):
        """Two chained megakernel runs == one long one: the tracker rows
        streamed out of the kernel are the same resumable adjust-unit state."""
        sim = Simulation("burgers1d", SMALL["burgers1d"], TRACKED)
        a = sim.run(60, snapshot_every=15, execution="megakernel")
        b = sim.run(
            60, snapshot_every=15, state0=a.state, tracker=a.tracker,
            execution="megakernel",
        )
        long = sim.run(120, snapshot_every=15, execution="megakernel")
        assert_bits_equal(b.state, long.state)
        np.testing.assert_array_equal(
            np.asarray(b.tracker.state.k), np.asarray(long.tracker.state.k)
        )

    def test_snapshot_shapes_with_remainder(self):
        fus, meg = _pair("heat1d", PRESETS["r2f2_16"], steps=20, every=6)
        assert meg.snapshots.shape == (3, SMALL["heat1d"].nx)
        assert fus.snapshots.shape == meg.snapshots.shape


# ---------------------------------------------------------------------------
# capture: the in-kernel evidence/histogram stream matches the chunked one
# ---------------------------------------------------------------------------


class TestMegaCapture:
    def test_capture_parity_with_chunked(self):
        """With ``capture=True`` the megakernel streams the same per-substep
        site evidence and exponent histograms the chunked kernels emit."""
        fus, meg = _pair("burgers1d", TRACKED, steps=18, every=6, capture=True)
        assert meg.profile is not None
        np.testing.assert_array_equal(
            np.asarray(fus.profile.evidence), np.asarray(meg.profile.evidence)
        )
        np.testing.assert_array_equal(
            np.asarray(fus.profile.exp_time), np.asarray(meg.profile.exp_time)
        )
        np.testing.assert_array_equal(
            np.asarray(fus.profile.exp_total), np.asarray(meg.profile.exp_total)
        )

    def test_capture_evidence_shape(self):
        sim = Simulation("burgers1d", SMALL["burgers1d"], TRACKED)
        res = sim.run(12, snapshot_every=4, execution="megakernel", capture=True)
        n_sites = len(get_stepper("burgers1d").sites)
        assert res.profile.evidence.shape == (12, n_sites, 2)
        assert res.profile.exp_time.shape[0] == 3


# ---------------------------------------------------------------------------
# carried storage: quantized and packed ride the megakernel too
# ---------------------------------------------------------------------------


class TestMegaStorage:
    @pytest.mark.parametrize("storage", ["quantized", "packed"])
    def test_storage_parity_with_chunked(self, storage):
        """Boundary storage rounding happens INSIDE the kernel at each
        snapshot boundary; the carried payloads must match the chunked
        plane's pack/unpack bits exactly (heat1d exercises the packed-io
        kernel path, swe2d the host-pack path)."""
        for name in ("heat1d", "swe2d"):
            fus, meg = _pair(name, PRESETS["r2f2_16"], storage=storage)
            ffl, _ = jax.tree_util.tree_flatten(fus.state)
            mfl, tdef = jax.tree_util.tree_flatten(meg.state)
            assert len(ffl) == len(mfl)
            for fa, ma in zip(ffl, mfl):
                np.testing.assert_array_equal(np.asarray(fa), np.asarray(ma))

    def test_packed_equals_quantized_shadow(self):
        """Unpacking the packed megakernel's carried state reproduces the
        quantized run bit for bit — packing is a lossless re-encode of the
        storage-rounded field."""
        sim = Simulation("heat1d", SMALL["heat1d"], PRESETS["r2f2_16"])
        qz = sim.run(20, snapshot_every=6, execution="megakernel", storage="quantized")
        pk = sim.run(20, snapshot_every=6, execution="megakernel", storage="packed")
        assert_bits_equal(qz.state, unpack_state(pk.state))


# ---------------------------------------------------------------------------
# dispatch: eligibility, strict "megakernel", auto preference + fallback
# ---------------------------------------------------------------------------


class _NoMegaStepper(Stepper):
    sites = ("nm.mul",)

    def default_config(self):
        return None

    def init_state(self, cfg):
        return jnp.ones((16,), jnp.float32)

    def step(self, u, cfg, ops):
        return ops.mul(jnp.float32(0.5), u, "nm.mul")


class TestMegaDispatch:
    def test_shape_gate_swe(self):
        """SWE megakernel parity needs the flux grid whole-in-block; a basin
        wider than the kernel block is fused-eligible but mega-ineligible."""
        big = SWEConfig(nx=200, ny=200)
        sim = Simulation("swe2d", big, PRESETS["r2f2_16"])
        assert sim.fused_eligible() and not sim.mega_eligible()
        with pytest.raises(ValueError, match="not megakernel-eligible"):
            sim.run(4, execution="megakernel")

    def test_auto_falls_back_to_fused_on_ineligible_shape(self):
        big = SWEConfig(nx=144, ny=144)
        sim = Simulation("swe2d", big, PRESETS["r2f2_16"])
        auto = sim.run(6, snapshot_every=3, execution="auto")
        fus = sim.run(6, snapshot_every=3, execution="fused")
        assert_bits_equal(auto.state, fus.state)

    def test_no_mega_step_hook_is_ineligible(self):
        from repro.pde.registry import _STEPPERS, register_stepper

        register_stepper("test_nomega", _NoMegaStepper)
        try:
            sim = Simulation("test_nomega", None, PRESETS["r2f2_16"])
            assert not sim.mega_eligible()
            assert not mega_eligible(PRESETS["r2f2_16"], get_stepper("test_nomega"))
            with pytest.raises(ValueError, match="not megakernel-eligible"):
                sim.run(4, execution="megakernel")
        finally:
            _STEPPERS.pop("test_nomega", None)

    def test_auto_prefers_megakernel_when_eligible(self):
        sim = Simulation("heat1d", SMALL["heat1d"], PRESETS["r2f2_16"])
        assert sim.mega_eligible()
        auto = sim.run(20, snapshot_every=6, execution="auto")
        meg = sim.run(20, snapshot_every=6, execution="megakernel")
        assert_bits_equal(auto.state, meg.state)
        assert_bits_equal(auto.snapshots, meg.snapshots)


# ---------------------------------------------------------------------------
# program structure: the whole horizon really is ONE pallas_call
# ---------------------------------------------------------------------------


def _count_pallas_weighted(jaxpr) -> int:
    """Scan-weighted pallas_call count — kernel LAUNCHES at runtime, not
    call sites in the jaxpr text (mirrors benchmarks.bench_pde)."""
    n = 0
    for eqn in jaxpr.eqns:
        w = eqn.params.get("length", 1) if eqn.primitive.name == "scan" else 1
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            vals = v if isinstance(v, (list, tuple)) else (v,)
            for wv in vals:
                inner = getattr(wv, "jaxpr", wv)
                if hasattr(inner, "eqns"):
                    n += w * _count_pallas_weighted(inner)
    return n


def _horizon_launches(sim, steps, every, execution):
    state0 = sim.stepper.init_state(sim.cfg)

    def fn(s0):
        return sim.run(
            steps, snapshot_every=every, state0=s0, execution=execution
        ).state

    return _count_pallas_weighted(jax.jit(fn).trace(state0).jaxpr.jaxpr)


class TestMegaLaunches:
    def test_single_launch_per_horizon(self):
        """The tentpole claim, asserted on the traced program: 24 steps at
        every=6 is 4 launches chunked, exactly 1 on the megakernel."""
        sim = Simulation("heat1d", SMALL["heat1d"], PRESETS["r2f2_16"])
        assert _horizon_launches(sim, 24, 6, "megakernel") == 1
        assert _horizon_launches(sim, 24, 6, "fused") == 4

    def test_single_launch_with_remainder_and_tracker(self):
        sim = Simulation("burgers1d", SMALL["burgers1d"], TRACKED)
        assert _horizon_launches(sim, 20, 6, "megakernel") == 1


# ---------------------------------------------------------------------------
# ensembles
# ---------------------------------------------------------------------------


class TestMegaEnsembles:
    def test_vmapped_mega_ensemble_matches_single_runs(self):
        cfg = SMALL["burgers1d"]
        sim = Simulation("burgers1d", cfg, PRESETS["r2f2_16"])
        u0b = jnp.asarray([0.5, 1.0, 2.0], jnp.float32)[:, None] * initial_wave(cfg)[None, :]
        ens = sim.run_ensemble(u0b, 24, execution="megakernel")
        assert ens.state.shape == (3, cfg.nx)
        for i in range(3):
            single = sim.run(24, state0=u0b[i], execution="megakernel")
            assert_bits_equal(ens.state[i], single.state)


# ---------------------------------------------------------------------------
# the scalar adjust-unit law: adjust_step IS tracker_observe's kernel
# ---------------------------------------------------------------------------


class TestAdjustLaw:
    def test_adjust_step_equals_tracker_observe(self):
        """Evolving one site's scalar state through adjust_step (the form
        the megakernel runs on-chip) matches gather/scatter tracker_observe
        tick for tick — same splits, EMAs, and §5.3 counters."""
        cfg = PRESETS["r2f2_16"]
        rng = np.random.default_rng(7)
        evidence = rng.uniform(-20, 30, size=(40, 2)).astype(np.float32)

        tr = tracker_init(3, cfg.fmt)
        site = 1
        k = tr.k[site]
        hi, lo = tr.hi_ema[site], tr.lo_ema[site]
        ov, sh = tr.overflow_steps[site], tr.shrink_steps[site]
        for ae, be in evidence:
            tr = tracker_observe(tr, site, jnp.float32(ae), jnp.float32(be), cfg)
            k, hi, lo, ov, sh = adjust_step(
                k, hi, lo, ov, sh, jnp.float32(ae), jnp.float32(be), cfg
            )
        assert int(tr.k[site]) == int(k)
        np.testing.assert_allclose(float(tr.hi_ema[site]), float(hi), rtol=0, atol=0)
        np.testing.assert_allclose(float(tr.lo_ema[site]), float(lo), rtol=0, atol=0)
        assert int(tr.overflow_steps[site]) == int(ov)
        assert int(tr.shrink_steps[site]) == int(sh)

    def test_adjust_step_respects_k_bounds(self):
        cfg = PRESETS["r2f2_16"]
        fx = cfg.fmt.fx
        k, *_ = adjust_step(
            jnp.int32(0),
            jnp.float32(-100.0),
            jnp.float32(100.0),
            jnp.int32(0),
            jnp.int32(0),
            jnp.float32(30.0),  # huge demand: wants k -> fx
            jnp.float32(30.0),
            cfg,
            k_bounds=(0, 2),
        )
        assert 0 <= int(k) <= 2 < fx
