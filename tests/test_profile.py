"""The repro.profile subsystem (DESIGN.md §11): histogram capture parity
between the reference and fused execution planes, artifact round-trips and
re-deploy bit-stability under jit, autotune convergence against the live
adjust unit, the closed validation loop, and policy consumption by
Simulation and the serving path."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import PRESETS, PrecisionConfig
from repro.pde import Simulation
from repro.pde.advection1d import AdvectionConfig
from repro.pde.burgers1d import BurgersConfig
from repro.pde.heat1d import HeatConfig
from repro.pde.heat2d import Heat2DConfig
from repro.pde.swe2d import SWEConfig
from repro.profile import (
    CaptureSpec,
    PrecisionPolicy,
    capture_profile,
    synthesize_policy,
    tune_policy,
    validate_policy,
)
from repro.profile.capture import pair_exp_hist

TRACKED = dataclasses.replace(PRESETS["r2f2_16"], mode="rr_tracked")
BUILTINS = ("advection1d", "burgers1d", "heat1d", "heat2d", "swe2d")

#: small shapes (same convention as tests/test_fused.py): every default
#: kernel block covers the whole field, so the fused plane histograms the
#: exact same operand elements as the reference loop — no pad lanes
SMALL = {
    "heat1d": HeatConfig(nx=64),
    "heat2d": Heat2DConfig(nx=24, ny=24),
    "advection1d": AdvectionConfig(nx=128),
    "burgers1d": BurgersConfig(nx=128),
    "swe2d": SWEConfig(nx=32, ny=32),
}


# ---------------------------------------------------------------------------
# capture: parity between planes, non-perturbation, ensemble batching
# ---------------------------------------------------------------------------


class TestCapture:
    @pytest.mark.parametrize("name", BUILTINS)
    def test_histogram_parity_reference_vs_fused(self, name):
        """The fused kernels' widened evidence stream (binned counts summed
        across blocks and substeps) must equal the reference loop's
        elementwise binning exactly — same multiplies, same exponents —
        on every registered stepper, remainder chunk included (38 steps
        never divides the snapshot cadence evenly)."""
        ref, _ = capture_profile(name, SMALL[name], steps=38, execution="reference")
        fus, _ = capture_profile(name, SMALL[name], steps=38, execution="fused")
        np.testing.assert_array_equal(ref.evidence, fus.evidence)
        np.testing.assert_array_equal(ref.exp_time, fus.exp_time)
        np.testing.assert_array_equal(ref.exp_total, fus.exp_total)
        assert ref.exp_total.sum() > 0
        # whole intervals live in the time axis; the total also covers the
        # remainder steps, so it dominates the time-axis sum
        assert (ref.exp_total >= ref.exp_time.sum(axis=0)).all()

    def test_histogram_parity_survives_kernel_padding(self):
        """A SWE grid that does NOT divide the kernel block (139 > 128 rows
        at the staggered midpoints) pads q3 with 1.0 — a non-zero constant
        that must be masked out of the fused counts, or the profile reports
        pad lanes as data."""
        cfg = SWEConfig(nx=140, ny=32)
        ref, _ = capture_profile("swe2d", cfg, steps=2, snapshot_every=1)
        fus, _ = capture_profile(
            "swe2d", cfg, steps=2, snapshot_every=1, execution="fused"
        )
        np.testing.assert_array_equal(ref.exp_total, fus.exp_total)
        np.testing.assert_array_equal(ref.evidence, fus.evidence)

    def test_capture_does_not_perturb_the_run(self):
        """Capture is passive: a tracked run with capture on must be
        bit-identical (state, splits, counters) to the same run without."""
        base = Simulation("heat1d", SMALL["heat1d"], TRACKED).run(60)
        cap = Simulation("heat1d", SMALL["heat1d"], TRACKED).run(60, capture=True)
        np.testing.assert_array_equal(np.asarray(base.state), np.asarray(cap.state))
        np.testing.assert_array_equal(
            np.asarray(base.tracker.state.k), np.asarray(cap.tracker.state.k)
        )
        np.testing.assert_array_equal(
            np.asarray(base.tracker.state.overflow_steps),
            np.asarray(cap.tracker.state.overflow_steps),
        )
        assert base.profile is None and cap.profile is not None

    def test_counts_match_direct_binning_of_the_operands(self):
        """One step of heat1d, reference plane: the captured histograms are
        exactly the binning of the operands the stepper multiplied."""
        cfg = SMALL["heat1d"]
        spec = CaptureSpec()
        prof, _ = capture_profile("heat1d", cfg, steps=1, snapshot_every=1)
        sim = Simulation("heat1d", cfg, PRESETS["f32"])
        u = sim.stepper.init_state(cfg)
        lap = u[:-2] - 2.0 * u[1:-1] + u[2:]
        alpha = jnp.broadcast_to(jnp.float32(cfg.alpha), lap.shape)
        expected = np.asarray(pair_exp_hist(alpha, lap, spec))
        np.testing.assert_array_equal(prof.exp_total[0], expected)

    def test_zeros_and_nonfinite_are_not_counted(self):
        spec = CaptureSpec()
        x = jnp.asarray([0.0, -0.0, jnp.inf, jnp.nan, 1.0, 2.0], jnp.float32)
        from repro.profile import exp_hist

        h = np.asarray(exp_hist(x, spec))
        assert h.sum() == 2  # only 1.0 and 2.0 carry exponents
        assert h[0 - spec.e_lo] == 1 and h[1 - spec.e_lo] == 1

    def test_ensemble_capture_has_per_member_profiles(self):
        sim = Simulation("heat1d", SMALL["heat1d"], PRESETS["f32"])
        u0 = sim.stepper.init_state(sim.cfg)
        u0b = jnp.stack([u0, 0.5 * u0, 2.0 * u0])
        res = sim.run_ensemble(u0b, 24, capture=True)
        assert res.profile.exp_total.shape[0] == 3
        assert res.profile.evidence.shape[:2] == (3, 24)
        # members see different amplitudes -> different histograms
        assert not np.array_equal(
            np.asarray(res.profile.exp_total[0]), np.asarray(res.profile.exp_total[2])
        )

    def test_capture_rejects_bad_arguments(self):
        sim = Simulation("heat1d", SMALL["heat1d"], PRESETS["f32"])
        with pytest.raises(TypeError):
            sim.run(8, capture="yes")
        with pytest.raises(ValueError):
            CaptureSpec(e_lo=5, e_hi=5)


# ---------------------------------------------------------------------------
# autotune: the offline replay IS the adjust unit
# ---------------------------------------------------------------------------


class TestAutotune:
    @pytest.mark.parametrize("name,steps", [("heat1d", 200), ("burgers1d", 600)])
    def test_autotuned_k_matches_rr_tracked_converged_k(self, name, steps):
        """Profiling under rr_tracked captures exactly the evidence the live
        adjust unit consumed, and the synthesizer replays it through the
        same law — so the tuned per-site k must equal the run's converged
        final k (burgers exercises the full grow-to-FX-then-shrink drift)."""
        prof, res = capture_profile(
            name, SMALL[name], steps=steps, prec=TRACKED, execution="reference"
        )
        policy = synthesize_policy(prof, TRACKED)
        sites = res.tracker.names
        np.testing.assert_array_equal(
            policy.k_array(sites), np.asarray(res.tracker.state.k)
        )
        if name == "burgers1d":  # the drift actually happened
            assert int(np.asarray(res.tracker.state.shrink_steps).sum()) >= 1
        # §5.3 counters ride into the artifact metadata
        np.testing.assert_array_equal(
            policy.meta["adjust_counters"]["overflow_steps"],
            np.asarray(res.tracker.state.overflow_steps),
        )

    def test_hints_bracket_the_tuned_split(self):
        prof, _ = capture_profile("burgers1d", SMALL["burgers1d"], steps=200)
        policy = synthesize_policy(prof)
        for d in policy.sites.values():
            assert d["k_lo"] <= d["k"] <= d["k_hi"] <= policy.fmt.fx

    def test_report_views(self):
        prof, _ = capture_profile("heat1d", SMALL["heat1d"], steps=40)
        report = prof.report()
        for name, s in report.sites.items():
            cov = [s["coverage_at_k"][k] for k in range(prof.prec.fmt.fx + 1)]
            assert cov == sorted(cov) and cov[-1] == 1.0  # monotone, FX covers all
            assert s["exp_span"] is not None and s["values_counted"] > 0
            assert len(s["spread_over_time"]) == prof.exp_time.shape[0]
        text = report.summary()
        assert "heat.flux" in text and "heat.update" in text


# ---------------------------------------------------------------------------
# artifact: round-trip, schema gate, re-deploy bit-stability
# ---------------------------------------------------------------------------


class TestArtifact:
    def _policy(self, steps=60):
        prof, _ = capture_profile("heat1d", SMALL["heat1d"], steps=steps)
        return synthesize_policy(prof)

    def test_save_load_round_trip(self, tmp_path):
        policy = self._policy()
        path = policy.save(str(tmp_path / "p.json"))
        loaded = PrecisionPolicy.load(path)
        assert loaded.sites == policy.sites
        assert loaded.fmt == policy.fmt
        assert loaded.stepper == "heat1d"
        assert loaded.to_dict()["sites"] == policy.to_dict()["sites"]

    def test_schema_gate(self, tmp_path):
        policy = self._policy()
        d = policy.to_dict()
        bad = dict(d, schema_version=99)
        with pytest.raises(ValueError, match="schema_version"):
            PrecisionPolicy.from_dict(bad)
        with pytest.raises(ValueError, match="schema"):
            PrecisionPolicy.from_dict(dict(d, schema="something/else"))

    def test_fmt_mismatch_refused(self):
        policy = self._policy()
        other = PrecisionConfig(mode="deploy", fmt=dataclasses.replace(policy.fmt, mb=8))
        with pytest.raises(ValueError, match="fmt"):
            policy.apply(other)

    def test_redeploy_round_trip_is_bit_stable_under_jit(self, tmp_path):
        """save -> load -> deploy must reproduce the pre-save deploy run bit
        for bit, jitted or not — the artifact is the whole state."""
        cfg = SMALL["heat1d"]
        policy = self._policy()
        path = policy.save(str(tmp_path / "p.json"))
        loaded = PrecisionPolicy.load(path)
        prec = PrecisionConfig(mode="deploy", pinned=True)

        def deploy(pol, u0=None):
            sim = Simulation("heat1d", cfg, prec)
            return sim.run(40, state0=u0, policy=pol)

        a = deploy(policy)
        b = deploy(loaded)
        np.testing.assert_array_equal(np.asarray(a.state), np.asarray(b.state))
        np.testing.assert_array_equal(
            np.asarray(a.tracker.state.k), np.asarray(b.tracker.state.k)
        )

        sim = Simulation("heat1d", cfg, prec)
        u0 = sim.stepper.init_state(cfg)
        jitted = jax.jit(lambda u: deploy(loaded, u).state)
        np.testing.assert_array_equal(np.asarray(jitted(u0)), np.asarray(jitted(u0)))
        np.testing.assert_array_equal(np.asarray(jitted(u0)), np.asarray(a.state))


# ---------------------------------------------------------------------------
# the closed loop: validate, then deploy reproduces what validation saw
# ---------------------------------------------------------------------------


class TestValidationLoop:
    def test_tune_policy_end_to_end_and_deploy_reproduces(self, tmp_path):
        cfg = SMALL["heat1d"]
        _, report, policy = tune_policy("heat1d", cfg, steps=80)
        assert policy.accepted
        stamp = policy.validation
        assert stamp["rel_l2_tracked"] <= stamp["tol"]

        # a fresh pinned deploy run under the saved+reloaded artifact must
        # land on exactly the rel-L2 the validation replay recorded
        loaded = PrecisionPolicy.load(policy.save(str(tmp_path / "p.json")))
        prec = PrecisionConfig(
            mode="deploy", fmt=loaded.fmt, ema=loaded.ema, headroom=loaded.headroom,
            pinned=True,
        )
        sim = Simulation("heat1d", cfg, prec)
        res = sim.run(80, policy=loaded)
        ref = Simulation("heat1d", cfg, PRESETS["f32"]).run(80)
        num = np.linalg.norm(np.asarray(res.state, np.float64) - np.asarray(ref.state, np.float64))
        rel = num / np.linalg.norm(np.asarray(ref.state, np.float64))
        assert rel == pytest.approx(stamp["rel_l2_deploy"], rel=0, abs=1e-15)

    def test_validation_rejects_a_bad_policy(self):
        """A deliberately starved policy (k pinned to 0 on an overflowing
        workload) must fail the closed loop, not get stamped."""
        prof, _ = capture_profile("advection1d", SMALL["advection1d"], steps=40)
        policy = synthesize_policy(prof)
        for d in policy.sites.values():
            d["k"] = 0
            d["k_lo"] = 0
            d["k_hi"] = 0  # ceiling forbids the tracker from growing
        stamp = validate_policy(policy, SMALL["advection1d"], steps=40)
        assert not stamp["accepted"]
        assert not policy.accepted


# ---------------------------------------------------------------------------
# policy consumption: pinned statics, tracked clamps, serving path
# ---------------------------------------------------------------------------


class TestPolicyConsumption:
    def _policy_with(self, k, lo, hi):
        sites = {
            "heat.flux": {"k": k, "k_lo": lo, "k_hi": hi},
            "heat.update": {"k": k, "k_lo": lo, "k_hi": hi},
        }
        return PrecisionPolicy(stepper="heat1d", fmt=PRESETS["deploy"].fmt, sites=sites)

    def test_pinned_deploy_keeps_the_policy_splits_static(self):
        policy = self._policy_with(k=1, lo=0, hi=3)
        prec = PrecisionConfig(mode="deploy", pinned=True)
        res = Simulation("heat1d", SMALL["heat1d"], prec).run(40, policy=policy)
        np.testing.assert_array_equal(np.asarray(res.tracker.state.k), [1, 1])
        assert int(np.asarray(res.tracker.state.overflow_steps).sum()) == 0
        assert int(np.asarray(res.tracker.state.shrink_steps).sum()) == 0

    def test_bounds_clamp_rr_tracked_repicks(self):
        """heat1d demands k=3; a ceiling of 2 must hold the tracker at 2
        (the arithmetic still grow-retries per multiply — only the carried
        bookkeeping is clamped)."""
        policy = self._policy_with(k=2, lo=2, hi=2)
        res = Simulation("heat1d", SMALL["heat1d"], TRACKED).run(40, policy=policy)
        np.testing.assert_array_equal(np.asarray(res.tracker.state.k), [2, 2])
        free = Simulation("heat1d", SMALL["heat1d"], TRACKED).run(40)
        assert int(np.asarray(free.tracker.state.k).max()) == 3

    def test_policy_seeds_the_fused_plane_floor(self):
        """policy= works on the fused plane too: same final splits as the
        reference plane under the same policy."""
        policy = self._policy_with(k=3, lo=0, hi=3)
        ref = Simulation("heat1d", SMALL["heat1d"], TRACKED).run(40, policy=policy)
        fus = Simulation("heat1d", SMALL["heat1d"], TRACKED).run(
            40, policy=policy, execution="fused"
        )
        np.testing.assert_array_equal(
            np.asarray(ref.tracker.state.k), np.asarray(fus.tracker.state.k)
        )

    def test_pinned_is_static_on_the_fused_plane_too(self):
        """cfg.pinned must mean the SAME thing on both planes: the carried
        split is THE split, no per-block live widen. At a fixed k the
        per-tensor and per-block quantizations coincide, so pinned fused
        runs are bit-exact vs pinned reference runs."""
        policy = self._policy_with(k=2, lo=0, hi=3)
        prec = dataclasses.replace(TRACKED, pinned=True)
        ref = Simulation("heat1d", SMALL["heat1d"], prec).run(40, policy=policy)
        fus = Simulation("heat1d", SMALL["heat1d"], prec).run(
            40, policy=policy, execution="fused"
        )
        np.testing.assert_array_equal(np.asarray(ref.state), np.asarray(fus.state))
        np.testing.assert_array_equal(
            np.asarray(ref.tracker.state.k), np.asarray(fus.tracker.state.k)
        )

    def test_starved_pinned_split_fails_identically_on_both_planes(self):
        """The static gate's premise: with the retry net gone, an
        under-provisioned split must actually fault. advection1d at k=0
        (E3M12: max ~15.5 vs a 1e5 field) must blow up on BOTH planes, not
        get rescued by the fused per-block selection."""
        sites = {
            "adv.flux": {"k": 0, "k_lo": 0, "k_hi": 0},
            "adv.update": {"k": 0, "k_lo": 0, "k_hi": 0},
        }
        policy = PrecisionPolicy(
            stepper="advection1d", fmt=PRESETS["deploy"].fmt, sites=sites
        )
        prec = dataclasses.replace(TRACKED, pinned=True)
        for execution in ("reference", "fused"):
            res = Simulation("advection1d", SMALL["advection1d"], prec).run(
                8, policy=policy, execution=execution
            )
            assert not np.isfinite(np.asarray(res.state)).all(), execution

    def test_serve_resolve_policy(self, tmp_path):
        from repro.serve.decode import resolve_policy

        _, _, policy = tune_policy("heat1d", SMALL["heat1d"], steps=60)
        path = policy.save(str(tmp_path / "p.json"))
        prec, loaded = resolve_policy(PRESETS["deploy"], path)
        assert prec.fmt == loaded.fmt
        # the PDE artifact's site names can't match LM tracker rows, so the
        # hints stay on the artifact rather than being installed positionally
        assert prec.k_bounds is None

        loaded.validation = None
        with pytest.raises(ValueError, match="accepted"):
            resolve_policy(PRESETS["deploy"], loaded)
        # explicit opt-out for dry runs
        prec2, _ = resolve_policy(PRESETS["deploy"], loaded, require_accepted=False)
        assert prec2.fmt == loaded.fmt


# ---------------------------------------------------------------------------
# the one-command pipeline (the acceptance criterion, in-process)
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestCli:
    def test_main_end_to_end(self, tmp_path, capsys):
        from repro.profile.__main__ import main

        rc = main(["heat1d", "--smoke", "--out", str(tmp_path)])
        out = capsys.readouterr().out
        assert rc == 0
        assert "parity: EXACT" in out
        assert "ACCEPTED" in out and "REPRODUCED" in out
        saved = json.loads((tmp_path / "heat1d_policy.json").read_text())
        assert saved["schema"] == "repro.profile/policy"
        assert saved["validation"]["accepted"]
