"""End-to-end system behaviour: train -> checkpoint -> serve; PDE apps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.policy import PRESETS
from repro.data import batch_for_step
from repro.serve import generate
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step


@pytest.mark.slow
def test_train_then_serve_end_to_end(tmp_path):
    """Train a tiny LM until loss visibly drops, checkpoint it, reload and
    serve batched greedy generation."""
    from repro.ckpt import restore, save

    cfg = reduced(get_config("mistral-nemo-12b"))
    tcfg = TrainConfig(opt=OptConfig(lr=2e-3, warmup_steps=5, total_steps=60))
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    fn = jax.jit(make_train_step(cfg, PRESETS["deploy"], tcfg))
    first = None
    for i in range(40):
        state, m = fn(state, batch_for_step(cfg, i, 8, 64))
        first = first if first is not None else float(m["loss"])
    last = float(m["loss"])
    assert last < first * 0.8, (first, last)

    save(state, str(tmp_path), 40)
    state2 = restore(state, str(tmp_path), 40)

    prompts = batch_for_step(cfg, 99, 4, 16)["tokens"]
    toks = generate(state2["params"], cfg, PRESETS["deploy"], prompts, max_new_tokens=8)
    assert toks.shape == (4, 8)
    assert bool((toks >= 0).all()) and bool((toks < cfg.vocab).all())


def test_pde_applications_run():
    from repro.pde import HeatConfig, SWEConfig, simulate_heat, simulate_swe

    u, _ = simulate_heat(HeatConfig(nx=64), PRESETS["r2f2_16"], 100)
    assert bool(jnp.isfinite(u).all())
    U, _ = simulate_swe(SWEConfig(nx=32, ny=32), PRESETS["r2f2_16"], 20)
    assert bool(jnp.isfinite(U).all())


def test_rr_precision_is_first_class_everywhere():
    """The same PrecisionConfig drives models, PDE solvers, and kernels."""
    from repro.core.policy import PRESETS, PrecisionConfig
    from repro.kernels import ops
    from repro.models import lm_loss, model_init

    prec = PRESETS["r2f2_16"]
    cfg = reduced(get_config("yi-34b"))
    params = model_init(jax.random.PRNGKey(0), cfg)
    b = batch_for_step(cfg, 0, 2, 16)
    assert bool(jnp.isfinite(lm_loss(params, b, cfg, prec)))

    x = np.random.default_rng(0).normal(size=(256, 256)).astype(np.float32)
    y, k = ops.r2f2_quantize(x, prec.fmt)
    assert bool(jnp.isfinite(y).all())
