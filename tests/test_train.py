"""Training substrate: optimizer behaviour, grad accumulation, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.policy import PRESETS
from repro.data import batch_for_step, batch_spec
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step

CFG = reduced(get_config("mistral-nemo-12b"))


def _run(tcfg, steps=10, seed=0):
    state = init_train_state(jax.random.PRNGKey(seed), CFG, tcfg)
    fn = jax.jit(make_train_step(CFG, PRESETS["deploy"], tcfg))
    losses = []
    for i in range(steps):
        state, m = fn(state, batch_for_step(CFG, i, 8, 64))
        losses.append(float(m["loss"]))
    return state, losses


class TestTraining:
    def test_loss_decreases(self):
        _, losses = _run(TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)), steps=15)
        assert losses[-1] < losses[0] * 0.9

    def test_grad_accum_equivalent(self):
        """microbatches=2 must match microbatches=1 on the same global batch
        (linearity of gradients; tolerances cover f32 reassociation)."""
        t1 = TrainConfig(opt=OptConfig(lr=1e-3), microbatches=1)
        t2 = TrainConfig(opt=OptConfig(lr=1e-3), microbatches=2)
        s1 = init_train_state(jax.random.PRNGKey(1), CFG, t1)
        s2 = init_train_state(jax.random.PRNGKey(1), CFG, t2)
        b = batch_for_step(CFG, 0, 8, 64)
        f1 = jax.jit(make_train_step(CFG, PRESETS["f32"], t1))
        f2 = jax.jit(make_train_step(CFG, PRESETS["f32"], t2))
        s1, m1 = f1(s1, b)
        s2, m2 = f2(s2, b)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
        for a, c in zip(
            jax.tree_util.tree_leaves(s1["params"]), jax.tree_util.tree_leaves(s2["params"])
        ):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)

    def test_adafactor_trains(self):
        _, losses = _run(
            TrainConfig(opt=OptConfig(kind="adafactor", lr=1e-2, warmup_steps=2, total_steps=50)),
            steps=12,
        )
        assert losses[-1] < losses[0]

    @pytest.mark.parametrize("mode", ["bf16", "rr16"])
    def test_grad_compression_trains(self, mode):
        _, losses = _run(
            TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50), grad_comm=mode),
            steps=12,
        )
        assert losses[-1] < losses[0] * 0.95

    @pytest.mark.slow
    def test_rr16_grad_compression_close_to_exact(self):
        tcfg = TrainConfig(opt=OptConfig(lr=1e-3))
        state = init_train_state(jax.random.PRNGKey(2), CFG, tcfg)
        b = batch_for_step(CFG, 0, 8, 64)
        f_plain = jax.jit(make_train_step(CFG, PRESETS["f32"], tcfg))
        f_rr = jax.jit(
            make_train_step(CFG, PRESETS["f32"], TrainConfig(opt=OptConfig(lr=1e-3), grad_comm="rr16"))
        )
        s1, _ = f_plain(state, b)
        s2, _ = f_rr(state, b)
        # rr16 grads carry >= 9 mantissa bits where ranges cluster
        num = sum(
            float(jnp.sum(jnp.abs(a - c)))
            for a, c in zip(
                jax.tree_util.tree_leaves(s1["params"]),
                jax.tree_util.tree_leaves(s2["params"]),
            )
        )
        den = sum(
            float(jnp.sum(jnp.abs(a))) for a in jax.tree_util.tree_leaves(s1["params"])
        )
        assert num / den < 1e-4


class TestDataPipeline:
    def test_pure_function_of_step(self):
        b1 = batch_for_step(CFG, 7, 4, 32)
        b2 = batch_for_step(CFG, 7, 4, 32)
        for a, c in zip(jax.tree_util.tree_leaves(b1), jax.tree_util.tree_leaves(b2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    def test_specs_match_data(self):
        for arch in ["hubert-xlarge", "pixtral-12b", "yi-34b"]:
            cfg = reduced(get_config(arch))
            b = batch_for_step(cfg, 0, 4, 2048 if cfg.frontend == "vision" else 32)
            s = batch_spec(cfg, 4, 2048 if cfg.frontend == "vision" else 32)
            assert set(b.keys()) == set(s.keys())
            for k in b:
                assert b[k].shape == s[k].shape, (arch, k)
                assert b[k].dtype == s[k].dtype, (arch, k)
