"""Fault tolerance: atomic checkpoints, exact restart, elastic re-shard."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import available_steps, latest_step, restore, save
from repro.configs import get_config, reduced
from repro.core.policy import PRESETS
from repro.data import batch_for_step
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step

CFG = reduced(get_config("stablelm-12b"))
TCFG = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50))


def _train(state, fn, start, stop):
    for i in range(start, stop):
        state, m = fn(state, batch_for_step(CFG, i, 4, 32))
    return state, float(m["loss"])


class TestCheckpoint:
    def test_atomic_and_latest(self, tmp_path):
        state = init_train_state(jax.random.PRNGKey(0), CFG, TCFG)
        save(state, str(tmp_path), 5)
        save(state, str(tmp_path), 10)
        # a stale tmp dir must never be trusted
        os.makedirs(tmp_path / "step_00000015.tmp")
        assert latest_step(str(tmp_path)) == 10
        assert available_steps(str(tmp_path)) == [5, 10]

    def test_roundtrip_bits(self, tmp_path):
        state = init_train_state(jax.random.PRNGKey(0), CFG, TCFG)
        save(state, str(tmp_path), 1)
        state2 = restore(state, str(tmp_path), 1)
        for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(state2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_interrupted_equals_uninterrupted(self, tmp_path):
        """Kill at step 6, resume from ckpt at step 4: final state must be
        bit-identical to a run that never failed (pure-function pipeline)."""
        fn = jax.jit(make_train_step(CFG, PRESETS["f32"], TCFG))
        s0 = init_train_state(jax.random.PRNGKey(0), CFG, TCFG)

        s_cont, _ = _train(s0, fn, 0, 10)

        s_a, _ = _train(s0, fn, 0, 4)
        save(s_a, str(tmp_path), 4)
        _train(s_a, fn, 4, 6)  # progress lost in the "crash"
        s_b = restore(s_a, str(tmp_path), 4)
        s_b, _ = _train(s_b, fn, 4, 10)

        for a, b in zip(jax.tree_util.tree_leaves(s_cont), jax.tree_util.tree_leaves(s_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestElastic:
    @pytest.mark.slow
    def test_restore_on_different_device_count(self, tmp_path):
        """Save in this process (1 device), resume in a child process with 8
        virtual devices on a (8,) data mesh — the mesh-agnostic checkpoint +
        pure data pipeline make this just 'restore with new shardings'."""
        state = init_train_state(jax.random.PRNGKey(0), CFG, TCFG)
        fn = jax.jit(make_train_step(CFG, PRESETS["f32"], TCFG))
        state, _ = _train(state, fn, 0, 3)
        save(state, str(tmp_path), 3)

        code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
assert len(jax.devices()) == 8
from repro.ckpt import restore, latest_step
from repro.configs import get_config, reduced
from repro.core.policy import PRESETS
from repro.data import batch_for_step
from repro.dist.sharding import axis_rules
from repro.launch.mesh import make_host_mesh
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step
cfg = reduced(get_config("stablelm-12b"))
tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50))
mesh = make_host_mesh()
with mesh, axis_rules(mesh):
    like = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    state = restore(like, r"{tmp_path}", 3)
    fn = jax.jit(make_train_step(cfg, PRESETS["f32"], tcfg))
    state, m = fn(state, batch_for_step(cfg, 3, 8, 32))
    assert np.isfinite(float(m["loss"]))
print("ELASTIC_OK", float(m["loss"]))
"""
        env = dict(os.environ, PYTHONPATH="src")
        env.pop("XLA_FLAGS", None)
        r = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]
