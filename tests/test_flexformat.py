"""Unit + property tests for the flexible floating-point format substrate."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import flexformat as ff

FORMATS = [(5, 10), (5, 9), (5, 8), (6, 9), (3, 12), (7, 8), (4, 11), (8, 7)]


def _finite_floats(max_mag=2.0**100):
    return st.floats(
        min_value=-max_mag,
        max_value=max_mag,
        allow_nan=False,
        allow_infinity=False,
        width=32,
    )


class TestBitExactness:
    def test_e5m10_matches_float16(self):
        rng = np.random.default_rng(0)
        x = np.concatenate(
            [
                rng.uniform(-70000, 70000, 50000),
                rng.uniform(-1e-4, 1e-4, 50000),
                (10.0 ** rng.uniform(-8, 5, 50000)) * rng.choice([-1, 1], 50000),
                [0.0, -0.0, 65504.0, 65520.0, 65519.99, 6e-8, 2**-24, np.inf, -np.inf],
            ]
        ).astype(np.float32)
        y = np.asarray(ff.quantize_em(x, 5, 10))
        ref = x.astype(np.float16).astype(np.float32)
        np.testing.assert_array_equal(y, ref)

    def test_paper_max_values(self):
        # §4.1: E5M10 max 65504; <3,8,4> at k=4 (E7M8) max 1.8410715e19
        assert float(ff.max_normal(5, 10)) == 65504.0
        assert float(ff.max_normal(7, 8)) == pytest.approx(1.8410715e19, rel=1e-6)

    def test_identity_at_f32(self):
        rng = np.random.default_rng(1)
        x = (10.0 ** rng.uniform(-37, 38, 20000) * rng.choice([-1, 1], 20000)).astype(
            np.float32
        )
        y = np.asarray(ff.quantize_em(x, 8, 23))
        np.testing.assert_array_equal(y, x)

    def test_redundancy_paper_example(self):
        # 8-bit exponent 10000111 (=2**8) is redundant; also values < 1 mirror
        assert bool(ff.exponent_redundant(jnp.float32(2.0**8), 8))
        assert not bool(ff.exponent_redundant(jnp.float32(2.0**100), 8))
        assert bool(ff.exponent_redundant(jnp.float32(0.9), 8))
        assert not bool(ff.exponent_redundant(jnp.float32(2.0**-100), 8))


class TestFlags:
    def test_overflow_underflow_flags(self):
        y, o, u = ff.quantize_em_with_flags(
            np.array([70000.0, 1e-8, 1.0, 0.0, -70000.0], np.float32), 5, 10
        )
        assert list(np.asarray(o)) == [True, False, False, False, True]
        assert list(np.asarray(u)) == [False, True, False, False, False]
        assert np.isinf(np.asarray(y)[0]) and np.asarray(y)[4] == -np.inf


@pytest.mark.parametrize("e,m", FORMATS)
class TestPerFormat:
    def test_idempotent(self, e, m):
        rng = np.random.default_rng(e * 100 + m)
        x = (10.0 ** rng.uniform(-20, 15, 5000) * rng.choice([-1, 1], 5000)).astype(
            np.float32
        )
        y1 = np.asarray(ff.quantize_em(x, e, m))
        y2 = np.asarray(ff.quantize_em(y1, e, m))
        np.testing.assert_array_equal(y1, y2)

    def test_pack_unpack_roundtrip(self, e, m):
        # family <2, m, e-2> at k = e-2 gives exactly E(e)M(m)
        fmt = ff.FlexFormat(2, m, e - 2)
        k = e - fmt.eb
        assert fmt.em(k) == (e, m)
        rng = np.random.default_rng(7)
        x = (10.0 ** rng.uniform(-15, 10, 5000) * rng.choice([-1, 1], 5000)).astype(
            np.float32
        )
        q = np.asarray(ff.quantize_em(x, e, m))
        payload = ff.pack_r2f2(q, fmt, k)
        back = np.asarray(ff.unpack_r2f2(payload, fmt, k))
        np.testing.assert_array_equal(back, q)
        assert int(np.asarray(payload).max()) < 2 ** fmt.total_bits

    def test_error_bound_half_ulp(self, e, m):
        """|q(x) - x| <= 0.5 ulp(x) for in-range normals (RNE)."""
        rng = np.random.default_rng(9)
        emax = 2 ** (e - 1) - 1
        emin = 2 - 2 ** (e - 1)
        exps = rng.integers(emin + 1, emax - 1, 4000)
        mant = rng.uniform(1, 2, 4000)
        x = (mant * (2.0**exps.astype(np.float64))).astype(np.float32)
        y = np.asarray(ff.quantize_em(x, e, m), np.float64)
        ulp = 2.0 ** (exps.astype(np.float64) - m)
        assert np.all(np.abs(y - x.astype(np.float64)) <= 0.5 * ulp + 1e-45)


@settings(max_examples=300, deadline=None)
@given(x=_finite_floats(), e=st.integers(2, 8), m=st.integers(1, 12))
def test_prop_idempotent_and_monotone_zero(x, e, m):
    xq = float(ff.quantize_em(np.float32(x), e, m))
    xqq = float(ff.quantize_em(np.float32(xq), e, m))
    assert xq == xqq or (np.isnan(xq) and np.isnan(xqq))
    # sign preservation
    if xq != 0 and np.isfinite(xq):
        assert np.sign(xq) == np.sign(x)


@settings(max_examples=200, deadline=None)
@given(
    a=_finite_floats(max_mag=2.0**50),
    b=_finite_floats(max_mag=2.0**50),
    e=st.integers(3, 8),
    m=st.integers(2, 12),
)
def test_prop_monotonicity(a, b, e, m):
    """x <= y  =>  q(x) <= q(y) (RNE is monotone)."""
    lo, hi = (a, b) if a <= b else (b, a)
    ql = float(ff.quantize_em(np.float32(lo), e, m))
    qh = float(ff.quantize_em(np.float32(hi), e, m))
    assert ql <= qh


@settings(max_examples=200, deadline=None)
@given(x=_finite_floats(max_mag=2.0**66), e=st.integers(2, 8), m=st.integers(1, 12))
def test_prop_quantize_within_format_bounds(x, e, m):
    q = float(ff.quantize_em(np.float32(x), e, m))
    if np.isfinite(q):
        assert abs(q) <= float(ff.max_normal(e, m))
