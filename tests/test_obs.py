"""repro.obs — the unified observability subsystem (DESIGN.md §15).

Covers the ISSUE-9 contract: span nesting + deterministic sampling,
Prometheus export round-trip through the strict parser, registry
label-cardinality bound, ServiceMetrics NaN guards and the
compile-vs-execute split, PASSIVITY (instrumented runs bit-identical to
uninstrumented ones on heat1d and swe2d across all three execution planes),
and precision telemetry whose k series equals the tracker's carried k at
every chunk boundary."""

import dataclasses
import json
import math

import jax
import numpy as np
import pytest

import repro.obs as obs
from repro.core.policy import PRESETS
from repro.obs.metrics import MetricsRegistry, parse_prometheus
from repro.obs.precision import PrecisionTelemetry, load_telemetry
from repro.obs.timing import measure
from repro.obs.trace import Tracer, load_trace
from repro.pde import Simulation
from repro.pde.heat1d import HeatConfig
from repro.pde.swe2d import SWEConfig
from repro.precision import site_tracker_init
from repro.service import ServiceConfig, SimRequest, SimService
from repro.service.metrics import ServiceMetrics

TRACKED = dataclasses.replace(PRESETS["r2f2_16"], mode="rr_tracked")

SMALL = {
    "heat1d": HeatConfig(nx=64),
    "swe2d": SWEConfig(nx=32, ny=32),
}


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with observability disabled."""
    obs.disable()
    yield
    obs.disable()


def assert_bits_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype
    if a.dtype == np.float32:
        np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))
    else:
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# tracing: nesting, sampling determinism, bounds, export
# ---------------------------------------------------------------------------


class TestTracer:
    def test_span_nesting_depths(self):
        tr = Tracer()
        with tr.span("outer"):
            with tr.span("mid"):
                with tr.span("inner"):
                    pass
            tr.instant("event")
        by_name = {s.name: s for s in tr.spans}
        assert by_name["outer"].depth == 0
        assert by_name["mid"].depth == 1
        assert by_name["inner"].depth == 2
        assert by_name["event"].depth == 1  # recorded inside "outer"
        # children complete before parents, so inner durations are smaller
        assert by_name["inner"].dur_us <= by_name["outer"].dur_us

    def test_span_args_mutable_late_attach(self):
        tr = Tracer()
        with tr.span("chunk", a=1) as args:
            args["computed"] = 42
        assert tr.spans[0].args == {"a": 1, "computed": 42}

    def test_sampling_is_deterministic_and_proportional(self):
        def record(n):
            tr = Tracer(sample=0.5)
            for i in range(n):
                with tr.span(f"s{i}"):
                    pass
            return [s.name for s in tr.spans]

        a, b = record(10), record(10)
        assert a == b  # no RNG: identical runs record identical span sets
        assert len(a) == 5  # exactly the sampled fraction
        # the analytic keep rule, spelled out
        kept = [
            f"s{n}"
            for n in range(10)
            if math.floor((n + 1) * 0.5) > math.floor(n * 0.5)
        ]
        assert a == kept

    def test_nested_spans_inherit_sampling_decision(self):
        tr = Tracer(sample=0.5)
        for i in range(4):
            with tr.span("top"):
                with tr.span("child"):
                    pass
                tr.instant("ev")
        # 2 of 4 tops kept, each with exactly its own child + instant
        names = [s.name for s in tr.spans]
        assert names.count("top") == 2
        assert names.count("child") == 2
        assert names.count("ev") == 2

    def test_sample_zero_keeps_nothing_but_bare_instants(self):
        tr = Tracer(sample=0.0)
        with tr.span("never"):
            tr.instant("inherits-drop")
        tr.instant("lifecycle")  # outside any span: always kept
        assert [s.name for s in tr.spans] == ["lifecycle"]

    def test_capacity_bound_and_dropped_counter(self):
        tr = Tracer(capacity=3)
        for i in range(7):
            with tr.span(f"s{i}"):
                pass
        assert len(tr.spans) == 3
        assert tr.dropped == 4

    def test_chrome_trace_export_and_load(self, tmp_path):
        tr = Tracer()
        with tr.span("work", kind="test"):
            tr.instant("mark")
        path = tr.save(str(tmp_path / "trace.json"))
        doc = load_trace(path)
        events = doc["traceEvents"]
        assert {e["ph"] for e in events} == {"X", "i"}
        x = next(e for e in events if e["ph"] == "X")
        assert x["name"] == "work" and x["dur"] >= 0
        assert x["args"]["kind"] == "test"
        i = next(e for e in events if e["ph"] == "i")
        assert "dur" not in i and i["s"] == "t"

    def test_load_trace_rejects_garbage(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text(json.dumps({"not": "a trace"}))
        with pytest.raises(ValueError):
            load_trace(str(p))

    def test_self_time_is_accounted(self):
        tr = Tracer()
        for _ in range(50):
            with tr.span("s"):
                pass
        assert tr.self_seconds > 0.0


# ---------------------------------------------------------------------------
# metrics registry: counters/gauges/histograms, export, strict parsing
# ---------------------------------------------------------------------------


class TestMetricsRegistry:
    def test_counter_labels_and_totals(self):
        reg = MetricsRegistry()
        c = reg.counter("jobs_total", "jobs")
        c.inc(ok="true")
        c.inc(2, ok="false")
        assert c.value(ok="true") == 1
        assert c.value(ok="false") == 2
        assert c.total() == 3
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_redeclare_same_type_ok_different_type_raises(self):
        reg = MetricsRegistry()
        reg.counter("x_total")
        assert reg.counter("x_total") is reg.counter("x_total")
        with pytest.raises(TypeError):
            reg.gauge("x_total")

    def test_histogram_cumulative_buckets(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 0.7, 5.0):
            h.observe(v)
        snap = h.snapshot()
        assert snap["count"] == 4
        assert snap["sum"] == pytest.approx(6.25)
        assert dict(snap["buckets"]) == {0.1: 1, 1.0: 3}  # cumulative

    def test_prometheus_round_trip(self):
        reg = MetricsRegistry()
        reg.counter("c_total", "a counter").inc(3, stage="x")
        reg.gauge("g", "a gauge").set(1.5)
        h = reg.histogram("h_seconds", "a histogram", buckets=(0.01, 0.1))
        h.observe(0.05, op="mul")
        h.observe(0.2, op="mul")
        families = parse_prometheus(reg.export_prometheus())
        assert families["c_total"]["type"] == "counter"
        assert ("c_total", {"stage": "x"}, 3.0) in families["c_total"]["samples"]
        assert ("g", {}, 1.5) in families["g"]["samples"]
        hs = {
            (name, labels.get("le")): v
            for name, labels, v in families["h_seconds"]["samples"]
        }
        assert hs[("h_seconds_bucket", "0.01")] == 0
        assert hs[("h_seconds_bucket", "0.1")] == 1
        assert hs[("h_seconds_bucket", "+Inf")] == 2
        assert hs[("h_seconds_count", None)] == 2
        assert hs[("h_seconds_sum", None)] == pytest.approx(0.25)

    @pytest.mark.parametrize(
        "text",
        [
            "no_type_decl 1.0\n",  # sample without a TYPE header
            "# TYPE h histogram\nh_bucket{le=\"+Inf\"} 2\nh_count 2\n",  # no _sum
            "# TYPE h histogram\nh_bucket{le=\"0.1\"} 1\nh_sum 1\nh_count 1\n",  # no +Inf
            "# TYPE h histogram\n"
            "h_bucket{le=\"0.1\"} 3\nh_bucket{le=\"+Inf\"} 2\n"
            "h_sum 1\nh_count 2\n",  # non-cumulative buckets
            "# TYPE h histogram\n"
            "h_bucket{le=\"+Inf\"} 2\nh_sum 1\nh_count 5\n",  # count != +Inf
            "# TYPE c counter\nc not-a-number\n",
            "# TYPE c counter\nc{bad-label=\"x\"} 1\n",
        ],
    )
    def test_strict_parser_rejects_malformed(self, text):
        with pytest.raises(ValueError):
            parse_prometheus(text)

    def test_label_cardinality_bound(self):
        reg = MetricsRegistry(max_series=4)
        c = reg.counter("wide_total")
        for i in range(10):
            c.inc(member=str(i))
        assert len(c.samples()) == 4
        assert reg.dropped_series == 6
        # export stays parseable after drops
        parse_prometheus(reg.export_prometheus())

    def test_export_json_schema(self):
        reg = MetricsRegistry()
        reg.counter("c_total").inc()
        doc = reg.export_json()
        assert doc["schema"] == "repro.obs/metrics@1"
        assert doc["metrics"]["c_total"]["samples"][0]["value"] == 1.0


# ---------------------------------------------------------------------------
# ServiceMetrics: NaN guards + compile/execute split
# ---------------------------------------------------------------------------


class TestServiceMetricsGuards:
    def test_zero_denominators_return_nan(self):
        m = ServiceMetrics()
        assert math.isnan(m.throughput())
        assert math.isnan(m.throughput("nokey"))
        assert math.isnan(m.latency_us(50))
        mean, mx = m.occupancy()
        assert math.isnan(mean) and mx == 0
        # summary never raises on the empty service
        s = m.summary()
        assert math.isnan(s["throughput_steps_per_s"])
        assert math.isnan(s["chunk_latency_p50_us"])
        assert "nan" in m.report()

    def test_only_compile_samples_still_nan_throughput(self):
        m = ServiceMetrics()
        m.observe_chunk("k", 2, 8, 1.0, compiled=True)
        assert math.isnan(m.throughput())
        assert math.isnan(m.latency_us(99))
        assert m.occupancy() == (2.0, 2)  # occupancy counts compile calls

    def test_compile_split_excluded_from_percentiles(self):
        m = ServiceMetrics()
        m.observe_chunk("k", 2, 8, 10.0, compiled=True)  # one huge compile
        for _ in range(9):
            m.observe_chunk("k", 2, 8, 0.001)
        s = m.summary()
        assert s["chunks"] == 10 and s["compiles"] == 1
        assert s["compile_seconds"] == pytest.approx(10.0)
        assert s["busy_seconds"] == pytest.approx(0.009)
        # the compile no longer pollutes the tail
        assert s["chunk_latency_p99_us"] < 2_000
        assert m.throughput() == pytest.approx(9 * 16 / 0.009)

    def test_attribute_increment_api_preserved(self):
        m = ServiceMetrics()
        m.submitted += 1
        m.evicted += 2
        assert m.submitted == 1 and m.evicted == 2
        assert m.registry.counter("repro_service_submitted_total").total() == 1

    def test_reports_into_active_obs_registry(self):
        scope = obs.enable()
        m = ServiceMetrics()
        m.submitted += 1
        assert scope.registry.counter("repro_service_submitted_total").total() == 1


class TestServiceCompileSplit:
    def test_first_call_per_program_books_as_compile(self):
        svc = SimService(ServiceConfig(max_queue=64))
        for _ in range(2):  # identical requests: 2nd rides the cached program
            svc.submit(SimRequest("heat1d", steps=32, precision="f32",
                                  overrides={"nx": 32}, snapshot_every=8))
        svc.run_until_idle()
        m = svc.metrics
        assert m.compiles >= 1
        assert m.compile_seconds > 0.0
        assert m.chunks > m.compiles  # warm calls exist
        assert np.isfinite(m.latency_us(50))
        compiled_flags = [c for *_, c in m.chunk_samples]
        assert any(compiled_flags) and not all(compiled_flags)
        # warm (execute) samples are all much faster than the compile call
        warm = [s for *_, s, c in m.chunk_samples if not c]
        cold = [s for *_, s, c in m.chunk_samples if c]
        assert max(warm) < max(cold)


# ---------------------------------------------------------------------------
# passivity: instrumented == uninstrumented, bit for bit, on every plane
# ---------------------------------------------------------------------------


def _run(name, prec, execution, steps=20, every=6):
    sim = Simulation(name, SMALL[name], prec)
    return sim.run(steps, snapshot_every=every, execution=execution)


class TestPassivity:
    @pytest.mark.parametrize("name", ["heat1d", "swe2d"])
    @pytest.mark.parametrize("execution", ["reference", "fused", "megakernel"])
    def test_tracked_run_bit_identical_under_obs(self, name, execution):
        base = _run(name, TRACKED, execution)
        obs.enable(sample=1.0)
        inst = _run(name, TRACKED, execution)
        o = obs.active()
        assert len(o.tracer.spans) > 0  # it really was instrumented
        assert len(o.telemetry) > 0  # and the tracker really was drained
        obs.disable()
        jax.tree_util.tree_map(assert_bits_equal, base.state, inst.state)
        jax.tree_util.tree_map(assert_bits_equal, base.snapshots, inst.snapshots)
        np.testing.assert_array_equal(
            np.asarray(base.tracker.state.k), np.asarray(inst.tracker.state.k)
        )
        np.testing.assert_array_equal(
            np.asarray(base.tracker.state.overflow_steps),
            np.asarray(inst.tracker.state.overflow_steps),
        )

    @pytest.mark.parametrize("name", ["heat1d", "swe2d"])
    def test_untracked_f32_bit_identical_under_obs(self, name):
        base = _run(name, PRESETS["f32"], "reference")
        obs.enable()
        inst = _run(name, PRESETS["f32"], "reference")
        obs.disable()
        jax.tree_util.tree_map(assert_bits_equal, base.state, inst.state)

    def test_record_tracker_refuses_jax_tracers(self):
        obs.enable()
        tracker = site_tracker_init(("a", "b"), TRACKED.fmt)

        @jax.jit
        def traced(tr):
            obs.record_tracker("inside-jit", tr, 0)
            return tr.state.k

        traced(tracker)
        assert len(obs.active().telemetry) == 0  # drain skipped under trace
        obs.record_tracker("outside", tracker, 0)
        assert len(obs.active().telemetry) == 2


# ---------------------------------------------------------------------------
# precision telemetry: k series == carried tracker at every chunk boundary
# ---------------------------------------------------------------------------


def _ground_truth_boundary_k(name, prec, execution, steps, every):
    """Thread (state, tracker) through per-chunk solo runs — the carried
    tracker at each chunk boundary, observed directly."""
    sim = Simulation(name, SMALL[name], prec)
    state, tracker = None, None
    out = []
    done = 0
    while done < steps:
        n = min(every, steps - done)
        res = sim.run(
            n, snapshot_every=n, state0=state, tracker=tracker,
            execution=execution,
        )
        state, tracker = res.state, res.tracker
        done += n
        out.append((done, np.asarray(tracker.state.k).copy()))
    return out


class TestTelemetrySeries:
    @pytest.mark.parametrize("name", ["heat1d", "swe2d"])
    @pytest.mark.parametrize("execution", ["reference", "fused"])
    def test_replayed_series_equals_carried_k(self, name, execution):
        """A captured instrumented run's telemetry series must equal the
        carried tracker's k at every chunk boundary (steps=20, every=6:
        includes the remainder chunk)."""
        steps, every = 20, 6
        truth = _ground_truth_boundary_k(name, TRACKED, execution, steps, every)
        obs.enable(sample=1.0)
        sim = Simulation(name, SMALL[name], TRACKED)
        res = sim.run(steps, snapshot_every=every, execution=execution,
                      capture=True)
        tel = obs.active().telemetry
        sites = sim.stepper.sites
        for j, site in enumerate(sites):
            t_steps, t_k = tel.k_series(f"sim:{name}", site)
            assert list(t_steps) == [s for s, _ in truth]
            assert list(t_k) == [int(k[j]) for _, k in truth]
        # and the last sample is the run's final carried tracker
        np.testing.assert_array_equal(
            np.asarray(res.tracker.state.k), truth[-1][1]
        )

    def test_coverage_fraction_attached(self):
        obs.enable()
        sim = Simulation("heat1d", SMALL["heat1d"], TRACKED)
        sim.run(12, snapshot_every=6, capture=True)
        for s in obs.active().telemetry.all_series():
            assert s.coverage is not None and 0.0 <= s.coverage <= 1.0

    def test_uncaptured_run_records_final_tracker(self):
        obs.enable()
        sim = Simulation("heat1d", SMALL["heat1d"], TRACKED)
        res = sim.run(12, snapshot_every=6)
        tel = obs.active().telemetry
        assert tel.final_k("sim:heat1d") == {
            n: int(res.tracker.state.k[i])
            for i, n in enumerate(res.tracker.names)
        }

    def test_service_chunk_boundary_drain_matches_result(self):
        obs.enable()
        svc = SimService(ServiceConfig(max_queue=16))
        h = svc.submit(SimRequest("heat1d", steps=24, precision=TRACKED,
                                  overrides={"nx": 32}, snapshot_every=8))
        svc.run_until_idle()
        res = h.result()
        tel = obs.active().telemetry
        scopes = [sc for sc in tel.scopes() if sc.endswith(":heat1d")]
        assert scopes, f"no service telemetry scopes in {tel.scopes()}"
        assert any(tel.final_k(sc) == res.final_k for sc in scopes)
        # one sample per chunk the request rode, stamped at its elapsed steps
        steps, _ = tel.k_series(scopes[0], res.tracker.names[0])
        assert len(steps) == res.chunks
        assert int(steps[-1]) == res.elapsed

    def test_telemetry_save_load_round_trip(self, tmp_path):
        t = PrecisionTelemetry()
        t.record_series(
            "s", ["a"], [6, 12], np.array([[3], [4]]), np.array([[1], [1]]),
            np.array([[0], [0]]), coverage={"a": 0.97},
        )
        p = t.save(str(tmp_path / "telemetry.json"))
        back = load_telemetry(p)
        s = back.all_series()[0]
        assert s.k == [3, 4] and s.grew == [1, 1] and s.coverage == 0.97
        with pytest.raises(ValueError):
            bad = tmp_path / "bad.json"
            bad.write_text(json.dumps({"schema": "other"}))
            load_telemetry(str(bad))


# ---------------------------------------------------------------------------
# shared timing helper + end-to-end export/reporter
# ---------------------------------------------------------------------------


class TestTimingAndReporter:
    def test_measure_splits_compile_from_steady_state(self):
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return x * 2.0

        t = measure(f, jnp.ones((64,)), iters=3)
        assert t.iters == 3
        assert t.compile_us > t.us_per_call  # first call paid the trace
        np.testing.assert_array_equal(np.asarray(t.result), np.full((64,), 2.0))

    def test_enable_export_disable_round_trip(self, tmp_path):
        obs.enable()
        with obs.span("unit", n=1):
            obs.inc("repro_test_events_total", kind="unit")
        paths = obs.export(str(tmp_path))
        obs.disable()
        doc = load_trace(paths["trace"])
        assert any(e["name"] == "unit" for e in doc["traceEvents"])
        with open(paths["prometheus"]) as f:
            fams = parse_prometheus(f.read())
        assert "repro_test_events_total" in fams
        with pytest.raises(RuntimeError):
            obs.export(str(tmp_path))  # disabled: must refuse

    def test_reporter_smoke_gate_passes(self, tmp_path):
        from repro.obs.__main__ import main

        assert main(["--smoke", "--out", str(tmp_path / "obs")]) == 0
        # and the report mode reads back what the smoke exported
        assert main(["--dir", str(tmp_path / "obs"), "--top", "3"]) == 0
