"""Bit-level suites for the flexible ALU ops (repro.alu) + paper gates.

Property tests (hypothesis; the vendored stub supplies the API when the
real package is absent — see tests/conftest.py) pin the op law against f64
oracles: quantize-operands -> substrate op -> quantize-result at the
effective ``E(EB+k)M(MB+FX-k)`` format, with NO tail truncation (only the
multiplier models dropped partial products). Covered operand regimes:
Sterbenz cancellation (exact subtraction), the subnormal floor, and the
near-overflow edge.

Paper-pattern gates mirror §5's per-workload story for the ops the SWE
momentum flux now routes through the engine: fixed E5M10 add/divide blow up
on SWE-ramp magnitudes while the 16-bit flexible ops stay finite and
f32-close, and the tracked divide shows up as a live policy site in a real
swe2d run.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.alu import flex_add, flex_div, flex_rsqrt, flex_sub
from repro.core import FlexFormat, max_normal, quantize_em
from repro.core.flexformat import min_subnormal
from repro.core.policy import PrecisionConfig
from repro.precision import PRESETS, add, divide, multiply, rsqrt

FMT = FlexFormat(3, 9, 3)


def _q(x, k):
    """Quantize to the effective format at split k, as f64."""
    e, m = FMT.eb + k, FMT.mb + FMT.fx - k
    return float(np.asarray(quantize_em(np.float32(x), e, m), np.float64))


def _fmt_bits(k):
    return FMT.eb + k, FMT.mb + FMT.fx - k


def _assert_oracle(res, exact, k, *, ulps=1.0):
    """res must be exact's format-rounding: within ``ulps`` ULPs of the
    effective format, inf past the overflow edge, 0 under the subnormal
    floor (each edge with a half-ULP tolerance band where either outcome is
    a legal rounding)."""
    e, m = _fmt_bits(k)
    top = float(max_normal(e, m))
    sub_floor = float(min_subnormal(e, m))
    if abs(exact) > top * (1.0 + 2.0**-m):
        assert np.isinf(res) and np.sign(res) == np.sign(exact), (res, exact)
        return
    if exact == 0.0:
        assert res == 0.0
        return
    if abs(exact) < sub_floor / 2.0:
        assert res == 0.0 or abs(res) == sub_floor, (res, exact)
        return
    if np.isinf(res):  # inside the band: rounding up to inf is legal
        assert abs(exact) >= top, (res, exact)
        return
    # ULP at exact's magnitude, floored at the subnormal spacing
    ulp = max(2.0 ** (np.floor(np.log2(abs(exact))) - m), sub_floor)
    assert abs(res - exact) <= ulps * ulp + 1e-300, (res, exact, ulp)


def _flex_scalar(fn, *args, k):
    out, _ = fn(*[np.float32([x]) for x in args], FMT, k=k)
    return float(np.asarray(out, np.float64)[0])


@settings(max_examples=120, deadline=None)
@given(
    a=st.floats(min_value=-1e6, max_value=1e6, allow_nan=False, allow_infinity=False, width=32),
    b=st.floats(min_value=-1e6, max_value=1e6, allow_nan=False, allow_infinity=False, width=32),
    k=st.integers(0, 3),
)
def test_prop_add_matches_f64_oracle(a, b, k):
    qa, qb = _q(a, k), _q(b, k)
    if not (np.isfinite(qa) and np.isfinite(qb)):
        return  # operand already past the format edge; covered by edge gate
    res = _flex_scalar(flex_add, a, b, k=k)
    # f32 substrate + format rounding: allow one extra ULP for the double
    # rounding against the f64 sum
    _assert_oracle(res, qa + qb, k, ulps=2.0)


@settings(max_examples=120, deadline=None)
@given(
    a=st.floats(min_value=-256.0, max_value=256.0, allow_nan=False, allow_infinity=False, width=32),
    b=st.floats(min_value=-256.0, max_value=256.0, allow_nan=False, allow_infinity=False, width=32),
    k=st.integers(0, 3),
)
def test_prop_div_matches_f64_oracle(a, b, k):
    qa, qb = _q(a, k), _q(b, k)
    if qb == 0.0 or not (np.isfinite(qa) and np.isfinite(qb)):
        return
    res = _flex_scalar(flex_div, a, b, k=k)
    _assert_oracle(res, qa / qb, k, ulps=2.0)


@settings(max_examples=120, deadline=None)
@given(
    x=st.floats(min_value=1e-6, max_value=1e6, allow_nan=False, allow_infinity=False, width=32),
    k=st.integers(0, 3),
)
def test_prop_rsqrt_matches_f64_oracle(x, k):
    qx = _q(x, k)
    if qx <= 0.0 or not np.isfinite(qx):
        return
    res = _flex_scalar(flex_rsqrt, x, k=k)
    # substrate rsqrt is itself a correctly-rounded-ish f32 approx: 3 ULPs
    _assert_oracle(res, 1.0 / np.sqrt(qx), k, ulps=3.0)


@settings(max_examples=120, deadline=None)
@given(
    a=st.floats(min_value=0.001, max_value=1000.0, allow_nan=False, allow_infinity=False, width=32),
    r=st.floats(min_value=0.5, max_value=2.0, allow_nan=False, allow_infinity=False, width=32),
    k=st.integers(0, 3),
)
def test_prop_sterbenz_subtraction_exact(a, r, k):
    """qb in [qa/2, 2qa] -> qa - qb is representable: flex_sub is EXACT.

    The classic alignment-cancellation case — any tail truncation in the
    add path (the multiplier's shortcut) would break this identity."""
    qa = _q(a, k)
    if not np.isfinite(qa):
        return  # operand past the split's overflow edge (small-k + big a)
    qb = _q(qa * r, k)
    if qa <= 0.0 or qb <= 0.0 or not (qa / 2.0 <= qb <= 2.0 * qa):
        return  # rounding pushed qb outside the Sterbenz band
    res = _flex_scalar(flex_sub, qa, qb, k=k)
    assert res == qa - qb, (qa, qb, res)


class TestEdges:
    def test_subnormal_operands_survive_add(self):
        # E3M12 at k=0: min normal 2^-2, subnormal grid down to 2^-14
        tiny = 2.0**-13
        res = _flex_scalar(flex_add, tiny, tiny, k=0)
        assert res == 2.0**-12

    def test_near_overflow_add_rounds_to_inf(self):
        e, m = _fmt_bits(0)  # E3M12: max normal just under 8
        top = float(max_normal(e, m))
        res = _flex_scalar(flex_add, top, top, k=0)
        assert np.isinf(res)

    def test_wide_split_rescues_the_same_add(self):
        top0 = float(max_normal(*_fmt_bits(0)))
        res = _flex_scalar(flex_add, top0, top0, k=3)  # E6M9 spans it
        assert np.isfinite(res) and res == pytest.approx(2 * top0, rel=2**-9)

    def test_auto_k_picks_covering_split(self):
        # 12+12=24 > E3's max normal (~16): evidence-selected k must widen
        out, stats = flex_add(np.float32([12.0]), np.float32([12.0]), FMT)
        assert np.isfinite(np.asarray(out)).all()
        assert int(np.asarray(stats.k).max()) >= 1


class TestSwePaperGates:
    """§5's SWE ramp, per op: E5M10 fails, 16-bit flexible matches f32."""

    # momentum-flux magnitudes from the SWE basin: h ~ 500 -> q1*q1 ~ 2.5e5
    T1, Q3 = 2.5e5, 500.0

    def test_e5m10_divide_overflows_on_momentum_flux(self):
        q = quantize_em(np.float32([self.T1]), 5, 10)  # 2.5e5 > 65504
        assert np.isinf(np.asarray(q)).all()
        out = np.asarray(quantize_em(np.asarray(q) / self.Q3, 5, 10))
        assert np.isinf(out).all()  # the ramp poisons the divide

    def test_flexible_divide_survives_momentum_flux(self):
        out, _ = flex_div(np.float32([self.T1]), np.float32([self.Q3]), FMT)
        out = float(np.asarray(out)[0])
        assert np.isfinite(out)
        assert out == pytest.approx(self.T1 / self.Q3, rel=2**-8)

    def test_e5m10_add_overflows_on_ramp_sums(self):
        out = np.asarray(
            quantize_em(np.float32(4.0e4) + np.float32(4.0e4), 5, 10)
        )
        assert np.isinf(out).all()
        fx, _ = flex_add(np.float32([4.0e4]), np.float32([4.0e4]), FMT)
        assert np.isfinite(np.asarray(fx)).all()

    def test_swe2d_tracked_divide_is_a_live_site(self):
        """Integration: the momentum-flux divide rides the policy engine —
        swe2d declares the div site/op and a tracked run carries a split
        for it while staying f32-correlated."""
        from repro.pde import Simulation, get_stepper

        stepper = get_stepper("swe2d")
        assert "swe.div" in stepper.sites
        assert stepper.site_ops[stepper.sites.index("swe.div")] == "div"

        cfg = dataclasses.replace(stepper.default_config(), nx=32, ny=32)
        steps = 40
        ref = Simulation("swe2d", cfg, PRESETS["f32"]).run(steps)
        prec = dataclasses.replace(PRESETS["r2f2_16"], mode="rr_tracked")
        sim = Simulation("swe2d", cfg, prec)
        res = sim.run(steps)
        obs = np.asarray(stepper.observables(res.state, cfg), np.float64)
        refo = np.asarray(stepper.observables(ref.state, cfg), np.float64)
        assert np.isfinite(obs).all()
        corr = np.corrcoef(
            (obs - cfg.depth).ravel(), (refo - cfg.depth).ravel()
        )[0, 1]
        assert corr > 0.98
        i = res.tracker.names.index("swe.div")
        k_div = int(np.asarray(res.tracker.state.k)[i])
        assert 0 <= k_div <= FMT.fx

    def test_e5m10_swe2d_destroyed_flexible_survives(self):
        """The full §5 verdict on a reduced basin: fixed E5M10 goes
        non-finite on the ramp; the same run under 16-bit flexible doesn't."""
        from repro.pde import Simulation, get_stepper

        stepper = get_stepper("swe2d")
        cfg = dataclasses.replace(stepper.default_config(), nx=32, ny=32)
        steps = 60
        fixed = Simulation("swe2d", cfg, PRESETS["e5m10"]).run(steps)
        obs_fixed = np.asarray(stepper.observables(fixed.state, cfg))
        assert not np.isfinite(obs_fixed).all()

        flex = Simulation("swe2d", cfg, PRESETS["r2f2_16"]).run(steps)
        obs_flex = np.asarray(stepper.observables(flex.state, cfg))
        assert np.isfinite(obs_flex).all()


@pytest.mark.parametrize("mode", ["f32", "bf16", "fixed", "rr_tile", "rr_tracked", "deploy"])
def test_engine_alu_protocol_coverage(mode):
    """Every registered engine implements the extended ALU protocol and
    returns finite, close-to-f32 results for in-range operands."""
    cfg = PrecisionConfig(mode=mode, fmt=FMT, fixed_em=(5, 10))
    rng = np.random.default_rng(7)
    a = rng.uniform(0.5, 4.0, 256).astype(np.float32)
    b = rng.uniform(0.5, 4.0, 256).astype(np.float32)
    for fn, exact in (
        (lambda: add(a, b, cfg), a.astype(np.float64) + b),
        (lambda: divide(a, b, cfg), a.astype(np.float64) / b),
        (lambda: rsqrt(jnp.abs(a), cfg), 1.0 / np.sqrt(a.astype(np.float64))),
        (lambda: multiply(a, b, cfg), a.astype(np.float64) * b),
    ):
        out = np.asarray(fn(), np.float64)
        assert np.isfinite(out).all()
        rel = np.abs(out - exact) / np.abs(exact)
        assert rel.max() < 2**-6  # every 16-bit mode keeps >= 7 mantissa bits
