"""The fused execution plane (DESIGN.md §10): per-stepper parity between
``execution="fused"`` (Pallas whole-step kernel chunks) and the reference
``StepOps`` path, tracker-evidence fold-in equivalence, graceful fallback,
and the shared sweep builder's padding/evidence plumbing."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import PRESETS
from repro.precision import FUSED_FAMILIES, fused_eligible, get_engine
from repro.pde import Simulation, Stepper, get_stepper, known_steppers
from repro.pde.advection1d import AdvectionConfig
from repro.pde.burgers1d import BurgersConfig, initial_wave
from repro.pde.heat1d import HeatConfig
from repro.pde.heat2d import Heat2DConfig
from repro.pde.swe2d import SWEConfig

TRACKED = dataclasses.replace(PRESETS["r2f2_16"], mode="rr_tracked")
BUILTINS = ("advection1d", "burgers1d", "heat1d", "heat2d", "swe2d")

#: small shapes: every default kernel block covers the whole field, so the
#: fused per-block split equals the reference per-tensor split and parity
#: is bit-exact for non-tracked modes
SMALL = {
    "heat1d": HeatConfig(nx=64),
    "heat2d": Heat2DConfig(nx=24, ny=24),
    "advection1d": AdvectionConfig(nx=128),
    "burgers1d": BurgersConfig(nx=128),
    "swe2d": SWEConfig(nx=32, ny=32),
}


def _pair(name, prec, steps=48, **kw):
    cfg = SMALL[name]
    ref = Simulation(name, cfg, prec).run(steps, **kw)
    fus = Simulation(name, cfg, prec).run(steps, execution="fused", **kw)
    return ref, fus


# ---------------------------------------------------------------------------
# parity: fused == reference, per stepper, across the mode ladder
# ---------------------------------------------------------------------------


class TestFusedParity:
    @pytest.mark.parametrize("name", BUILTINS)
    @pytest.mark.parametrize("preset", ["r2f2_16", "e5m10", "bf16", "f32"])
    def test_untracked_modes_bit_exact(self, name, preset):
        """With the field whole-in-block, the fused kernels run the same
        quantization at the same split as the reference engines — the two
        planes must agree bit for bit, snapshots included."""
        ref, fus = _pair(name, PRESETS[preset])
        np.testing.assert_array_equal(np.asarray(ref.state), np.asarray(fus.state))
        np.testing.assert_array_equal(np.asarray(ref.snapshots), np.asarray(fus.snapshots))
        assert fus.tracker is None

    @pytest.mark.parametrize("name", BUILTINS)
    def test_deploy_bit_exact_including_tracker(self, name):
        """deploy's bf16 datapath is split-independent, so the fused chunk's
        arithmetic AND its evidence-fed tracker must match the stepwise loop
        exactly."""
        ref, fus = _pair(name, PRESETS["deploy"])
        np.testing.assert_array_equal(np.asarray(ref.state), np.asarray(fus.state))
        np.testing.assert_array_equal(
            np.asarray(ref.tracker.state.k), np.asarray(fus.tracker.state.k)
        )
        np.testing.assert_array_equal(
            np.asarray(ref.tracker.state.hi_ema), np.asarray(fus.tracker.state.hi_ema)
        )

    @pytest.mark.parametrize("name", BUILTINS)
    def test_rr_tracked_close_and_same_final_k(self, name):
        """rr_tracked fused chunks hold the carried split as a floor for the
        whole chunk (the stepwise loop re-picks per step), so the states may
        differ below working precision — but the adjust unit must land on
        the same splits."""
        ref, fus = _pair(name, TRACKED)
        r, f = np.asarray(ref.state), np.asarray(fus.state)
        assert np.isfinite(f).all()
        assert np.linalg.norm(f - r) / max(np.linalg.norm(r), 1e-30) < 1e-2
        np.testing.assert_array_equal(
            np.asarray(ref.tracker.state.k), np.asarray(fus.tracker.state.k)
        )

    def test_all_builtins_are_fused_eligible(self):
        """The acceptance criterion: every registered stepper has a fused
        body for every builtin fused family."""
        for name in known_steppers():
            st = get_stepper(name)
            for mode in FUSED_FAMILIES:
                prec = dataclasses.replace(PRESETS["r2f2_16"], mode=mode)
                assert fused_eligible(prec, st, SMALL.get(name) or st.default_config())

    def test_snapshot_every_and_remainder_on_fused_path(self):
        res = Simulation("heat1d", SMALL["heat1d"], PRESETS["r2f2_16"]).run(
            103, snapshot_every=25, execution="fused"
        )
        ref = Simulation("heat1d", SMALL["heat1d"], PRESETS["r2f2_16"]).run(
            103, snapshot_every=25
        )
        assert res.snapshots.shape == (4, 64)
        np.testing.assert_array_equal(np.asarray(res.state), np.asarray(ref.state))


# ---------------------------------------------------------------------------
# tracker evidence: the fused chunk fold-in moves k like the stepwise loop
# ---------------------------------------------------------------------------


class TestTrackerEvidence:
    def test_fused_k_grows_like_stepwise(self):
        """heat1d from a deliberately narrow start: the fused chunks' range
        evidence must grow the carried split exactly like per-step
        tracker_update calls do."""
        sim = Simulation("heat1d", SMALL["heat1d"], TRACKED)
        tr0 = sim.init_tracker(k0=0)
        ref = sim.run(50, tracker=tr0)
        fus = sim.run(50, tracker=tr0, execution="fused")
        assert int(fus.tracker.k("heat.flux")) == TRACKED.fmt.fx
        np.testing.assert_array_equal(
            np.asarray(ref.tracker.state.k), np.asarray(fus.tracker.state.k)
        )

    def test_fused_k_shrinks_like_stepwise(self):
        """Burgers post-shock decay: the carried split must shrink below its
        wide start on the fused path too, landing where the stepwise loop
        lands (the §4.2 redundancy rule via chunk evidence)."""
        sim = Simulation("burgers1d", SMALL["burgers1d"], TRACKED)
        ref = sim.run(1200)
        fus = sim.run(1200, execution="fused")
        assert int(fus.tracker.k("burgers.uu")) < TRACKED.fmt.fx
        assert int(np.asarray(fus.tracker.state.shrink_steps).sum()) >= 1
        np.testing.assert_array_equal(
            np.asarray(ref.tracker.state.k), np.asarray(fus.tracker.state.k)
        )

    def test_fused_counters_match_stepwise(self):
        """§5.3 adjustment counters come from the same observe math, so the
        evidence replay must reproduce them."""
        ref, fus = _pair("burgers1d", TRACKED, steps=300)
        np.testing.assert_array_equal(
            np.asarray(ref.tracker.state.shrink_steps),
            np.asarray(fus.tracker.state.shrink_steps),
        )
        np.testing.assert_array_equal(
            np.asarray(ref.tracker.state.overflow_steps),
            np.asarray(fus.tracker.state.overflow_steps),
        )

    def test_fused_tracker_resumes(self):
        """Two chained fused runs == one long fused run (the folded tracker
        is the same resumable adjust-unit state as the stepwise one)."""
        sim = Simulation("burgers1d", SMALL["burgers1d"], TRACKED)
        a = sim.run(200, execution="fused")
        b = sim.run(200, state0=a.state, tracker=a.tracker, execution="fused")
        long = sim.run(400, execution="fused")
        np.testing.assert_array_equal(np.asarray(b.state), np.asarray(long.state))
        np.testing.assert_array_equal(
            np.asarray(b.tracker.state.k), np.asarray(long.tracker.state.k)
        )


# ---------------------------------------------------------------------------
# dispatch: auto fallback, strict "fused", eligibility surface
# ---------------------------------------------------------------------------


class _NoFusedStepper(Stepper):
    sites = ("nf.mul",)

    def default_config(self):
        return None

    def init_state(self, cfg):
        return jnp.ones((16,), jnp.float32)

    def step(self, u, cfg, ops):
        return ops.mul(jnp.float32(0.5), u, "nf.mul")


class TestFusedDispatch:
    def _with_stepper(self):
        from repro.pde.registry import _STEPPERS, register_stepper

        register_stepper("test_nofused", _NoFusedStepper)
        return _STEPPERS

    def test_auto_degrades_gracefully_without_fused_step(self):
        steppers = self._with_stepper()
        try:
            sim = Simulation("test_nofused", None, PRESETS["r2f2_16"])
            assert not sim.fused_eligible()
            auto = sim.run(5, execution="auto")
            ref = sim.run(5)
            np.testing.assert_array_equal(np.asarray(auto.state), np.asarray(ref.state))
        finally:
            steppers.pop("test_nofused", None)

    def test_explicit_fused_raises_without_fused_step(self):
        steppers = self._with_stepper()
        try:
            with pytest.raises(ValueError, match="not fused-eligible"):
                Simulation("test_nofused", None, PRESETS["r2f2_16"]).run(
                    5, execution="fused"
                )
        finally:
            steppers.pop("test_nofused", None)

    def test_auto_takes_fused_path_when_eligible(self):
        sim = Simulation("burgers1d", SMALL["burgers1d"], PRESETS["r2f2_16"])
        assert sim.fused_eligible()
        auto = sim.run(30, execution="auto")
        fused = sim.run(30, execution="fused")
        np.testing.assert_array_equal(np.asarray(auto.state), np.asarray(fused.state))

    def test_unknown_execution_mode_raises(self):
        with pytest.raises(ValueError, match="unknown execution mode"):
            Simulation("heat1d", SMALL["heat1d"], PRESETS["f32"]).run(
                4, execution="warp"
            )

    def test_unknown_mode_family_falls_back(self):
        """A mode without a fused arithmetic family is ineligible even when
        the stepper has a fused body (third-party engines default to the
        reference path)."""
        st = get_stepper("heat1d")
        assert FUSED_FAMILIES.get("rr_tile") == "rr"
        fake = dataclasses.replace(PRESETS["r2f2_16"])  # rr_tile: eligible
        assert fused_eligible(fake, st, SMALL["heat1d"])
        assert get_engine("rr_tile") is not None


# ---------------------------------------------------------------------------
# ensembles over the fused plane
# ---------------------------------------------------------------------------


class TestFusedEnsembles:
    def _batch(self, cfg, scales):
        return jnp.asarray(scales, jnp.float32)[:, None] * initial_wave(cfg)[None, :]

    def test_vmapped_fused_ensemble_matches_single_runs(self):
        cfg = SMALL["burgers1d"]
        sim = Simulation("burgers1d", cfg, PRESETS["r2f2_16"])
        u0b = self._batch(cfg, [0.5, 1.0, 2.0])
        ens = sim.run_ensemble(u0b, 60, execution="fused")
        assert ens.state.shape == (3, cfg.nx)
        for i in range(3):
            single = sim.run(60, state0=u0b[i], execution="fused")
            np.testing.assert_array_equal(
                np.asarray(ens.state[i]), np.asarray(single.state)
            )

    def test_tracked_fused_ensemble_has_per_member_trackers(self):
        cfg = SMALL["burgers1d"]
        sim = Simulation("burgers1d", cfg, TRACKED)
        ens = sim.run_ensemble(self._batch(cfg, [0.001, 1.0]), 30, execution="fused")
        k = np.asarray(ens.tracker.state.k)
        assert k.shape[0] == 2
        i_uu = ens.tracker.names.index("burgers.uu")
        assert k[0, i_uu] < k[1, i_uu]

    def test_sharded_fused_ensemble_runs_under_mesh(self):
        import jax
        from jax.sharding import Mesh

        from repro.dist.sharding import axis_rules

        cfg = SMALL["burgers1d"]
        sim = Simulation("burgers1d", cfg, PRESETS["r2f2_16"])
        u0b = self._batch(cfg, [1.0] * 4)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
        with mesh, axis_rules(mesh):
            ens = sim.run_ensemble(u0b, 20, sharded=True, execution="fused")
        assert ens.state.shape == (4, cfg.nx)
        assert np.isfinite(np.asarray(ens.state)).all()


# ---------------------------------------------------------------------------
# the shared sweep builder: padding, evidence plumbing, guard rails
# ---------------------------------------------------------------------------


class TestFusedSweepBuilder:
    def test_row_padding_is_cropped_and_range_neutral(self):
        """Batched rods whose row count doesn't divide block_rows: the padded
        rows are zeros, which can't shift any block's max exponent, so each
        real rod matches the same rod run alone."""
        from repro.kernels.heat_stencil import heat1d_sweep

        rng = np.random.default_rng(3)
        u = (500 * rng.normal(size=(5, 64))).astype(np.float32)  # 5 % 2 != 0
        prec = PRESETS["r2f2_16"]
        out, _ = heat1d_sweep(
            jnp.asarray(u), alpha=1e-5, dtodx2=4e4, prec=prec, steps=7, block_rows=2
        )
        assert out.shape == (5, 64)
        solo, _ = heat1d_sweep(
            jnp.asarray(u[4:5]), alpha=1e-5, dtodx2=4e4, prec=prec, steps=7, block_rows=1
        )
        np.testing.assert_array_equal(np.asarray(out[4:5]), np.asarray(solo))

    def test_evidence_shape_and_values(self):
        """Evidence is (steps, n_sites, 2) cross-block-maxed operand
        exponents — site order is the stepper's ``sites`` tuple."""
        from repro.kernels.pde_steps import burgers1d_sweep

        cfg = SMALL["burgers1d"]
        u0 = initial_wave(cfg)
        out, ev = burgers1d_sweep(
            u0, dt=cfg.dt, dx=cfg.dx, prec=TRACKED, steps=3, collect_evidence=True
        )
        assert ev.shape == (3, 2, 2)
        # burgers.uu multiplies u by u: both operand exponents equal, ~e(350)
        assert float(ev[0, 0, 0]) == float(ev[0, 0, 1]) == 8.0

    def test_multi_substep_leaf_mismatch_raises(self):
        from repro.kernels import fused

        def bad_body(state, ops):
            (a, b) = state
            return (ops.mul(a, b, "x.y"),)  # 2 leaves in, 1 out

        with pytest.raises(ValueError, match="fused body returned|in/out leaf counts"):
            fused.fused_sweep(
                bad_body,
                (jnp.ones((1, 8)), jnp.ones((1, 8))),
                prec=PRESETS["r2f2_16"],
                sites=("x.y",),
                steps=2,
                block=(1, 8),
            )

    def test_swe_flux_fused_padding_matches_unpadded(self):
        """Odd-shaped staggered SWE fields (the (nx-1, ny) midpoint grid)
        pad-and-crop without disturbing the real region: q3 pads with 1.0 so
        the divisor stays finite and range-neutral."""
        from repro.kernels.swe_flux import swe_flux_fused

        rng = np.random.default_rng(11)
        q3 = (500.0 + 100 * rng.normal(size=(127, 128))).astype(np.float32)
        q1 = (q3 * rng.normal(0, 5, (127, 128))).astype(np.float32)
        prec = PRESETS["r2f2_16"]
        padded, _ = swe_flux_fused(jnp.asarray(q1), jnp.asarray(q3), prec=prec)
        whole, _ = swe_flux_fused(
            jnp.asarray(q1), jnp.asarray(q3), prec=prec, block=(127, 128)
        )
        np.testing.assert_array_equal(np.asarray(padded), np.asarray(whole))
