"""The new scenario workloads: analytic convergence (2D heat Fourier-mode
decay, advection exact translation, Burgers conservation) and the paper's
precision pattern per stepper — E5M10 fails its failure mode, 16-bit R2F2
matches the f32 reference."""

import numpy as np
import pytest

from repro.core.policy import PRESETS
from repro.pde import (
    AdvectionConfig,
    BurgersConfig,
    Heat2DConfig,
    Simulation,
    initial_profile,
    initial_wave,
)


def _final(name, cfg, prec, steps):
    return np.asarray(Simulation(name, cfg, PRESETS[prec]).run(steps).state)


def _rel(out, ref):
    return np.linalg.norm(out - ref) / np.linalg.norm(ref)


class TestHeat2D:
    def test_fourier_mode_decay_analytic(self):
        """A single (mx, my) sin mode is an exact eigenvector of the 5-point
        Laplacian, so it decays geometrically at the discrete eigenvalue —
        which converges to the continuous exp(-alpha*|k|^2 t) rate."""
        cfg = Heat2DConfig(nx=64, ny=64, modes=(2, 1), amplitude=1.0)
        steps = 800
        out = _final("heat2d", cfg, "f32", steps)
        x = np.linspace(0, cfg.length, cfg.nx)
        y = np.linspace(0, cfg.length_y, cfg.ny)
        xx, yy = np.meshgrid(x, y, indexing="ij")
        mode = np.sin(2 * np.pi * xx / cfg.length) * np.sin(np.pi * yy / cfg.length_y)
        # per-step decay factor of the discrete mode (grid spacing is
        # length/(nx-1): linspace includes both Dirichlet endpoints)
        g = 1.0 - 4.0 * cfg.cfl * (
            np.sin(2 * np.pi / (2 * (cfg.nx - 1))) ** 2
            + np.sin(np.pi / (2 * (cfg.ny - 1))) ** 2
        )
        assert _rel(out, g**steps * mode) < 1e-3  # exact eigen-decay, f32 noise
        # and the discrete rate is the continuous one to O(dx^2)
        assert abs(-np.log(g) / (cfg.decay_rate * cfg.dt) - 1.0) < 0.05

    @pytest.mark.slow
    def test_e5m10_fails_r2f2_matches(self):
        """The 1D paper claim generalises: by 1.5k steps the decayed flux
        products sit below E5M10's floor (frozen dynamics) while 16-bit
        R2F2 still tracks f32."""
        cfg = Heat2DConfig()
        steps = 1500
        ref = _final("heat2d", cfg, "f32", steps)
        half = _final("heat2d", cfg, "e5m10", steps)
        rr = _final("heat2d", cfg, "r2f2_16", steps)
        assert _rel(half, ref) > 1.0  # grossly wrong
        assert _rel(rr, ref) < 0.05


class TestAdvection1D:
    def test_cfl1_upwind_translates_exactly(self):
        """At cfl=1 the upwind scheme is exact: nx steps translate the
        profile one full period (to f32 rounding — the update's
        ``u - (u - u_left)`` cancellation rounds the far gaussian tail)."""
        cfg = AdvectionConfig(nx=128, amplitude=1.0)
        u0 = np.asarray(initial_profile(cfg))
        out = _final("advection1d", cfg, "f32", cfg.nx)
        assert _rel(out, u0) < 1e-6
        # and a quarter period is the same profile rolled nx/4 cells
        quarter = _final("advection1d", cfg, "f32", cfg.nx // 4)
        assert _rel(quarter, np.roll(u0, cfg.nx // 4)) < 1e-6

    def test_e5m10_destroyed_r2f2_matches(self):
        """The 1e5-amplitude pulse overflows E5M10 in the flux multiply
        (inf -> NaN within a step); R2F2 widens k and stays within
        multiplier rounding of the exact translation."""
        cfg = AdvectionConfig()
        steps = cfg.nx  # one period: the f32 reference is the initial profile
        ref = _final("advection1d", cfg, "f32", steps)
        half = _final("advection1d", cfg, "e5m10", steps)
        rr = _final("advection1d", cfg, "r2f2_16", steps)
        assert not np.isfinite(half).all()
        assert np.isfinite(rr).all()
        assert _rel(rr, ref) < 0.05


class TestBurgers1D:
    def test_lax_friedrichs_conserves_mass(self):
        """Conservative form on a periodic domain: sum(u) is invariant."""
        cfg = BurgersConfig(nx=128)
        u0 = np.asarray(initial_wave(cfg))
        out = _final("burgers1d", cfg, "f32", 500)
        assert np.isfinite(out).all()
        assert abs(float(out.sum()) - float(u0.sum())) < 1e-2 * cfg.amplitude

    def test_shock_decays_amplitude(self):
        """Post-shock N-wave decay — the range drift the tracked modes ride."""
        cfg = BurgersConfig(nx=128)
        out = _final("burgers1d", cfg, "f32", 1200)
        assert np.abs(out).max() < 0.3 * cfg.amplitude

    @pytest.mark.slow
    def test_e5m10_destroyed_r2f2_matches(self):
        """u*u ~ 1.2e5 overflows E5M10 at t=0; R2F2's runtime split carries
        the squared range and matches f32 through shock formation."""
        cfg = BurgersConfig()
        steps = 1200
        ref = _final("burgers1d", cfg, "f32", steps)
        half = _final("burgers1d", cfg, "e5m10", steps)
        rr = _final("burgers1d", cfg, "r2f2_16", steps)
        assert not np.isfinite(half).all()
        assert np.isfinite(rr).all()
        assert _rel(rr, ref) < 0.05
