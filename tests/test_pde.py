"""PDE case studies: the paper's central claims as assertions."""

import numpy as np
import pytest

from repro.core.policy import PRESETS
from repro.pde import HeatConfig, SWEConfig, simulate_heat, simulate_swe


@pytest.fixture(scope="module")
def heat_ref():
    cfg = HeatConfig(nx=128, init="sin")
    ref, _ = simulate_heat(cfg, PRESETS["f32"], 4000)
    return cfg, np.asarray(ref)


class TestHeatClaims:
    def test_f32_decays(self, heat_ref):
        cfg, ref = heat_ref
        assert np.max(np.abs(ref)) < 0.2 * cfg.amplitude  # physics happened

    def test_e5m10_fails(self, heat_ref):
        """Paper Fig. 1: standard half produces wrong simulation results."""
        cfg, ref = heat_ref
        out, _ = simulate_heat(cfg, PRESETS["e5m10"], 4000)
        err = np.linalg.norm(np.asarray(out) - ref) / np.linalg.norm(ref)
        assert err > 1.0  # grossly wrong (dynamics frozen by underflow)

    @pytest.mark.parametrize("prec", ["r2f2_16", "r2f2_15"])
    def test_r2f2_matches_f32(self, heat_ref, prec):
        """Paper Fig. 7: 16/15-bit R2F2 achieve the f32 result."""
        cfg, ref = heat_ref
        out, _ = simulate_heat(cfg, PRESETS[prec], 4000)
        err = np.linalg.norm(np.asarray(out) - ref) / np.linalg.norm(ref)
        assert err < 0.05

    @pytest.mark.slow
    def test_exp_init_r2f2_beats_half(self):
        cfg = HeatConfig(nx=128, init="exp")
        ref, _ = simulate_heat(cfg, PRESETS["f32"], 4000)
        half, _ = simulate_heat(cfg, PRESETS["e5m10"], 4000)
        rr, _ = simulate_heat(cfg, PRESETS["r2f2_16"], 4000)
        ref = np.asarray(ref)
        e_half = np.linalg.norm(np.asarray(half) - ref) / np.linalg.norm(ref)
        e_rr = np.linalg.norm(np.asarray(rr) - ref) / np.linalg.norm(ref)
        assert e_rr < e_half / 2

    def test_heat_convergence_to_analytic(self):
        """f32 solver sanity: single sin mode decays as exp(-alpha k^2 t)."""
        cfg = HeatConfig(nx=256, init="sin", modes=1, amplitude=1.0)
        steps = 2000
        out, _ = simulate_heat(cfg, PRESETS["f32"], steps)
        x = np.linspace(0, cfg.length, cfg.nx)
        k = np.pi / cfg.length
        analytic = np.exp(-cfg.alpha * k * k * cfg.dt * steps) * np.sin(k * x)
        err = np.linalg.norm(np.asarray(out) - analytic) / np.linalg.norm(analytic)
        assert err < 0.01


class TestSWEClaims:
    @pytest.fixture(scope="class")
    def swe_ref(self):
        cfg = SWEConfig()
        ref, _ = simulate_swe(cfg, PRESETS["f32"], 400)
        return cfg, np.asarray(ref[0]) - cfg.depth

    def test_e5m10_destroys_simulation(self, swe_ref):
        """Paper Fig. 8c: E5M10 corrupts the run (h*h overflows 65504)."""
        cfg, _ = swe_ref
        out, _ = simulate_swe(cfg, PRESETS["e5m10"], 400)
        assert not np.isfinite(np.asarray(out)).all()

    @pytest.mark.parametrize("prec", ["r2f2_16", "r2f2_16_384"])
    def test_r2f2_tracks_f32(self, swe_ref, prec):
        """Paper Fig. 8b: R2F2 gives the same simulation (field corr)."""
        cfg, wref = swe_ref
        out, _ = simulate_swe(cfg, PRESETS[prec], 400)
        wout = np.asarray(out[0]) - cfg.depth
        assert np.isfinite(wout).all()
        corr = np.corrcoef(wout.reshape(-1), wref.reshape(-1))[0, 1]
        assert corr > 0.98

    def test_mass_conservation_f32(self):
        cfg = SWEConfig(nx=64, ny=64)
        U0_total = None
        from repro.pde.swe2d import initial_state

        U0 = initial_state(cfg)
        out, _ = simulate_swe(cfg, PRESETS["f32"], 200, U0=U0)
        m0 = float(np.sum(np.asarray(U0[0])))
        m1 = float(np.sum(np.asarray(out[0])))
        assert abs(m1 - m0) / m0 < 5e-3  # reflective walls conserve mass
