"""The repro.pde.solver framework: stepper registry, scan/snapshot driver,
tracker threading (the ISSUE 2 regression: rr_tracked PDE runs genuinely
carry k across steps), vmapped + sharded ensembles, and shim parity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import PRESETS, PrecisionConfig
from repro.pde import (
    BurgersConfig,
    HeatConfig,
    SimResult,
    Simulation,
    StepOps,
    Stepper,
    get_stepper,
    initial_wave,
    known_steppers,
    register_stepper,
    simulate_heat,
    simulate_swe,
    SWEConfig,
)
from repro.precision import SiteTracker, get_engine

TRACKED = dataclasses.replace(PRESETS["r2f2_16"], mode="rr_tracked")
BUILTINS = ("advection1d", "burgers1d", "heat1d", "heat2d", "swe2d")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class TestStepperRegistry:
    def test_builtins_registered(self):
        assert set(BUILTINS) <= set(known_steppers())

    def test_get_stepper_resolves(self):
        for name in BUILTINS:
            st = get_stepper(name)
            assert isinstance(st, Stepper)
            assert st.name == name
            assert st.sites, name  # every workload declares its sites
            assert st.failure_mode in ("underflow", "overflow", "nonlinear-drift")

    def test_unknown_stepper_raises(self):
        with pytest.raises(KeyError, match="no PDE stepper"):
            get_stepper("not-a-stepper")

    def test_custom_stepper_is_drop_in(self):
        """A registered stepper immediately drives through Simulation."""

        class DecayStepper(Stepper):
            sites = ("decay.mul",)

            def default_config(self):
                return None

            def init_state(self, cfg):
                return jnp.ones((16,), jnp.float32)

            def step(self, u, cfg, ops):
                return ops.mul(jnp.float32(0.5), u, "decay.mul")

        from repro.pde.registry import _STEPPERS

        try:
            register_stepper("test_decay", DecayStepper)
            res = Simulation("test_decay", None, PRESETS["f32"]).run(3)
            np.testing.assert_allclose(np.asarray(res.state), 0.125)
        finally:
            _STEPPERS.pop("test_decay", None)


# ---------------------------------------------------------------------------
# shim parity: the old per-workload simulate() == the framework, bit for bit
# ---------------------------------------------------------------------------


class TestShimParity:
    @pytest.mark.parametrize("prec", ["f32", "r2f2_16", "e5m10"])
    def test_heat_shim_is_framework(self, prec):
        cfg = HeatConfig(nx=64)
        out, snaps = simulate_heat(cfg, PRESETS[prec], 120)
        res = Simulation("heat1d", cfg, PRESETS[prec]).run(120)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(res.state))
        np.testing.assert_array_equal(np.asarray(snaps), np.asarray(res.snapshots))

    def test_swe_shim_is_framework(self):
        cfg = SWEConfig(nx=32, ny=32)
        out, snaps = simulate_swe(cfg, PRESETS["r2f2_16"], 40)
        res = Simulation("swe2d", cfg, PRESETS["r2f2_16"]).run(40)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(res.state))
        np.testing.assert_array_equal(np.asarray(snaps), np.asarray(res.snapshots))
        assert snaps.shape[0] == 4  # swe snapshots h only, 4 by default

    def test_snapshot_every_and_remainder(self):
        cfg = HeatConfig(nx=64)
        res = Simulation("heat1d", cfg, PRESETS["f32"]).run(103, snapshot_every=25)
        assert res.snapshots.shape == (4, 64)  # 103 = 4*25 + 3 remainder steps
        # remainder steps really ran: state != last snapshot
        assert not np.array_equal(np.asarray(res.state), np.asarray(res.snapshots[-1]))


# ---------------------------------------------------------------------------
# tracker threading — the regression this refactor exists for
# ---------------------------------------------------------------------------


class TestTrackerThreading:
    def test_untracked_modes_get_no_tracker(self):
        for prec in ("f32", "e5m10", "r2f2_16", "bf16"):
            sim = Simulation("heat1d", HeatConfig(nx=32), PRESETS[prec])
            assert sim.init_tracker() is None
            assert sim.run(5).tracker is None

    def test_tracked_mode_auto_tracker_covers_sites(self):
        sim = Simulation("heat1d", HeatConfig(nx=32), TRACKED)
        tr = sim.init_tracker()
        assert isinstance(tr, SiteTracker)
        assert tr.names == get_stepper("heat1d").sites

    def test_rr_tracked_k_grows_during_run(self):
        """From a narrow start, the carried split must grow to cover the
        heat workload's alpha~1e-5 underflow pressure — stateless selection
        cannot leave this trace."""
        sim = Simulation("heat1d", HeatConfig(nx=64), TRACKED)
        tr0 = sim.init_tracker(k0=0)
        res = sim.run(50, tracker=tr0)
        assert isinstance(res.tracker, SiteTracker)
        k0 = np.asarray(tr0.state.k)
        k1 = np.asarray(res.tracker.state.k)
        assert (k1 != k0).any(), "tracker state did not evolve during the run"
        assert int(res.tracker.k("heat.flux")) == TRACKED.fmt.fx

    def test_rr_tracked_k_shrinks_on_range_drift(self):
        """Burgers: u*u needs the full split at t=0, then post-shock decay
        collapses the range — the carried k must shrink back (the paper's
        §4.2 redundancy rule exercised across steps)."""
        sim = Simulation("burgers1d", BurgersConfig(nx=128), TRACKED)
        res = sim.run(1200)
        k_init = TRACKED.fmt.fx  # default tracker starts wide
        k_fin = int(res.tracker.k("burgers.uu"))
        assert k_fin < k_init
        assert int(np.asarray(res.tracker.state.shrink_steps).sum()) >= 1

    def test_deploy_mode_tracks_too(self):
        res = Simulation("burgers1d", BurgersConfig(nx=128), PRESETS["deploy"]).run(600)
        assert isinstance(res.tracker, SiteTracker)
        assert int(res.tracker.k("burgers.uu")) < PRESETS["deploy"].fmt.fx

    def test_rr_tracked_heat_matches_f32(self):
        """Accuracy: the tracked engine (k carried across steps) reproduces
        the f32 run like the stateless rr engine does."""
        cfg = HeatConfig(nx=128)
        ref, _ = simulate_heat(cfg, PRESETS["f32"], 1000)
        res = Simulation("heat1d", cfg, TRACKED).run(1000)
        err = np.linalg.norm(np.asarray(res.state) - np.asarray(ref)) / np.linalg.norm(
            np.asarray(ref)
        )
        assert err < 0.05

    def test_rr_tracked_swe_survives_range_ramp(self):
        """SWE from rest: hu ramps ~2 exponents/step at first, so a stale
        carried k would inf the momentum flux. The engine's Fig.-5 semantics
        (grow-and-retry within the step, shrink only via EMA evidence) must
        keep the tracked run finite and on the f32 solution."""
        cfg = SWEConfig(nx=64, ny=64)
        ref = np.asarray(Simulation("swe2d", cfg, PRESETS["f32"]).run(150).state)
        res = Simulation("swe2d", cfg, TRACKED).run(150)
        out = np.asarray(res.state)
        assert np.isfinite(out).all()
        w, wr = out[0] - cfg.depth, ref[0] - cfg.depth
        corr = np.corrcoef(w.reshape(-1), wr.reshape(-1))[0, 1]
        assert corr > 0.98

    def test_explicit_tracker_resumes(self):
        """Two chained runs == one long run (tracker is resumable state)."""
        sim = Simulation("burgers1d", BurgersConfig(nx=128), TRACKED)
        a = sim.run(200)
        b = sim.run(200, state0=a.state, tracker=a.tracker)
        long = sim.run(400)
        np.testing.assert_array_equal(np.asarray(b.state), np.asarray(long.state))
        np.testing.assert_array_equal(
            np.asarray(b.tracker.state.k), np.asarray(long.tracker.state.k)
        )


# ---------------------------------------------------------------------------
# ensembles
# ---------------------------------------------------------------------------


class TestEnsembles:
    def _batch(self, cfg, scales):
        return jnp.asarray(scales, jnp.float32)[:, None] * initial_wave(cfg)[None, :]

    def test_vmapped_ensemble_matches_single_runs(self):
        cfg = BurgersConfig(nx=64)
        sim = Simulation("burgers1d", cfg, PRESETS["r2f2_16"])
        u0b = self._batch(cfg, [0.5, 1.0, 2.0])
        ens = sim.run_ensemble(u0b, 100)
        assert ens.state.shape == (3, 64)
        assert ens.snapshots.shape[0] == 3
        for i in range(3):
            single = sim.run(100, state0=u0b[i])
            np.testing.assert_array_equal(
                np.asarray(ens.state[i]), np.asarray(single.state)
            )

    def test_tracked_ensemble_has_per_member_trackers(self):
        """Each member carries its own adjust-unit state: a small-amplitude
        member must settle on a smaller split than a large one."""
        cfg = BurgersConfig(nx=64)
        sim = Simulation("burgers1d", cfg, TRACKED)
        ens = sim.run_ensemble(self._batch(cfg, [0.001, 1.0]), 30)
        k = np.asarray(ens.tracker.state.k)
        assert k.shape[0] == 2  # leading member dim
        i_uu = ens.tracker.names.index("burgers.uu")
        assert k[0, i_uu] < k[1, i_uu]

    def test_sharded_ensemble_runs_under_mesh(self):
        from jax.sharding import Mesh

        from repro.dist.sharding import axis_rules

        cfg = BurgersConfig(nx=64)
        sim = Simulation("burgers1d", cfg, PRESETS["r2f2_16"])
        u0b = self._batch(cfg, [1.0] * 4)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
        with mesh, axis_rules(mesh):
            ens = sim.run_ensemble(u0b, 20, sharded=True)
        assert ens.state.shape == (4, 64)
        assert np.isfinite(np.asarray(ens.state)).all()


# ---------------------------------------------------------------------------
# StepOps + engine `tracks` contract
# ---------------------------------------------------------------------------


class TestStepOps:
    def test_tracks_attribute(self):
        assert get_engine("rr_tracked").tracks
        assert get_engine("deploy").tracks
        for mode in ("f32", "bf16", "fixed", "rr_tile"):
            assert not get_engine(mode).tracks

    def test_stepops_untracked_matches_module_multiply(self):
        from repro.precision import multiply

        a = jnp.asarray(np.random.default_rng(0).normal(0, 30, (64,)), jnp.float32)
        for prec in ("f32", "e5m10", "r2f2_16", "bf16"):
            cfg = PRESETS[prec]
            ops = StepOps(cfg)
            np.testing.assert_array_equal(
                np.asarray(ops.mul(a, a, "x.y")),
                np.asarray(multiply(a, a, cfg, site="x.y")),
            )
            assert ops.tracker is None

    def test_stepops_div_store(self):
        cfg = PRESETS["e5m10"]
        ops = StepOps(cfg)
        a = jnp.asarray([1.5, 2.5, 3.75], jnp.float32)
        from repro.precision import divide, store

        np.testing.assert_array_equal(
            np.asarray(ops.div(a, a + 1)), np.asarray(divide(a, a + 1, cfg))
        )
        np.testing.assert_array_equal(
            np.asarray(ops.store(a)), np.asarray(store(a, cfg))
        )

    def test_simresult_fields(self):
        res = Simulation("heat1d", HeatConfig(nx=32), PRESETS["f32"]).run(4)
        assert isinstance(res, SimResult)
        assert res.tracker is None
        assert res.state.shape == (32,)
