"""Validation of the trip-count-aware HLO cost rollup (launch/hlo_cost.py)
against programs with hand-computable costs — the measurement layer behind
EXPERIMENTS.md §Roofline."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import parse_hlo_costs

MM_FLOPS = 2 * 256 * 512 * 512  # one (256,512)x(512,512) matmul


def _compile_text(f, *sds):
    return jax.jit(f).lower(*sds).compile().as_text()


X = jax.ShapeDtypeStruct((256, 512), jnp.float32)
W = jax.ShapeDtypeStruct((512, 512), jnp.float32)


class TestTripCounts:
    @pytest.mark.parametrize("L", [1, 4, 16, 64])
    def test_scan_multiplies_body_cost(self, L):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None

            y, _ = jax.lax.scan(body, x, None, length=L)
            return y

        cost = parse_hlo_costs(_compile_text(f, X, W))
        assert cost["flops"] == pytest.approx(L * MM_FLOPS, rel=0.01)

    def test_nested_scan(self):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None

            def outer(c, _):
                c, _ = jax.lax.scan(body, c, None, length=4)
                return c, None

            y, _ = jax.lax.scan(outer, x, None, length=4)
            return y

        cost = parse_hlo_costs(_compile_text(f, X, W))
        assert cost["flops"] == pytest.approx(16 * MM_FLOPS, rel=0.01)

    def test_naive_cost_analysis_misses_trips(self):
        """Documents WHY this module exists: XLA counts loop bodies once."""

        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None

            y, _ = jax.lax.scan(body, x, None, length=16)
            return y

        compiled = jax.jit(f).lower(X, W).compile()
        naive = compiled.cost_analysis()
        if isinstance(naive, (list, tuple)):  # older jax wraps in a list
            naive = naive[0] if naive else {}
        naive = naive.get("flops", 0.0)
        assert naive < 2 * MM_FLOPS  # counts ~1 matmul, not 16
        corrected = parse_hlo_costs(compiled.as_text())["flops"]
        assert corrected == pytest.approx(16 * MM_FLOPS, rel=0.01)


class TestBytesModel:
    def test_scan_bytes_near_hand_model(self):
        # VMEM-resident small operands charged once per loop entry; per-iter
        # traffic = dot result (.5M) + tanh fusion (.5M) = 1MB x 16 iters,
        # plus one residency charge for x and w (~1.5M) ~ 17.5MB.
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None

            y, _ = jax.lax.scan(body, x, None, length=16)
            return y

        cost = parse_hlo_costs(_compile_text(f, X, W))
        assert 8e6 < cost["bytes"] < 48e6


class TestCollectives:
    def test_collective_inside_scan_multiplied(self):
        import os
        import subprocess
        import sys

        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_cost import parse_hlo_costs
mesh = jax.make_mesh((8,), ("model",))
def f(x, w):
    def body(c, _):
        y = c @ w  # w sharded on the contracting dim -> all-reduce per iter
        return jax.lax.with_sharding_constraint(jnp.tanh(y), NamedSharding(mesh, P())), None
    out, _ = jax.lax.scan(body, x, None, length=5)
    return out
x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
with mesh:
    c = jax.jit(f, in_shardings=(NamedSharding(mesh, P()), NamedSharding(mesh, P("model", None)))).lower(x, w).compile()
cost = parse_hlo_costs(c.as_text())
ar = cost["collective_bytes"].get("all-reduce", 0)
expect = 5 * 256 * 512 * 4
assert abs(ar - expect) / expect < 0.01, (ar, expect)
print("COLL_OK", ar)
"""
        env = dict(os.environ, PYTHONPATH="src")
        env.pop("XLA_FLAGS", None)
        r = subprocess.run(
            [sys.executable, "-c", code],
            capture_output=True,
            text=True,
            env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert "COLL_OK" in r.stdout, r.stderr[-1500:]
