"""The repro.precision engine surface: registry, named sites, shim parity,
storage-format round-trip, and Pallas kernel dispatch (ISSUE 1 acceptance)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rr_einsum, rr_operand
from repro.core.flexformat import FlexFormat, pack_r2f2, quantize_em, unpack_r2f2
from repro.core.policy import KNOWN_MODES, PRESETS, PrecisionConfig, tracker_init
from repro.pde.precision_ops import pdiv, pmul, pstore
from repro.precision import (
    PrecisionEngine,
    SiteTracker,
    contract,
    divide,
    dot,
    get_engine,
    multiply,
    prepare_operand,
    register_engine,
    site_tracker_init,
    store,
)

FMT = FlexFormat(3, 9, 3)
ALL_MODES = ("f32", "bf16", "fixed", "rr_tile", "rr_tracked", "deploy")


def _data(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return (scale * rng.normal(0, 1, shape)).astype(np.float32)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_all_presets_resolve(self):
        for name, cfg in PRESETS.items():
            eng = get_engine(cfg)
            assert isinstance(eng, PrecisionEngine), name
            assert eng.name == cfg.mode

    def test_all_modes_resolve(self):
        for mode in ALL_MODES:
            assert get_engine(mode).name == mode

    def test_unknown_mode_raises(self):
        with pytest.raises(KeyError, match="no precision engine"):
            get_engine("not-a-mode")

    def test_unknown_config_mode_raises_at_construction(self):
        with pytest.raises(ValueError, match="unknown precision mode"):
            PrecisionConfig(mode="not-a-mode")

    def test_custom_engine_is_drop_in(self):
        """A registered engine immediately becomes a valid config mode and
        receives dispatch — the fp8/stochastic-rounding extension path."""

        class NegatingEngine(PrecisionEngine):
            def prepare_operand(self, x, cfg, *, k=None):
                return -jnp.asarray(x, jnp.float32), None

        try:
            register_engine("test_negate", NegatingEngine)
            assert "test_negate" in KNOWN_MODES
            cfg = PrecisionConfig(mode="test_negate")
            x = _data((4, 4), seed=1)
            w = _data((4, 4), seed=2)
            out = contract("md,df->mf", x, w, cfg)  # (-x) @ (-w) == x @ w
            np.testing.assert_allclose(np.asarray(out), x @ w, rtol=1e-6)
            assert not cfg.is_emulated
        finally:
            from repro.precision.registry import _REGISTRY

            _REGISTRY.pop("test_negate", None)
            KNOWN_MODES.discard("test_negate")


# ---------------------------------------------------------------------------
# uniform return contract (the historical rr_einsum inconsistency)
# ---------------------------------------------------------------------------


class TestReturnContract:
    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_tracker_in_tuple_out_every_mode(self, mode):
        cfg = PrecisionConfig(mode=mode, fmt=FMT)
        tr = tracker_init(1, FMT)
        a, b = _data((8, 8), 1), _data((8, 8), 2)
        res = rr_einsum("md,df->mf", a, b, cfg, tracker=tr, site=0)
        assert isinstance(res, tuple) and len(res) == 2, mode
        out, tr_out = res
        assert out.shape == (8, 8)
        assert tr_out is not None

    @pytest.mark.parametrize("mode", [m for m in ALL_MODES if m != "rr_tracked"])
    def test_no_tracker_bare_array_every_mode(self, mode):
        cfg = PrecisionConfig(mode=mode, fmt=FMT)
        out = rr_einsum("md,df->mf", _data((8, 8), 1), _data((8, 8), 2), cfg)
        assert not isinstance(out, tuple), mode

    def test_rr_tracked_without_tracker_raises(self):
        cfg = PrecisionConfig(mode="rr_tracked", fmt=FMT)
        with pytest.raises(ValueError, match="tracker"):
            rr_einsum("md,df->mf", _data((8, 8)), _data((8, 8)), cfg)


# ---------------------------------------------------------------------------
# named sites
# ---------------------------------------------------------------------------


class TestSiteTracker:
    def test_named_equals_legacy_integer_sites(self):
        """SiteTracker + name must be bit-identical to RangeTracker + index."""
        cfg = PrecisionConfig(mode="rr_tracked", fmt=FMT, ema=0.5)
        st = site_tracker_init(("attn.qk", "heat.flux"), FMT)
        raw = tracker_init(2, FMT)
        a, b = _data((16, 16), 3, scale=30.0), _data((16, 16), 4)
        for _ in range(3):
            o_named, st = contract("md,df->mf", a, b, cfg, tracker=st, site="heat.flux")
            o_raw, raw = rr_einsum("md,df->mf", a, b, cfg, tracker=raw, site=1)
            np.testing.assert_array_equal(np.asarray(o_named), np.asarray(o_raw))
        np.testing.assert_array_equal(np.asarray(st.state.k), np.asarray(raw.k))
        assert int(st.k("heat.flux")) == int(raw.k[1])

    def test_unknown_site_name_raises(self):
        st = site_tracker_init(("a.b",), FMT)
        cfg = PrecisionConfig(mode="rr_tracked", fmt=FMT)
        with pytest.raises(KeyError, match="unknown precision site"):
            contract("md,df->mf", _data((4, 4)), _data((4, 4)), cfg, tracker=st, site="zzz")

    def test_named_site_on_raw_tracker_raises(self):
        cfg = PrecisionConfig(mode="rr_tracked", fmt=FMT)
        with pytest.raises(TypeError, match="SiteTracker"):
            contract(
                "md,df->mf", _data((4, 4)), _data((4, 4)), cfg,
                tracker=tracker_init(1, FMT), site="attn.qk",
            )

    def test_roundtrip_under_jit(self):
        cfg = PrecisionConfig(mode="rr_tracked", fmt=FMT)
        st = site_tracker_init(("mlp.up", "mlp.down"), FMT)
        w = _data((16, 16), 5)

        @jax.jit
        def step(st, x):
            h, st = contract("md,df->mf", x, w, cfg, tracker=st, site="mlp.up")
            out, st = contract("md,df->mf", h, w, cfg, tracker=st, site="mlp.down")
            return out, st

        x = _data((8, 16), 6, scale=100.0)
        out, st2 = step(st, x)
        assert isinstance(st2, SiteTracker)
        assert st2.names == st.names  # names are static aux data
        assert np.isfinite(np.asarray(out)).all()
        assert int(st2.state.overflow_steps.sum()) >= 0

    def test_roundtrip_under_scan(self):
        """SiteTracker threads through lax.scan like any carried state."""
        cfg = PrecisionConfig(mode="rr_tracked", fmt=FMT, ema=0.5)
        st = site_tracker_init(("site.a",), FMT, k0=0)
        w = _data((16, 16), 7)
        xs = jnp.asarray(_data((5, 8, 16), 8, scale=1e4))  # spike: k must grow

        def body(st, x):
            out, st = contract("md,df->mf", x, w, cfg, tracker=st, site="site.a")
            return st, out

        st_fin, outs = jax.lax.scan(body, st, xs)
        assert isinstance(st_fin, SiteTracker)
        assert outs.shape == (5, 8, 16)
        assert int(st_fin.k("site.a")) == FMT.fx  # 1e4 operands need the full split

    def test_duplicate_site_names_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            site_tracker_init(("a", "a"), FMT)

    def test_multiply_threads_named_sites(self):
        """The PDE-facing elementwise op supports the same tracker contract."""
        cfg = PrecisionConfig(mode="rr_tracked", fmt=FMT)
        st = site_tracker_init(("heat.flux",), FMT, k0=0)
        a = jnp.float32(3e4) * jnp.abs(jnp.asarray(_data((64,), 9))) + 1.0
        out, st = multiply(a, a, cfg, tracker=st, site="heat.flux")
        assert isinstance(st, SiteTracker)
        assert int(st.k("heat.flux")) == FMT.fx  # 9e8 product forces max k


# ---------------------------------------------------------------------------
# shim equivalence: old surface == engine surface, bit for bit
# ---------------------------------------------------------------------------


class TestShimEquivalence:
    A = _data((32, 48), 10, scale=10.0)
    B = _data((48, 16), 11, scale=0.1)

    @pytest.mark.parametrize("mode", [m for m in ALL_MODES if m != "rr_tracked"])
    def test_rr_einsum_matches_contract(self, mode):
        cfg = PrecisionConfig(mode=mode, fmt=FMT)
        old = rr_einsum("md,df->mf", self.A, self.B, cfg)
        new = contract("md,df->mf", self.A, self.B, cfg)
        np.testing.assert_array_equal(np.asarray(old), np.asarray(new))

    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_pmul_matches_multiply(self, mode):
        cfg = PrecisionConfig(mode=mode, fmt=FMT)
        old = pmul(self.A, self.A + 1.0, cfg)
        new = multiply(self.A, self.A + 1.0, cfg)
        np.testing.assert_array_equal(np.asarray(old), np.asarray(new))

    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_pstore_pdiv_match_engine(self, mode):
        cfg = PrecisionConfig(mode=mode, fmt=FMT)
        np.testing.assert_array_equal(
            np.asarray(pstore(self.A, cfg)), np.asarray(store(self.A, cfg))
        )
        np.testing.assert_array_equal(
            np.asarray(pdiv(self.A, self.A + 2.0, cfg)),
            np.asarray(divide(self.A, self.A + 2.0, cfg)),
        )

    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_rr_operand_matches_prepare_operand(self, mode):
        cfg = PrecisionConfig(mode=mode, fmt=FMT)
        xo, ko = rr_operand(self.A, cfg)
        xn, kn = prepare_operand(self.A, cfg)
        np.testing.assert_array_equal(np.asarray(xo), np.asarray(xn))
        assert (ko is None) == (kn is None)

    def test_known_mode_semantics_preserved(self):
        """Engines reproduce the documented per-mode arithmetic — guards the
        migration itself, not just shim wiring."""
        a, b = self.A, self.B
        np.testing.assert_array_equal(
            np.asarray(contract("md,df->mf", a, b, PRESETS["f32"])),
            np.asarray(jnp.einsum("md,df->mf", a, b)),
        )
        bq = lambda x: jnp.asarray(x).astype(jnp.bfloat16).astype(jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(contract("md,df->mf", a, b, PRESETS["bf16"])),
            np.asarray(
                jnp.einsum("md,df->mf", bq(a), bq(b), preferred_element_type=jnp.float32)
            ),
        )
        e, m = PRESETS["e5m10"].fixed_em
        np.testing.assert_array_equal(
            np.asarray(contract("md,df->mf", a, b, PRESETS["e5m10"])),
            np.asarray(jnp.einsum("md,df->mf", quantize_em(a, e, m), quantize_em(b, e, m))),
        )

    def test_ste_gradient_preserved(self):
        """Emulated contractions stay trainable (straight-through grads)."""
        cfg = PRESETS["r2f2_16"]
        w = jnp.asarray(_data((16, 8), 12))

        def loss(w):
            return jnp.sum(contract("md,df->mf", jnp.asarray(self.A[:, :16]), w, cfg) ** 2)

        g = jax.grad(loss)(w)
        assert np.isfinite(np.asarray(g)).all()
        assert float(jnp.abs(g).sum()) > 0.0


# ---------------------------------------------------------------------------
# pack/unpack storage round-trip (hypothesis-free property sweep)
# ---------------------------------------------------------------------------


class TestPackRoundTrip:
    @pytest.mark.parametrize("k", [0, 1, 2, 3])
    def test_roundtrip_all_k(self, k):
        """Any quantize_em output must survive the Fig. 4a storage layout
        bit-exactly, for every flexible split of <3,9,3>."""
        e_bits, m_bits = FMT.em(k)
        rng = np.random.default_rng(100 + k)
        x = np.concatenate(
            [
                (rng.normal(0, 1, 4096) * 10.0 ** rng.integers(-8, 8, 4096)),
                [0.0, -0.0, np.inf, -np.inf, 1e-30, -1e-30, 65504.0, 1.84e19],
            ]
        ).astype(np.float32)
        xq = np.asarray(quantize_em(x, e_bits, m_bits))
        payload = np.asarray(pack_r2f2(xq, FMT, k))
        assert int(payload.max()) < (1 << FMT.total_bits)  # fits the 16-bit word
        back = np.asarray(unpack_r2f2(payload, FMT, k))
        np.testing.assert_array_equal(back, xq)
        # signed zero survives the trip
        assert np.signbit(back[np.signbit(xq) & (xq == 0)]).all()

    def test_roundtrip_per_element_k(self):
        """k may vary per element (per-tile metadata)."""
        rng = np.random.default_rng(200)
        x = (rng.normal(0, 1, 1024) * 10.0 ** rng.integers(-6, 6, 1024)).astype(np.float32)
        k = rng.integers(0, FMT.fx + 1, 1024).astype(np.int32)
        xq = np.asarray(quantize_em(x, FMT.eb + k, FMT.mb + FMT.fx - k))
        back = np.asarray(unpack_r2f2(pack_r2f2(xq, FMT, k), FMT, k))
        np.testing.assert_array_equal(back, xq)


# ---------------------------------------------------------------------------
# Pallas kernel dispatch (ISSUE 1 acceptance criterion)
# ---------------------------------------------------------------------------


class TestKernelDispatch:
    def _spy(self, monkeypatch):
        from repro.kernels import ops as kernel_ops

        calls = []
        real = kernel_ops.r2f2_matmul

        def spy(*args, **kw):
            calls.append((args, kw))
            return real(*args, **kw)

        monkeypatch.setattr(kernel_ops, "r2f2_matmul", spy)
        return calls

    def test_rr_einsum_reaches_pallas_kernel(self, monkeypatch):
        """rr_einsum + PRESETS['r2f2_16'] + use_kernels on a 256x256
        block-divisible matmul must hit kernels.ops.r2f2_matmul."""
        calls = self._spy(monkeypatch)
        cfg = dataclasses.replace(PRESETS["r2f2_16"], use_kernels=True)
        a, b = _data((256, 256), 20), _data((256, 256), 21)
        out = rr_einsum("mk,kn->mn", a, b, cfg)
        assert len(calls) == 1, "policy did not select the Pallas fast path"
        assert out.shape == (256, 256)
        # and the policy path returns exactly what the kernel returns
        from repro.kernels import ops as kernel_ops

        direct = kernel_ops.r2f2_matmul(a, b, cfg.fmt, tail_approx=cfg.tail_approx)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(direct))

    def test_dot_reaches_kernel_too(self, monkeypatch):
        calls = self._spy(monkeypatch)
        cfg = dataclasses.replace(PRESETS["r2f2_16"], use_kernels=True)
        dot(_data((128, 128), 22), _data((128, 128), 23), cfg)
        assert len(calls) == 1

    def test_no_dispatch_without_knob(self, monkeypatch):
        calls = self._spy(monkeypatch)
        rr_einsum("mk,kn->mn", _data((256, 256), 24), _data((256, 256), 25), PRESETS["r2f2_16"])
        assert calls == []

    def test_no_dispatch_on_ineligible_specs(self, monkeypatch):
        calls = self._spy(monkeypatch)
        cfg = dataclasses.replace(PRESETS["r2f2_16"], use_kernels=True)
        # not a 2-D row-by-column contraction
        rr_einsum("bmk,kn->bmn", _data((2, 128, 128), 28), _data((128, 128), 29), cfg)
        rr_einsum("mk,nk->mn", _data((128, 128), 30), _data((128, 128), 31), cfg)
        assert calls == []

    def test_non_divisible_shapes_dispatch_via_pad_and_crop(self, monkeypatch):
        """Odd shapes stay kernel-eligible: the kernel zero-pads up to block
        multiples and crops — padded zeros can't raise a block's max
        exponent, so the real region matches the padded oracle exactly."""
        from repro.kernels import ref

        calls = self._spy(monkeypatch)
        cfg = dataclasses.replace(PRESETS["r2f2_16"], use_kernels=True)
        a, b = _data((192, 192), 26), _data((192, 192), 27)
        out = rr_einsum("mk,kn->mn", a, b, cfg)
        assert len(calls) == 1, "non-divisible matmul no longer dispatches"
        pad = [(0, 64), (0, 64)]
        oracle = ref.r2f2_matmul_ref(np.pad(a, pad), np.pad(b, pad), fmt=cfg.fmt)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle)[:192, :192])

    def test_kernel_blocks_is_a_policy_knob(self, monkeypatch):
        """cfg.kernel_blocks — not the kernel module's defaults — picks the
        fast path's tiling."""
        calls = self._spy(monkeypatch)
        cfg = dataclasses.replace(
            PRESETS["r2f2_16"], use_kernels=True, kernel_blocks=(64, 64, 64)
        )
        rr_einsum("mk,kn->mn", _data((128, 128), 40), _data((128, 128), 41), cfg)
        assert len(calls) == 1
        assert calls[0][1]["blocks"] == (64, 64, 64)

    def test_no_dispatch_for_non_rr_engines(self, monkeypatch):
        calls = self._spy(monkeypatch)
        for preset in ("f32", "bf16", "e5m10", "deploy"):
            cfg = dataclasses.replace(PRESETS[preset], use_kernels=True)
            rr_einsum("mk,kn->mn", _data((256, 256), 32), _data((256, 256), 33), cfg)
        assert calls == []

    def test_kernel_path_close_to_emulation(self):
        """Fast path and jnp emulation agree to rr-16 tolerance (they differ
        only in k granularity: per block pair vs per operand tile)."""
        cfg = dataclasses.replace(PRESETS["r2f2_16"], use_kernels=True)
        a, b = _data((256, 256), 34), _data((256, 256), 35, scale=0.05)
        fast = np.asarray(rr_einsum("mk,kn->mn", a, b, cfg))
        slow = np.asarray(rr_einsum("mk,kn->mn", a, b, PRESETS["r2f2_16"]))
        rel = np.linalg.norm(fast - slow) / np.linalg.norm(slow)
        assert rel < 2e-3
