"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp ref oracle,
swept over shapes, block sizes, and format configurations."""

import numpy as np
import pytest

from repro.core.flexformat import FlexFormat
from repro.kernels import ops, ref

FMTS = [FlexFormat(3, 9, 3), FlexFormat(3, 8, 4), FlexFormat(3, 7, 3), FlexFormat(5, 10, 0)]


def _data(shape, scale_exp_range=(-3, 4), seed=0):
    rng = np.random.default_rng(seed)
    return (
        rng.normal(0, 1, shape) * 10.0 ** rng.integers(*scale_exp_range, shape)
    ).astype(np.float32)


class TestQuantizeKernel:
    @pytest.mark.parametrize("shape", [(256, 256), (512, 256), (256, 768), (1024, 1024)])
    @pytest.mark.parametrize("fmt", FMTS, ids=str)
    def test_matches_ref(self, shape, fmt):
        x = _data(shape, seed=hash(shape) % 1000)
        yk, kk = ops.r2f2_quantize(x, fmt)
        yr, kr = ref.r2f2_quantize_ref(x, fmt=fmt)
        np.testing.assert_array_equal(np.asarray(yk), np.asarray(yr))
        np.testing.assert_array_equal(np.asarray(kk), np.asarray(kr))

    @pytest.mark.parametrize("block", [(128, 128), (256, 128), (128, 256)])
    def test_block_sweep(self, block):
        x = _data((512, 512), seed=5)
        fmt = FMTS[0]
        yk, _ = ops.r2f2_quantize(x, fmt, block=block)
        yr, _ = ref.r2f2_quantize_ref(x, fmt=fmt, block=block)
        np.testing.assert_array_equal(np.asarray(yk), np.asarray(yr))

    def test_k_respects_range(self):
        x = np.full((256, 256), 1e6, np.float32)  # big values: k must grow
        _, k = ops.r2f2_quantize(x, FMTS[0])
        assert int(np.asarray(k).max()) == 3


class TestMatmulKernel:
    @pytest.mark.parametrize(
        "mnk", [(128, 128, 128), (256, 128, 384), (128, 256, 128), (384, 384, 256)]
    )
    @pytest.mark.parametrize("fmt", FMTS[:2], ids=str)
    def test_matches_ref(self, mnk, fmt):
        m, n, k = mnk
        a = _data((m, k), (-2, 2), seed=m + n)
        b = _data((k, n), (-2, 2), seed=k)
        ck = ops.r2f2_matmul(a, b, fmt)
        cr = ref.r2f2_matmul_ref(a, b, fmt=fmt)
        np.testing.assert_allclose(np.asarray(ck), np.asarray(cr), rtol=0, atol=0)

    def test_round_products_mode(self):
        a = _data((128, 128), (-1, 1), seed=1)
        b = _data((128, 128), (-1, 1), seed=2)
        fmt = FMTS[0]
        ck = ops.r2f2_matmul(a, b, fmt, blocks=(64, 64, 64), round_products=True)
        cr = ref.r2f2_matmul_ref(a, b, fmt=fmt, blocks=(64, 64, 64), round_products=True)
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))

    def test_close_to_f32(self):
        rng = np.random.default_rng(9)
        a = rng.normal(0, 1, (256, 256)).astype(np.float32)
        b = rng.normal(0, 0.05, (256, 256)).astype(np.float32)
        c = np.asarray(ops.r2f2_matmul(a, b, FMTS[0]))
        rel = np.linalg.norm(c - a @ b) / np.linalg.norm(a @ b)
        assert rel < 1e-3  # 12-bit mantissa at k=0


class TestHeatKernel:
    @pytest.mark.parametrize(
        "steps", [1, 10, pytest.param(100, marks=pytest.mark.slow)]
    )
    def test_matches_ref(self, steps):
        u0 = (
            500 * np.sin(np.linspace(0, 3 * np.pi, 512))[None] * np.ones((8, 1))
        ).astype(np.float32)
        fmt = FMTS[0]
        hk = ops.heat_stencil(u0, 1e-5, 4e4, fmt, steps=steps)
        hr = ref.heat_stencil_ref(u0, 1e-5, 4e4, fmt=fmt, steps=steps)
        np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr))

    def test_matches_solver(self):
        """The fused kernel must agree with repro.pde.heat1d exactly."""
        from repro.core.policy import PRESETS
        from repro.pde import HeatConfig, simulate_heat
        from repro.pde.heat1d import initial_condition

        cfg = HeatConfig(nx=256)
        u0 = np.tile(np.asarray(initial_condition(cfg)), (8, 1))
        k_out = ops.heat_stencil(u0, cfg.alpha, cfg.dtodx2, FMTS[0], steps=50)
        sol, _ = simulate_heat(cfg, PRESETS["r2f2_16"], 50)
        np.testing.assert_array_equal(np.asarray(k_out)[0], np.asarray(sol))


class TestBlockOps:
    """kernels/blockops.py consolidated the per-kernel R2F2 block helpers;
    the move must be bit-invisible."""

    def test_rr_mul_block_matches_pre_move_helper(self):
        """Bit-identity against an inline copy of the helper both kernels
        carried before the consolidation."""
        from jax import numpy as jnp

        from repro.core.flexformat import quantize_em, unbiased_exponent
        from repro.core.r2f2 import product_guard_bits, select_k
        from repro.kernels.blockops import rr_mul_block

        def legacy(a, b, fmt, tail_approx):
            def tile_max_exp(t):
                mag = jnp.where(jnp.isfinite(t), jnp.abs(t), 0.0)
                return unbiased_exponent(jnp.maximum(jnp.max(mag), jnp.float32(1e-38)))

            k = select_k(tile_max_exp(a), tile_max_exp(b), fmt)
            e_b, m_b = fmt.eb + k, fmt.mb + fmt.fx - k
            aq = quantize_em(a, e_b, m_b)
            bq = quantize_em(b, e_b, m_b)
            guard = product_guard_bits(fmt, k) if tail_approx else None
            return quantize_em(aq * bq, e_b, m_b, tail_trunc_bits=guard)

        rng = np.random.default_rng(42)
        for fmt in FMTS:
            for tail in (True, False):
                a = jnp.asarray(_data((64, 128), (-4, 5), seed=rng.integers(1e6)))
                b = jnp.asarray(_data((64, 128), (-4, 5), seed=rng.integers(1e6)))
                np.testing.assert_array_equal(
                    np.asarray(rr_mul_block(a, b, fmt, tail)),
                    np.asarray(legacy(a, b, fmt, tail)),
                )

    def test_both_kernels_share_the_helper(self):
        """The dedup satellite: neither kernel module re-defines a private
        block-multiply helper anymore."""
        from repro.kernels import blockops, heat_stencil, swe_flux

        assert heat_stencil.rr_mul_block is blockops.rr_mul_block
        assert swe_flux.rr_mul_block is blockops.rr_mul_block


class TestSWEFluxKernel:
    @pytest.mark.parametrize("shape", [(64, 128), (128, 256), (128, 128)])
    def test_matches_ref(self, shape):
        rng = np.random.default_rng(11)
        q3 = (500.0 + 100 * rng.normal(size=shape)).astype(np.float32)
        q1 = (q3 * rng.normal(0, 5, shape)).astype(np.float32)
        fmt = FMTS[0]
        fk = ops.swe_flux(q1, q3, fmt)
        fr = ref.swe_flux_ref(q1, q3, fmt=fmt)
        np.testing.assert_array_equal(np.asarray(fk), np.asarray(fr))

    def test_matches_solver_equation(self):
        """Kernel == repro.pde.swe2d._momentum_flux_x under rr_tile policy."""
        from repro.core.policy import PRESETS
        from repro.pde.swe2d import _momentum_flux_x

        rng = np.random.default_rng(12)
        q3 = (500.0 + 100 * rng.normal(size=(64, 128))).astype(np.float32)
        q1 = (q3 * rng.normal(0, 5, (64, 128))).astype(np.float32)
        fmt = FMTS[0]
        fk = ops.swe_flux(q1, q3, fmt, block=(64, 128))
        fs = _momentum_flux_x(q1, q3, PRESETS["r2f2_16"])
        np.testing.assert_allclose(np.asarray(fk), np.asarray(fs), rtol=2e-3)
