"""Sharding-spec validity for every architecture on small stand-in meshes
(regression for the MoE duplicate-axis bug; full meshes run in the dry-run)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config
from repro.train.step import TrainConfig


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) > 1:
        return jax.make_mesh((1, len(jax.devices())), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))


def _check_no_dup(spec_tree, mesh):
    for path, spec in jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=lambda x: isinstance(x, P)
    )[0]:
        axes = []
        for entry in spec:
            if entry is None:
                continue
            axes += list(entry) if isinstance(entry, tuple) else [entry]
        assert len(axes) == len(set(axes)), (path, spec)
        NamedSharding(mesh, spec)  # must construct


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_valid(arch, mesh):
    from repro.launch.specs import params_specs

    cfg = get_config(arch)
    sds, specs = params_specs(cfg, mesh)
    _check_no_dup(specs, mesh)
    # every sharded dim must divide the mesh extent (guard behaviour)
    for (path, spec), (_, leaf) in zip(
        jax.tree_util.tree_flatten_with_path(specs, is_leaf=lambda x: isinstance(x, P))[0],
        jax.tree_util.tree_flatten_with_path(sds)[0],
    ):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            ext = 1
            for a in entry if isinstance(entry, tuple) else (entry,):
                ext *= mesh.shape[a]
            assert dim % ext == 0, (path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "qwen3-moe-235b-a22b", "xlstm-1.3b"])
def test_state_and_cache_specs_valid(arch, mesh):
    from repro.launch.specs import decode_specs, state_specs

    cfg = get_config(arch)
    _, specs = state_specs(cfg, TrainConfig(), mesh)
    _check_no_dup(specs, mesh)

    (p_sds, c_sds, t_sds, pos_sds), shardings = decode_specs(cfg, SHAPES["decode_32k"], mesh)
    # NamedShardings constructed without error is the assertion
    assert shardings is not None


def test_logical_rules_resolve():
    from repro.dist.sharding import DEFAULT_RULES, logical_spec

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for name in DEFAULT_RULES:
        logical_spec(name, mesh=mesh)  # must not raise
    with pytest.raises(KeyError):
        logical_spec("not-an-axis", mesh=mesh)
