"""Per-architecture smoke tests (reduced configs, CPU) + decode consistency.

Each assigned architecture instantiates a REDUCED config of the same family
and runs one forward/train step asserting output shapes and no NaNs, plus a
decode step against its cache/state (except encoder-only)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced

# The two stacked-scan hybrids dominate suite wall-clock (tens of seconds
# each even reduced); their cases run in the weekly full-suite tier.
_SLOW_ARCHS = {"jamba-v0.1-52b", "xlstm-1.3b"}
ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a for a in ARCHS
]
from repro.core.policy import PRESETS
from repro.models import (
    decode_step,
    forward,
    init_decode_state,
    lm_loss,
    model_init,
    prefill,
)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    batch = {"labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.frontend:
        batch["embeds"] = jax.random.normal(KEY, (B, S, cfg.frontend_dim))
        if cfg.frontend == "vision":
            batch["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = reduced(get_config(arch))
        params = model_init(KEY, cfg)
        batch = _batch(cfg)
        tokens = batch.get("tokens")
        logits, aux = forward(
            params, cfg, PRESETS["deploy"], tokens=tokens, embeds=batch.get("embeds")
        )
        S_exp = 16 * (2 if (cfg.frontend == "vision") else 1)
        assert logits.shape == (2, S_exp, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_train_step_no_nans(self, arch):
        cfg = reduced(get_config(arch))
        params = model_init(KEY, cfg)
        batch = _batch(cfg)
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, batch, cfg, PRESETS["deploy"])
        )(params)
        assert bool(jnp.isfinite(loss))
        gn = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree_util.tree_leaves(grads)))
        assert bool(jnp.isfinite(gn)) and float(gn) > 0

    def test_decode_step(self, arch):
        cfg = reduced(get_config(arch))
        if cfg.encoder_only:
            pytest.skip("encoder-only: no decode")
        params = model_init(KEY, cfg)
        caches = init_decode_state(cfg, 2, 32)
        tok = jax.random.randint(KEY, (2, 1), 0, cfg.vocab)
        logits, new_caches = decode_step(
            params, caches, tok, jnp.int32(0), cfg, PRESETS["deploy"]
        )
        assert logits.shape == (2, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_rr_emulated_close_to_f32(self, arch):
        cfg = reduced(get_config(arch))
        params = model_init(KEY, cfg)
        batch = _batch(cfg)
        l_f32 = float(lm_loss(params, batch, cfg, PRESETS["f32"]))
        l_rr = float(lm_loss(params, batch, cfg, PRESETS["r2f2_16"]))
        assert abs(l_rr - l_f32) / abs(l_f32) < 0.05


@pytest.mark.parametrize(
    "arch",
    [
        "mistral-nemo-12b",
        pytest.param("jamba-v0.1-52b", marks=pytest.mark.slow),
        pytest.param("xlstm-1.3b", marks=pytest.mark.slow),
    ],
)
def test_decode_matches_forward(arch):
    """Greedy decode over a short prompt must match the full forward pass.

    MoE archs need a drop-free capacity factor here: capacity-based dispatch
    may drop tokens in the full pass while single-token decode never drops —
    a known train/serve semantic of capacity MoE, not a bug (DESIGN.md §8).
    """
    import dataclasses

    cfg = reduced(get_config(arch))
    if cfg.moe_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = model_init(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab)
    full, _ = forward(params, cfg, PRESETS["f32"], tokens=toks, remat=False)
    caches = init_decode_state(cfg, 1, 8, cache_dtype=jnp.float32)
    outs = []
    for i in range(8):
        lg, caches = decode_step(
            params, caches, toks[:, i : i + 1], jnp.int32(i), cfg, PRESETS["f32"]
        )
        outs.append(lg[:, 0])
    seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq), atol=3e-4)


def test_prefill_then_decode_matches_forward():
    """prefill(S tokens) + decode(token S) == forward(S+1 tokens) tail."""
    cfg = reduced(get_config("mistral-nemo-12b"))
    params = model_init(jax.random.PRNGKey(3), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 9), 0, cfg.vocab)
    full, _ = forward(params, cfg, PRESETS["f32"], tokens=toks, remat=False)
    logits_p, caches = prefill(params, cfg, PRESETS["f32"], tokens=toks[:, :8], max_len=16, cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(full[:, :8]), np.asarray(logits_p), atol=3e-4)
    lg, _ = decode_step(params, caches, toks[:, 8:9], jnp.int32(8), cfg, PRESETS["f32"])
    np.testing.assert_allclose(np.asarray(full[:, 8]), np.asarray(lg[:, 0]), atol=3e-4)


def test_flash_attention_matches_dense():
    """Chunked online-softmax path == dense path."""
    from repro.models import attention
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        name="t", n_layers=1, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=64
    )
    p = attention.attn_init(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 2048, 64))
    old = attention.FLASH_THRESHOLD
    try:
        attention.FLASH_THRESHOLD = 4096
        dense, _ = attention.attn_apply(p, x, cfg, PRESETS["f32"])
        attention.FLASH_THRESHOLD = 512
        flash, _ = attention.attn_apply(p, x, cfg, PRESETS["f32"])
    finally:
        attention.FLASH_THRESHOLD = old
    np.testing.assert_allclose(np.asarray(dense), np.asarray(flash), atol=2e-5)
