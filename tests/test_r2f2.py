"""Tests for the R2F2 multiplier: split selection, tile/sequential modes."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    FlexFormat,
    max_normal,
    min_normal,
    quantize_em,
    r2f2_mul_sequential,
    r2f2_multiply,
    select_k,
    select_k_operand,
)

FMT = FlexFormat(3, 9, 3)


class TestSelectK:
    def test_covers_product_overflow(self):
        # h*h with h ~ 1000: product exp ~ 19 -> needs E6 (k=3 for EB=3)
        k = int(select_k(jnp.int32(9), jnp.int32(9), FMT))
        e = FMT.eb + k
        assert float(max_normal(e, FMT.mb + FMT.fx - k)) > 1e6

    def test_covers_small_products(self):
        # paper §3.1: operands < 1e-4 need E6M9, not E5M10
        k = int(select_k(jnp.int32(-17), jnp.int32(-3), FMT))  # alpha=1e-5 * lap
        assert FMT.eb + k == 6

    def test_minimal_for_unit_range(self):
        k = int(select_k(jnp.int32(0), jnp.int32(0), FMT))
        assert k == 0  # E3M12 suffices around 1.0

    def test_operand_only(self):
        assert int(select_k_operand(jnp.int32(0), FMT)) == 0
        assert int(select_k_operand(jnp.int32(40), FMT)) == 3  # needs E6
        assert int(select_k_operand(jnp.int32(-25), FMT)) == 3


class TestTileMultiply:
    def test_more_accurate_than_fixed_half(self):
        rng = np.random.default_rng(0)
        a = (10.0 ** rng.uniform(-4, 4, 50000)).astype(np.float32)
        b = (10.0 ** rng.uniform(-4, 4, 50000)).astype(np.float32)
        exact = a.astype(np.float64) * b.astype(np.float64)
        p_rr, _ = r2f2_multiply(a, b, FMT, tile_shape=(100,))
        p_fx = np.asarray(
            quantize_em(
                np.asarray(quantize_em(a, 5, 10)) * np.asarray(quantize_em(b, 5, 10)),
                5,
                10,
            ),
            np.float64,
        )
        err_rr = np.abs(np.asarray(p_rr, np.float64) - exact) / np.abs(exact)
        ovf = ~np.isfinite(p_fx)
        err_fx = np.where(ovf, 1.0, np.abs(np.nan_to_num(p_fx) - exact) / np.abs(exact))
        # paper: ~70% avg error reduction
        assert err_rr.mean() < 0.5 * err_fx.mean()

    def test_no_overflow_in_sweep_range(self):
        rng = np.random.default_rng(1)
        a = (10.0 ** rng.uniform(-4, 4, 20000)).astype(np.float32)
        b = (10.0 ** rng.uniform(-4, 4, 20000)).astype(np.float32)
        p, stats = r2f2_multiply(a, b, FMT, tile_shape=(100,))
        assert np.isfinite(np.asarray(p)).all()
        assert int(stats.overflow_count) == 0

    def test_tail_approx_small_and_rare(self):
        """Paper §4.1: approximation errors < 0.1% in < 0.04%... of products.
        (we assert the same order of magnitude)"""
        rng = np.random.default_rng(2)
        a = rng.uniform(0.5, 2.0, 200000).astype(np.float32)
        b = rng.uniform(0.5, 2.0, 200000).astype(np.float32)
        p_t, _ = r2f2_multiply(a, b, FMT, tail_approx=True)
        p_e, _ = r2f2_multiply(a, b, FMT, tail_approx=False)
        p_t, p_e = np.asarray(p_t, np.float64), np.asarray(p_e, np.float64)
        diff = p_t != p_e
        assert diff.mean() < 0.01  # rare
        if diff.any():
            rel = np.abs(p_t[diff] - p_e[diff]) / np.abs(p_e[diff])
            assert rel.max() < 1.5e-3  # small


class TestSequential:
    def test_adapts_to_drifting_range(self):
        # stream drifts large -> small; k must grow for overflow then shrink
        t = np.linspace(0, 1, 3000).astype(np.float32)
        a = (3e4 * np.exp(-10 * t)).astype(np.float32) + 1e-6
        b = a.copy()
        prods, st_ = r2f2_mul_sequential(a, b, FMT)
        assert int(st_.overflow_adjusts) >= 1  # a*a ~ 9e8 needs E6+ early
        assert int(st_.redundancy_adjusts) >= 1  # late values ~1e-6 shrink back
        exact = a.astype(np.float64) ** 2
        rel = np.abs(np.asarray(prods, np.float64) - exact) / exact
        assert np.median(rel) < 2e-3

    def test_matches_tile_mode_in_steady_state(self):
        rng = np.random.default_rng(3)
        a = rng.uniform(1.0, 2.0, 500).astype(np.float32)
        b = rng.uniform(1.0, 2.0, 500).astype(np.float32)
        p_seq, st_ = r2f2_mul_sequential(a, b, FMT)
        p_tile, _ = r2f2_multiply(a, b, FMT, k=0)
        # steady state k=0 (range ~1): sequential settles immediately
        np.testing.assert_array_equal(np.asarray(p_seq)[10:], np.asarray(p_tile)[10:])


@settings(max_examples=150, deadline=None)
@given(
    ea=st.integers(-20, 20),
    eb=st.integers(-20, 20),
)
def test_prop_selected_k_covers_cluster_when_possible(ea, eb):
    """For any operand cluster tops, the chosen split represents both
    operands' tops and the product top without overflow or flush-to-zero —
    whenever the format family can (otherwise the hardware saturates at
    k=FX and overflows, like any 16-bit unit would)."""
    k = int(select_k(jnp.int32(ea), jnp.int32(eb), FMT))
    e = FMT.eb + k
    m = FMT.mb + FMT.fx - k
    emax_family = 2 ** (FMT.eb + FMT.fx - 1) - 1  # 31 for <3,9,3>
    need_hi = max(ea, eb, ea + eb + 1)
    a = np.float32(1.5 * 2.0**ea)
    b = np.float32(1.5 * 2.0**eb)
    for v, top in ((a, ea), (b, eb), (np.float32(a * b), ea + eb + 1)):
        q = float(quantize_em(v, e, m))
        if top <= emax_family and need_hi <= emax_family:
            assert np.isfinite(q), (k, v)
            assert q != 0.0, (k, v)
        elif top > emax_family:
            assert np.isinf(q)  # saturated family: hardware overflow
