"""Paper Fig. 6 / §5.1 — multiplication accuracy of R2F2 vs fixed formats.

Protocol follows the paper: operands swept over (0.0001, 10000), divided
into intervals, 1000 random pairs each; absolute error vs the 32-bit
product; overflow counted as 100% error ("errors are cast to 100% if
overflow happens"); error reduction of k-bit R2F2 vs its fixed-format
counterpart (E5M10 / E5M9 / E5M8). The paper reports 70.2 / 70.6 / 70.7%
average reductions — the in-range reduction is the comparable number; the
with-overflow reduction is larger because fixed formats overflow above
65504 while R2F2 reconfigures.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.alu import flex_add, flex_div
from repro.core import FlexFormat, quantize_em, r2f2_multiply

CONFIGS = [
    ("r2f2_16<3,9,3>", FlexFormat(3, 9, 3), (5, 10), "E5M10"),
    ("r2f2_15<3,8,3>", FlexFormat(3, 8, 3), (5, 9), "E5M9"),
    ("r2f2_14<3,7,3>", FlexFormat(3, 7, 3), (5, 8), "E5M8"),
]

#: the paper's abstract headline: average error reduction of k-bit R2F2 vs
#: its equal-width fixed counterpart. Used as the regression floor for the
#: named err_reduction rows (our overflow-as-100% ratio-of-means clears it
#: with a wide margin because R2F2 never overflows in the sweep).
PAPER_REDUCTION_PCT = {"E5M10": 70.2, "E5M9": 70.6, "E5M8": 70.7}

N_INTERVALS = 400  # log-spaced intervals over (1e-4, 1e4)
PER_INTERVAL = 1000


def _sample_operands(rng):
    edges = np.logspace(-4, 4, N_INTERVALS + 1)
    lo = edges[:-1][:, None]
    hi = edges[1:][:, None]
    a = rng.uniform(lo, hi, (N_INTERVALS, PER_INTERVAL)).astype(np.float32)
    b = rng.uniform(lo, hi, (N_INTERVALS, PER_INTERVAL)).astype(np.float32)
    return a.reshape(-1), b.reshape(-1)


def _fixed_mul(a, b, e, m):
    qa = quantize_em(a, e, m)
    qb = quantize_em(b, e, m)
    return np.asarray(quantize_em(np.asarray(qa) * np.asarray(qb), e, m))


def run():
    rng = np.random.default_rng(42)
    a, b = _sample_operands(rng)
    exact = a.astype(np.float64) * b.astype(np.float64)

    rows = []
    for name, fmt, (e, m), fixed_name in CONFIGS:
        t0 = time.perf_counter()
        p_rr, stats = r2f2_multiply(a, b, fmt, tile_shape=(PER_INTERVAL,))
        p_rr = np.asarray(p_rr, np.float64)
        us = (time.perf_counter() - t0) * 1e6 / a.size

        p_fx = _fixed_mul(a, b, e, m).astype(np.float64)

        rel_rr = np.abs(p_rr - exact) / np.abs(exact)
        ovf_fx = ~np.isfinite(p_fx)
        rel_fx = np.where(ovf_fx, 1.0, np.abs(np.where(ovf_fx, 0.0, p_fx) - exact) / np.abs(exact))

        red_all = (1.0 - rel_rr.mean() / rel_fx.mean()) * 100.0
        inr = ~ovf_fx & (np.abs(exact) > 1.2e-4)  # both representable
        red_inr = (1.0 - rel_rr[inr].mean() / rel_fx[inr].mean()) * 100.0
        red_max = (1.0 - (rel_rr[inr] + 1e-12) / (rel_fx[inr] + 1e-12)).max() * 100.0

        rows.append(
            dict(
                name=name,
                fixed=fixed_name,
                us_per_call=us,
                rr_mean_err_pct=rel_rr.mean() * 100,
                fixed_mean_err_pct=rel_fx.mean() * 100,
                reduction_incl_overflow_pct=red_all,
                reduction_in_range_pct=red_inr,
                reduction_max_pct=red_max,
                fixed_overflow_frac=ovf_fx.mean(),
            )
        )
    return rows


#: the flexible ALU ops benched against their fixed-format counterparts,
#: same operand sweep and overflow-as-100% protocol as the multiply rows
ALU_OPS = (("add", flex_add, np.add), ("div", flex_div, np.divide))


def _fixed_alu(a, b, e, m, np_op):
    qa = np.asarray(quantize_em(a, e, m), np.float64)
    qb = np.asarray(quantize_em(b, e, m), np.float64)
    return np.asarray(quantize_em(np_op(qa, qb).astype(np.float32), e, m), np.float64)


def run_alu():
    """err_reduction rows for the flexible add/divide engine ops.

    Mirrors :func:`run`'s protocol (same sweep, overflow-as-100%, in-range
    ratio-of-means) for the ``repro.alu`` ops the PDE engines now route
    through. No paper figure exists for these — the regression gate is the
    qualitative claim only: flexible strictly dominates its equal-width
    fixed counterpart in range. Operands stay interval-paired exactly like
    the mul rows — quotients/sums then stay near the operand scale, so this
    measures in-range accuracy, not overflow rescue. (Deliberately NOT a
    shuffled-divisor sweep: tile-wise k derives from max-exponent evidence,
    and a tile mixing 1e-4 and 1e4 divisors is an adversarial distribution
    no solver field produces — the overflow edges are covered per-op by the
    paper-pattern gates in tests/test_alu.py instead.)
    """
    rng = np.random.default_rng(43)
    a, b = _sample_operands(rng)

    rows = []
    for op_name, flex, np_op in ALU_OPS:
        exact = np_op(a.astype(np.float64), b.astype(np.float64))
        for name, fmt, (e, m), fixed_name in CONFIGS:
            t0 = time.perf_counter()
            p_rr, _ = flex(a, b, fmt, tile_shape=(PER_INTERVAL,))
            p_rr = np.asarray(p_rr, np.float64)
            us = (time.perf_counter() - t0) * 1e6 / a.size

            p_fx = _fixed_alu(a, b, e, m, np_op)

            rel_rr = np.abs(p_rr - exact) / np.abs(exact)
            ovf_fx = ~np.isfinite(p_fx)
            rel_fx = np.where(
                ovf_fx, 1.0, np.abs(np.where(ovf_fx, 0.0, p_fx) - exact) / np.abs(exact)
            )

            red_all = (1.0 - rel_rr.mean() / rel_fx.mean()) * 100.0
            inr = ~ovf_fx & (np.abs(exact) > 1.2e-4)
            red_inr = (1.0 - rel_rr[inr].mean() / rel_fx[inr].mean()) * 100.0

            rows.append(
                dict(
                    op=op_name,
                    name=name,
                    fixed=fixed_name,
                    us_per_call=us,
                    reduction_incl_overflow_pct=red_all,
                    reduction_in_range_pct=red_inr,
                    rr_overflow_frac=float((~np.isfinite(p_rr)).mean()),
                    fixed_overflow_frac=float(ovf_fx.mean()),
                )
            )
    return rows


def main():
    print("# paper Fig. 6 — R2F2 vs fixed-format multiplication error")
    print("# paper claims: avg error reduction 70.2% (16b), 70.6% (15b), 70.7% (14b); max 99.9%")
    print("# note: the paper's averaging convention is unspecified; we report")
    print("#   ratio-of-means incl. overflow-as-100% (our R2F2 never overflows in the")
    print("#   sweep -> 99+%), and the in-range-only ratio. Qualitative claim (R2F2")
    print("#   strictly dominates equal-width fixed formats) reproduces under all of them.")
    for r in run():
        print(
            f"mul_accuracy/{r['name']},{r['us_per_call']:.3f},"
            f"in_range_reduction={r['reduction_in_range_pct']:.1f}%"
            f";incl_overflow={r['reduction_incl_overflow_pct']:.1f}%"
            f";max={r['reduction_max_pct']:.1f}%"
            f";fixed_{r['fixed']}_err={r['fixed_mean_err_pct']:.4f}%"
            f";rr_err={r['rr_mean_err_pct']:.4f}%"
            f";fixed_overflow_frac={r['fixed_overflow_frac']:.3f}"
        )
        # the abstract's headline as a named, regression-checked row: the
        # overflow-as-100% reduction must clear the paper's figure and R2F2
        # must strictly dominate in-range (reduction > 0) — a numerics
        # regression in the multiplier shows up here as a verdict flip
        paper = PAPER_REDUCTION_PCT[r["fixed"]]
        ok = r["reduction_incl_overflow_pct"] >= paper and r["reduction_in_range_pct"] > 0
        print(
            f"mul_accuracy/err_reduction_vs_{r['fixed']},{r['us_per_call']:.3f},"
            f"pct={r['reduction_incl_overflow_pct']:.1f}"
            f";paper={paper}"
            f";in_range_pct={r['reduction_in_range_pct']:.1f}"
            f";{'OK' if ok else 'REGRESSED'}"
        )
    # the flexible ALU ops (repro.alu) through the same protocol: no paper
    # figure, so the verdict is the dominance claim alone — flexible >= its
    # fixed counterpart in range, no overflow where the fixed format blows up
    print("# flexible add/divide vs fixed counterparts (same sweep; no paper figure)")
    for r in run_alu():
        ok = r["reduction_in_range_pct"] >= 0 and r["rr_overflow_frac"] == 0.0
        print(
            f"mul_accuracy/err_reduction_{r['op']}_vs_{r['fixed']},{r['us_per_call']:.3f},"
            f"pct={r['reduction_incl_overflow_pct']:.1f}"
            f";in_range_pct={r['reduction_in_range_pct']:.1f}"
            f";fixed_overflow_frac={r['fixed_overflow_frac']:.3f}"
            f";{'OK' if ok else 'REGRESSED'}"
        )


if __name__ == "__main__":
    main()
