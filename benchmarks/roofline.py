"""Roofline analysis: PDE storage-traffic rows + LM dry-run table.

The PDE section is analytic and always runs (no artifacts needed): per
registered stepper x carried-storage format, the bytes one step moves
across the HBM boundary (2x the carried-state footprint — one read, one
write) and the memory-roofline time that traffic costs at HBM bandwidth.
The ``packed`` rows carry R2F2 payloads (``repro.pack``) instead of f32;
their bytes-per-step ratio against the f32 rows is the bandwidth headline
the packed execution plane banks. Emitted as ``name,us,derived`` CSV so
``benchmarks.run`` captures them into ``BENCH_roofline.json``.

The LM table below it analyzes compiled dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, from artifacts/dryrun/<cell>.json:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs          [s]
    memory term     = HLO_bytes_per_device / HBM_bw              [s]
    collective term = collective_bytes_per_device / link_bw      [s]

(cost_analysis of the SPMD-partitioned executable is already per-device, so
the prompt's "/ chips" is folded in.) Hardware: TPU v5e-like — 197 TFLOP/s
bf16, 819 GB/s HBM, ~50 GB/s/link ICI (3D-torus links; we charge the
busiest single link, a conservative serialization bound).

Also reported: MODEL_FLOPS (6ND train / 2ND forward, N_active for MoE), the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs (catches remat & masked-block
waste), the dominant term, and roofline fraction = dominant / sum-of-terms
upper-bounded step time.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link
#: fixed dispatch cost charged per pallas_call launch (host->device setup,
#: grid program bring-up) — the term the megakernel amortizes: a chunked
#: horizon pays it steps/every times, the megakernel exactly once.
LAUNCH_OVERHEAD_US = 4.0

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * shape.global_batch
    return total / chips


def analyze_cell(r: Dict) -> Optional[Dict]:
    if r.get("status") != "ok":
        return None
    chips = r["chips"]
    # trip-count-corrected rollup (launch/hlo_cost.py); raw cost_analysis
    # counts loop bodies once and is kept in the artifact for reference
    cor = r.get("corrected")
    if cor:
        flops = cor["flops_per_device"]
        bytes_acc = cor["bytes_per_device"]
        coll = sum(cor["collective_bytes"].values())
    else:
        flops = r["flops_per_device"]
        bytes_acc = r["bytes_accessed_per_device"]
        coll = sum(r["collective_bytes"].values())

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mflops = model_flops_per_device(r["arch"], r["shape"], chips)
    useful = mflops / flops if flops > 0 else 0.0
    # roofline fraction: useful compute time over the overlap-free bound
    t_bound = max(terms.values())
    frac = (mflops / PEAK_FLOPS) / t_bound if t_bound > 0 else 0.0

    hbm_gib = (r["memory"]["argument_bytes"] + r["memory"]["temp_bytes"]) / 2**30
    return dict(
        cell=r["cell"],
        arch=r["arch"],
        shape=r["shape"],
        mesh=r["mesh"],
        t_compute_s=t_compute,
        t_memory_s=t_memory,
        t_collective_s=t_coll,
        dominant=dominant,
        useful_ratio=useful,
        roofline_frac=frac,
        hbm_gib_per_dev=hbm_gib,
        fits_16g=hbm_gib < 16.0,
    )


def load_all(mesh: str = "16x16") -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        r = json.load(open(f))
        if r["status"] == "skip":
            rows.append(dict(cell=r["cell"], skip=r["reason"]))
            continue
        a = analyze_cell(r)
        if a:
            rows.append(a)
        else:
            rows.append(dict(cell=r["cell"], skip="ERROR: " + r.get("error", "?")[:60]))
    return rows


def pde_storage_rows():
    """Analytic bytes-moved-per-step rows, per stepper x storage format.

    Pure metadata arithmetic — packs each stepper's initial state once to
    measure the carried footprint; nothing is stepped or jitted.
    """
    import jax

    from repro.pack import pack_state, state_nbytes
    from repro.pde import get_stepper, known_steppers
    from repro.precision import PRESETS

    fmt = PRESETS["r2f2_16"].fmt
    rows = []
    for name in known_steppers():
        stepper = get_stepper(name)
        cfg = stepper.default_config()
        state = jax.tree_util.tree_map(jax.numpy.asarray, stepper.init_state(cfg))
        f32_bytes = 2 * state_nbytes(state)
        packed_bytes = 2 * state_nbytes(pack_state(state, fmt))
        for storage, nbytes in (("f32", f32_bytes), ("packed", packed_bytes)):
            t_mem_us = nbytes / HBM_BW * 1e6
            rows.append(
                (
                    f"roofline/pde/{name}/{storage}",
                    t_mem_us,
                    f"bytes_per_step={nbytes}"
                    f";ratio_vs_f32={nbytes / f32_bytes:.3f}"
                    f";hbm_bw_gbps={HBM_BW / 1e9:.0f}",
                )
            )
    return rows


def pde_step_bound_us(nbytes_per_step: float, steps: int, launches: int) -> float:
    """Analytic per-step lower bound for one horizon: boundary HBM traffic
    at bandwidth + the fixed launch overhead amortized over the horizon's
    steps. The bench's measured us_per_step can approach but not beat this
    (``benchmarks.run --check`` flags rows that do as measurement noise)."""
    return nbytes_per_step / HBM_BW * 1e6 + LAUNCH_OVERHEAD_US * launches / steps


def pde_launch_rows(steps: int = 240):
    """Chunked-vs-megakernel launch-overhead model, per stepper x storage.

    For each registered stepper's default config and snapshot cadence: the
    chunked fused plane issues one pallas_call per snapshot interval
    (``steps/every`` launches per horizon, remainder included) while the
    megakernel issues exactly 1. Each row reports the per-step analytic
    bound (:func:`pde_step_bound_us`), its two terms, and which one
    dominates — ``launch``-bound horizons are the megakernel's win case,
    ``bandwidth``-bound ones are the packed plane's. Pure metadata
    arithmetic, nothing is stepped or jitted.
    """
    import jax

    from repro.pack import pack_state, state_nbytes
    from repro.pde import get_stepper, known_steppers
    from repro.precision import PRESETS

    fmt = PRESETS["r2f2_16"].fmt
    rows = []
    for name in known_steppers():
        stepper = get_stepper(name)
        cfg = stepper.default_config()
        state = jax.tree_util.tree_map(jax.numpy.asarray, stepper.init_state(cfg))
        every = max(1, steps // stepper.snapshots_default)
        n_chunks = steps // every + (1 if steps % every else 0)
        for storage, nbytes in (
            ("f32", 2 * state_nbytes(state)),
            ("packed", 2 * state_nbytes(pack_state(state, fmt))),
        ):
            for plane, launches in (("chunked", n_chunks), ("megakernel", 1)):
                t_mem_us = nbytes / HBM_BW * 1e6
                t_launch_us = LAUNCH_OVERHEAD_US * launches / steps
                bound = pde_step_bound_us(nbytes, steps, launches)
                rows.append(
                    (
                        f"roofline/pde_launch/{name}/{plane}/{storage}",
                        bound,
                        f"launches={launches};steps={steps}"
                        f";bytes_per_step={nbytes}"
                        f";t_mem_us={t_mem_us:.4f};t_launch_us={t_launch_us:.4f}"
                        f";bound={'launch' if t_launch_us > t_mem_us else 'bandwidth'}"
                        f";launch_overhead_us={LAUNCH_OVERHEAD_US}",
                    )
                )
    return rows


def main():
    print("# roofline — PDE carried-state HBM traffic per step (analytic)")
    print("# us column = memory-roofline time of one step's state traffic")
    for name, us, derived in pde_storage_rows():
        print(f"{name},{us:.4f},{derived}")
    print()
    print("# roofline — chunked-vs-megakernel launch model (analytic)")
    print("# us column = per-step bound: HBM traffic + amortized launch overhead")
    for name, us, derived in pde_launch_rows():
        print(f"{name},{us:.4f},{derived}")
    print()
    print("# roofline — single-pod 16x16 (256 chips); terms in ms per step")
    print(
        f"{'cell':58s} {'comp':>7s} {'mem':>7s} {'coll':>7s} "
        f"{'dominant':>10s} {'useful':>7s} {'frac':>6s} {'HBM':>7s}"
    )
    for row in load_all("16x16"):
        if "skip" in row:
            print(f"{row['cell']:58s} SKIP: {row['skip']}")
            continue
        print(
            f"{row['cell']:58s} "
            f"{row['t_compute_s']*1e3:7.2f} {row['t_memory_s']*1e3:7.2f} "
            f"{row['t_collective_s']*1e3:7.2f} {row['dominant']:>10s} "
            f"{row['useful_ratio']:7.3f} {row['roofline_frac']:6.3f} "
            f"{row['hbm_gib_per_dev']:6.2f}G"
        )
    print("\n# multi-pod 2x16x16 (512 chips)")
    for row in load_all("2x16x16"):
        if "skip" in row:
            continue
        print(
            f"{row['cell']:58s} "
            f"{row['t_compute_s']*1e3:7.2f} {row['t_memory_s']*1e3:7.2f} "
            f"{row['t_collective_s']*1e3:7.2f} {row['dominant']:>10s} "
            f"{row['useful_ratio']:7.3f} {row['roofline_frac']:6.3f} "
            f"{row['hbm_gib_per_dev']:6.2f}G"
        )


if __name__ == "__main__":
    main()
