"""Paper §3 (Exploration, Q1) — Figs. 2 & 3.

Fig. 2: data-range distribution during the heat simulation — *globally wide,
locally clustered, dynamically shifting*. We quantify the paper's three
observations on the live simulation: global dynamic range, per-quarter range
shrinkage (paper: -500 -> (-5,5) -> (-1,1) -> (-0.25,0.25)), and the
exponent-cluster width per stage.

Fig. 3: per-operand-range error profiling across E(e)M(m) configurations —
different ranges favor different splits, and the analytic Eq. (1) exponent
formula mis-predicts the empirically best config (the paper's motivation for
a feedback-driven adjust unit over a formula).
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from repro.core import quantize_em
from repro.precision import PRESETS
from repro.pde import HeatConfig, simulate_heat

# 16-bit configs swept in Fig. 3 (e + m = 15 plus sign)
CONFIGS_16 = [(3, 12), (4, 11), (5, 10), (6, 9), (7, 8), (8, 7)]
RANGES = [(0.05, 0.07), (4.0, 5.0), (100.0, 110.0), (1000.0, 1100.0), (1e-5, 2e-5)]


def fig2_range_shift(steps=4000):
    """Per-quarter value-range statistics of the heat simulation."""
    cfg = HeatConfig(nx=128, init="sin")
    quarter = steps // 4
    out = []
    _, snaps = simulate_heat(cfg, PRESETS["f32"], steps, snapshot_every=quarter)
    snaps = np.asarray(snaps)
    for i, snap in enumerate(snaps):
        mag = np.abs(snap[np.abs(snap) > 0])
        if mag.size == 0:
            continue
        out.append(
            dict(
                quarter=i + 1,
                max_abs=float(mag.max()),
                min_abs=float(mag.min()),
                exp_spread=float(np.log2(mag.max() / max(mag.min(), 1e-38))),
            )
        )
    return out


def eq1_exponent_bits(vmax: float) -> int:
    """The paper's Eq. (1) analytic estimate (log base 10 — reproduces the
    paper's quoted predictions of 4/6/8 bits for the ranges (0.05,0.07),
    (100,110), (1000,1100) where profiling favors 5/5/6)."""
    if vmax >= 1:
        return int(math.ceil(math.log10(vmax**2))) + 1
    return int(math.ceil(math.log10((1.0 / vmax) ** 2))) + 1


def fig3_profile(n=20000, seed=0):
    """Mean multiplication error per (range x config); returns per-range
    best config and the Eq. (1) prediction."""
    rng = np.random.default_rng(seed)
    rows = []
    for lo, hi in RANGES:
        a = rng.uniform(lo, hi, n).astype(np.float32)
        b = rng.uniform(lo, hi, n).astype(np.float32)
        exact = a.astype(np.float64) * b.astype(np.float64)
        errs = {}
        for e, m in CONFIGS_16:
            qa = np.asarray(quantize_em(a, e, m))
            qb = np.asarray(quantize_em(b, e, m))
            p = np.asarray(quantize_em(qa * qb, e, m), np.float64)
            rel = np.where(
                np.isfinite(p), np.abs(p - exact) / np.abs(exact), 1.0
            )
            errs[(e, m)] = float(np.mean(rel))
        best = min(errs, key=errs.get)
        rows.append(
            dict(
                range=(lo, hi),
                best_e=best[0],
                best_err_pct=errs[best] * 100,
                eq1_e=eq1_exponent_bits(hi),
                errs={f"E{e}M{m}": round(v * 100, 4) for (e, m), v in errs.items()},
            )
        )
    return rows


def main():
    print("# paper Fig. 2 — heat-sim value ranges: globally wide, locally")
    print("# clustered, shifting per quarter (paper: -500 -> +-5 -> +-1 -> +-0.25)")
    for r in fig2_range_shift():
        print(
            f"exploration/fig2/quarter{r['quarter']},{r['max_abs']:.4g},"
            f"min_abs={r['min_abs']:.3g};exp_spread_bits={r['exp_spread']:.1f}"
        )
    print("# paper Fig. 3 — per-range optimal 16-bit split; Eq.(1) mis-predicts")
    for r in fig3_profile():
        agree = "match" if r["best_e"] == r["eq1_e"] else "MISPREDICT"
        print(
            f"exploration/fig3/range_{r['range'][0]:g}-{r['range'][1]:g},"
            f"{r['best_err_pct']:.4f},best_e={r['best_e']};eq1_e={r['eq1_e']};{agree}"
        )


if __name__ == "__main__":
    main()
