"""Serving-plane benchmark: the batched simulation service under load.

For each (stepper, precision, execution) cell, submit a burst of
``MEMBERS`` scaled-IC requests into one :class:`repro.service.SimService`,
drive it to idle, and report per-bucket serving statistics from the
service's own metrics surface:

    service/<stepper>/<prec>/<exec>,p50_chunk_us,thr=<member-steps/s>;p99=<us>;occ=<mean>;chunks=<n>;err_budget=<rel-L2>;alerts=<n>

plus one aggregate row with overall throughput and bucket occupancy:

    service/_total/all/all,p50_chunk_us,thr=..;p99=..;occ=../max=..;snapshots=..;alerts=..;shadow_s=..

The whole burst runs under the :mod:`repro.obs.health` monitor:
``err_budget`` is the cell's worst shadow-replay rel-L2 vs the f32 oracle
(``nan`` when the deterministic sampler picked none of the cell's
requests), ``alerts`` counts health alerts attributed to the cell's
requests plus — on the aggregate row — fleet-scoped alerts, and
``shadow_s`` is the measured shadow-replay overhead (host-side, off the
chunk critical path). A healthy bench burst has ``alerts=0`` everywhere;
``benchmarks/run.py --check`` hard-fails otherwise.

The warm half of the burst dominates (compiled-chunk cache hits); the cold
tracing cost is real serving behaviour and stays in the numbers — this
suite tracks the *service* trajectory, not kernel microlatency (that is
``bench_pde``'s job). ``--smoke``/``main(smoke=True)`` shrinks grids and
horizons for the CI fast tier; rows are captured by ``benchmarks.run`` into
``BENCH_service.json``.
"""

from __future__ import annotations

import argparse
import math
import re

import repro.obs as obs
import repro.obs.health as health
from repro.service import ServiceConfig, SimRequest, SimService, scaled_state0

#: benchmarked cells: (stepper, precision, execution)
CELLS = (
    ("heat1d", "f32", "reference"),
    ("heat1d", "r2f2_16", "reference"),
    ("heat1d", "rr_tracked", "reference"),
    ("heat2d", "r2f2_16", "reference"),
    ("heat2d", "deploy", "fused"),
    ("advection1d", "rr_tracked", "reference"),
    ("burgers1d", "rr_tracked", "reference"),
    ("burgers1d", "deploy", "fused"),
    ("swe2d", "rr_tracked", "reference"),
)
SMOKE_CELLS = (
    ("heat1d", "r2f2_16", "reference"),
    ("heat1d", "rr_tracked", "reference"),
    ("heat2d", "deploy", "fused"),
)

MEMBERS = 4  # requests per cell — the bucket packing width under test


def _overrides(stepper: str, smoke: bool):
    if not smoke:
        return None
    return {
        "heat1d": {"nx": 64},
        "heat2d": {"nx": 16, "ny": 16},
        "advection1d": {"nx": 64},
        "burgers1d": {"nx": 64},
        "swe2d": {"nx": 16, "ny": 16},
    }.get(stepper)


SHADOW_RATE = 0.5  # deterministic sampler: every other request replays at f32


def main(smoke: bool = False) -> None:
    cells = SMOKE_CELLS if smoke else CELLS
    steps = 48 if smoke else 240
    every = 12 if smoke else 30

    had_obs = obs.enabled()
    if not had_obs:
        obs.enable(sample=0.0)  # registry only; no span recording in a bench
    monitor = health.enable(shadow_rate=SHADOW_RATE)

    try:
        svc = SimService(ServiceConfig(max_queue=1024, max_bucket=MEMBERS))
        handles = []
        cell_keys = {}  # (stepper, prec, exec) -> full BucketKey (metrics key)
        cell_ids = {}  # (stepper, prec, exec) -> request ids (health key)
        for stepper, prec, execution in cells:
            ov = _overrides(stepper, smoke)
            for i in range(MEMBERS):
                h = svc.submit(
                    SimRequest(
                        stepper,
                        steps=steps,
                        precision=prec,
                        overrides=ov,
                        snapshot_every=every,
                        execution=execution,
                        state0=scaled_state0(stepper, 0.6 + 0.15 * i, overrides=ov),
                        tag=f"{stepper}/{prec}/{execution}",
                    )
                )
                handles.append(h)
                cell_keys[(stepper, prec, execution)] = h.bucket_key
                cell_ids.setdefault((stepper, prec, execution), []).append(h.id)
        svc.run_until_idle()
    finally:
        health.disable()
        if not had_obs:
            obs.disable()

    m = svc.metrics
    incomplete = [h.tag for h in handles if h.status != "done"]
    if incomplete:
        raise RuntimeError(f"service bench left requests unfinished: {incomplete}")

    # health attribution: alert -> request id (scopes are "req<id>:<stepper>";
    # fleet-scoped alerts, e.g. SLO breaches, only count on the aggregate row)
    alert_ids = []
    for a in monitor.alerts:
        match = re.match(r"req(\d+):", a.scope)
        alert_ids.append(int(match.group(1)) if match else None)

    for stepper, prec, execution in cells:
        key = cell_keys[(stepper, prec, execution)]  # full key: formats never merge
        ids = cell_ids[(stepper, prec, execution)]
        occ_mean, _ = m.occupancy(key)
        n_chunks = sum(1 for k, _, _, _, _ in m.chunk_samples if k == key)
        n_compiles = sum(
            1 for k, _, _, _, compiled in m.chunk_samples if k == key and compiled
        )
        rels = [monitor.shadow_rel[i] for i in ids if i in monitor.shadow_rel]
        err = max(rels) if rels else math.nan  # worst shadowed drift in the cell
        n_alerts = sum(1 for i in alert_ids if i in ids)
        print(  # row name keeps the preset label (distinguishes formats)
            f"service/{stepper}/{prec}/{execution},{m.latency_us(50, key):.1f},"
            f"thr={m.throughput(key):.0f};p99={m.latency_us(99, key):.1f}us;"
            f"occ={occ_mean:.2f};chunks={n_chunks};compiles={n_compiles};"
            f"err_budget={err:.3e};alerts={n_alerts}"
        )
    occ_mean, occ_max = m.occupancy()
    shadow_s = monitor.obs.registry.counter(
        "repro_health_shadow_seconds_total"
    ).total()
    print(
        f"service/_total/all/all,{m.latency_us(50):.1f},"
        f"thr={m.throughput():.0f};p99={m.latency_us(99):.1f}us;"
        f"occ={occ_mean:.2f}/max{occ_max};snapshots={m.snapshots_emitted};"
        f"completed={m.completed};compiles={m.compiles};"
        f"compile_s={m.compile_seconds:.2f};"
        f"alerts={len(monitor.alerts)};shadowed={len(monitor.shadow_rel)};"
        f"shadow_s={shadow_s:.2f}"
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    main(smoke=ap.parse_args().smoke)
