"""Serving-plane benchmark: the batched simulation service under load.

For each (stepper, precision, execution) cell, submit a burst of
``MEMBERS`` scaled-IC requests into one :class:`repro.service.SimService`,
drive it to idle, and report per-bucket serving statistics from the
service's own metrics surface:

    service/<stepper>/<prec>/<exec>,p50_chunk_us,thr=<member-steps/s>;p99=<us>;occ=<mean>;chunks=<n>

plus one aggregate row with overall throughput and bucket occupancy:

    service/_total/all/all,p50_chunk_us,thr=..;p99=..;occ=../max=..;snapshots=..

The warm half of the burst dominates (compiled-chunk cache hits); the cold
tracing cost is real serving behaviour and stays in the numbers — this
suite tracks the *service* trajectory, not kernel microlatency (that is
``bench_pde``'s job). ``--smoke``/``main(smoke=True)`` shrinks grids and
horizons for the CI fast tier; rows are captured by ``benchmarks.run`` into
``BENCH_service.json``.
"""

from __future__ import annotations

import argparse

from repro.service import ServiceConfig, SimRequest, SimService, scaled_state0

#: benchmarked cells: (stepper, precision, execution)
CELLS = (
    ("heat1d", "f32", "reference"),
    ("heat1d", "r2f2_16", "reference"),
    ("heat1d", "rr_tracked", "reference"),
    ("heat2d", "r2f2_16", "reference"),
    ("heat2d", "deploy", "fused"),
    ("advection1d", "rr_tracked", "reference"),
    ("burgers1d", "rr_tracked", "reference"),
    ("burgers1d", "deploy", "fused"),
    ("swe2d", "rr_tracked", "reference"),
)
SMOKE_CELLS = (
    ("heat1d", "r2f2_16", "reference"),
    ("heat1d", "rr_tracked", "reference"),
    ("heat2d", "deploy", "fused"),
)

MEMBERS = 4  # requests per cell — the bucket packing width under test


def _overrides(stepper: str, smoke: bool):
    if not smoke:
        return None
    return {
        "heat1d": {"nx": 64},
        "heat2d": {"nx": 16, "ny": 16},
        "advection1d": {"nx": 64},
        "burgers1d": {"nx": 64},
        "swe2d": {"nx": 16, "ny": 16},
    }.get(stepper)


def main(smoke: bool = False) -> None:
    cells = SMOKE_CELLS if smoke else CELLS
    steps = 48 if smoke else 240
    every = 12 if smoke else 30

    svc = SimService(ServiceConfig(max_queue=1024, max_bucket=MEMBERS))
    handles = []
    cell_keys = {}  # (stepper, prec, execution) -> full BucketKey (metrics key)
    for stepper, prec, execution in cells:
        ov = _overrides(stepper, smoke)
        for i in range(MEMBERS):
            h = svc.submit(
                SimRequest(
                    stepper,
                    steps=steps,
                    precision=prec,
                    overrides=ov,
                    snapshot_every=every,
                    execution=execution,
                    state0=scaled_state0(stepper, 0.6 + 0.15 * i, overrides=ov),
                    tag=f"{stepper}/{prec}/{execution}",
                )
            )
            handles.append(h)
            cell_keys[(stepper, prec, execution)] = h.bucket_key
    svc.run_until_idle()

    m = svc.metrics
    incomplete = [h.tag for h in handles if h.status != "done"]
    if incomplete:
        raise RuntimeError(f"service bench left requests unfinished: {incomplete}")

    for stepper, prec, execution in cells:
        key = cell_keys[(stepper, prec, execution)]  # full key: formats never merge
        occ_mean, _ = m.occupancy(key)
        n_chunks = sum(1 for k, _, _, _, _ in m.chunk_samples if k == key)
        n_compiles = sum(
            1 for k, _, _, _, compiled in m.chunk_samples if k == key and compiled
        )
        print(  # row name keeps the preset label (distinguishes formats)
            f"service/{stepper}/{prec}/{execution},{m.latency_us(50, key):.1f},"
            f"thr={m.throughput(key):.0f};p99={m.latency_us(99, key):.1f}us;"
            f"occ={occ_mean:.2f};chunks={n_chunks};compiles={n_compiles}"
        )
    occ_mean, occ_max = m.occupancy()
    print(
        f"service/_total/all/all,{m.latency_us(50):.1f},"
        f"thr={m.throughput():.0f};p99={m.latency_us(99):.1f}us;"
        f"occ={occ_mean:.2f}/max{occ_max};snapshots={m.snapshots_emitted};"
        f"completed={m.completed};compiles={m.compiles};"
        f"compile_s={m.compile_seconds:.2f}"
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    main(smoke=ap.parse_args().smoke)
