"""Paper Figs. 1 & 7 + §5.3 counters — heat-equation case study.

Claims reproduced:
  * E5M10 produces wrong simulation results for both initializations
    (underflow of the alpha*lap intermediates freezes the dynamics);
  * 16-bit R2F2 <3,9,3> and 15-bit <3,8,3> match single precision;
  * the precision adjustment unit fires rarely (paper: 5 overflow /
    23 redundancy adjustments over 1.5M multiplications).

The precision-ladder table itself runs on the generic per-stepper harness
(``benchmarks.bench_pde.run_case``); this module keeps the figure-faithful
sin/exp scenario pair plus the §5.3 sequential-multiplier counters.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.bench_pde import Scenario, run_case
from repro.core import FlexFormat, r2f2_mul_sequential
from repro.precision import PRESETS
from repro.pde import HeatConfig

CASES = [("sin", 4000), ("exp", 24000)]
PRECS = ("e5m10", "r2f2_16", "r2f2_15", "r2f2_14", "bf16")


def run():
    rows = []
    for init, steps in CASES:
        cfg = HeatConfig(nx=128, init=init)
        sc = Scenario(cfg, steps, precs=PRECS, label=f"heat_{init}")
        rows += run_case("heat1d", sc)
    return rows


def adjustment_counts(n_muls: int = 200_000):
    """Paper §5.3: run the hardware-faithful sequential multiplier over the
    heat simulation's multiplication stream and count adjustments."""
    cfg = HeatConfig(nx=128, init="sin")
    steps = n_muls // (cfg.nx - 2)
    # regenerate the (alpha, lap) operand stream from the f32 trajectory
    from repro.pde.heat1d import heat_step, initial_condition

    u = initial_condition(cfg)
    a_stream, b_stream = [], []
    for _ in range(steps):
        lap = u[:-2] - 2.0 * u[1:-1] + u[2:]
        a_stream.append(jnp.full_like(lap, cfg.alpha))
        b_stream.append(lap)
        u = heat_step(u, cfg, PRESETS["f32"])
    a = jnp.concatenate(a_stream)
    b = jnp.concatenate(b_stream)
    _, st = r2f2_mul_sequential(a, b, FlexFormat(3, 9, 3))
    return int(a.size), int(st.overflow_adjusts), int(st.redundancy_adjusts)


def main():
    print("# paper Figs. 1 & 7 — heat equation: E5M10 fails, R2F2<=16b matches f32")
    for r in run():
        # historical row format, so BENCH_heat.json stays comparable
        status = "CORRECT" if r["correct"] else ("NaN" if not r["finite"] else "WRONG")
        print(
            f"heat/{r['case']}/{r['prec']},{r['us_per_step']:.1f},"
            f"rel_l2={r['rel']:.4f};{status}"
        )
    n, ovf, red = adjustment_counts()
    print(f"# paper §5.3: 5 overflow / 23 redundancy adjustments in 1.5M muls")
    print(f"heat/adjustments,{n},overflow_adjusts={ovf};redundancy_adjusts={red}")


if __name__ == "__main__":
    main()
