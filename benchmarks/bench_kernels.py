"""Kernel microbench: Pallas (interpret on CPU) vs pure-jnp oracle.

Paper Table 1 is FPGA LUT/FF area — N/A on TPU (DESIGN.md §2). The TPU
replacement cost story is measured here instead: the *deployment* path runs
at bf16 operand width (half of f32's HBM bytes at equal MXU rate), while
the bit-exact emulation path costs ~8 elementwise u32 ops per quantization.
On CPU the numbers below time the emulation; on a TPU backend the same
call sites compile the Pallas kernels natively.

Timing goes through :func:`repro.obs.timing.measure` — the shared helper
every bench suite uses — so each row's steady-state ``us_per_call`` is the
headline number and the first-call trace+compile time rides along as a
``compile_us`` derived field instead of silently inflating the mean.
"""

from __future__ import annotations

import numpy as np

from repro.core.flexformat import FlexFormat
from repro.kernels import ops, ref
from repro.obs.timing import measure


def main():
    rng = np.random.default_rng(3)
    fmt = FlexFormat(3, 9, 3)

    x = rng.normal(0, 1, (1024, 1024)).astype(np.float32)
    tk = measure(ops.r2f2_quantize, x, fmt)
    yk, kk = tk.result
    yr, kr = ref.r2f2_quantize_ref(x, fmt=fmt)
    match = np.array_equal(np.asarray(yk), np.asarray(yr))
    print(
        f"kernel/r2f2_quantize_1024,{tk.us_per_call:.0f},"
        f"bitexact_vs_ref={match};compile_us={tk.compile_us:.0f}"
    )

    a = rng.normal(0, 1, (512, 512)).astype(np.float32)
    b = rng.normal(0, 0.05, (512, 512)).astype(np.float32)
    tm = measure(ops.r2f2_matmul, a, b, fmt)
    cm = tm.result
    cr = ref.r2f2_matmul_ref(a, b, fmt=fmt)
    dev = float(np.max(np.abs(np.asarray(cm) - np.asarray(cr))))
    rel = float(np.linalg.norm(np.asarray(cm) - a @ b) / np.linalg.norm(a @ b))
    gflops = 2 * 512**3 / (tm.us_per_call / 1e6) / 1e9
    print(
        f"kernel/r2f2_matmul_512,{tm.us_per_call:.0f},"
        f"max_dev_vs_ref={dev:.2e};rel_vs_f32={rel:.5f};"
        f"emul_gflops={gflops:.2f};compile_us={tm.compile_us:.0f}"
    )

    u0 = (500 * np.sin(np.linspace(0, 3 * np.pi, 1024))[None] * np.ones((8, 1))).astype(np.float32)
    th = measure(ops.heat_stencil, u0, 1e-5, 4e4, fmt, steps=10)
    hr = ref.heat_stencil_ref(u0, 1e-5, 4e4, fmt=fmt, steps=10)
    hmatch = np.array_equal(np.asarray(th.result), np.asarray(hr))
    print(
        f"kernel/heat_stencil_8x1024x10,{th.us_per_call:.0f},"
        f"bitexact_vs_ref={hmatch};compile_us={th.compile_us:.0f}"
    )

    q3 = (500.0 + 100 * rng.normal(size=(128, 256))).astype(np.float32)
    q1 = (q3 * rng.normal(0, 5, (128, 256))).astype(np.float32)
    ts = measure(ops.swe_flux, q1, q3, fmt)
    fr = ref.swe_flux_ref(q1, q3, fmt=fmt)
    smatch = np.array_equal(np.asarray(ts.result), np.asarray(fr))
    print(
        f"kernel/swe_flux_128x256,{ts.us_per_call:.0f},"
        f"bitexact_vs_ref={smatch};compile_us={ts.compile_us:.0f}"
    )


if __name__ == "__main__":
    main()
