"""Kernel microbench: Pallas (interpret on CPU) vs pure-jnp oracle.

Paper Table 1 is FPGA LUT/FF area — N/A on TPU (DESIGN.md §2). The TPU
replacement cost story is measured here instead: the *deployment* path runs
at bf16 operand width (half of f32's HBM bytes at equal MXU rate), while
the bit-exact emulation path costs ~8 elementwise u32 ops per quantization.
On CPU the numbers below time the emulation; on a TPU backend the same
call sites compile the Pallas kernels natively.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flexformat import FlexFormat
from repro.kernels import ops, ref


def _time(fn, *args, iters=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out


def main():
    rng = np.random.default_rng(3)
    fmt = FlexFormat(3, 9, 3)

    x = rng.normal(0, 1, (1024, 1024)).astype(np.float32)
    us_k, (yk, kk) = _time(ops.r2f2_quantize, x, fmt)
    yr, kr = ref.r2f2_quantize_ref(x, fmt=fmt)
    match = np.array_equal(np.asarray(yk), np.asarray(yr))
    print(f"kernel/r2f2_quantize_1024,{us_k:.0f},bitexact_vs_ref={match}")

    a = rng.normal(0, 1, (512, 512)).astype(np.float32)
    b = rng.normal(0, 0.05, (512, 512)).astype(np.float32)
    us_m, cm = _time(ops.r2f2_matmul, a, b, fmt)
    cr = ref.r2f2_matmul_ref(a, b, fmt=fmt)
    dev = float(np.max(np.abs(np.asarray(cm) - np.asarray(cr))))
    rel = float(np.linalg.norm(np.asarray(cm) - a @ b) / np.linalg.norm(a @ b))
    gflops = 2 * 512**3 / (us_m / 1e6) / 1e9
    print(f"kernel/r2f2_matmul_512,{us_m:.0f},max_dev_vs_ref={dev:.2e};rel_vs_f32={rel:.5f};emul_gflops={gflops:.2f}")

    u0 = (500 * np.sin(np.linspace(0, 3 * np.pi, 1024))[None] * np.ones((8, 1))).astype(np.float32)
    us_h, hk = _time(ops.heat_stencil, u0, 1e-5, 4e4, fmt, steps=10)
    hr = ref.heat_stencil_ref(u0, 1e-5, 4e4, fmt=fmt, steps=10)
    hmatch = np.array_equal(np.asarray(hk), np.asarray(hr))
    print(f"kernel/heat_stencil_8x1024x10,{us_h:.0f},bitexact_vs_ref={hmatch}")

    q3 = (500.0 + 100 * rng.normal(size=(128, 256))).astype(np.float32)
    q1 = (q3 * rng.normal(0, 5, (128, 256))).astype(np.float32)
    us_s, fk = _time(ops.swe_flux, q1, q3, fmt)
    fr = ref.swe_flux_ref(q1, q3, fmt=fmt)
    smatch = np.array_equal(np.asarray(fk), np.asarray(fr))
    print(f"kernel/swe_flux_128x256,{us_s:.0f},bitexact_vs_ref={smatch}")


if __name__ == "__main__":
    main()
