"""Benchmark harness: one function per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only mul,heat,swe,kernels,roofline]

Prints ``name,us_per_call,derived`` CSV lines per bench.
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    if want("mul"):
        from benchmarks import bench_mul_accuracy
        bench_mul_accuracy.main()
        print()
    if want("exploration"):
        from benchmarks import bench_exploration
        bench_exploration.main()
        print()
    if want("heat"):
        from benchmarks import bench_heat
        bench_heat.main()
        print()
    if want("swe"):
        from benchmarks import bench_swe
        bench_swe.main()
        print()
    if want("kernels"):
        from benchmarks import bench_kernels
        bench_kernels.main()
        print()
    if want("roofline"):
        from benchmarks import roofline
        roofline.main()


if __name__ == "__main__":
    main()
