"""Benchmark harness: one function per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only mul,heat,swe,pde,service,kernels,roofline]
                                            [--json-dir artifacts/bench] [--smoke]

Most benches print ``name,us_per_call,derived`` CSV lines; the harness
captures them and emits one machine-readable ``BENCH_<suite>.json`` per
suite so the perf trajectory accumulates across commits (CI keeps these as
artifacts). Suites with non-CSV output (e.g. roofline's table) are kept as
raw text lines instead of parsed rows. JSON schema:

    {"suite": str, "unix_time": float, "backend": str, "git_sha": str|null,
     "rows": [{"name": str, "us_per_call": float, "derived": str}],
     "raw_lines": [str]}   # only when no CSV rows were found

``git_sha`` + ``backend`` pin every BENCH json to the commit and JAX
backend that produced it, so the accumulated artifact trajectory is
attributable without relying on CI-side bookkeeping.
"""

import argparse
import contextlib
import inspect
import io
import json
import os
import subprocess
import time

SUITES = ("mul", "exploration", "heat", "swe", "pde", "service", "kernels", "roofline")


def _git_sha():
    """Commit that produced this BENCH json (None outside a git checkout)."""
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            text=True,
            stderr=subprocess.DEVNULL,
        ).strip()
    except Exception:
        return None


def _run_suite(name: str, smoke: bool = False) -> str:
    """Import lazily and run one suite, returning its captured stdout."""
    if name == "mul":
        from benchmarks import bench_mul_accuracy as mod
    elif name == "exploration":
        from benchmarks import bench_exploration as mod
    elif name == "heat":
        from benchmarks import bench_heat as mod
    elif name == "swe":
        from benchmarks import bench_swe as mod
    elif name == "pde":
        from benchmarks import bench_pde as mod
    elif name == "service":
        from benchmarks import bench_service as mod
    elif name == "kernels":
        from benchmarks import bench_kernels as mod
    elif name == "roofline":
        from benchmarks import roofline as mod
    else:
        raise ValueError(f"unknown suite {name!r}")

    # suites that implement a reduced-step smoke tier accept main(smoke=...);
    # the rest run their usual size regardless of --smoke
    kwargs = {}
    if smoke and "smoke" in inspect.signature(mod.main).parameters:
        kwargs["smoke"] = True
    buf = io.StringIO()
    try:
        with contextlib.redirect_stdout(buf):
            mod.main(**kwargs)
    except BaseException:
        # surface whatever the suite printed before dying, then the traceback
        print(buf.getvalue(), end="")
        raise
    return buf.getvalue()


def _parse_rows(text: str):
    """``name,us_per_call,derived`` CSV lines -> row dicts (others ignored)."""
    rows = []
    for line in text.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) < 2 or "/" not in parts[0]:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows.append(
            {
                "name": parts[0],
                "us_per_call": us,
                "derived": parts[2] if len(parts) > 2 else "",
            }
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument(
        "--json-dir",
        default=".",
        help="directory for BENCH_<suite>.json files (created if missing)",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="reduced-step tier for per-push CI (suites that support it)",
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    os.makedirs(args.json_dir, exist_ok=True)

    import jax

    git_sha = _git_sha()
    for suite in SUITES:
        if only is not None and suite not in only:
            continue
        text = _run_suite(suite, smoke=args.smoke)
        print(text, end="")
        print()
        record = {
            "suite": suite,
            "unix_time": time.time(),
            "backend": jax.default_backend(),
            "git_sha": git_sha,
            "smoke": args.smoke,
            "rows": _parse_rows(text),
        }
        if not record["rows"]:  # non-CSV suite: keep the output verbatim
            record["raw_lines"] = [l for l in text.splitlines() if l.strip()]
        path = os.path.join(args.json_dir, f"BENCH_{suite}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
        n = len(record["rows"]) or len(record.get("raw_lines", []))
        kind = "rows" if record["rows"] else "raw lines"
        print(f"[bench] wrote {path} ({n} {kind})")


if __name__ == "__main__":
    main()
