"""Benchmark harness: one function per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only mul,heat,swe,pde,service,kernels,roofline]
                                            [--json-dir artifacts/bench] [--smoke]
                                            [--check] [--check-tol 10.0]

Most benches print ``name,us_per_call,derived`` CSV lines; the harness
captures them and emits one machine-readable ``BENCH_<suite>.json`` per
suite so the perf trajectory accumulates across commits (CI keeps these as
artifacts). Suites with non-CSV output (e.g. roofline's table) are kept as
raw text lines instead of parsed rows. JSON schema:

    {"suite": str, "unix_time": float, "backend": str, "git_sha": str|null,
     "rows": [{"name": str, "us_per_call": float, "derived": str}],
     "raw_lines": [str]}   # only when no CSV rows were found

``git_sha`` + ``backend`` pin every BENCH json to the commit and JAX
backend that produced it, so the accumulated artifact trajectory is
attributable without relying on CI-side bookkeeping.

``--check`` turns the harness into a regression gate: the committed
``BENCH_<suite>.json`` files already in ``--json-dir`` are loaded as the
baseline BEFORE the suites overwrite them, and every fresh row is compared
against the baseline row of the same name. Structural metrics regressing is
a hard failure (nonzero exit): ``bytes_per_step`` (the packed plane's
bandwidth claim) and ``launches`` (the megakernel's whole-horizon claim)
must not grow, and a nonzero health ``alerts`` count on a service row is
a hard failure too — the bench burst is healthy traffic, so an alert
firing during it means a numerics or serving regression. Wall time is
noisy, so ``us_per_call`` beyond ``--check-tol``
x the baseline only warns (and only when the fresh and baseline smoke tiers
match); a measured time BELOW the row's own analytic bandwidth bound
(``bytes_per_step / HBM_BW``) also warns — that is measurement error, not
speed. CI runs the smoke tier with ``--check`` after the bench step.
"""

import argparse
import contextlib
import inspect
import io
import json
import os
import subprocess
import time

SUITES = ("mul", "exploration", "heat", "swe", "pde", "service", "kernels", "roofline")


def _git_sha():
    """Commit that produced this BENCH json (None outside a git checkout)."""
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            text=True,
            stderr=subprocess.DEVNULL,
        ).strip()
    except Exception:
        return None


def _run_suite(name: str, smoke: bool = False) -> str:
    """Import lazily and run one suite, returning its captured stdout."""
    if name == "mul":
        from benchmarks import bench_mul_accuracy as mod
    elif name == "exploration":
        from benchmarks import bench_exploration as mod
    elif name == "heat":
        from benchmarks import bench_heat as mod
    elif name == "swe":
        from benchmarks import bench_swe as mod
    elif name == "pde":
        from benchmarks import bench_pde as mod
    elif name == "service":
        from benchmarks import bench_service as mod
    elif name == "kernels":
        from benchmarks import bench_kernels as mod
    elif name == "roofline":
        from benchmarks import roofline as mod
    else:
        raise ValueError(f"unknown suite {name!r}")

    # suites that implement a reduced-step smoke tier accept main(smoke=...);
    # the rest run their usual size regardless of --smoke
    kwargs = {}
    if smoke and "smoke" in inspect.signature(mod.main).parameters:
        kwargs["smoke"] = True
    buf = io.StringIO()
    try:
        with contextlib.redirect_stdout(buf):
            mod.main(**kwargs)
    except BaseException:
        # surface whatever the suite printed before dying, then the traceback
        print(buf.getvalue(), end="")
        raise
    return buf.getvalue()


def _parse_rows(text: str):
    """``name,us_per_call,derived`` CSV lines -> row dicts (others ignored)."""
    rows = []
    for line in text.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) < 2 or "/" not in parts[0]:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows.append(
            {
                "name": parts[0],
                "us_per_call": us,
                "derived": parts[2] if len(parts) > 2 else "",
            }
        )
    return rows


def _parse_derived(derived: str) -> dict:
    """``k=v;k=v`` derived strings -> dict (tokens without '=' ignored)."""
    out = {}
    for part in derived.split(";"):
        k, sep, v = part.partition("=")
        if sep:
            out[k] = v
    return out


#: derived keys whose growth vs the baseline is a hard --check failure:
#: bytes_per_step is the packed storage plane's bandwidth claim, launches
#: is the megakernel's one-pallas_call-per-horizon claim
CHECK_STRUCTURAL = ("bytes_per_step", "launches")


def check_records(fresh: dict, baselines: dict, tol: float = 10.0):
    """Compare fresh suite records against the committed baselines.

    Returns ``(failures, warnings)`` — string lists. Failures: a
    :data:`CHECK_STRUCTURAL` metric grew on a row present in both, or a
    service row reporting a nonzero health ``alerts`` count — the bench
    burst is healthy traffic, so any alert (overflow storm, k-thrash,
    SLO breach) firing during it is a real numerics/serving regression,
    baseline or not. Warnings:
    ``us_per_call`` beyond ``tol`` x baseline on matching smoke tiers, or a
    measured time below the row's own analytic bandwidth bound
    (``bytes_per_step`` at :data:`benchmarks.roofline.HBM_BW` — beating the
    roofline is measurement error, not speed).
    """
    from benchmarks.roofline import HBM_BW

    failures, warnings = [], []
    for suite, rec in fresh.items():
        base = baselines.get(suite)
        base_rows = (
            {r["name"]: r for r in base.get("rows", [])} if base is not None else {}
        )
        for row in rec.get("rows", []):
            d = _parse_derived(row.get("derived", ""))
            # health gate: alerts during the bench burst are a hard failure
            # with or without a baseline (the burst itself is healthy traffic)
            try:
                n_alerts = int(d.get("alerts", 0))
            except ValueError:
                n_alerts = 0
            if n_alerts > 0:
                failures.append(
                    f"{row['name']}: {n_alerts} health alert(s) fired in the "
                    "bench burst (expected a clean run)"
                )
            b = base_rows.get(row["name"])
            if b is not None:
                bd = _parse_derived(b.get("derived", ""))
                for key in CHECK_STRUCTURAL:
                    if key in d and key in bd and int(d[key]) > int(bd[key]):
                        failures.append(
                            f"{row['name']}: {key} regressed "
                            f"{bd[key]} -> {d[key]}"
                        )
                if (
                    base.get("smoke") == rec.get("smoke")
                    and b["us_per_call"] > 0
                    and row["us_per_call"] > tol * b["us_per_call"]
                ):
                    warnings.append(
                        f"{row['name']}: us_per_call {b['us_per_call']:.2f} -> "
                        f"{row['us_per_call']:.2f} "
                        f"({row['us_per_call'] / b['us_per_call']:.1f}x baseline, "
                        f"tol {tol:.1f}x)"
                    )
            # bound sanity only applies to MEASURED rows — the roofline
            # suite's rows ARE the analytic bound and would flag themselves
            if "bytes_per_step" in d and not row["name"].startswith("roofline/"):
                bound_us = float(d["bytes_per_step"]) / HBM_BW * 1e6
                if 0 < row["us_per_call"] < bound_us:
                    warnings.append(
                        f"{row['name']}: measured {row['us_per_call']:.4f}us "
                        f"beats the analytic bandwidth bound {bound_us:.4f}us "
                        "— measurement error?"
                    )
    return failures, warnings


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument(
        "--json-dir",
        default=".",
        help="directory for BENCH_<suite>.json files (created if missing)",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="reduced-step tier for per-push CI (suites that support it)",
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="gate fresh rows against the BENCH jsons committed in "
        "--json-dir (loaded before the suites overwrite them); structural "
        "regressions (bytes_per_step, launches) exit nonzero",
    )
    ap.add_argument(
        "--check-tol",
        type=float,
        default=10.0,
        help="us_per_call warn threshold as a multiple of the baseline",
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    os.makedirs(args.json_dir, exist_ok=True)

    # --check baselines: snapshot the committed jsons before overwriting
    baselines = {}
    if args.check:
        for suite in SUITES:
            path = os.path.join(args.json_dir, f"BENCH_{suite}.json")
            if os.path.exists(path):
                try:
                    with open(path) as f:
                        baselines[suite] = json.load(f)
                except (OSError, ValueError):
                    pass  # unreadable baseline: nothing to gate against

    import jax

    git_sha = _git_sha()
    fresh = {}
    for suite in SUITES:
        if only is not None and suite not in only:
            continue
        text = _run_suite(suite, smoke=args.smoke)
        print(text, end="")
        print()
        record = {
            "suite": suite,
            "unix_time": time.time(),
            "backend": jax.default_backend(),
            "git_sha": git_sha,
            "smoke": args.smoke,
            "rows": _parse_rows(text),
        }
        if not record["rows"]:  # non-CSV suite: keep the output verbatim
            record["raw_lines"] = [l for l in text.splitlines() if l.strip()]
        fresh[suite] = record
        path = os.path.join(args.json_dir, f"BENCH_{suite}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
        n = len(record["rows"]) or len(record.get("raw_lines", []))
        kind = "rows" if record["rows"] else "raw lines"
        print(f"[bench] wrote {path} ({n} {kind})")

    if args.check:
        failures, warnings = check_records(fresh, baselines, tol=args.check_tol)
        for w in warnings:
            print(f"[bench --check] WARN {w}")
        for f_ in failures:
            print(f"[bench --check] FAIL {f_}")
        checked = [s for s in fresh if s in baselines]
        print(
            f"[bench --check] {len(checked)} suite(s) gated "
            f"({', '.join(checked) or 'none with baselines'}): "
            f"{len(failures)} failure(s), {len(warnings)} warning(s)"
        )
        if failures:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
