"""Generic per-stepper PDE benchmark — every registered solver workload
through the same precision ladder, on ALL execution planes.

One scenario per registered stepper (``repro.pde.known_steppers``): run the
f32 reference, then each precision in the ladder under
``execution="reference"`` (the stepwise StepOps engine path),
``execution="fused"`` (whole snapshot intervals as Pallas kernel chunks)
AND ``execution="megakernel"`` (the entire horizon in ONE pallas_call,
DESIGN.md §14), reporting per-step wall time, the paper's correctness
verdict (relative L2 for decaying fields, field correlation for the SWE
basin), static op counts of one snapshot-chunk program (``pallas`` =
pallas_call count — the fused plane collapses a chunk into one; ``hlo`` =
lowered instruction count), the whole-horizon launch count (``launches`` =
scan-weighted pallas_call count of the full run's program: ``steps/every``
for the chunked plane, exactly 1 for the megakernel — asserted, that IS
the tentpole claim), and the §5.3 adjustment counters
(``adj=+grow/-shrink``) for tracked runs. ``main`` fails loudly if a
registered stepper has no scenario, so adding a workload without
benchmarking it is impossible.

CSV rows: ``pde/<case>/<prec>/<exec>,us_per_step,rel=..;corr=..;STATUS;...``
— captured by ``benchmarks.run`` into ``BENCH_pde.json``. ``--smoke`` (or
``main(smoke=True)``) caps step counts for the CI fast tier, so the bench
trajectory accumulates on every push.

Storage pairing: for the rr precisions in :data:`PACKED_PRECS`, every fused
row gets a paired ``fused+packed`` row — the same chunked program carrying
R2F2-packed state (``storage="packed"``) between chunk boundaries instead
of f32 — and both report ``bytes_per_step`` (2x the carried-state footprint:
one read + one write per step at the storage boundary,
``repro.pack.state_nbytes``). The packed row's bytes must come in under the
f32 row's — that IS the bandwidth claim, regression-checked per push.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional, Tuple

import numpy as np

from repro.pack import is_packed, state_nbytes, unpack_state
from repro.precision import PRESETS
from repro.pde import Simulation, get_stepper, known_steppers

DEFAULT_PRECS = ("e5m10", "r2f2_16", "r2f2_15", "bf16", "rr_tracked")
#: rr precisions whose fused rows get a paired ``fused+packed`` storage row
PACKED_PRECS = ("r2f2_16", "rr_tracked")
SMOKE_STEPS = 60

#: the bench ladder's precision configs: the PRESETS plus the tracked rr
#: mode (the adjustment-counter story needs a carried tracker)
PREC_LADDER = dict(
    PRESETS,
    rr_tracked=dataclasses.replace(PRESETS["r2f2_16"], mode="rr_tracked"),
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One benchmarked configuration of a registered stepper."""

    cfg: Any
    steps: int
    precs: Tuple[str, ...] = DEFAULT_PRECS
    judge: str = "rel"  # "rel": rel_l2 < 0.1 | "corr": field corr > 0.98
    offset: float = 0.0  # constant background removed before the metrics
    label: Optional[str] = None


def scenarios():
    """Scenario table, keyed by stepper name (configs/* are the source of
    figure-faithful shapes/steps)."""
    from repro.configs import advection1d, burgers1d, heat1d, heat2d, swe2d

    return {
        "heat1d": Scenario(heat1d.CONFIG, heat1d.BENCH_STEPS["sin"]),
        "heat2d": Scenario(heat2d.CONFIG, heat2d.BENCH_STEPS),
        "advection1d": Scenario(advection1d.CONFIG, advection1d.BENCH_STEPS),
        "burgers1d": Scenario(burgers1d.CONFIG, burgers1d.BENCH_STEPS),
        "swe2d": Scenario(
            swe2d.CONFIG,
            swe2d.BENCH_STEPS,
            precs=("e5m10", "r2f2_16", "r2f2_16_384", "bf16", "rr_tracked"),
            judge="corr",
            offset=swe2d.CONFIG.depth,
        ),
    }


def observe(stepper, cfg, state, offset: float = 0.0):
    """A run's observable as a metrics-ready array (background removed)."""
    return np.asarray(stepper.observables(state, cfg)) - offset


def measure(out, ref, judge: str = "rel"):
    """The suite's single verdict logic: finite / rel L2 / corr / correct.

    Shared with examples/pde_zoo.py so the zoo's printout and
    BENCH_pde.json can never disagree about a workload.
    """
    finite = bool(np.isfinite(out).all())
    if finite:
        rel = float(np.linalg.norm(out - ref) / np.linalg.norm(ref))
        corr = float(np.corrcoef(out.reshape(-1), ref.reshape(-1))[0, 1])
    else:
        rel, corr = float("nan"), float("nan")
    ok = finite and (corr > 0.98 if judge == "corr" else rel < 0.1)
    return dict(rel=rel, corr=corr, finite=finite, correct=ok)


def _iter_subjaxprs(v):
    vals = v if isinstance(v, (list, tuple)) else (v,)
    for w in vals:
        inner = getattr(w, "jaxpr", w)
        if hasattr(inner, "eqns"):
            yield inner


def _count_pallas_weighted(jaxpr) -> int:
    """pallas_call count with scan trip counts multiplied through — i.e.
    the number of kernel LAUNCHES the program issues at runtime, not the
    number of call sites in the jaxpr text."""
    n = 0
    for eqn in jaxpr.eqns:
        w = eqn.params.get("length", 1) if eqn.primitive.name == "scan" else 1
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in _iter_subjaxprs(v):
                n += w * _count_pallas_weighted(sub)
    return n


def chunk_op_counts(sim: Simulation, chunk: int, execution: str, storage: str = "f32"):
    """Static op counts of one snapshot-chunk program: (pallas_calls,
    lowered instruction count). The fused plane's signature is one
    pallas_call per chunk where the reference plane scans per-step engine
    ops."""
    import jax

    state0 = sim.stepper.init_state(sim.cfg)

    def fn(s0):
        return sim.run(
            chunk, snapshot_every=chunk, state0=s0, execution=execution,
            storage=storage,
        ).state

    traced = jax.jit(fn).trace(state0)  # one trace serves both counts
    n_pallas = _count_pallas_weighted(traced.jaxpr.jaxpr)
    lowered = traced.lower().as_text()
    n_hlo = sum(1 for line in lowered.splitlines() if " = " in line)
    return n_pallas, n_hlo


def horizon_launches(
    sim: Simulation, steps: int, every: int, execution: str, storage: str = "f32"
) -> int:
    """Kernel launches of the FULL horizon program (scan-weighted
    pallas_call count): ``steps/every`` chunks on the fused plane, 0 on the
    reference plane, and — the whole point — exactly 1 on the megakernel
    plane, snapshots and remainder included."""
    import jax

    state0 = sim.stepper.init_state(sim.cfg)

    def fn(s0):
        return sim.run(
            steps, snapshot_every=every, state0=s0, execution=execution,
            storage=storage,
        ).state

    return _count_pallas_weighted(jax.jit(fn).trace(state0).jaxpr.jaxpr)


def run_case(name: str, sc: Scenario, smoke: bool = False):
    """f32 reference + precision ladder, reference-vs-fused paired rows."""
    stepper = get_stepper(name)
    cfg = sc.cfg
    steps = min(sc.steps, SMOKE_STEPS) if smoke else sc.steps
    chunk = max(1, steps // stepper.snapshots_default)
    ref = observe(
        stepper, cfg, Simulation(name, cfg, PRESETS["f32"]).run(steps).state, sc.offset
    )
    rows = []
    for prec_name in sc.precs:
        prec = PREC_LADDER[prec_name]
        # chunked-vs-mega paired rows: every fused row gets a megakernel
        # partner (same storage), so launches/bytes/us compare side by side
        storages = [("reference", "f32"), ("fused", "f32"), ("megakernel", "f32")]
        if prec_name in PACKED_PRECS:
            storages.append(("fused", "packed"))  # the bandwidth pair row
            storages.append(("megakernel", "packed"))
        for execution, storage in storages:
            sim = Simulation(name, cfg, prec)
            if execution == "fused" and not sim.fused_eligible():
                continue  # mode/stepper outside the fused plane: no pair row
            if execution == "megakernel" and not sim.mega_eligible():
                continue  # outside the megakernel plane: no pair row
            t0 = time.perf_counter()
            res = sim.run(steps, execution=execution, storage=storage)
            state = res.state
            out_state = unpack_state(state) if is_packed(state) else state
            out = observe(stepper, cfg, out_state, sc.offset)
            us = (time.perf_counter() - t0) * 1e6 / steps
            n_pallas, n_hlo = chunk_op_counts(sim, chunk, execution, storage)
            launches = horizon_launches(sim, steps, chunk, execution, storage)
            if execution == "megakernel" and launches != 1:
                raise SystemExit(
                    f"megakernel row {name}/{prec_name}/{storage} issued "
                    f"{launches} kernel launches for the horizon; the "
                    "whole-horizon contract is exactly 1"
                )
            row = dict(
                case=sc.label or name,
                prec=prec_name,
                execution=execution if storage == "f32" else f"{execution}+{storage}",
                us_per_step=us,
                pallas_calls=n_pallas,
                hlo_ops=n_hlo,
                launches=launches,
                # one read + one write of the carried state per step
                bytes_per_step=2 * state_nbytes(state),
                **measure(out, ref, sc.judge),
            )
            if res.tracker is not None:  # §5.3 adjustment counters
                row["grow_adjusts"] = int(np.asarray(res.tracker.state.overflow_steps).sum())
                row["shrink_adjusts"] = int(np.asarray(res.tracker.state.shrink_steps).sum())
            rows.append(row)
    return rows


def format_row(r, suite: str = "pde") -> str:
    status = (
        "DESTROYED(NaN)"
        if not r["finite"]
        else ("CORRECT" if r["correct"] else "WRONG")
    )
    derived = (
        f"rel={r['rel']:.4f};corr={r['corr']:.4f};{status};"
        f"pallas={r['pallas_calls']};hlo={r['hlo_ops']}"
        f";launches={r['launches']}"
        f";bytes_per_step={r['bytes_per_step']}"
    )
    if "grow_adjusts" in r:
        derived += f";adj=+{r['grow_adjusts']}/-{r['shrink_adjusts']}"
    return f"{suite}/{r['case']}/{r['prec']}/{r['execution']},{r['us_per_step']:.1f},{derived}"


def main(smoke: bool = False):
    table = scenarios()
    missing = [s for s in known_steppers() if s not in table]
    if missing:
        raise SystemExit(f"steppers without a bench scenario: {missing}")
    print("# per-stepper precision ladder x execution plane:")
    print("# E5M10 fails its way, R2F2-16 matches f32; fused == reference in 1 pallas_call/chunk")
    for name in known_steppers():
        sc = table[name]
        st = get_stepper(name)
        print(f"# {name} [{st.failure_mode}] {st.story}")
        for r in run_case(name, sc, smoke=smoke):
            print(format_row(r))


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
