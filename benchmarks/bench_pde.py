"""Generic per-stepper PDE benchmark — every registered solver workload
through the same precision ladder.

One scenario per registered stepper (``repro.pde.known_steppers``): run the
f32 reference, then each precision in the ladder, and report per-step time
plus the paper's correctness verdict (relative L2 for decaying fields, field
correlation for the SWE basin, exactly as the per-workload benches judged).
``main`` fails loudly if a registered stepper has no scenario, so adding a
workload without benchmarking it is impossible.

CSV rows: ``pde/<case>/<prec>,us_per_step,rel=..;corr=..;STATUS`` — captured
by ``benchmarks.run`` into ``BENCH_pde.json``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional, Tuple

import numpy as np

from repro.precision import PRESETS
from repro.pde import Simulation, get_stepper, known_steppers

DEFAULT_PRECS = ("e5m10", "r2f2_16", "r2f2_15", "bf16")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One benchmarked configuration of a registered stepper."""

    cfg: Any
    steps: int
    precs: Tuple[str, ...] = DEFAULT_PRECS
    judge: str = "rel"  # "rel": rel_l2 < 0.1 | "corr": field corr > 0.98
    offset: float = 0.0  # constant background removed before the metrics
    label: Optional[str] = None


def scenarios():
    """Scenario table, keyed by stepper name (configs/* are the source of
    figure-faithful shapes/steps)."""
    from repro.configs import advection1d, burgers1d, heat1d, heat2d, swe2d

    return {
        "heat1d": Scenario(heat1d.CONFIG, heat1d.BENCH_STEPS["sin"]),
        "heat2d": Scenario(heat2d.CONFIG, heat2d.BENCH_STEPS),
        "advection1d": Scenario(advection1d.CONFIG, advection1d.BENCH_STEPS),
        "burgers1d": Scenario(burgers1d.CONFIG, burgers1d.BENCH_STEPS),
        "swe2d": Scenario(
            swe2d.CONFIG,
            swe2d.BENCH_STEPS,
            precs=("e5m10", "r2f2_16", "r2f2_16_384", "bf16"),
            judge="corr",
            offset=swe2d.CONFIG.depth,
        ),
    }


def observe(stepper, cfg, state, offset: float = 0.0):
    """A run's observable as a metrics-ready array (background removed)."""
    return np.asarray(stepper.observables(state, cfg)) - offset


def measure(out, ref, judge: str = "rel"):
    """The suite's single verdict logic: finite / rel L2 / corr / correct.

    Shared with examples/pde_zoo.py so the zoo's printout and
    BENCH_pde.json can never disagree about a workload.
    """
    finite = bool(np.isfinite(out).all())
    if finite:
        rel = float(np.linalg.norm(out - ref) / np.linalg.norm(ref))
        corr = float(np.corrcoef(out.reshape(-1), ref.reshape(-1))[0, 1])
    else:
        rel, corr = float("nan"), float("nan")
    ok = finite and (corr > 0.98 if judge == "corr" else rel < 0.1)
    return dict(rel=rel, corr=corr, finite=finite, correct=ok)


def run_case(name: str, sc: Scenario):
    """f32 reference + precision ladder for one scenario -> row dicts."""
    stepper = get_stepper(name)
    cfg = sc.cfg
    ref = observe(
        stepper, cfg, Simulation(name, cfg, PRESETS["f32"]).run(sc.steps).state, sc.offset
    )
    rows = []
    for prec in sc.precs:
        t0 = time.perf_counter()
        out = observe(
            stepper, cfg, Simulation(name, cfg, PRESETS[prec]).run(sc.steps).state, sc.offset
        )
        us = (time.perf_counter() - t0) * 1e6 / sc.steps
        rows.append(
            dict(case=sc.label or name, prec=prec, us_per_step=us, **measure(out, ref, sc.judge))
        )
    return rows


def format_row(r, suite: str = "pde") -> str:
    status = (
        "DESTROYED(NaN)"
        if not r["finite"]
        else ("CORRECT" if r["correct"] else "WRONG")
    )
    return (
        f"{suite}/{r['case']}/{r['prec']},{r['us_per_step']:.1f},"
        f"rel={r['rel']:.4f};corr={r['corr']:.4f};{status}"
    )


def main():
    table = scenarios()
    missing = [s for s in known_steppers() if s not in table]
    if missing:
        raise SystemExit(f"steppers without a bench scenario: {missing}")
    print("# per-stepper precision ladder: E5M10 fails its way, R2F2-16 matches f32")
    for name in known_steppers():
        sc = table[name]
        st = get_stepper(name)
        print(f"# {name} [{st.failure_mode}] {st.story}")
        for r in run_case(name, sc):
            print(format_row(r))


if __name__ == "__main__":
    main()
