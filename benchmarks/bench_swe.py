"""Paper Fig. 8 — shallow-water-equation case study.

As in the paper, only the multiplications of the x-midpoint momentum-flux
equation run on the low-precision multiplier. With a realistic basin
(h ~ 500 m) the h*h term (~2.5e5) overflows E5M10's 65504 ceiling and the
simulation is destroyed, while R2F2 widens its exponent at runtime and
tracks the f32 reference (field correlation ~ visual identity in the
paper's plots). Adjustment counters reported per §5.3.

The precision-ladder table runs on the generic per-stepper harness
(``benchmarks.bench_pde.run_case``); this module keeps the Fig. 8 scenario
plus the §5.3 sequential-multiplier counters.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.bench_pde import run_case, scenarios
from repro.core import FlexFormat, r2f2_mul_sequential
from repro.precision import PRESETS
from repro.pde import SWEConfig, simulate_swe

def run():
    # the one scenario definition lives in bench_pde.scenarios(), so this
    # figure bench and BENCH_pde.json always report the same configuration
    return run_case("swe2d", scenarios()["swe2d"])


def adjustment_counts():
    """§5.3: sequential multiplier over the substituted equation's operand
    stream (paper: 7 overflow / 15 redundancy in 30K muls)."""
    cfg = SWEConfig()
    U, _ = simulate_swe(cfg, PRESETS["f32"], 50)
    h = jnp.asarray(U[0]).reshape(-1)[:15000]
    _, st = r2f2_mul_sequential(h, h, FlexFormat(3, 8, 4))
    return int(h.size), int(st.overflow_adjusts), int(st.redundancy_adjusts)


def main():
    print("# paper Fig. 8 — SWE: E5M10 destroys the simulation, R2F2 tracks f32")
    for r in run():
        # keep the historical row names/verdicts (swe/<prec>, DEGRADED for
        # finite-but-off) so BENCH_swe.json rows stay keyed consistently;
        # us_per_step now includes host materialization like every other
        # suite (the pre-refactor swe bench stopped the clock earlier)
        status = (
            "DESTROYED(NaN)"
            if not r["finite"]
            else ("CORRECT" if r["corr"] > 0.98 else "DEGRADED")
        )
        print(
            f"swe/{r['prec']},{r['us_per_step']:.1f},"
            f"wave_rel={r['rel']:.4f};corr={r['corr']:.4f};{status}"
        )
    n, ovf, red = adjustment_counts()
    print(f"# paper §5.3: 7 overflow / 15 redundancy adjustments in 30K muls")
    print(f"swe/adjustments,{n},overflow_adjusts={ovf};redundancy_adjusts={red}")


if __name__ == "__main__":
    main()
