"""Paper Fig. 8 — shallow-water-equation case study.

As in the paper, only the multiplications of the x-midpoint momentum-flux
equation run on the low-precision multiplier. With a realistic basin
(h ~ 500 m) the h*h term (~2.5e5) overflows E5M10's 65504 ceiling and the
simulation is destroyed, while R2F2 widens its exponent at runtime and
tracks the f32 reference (field correlation ~ visual identity in the
paper's plots). Adjustment counters reported per §5.3.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import FlexFormat, r2f2_mul_sequential
from repro.precision import PRESETS
from repro.pde import SWEConfig, simulate_swe

PRECS = ["e5m10", "r2f2_16", "r2f2_16_384", "bf16"]
STEPS = 400


def run():
    cfg = SWEConfig()
    ref, _ = simulate_swe(cfg, PRESETS["f32"], STEPS)
    wref = np.asarray(ref[0]) - cfg.depth
    rows = []
    for name in PRECS:
        t0 = time.perf_counter()
        out, _ = simulate_swe(cfg, PRESETS[name], STEPS)
        dt_us = (time.perf_counter() - t0) * 1e6 / STEPS
        wout = np.asarray(out[0]) - cfg.depth
        finite = bool(np.isfinite(wout).all())
        if finite:
            rel = float(np.linalg.norm(wout - wref) / np.linalg.norm(wref))
            corr = float(np.corrcoef(wout.reshape(-1), wref.reshape(-1))[0, 1])
        else:
            rel, corr = float("nan"), float("nan")
        rows.append(dict(prec=name, us_per_step=dt_us, rel=rel, corr=corr, finite=finite))
    return rows


def adjustment_counts():
    """§5.3: sequential multiplier over the substituted equation's operand
    stream (paper: 7 overflow / 15 redundancy in 30K muls)."""
    cfg = SWEConfig()
    U, _ = simulate_swe(cfg, PRESETS["f32"], 50)
    h = jnp.asarray(U[0]).reshape(-1)[:15000]
    _, st = r2f2_mul_sequential(h, h, FlexFormat(3, 8, 4))
    return int(h.size), int(st.overflow_adjusts), int(st.redundancy_adjusts)


def main():
    print("# paper Fig. 8 — SWE: E5M10 destroys the simulation, R2F2 tracks f32")
    for r in run():
        status = (
            "DESTROYED(NaN)"
            if not r["finite"]
            else ("CORRECT" if r["corr"] > 0.98 else "DEGRADED")
        )
        print(
            f"swe/{r['prec']},{r['us_per_step']:.1f},"
            f"wave_rel={r['rel']:.4f};corr={r['corr']:.4f};{status}"
        )
    n, ovf, red = adjustment_counts()
    print(f"# paper §5.3: 7 overflow / 15 redundancy adjustments in 30K muls")
    print(f"swe/adjustments,{n},overflow_adjusts={ovf};redundancy_adjusts={red}")


if __name__ == "__main__":
    main()
